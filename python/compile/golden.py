"""Golden-file generator: pins the Python oracle's MoBA gate + attention
outputs so the pure-Rust implementation (`rust/src/sparse/`) can be
checked bit-for-bit (gate) / to f32 round-off (attention).

Run by `make artifacts`; consumed by `rust/tests/golden_parity.rs`.
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from .kernels import ref

CASES = [
    # (name, n, heads, d, block, topk, seed)
    ("small", 64, 2, 8, 16, 2, 101),
    ("tall", 128, 1, 16, 32, 3, 202),
    ("fine", 96, 3, 8, 8, 4, 303),
    ("cover", 64, 2, 8, 16, 8, 404),  # topk covers everything
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/golden")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    for name, n, h, d, block, topk, seed in CASES:
        rng = np.random.default_rng(seed)
        q = rng.normal(size=(n, h, d)).astype("float32")
        k = rng.normal(size=(n, h, d)).astype("float32")
        v = rng.normal(size=(n, h, d)).astype("float32")
        gate = np.asarray(ref.moba_gate(q, k, block, topk))
        out = np.asarray(ref.moba_attention_ref(q, k, v, block, topk))
        doc = {
            "n": n, "heads": h, "d": d, "block": block, "topk": topk,
            "q": q.ravel().tolist(),
            "k": k.ravel().tolist(),
            "v": v.ravel().tolist(),
            "gate": gate.ravel().astype(int).tolist(),
            "out": out.ravel().tolist(),
        }
        path = os.path.join(args.out, f"{name}.json")
        with open(path, "w") as f:
            json.dump(doc, f)
        print(f"  golden {name}: {path}")


if __name__ == "__main__":
    main()
