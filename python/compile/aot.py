"""AOT lowering: every graph the Rust coordinator executes is produced here.

``python -m compile.aot --out ../artifacts [--group core|scaling|...]``

For each artifact spec this module traces the L2 function, lowers it to
stablehlo, converts to an XlaComputation and writes **HLO text** (NOT
``.serialize()`` — xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id
protos; the text parser reassigns ids; see /opt/xla-example/README.md).

A ``manifest.json`` is written next to the HLO files describing, for each
artifact: the model geometry, the ordered parameter spec (name/shape/init)
and the full ordered input/output signature. The Rust runtime drives
executables purely from this manifest. Lowering is incremental: an
artifact is re-lowered only if its spec hash changed or the file is gone.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.flash import flash_attention_pallas
from .kernels.moba import moba_attention_pallas

# ---------------------------------------------------------------------------
# model ladder (DESIGN.md §8 — Table 1 scaled; head_dim 16, vocab 512)
# ---------------------------------------------------------------------------

VOCAB = 512
HEAD_DIM = 16

# name -> (d_model, n_layers, n_heads)  [paper Table 1, /16-ish scale]
LADDER = {
    "s0": (48, 3, 3),
    "s1": (64, 4, 4),
    "s2": (96, 5, 6),
    "s3": (128, 6, 8),
    "s4": (160, 7, 10),
}


def ladder_cfg(size: str, *, block_size: int, topk: int,
               layer_variants=(), pi_scale: float = 1.0,
               attn_impl: str = "jnp") -> M.ModelCfg:
    d, l, h = LADDER[size]
    return M.ModelCfg(vocab=VOCAB, d_model=d, n_layers=l, n_heads=h,
                      head_dim=HEAD_DIM, block_size=block_size, topk=topk,
                      layer_variants=tuple(layer_variants), pi_scale=pi_scale,
                      attn_impl=attn_impl)


def variants(kind: str, n_layers: int, full_last: int = 0):
    """Layer-variant helper: 'moba'/'full' everywhere, or moba with the
    last ``full_last`` layers full (the paper's layer-wise hybrid)."""
    if kind == "full":
        return ("full",) * n_layers
    v = ["moba"] * n_layers
    for i in range(full_last):
        v[n_layers - 1 - i] = "full"
    return tuple(v)


# ---------------------------------------------------------------------------
# artifact specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Spec:
    name: str
    group: str
    # train | train_k | eval | logits | last_logits | kernel_moba | kernel_flash
    kind: str
    cfg: M.ModelCfg | None
    batch: int = 1
    seq: int = 256
    # kernel-artifact geometry
    heads: int = 4
    head_dim: int = 32
    # fused steps for kind == "train_k"
    k_steps: int = 8

    def hash(self) -> str:
        payload = json.dumps(dataclasses.asdict(self), sort_keys=True, default=str)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]


def build_specs() -> List[Spec]:
    specs: List[Spec] = []

    def add(name, group, kind, cfg, batch, seq):
        specs.append(Spec(name=name, group=group, kind=kind, cfg=cfg,
                          batch=batch, seq=seq))

    # ---- core / quickstart (tiny; pallas impl exercised through eval) ----
    qcfg = M.ModelCfg(vocab=256, d_model=32, n_layers=2, n_heads=2,
                      head_dim=16, block_size=32, topk=2)
    qcfg_pallas = dataclasses.replace(qcfg, attn_impl="pallas")
    add("quickstart_train", "core", "train", qcfg, 2, 256)
    add("quickstart_eval", "core", "eval", qcfg, 2, 256)
    add("quickstart_eval_pallas", "core", "eval", qcfg_pallas, 2, 256)
    add("quickstart_logits", "core", "logits", qcfg, 1, 256)
    add("quickstart_last_logits", "core", "last_logits", qcfg, 4, 256)
    # standalone L1 kernel artifacts (q,k,v -> out), run by rust runtime tests
    specs.append(Spec(name="kernel_moba_n256", group="core", kind="kernel_moba",
                      cfg=M.ModelCfg(block_size=32, topk=3), seq=256, heads=2,
                      head_dim=32))
    specs.append(Spec(name="kernel_flash_n256", group="core", kind="kernel_flash",
                      cfg=M.ModelCfg(block_size=32), seq=256, heads=2,
                      head_dim=32))

    # ---- F3a scaling law: seq 512, block 32, top-3 -> 81.25% sparsity ----
    for size in LADDER:
        for var in ("moba", "full"):
            cfg = ladder_cfg(size, block_size=32, topk=3,
                             layer_variants=variants(var, LADDER[size][1]))
            add(f"scaling_{size}_{var}_train", "scaling", "train", cfg, 2, 512)
            add(f"scaling_{size}_{var}_eval", "scaling", "eval", cfg, 2, 512)

    # ---- F3b trailing loss: seq 2048, block 32, top-3 -> 95.31% ----
    for size in LADDER:
        for var in ("moba", "full"):
            cfg = ladder_cfg(size, block_size=32, topk=3,
                             layer_variants=variants(var, LADDER[size][1]))
            add(f"long_{size}_{var}_train", "scaling_long", "train", cfg, 1, 2048)
            add(f"long_{size}_{var}_eval", "scaling_long", "eval", cfg, 1, 2048)

    # ---- F4 granularity ablation: S2, seq 1024, 75% sparsity ----
    for nb, topk in ((8, 2), (16, 4), (32, 8), (64, 16), (128, 32)):
        bs = 1024 // nb
        cfg = ladder_cfg("s2", block_size=bs, topk=topk)
        add(f"gran_nb{nb:03d}_train", "granularity", "train", cfg, 1, 1024)
        add(f"gran_nb{nb:03d}_eval", "granularity", "eval", cfg, 1, 1024)

    # ---- F5a hybrid pretrain: S2, seq 1024, block 64 top-3 (16 blocks) ----
    for var in ("moba", "full"):
        cfg = ladder_cfg("s2", block_size=64, topk=3,
                         layer_variants=variants(var, LADDER["s2"][1]))
        add(f"hybrid_{var}_train", "hybrid", "train", cfg, 1, 1024)
        add(f"hybrid_{var}_eval", "hybrid", "eval", cfg, 1, 1024)

    # ---- F5b/c layer-wise hybrid SFT: S2, seq 512, last-k full ----
    nl = LADDER["s2"][1]  # 5 layers
    for k in (0, 1, 2, 3, nl):
        cfg = ladder_cfg("s2", block_size=32, topk=3,
                         layer_variants=variants("moba", nl, full_last=k))
        add(f"sft_full{k}_train", "sft", "train", cfg, 2, 512)
        add(f"sft_full{k}_eval", "sft", "eval", cfg, 2, 512)

    # ---- F6/F7 needle: continual-pretrain stages with PI, eval logits ----
    # stage 1: native 512; stage 2: 1024 via PI x2; stage 3: 2048 via PI x4
    nl = LADDER["s2"][1]
    for stage, (seq, pi) in enumerate(((512, 1.0), (1024, 2.0), (2048, 4.0))):
        cfg = ladder_cfg("s2", block_size=32, topk=3, pi_scale=pi)
        add(f"needle_s{stage}_train", "needle", "train", cfg, 1, seq)
        add(f"needle_s{stage}_logits", "needle", "logits", cfg, 1, seq)
        # full-attention twin for Table-2-style parity at matched training
        cfg_f = ladder_cfg("s2", block_size=32, topk=3, pi_scale=pi,
                           layer_variants=variants("full", nl))
        add(f"needle_s{stage}_full_train", "needle", "train", cfg_f, 1, seq)
        add(f"needle_s{stage}_full_logits", "needle", "logits", cfg_f, 1, seq)
    # serving decode step (full attention recompute, §3.3 deployment mode)
    cfg = ladder_cfg("s2", block_size=32, topk=3, pi_scale=4.0)
    add("needle_decode", "needle", "last_logits", cfg, 1, 2048)
    # layer-wise hybrid deployment cfg (last 1 layer full out of 5 ~ paper's 3/32)
    cfg_h = ladder_cfg("s2", block_size=32, topk=3, pi_scale=4.0,
                       layer_variants=variants("moba", nl, full_last=1))
    add("needle_hybrid_logits", "needle", "logits", cfg_h, 1, 2048)

    # ---- §Perf: scan-fused K-step train graphs (roundtrip amortization) --
    specs.append(Spec(name="quickstart_train_k8", group="perf", kind="train_k",
                      cfg=qcfg, batch=2, seq=256, k_steps=8))
    s2cfg = ladder_cfg("s2", block_size=32, topk=3)
    specs.append(Spec(name="scaling_s2_moba_train_k8", group="perf",
                      kind="train_k", cfg=s2cfg, batch=2, seq=512, k_steps=8))

    names = [s.name for s in specs]
    assert len(names) == len(set(names)), "duplicate artifact names"
    return specs


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _shape_structs(spec: Spec):
    """Ordered (name, ShapeDtypeStruct) input list for an artifact."""
    cfg, b, s = spec.cfg, spec.batch, spec.seq
    f32 = jnp.float32
    if spec.kind in ("kernel_moba", "kernel_flash"):
        qkv = jax.ShapeDtypeStruct((spec.seq, spec.heads, spec.head_dim), f32)
        return [("q", qkv), ("k", qkv), ("v", qkv)]
    ins = [(name, jax.ShapeDtypeStruct(shape, f32))
           for name, shape, _, _ in M.params_spec(cfg)]
    if spec.kind in ("train", "train_k"):
        ins = ins * 3  # params, m, v share the leaf layout
        ins = [(f"p.{n}" if i < len(ins) // 3 else (f"m.{n}" if i < 2 * len(ins) // 3 else f"v.{n}"), sd)
               for i, (n, sd) in enumerate(ins)]
        if spec.kind == "train":
            ins += [("step", jax.ShapeDtypeStruct((), f32)),
                    ("lr", jax.ShapeDtypeStruct((), f32)),
                    ("tokens", jax.ShapeDtypeStruct((b, s), jnp.int32)),
                    ("mask", jax.ShapeDtypeStruct((b, s - 1), f32))]
        else:
            kk = spec.k_steps
            ins += [("step", jax.ShapeDtypeStruct((), f32)),
                    ("lrs", jax.ShapeDtypeStruct((kk,), f32)),
                    ("tokens", jax.ShapeDtypeStruct((kk, b, s), jnp.int32)),
                    ("masks", jax.ShapeDtypeStruct((kk, b, s - 1), f32))]
    elif spec.kind == "eval":
        ins = [(f"p.{n}", sd) for n, sd in ins]
        ins += [("tokens", jax.ShapeDtypeStruct((b, s), jnp.int32)),
                ("mask", jax.ShapeDtypeStruct((b, s - 1), f32))]
    elif spec.kind in ("logits", "last_logits"):
        ins = [(f"p.{n}", sd) for n, sd in ins]
        ins += [("tokens", jax.ShapeDtypeStruct((b, s), jnp.int32))]
    else:
        raise ValueError(spec.kind)
    return ins


def _fn_for(spec: Spec):
    if spec.kind == "train":
        return M.make_train_fn(spec.cfg)
    if spec.kind == "train_k":
        return M.make_train_k_fn(spec.cfg, spec.k_steps)
    if spec.kind == "eval":
        return M.make_eval_fn(spec.cfg)
    if spec.kind == "logits":
        return M.make_logits_fn(spec.cfg)
    if spec.kind == "last_logits":
        return M.make_last_logits_fn(spec.cfg)
    if spec.kind == "kernel_moba":
        bs, tk = spec.cfg.block_size, spec.cfg.topk
        return lambda q, k, v: (moba_attention_pallas(q, k, v, bs, tk),)
    if spec.kind == "kernel_flash":
        bs = spec.cfg.block_size
        return lambda q, k, v: (flash_attention_pallas(q, k, v, kv_block=bs),)
    raise ValueError(spec.kind)


def manifest_entry(spec: Spec, path: str, ins, lowered) -> Dict:
    out_avals = jax.tree_util.tree_leaves(lowered.out_info)
    entry = {
        "name": spec.name,
        "group": spec.group,
        "kind": spec.kind,
        "path": path,
        "hash": spec.hash(),
        "batch": spec.batch,
        "seq": spec.seq,
        "k_steps": spec.k_steps if spec.kind == "train_k" else 1,
        "inputs": [{"name": n, "shape": list(sd.shape), "dtype": str(sd.dtype)}
                   for n, sd in ins],
        "outputs": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                    for a in out_avals],
    }
    if spec.cfg is not None and spec.kind not in ("kernel_moba", "kernel_flash"):
        cfg = spec.cfg
        entry["model"] = {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim, "mlp_mult": cfg.mlp_mult,
            "block_size": cfg.block_size, "topk": cfg.topk,
            "pi_scale": cfg.pi_scale, "attn_impl": cfg.attn_impl,
            "layer_variants": list(cfg.variants()),
            "param_count": cfg.param_count(),
        }
        entry["params"] = [
            {"name": n, "shape": list(shape), "init": kind, "scale": scale}
            for n, shape, kind, scale in M.params_spec(cfg)]
    else:
        entry["model"] = {"block_size": spec.cfg.block_size,
                          "topk": spec.cfg.topk,
                          "heads": spec.heads, "head_dim": spec.head_dim}
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--group", action="append", default=None,
                    help="restrict to group(s); default: all")
    ap.add_argument("--force", action="store_true", help="re-lower everything")
    ap.add_argument("--list", action="store_true", help="list specs and exit")
    args = ap.parse_args()

    specs = build_specs()
    if args.list:
        for s in specs:
            print(f"{s.group:14s} {s.kind:12s} {s.name}")
        return
    if args.group:
        specs = [s for s in specs if s.group in args.group]

    os.makedirs(args.out, exist_ok=True)
    mpath = os.path.join(args.out, "manifest.json")
    manifest: Dict[str, Dict] = {}
    if os.path.exists(mpath):
        with open(mpath) as f:
            manifest = {e["name"]: e for e in json.load(f)["artifacts"]}

    t_all = time.time()
    for spec in specs:
        path = os.path.join(args.out, spec.name + ".hlo.txt")
        prev = manifest.get(spec.name)
        if (not args.force and prev is not None and prev.get("hash") == spec.hash()
                and os.path.exists(path)):
            print(f"  cached  {spec.name}")
            continue
        t0 = time.time()
        ins = _shape_structs(spec)
        lowered = jax.jit(_fn_for(spec)).lower(*[sd for _, sd in ins])
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest[spec.name] = manifest_entry(spec, spec.name + ".hlo.txt",
                                             ins, lowered)
        print(f"  lowered {spec.name}  ({time.time() - t0:.1f}s, "
              f"{len(text) / 1e6:.2f} MB)")

    with open(mpath, "w") as f:
        json.dump({"artifacts": list(manifest.values())}, f, indent=1)
    print(f"manifest: {mpath}  ({len(manifest)} artifacts, "
          f"{time.time() - t_all:.0f}s)")


if __name__ == "__main__":
    main()
