"""L2: the transformer LM whose attention layers are MoBA or full attention.

Build-time only: these functions are traced by ``aot.py`` and lowered to
HLO text; the Rust coordinator executes the lowered graphs via PJRT and
never imports this module at runtime.

Everything the Rust side needs to *drive* the graphs — parameter layout,
init scheme, input ordering — is described by :func:`params_spec` and
exported into ``artifacts/manifest.json``.

Model: pre-norm transformer (RMSNorm) with RoPE (+ position-interpolation
scaling for context extension, paper §3.3), per-layer choice of MoBA or
full attention (the paper's layer-wise hybrid, §3.2), GELU MLP, untied
output head. Optimizer: Adam with decoupled weight decay, implemented
in-graph so one PJRT call performs a whole training step.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.flash import flash_attention_pallas
from .kernels.moba import moba_attention_pallas

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """Static (compile-time) model + MoBA hyperparameters.

    ``layer_variants`` is the per-layer attention choice: "moba" or "full".
    The paper's layer-wise hybrid (last k layers full) is expressed here,
    so each hybrid configuration is its own artifact. MoBA adds no
    parameters, so *all* variants of the same geometry share one parameter
    tree — this is what lets the Rust stage scheduler swap executables
    mid-training (Fig 5a) without touching state.
    """
    vocab: int = 512
    d_model: int = 64
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 16
    mlp_mult: int = 4
    rope_theta: float = 10000.0
    pi_scale: float = 1.0  # position interpolation: effective pos = pos / pi_scale
    block_size: int = 64
    topk: int = 3
    layer_variants: Tuple[str, ...] = ()
    attn_impl: str = "jnp"  # "jnp" (dense-mask oracle math) or "pallas"

    def variants(self) -> Tuple[str, ...]:
        if self.layer_variants:
            assert len(self.layer_variants) == self.n_layers
            return self.layer_variants
        return ("moba",) * self.n_layers

    @property
    def d_attn(self) -> int:
        return self.n_heads * self.head_dim

    def param_count(self) -> int:
        total = 0
        for _, shape, _, _ in params_spec(self):
            n = 1
            for s in shape:
                n *= s
            total += n
        return total


# ---------------------------------------------------------------------------
# parameter spec: single source of truth for layout, init, and ordering
# ---------------------------------------------------------------------------

def params_spec(cfg: ModelCfg) -> List[Tuple[str, Tuple[int, ...], str, float]]:
    """Ordered list of (name, shape, init_kind, init_scale).

    init_kind: "normal" (std = init_scale), "zeros", "ones".
    The order here *is* the flattened argument order of every artifact;
    the Rust runtime initializes and marshals parameters from this spec
    (via manifest.json) with its own RNG.
    """
    d, da, m = cfg.d_model, cfg.d_attn, cfg.mlp_mult
    spec: List[Tuple[str, Tuple[int, ...], str, float]] = []
    spec.append(("embed", (cfg.vocab, d), "normal", 0.02))
    for i in range(cfg.n_layers):
        p = f"layer{i:02d}."
        spec.append((p + "ln1", (d,), "ones", 0.0))
        spec.append((p + "wq", (d, da), "normal", 0.02))
        spec.append((p + "wk", (d, da), "normal", 0.02))
        spec.append((p + "wv", (d, da), "normal", 0.02))
        # residual-branch projections scaled down with depth (GPT-2 style)
        spec.append((p + "wo", (da, d), "normal", 0.02 / (2 * cfg.n_layers) ** 0.5))
        spec.append((p + "ln2", (d,), "ones", 0.0))
        spec.append((p + "wup", (d, m * d), "normal", 0.02))
        spec.append((p + "wdown", (m * d, d), "normal", 0.02 / (2 * cfg.n_layers) ** 0.5))
    spec.append(("lnf", (d,), "ones", 0.0))
    spec.append(("head", (d, cfg.vocab), "normal", 0.02))
    return spec


def init_params(rng: jax.Array, cfg: ModelCfg) -> Params:
    """Reference initializer (used by pytest; Rust re-implements from spec)."""
    params: Params = {}
    for name, shape, kind, scale in params_spec(cfg):
        rng, sub = jax.random.split(rng)
        if kind == "normal":
            params[name] = (jax.random.normal(sub, shape) * scale).astype(jnp.float32)
        elif kind == "zeros":
            params[name] = jnp.zeros(shape, jnp.float32)
        elif kind == "ones":
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            raise ValueError(kind)
    return params


def flatten(cfg: ModelCfg, params: Params) -> List[jnp.ndarray]:
    return [params[name] for name, *_ in params_spec(cfg)]


def unflatten(cfg: ModelCfg, leaves) -> Params:
    names = [name for name, *_ in params_spec(cfg)]
    assert len(names) == len(leaves)
    return dict(zip(names, leaves))


# ---------------------------------------------------------------------------
# model forward
# ---------------------------------------------------------------------------

def _rms_norm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def _rope(x: jnp.ndarray, cfg: ModelCfg) -> jnp.ndarray:
    """Rotary embedding over [S, H, D]; positions scaled by 1/pi_scale
    (position interpolation, S. Chen et al. 2023 / paper §3.3)."""
    s, h, d = x.shape
    half = d // 2
    freqs = cfg.rope_theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    pos = jnp.arange(s, dtype=jnp.float32) / cfg.pi_scale
    ang = pos[:, None] * freqs[None, :]  # [S, half]
    cos, sin = jnp.cos(ang)[:, None, :], jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(cfg: ModelCfg, variant: str, q, k, v) -> jnp.ndarray:
    """Dispatch one layer's attention. q,k,v: [S, H, D] -> [S, H, D]."""
    if variant == "full":
        if cfg.attn_impl == "pallas":
            return flash_attention_pallas(q, k, v,
                                          kv_block=min(cfg.block_size, q.shape[0]))
        return ref.full_attention_ref(q, k, v)
    elif variant == "moba":
        bs = min(cfg.block_size, q.shape[0])
        if cfg.attn_impl == "pallas":
            return moba_attention_pallas(q, k, v, block_size=bs, topk=cfg.topk)
        return ref.moba_attention_ref(q, k, v, block_size=bs, topk=cfg.topk)
    raise ValueError(variant)


def forward(cfg: ModelCfg, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: [B, S] int32 -> logits [B, S, vocab]."""

    def one(seq: jnp.ndarray) -> jnp.ndarray:
        x = params["embed"][seq]  # [S, d]
        s = seq.shape[0]
        for i, variant in enumerate(cfg.variants()):
            p = f"layer{i:02d}."
            h = _rms_norm(x, params[p + "ln1"])
            q = (h @ params[p + "wq"]).reshape(s, cfg.n_heads, cfg.head_dim)
            k = (h @ params[p + "wk"]).reshape(s, cfg.n_heads, cfg.head_dim)
            v = (h @ params[p + "wv"]).reshape(s, cfg.n_heads, cfg.head_dim)
            q, k = _rope(q, cfg), _rope(k, cfg)
            o = _attention(cfg, variant, q, k, v).reshape(s, cfg.d_attn)
            x = x + o @ params[p + "wo"]
            h = _rms_norm(x, params[p + "ln2"])
            x = x + jax.nn.gelu(h @ params[p + "wup"]) @ params[p + "wdown"]
        x = _rms_norm(x, params["lnf"])
        return x @ params["head"]

    return jax.vmap(one)(tokens)


def position_losses(cfg: ModelCfg, params: Params, tokens: jnp.ndarray,
                    mask: jnp.ndarray) -> jnp.ndarray:
    """Per-position next-token CE loss. tokens [B,S], mask [B,S-1] (1 = count).

    Returns [B, S-1] losses, already multiplied by the mask. This is the
    primitive from which the Rust side computes mean LM loss, trailing LM
    loss (paper Fig 3b) and position-wise LM loss (Fig 5a).
    """
    logits = forward(cfg, params, tokens)[:, :-1]  # predict token t+1
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (logz - gold) * mask


def mean_loss(cfg: ModelCfg, params: Params, tokens: jnp.ndarray,
              mask: jnp.ndarray) -> jnp.ndarray:
    pls = position_losses(cfg, params, tokens, mask)
    return pls.sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# training step (Adam, in-graph)
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8
GRAD_CLIP = 1.0
WEIGHT_DECAY = 0.1  # decoupled, applied to matmul weights only


def _decayed(name: str) -> bool:
    return not (name.endswith("ln1") or name.endswith("ln2") or name == "lnf")


def train_step(cfg: ModelCfg, params: Params, m: Params, v: Params,
               step: jnp.ndarray, lr: jnp.ndarray, tokens: jnp.ndarray,
               mask: jnp.ndarray):
    """One Adam step. Returns (params', m', v', loss).

    ``step`` is the 1-based step counter (f32 scalar, drives bias
    correction); ``lr`` is supplied per-call by the Rust scheduler so the
    LR policy lives in L3.
    """
    loss, grads = jax.value_and_grad(
        lambda p: mean_loss(cfg, p, tokens, mask))(params)

    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
    clip = jnp.minimum(1.0, GRAD_CLIP / (gnorm + 1e-12))

    b1t = 1.0 - ADAM_B1 ** step
    b2t = 1.0 - ADAM_B2 ** step
    new_p, new_m, new_v = {}, {}, {}
    for name in params:
        g = grads[name] * clip
        mm = ADAM_B1 * m[name] + (1 - ADAM_B1) * g
        vv = ADAM_B2 * v[name] + (1 - ADAM_B2) * g * g
        upd = (mm / b1t) / (jnp.sqrt(vv / b2t) + ADAM_EPS)
        if _decayed(name):
            upd = upd + WEIGHT_DECAY * params[name]
        new_p[name] = params[name] - lr * upd
        new_m[name] = mm
        new_v[name] = vv
    return new_p, new_m, new_v, loss


# ---------------------------------------------------------------------------
# artifact entry points (flat-argument wrappers that aot.py lowers)
# ---------------------------------------------------------------------------

def make_train_fn(cfg: ModelCfg):
    nleaves = len(params_spec(cfg))

    def fn(*args):
        p = unflatten(cfg, args[:nleaves])
        m = unflatten(cfg, args[nleaves:2 * nleaves])
        v = unflatten(cfg, args[2 * nleaves:3 * nleaves])
        step, lr, tokens, mask = args[3 * nleaves:]
        np_, nm, nv, loss = train_step(cfg, p, m, v, step, lr, tokens, mask)
        return (*flatten(cfg, np_), *flatten(cfg, nm), *flatten(cfg, nv), loss)

    return fn


def make_eval_fn(cfg: ModelCfg):
    nleaves = len(params_spec(cfg))

    def fn(*args):
        p = unflatten(cfg, args[:nleaves])
        tokens, mask = args[nleaves:]
        return (position_losses(cfg, p, tokens, mask),)

    return fn


def make_logits_fn(cfg: ModelCfg):
    """Full logits [B, S, vocab] — used by the needle scorer and the
    serving prefill path (Rust picks positions / samples)."""
    nleaves = len(params_spec(cfg))

    def fn(*args):
        p = unflatten(cfg, args[:nleaves])
        (tokens,) = args[nleaves:]
        return (forward(cfg, p, tokens),)

    return fn


def make_last_logits_fn(cfg: ModelCfg):
    """Last-position logits [B, vocab] — the decode step for serving
    (full-attention recompute decode; MoBA used for prefill only, §3.3)."""
    nleaves = len(params_spec(cfg))

    def fn(*args):
        p = unflatten(cfg, args[:nleaves])
        (tokens,) = args[nleaves:]
        return (forward(cfg, p, tokens)[:, -1],)

    return fn


def make_train_k_fn(cfg: ModelCfg, k_steps: int):
    """K fused optimizer steps via lax.scan — the L3 §Perf optimization.

    One PJRT call performs `k_steps` Adam steps, so the host<->device
    state roundtrip (the dominant non-compute cost of small models, see
    EXPERIMENTS.md §Perf) is amortized K-fold. Inputs append per-step
    LRs `[K]`, tokens `[K, B, S]` and masks `[K, B, S-1]`; output ends
    with the per-step losses `[K]`.
    """
    nleaves = len(params_spec(cfg))

    def fn(*args):
        p = unflatten(cfg, args[:nleaves])
        m = unflatten(cfg, args[nleaves:2 * nleaves])
        v = unflatten(cfg, args[2 * nleaves:3 * nleaves])
        step0, lrs, tokens, masks = args[3 * nleaves:]

        def body(carry, xs):
            p, m, v, step = carry
            lr, toks, mask = xs
            p, m, v, loss = train_step(cfg, p, m, v, step, lr, toks, mask)
            return (p, m, v, step + 1.0), loss

        (p, m, v, _), losses = jax.lax.scan(
            body, (p, m, v, step0), (lrs, tokens, masks), length=k_steps)
        return (*flatten(cfg, p), *flatten(cfg, m), *flatten(cfg, v), losses)

    return fn
