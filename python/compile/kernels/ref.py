"""Pure-jnp oracles for MoBA and full attention.

These are the *correctness ground truth* for the whole stack:

- the Pallas kernels in ``moba.py`` / ``flash.py`` are pytest-checked
  ``allclose`` against these functions (see ``python/tests/``);
- the L2 model (``model.py``) uses the dense-mask implementation below for
  its training artifacts (identical math to the streaming kernel);
- the Rust pure-f32 reference in ``rust/src/sparse/`` is checked against
  golden files generated from these functions.

Shapes follow Algorithm 1 of the paper: ``q, k, v: [N, H, D]`` (sequence,
heads, head_dim). All math is f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # used instead of -inf so fully-masked rows stay finite


def mean_pool_blocks(k: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Mean-pool keys along the sequence dim into block representatives.

    k: [N, H, D] -> [n_blocks, H, D] with n_blocks = N // block_size.
    N must be divisible by block_size (the paper's WLOG assumption; the
    data pipeline pads sequences to a multiple of the block size).
    """
    n, h, d = k.shape
    assert n % block_size == 0, f"N={n} not divisible by block_size={block_size}"
    nb = n // block_size
    return k.reshape(nb, block_size, h, d).mean(axis=1)


def moba_gate(q: jnp.ndarray, k: jnp.ndarray, block_size: int, topk: int) -> jnp.ndarray:
    """MoBA gating (paper Eq. 5-6 plus the two causality rules).

    Returns a boolean gate ``G: [H, N, n_blocks]`` where, for query position
    t and head h:

    - ``G[h, t, c] = True`` for the *current* block ``c = t // B``
      (mandatory routing, akin to a shared expert);
    - ``G[h, t, i] = False`` for every *future* block ``i > c``;
    - among *past* blocks ``i < c`` the ``topk - 1`` highest affinity scores
      ``s_i = <q_t, mean_pool(K[I_i])>`` are selected (paper footnote 3:
      top-k counts the current block, so k=3 means the current block plus
      at most 2 history blocks).

    Ties are broken deterministically toward the lower block index so that
    the Rust router reproduces the selection bit-for-bit.
    """
    n, h, d = q.shape
    nb = n // block_size
    pooled = mean_pool_blocks(k, block_size)  # [nb, H, D]
    # affinity scores: s[h, t, i] = <q[t, h], pooled[i, h]>
    s = jnp.einsum("nhd,bhd->hnb", q, pooled)

    t_idx = jnp.arange(n)
    cur = t_idx // block_size  # current block of each query position
    blk = jnp.arange(nb)
    is_future = blk[None, :] > cur[:, None]   # [N, nb]
    is_current = blk[None, :] == cur[:, None]  # [N, nb]

    big = jnp.asarray(1e30, s.dtype)
    # current block is forced into the top-k; future blocks are excluded.
    s = jnp.where(is_current[None], big, s)
    s = jnp.where(is_future[None], -big, s)

    # deterministic tie-break toward lower block index
    tie = -blk.astype(s.dtype) * 1e-6
    s = s + tie[None, None, :]

    kk = min(topk, nb)
    # Selection is *hard* top-k: gradients never flow through the gate
    # (as in hard MoE routing), so stop_gradient is semantically a no-op
    # here — and it is also load-bearing twice over for this image:
    #  1. lax.top_k lowers to the `topk` HLO instruction, which the
    #     xla_extension 0.5.1 HLO parser rejects -> use sort (ancient HLO);
    #  2. sort's VJP emits a gather with operand_batching_dims, which the
    #     installed jaxlib cannot construct under vmap -> stop_gradient
    #     keeps the sort out of the backward graph entirely.
    s = jax.lax.stop_gradient(s)
    kth = jnp.sort(s, axis=-1)[..., nb - kk]
    gate = (s >= kth[..., None]) & (~is_future[None])
    return gate


def moba_token_mask(gate: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Expand a block gate ``[H, N, nb]`` to a token-level attention mask
    ``[H, N, N]``: position t may attend to j iff block(j) is gated for t
    AND j <= t (causality inside the current block; history blocks satisfy
    j <= t automatically but the constraint is applied uniformly)."""
    h, n, nb = gate.shape
    # block i covers columns [i*B, (i+1)*B): expand by uniform repeat
    # (broadcast+reshape — avoids a gather, which breaks vmap lowering on
    # the image's old HLO converter).
    tok = jnp.repeat(gate, block_size, axis=2)  # [H, N, N]
    causal = jnp.arange(n)[:, None] >= jnp.arange(n)[None, :]
    return tok & causal[None]


def attention_with_mask(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        mask: jnp.ndarray) -> jnp.ndarray:
    """Masked softmax attention. q, k, v: [N, H, D]; mask: [H, N, N] bool."""
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    scores = jnp.einsum("nhd,mhd->hnm", q, k) * scale
    scores = jnp.where(mask, scores, NEG_INF)
    scores = scores - scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores)
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hnm,mhd->nhd", p, v)


def full_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Causal full attention oracle. q, k, v: [N, H, D] -> [N, H, D]."""
    n = q.shape[0]
    causal = jnp.arange(n)[:, None] >= jnp.arange(n)[None, :]
    mask = jnp.broadcast_to(causal[None], (q.shape[1], n, n))
    return attention_with_mask(q, k, v, mask)


def moba_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       block_size: int, topk: int) -> jnp.ndarray:
    """MoBA attention oracle (paper Eq. 2), dense-mask formulation.

    Mathematically identical to the streaming block-sparse kernel: the
    softmax over the union of gated blocks equals the online-softmax
    combination of per-block partial attentions (paper §2.3 step 5).
    """
    gate = moba_gate(q, k, block_size, topk)
    mask = moba_token_mask(gate, block_size)
    return attention_with_mask(q, k, v, mask)
