"""Pallas MoBA attention kernel (L1).

Implements the paper's Algorithm 1 as a streaming block-sparse kernel,
re-thought for the TPU memory model (DESIGN.md §2 Hardware-Adaptation):

- grid = (heads, query tiles). Each grid step holds one q-tile in VMEM
  (``BlockSpec``-mapped) and streams KV blocks HBM->VMEM one at a time
  via dynamic slices inside a ``fori_loop`` — the Pallas analogue of the
  paper's FlashAttention-varlen segments. On a real TPU this loop is the
  double-buffered DMA schedule; under ``interpret=True`` (mandatory on
  CPU PJRT) it executes as the same dataflow in the interpreter.
- the MoE-style gate (mean-pooled key affinity + top-k + causal rules) is
  computed in jnp *outside* the kernel — it is O(N * n_blocks), negligible
  next to attention — and passed in as a boolean gate ``G[H, N, nb]``.
  The kernel skips the contribution of non-gated blocks through the mask,
  which on TPU is where the FLOP savings realize (unselected KV blocks are
  never DMA'd in the production schedule; the interpreter still walks them,
  which is why wall-clock speed is *not* measured here — see DESIGN.md §7).
- the paper's separate "current block attention" (causal) vs "history
  block attention" (non-causal) paths, combined with online softmax
  (Algorithm 1 lines 10-16), appear here as a single online-softmax loop
  whose mask is `gate AND (j <= t)` — mathematically identical and
  TPU-friendlier (no varlen re-arrangement needed when the q-tile loop is
  dense).

VMEM footprint per grid step (f32):
  q-tile (Bq*D) + kv block (2*B*D) + scores (Bq*B) + accum (Bq*D + 2*Bq)
which for the default Bq=128, B=64, D=32 is ~57 KiB — comfortably inside
a TPU core's ~16 MiB VMEM with room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e30


def _moba_kernel(gate_ref, q_ref, k_ref, v_ref, o_ref, *, block_size: int,
                 q_tile: int, n_ctx: int):
    """One (head, q-tile) grid step.

    gate_ref: [q_tile, nb] bool   gate for this head's q-tile
    q_ref:    [q_tile, D]         VMEM-resident query tile
    k_ref:    [N, D]              full K for this head (HBM; sliced per block)
    v_ref:    [N, D]              full V for this head
    o_ref:    [q_tile, D]         output tile
    """
    qt = pl.program_id(1)
    d = q_ref.shape[-1]
    nb = n_ctx // block_size
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    q = q_ref[...].astype(jnp.float32) * scale
    # global row positions of this q-tile
    rows = qt * q_tile + jax.lax.iota(jnp.int32, q_tile)

    def body(i, carry):
        acc, m, l = carry
        # HBM -> VMEM stream of the i-th KV block (on TPU: one DMA)
        kb = pl.load(k_ref, (pl.dslice(i * block_size, block_size), slice(None)))
        vb = pl.load(v_ref, (pl.dslice(i * block_size, block_size), slice(None)))
        s = q @ kb.T  # [q_tile, B] — MXU matmul
        cols = i * block_size + jax.lax.iota(jnp.int32, block_size)
        sel = pl.load(gate_ref, (slice(None), i))  # [q_tile] gate for block i
        mask = sel[:, None] & (rows[:, None] >= cols[None, :])
        s = jnp.where(mask, s, NEG_INF)
        # online softmax update (Algorithm 1 line 16 / Milakov-Gimelshein)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ vb
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((q_tile, d), jnp.float32)
    m0 = jnp.full((q_tile,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q_tile,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nb, body, (acc0, m0, l0))
    o_ref[...] = acc / l[:, None]


def moba_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                          block_size: int, topk: int,
                          q_tile: int | None = None) -> jnp.ndarray:
    """MoBA attention via the Pallas kernel. q, k, v: [N, H, D] -> [N, H, D].

    The gate is computed with the same jnp code as the oracle (`ref.moba_gate`)
    so kernel-vs-ref comparisons isolate the streaming attention math.
    """
    n, h, d = q.shape
    assert n % block_size == 0
    nb = n // block_size
    if q_tile is None:
        q_tile = min(128, n)
    assert n % q_tile == 0

    gate = ref.moba_gate(q, k, block_size, topk)  # [H, N, nb]

    # head-major layout for the kernel grid
    qh = q.transpose(1, 0, 2)  # [H, N, D]
    kh = k.transpose(1, 0, 2)
    vh = v.transpose(1, 0, 2)

    kernel = functools.partial(_moba_kernel, block_size=block_size,
                               q_tile=q_tile, n_ctx=n)
    out = pl.pallas_call(
        kernel,
        grid=(h, n // q_tile),
        in_specs=[
            pl.BlockSpec((None, q_tile, nb), lambda hh, qt: (hh, qt, 0)),
            pl.BlockSpec((None, q_tile, d), lambda hh, qt: (hh, qt, 0)),
            pl.BlockSpec((None, n, d), lambda hh, qt: (hh, 0, 0)),
            pl.BlockSpec((None, n, d), lambda hh, qt: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_tile, d), lambda hh, qt: (hh, qt, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n, d), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(gate, qh, kh, vh)
    return out.transpose(1, 0, 2)
