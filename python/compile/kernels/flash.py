"""Pallas causal full-attention kernel (FlashAttention-style baseline, L1).

Same streaming/online-softmax structure as the MoBA kernel in ``moba.py``
minus the gate: every causal KV block participates. This is the paper's
"full attention (implemented with Flash Attention)" baseline in kernel
form; it shares the VMEM tiling so Fig-2-style comparisons at the cost
model level use the same per-block constants for both kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block: int, q_tile: int,
                  n_ctx: int):
    qt = pl.program_id(1)
    d = q_ref.shape[-1]
    nb = n_ctx // kv_block
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    q = q_ref[...].astype(jnp.float32) * scale
    rows = qt * q_tile + jax.lax.iota(jnp.int32, q_tile)

    def body(i, carry):
        acc, m, l = carry
        kb = pl.load(k_ref, (pl.dslice(i * kv_block, kv_block), slice(None)))
        vb = pl.load(v_ref, (pl.dslice(i * kv_block, kv_block), slice(None)))
        s = q @ kb.T
        cols = i * kv_block + jax.lax.iota(jnp.int32, kv_block)
        mask = rows[:, None] >= cols[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ vb
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((q_tile, d), jnp.float32)
    m0 = jnp.full((q_tile,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q_tile,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nb, body, (acc0, m0, l0))
    o_ref[...] = acc / l[:, None]


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           kv_block: int = 64,
                           q_tile: int | None = None) -> jnp.ndarray:
    """Causal full attention via the Pallas kernel. q,k,v: [N,H,D] -> [N,H,D]."""
    n, h, d = q.shape
    if q_tile is None:
        q_tile = min(128, n)
    assert n % q_tile == 0 and n % kv_block == 0

    qh = q.transpose(1, 0, 2)
    kh = k.transpose(1, 0, 2)
    vh = v.transpose(1, 0, 2)

    kernel = functools.partial(_flash_kernel, kv_block=kv_block,
                               q_tile=q_tile, n_ctx=n)
    out = pl.pallas_call(
        kernel,
        grid=(h, n // q_tile),
        in_specs=[
            pl.BlockSpec((None, q_tile, d), lambda hh, qt: (hh, qt, 0)),
            pl.BlockSpec((None, n, d), lambda hh, qt: (hh, 0, 0)),
            pl.BlockSpec((None, n, d), lambda hh, qt: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_tile, d), lambda hh, qt: (hh, qt, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n, d), jnp.float32),
        interpret=True,
    )(qh, kh, vh)
    return out.transpose(1, 0, 2)
