"""L1 correctness: Pallas kernels vs the pure-jnp oracles.

This is the CORE correctness signal for the kernel layer: the streaming
online-softmax MoBA kernel must match the dense-mask oracle to f32
round-off across shapes, block sizes and top-k settings. Hypothesis
sweeps the shape/hyperparameter space.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.flash import flash_attention_pallas
from compile.kernels.moba import moba_attention_pallas

TOL = dict(rtol=2e-5, atol=2e-5)


def rand_qkv(rng, n, h, d, scale=1.0):
    q = jnp.asarray(rng.normal(size=(n, h, d)).astype("float32")) * scale
    k = jnp.asarray(rng.normal(size=(n, h, d)).astype("float32")) * scale
    v = jnp.asarray(rng.normal(size=(n, h, d)).astype("float32")) * scale
    return q, k, v


# ---------------------------------------------------------------------------
# fixed-shape sanity
# ---------------------------------------------------------------------------

class TestMobaKernelBasic:
    def test_matches_ref_default(self):
        rng = np.random.default_rng(0)
        q, k, v = rand_qkv(rng, 256, 2, 16)
        out = moba_attention_pallas(q, k, v, block_size=32, topk=3, q_tile=64)
        exp = ref.moba_attention_ref(q, k, v, block_size=32, topk=3)
        np.testing.assert_allclose(out, exp, **TOL)

    def test_single_block_equals_full(self):
        """With one block (block_size == N), MoBA degenerates to causal full
        attention (the current block is always selected)."""
        rng = np.random.default_rng(1)
        q, k, v = rand_qkv(rng, 64, 2, 16)
        out = moba_attention_pallas(q, k, v, block_size=64, topk=1, q_tile=64)
        exp = ref.full_attention_ref(q, k, v)
        np.testing.assert_allclose(out, exp, **TOL)

    def test_topk_ge_nblocks_equals_full(self):
        """top-k >= n_blocks selects every causal block -> full attention."""
        rng = np.random.default_rng(2)
        q, k, v = rand_qkv(rng, 128, 2, 16)
        out = moba_attention_pallas(q, k, v, block_size=16, topk=8, q_tile=64)
        exp = ref.full_attention_ref(q, k, v)
        np.testing.assert_allclose(out, exp, **TOL)

    def test_first_block_rows_equal_full(self):
        """Queries inside the first block only ever see the (current) first
        block, under any gate -> identical to full attention there."""
        rng = np.random.default_rng(3)
        q, k, v = rand_qkv(rng, 128, 2, 16)
        out = moba_attention_pallas(q, k, v, block_size=32, topk=2, q_tile=32)
        exp = ref.full_attention_ref(q, k, v)
        np.testing.assert_allclose(out[:32], exp[:32], **TOL)

    def test_q_tile_invariance(self):
        rng = np.random.default_rng(4)
        q, k, v = rand_qkv(rng, 128, 2, 16)
        a = moba_attention_pallas(q, k, v, block_size=32, topk=2, q_tile=32)
        b = moba_attention_pallas(q, k, v, block_size=32, topk=2, q_tile=128)
        np.testing.assert_allclose(a, b, **TOL)

    def test_large_scale_inputs_stable(self):
        """Online softmax must be stable for large-magnitude scores."""
        rng = np.random.default_rng(5)
        # moderate scale: numerically hard but softmax not yet an argmax
        q, k, v = rand_qkv(rng, 128, 2, 16, scale=5.0)
        out = moba_attention_pallas(q, k, v, block_size=32, topk=2)
        exp = ref.moba_attention_ref(q, k, v, block_size=32, topk=2)
        np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)
        # extreme scale: only require finiteness (softmax ~ argmax; tiny
        # round-off flips winners, so elementwise comparison is meaningless)
        q, k, v = rand_qkv(rng, 128, 2, 16, scale=30.0)
        out = moba_attention_pallas(q, k, v, block_size=32, topk=2)
        assert np.isfinite(np.asarray(out)).all()


class TestFlashKernelBasic:
    def test_matches_ref(self):
        rng = np.random.default_rng(6)
        q, k, v = rand_qkv(rng, 256, 2, 16)
        out = flash_attention_pallas(q, k, v, kv_block=32, q_tile=64)
        exp = ref.full_attention_ref(q, k, v)
        np.testing.assert_allclose(out, exp, **TOL)

    def test_kv_block_invariance(self):
        rng = np.random.default_rng(7)
        q, k, v = rand_qkv(rng, 128, 2, 16)
        a = flash_attention_pallas(q, k, v, kv_block=16)
        b = flash_attention_pallas(q, k, v, kv_block=128)
        np.testing.assert_allclose(a, b, **TOL)


# ---------------------------------------------------------------------------
# gate invariants (paper §2.2 causality rules)
# ---------------------------------------------------------------------------

class TestGateInvariants:
    def setup_method(self):
        rng = np.random.default_rng(8)
        self.q, self.k, _ = rand_qkv(rng, 128, 3, 16)

    def test_current_block_always_selected(self):
        g = np.asarray(ref.moba_gate(self.q, self.k, block_size=16, topk=3))
        cur = np.arange(128) // 16
        for t in range(128):
            assert g[:, t, cur[t]].all()

    def test_no_future_blocks(self):
        g = np.asarray(ref.moba_gate(self.q, self.k, block_size=16, topk=3))
        cur = np.arange(128) // 16
        for t in range(128):
            assert not g[:, t, cur[t] + 1:].any()

    def test_selection_count(self):
        """Exactly min(topk, causal blocks available) blocks per query."""
        topk = 3
        g = np.asarray(ref.moba_gate(self.q, self.k, block_size=16, topk=topk))
        cur = np.arange(128) // 16
        for t in range(128):
            avail = cur[t] + 1
            assert (g[:, t].sum(-1) == min(topk, avail)).all()

    def test_gate_matches_bruteforce(self):
        """Gate equals argsort-based brute force on the affinity scores."""
        bs, topk = 32, 2
        g = np.asarray(ref.moba_gate(self.q, self.k, block_size=bs, topk=topk))
        qn = np.asarray(self.q)
        kn = np.asarray(self.k)
        pooled = kn.reshape(-1, bs, 3, 16).mean(1)  # [nb, H, D]
        nb = pooled.shape[0]
        for h in range(3):
            for t in range(128):
                c = t // bs
                scores = pooled[:, h] @ qn[t, h]
                sel = {c}
                hist = [(scores[i], -i) for i in range(c)]
                hist.sort(reverse=True)
                for s, negi in hist[:topk - 1]:
                    sel.add(-negi)
                expect = np.zeros(nb, bool)
                expect[list(sel)] = True
                np.testing.assert_array_equal(g[h, t], expect,
                                              err_msg=f"h={h} t={t}")


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------

@st.composite
def moba_case(draw):
    log_bs = draw(st.integers(3, 5))         # block 8..32
    bs = 2 ** log_bs
    nb = draw(st.integers(1, 6))
    n = bs * nb
    # q_tile must divide n
    qt = 2 ** draw(st.integers(3, 5))
    while n % qt:
        qt //= 2
    h = draw(st.integers(1, 3))
    d = draw(st.sampled_from([8, 16, 32]))
    topk = draw(st.integers(1, 4))
    seed = draw(st.integers(0, 2 ** 16))
    return n, h, d, bs, topk, qt, seed


@settings(max_examples=25, deadline=None)
@given(moba_case())
def test_hypothesis_moba_vs_ref(case):
    n, h, d, bs, topk, qt, seed = case
    rng = np.random.default_rng(seed)
    q, k, v = rand_qkv(rng, n, h, d)
    out = moba_attention_pallas(q, k, v, block_size=bs, topk=topk, q_tile=qt)
    exp = ref.moba_attention_ref(q, k, v, block_size=bs, topk=topk)
    np.testing.assert_allclose(out, exp, rtol=5e-5, atol=5e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 5), st.integers(1, 5), st.integers(0, 2 ** 16))
def test_hypothesis_flash_vs_ref(log_bs, nb, seed):
    bs = 2 ** log_bs
    n = bs * nb
    qt = bs
    rng = np.random.default_rng(seed)
    q, k, v = rand_qkv(rng, n, 2, 16)
    out = flash_attention_pallas(q, k, v, kv_block=bs, q_tile=qt)
    exp = ref.full_attention_ref(q, k, v)
    np.testing.assert_allclose(out, exp, rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# structural sparse-attention properties
# ---------------------------------------------------------------------------

class TestSparsityProperties:
    def test_output_independent_of_ungated_values(self):
        """Perturbing V inside a never-gated block must not change outputs
        of queries that did not select it."""
        rng = np.random.default_rng(9)
        n, h, d, bs, topk = 128, 1, 16, 32, 2
        q, k, v = rand_qkv(rng, n, h, d)
        g = np.asarray(ref.moba_gate(q, k, bs, topk))[0]  # [N, nb]
        out1 = np.asarray(ref.moba_attention_ref(q, k, v, bs, topk))
        # find a block not gated by some late query
        t = n - 1
        blocked = [i for i in range(n // bs) if not g[t, i]]
        assert blocked, "needs at least one ungated block for the late query"
        b = blocked[0]
        v2 = np.asarray(v).copy()
        v2[b * bs:(b + 1) * bs] += 100.0
        out2 = np.asarray(ref.moba_attention_ref(q, k, jnp.asarray(v2), bs, topk))
        np.testing.assert_allclose(out1[t], out2[t], rtol=1e-5, atol=1e-5)

    def test_sliding_window_is_special_case(self):
        """Paper §2.2: a gate that always selects the most recent blocks is
        sliding-window attention. Force it by constructing keys whose
        pooled affinity is monotonically increasing in block index."""
        n, bs, topk = 128, 32, 2
        h, d = 1, 8
        rng = np.random.default_rng(10)
        q = jnp.ones((n, h, d), jnp.float32)
        # block i gets mean key value ~ i (affinity grows with recency)
        base = np.repeat(np.arange(n // bs, dtype="float32"), bs)
        k = jnp.asarray(np.broadcast_to(base[:, None, None], (n, h, d)).copy())
        v = jnp.asarray(rng.normal(size=(n, h, d)).astype("float32"))
        g = np.asarray(ref.moba_gate(q, k, bs, topk))[0]
        cur = np.arange(n) // bs
        for t in range(n):
            want = {cur[t]} | {cur[t] - j for j in range(1, topk) if cur[t] - j >= 0}
            np.testing.assert_array_equal(np.nonzero(g[t])[0], sorted(want))

    def test_attention_rows_sum_to_one(self):
        """Each output row is a convex combination of V rows."""
        rng = np.random.default_rng(11)
        n, bs, topk = 64, 16, 2
        q, k, _ = rand_qkv(rng, n, 2, 8)
        v = jnp.ones((n, 2, 8), jnp.float32)
        out = np.asarray(ref.moba_attention_ref(q, k, v, bs, topk))
        np.testing.assert_allclose(out, np.ones_like(out), rtol=1e-5, atol=1e-5)
