"""L2 correctness: model shapes, loss semantics, optimizer step, hybrids."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model as M

CFG = M.ModelCfg(vocab=64, d_model=32, n_layers=2, n_heads=2, head_dim=16,
                 block_size=16, topk=2)


def make_state(cfg, seed=0):
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return params, zeros, {k: jnp.zeros_like(v) for k, v in params.items()}


def rand_tokens(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, size=(b, s)).astype("int32"))


class TestForward:
    def test_logits_shape(self):
        params, _, _ = make_state(CFG)
        toks = rand_tokens(CFG, 2, 64)
        logits = M.forward(CFG, params, toks)
        assert logits.shape == (2, 64, CFG.vocab)
        assert np.isfinite(np.asarray(logits)).all()

    def test_causality(self):
        """Changing token t must not change logits at positions < t."""
        params, _, _ = make_state(CFG)
        toks = rand_tokens(CFG, 1, 64)
        l1 = np.asarray(M.forward(CFG, params, toks))
        toks2 = np.asarray(toks).copy()
        toks2[0, 40] = (toks2[0, 40] + 1) % CFG.vocab
        l2 = np.asarray(M.forward(CFG, params, jnp.asarray(toks2)))
        np.testing.assert_allclose(l1[0, :40], l2[0, :40], rtol=1e-5, atol=1e-5)
        assert not np.allclose(l1[0, 40:], l2[0, 40:])

    def test_moba_vs_full_variants_differ(self):
        params, _, _ = make_state(CFG)
        toks = rand_tokens(CFG, 1, 64)
        full_cfg = dataclasses.replace(CFG, layer_variants=("full",) * 2)
        lm = np.asarray(M.forward(CFG, params, toks))
        lf = np.asarray(M.forward(full_cfg, params, toks))
        assert not np.allclose(lm, lf)

    def test_moba_equals_full_when_topk_covers(self):
        """topk >= n_blocks makes MoBA layers exactly full attention."""
        params, _, _ = make_state(CFG)
        toks = rand_tokens(CFG, 1, 64)
        cov = dataclasses.replace(CFG, topk=64 // CFG.block_size + 1)
        full_cfg = dataclasses.replace(CFG, layer_variants=("full",) * 2)
        lm = np.asarray(M.forward(cov, params, toks))
        lf = np.asarray(M.forward(full_cfg, params, toks))
        np.testing.assert_allclose(lm, lf, rtol=1e-4, atol=1e-4)

    def test_param_count_matches_spec(self):
        params, _, _ = make_state(CFG)
        n = sum(int(np.prod(v.shape)) for v in params.values())
        assert n == CFG.param_count()

    def test_pi_scale_changes_positions(self):
        params, _, _ = make_state(CFG)
        toks = rand_tokens(CFG, 1, 64)
        pi = dataclasses.replace(CFG, pi_scale=2.0)
        l1 = np.asarray(M.forward(CFG, params, toks))
        l2 = np.asarray(M.forward(pi, params, toks))
        assert not np.allclose(l1, l2)


class TestLoss:
    def test_position_losses_shape_and_mask(self):
        params, _, _ = make_state(CFG)
        toks = rand_tokens(CFG, 2, 64)
        mask = np.ones((2, 63), "float32")
        mask[:, :10] = 0.0
        pls = np.asarray(M.position_losses(CFG, params, toks, jnp.asarray(mask)))
        assert pls.shape == (2, 63)
        assert (pls[:, :10] == 0).all()
        assert (pls[:, 10:] > 0).all()

    def test_mean_loss_near_uniform_at_init(self):
        """At init the model is near-uniform: loss ~ ln(vocab)."""
        params, _, _ = make_state(CFG)
        toks = rand_tokens(CFG, 2, 64)
        mask = jnp.ones((2, 63), jnp.float32)
        loss = float(M.mean_loss(CFG, params, toks, mask))
        assert abs(loss - np.log(CFG.vocab)) < 0.5


class TestTrainStep:
    def test_loss_decreases_on_repeated_batch(self):
        params, m, v = make_state(CFG)
        toks = rand_tokens(CFG, 2, 64)
        mask = jnp.ones((2, 63), jnp.float32)
        step_fn = jax.jit(lambda p, m_, v_, s: M.train_step(
            CFG, p, m_, v_, s, jnp.asarray(3e-3), toks, mask))
        losses = []
        for i in range(8):
            params, m, v, loss = step_fn(params, m, v, jnp.asarray(float(i + 1)))
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.1, losses

    def test_masked_positions_get_no_gradient_from_embed_row(self):
        """A token id that appears only at masked positions gets no
        embedding-row gradient (modulo weight decay)."""
        cfg = dataclasses.replace(CFG, vocab=32)
        params, m, v = make_state(cfg)
        toks = np.zeros((1, 32), "int32")
        toks[0, 0] = 31  # only occurrence, as an input at masked position
        mask = np.ones((1, 31), "float32")
        mask[0, 0] = 0.0  # mask the prediction made FROM position 0
        loss, grads = jax.value_and_grad(
            lambda p: M.mean_loss(cfg, p, jnp.asarray(toks), jnp.asarray(mask)))(params)
        g = np.asarray(grads["embed"])
        # row 31 feeds only position 0 whose loss is masked; row-31 grad
        # can only come from attention *keys/values* of later queries.
        # With MoBA top-2 over 2 blocks all later queries still see pos 0,
        # so we just assert finiteness here and exact zero for an unused id.
        assert np.isfinite(g).all()
        unused = 30  # id never in the batch
        np.testing.assert_allclose(g[unused], 0.0, atol=1e-8)

    def test_train_fn_flat_wrapper_roundtrip(self):
        cfg = CFG
        params, m, v = make_state(cfg)
        toks = rand_tokens(cfg, 1, 64)
        mask = jnp.ones((1, 63), jnp.float32)
        fn = M.make_train_fn(cfg)
        flat = [*M.flatten(cfg, params), *M.flatten(cfg, m), *M.flatten(cfg, v),
                jnp.asarray(1.0), jnp.asarray(1e-3), toks, mask]
        out = fn(*flat)
        nleaves = len(M.params_spec(cfg))
        assert len(out) == 3 * nleaves + 1
        # direct call must agree
        p2, m2, v2, loss = M.train_step(cfg, params, m, v, jnp.asarray(1.0),
                                        jnp.asarray(1e-3), toks, mask)
        np.testing.assert_allclose(float(out[-1]), float(loss), rtol=1e-6)
        for a, b in zip(out[:nleaves], M.flatten(cfg, p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


class TestHybridVariants:
    def test_layer_variants_validation(self):
        with pytest.raises(AssertionError):
            dataclasses.replace(CFG, layer_variants=("moba",)).variants()

    def test_hybrid_between_full_and_moba(self):
        """Hybrid (last layer full) output differs from both pure variants."""
        params, _, _ = make_state(CFG)
        toks = rand_tokens(CFG, 1, 64)
        hy = dataclasses.replace(CFG, layer_variants=("moba", "full"))
        fu = dataclasses.replace(CFG, layer_variants=("full", "full"))
        lm = np.asarray(M.forward(CFG, params, toks))
        lh = np.asarray(M.forward(hy, params, toks))
        lf = np.asarray(M.forward(fu, params, toks))
        assert not np.allclose(lh, lm) and not np.allclose(lh, lf)
