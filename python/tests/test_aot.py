"""AOT pipeline tests: spec registry consistency, manifest integrity,
HLO-text emission, and the flat-argument conventions the Rust runtime
relies on."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


class TestSpecs:
    def setup_method(self):
        self.specs = aot.build_specs()

    def test_unique_names(self):
        names = [s.name for s in self.specs]
        assert len(names) == len(set(names))

    def test_all_groups_present(self):
        groups = {s.group for s in self.specs}
        assert {"core", "scaling", "scaling_long", "granularity", "hybrid",
                "sft", "needle"} <= groups

    def test_every_figure_has_artifacts(self):
        names = {s.name for s in self.specs}
        # Fig 3a/3b ladder
        for size in aot.LADDER:
            for var in ("moba", "full"):
                assert f"scaling_{size}_{var}_train" in names
                assert f"long_{size}_{var}_train" in names
        # Fig 4 granularity
        for nb in (8, 16, 32, 64, 128):
            assert f"gran_nb{nb:03d}_train" in names
        # Fig 5 hybrid + sft
        assert "hybrid_moba_train" in names and "hybrid_full_train" in names
        for k in (0, 1, 2, 3, 5):
            assert f"sft_full{k}_train" in names
        # Fig 6/7 needle stages
        for s in range(3):
            assert f"needle_s{s}_train" in names

    def test_hash_stable_and_sensitive(self):
        a = self.specs[0]
        assert a.hash() == a.hash()
        import dataclasses
        b = dataclasses.replace(a, seq=a.seq * 2)
        assert a.hash() != b.hash()

    def test_sparsity_settings_match_paper(self):
        """The scaled configs preserve the paper's sparsity ratios."""
        by_name = {s.name: s for s in self.specs}
        s = by_name["scaling_s0_moba_train"]
        assert 1 - s.cfg.block_size * s.cfg.topk / s.seq == pytest.approx(0.8125)
        l = by_name["long_s0_moba_train"]
        assert 1 - l.cfg.block_size * l.cfg.topk / l.seq == pytest.approx(0.953125)
        # granularity ablation: 75% sparsity at every granularity
        for nb, topk in ((8, 2), (16, 4), (32, 8), (64, 16), (128, 32)):
            g = by_name[f"gran_nb{nb:03d}_train"]
            assert 1 - g.cfg.block_size * g.cfg.topk / g.seq == pytest.approx(0.75)

    def test_layer_variants_helper(self):
        assert aot.variants("full", 3) == ("full",) * 3
        assert aot.variants("moba", 4, full_last=2) == ("moba", "moba", "full", "full")


class TestLowering:
    def test_train_fn_io_counts(self):
        cfg = M.ModelCfg(vocab=64, d_model=16, n_layers=1, n_heads=1,
                         head_dim=16, block_size=16, topk=2)
        spec = aot.Spec(name="t", group="g", kind="train", cfg=cfg, batch=1, seq=32)
        ins = aot._shape_structs(spec)
        n = len(M.params_spec(cfg))
        assert len(ins) == 3 * n + 4
        lowered = jax.jit(aot._fn_for(spec)).lower(*[sd for _, sd in ins])
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        # no `topk` custom instruction (xla_extension 0.5.1 cannot parse it)
        assert " topk(" not in text

    def test_eval_fn_shapes(self):
        cfg = M.ModelCfg(vocab=64, d_model=16, n_layers=1, n_heads=1,
                         head_dim=16, block_size=16, topk=2)
        spec = aot.Spec(name="e", group="g", kind="eval", cfg=cfg, batch=2, seq=32)
        ins = aot._shape_structs(spec)
        fn = aot._fn_for(spec)
        out = fn(*[jnp.zeros(sd.shape, sd.dtype) for _, sd in ins])
        assert out[0].shape == (2, 31)


@pytest.mark.skipif(not os.path.exists("../artifacts/manifest.json"),
                    reason="run `make artifacts` first")
class TestManifestOnDisk:
    def setup_method(self):
        with open("../artifacts/manifest.json") as f:
            self.manifest = {e["name"]: e for e in json.load(f)["artifacts"]}

    def test_manifest_covers_specs(self):
        for spec in aot.build_specs():
            assert spec.name in self.manifest, f"{spec.name} missing from manifest"

    def test_files_exist_and_are_hlo(self):
        for name, e in list(self.manifest.items())[:10]:
            path = os.path.join("../artifacts", e["path"])
            assert os.path.exists(path), name
            with open(path) as f:
                head = f.read(32)
            assert head.startswith("HloModule"), name

    def test_train_entries_have_consistent_leaves(self):
        e = self.manifest["quickstart_train"]
        n = len(e["params"])
        assert len(e["inputs"]) == 3 * n + 4
        assert len(e["outputs"]) == 3 * n + 1
        for i, p in enumerate(e["params"]):
            assert e["inputs"][i]["shape"] == p["shape"]

    def test_param_counts_match_spec(self):
        for spec in aot.build_specs():
            if spec.kind in ("kernel_moba", "kernel_flash"):
                continue
            e = self.manifest[spec.name]
            total = sum(
                int(jnp.prod(jnp.asarray(p["shape"]))) if p["shape"] else 1
                for p in e["params"])
            assert total == e["model"]["param_count"], spec.name
