//! Continuous-batching serving demo on the pure-Rust stack — no AOT
//! artifacts, no PJRT: a Poisson-ish arrival stream of synthetic prompts
//! is prefilled once through the MoBA backend and then decoded
//! incrementally over the KV/block-pool caches, with the iteration-level
//! scheduler admitting new requests into the in-flight decode batch.
//! Thin wrapper over the shared driver in `moba::serve::demo` (the
//! `repro serve` subcommand drives the same code).
//!
//! Compare backends to see the cache win end-to-end:
//!
//! ```sh
//! cargo run --release --example serve_continuous -- --backend cached-sparse
//! cargo run --release --example serve_continuous -- --backend full   # recompute baseline
//! # shared-system-prompt serving over the copy-on-write paged pool:
//! cargo run --release --example serve_continuous -- --backend paged \
//!     --shared-prefix 1024 --pool-blocks 512
//! # oversubscribed pool: capacity below the working set forces LRU
//! # eviction + re-prefill resume (tokens unchanged; the report shows
//! # preemptions, reclaimed blocks and re-prefill overhead):
//! cargo run --release --example serve_continuous -- --backend paged \
//!     --requests 12 --prompt-len 256 --pool-blocks 24
//! # thread-per-core decode: persistent pinned workers + work stealing
//! # (the default; compare against the legacy re-spawning tick loop):
//! cargo run --release --example serve_continuous -- --decode-workers 0 \
//!     --runtime persistent
//! cargo run --release --example serve_continuous -- --decode-workers 0 \
//!     --runtime tick
//! # multi-layer hybrid stack: one paged backend per layer, full
//! # attention on layer 2, layer-summed pool accounting:
//! cargo run --release --example serve_continuous -- --backend paged \
//!     --layers moba,moba,full,moba --pool-blocks 256
//! ```

use moba::serve::{run_demo, DemoCfg, RuntimeKind};
use moba::sparse::BackendKind;
use moba::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["no-steal", "no-pin"])?;
    // `--workers 0` / `--decode-workers 0` mean "all available cores"
    let resolve = |n: usize| if n == 0 { moba::sparse::default_workers() } else { n };
    let d = DemoCfg::default();
    let cfg = DemoCfg {
        requests: args.get_usize("requests", 12)?,
        max_in_flight: args.get_usize("max-batch", 4)?,
        prompt_len: args.get_usize("prompt-len", 256)?,
        max_new: args.get_usize("max-new", 32)?,
        block_size: args.get_usize("block", 32)?,
        topk: args.get_usize("topk", 3)?,
        backend: BackendKind::parse(args.get_str("backend", "cached-sparse"))?,
        layers: match args.get("layers") {
            Some(v) => moba::serve::parse_layers("--layers", Some(v.to_string()))
                .map_err(|e| anyhow::anyhow!(e))?
                .unwrap_or_default(),
            None => d.layers.clone(), // lenient MOBA_LAYERS via DemoCfg::default
        },
        workers: resolve(args.get_usize("workers", 1)?),
        decode_workers: resolve(args.get_usize("decode-workers", 1)?),
        runtime: RuntimeKind::parse(args.get_str("runtime", d.runtime.label()))?,
        steal: if args.flag("no-steal") { false } else { d.steal },
        pin: if args.flag("no-pin") { false } else { d.pin },
        shared_prefix: args.get_usize("shared-prefix", 0)?,
        pool_blocks: args.get_usize("pool-blocks", 0)?,
        seed: args.get_u64("seed", 7)?,
        // swap_blocks / chaos_seed / barrier_deadline_secs keep their
        // env-derived defaults (MOBA_SWAP_BLOCKS / MOBA_CHAOS_SEED)
        ..d
    };
    run_demo(&cfg)
}
