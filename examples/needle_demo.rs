//! Needle-in-a-haystack demo (a fast, single-length slice of Fig 7):
//! trains the retrieval model at 512 context and prints a depth sweep of
//! retrieval accuracy, comparing the MoBA scoring graph against the
//! layer-wise-hybrid graph.
//!
//! ```sh
//! cargo run --release --example needle_demo -- [--steps 150]
//! ```

use moba::coordinator::StageSchedule;
use moba::data::NeedleGen;
use moba::eval::needle_score::score_needles;
use moba::runtime::{artifacts_dir, Engine};
use moba::train::{LrSchedule, Trainer};
use moba::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let steps = args.get_u64("steps", 150)?;

    let engine = Engine::new(&artifacts_dir())?;
    let gen = NeedleGen::new(13);

    println!("training needle model at 512 ctx ({steps} steps, MoBA block 32 top-3)...");
    let lr = LrSchedule::new(2e-3, steps, 0.05, 0.1);
    let mut trainer =
        Trainer::new(&engine, StageSchedule::single("needle_s0_train", steps), lr, 13)?;
    trainer.run(
        |step| gen.train_batch(13, step, 1, 512, 0.1),
        |info| {
            if info.step % 25 == 0 {
                println!("  step {:>4} loss {:.4}", info.step, info.loss);
            }
        },
    )?;

    println!("\ndepth sweep @512 ctx (8 needles per cell):");
    println!("{:>7} {:>10} {:>10}", "depth", "moba", "hybrid*");
    for depth in [0.0, 0.2, 0.4, 0.6, 0.8, 1.0] {
        let samples = gen.eval_samples(77, 512, depth, 8);
        let acc = score_needles(&engine, "needle_s0_logits", &trainer.state.params, &samples)?;
        // full-attention twin shares geometry -> same params score there too
        let acc_full =
            score_needles(&engine, "needle_s0_full_logits", &trainer.state.params, &samples)?;
        println!("{depth:>7.1} {acc:>10.2} {acc_full:>10.2}");
    }
    println!("(*hybrid column scores the same weights through the full-attention graph,");
    println!("  the paper's decode-time configuration)");
    Ok(())
}
