//! Serving demo: the paper's deployment mode (§3.3) — MoBA for prefill,
//! full attention for decode — behind a vLLM-style admission batcher
//! with a simulated Poisson-ish arrival process.
//!
//! Trains a small retrieval model, then serves a stream of
//! needle-retrieval requests and reports accuracy, queueing and service
//! latency distributions, and prefill/decode throughput.
//!
//! ```sh
//! cargo run --release --example serve_moba -- [--requests 12] [--steps 80]
//! ```

use moba::coordinator::StageSchedule;
use moba::data::NeedleGen;
use moba::metrics::{mean, quantile};
use moba::runtime::{artifacts_dir, Engine};
use moba::serve::{ArtifactServeEngine, Batcher, BatcherCfg, Request, RequestResult};
use moba::train::{LrSchedule, Trainer};
use moba::util::cli::Args;
use moba::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[])?;
    let n_requests = args.get_usize("requests", 12)?;
    let steps = args.get_u64("steps", 80)?;

    let engine = Engine::new(&artifacts_dir())?;
    let gen = NeedleGen::new(7);

    // --- train the backing model -----------------------------------------
    println!("training retrieval model ({steps} steps)...");
    let lr = LrSchedule::new(2e-3, steps, 0.05, 0.1);
    let mut trainer =
        Trainer::new(&engine, StageSchedule::single("needle_s0_train", steps), lr, 7)?;
    trainer.run(
        |step| gen.train_batch(7, step, 1, 512, 0.1),
        |info| {
            if info.step % 20 == 0 {
                println!("  step {:>4} loss {:.4}", info.step, info.loss);
            }
        },
    )?;

    let serve = ArtifactServeEngine::new(
        &engine,
        trainer.state.params.clone(),
        "needle_s0_logits",      // MoBA graph: prefill
        "needle_s0_full_logits", // full-attention graph: decode
    )?;

    // --- simulated arrival stream + batcher -------------------------------
    let mut batcher = Batcher::new(BatcherCfg { max_batch: 4, max_wait_secs: 0.2 });
    let mut rng = Rng::new(99);
    let mut arrivals = Vec::new();
    let mut t = 0.0f64;
    for id in 0..n_requests as u64 {
        t += -0.3 * (1.0 - rng.f64()).ln(); // exp(0.3s) inter-arrival
        let sample = gen.eval_samples(500 + id, 512, rng.f64(), 1).remove(0);
        arrivals.push((
            Request {
                id,
                prompt: sample.tokens[..sample.answer_pos].to_vec(),
                max_new: 1,
                arrival: t,
            },
            sample.value,
        ));
    }

    println!("\nserving {n_requests} requests (max_batch=4, max_wait=200ms)...");
    let mut results: Vec<(RequestResult, i32)> = Vec::new();
    let mut clock = 0.0f64;
    let mut idx = 0;
    let mut prefill_total = 0.0;
    let mut decode_total = 0.0;
    while results.len() < n_requests {
        // admit everything that has arrived by `clock`
        while idx < arrivals.len() && arrivals[idx].0.arrival <= clock {
            batcher.push(arrivals[idx].0.clone());
            idx += 1;
        }
        let batch = match batcher.pop_batch(clock) {
            Some(b) => b,
            None => {
                // advance the clock to the next event
                clock = if idx < arrivals.len() {
                    arrivals[idx].0.arrival
                } else {
                    clock + 0.05
                };
                continue;
            }
        };
        for req in batch {
            let queue_secs = clock - req.arrival;
            let t0 = std::time::Instant::now();
            let (out, stats) = serve.generate(&req.prompt, req.max_new)?;
            let service = t0.elapsed().as_secs_f64();
            prefill_total += stats.prefill_secs;
            decode_total += stats.decode_secs;
            clock += service; // single worker: service advances the clock
            let expect = arrivals.iter().find(|(r, _)| r.id == req.id).unwrap().1;
            results.push((
                RequestResult {
                    id: req.id,
                    output: out,
                    queue_secs,
                    prefill_secs: stats.prefill_secs,
                    decode_secs: stats.decode_secs,
                    decode_steps: stats.decode_steps,
                },
                expect,
            ));
        }
    }

    // --- report -----------------------------------------------------------
    let correct = results.iter().filter(|(r, expect)| r.output[0] == *expect).count();
    let queues: Vec<f64> = results.iter().map(|(r, _)| r.queue_secs * 1e3).collect();
    let services: Vec<f64> = results.iter().map(|(r, _)| r.service_secs() * 1e3).collect();
    println!("\n== serving report ==");
    println!("retrieval accuracy: {correct}/{n_requests}");
    println!(
        "queue latency   ms: mean {:.0}  p50 {:.0}  p95 {:.0}",
        mean(&queues),
        quantile(&queues, 0.5),
        quantile(&queues, 0.95)
    );
    println!(
        "service latency ms: mean {:.0}  p50 {:.0}  p95 {:.0}",
        mean(&services),
        quantile(&services, 0.5),
        quantile(&services, 0.95)
    );
    println!(
        "prefill {:.2}s total (MoBA graph), decode {:.2}s total (full graph)",
        prefill_total, decode_total
    );
    println!("throughput: {:.1} req/s", n_requests as f64 / clock.max(1e-9));
    Ok(())
}
