//! Quickstart: the smallest end-to-end path through all three layers.
//!
//! Loads the AOT artifacts (L1 Pallas kernel + L2 train/eval graphs),
//! trains a tiny MoBA language model for a few dozen steps on the
//! synthetic corpus, evaluates held-out loss, and runs the standalone
//! MoBA kernel artifact against the pure-Rust reference.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use moba::coordinator::StageSchedule;
use moba::data::{Corpus, VAL_STREAM_BASE};
use moba::eval::losses::positionwise_mean;
use moba::runtime::{artifacts_dir, Engine};
use moba::tensor::Tensor;
use moba::train::{LrSchedule, Trainer};
use moba::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(&artifacts_dir())?;
    println!("PJRT platform: {}", engine.platform());

    // --- 1. the L1 kernel, straight through PJRT -------------------------
    let mut rng = Rng::new(7);
    let mk = |rng: &mut Rng| {
        Tensor::from_vec(&[256, 2, 32], (0..256 * 2 * 32).map(|_| rng.normal_f32(1.0)).collect())
            .unwrap()
    };
    let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
    let kernel_out = engine.kernel("kernel_moba_n256", &q, &k, &v)?;
    let reference = moba::sparse::moba_attention(&q, &k, &v, 32, 3);
    println!(
        "MoBA Pallas kernel vs pure-Rust reference: max |diff| = {:.2e}",
        kernel_out.max_abs_diff(&reference)
    );

    // --- 2. train a tiny MoBA LM ----------------------------------------
    let steps = 40;
    let art = engine.manifest.get("quickstart_train")?;
    println!(
        "training quickstart model: {} params, seq {}, block {} top-{} ({:.1}% sparse)",
        art.model.param_count,
        art.seq,
        art.model.block_size,
        art.model.topk,
        art.sparsity() * 100.0
    );
    let corpus = Corpus::for_vocab(art.model.vocab, 42);
    let lr = LrSchedule::new(3e-3, steps, 0.1, 0.1);
    let mut trainer =
        Trainer::new(&engine, StageSchedule::single("quickstart_train", steps), lr, 42)?;
    let (batch, seq) = (art.batch, art.seq);
    let summary = trainer.run(
        |step| corpus.batch(42, step, batch, seq),
        |info| {
            if info.step % 10 == 0 {
                println!("  step {:>3}  loss {:.4}", info.step, info.loss);
            }
        },
    )?;
    println!("final train loss: {:.4} ({:.1}s)", summary.final_loss, summary.total_secs);

    // --- 3. held-out evaluation -----------------------------------------
    let eval = positionwise_mean(
        &engine,
        "quickstart_eval",
        &trainer.state.params,
        |i| corpus.batch(42, VAL_STREAM_BASE + i, batch, seq),
        4,
    )?;
    println!("held-out loss: {:.4} (ppl {:.1})", eval.mean(), eval.mean().exp());
    Ok(())
}
