//! End-to-end training driver (the repository's E2E validation run):
//! trains the largest ladder model (s4) with MoBA attention on the
//! synthetic corpus with the full production path — stage schedule,
//! cosine LR, CSV logging, checkpointing, held-out position-wise eval —
//! and prints the loss curve summary. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! cargo run --release --example train_lm -- [--steps 150] [--size s4] [--full]
//! ```

use moba::config::TrainConfig;
use moba::coordinator::StageSchedule;
use moba::data::{Corpus, VAL_STREAM_BASE};
use moba::eval::losses::{positionwise_mean, trailing_mean};
use moba::metrics::writer::RunDir;
use moba::runtime::{artifacts_dir, checkpoint, Engine};
use moba::train::{LrSchedule, Trainer};
use moba::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["full"])?;
    let size = args.get_str("size", "s4");
    let variant = if args.flag("full") { "full" } else { "moba" };
    let steps = args.get_u64("steps", 150)?;

    let engine = Engine::new(&artifacts_dir())?;
    let train_name = format!("scaling_{size}_{variant}_train");
    let eval_name = format!("scaling_{size}_{variant}_eval");
    let art = engine.manifest.get(&train_name)?;
    let cfg = TrainConfig { steps, batch: art.batch, seq: art.seq, ..Default::default() };

    println!(
        "== train_lm: {} ({} params, {} layers, seq {}, {} tokens total) ==",
        train_name,
        art.model.param_count,
        art.model.n_layers,
        art.seq,
        cfg.tokens()
    );

    let dir = RunDir::create(&format!("train_lm/{size}_{variant}"))?;
    let mut csv = dir.csv("loss.csv", &["step", "loss", "lr", "secs"])?;
    let corpus = Corpus::for_vocab(art.model.vocab, cfg.seed);
    let lr = LrSchedule::new(cfg.base_lr, steps, cfg.warmup_frac, cfg.min_lr_frac);
    let mut trainer = Trainer::new(&engine, StageSchedule::single(&train_name, steps), lr, cfg.seed)?;
    let (batch, seq, seed) = (cfg.batch, cfg.seq, cfg.seed);
    let summary = trainer.run(
        |step| corpus.batch(seed, step, batch, seq),
        |info| {
            let _ = csv.row(&[info.step as f64, info.loss as f64, info.lr, info.step_secs]);
            if info.step % 10 == 0 {
                println!(
                    "step {:>5}/{steps}  loss {:.4}  lr {:.2e}  {:.2}s/step",
                    info.step, info.loss, info.lr, info.step_secs
                );
            }
        },
    )?;
    csv.flush()?;
    checkpoint::save(&trainer.state, &dir.path.join("model.ckpt"))?;

    let eval = positionwise_mean(
        &engine,
        &eval_name,
        &trainer.state.params,
        |i| corpus.batch(seed, VAL_STREAM_BASE + i, batch, seq),
        6,
    )?;
    println!("\n== summary ==");
    println!("train loss: {:.4} -> {:.4}", summary.losses[0], summary.final_loss);
    println!("held-out loss: {:.4} (ppl {:.1})", eval.mean(), eval.mean().exp());
    println!("trailing (last 1/32): {:.4}", trailing_mean(&eval, 1.0 / 32.0));
    println!("wall clock: {:.1}s ({:.2}s/step)", summary.total_secs, summary.total_secs / steps as f64);
    println!("artifacts: {}", dir.path.display());
    Ok(())
}
