//! API stub for the `xla` PJRT bindings used by `moba::runtime::engine`.
//!
//! The real crate (vendored on PJRT-capable build images) wraps the XLA
//! client: HLO-proto parsing, JIT compilation and device execution. This
//! stub mirrors exactly the surface the engine consumes so that
//! `--features xla` type-checks on any box; every entry point that would
//! need a PJRT runtime returns [`Error::Unavailable`] instead. Swap the
//! `xla` path dependency in `rust/Cargo.toml` to the real crate to run
//! against actual artifacts.

use std::fmt;
use std::path::Path;

/// Stub error: the PJRT runtime is not linked into this build.
#[derive(Debug)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the real PJRT-backed `xla` crate \
                 (this build links the API stub in rust/vendor/xla-stub)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the engine marshals across the host/device boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host literal (stub: carries no data).
#[derive(Debug, Default, Clone)]
pub struct Literal {}

impl Literal {
    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal {}
    }

    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal {}
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal {})
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::Unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }
}

/// Array shape of a literal.
#[derive(Debug, Default, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module proto.
#[derive(Debug, Default)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation built from a proto.
#[derive(Debug, Default)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Device-resident execution result buffer.
#[derive(Debug, Default)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable.
#[derive(Debug, Default)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
#[derive(Debug, Default)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}
