//! Bench: regenerates Fig 2a/2b (cost-model sweeps at paper scale plus
//! measured CPU kernel crossover). `cargo bench --bench fig2_efficiency`.
//!
//! Criterion is unavailable offline; this is a plain-main bench
//! (harness=false) that prints the paper-shaped series.

use moba::experiments::efficiency::{run, EfficiencyArgs};

fn main() {
    let max = std::env::var("FIG2_MEASURE_MAX")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2048);
    run(&EfficiencyArgs { measure_max: max, seed: 42 }).expect("fig2 bench");
}
