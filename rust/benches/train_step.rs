//! Bench: end-to-end PJRT train-step latency with the marshal/execute
//! breakdown — the number that bounds every experiment's wall clock and
//! the main L3 §Perf target (state roundtrip must stay a small fraction
//! of the step).

use std::time::Instant;

use moba::data::Corpus;
use moba::runtime::{artifacts_dir, Engine, ModelState};

fn main() {
    let engine = Engine::new(&artifacts_dir()).expect("run `make artifacts` first");
    println!("== train-step bench (PJRT CPU) ==");
    println!(
        "{:>26} {:>10} {:>10} {:>12} {:>12} {:>10}",
        "artifact", "params", "step_ms", "exec_ms", "marshal_ms", "marshal%"
    );
    for name in ["quickstart_train", "scaling_s2_moba_train", "scaling_s2_full_train"] {
        let art = match engine.manifest.get(name) {
            Ok(a) => a.clone(),
            Err(_) => continue,
        };
        let mut state = ModelState::init(&art, 1).unwrap();
        let corpus = Corpus::for_vocab(art.model.vocab, 1);
        let (tokens, mask) = corpus.batch(1, 0, art.batch, art.seq);
        // warmup (includes XLA compile)
        engine.train_step(name, &mut state, 1e-3, &tokens, &mask).unwrap();
        engine.reset_timers();
        let reps = 5;
        let t0 = Instant::now();
        for _ in 0..reps {
            engine.train_step(name, &mut state, 1e-3, &tokens, &mask).unwrap();
        }
        let step_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        let exec_ms = *engine.exec_secs.borrow() * 1e3 / reps as f64;
        let marshal_ms = *engine.marshal_secs.borrow() * 1e3 / reps as f64;
        println!(
            "{:>26} {:>10} {:>10.1} {:>12.1} {:>12.1} {:>9.1}%",
            name,
            art.model.param_count,
            step_ms,
            exec_ms,
            marshal_ms,
            100.0 * marshal_ms / step_ms
        );
    }

    // §Perf: scan-fused K-step graphs vs single-step loops
    println!("\n== fused train_k vs single-step loop (per-step ms) ==");
    println!("{:>30} {:>12} {:>12} {:>9}", "artifact", "single_ms", "fused_ms", "speedup");
    for (single, fused) in [
        ("quickstart_train", "quickstart_train_k8"),
        ("scaling_s2_moba_train", "scaling_s2_moba_train_k8"),
    ] {
        let (Ok(art), Ok(artk)) = (engine.manifest.get(single), engine.manifest.get(fused))
        else {
            continue;
        };
        let (art, artk) = (art.clone(), artk.clone());
        let k = artk.k_steps;
        let corpus = Corpus::for_vocab(art.model.vocab, 2);
        let mut state = moba::runtime::ModelState::init(&art, 2).unwrap();
        let (tokens, mask) = corpus.batch(2, 0, art.batch, art.seq);
        engine.train_step(single, &mut state, 1e-3, &tokens, &mask).unwrap(); // warm
        let reps = 2;
        let t0 = Instant::now();
        for _ in 0..reps * k {
            engine.train_step(single, &mut state, 1e-3, &tokens, &mask).unwrap();
        }
        let single_ms = t0.elapsed().as_secs_f64() * 1e3 / (reps * k) as f64;

        let mut toks = Vec::new();
        let mut masks = Vec::new();
        for i in 0..k {
            let (t, m) = corpus.batch(2, i as u64, art.batch, art.seq);
            toks.extend(t.data);
            masks.extend(m.data);
        }
        let ktokens =
            moba::tensor::IntTensor::from_vec(&[k, art.batch, art.seq], toks).unwrap();
        let kmask =
            moba::tensor::Tensor::from_vec(&[k, art.batch, art.seq - 1], masks).unwrap();
        let lrs = vec![1e-3f32; k];
        engine.train_k_steps(fused, &mut state, &lrs, &ktokens, &kmask).unwrap(); // warm
        let t1 = Instant::now();
        for _ in 0..reps {
            engine.train_k_steps(fused, &mut state, &lrs, &ktokens, &kmask).unwrap();
        }
        let fused_ms = t1.elapsed().as_secs_f64() * 1e3 / (reps * k) as f64;
        println!(
            "{:>30} {:>12.1} {:>12.1} {:>9.2}",
            fused,
            single_ms,
            fused_ms,
            single_ms / fused_ms
        );
    }
}
