//! Bench: cached incremental decode vs full recompute, per generated
//! token, across context lengths — the serving rewrite's headline number
//! — plus the paged-pool sharing arm: bytes per session when S sessions
//! share a long system prefix copy-on-write.
//!
//! Four backends at each N: the recompute baselines (`full`, `moba` —
//! what the old serving path did every step) and the cached backends
//! (`cached-full` O(N·D), `cached-sparse` O(N/B·D + k·B·D)). The paged
//! arm forks S sessions off a shared 4096-token prefix and reports
//! per-session decode latency and unique-KV bytes per session against
//! the private-cache cost. The oversubscribed arm serves a request burst
//! through a pool capped at ~50% of the concurrent working set and
//! reports the eviction/re-prefill overhead the bounded pool trades for
//! the halved residency (tokens are asserted bitwise equal to the
//! uncapped run). Appends a trajectory entry to `BENCH_decode.json` at
//! the repo root (quick mode too, flagged `"quick": true`) and asserts
//! the acceptance floors: cached-sparse beats full recompute by ≥5× at
//! N=8192, and the shared pool holds < 0.65× the private per-session
//! bytes.
//!
//! ```sh
//! cargo bench --bench decode_latency            # full run + asserts
//! cargo bench --bench decode_latency -- --quick # CI smoke: small N,
//!                                               # bit-identity asserts only
//! ```

use std::time::Instant;

use moba::serve::{ContinuousScheduler, Request, SchedulerCfg, ServeCfg, ServeEngine, ToyModel};
use moba::sparse::{build_backend, shared_pool, AttentionBackend, BackendKind, PagedMobaAttention};
use moba::tensor::Tensor;
use moba::util::json::{arr, num, obj, s, Json};
use moba::util::rng::Rng;

const HEADS: usize = 2;
const DIM: usize = 32;
const BLOCK: usize = 64;
const TOPK: usize = 3;

fn rand_t(shape: &[usize], rng: &mut Rng) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
}

fn prefix(t: &Tensor, n: usize) -> Tensor {
    let w = t.shape[1] * t.shape[2];
    Tensor::from_vec(&[n, t.shape[1], t.shape[2]], t.data[..n * w].to_vec()).unwrap()
}

fn row(t: &Tensor, i: usize) -> &[f32] {
    let w = t.shape[1] * t.shape[2];
    &t.data[i * w..(i + 1) * w]
}

/// Prefill `n - steps` tokens, then time `steps` decode tokens.
/// Returns ms per decoded token.
fn decode_ms_per_token(
    kind: BackendKind,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    n: usize,
    steps: usize,
) -> f64 {
    let mut backend = build_backend(kind, HEADS, DIM, BLOCK, TOPK);
    let base = n - steps;
    backend.prefill(&prefix(q, base), &prefix(k, base), &prefix(v, base));
    let t0 = Instant::now();
    for t in base..n {
        let out = backend.decode(row(q, t), row(k, t), row(v, t));
        assert!(out.iter().all(|x| x.is_finite()));
    }
    t0.elapsed().as_secs_f64() * 1e3 / steps as f64
}

/// Results of the paged-pool sharing arm.
struct PagedArm {
    json: Json,
    ms_per_tok: f64,
    pool_bytes_per_session: usize,
    sharing_ratio: f64,
}

/// The paged-pool sharing arm: S sessions forked off an `n_prefix`-token
/// shared system prompt, each decoding its own tail out to context `n`.
/// Session 0 replays the original stream and must match a private
/// cached-sparse session bit-for-bit — the parity contract the pool
/// ships under; the rest decode divergent tails for the memory and
/// latency numbers.
fn paged_sharing_arm(n: usize, n_prefix: usize, sessions: usize, rng: &mut Rng) -> PagedArm {
    assert!(sessions >= 2 && n_prefix < n && n_prefix % BLOCK == 0);
    let q = rand_t(&[n, HEADS, DIM], rng);
    let k = rand_t(&[n, HEADS, DIM], rng);
    let v = rand_t(&[n, HEADS, DIM], rng);

    let pool = shared_pool(BLOCK, HEADS, DIM, None);
    let mut parent = PagedMobaAttention::new(pool.clone(), TOPK);
    parent.prefill(&prefix(&q, n_prefix), &prefix(&k, n_prefix), &prefix(&v, n_prefix));

    let mut forks: Vec<Box<dyn AttentionBackend>> =
        (0..sessions).map(|_| parent.fork().expect("paged backend forks")).collect();

    let mut reference = build_backend(BackendKind::CachedSparse, HEADS, DIM, BLOCK, TOPK);
    reference.prefill(&prefix(&q, n_prefix), &prefix(&k, n_prefix), &prefix(&v, n_prefix));
    for i in n_prefix..n {
        let got = forks[0].decode(row(&q, i), row(&k, i), row(&v, i));
        let want = reference.decode(row(&q, i), row(&k, i), row(&v, i));
        assert_eq!(got, want, "paged fork diverged from private cache at t={i}");
    }

    let tail = n - n_prefix;
    let mut decode_secs = 0.0f64;
    for fork in forks.iter_mut().skip(1) {
        // divergent per-session tails: fresh noise, same geometry
        let qt = rand_t(&[tail, HEADS, DIM], rng);
        let kt = rand_t(&[tail, HEADS, DIM], rng);
        let vt = rand_t(&[tail, HEADS, DIM], rng);
        let t0 = Instant::now();
        for i in 0..tail {
            let out = fork.decode(row(&qt, i), row(&kt, i), row(&vt, i));
            assert!(out.iter().all(|x| x.is_finite()));
        }
        decode_secs += t0.elapsed().as_secs_f64();
    }
    // mean over every measured fork's tail, not just the last one
    let ms_per_tok = decode_secs * 1e3 / ((sessions - 1) * tail) as f64;

    // sample the pool while every session is still alive: S full contexts
    // resident, prefix blocks held once
    let (used_blocks, payload) = {
        let p = pool.read().unwrap();
        (p.used_blocks(), p.payload_bytes())
    };
    let row_bytes = HEADS * DIM * 2 * std::mem::size_of::<f32>();
    let private_per_session = n * row_bytes;
    let pool_per_session = payload / sessions;
    let sharing_ratio = pool_per_session as f64 / private_per_session as f64;
    let json = obj(vec![
        ("n", num(n as f64)),
        ("shared_prefix", num(n_prefix as f64)),
        ("sessions", num(sessions as f64)),
        ("paged_decode_ms_per_tok", num(ms_per_tok)),
        ("pool_blocks", num(used_blocks as f64)),
        ("pool_bytes_per_session", num(pool_per_session as f64)),
        ("private_bytes_per_session", num(private_per_session as f64)),
        ("sharing_ratio", num(sharing_ratio)),
    ]);
    PagedArm { json, ms_per_tok, pool_bytes_per_session: pool_per_session, sharing_ratio }
}

/// The oversubscribed-pool serving arm: a burst of `requests` equal
/// prompts decoded under the continuous scheduler, once with an
/// unbounded pool and once with capacity at ~50% of the concurrent
/// worst-case working set. The bounded run must serve bitwise-identical
/// tokens (asserted, quick mode included) via LRU eviction + re-prefill
/// resume; returns the JSON row reporting the recompute overhead.
fn oversubscribed_arm(quick: bool) -> Json {
    let (requests, prompt_len, max_new) =
        if quick { (6usize, 96usize, 8usize) } else { (12, 1024, 32) };
    let max_in_flight = 4usize;
    let mk_engine = |pool_blocks| {
        ServeEngine::new(
            ToyModel::new(64, HEADS, DIM, 7),
            ServeCfg {
                block_size: BLOCK,
                topk: TOPK,
                max_seq: 8192,
                backend: BackendKind::Paged,
                workers: 1,
                pool_blocks,
                ..Default::default()
            },
        )
    };
    let mk_reqs = || -> Vec<Request> {
        (0..requests as u64)
            .map(|id| {
                let prompt = (0..prompt_len as i32).map(|i| (i * 5 + id as i32) % 64).collect();
                Request::new(id, prompt, max_new, 0.0)
            })
            .collect()
    };
    let per_need = (prompt_len + max_new + BLOCK - 1) / BLOCK;
    let working_set = max_in_flight * per_need;
    let pool_blocks = (working_set / 2).max(per_need + 1);

    let run = |pool_blocks: usize| {
        let mut sched = ContinuousScheduler::new(
            mk_engine(pool_blocks),
            SchedulerCfg { max_in_flight, decode_workers: 1, ..SchedulerCfg::default() },
        );
        let t0 = Instant::now();
        let mut out = sched.run_stream(mk_reqs(), 0.001).expect("oversubscribed stream");
        out.sort_by_key(|r| r.id);
        (out, sched.stats.clone(), t0.elapsed().as_secs_f64())
    };
    let (base, _, uncapped_secs) = run(0);
    let (got, stats, capped_secs) = run(pool_blocks);
    assert_eq!(base.len(), got.len(), "oversubscribed run lost requests");
    for (b, g) in base.iter().zip(&got) {
        assert_eq!(b.output, g.output, "req {}: tokens changed under oversubscription", b.id);
    }
    let ev = &stats.eviction;
    assert!(ev.evictions > 0, "a pool at 50% of the working set must evict");
    assert!(stats.peak_pool_blocks <= pool_blocks, "pool capacity violated");
    println!(
        "oversubscribed: pool {pool_blocks}/{working_set} working-set blocks: \
         {} evictions ({} blocks), {} resumes, re-prefill {:.1} ms \
         ({:.2}x wall vs uncapped)",
        ev.evictions,
        ev.blocks_reclaimed,
        ev.resumes,
        ev.reprefill_secs * 1e3,
        capped_secs / uncapped_secs.max(1e-9)
    );
    obj(vec![
        ("requests", num(requests as f64)),
        ("prompt_len", num(prompt_len as f64)),
        ("max_new", num(max_new as f64)),
        ("pool_blocks", num(pool_blocks as f64)),
        ("working_set_blocks", num(working_set as f64)),
        ("evictions", num(ev.evictions as f64)),
        ("blocks_reclaimed", num(ev.blocks_reclaimed as f64)),
        ("resumes", num(ev.resumes as f64)),
        ("reprefill_ms", num(ev.reprefill_secs * 1e3)),
        ("uncapped_secs", num(uncapped_secs)),
        ("capped_secs", num(capped_secs)),
    ])
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    println!("== decode latency: cached incremental vs recompute ==");
    println!("H={HEADS} D={DIM} block={BLOCK} top-{TOPK}; per-token decode ms at context N");
    println!(
        "{:>8} {:>14} {:>14} {:>14} {:>14} {:>10}",
        "N", "recomp_full", "recomp_moba", "cached_full", "cached_sparse", "speedup"
    );

    let mut rng = Rng::new(2025);
    let mut rows = Vec::new();
    let mut speedup_at_8192 = 0.0f64;
    let lengths: &[usize] = if quick { &[512] } else { &[512, 2048, 8192] };
    for &n in lengths {
        let q = rand_t(&[n, HEADS, DIM], &mut rng);
        let k = rand_t(&[n, HEADS, DIM], &mut rng);
        let v = rand_t(&[n, HEADS, DIM], &mut rng);
        // recompute decode is O(N^2)/step — keep its sample count small;
        // cached decode is cheap, average over more steps
        let recompute_steps = if quick || n >= 8192 { 2 } else { 4 };
        let cached_steps = if quick { 8 } else { 32 };

        let rf = decode_ms_per_token(BackendKind::RecomputeFull, &q, &k, &v, n, recompute_steps);
        let rm = decode_ms_per_token(BackendKind::RecomputeMoba, &q, &k, &v, n, recompute_steps);
        let cf = decode_ms_per_token(BackendKind::CachedFull, &q, &k, &v, n, cached_steps);
        let cs = decode_ms_per_token(BackendKind::CachedSparse, &q, &k, &v, n, cached_steps);

        let speedup = rf / cs;
        if n == 8192 {
            speedup_at_8192 = speedup;
        }
        println!(
            "{:>8} {:>14.3} {:>14.3} {:>14.4} {:>14.4} {:>9.0}x",
            n, rf, rm, cf, cs, speedup
        );
        rows.push(obj(vec![
            ("n", num(n as f64)),
            ("recompute_full_ms_per_tok", num(rf)),
            ("recompute_moba_ms_per_tok", num(rm)),
            ("cached_full_ms_per_tok", num(cf)),
            ("cached_sparse_ms_per_tok", num(cs)),
            ("speedup_cached_sparse_vs_recompute_full", num(speedup)),
        ]));
    }

    // the paged-pool sharing arm: S sessions, one shared system prefix
    let (pn, pprefix, psessions) = if quick { (512, 256, 3) } else { (8192, 4096, 8) };
    let paged = paged_sharing_arm(pn, pprefix, psessions, &mut rng);
    println!(
        "paged sharing: N={pn} prefix={pprefix} S={psessions}: {:.4} ms/tok, \
         {:.1} KiB/session unique KV ({:.2}x of private)",
        paged.ms_per_tok,
        paged.pool_bytes_per_session as f64 / 1024.0,
        paged.sharing_ratio
    );

    // the oversubscribed-pool arm: bitwise-parity asserted in quick mode
    // too — eviction + re-prefill must be invisible in the tokens
    let oversub = oversubscribed_arm(quick);

    // the trajectory entry is written in quick mode as well (flagged), so
    // CI can upload BENCH_decode.json as an artifact from the smoke run
    let unix_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let entry = obj(vec![
        ("bench", s("decode_latency")),
        ("quick", Json::Bool(quick)),
        ("unix_secs", num(unix_secs)),
        ("heads", num(HEADS as f64)),
        ("head_dim", num(DIM as f64)),
        ("block", num(BLOCK as f64)),
        ("topk", num(TOPK as f64)),
        ("rows", arr(rows)),
        ("paged_sharing", paged.json),
        ("oversubscribed", oversub),
    ]);
    // trajectory file at the REPO ROOT regardless of bench cwd
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_decode.json");
    let mut trajectory = match std::fs::read_to_string(path).ok().and_then(|t| Json::parse(&t).ok())
    {
        Some(Json::Arr(entries)) => entries,
        _ => Vec::new(),
    };
    trajectory.push(entry);
    // temp-file + rename: a crash mid-write cannot truncate the trajectory
    moba::metrics::atomic_write(std::path::Path::new(path), &Json::Arr(trajectory).to_string())
        .expect("writing BENCH_decode.json");
    println!("-> {path}");

    if quick {
        println!("quick mode: finite outputs + paged/eviction parity; perf asserts skipped");
        return;
    }

    assert!(
        speedup_at_8192 >= 5.0,
        "acceptance: cached decode must beat recompute by >=5x at N=8192 (got {speedup_at_8192:.1}x)"
    );
    println!("acceptance OK: {speedup_at_8192:.0}x >= 5x at N=8192");
    assert!(
        paged.sharing_ratio < 0.65,
        "acceptance: shared pool must hold < 0.65x private bytes/session (got {:.2}x)",
        paged.sharing_ratio
    );
    println!(
        "acceptance OK: paged sharing at {:.2}x of private bytes/session",
        paged.sharing_ratio
    );
}
