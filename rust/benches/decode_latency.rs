//! Bench: cached incremental decode vs full recompute, per generated
//! token, across context lengths — the serving rewrite's headline number.
//!
//! Four backends at each N: the recompute baselines (`full`, `moba` —
//! what the old serving path did every step) and the cached backends
//! (`cached-full` O(N·D), `cached-sparse` O(N/B·D + k·B·D)). Appends a
//! trajectory entry to `BENCH_decode.json` and asserts the acceptance
//! floor: cached-sparse beats full recompute by ≥5× at N=8192.
//!
//! ```sh
//! cargo bench --bench decode_latency
//! ```

use std::time::Instant;

use moba::sparse::{build_backend, AttentionBackend, BackendKind};
use moba::tensor::Tensor;
use moba::util::json::{arr, num, obj, s, Json};
use moba::util::rng::Rng;

const HEADS: usize = 2;
const DIM: usize = 32;
const BLOCK: usize = 64;
const TOPK: usize = 3;

fn rand_t(shape: &[usize], rng: &mut Rng) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
}

fn prefix(t: &Tensor, n: usize) -> Tensor {
    let w = t.shape[1] * t.shape[2];
    Tensor::from_vec(&[n, t.shape[1], t.shape[2]], t.data[..n * w].to_vec()).unwrap()
}

fn row(t: &Tensor, i: usize) -> &[f32] {
    let w = t.shape[1] * t.shape[2];
    &t.data[i * w..(i + 1) * w]
}

/// Prefill `n - steps` tokens, then time `steps` decode tokens.
/// Returns ms per decoded token.
fn decode_ms_per_token(
    kind: BackendKind,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    n: usize,
    steps: usize,
) -> f64 {
    let mut backend = build_backend(kind, HEADS, DIM, BLOCK, TOPK);
    let base = n - steps;
    backend.prefill(&prefix(q, base), &prefix(k, base), &prefix(v, base));
    let t0 = Instant::now();
    for t in base..n {
        let out = backend.decode(row(q, t), row(k, t), row(v, t));
        assert!(out.iter().all(|x| x.is_finite()));
    }
    t0.elapsed().as_secs_f64() * 1e3 / steps as f64
}

fn main() {
    println!("== decode latency: cached incremental vs recompute ==");
    println!("H={HEADS} D={DIM} block={BLOCK} top-{TOPK}; per-token decode ms at context N");
    println!(
        "{:>8} {:>14} {:>14} {:>14} {:>14} {:>10}",
        "N", "recomp_full", "recomp_moba", "cached_full", "cached_sparse", "speedup"
    );

    let mut rng = Rng::new(2025);
    let mut rows = Vec::new();
    let mut speedup_at_8192 = 0.0f64;
    for &n in &[512usize, 2048, 8192] {
        let q = rand_t(&[n, HEADS, DIM], &mut rng);
        let k = rand_t(&[n, HEADS, DIM], &mut rng);
        let v = rand_t(&[n, HEADS, DIM], &mut rng);
        // recompute decode is O(N^2)/step — keep its sample count small;
        // cached decode is cheap, average over more steps
        let recompute_steps = if n >= 8192 { 2 } else { 4 };
        let cached_steps = 32;

        let rf = decode_ms_per_token(BackendKind::RecomputeFull, &q, &k, &v, n, recompute_steps);
        let rm = decode_ms_per_token(BackendKind::RecomputeMoba, &q, &k, &v, n, recompute_steps);
        let cf = decode_ms_per_token(BackendKind::CachedFull, &q, &k, &v, n, cached_steps);
        let cs = decode_ms_per_token(BackendKind::CachedSparse, &q, &k, &v, n, cached_steps);

        let speedup = rf / cs;
        if n == 8192 {
            speedup_at_8192 = speedup;
        }
        println!(
            "{:>8} {:>14.3} {:>14.3} {:>14.4} {:>14.4} {:>9.0}x",
            n, rf, rm, cf, cs, speedup
        );
        rows.push(obj(vec![
            ("n", num(n as f64)),
            ("recompute_full_ms_per_tok", num(rf)),
            ("recompute_moba_ms_per_tok", num(rm)),
            ("cached_full_ms_per_tok", num(cf)),
            ("cached_sparse_ms_per_tok", num(cs)),
            ("speedup_cached_sparse_vs_recompute_full", num(speedup)),
        ]));
    }

    let unix_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let entry = obj(vec![
        ("bench", s("decode_latency")),
        ("unix_secs", num(unix_secs)),
        ("heads", num(HEADS as f64)),
        ("head_dim", num(DIM as f64)),
        ("block", num(BLOCK as f64)),
        ("topk", num(TOPK as f64)),
        ("rows", arr(rows)),
    ]);
    // trajectory file: append this run's entry to the JSON array
    let path = "BENCH_decode.json";
    let mut trajectory = match std::fs::read_to_string(path).ok().and_then(|t| Json::parse(&t).ok())
    {
        Some(Json::Arr(entries)) => entries,
        _ => Vec::new(),
    };
    trajectory.push(entry);
    std::fs::write(path, Json::Arr(trajectory).to_string()).expect("writing BENCH_decode.json");
    println!("-> {path}");

    assert!(
        speedup_at_8192 >= 5.0,
        "acceptance: cached decode must beat recompute by >=5x at N=8192 (got {speedup_at_8192:.1}x)"
    );
    println!("acceptance OK: {speedup_at_8192:.0}x >= 5x at N=8192");
}
