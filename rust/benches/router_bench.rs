//! Bench: Algorithm-1 router throughput — gate computation and dispatch
//! plan construction, in token-assignments/s. The L3 hot-path components
//! a serving deployment would run per prefill.

use std::time::Instant;

use moba::coordinator::RoutingPlan;
use moba::sparse::moba_gate;
use moba::tensor::Tensor;
use moba::util::rng::Rng;

fn rand_t(shape: &[usize], rng: &mut Rng) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
}

fn main() {
    println!("== router bench: gate + dispatch plan ==");
    println!(
        "{:>8} {:>6} {:>8} {:>12} {:>14} {:>14}",
        "N", "heads", "block", "gate_ms", "plan_ms", "assign/s"
    );
    let mut rng = Rng::new(1);
    for &(n, h, block, topk) in
        &[(1024usize, 2usize, 64usize, 3usize), (4096, 2, 64, 3), (4096, 8, 64, 3), (16384, 2, 256, 3)]
    {
        let q = rand_t(&[n, h, 32], &mut rng);
        let k = rand_t(&[n, h, 32], &mut rng);
        let reps = 3;

        let t0 = Instant::now();
        let mut gate = None;
        for _ in 0..reps {
            gate = Some(moba_gate(&q, &k, block, topk));
        }
        let gate_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        let gate = gate.unwrap();

        let t1 = Instant::now();
        let mut pairs = 0usize;
        for _ in 0..reps {
            pairs = 0;
            for hh in 0..h {
                let plan = RoutingPlan::build(&gate, hh, block);
                pairs += plan.total_pairs();
            }
        }
        let plan_ms = t1.elapsed().as_secs_f64() * 1e3 / reps as f64;
        let per_s = pairs as f64 / (plan_ms / 1e3);
        println!(
            "{:>8} {:>6} {:>8} {:>12.2} {:>14.3} {:>14.0}",
            n, h, block, gate_ms, plan_ms, per_s
        );
    }
}
