//! Bench: Algorithm-1 router throughput — gate computation and dispatch
//! plan construction, in token-assignments/s. The L3 hot-path components
//! a serving deployment would run per prefill. The gate is obtained
//! through the `AttentionBackend` trait (the path the serving stack
//! takes), and the bench asserts the gate's selection counts against the
//! paper invariant `|selected| = min(topk, cur+1)` — pinning that the
//! `select_nth_unstable_by` top-k rewrite left selections unchanged.

use std::time::Instant;

use moba::coordinator::RoutingPlan;
use moba::sparse::{AttentionBackend, Gate, MobaAttention};
use moba::tensor::Tensor;
use moba::util::rng::Rng;

fn rand_t(shape: &[usize], rng: &mut Rng) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
}

/// Selection-count invariant: every (head, query) row selects exactly
/// `min(topk, available-causal-blocks)` blocks, and the total matches the
/// closed form — any change to the top-k selection would break this.
fn assert_selection_counts(gate: &Gate, n: usize, h: usize, block: usize, topk: usize) {
    let mut expect_total = 0usize;
    for t in 0..n {
        expect_total += topk.min(t / block + 1);
    }
    expect_total *= h;
    assert_eq!(gate.total_selected(), expect_total, "total selected pairs changed");
    for hh in 0..h {
        for t in (0..n).step_by(17) {
            assert_eq!(
                gate.selected(hh, t).len(),
                topk.min(t / block + 1),
                "selection count changed at h={hh} t={t}"
            );
        }
    }
}

fn main() {
    println!("== router bench: gate + dispatch plan ==");
    println!(
        "{:>8} {:>6} {:>8} {:>12} {:>14} {:>14}",
        "N", "heads", "block", "gate_ms", "plan_ms", "assign/s"
    );
    let mut rng = Rng::new(1);
    for &(n, h, block, topk) in
        &[(1024usize, 2usize, 64usize, 3usize), (4096, 2, 64, 3), (4096, 8, 64, 3), (16384, 2, 256, 3)]
    {
        let q = rand_t(&[n, h, 32], &mut rng);
        let k = rand_t(&[n, h, 32], &mut rng);
        let backend = MobaAttention::new(h, 32, block, topk);
        let reps = 3;

        let t0 = Instant::now();
        let mut gate = None;
        for _ in 0..reps {
            gate = backend.gate(&q, &k);
        }
        let gate_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        let gate = gate.expect("moba backend always gates");
        assert_selection_counts(&gate, n, h, block, topk);

        let t1 = Instant::now();
        let mut pairs = 0usize;
        for _ in 0..reps {
            pairs = 0;
            for hh in 0..h {
                let plan = RoutingPlan::build(&gate, hh, block);
                pairs += plan.total_pairs();
            }
        }
        let plan_ms = t1.elapsed().as_secs_f64() * 1e3 / reps as f64;
        let per_s = pairs as f64 / (plan_ms / 1e3);
        println!(
            "{:>8} {:>6} {:>8} {:>12.2} {:>14.3} {:>14.0}",
            n, h, block, gate_ms, plan_ms, per_s
        );
    }
    println!("selection counts OK (top-k rewrite is selection-preserving)");
}
