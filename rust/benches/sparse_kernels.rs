//! Bench: pure-Rust attention kernels — GFLOP/s of the full-attention
//! baseline vs MoBA block-sparse streaming, and the mean-pool gate.
//! These are the measured kernels behind the Fig-2 CPU crossover.

use std::time::Instant;

use moba::attn_sim::{full_attention_flops, moba_attention_flops, AttnShape};
use moba::sparse;
use moba::tensor::Tensor;
use moba::util::rng::Rng;

fn rand_t(shape: &[usize], rng: &mut Rng) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
}

fn main() {
    println!("== sparse kernel bench (H=2, D=32, block 64, top-3) ==");
    println!(
        "{:>8} {:>12} {:>10} {:>12} {:>10} {:>9}",
        "N", "full_ms", "full_GF/s", "moba_ms", "moba_GF/s", "speedup"
    );
    let mut rng = Rng::new(3);
    let (h, d, block, topk) = (2usize, 32usize, 64usize, 3usize);
    let mut n = 512usize;
    while n <= 4096 {
        let q = rand_t(&[n, h, d], &mut rng);
        let k = rand_t(&[n, h, d], &mut rng);
        let v = rand_t(&[n, h, d], &mut rng);
        let reps = if n <= 1024 { 3 } else { 1 };

        let t0 = Instant::now();
        for _ in 0..reps {
            let _ = sparse::full_attention(&q, &k, &v);
        }
        let full_s = t0.elapsed().as_secs_f64() / reps as f64;

        let t1 = Instant::now();
        for _ in 0..reps {
            let _ = sparse::moba_attention(&q, &k, &v, block, topk);
        }
        let moba_s = t1.elapsed().as_secs_f64() / reps as f64;

        let shape = AttnShape::new(n, h, d);
        let f_gf = full_attention_flops(shape) / full_s / 1e9;
        let m_gf = moba_attention_flops(shape, block, topk) / moba_s / 1e9;
        println!(
            "{:>8} {:>12.1} {:>10.2} {:>12.1} {:>10.2} {:>9.2}",
            n,
            full_s * 1e3,
            f_gf,
            moba_s * 1e3,
            m_gf,
            full_s / moba_s
        );
        n *= 2;
    }
}
