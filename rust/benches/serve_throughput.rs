//! Bench: end-to-end serving throughput (tokens/s) — the persistent
//! thread-per-core decode runtime vs the legacy per-tick scoped-thread
//! loop, on a uniform burst and on a steal-heavy skewed-length burst.
//!
//! The persistent runtime spawns its named, core-pinned workers once and
//! feeds them over bounded channels; the tick loop re-spawns scoped
//! threads every decode round. Served tokens are bitwise identical
//! across runtimes, worker counts and stealing schedules — every arm is
//! asserted against the single-worker tick-loop baseline (quick mode
//! included) before any timing is reported. The skewed arm gives every
//! 4th request an 8× decode budget so shards drain unevenly and idle
//! persistent workers actually steal. Appends a trajectory entry to
//! `BENCH_serve.json` at the repo root and asserts the acceptance floor:
//! persistent + stealing ≥ 1.2× tick-loop tokens/s on the skewed arm at
//! the same worker count, on a 4+ core box.
//!
//! The **overload storm** arm feeds a seeded bursty multi-tenant trace
//! (`serve::load::storm` — long-tail prompts, priority mix, deadlines,
//! conversation resumes, plus one whale that can never fit the pool)
//! through a paged pool at roughly 4× oversubscription. Acceptance: the
//! run never aborts, sheds are nonzero (typed `ServeError::Shed`), every
//! non-shed request finishes, and with the degradation dial off the shed
//! id set and served tokens are bitwise identical to the tick-loop
//! oracle. p50/p99 queue/prefill/decode latency, shed counts and SLA
//! violations land in `BENCH_serve.json` alongside the throughput rows.
//!
//! The **hybrid-l4** arm serves the same burst shapes through a 4-layer
//! moba,moba,full,moba session stack (one paged backend per model layer,
//! `ServeCfg::layers`), parity-asserted against its own tick-loop
//! oracle, and probes `pool_layer_usage` on a live batch — the per-layer
//! block counts land in `BENCH_serve.json`.
//!
//! The **storm-swap** arm replays the same trace with the host swap tier
//! on (`SchedulerCfg::swap_blocks`): evictions snapshot victims to host
//! memory and resumes restore the bytes instead of re-prefilling. Shed
//! ids and tokens must stay bitwise identical to the swap-free oracle on
//! both runtimes, and (full mode) the mean swap-in resume must cost less
//! wall-clock than the mean re-prefill resume — the trade the tier
//! exists to win. Both resume costs are reported to `BENCH_serve.json`.
//!
//! ```sh
//! cargo bench --bench serve_throughput            # full run + asserts
//! cargo bench --bench serve_throughput -- --quick # CI smoke: small run,
//!                                                 # parity asserts only
//! ```

use std::time::Instant;

use moba::serve::{
    storm, summarize, ContinuousScheduler, DegradeCfg, LayerKind, Request, RuntimeKind,
    SchedulerCfg, ServeCfg, ServeEngine, StormCfg, ToyModel,
};
use moba::sparse::BackendKind;
use moba::util::json::{arr, num, obj, s, Json};

const HEADS: usize = 2;
const DIM: usize = 16;
const BLOCK: usize = 32;
const TOPK: usize = 2;
const VOCAB: usize = 64;

struct Arm {
    name: &'static str,
    requests: usize,
    prompt_len: usize,
    max_new: usize,
    /// every `skew_every`-th request gets `skew_factor * max_new` decode
    /// steps (0 = uniform)
    skew_every: usize,
    skew_factor: usize,
}

fn arm_requests(arm: &Arm) -> Vec<Request> {
    (0..arm.requests as u64)
        .map(|id| {
            let skewed = arm.skew_every > 0 && id as usize % arm.skew_every == 0;
            let prompt: Vec<i32> = (0..arm.prompt_len as i32)
                .map(|i| (i * 7 + 3 * id as i32) % VOCAB as i32)
                .collect();
            let max_new = if skewed { arm.max_new * arm.skew_factor } else { arm.max_new };
            // a burst: everything queued up front, pure decode
            // throughput, no arrival-process noise
            Request::new(id, prompt, max_new, 0.0)
        })
        .collect()
}

struct RunOut {
    outputs: Vec<Vec<i32>>,
    tokens: usize,
    wall_secs: f64,
    steals: usize,
    stolen_steps: usize,
}

/// The single-layer throughput engine (fused backend, private caches).
fn fused_engine() -> ServeEngine<ToyModel> {
    ServeEngine::new(
        ToyModel::new(VOCAB, HEADS, DIM, 11),
        ServeCfg {
            block_size: BLOCK,
            topk: TOPK,
            max_seq: 8192,
            backend: BackendKind::Fused,
            workers: 1,
            ..Default::default()
        },
    )
}

/// A 4-layer hybrid moba,moba,full,moba paged engine: one backend per
/// model layer per session, all four block tables sharing one pool.
fn hybrid_engine(pool_blocks: usize) -> ServeEngine<ToyModel> {
    let layers = vec![LayerKind::Moba, LayerKind::Moba, LayerKind::Full, LayerKind::Moba];
    ServeEngine::new(
        ToyModel::stacked(VOCAB, HEADS, DIM, 11, layers.len()),
        ServeCfg {
            block_size: BLOCK,
            topk: TOPK,
            max_seq: 8192,
            backend: BackendKind::Paged,
            workers: 1,
            pool_blocks,
            layers,
        },
    )
}

fn run(
    engine: ServeEngine<ToyModel>,
    arm: &Arm,
    runtime: RuntimeKind,
    decode_workers: usize,
    steal: bool,
) -> RunOut {
    let mut sched = ContinuousScheduler::new(
        engine,
        SchedulerCfg {
            max_in_flight: 16,
            decode_workers,
            runtime,
            steal,
            ..SchedulerCfg::default()
        },
    );
    let t0 = Instant::now();
    let mut results = sched.run_stream(arm_requests(arm), 0.0).expect("serve stream");
    let wall_secs = t0.elapsed().as_secs_f64();
    results.sort_by_key(|r| r.id);
    let outputs: Vec<Vec<i32>> = results.iter().map(|r| r.output.clone()).collect();
    let tokens: usize = outputs.iter().map(|o| o.len()).sum();
    let ws = sched.worker_stats();
    RunOut {
        outputs,
        tokens,
        wall_secs,
        steals: ws.iter().map(|w| w.steals).sum(),
        stolen_steps: ws.iter().map(|w| w.stolen_steps).sum(),
    }
}

/// The overload trace: a seeded storm sized to roughly 4× pool
/// oversubscription (`max_in_flight` sessions wanting ~4× the blocks the
/// pool holds), plus one whale whose reservation exceeds the whole pool —
/// it can never fit and must be shed with a typed error. Returns
/// `(trace, pool_blocks)`.
fn storm_trace(quick: bool) -> (Vec<Request>, usize) {
    let pool_blocks = 12;
    let cfg = StormCfg {
        requests: if quick { 24 } else { 1000 },
        seed: 20260808,
        vocab: VOCAB,
        prompt_len: 40,
        max_new: 10,
        deadline_secs: 0.5,
        ..StormCfg::default()
    };
    let mut reqs = storm(&cfg);
    let whale = (pool_blocks + 2) * BLOCK;
    reqs.push(Request::new(reqs.len() as u64, vec![1; whale], 4, 0.0));
    (reqs, pool_blocks)
}

struct StormRun {
    outputs: Vec<(u64, Vec<i32>)>,
    shed_ids: Vec<u64>,
    wall_secs: f64,
    summary: moba::serve::StormSummary,
    evictions: usize,
    degraded: usize,
    /// re-prefill resumes and their wall-clock cost (the recompute path)
    resumes: usize,
    reprefill_secs: f64,
    /// host swap-tier counters (all zero when `swap_blocks == 0`)
    swap: moba::serve::SwapStats,
}

fn run_storm(
    trace: &[Request],
    pool_blocks: usize,
    runtime: RuntimeKind,
    workers: usize,
    steal: bool,
    degrade: Option<DegradeCfg>,
    swap_blocks: usize,
) -> StormRun {
    let engine = ServeEngine::new(
        ToyModel::new(VOCAB, HEADS, DIM, 11),
        ServeCfg {
            block_size: BLOCK,
            topk: TOPK,
            max_seq: 8192,
            backend: BackendKind::Paged,
            workers: 1,
            pool_blocks,
            ..Default::default()
        },
    );
    let mut sched = ContinuousScheduler::new(
        engine,
        SchedulerCfg {
            max_in_flight: 16,
            decode_workers: workers,
            runtime,
            steal,
            degrade,
            swap_blocks,
            ..SchedulerCfg::default()
        },
    );
    let t0 = Instant::now();
    let mut results = sched.run_stream(trace.to_vec(), 0.002).expect("storm stream");
    let wall_secs = t0.elapsed().as_secs_f64();
    results.sort_by_key(|r| r.id);
    let summary = summarize(trace, &results, sched.sheds().len());
    let mut shed_ids: Vec<u64> = sched.sheds().iter().map(|(id, _)| *id).collect();
    shed_ids.sort_unstable();
    StormRun {
        outputs: results.iter().map(|r| (r.id, r.output.clone())).collect(),
        shed_ids,
        wall_secs,
        summary,
        evictions: sched.stats.eviction.evictions,
        degraded: sched.stats.overload.degraded_sessions,
        resumes: sched.stats.eviction.resumes,
        reprefill_secs: sched.stats.eviction.reprefill_secs,
        swap: sched.stats.swap.clone(),
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // physical cores, NOT default_workers(): a MOBA_WORKERS override must
    // not distort the comparison or fake a "4+ core box"
    let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let multi = ncpu.max(2);

    let arms: Vec<Arm> = if quick {
        vec![
            Arm {
                name: "uniform",
                requests: 8,
                prompt_len: 48,
                max_new: 6,
                skew_every: 0,
                skew_factor: 1,
            },
            Arm {
                name: "skewed",
                requests: 8,
                prompt_len: 48,
                max_new: 4,
                skew_every: 4,
                skew_factor: 8,
            },
        ]
    } else {
        vec![
            Arm {
                name: "uniform",
                requests: 48,
                prompt_len: 128,
                max_new: 48,
                skew_every: 0,
                skew_factor: 1,
            },
            Arm {
                name: "skewed",
                requests: 32,
                prompt_len: 128,
                max_new: 16,
                skew_every: 4,
                skew_factor: 8,
            },
        ]
    };

    println!("== serving throughput: persistent thread-per-core vs tick-loop ==");
    println!(
        "H={HEADS} D={DIM} block={BLOCK} top-{TOPK}; {multi} decode workers multi{}",
        if quick { " (quick mode)" } else { "" }
    );
    println!(
        "{:>8} {:>11} {:>8} {:>6} {:>10} {:>12} {:>8} {:>8}",
        "arm", "runtime", "workers", "steal", "wall_s", "tok/s", "steals", "stolen"
    );

    let mut rows = Vec::new();
    let mut skewed_speedup = f64::NAN;
    for arm in &arms {
        // ground truth: single-worker tick loop
        let base = run(fused_engine(), arm, RuntimeKind::TickLoop, 1, false);
        let mut report = |label: &str, workers: usize, steal: bool, out: &RunOut| {
            let tok_per_s = out.tokens as f64 / out.wall_secs.max(1e-9);
            println!(
                "{:>8} {:>11} {:>8} {:>6} {:>10.3} {:>12.0} {:>8} {:>8}",
                arm.name, label, workers, steal, out.wall_secs, tok_per_s, out.steals,
                out.stolen_steps
            );
            rows.push(obj(vec![
                ("arm", s(arm.name)),
                ("runtime", s(label)),
                ("workers", num(workers as f64)),
                ("steal", Json::Bool(steal)),
                ("wall_secs", num(out.wall_secs)),
                ("tokens", num(out.tokens as f64)),
                ("tok_per_s", num(tok_per_s)),
                ("steals", num(out.steals as f64)),
                ("stolen_steps", num(out.stolen_steps as f64)),
            ]));
            tok_per_s
        };
        report("tick-loop", 1, false, &base);
        let mut best_tick = f64::NEG_INFINITY;
        let mut best_persistent = f64::NEG_INFINITY;
        for (runtime, workers, steal) in [
            (RuntimeKind::TickLoop, multi, false),
            (RuntimeKind::Persistent, 1, false),
            (RuntimeKind::Persistent, multi, false),
            (RuntimeKind::Persistent, multi, true),
        ] {
            let out = run(fused_engine(), arm, runtime, workers, steal);
            assert_eq!(
                out.outputs,
                base.outputs,
                "{}: {} workers={workers} steal={steal} changed served tokens",
                arm.name,
                runtime.label()
            );
            let tok_per_s = report(runtime.label(), workers, steal, &out);
            match runtime {
                RuntimeKind::TickLoop => best_tick = best_tick.max(tok_per_s),
                RuntimeKind::Persistent => {
                    if workers == multi {
                        best_persistent = best_persistent.max(tok_per_s);
                    }
                }
            }
        }
        if arm.skew_every > 0 {
            skewed_speedup = best_persistent / best_tick;
        }
    }

    // == multi-layer hybrid: a 4-layer moba,moba,full,moba paged stack ==
    // parity against the tick-loop oracle first, then a per-layer pool
    // accounting probe on a live batch; both land in BENCH_serve.json so
    // the hybrid stack's serving cost has a trajectory too
    let hybrid = Arm {
        name: "hybrid-l4",
        requests: if quick { 6 } else { 24 },
        prompt_len: if quick { 48 } else { 128 },
        max_new: if quick { 4 } else { 16 },
        skew_every: 4,
        skew_factor: 4,
    };
    let hybrid_base = run(hybrid_engine(0), &hybrid, RuntimeKind::TickLoop, 1, false);
    let hybrid_multi = run(hybrid_engine(0), &hybrid, RuntimeKind::Persistent, multi, true);
    assert_eq!(
        hybrid_multi.outputs, hybrid_base.outputs,
        "hybrid-l4: persistent workers={multi} changed served tokens"
    );
    for (label, workers, steal, out) in [
        ("tick-loop", 1usize, false, &hybrid_base),
        ("persistent", multi, true, &hybrid_multi),
    ] {
        let tok_per_s = out.tokens as f64 / out.wall_secs.max(1e-9);
        println!(
            "{:>8} {:>11} {:>8} {:>6} {:>10.3} {:>12.0} {:>8} {:>8}",
            hybrid.name, label, workers, steal, out.wall_secs, tok_per_s, out.steals,
            out.stolen_steps
        );
        rows.push(obj(vec![
            ("arm", s(hybrid.name)),
            ("layers", s("moba,moba,full,moba")),
            ("runtime", s(label)),
            ("workers", num(workers as f64)),
            ("steal", Json::Bool(steal)),
            ("wall_secs", num(out.wall_secs)),
            ("tokens", num(out.tokens as f64)),
            ("tok_per_s", num(tok_per_s)),
        ]));
    }
    // per-layer pool accounting probe: a live batch of uniform contexts
    // must hold the same block count in every layer's table set
    let probe = hybrid_engine(0);
    let probe_sessions: Vec<_> = (0..4u64)
        .map(|id| {
            let prompt: Vec<i32> = (0..hybrid.prompt_len as i32)
                .map(|i| (i * 7 + 3 * id as i32) % VOCAB as i32)
                .collect();
            probe.start(&prompt, 4).expect("probe session")
        })
        .collect();
    let per_layer = probe.pool_layer_usage().expect("hybrid stack is paged");
    assert_eq!(per_layer.len(), 4, "one usage counter per layer");
    assert!(
        per_layer.iter().all(|&u| u == per_layer[0]),
        "uniform contexts must hold equal blocks in every layer: {per_layer:?}"
    );
    rows.push(obj(vec![
        ("arm", s("hybrid-l4-pool")),
        ("layers", s("moba,moba,full,moba")),
        ("sessions", num(probe_sessions.len() as f64)),
        ("pool_blocks_total", num(per_layer.iter().sum::<usize>() as f64)),
        ("pool_blocks_by_layer", arr(per_layer.iter().map(|&u| num(u as f64)).collect())),
    ]));

    // == overload storm: bursty multi-tenant trace vs a small paged pool ==
    let (trace, pool_blocks) = storm_trace(quick);
    println!(
        "== overload storm: {} requests vs a {pool_blocks}-block paged pool ==",
        trace.len()
    );
    println!(
        "{:>11} {:>8} {:>6} {:>10} {:>6} {:>5} {:>5} {:>6} {:>10} {:>10}",
        "runtime", "workers", "steal", "wall_s", "done", "shed", "sla", "evict", "q_p50", "q_p99"
    );
    let mut storm_report = |arm: &str, label: &str, workers: usize, steal: bool, out: &StormRun| {
        let sm = &out.summary;
        println!(
            "{:>11} {:>8} {:>6} {:>10.3} {:>6} {:>5} {:>5} {:>6} {:>10.4} {:>10.4}",
            label, workers, steal, out.wall_secs, sm.completed, sm.shed, sm.sla_violations,
            out.evictions, sm.queue_p50, sm.queue_p99
        );
        rows.push(obj(vec![
            ("arm", s(arm)),
            ("runtime", s(label)),
            ("workers", num(workers as f64)),
            ("steal", Json::Bool(steal)),
            ("degraded", num(out.degraded as f64)),
            ("wall_secs", num(out.wall_secs)),
            ("completed", num(sm.completed as f64)),
            ("shed", num(sm.shed as f64)),
            ("sla_violations", num(sm.sla_violations as f64)),
            ("evictions", num(out.evictions as f64)),
            ("queue_p50", num(sm.queue_p50)),
            ("queue_p99", num(sm.queue_p99)),
            ("prefill_p50", num(sm.prefill_p50)),
            ("prefill_p99", num(sm.prefill_p99)),
            ("decode_p50", num(sm.decode_p50)),
            ("decode_p99", num(sm.decode_p99)),
            // resume-cost accounting: re-prefill recompute vs swap-in
            // restore, both in wall seconds (reporting-only)
            ("resumes", num(out.resumes as f64)),
            ("reprefill_secs", num(out.reprefill_secs)),
            ("swap_outs", num(out.swap.swap_outs as f64)),
            ("swap_ins", num(out.swap.swap_ins as f64)),
            ("swap_bytes", num(out.swap.bytes as f64)),
            ("swap_fallbacks", num(out.swap.fallbacks as f64)),
            ("swapin_secs", num(out.swap.swapin_secs)),
        ]));
    };
    // ground truth: the fault-free single-worker tick loop on the same
    // trace — overload decisions are simulation-clock arithmetic, so the
    // shed set and all served tokens must be bitwise identical under
    // every runtime/worker/steal combination
    let storm_base = run_storm(&trace, pool_blocks, RuntimeKind::TickLoop, 1, false, None, 0);
    assert!(
        !storm_base.shed_ids.is_empty(),
        "the storm must shed: the whale's reservation can never fit the pool"
    );
    assert_eq!(
        storm_base.outputs.len() + storm_base.shed_ids.len(),
        trace.len(),
        "overload control must account for every request: finished or shed, never lost"
    );
    storm_report("storm", "tick-loop", 1, false, &storm_base);
    for (runtime, workers, steal) in
        [(RuntimeKind::Persistent, 1, false), (RuntimeKind::Persistent, multi, true)]
    {
        let out = run_storm(&trace, pool_blocks, runtime, workers, steal, None, 0);
        assert_eq!(
            out.shed_ids,
            storm_base.shed_ids,
            "storm: {} workers={workers} steal={steal} changed the shed set",
            runtime.label()
        );
        assert_eq!(
            out.outputs,
            storm_base.outputs,
            "storm: {} workers={workers} steal={steal} changed served tokens",
            runtime.label()
        );
        storm_report("storm", runtime.label(), workers, steal, &out);
    }

    // == tiered KV swap: the same storm with a host swap tier on ==
    // Acceptance: the tier changes HOW preempted state survives, never
    // WHAT is served — shed ids and tokens stay bitwise identical to the
    // swap-free oracle on both runtimes — and a swap-in resume (block
    // memcpy) costs less wall-clock than a re-prefill resume (recompute).
    let swap_tier = 4 * pool_blocks;
    let mut swapin_mean = f64::NAN;
    for (runtime, workers, steal) in
        [(RuntimeKind::TickLoop, 1, false), (RuntimeKind::Persistent, multi, true)]
    {
        let out = run_storm(&trace, pool_blocks, runtime, workers, steal, None, swap_tier);
        assert_eq!(
            out.shed_ids,
            storm_base.shed_ids,
            "storm-swap: {} workers={workers} changed the shed set",
            runtime.label()
        );
        assert_eq!(
            out.outputs,
            storm_base.outputs,
            "storm-swap: {} workers={workers} changed served tokens",
            runtime.label()
        );
        assert!(
            out.swap.swap_outs > 0 && out.swap.swap_ins > 0,
            "storm-swap: {} an oversubscribed storm must exercise the tier",
            runtime.label()
        );
        if runtime == RuntimeKind::TickLoop {
            swapin_mean = out.swap.swapin_secs / out.swap.swap_ins.max(1) as f64;
        }
        storm_report("storm-swap", runtime.label(), workers, steal, &out);
    }
    let reprefill_mean = storm_base.reprefill_secs / storm_base.resumes.max(1) as f64;
    println!(
        "resume cost: re-prefill {:.1}us/resume ({} resumes) vs swap-in {:.1}us/resume",
        reprefill_mean * 1e6,
        storm_base.resumes,
        swapin_mean * 1e6
    );
    if !quick {
        assert!(storm_base.resumes > 0, "the swap-free storm must re-prefill");
        assert!(
            swapin_mean < reprefill_mean,
            "acceptance: swap-in restore ({swapin_mean:.2e}s) must resume cheaper than \
             re-prefill recompute ({reprefill_mean:.2e}s)"
        );
        println!(
            "acceptance OK: swap-in resumes {:.1}x cheaper than re-prefill",
            reprefill_mean / swapin_mean.max(1e-12)
        );
    }

    if !quick {
        // the pressure dial downshifts low-priority sessions' top-k under
        // occupancy pressure: tokens legitimately differ, but the run must
        // still account for every request and actually degrade someone
        let dial = Some(DegradeCfg { occupancy: 0.5, topk: 1 });
        let out = run_storm(&trace, pool_blocks, RuntimeKind::Persistent, multi, true, dial, 0);
        assert_eq!(out.outputs.len() + out.shed_ids.len(), trace.len());
        assert!(out.degraded > 0, "a 4x-oversubscribed storm must trip the 0.5-occupancy dial");
        storm_report("storm", "degraded", multi, true, &out);
    }

    // the trajectory entry is written in quick mode as well (flagged), so
    // CI can upload BENCH_serve.json as an artifact from the smoke run
    let unix_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let entry = obj(vec![
        ("bench", s("serve_throughput")),
        ("quick", Json::Bool(quick)),
        ("unix_secs", num(unix_secs)),
        ("heads", num(HEADS as f64)),
        ("head_dim", num(DIM as f64)),
        ("block", num(BLOCK as f64)),
        ("topk", num(TOPK as f64)),
        ("workers_multi", num(multi as f64)),
        ("rows", arr(rows)),
    ]);
    // trajectory file at the REPO ROOT regardless of bench cwd
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    let mut trajectory = match std::fs::read_to_string(path).ok().and_then(|t| Json::parse(&t).ok())
    {
        Some(Json::Arr(entries)) => entries,
        _ => Vec::new(),
    };
    trajectory.push(entry);
    // temp-file + rename: a crash mid-write cannot truncate the trajectory
    moba::metrics::atomic_write(std::path::Path::new(path), &Json::Arr(trajectory).to_string())
        .expect("writing BENCH_serve.json");
    println!("-> {path}");

    if quick {
        println!("quick mode: token parity verified across runtimes; perf asserts skipped");
        return;
    }

    if ncpu >= 4 {
        assert!(
            skewed_speedup >= 1.2,
            "acceptance: persistent runtime must serve >=1.2x tick-loop tokens/s on the \
             skewed arm at {multi} workers (got {skewed_speedup:.2}x)"
        );
        println!("acceptance OK: persistent {skewed_speedup:.2}x >= 1.2x tick-loop (skewed arm)");
    } else {
        println!(
            "perf acceptance skipped: only {ncpu} cores available (needs 4+); \
             parity was asserted on every arm"
        );
    }
}
