//! Bench: prefill throughput (tokens/s) — fused single-pass MoBA vs the
//! two-pass gate+attend baseline, single- and multi-worker.
//!
//! The fused kernel interleaves representative scoring, top-k selection
//! and online-softmax streaming in one pass per query row (no
//! materialized gate or affinity tensor); the head×query-tile
//! partitioner then spreads rows over worker threads. Outputs are
//! bit-identical across all of it, so this bench both measures AND
//! asserts: fused ≥ 1.3× two-pass at N=8192 on one worker, multi-worker
//! scaling ≥ 2× on a 4+ core box, and exact output equality everywhere.
//! Appends a trajectory entry to `BENCH_prefill.json` at the repo root.
//!
//! ```sh
//! cargo bench --bench prefill_throughput            # full run + asserts
//! cargo bench --bench prefill_throughput -- --quick # CI smoke: small N,
//!                                                   # identity asserts only
//! ```

use std::time::Instant;

use moba::sparse::{fused_moba_attention, moba_attention_par};
use moba::tensor::Tensor;
use moba::util::json::{arr, num, obj, s, Json};
use moba::util::rng::Rng;

const HEADS: usize = 2;
const DIM: usize = 32;
const BLOCK: usize = 64;
const TOPK: usize = 3;

fn rand_t(shape: &[usize], rng: &mut Rng) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
}

/// Best-of-`reps` wall time for `f`, in seconds.
fn time_best(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // physical cores, NOT default_workers(): a MOBA_WORKERS override must
    // not distort the scaling measurement or fake a "4+ core box"
    let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let lengths: &[usize] = if quick { &[512] } else { &[4096, 8192] };
    let reps = if quick { 1 } else { 2 };
    let multi = ncpu.max(2); // scaling column even on small boxes

    println!("== prefill throughput: fused single-pass vs two-pass gate+attend ==");
    println!(
        "H={HEADS} D={DIM} block={BLOCK} top-{TOPK}; tokens/s per kernel; {multi} workers multi{}",
        if quick { " (quick mode)" } else { "" }
    );
    println!(
        "{:>8} {:>14} {:>14} {:>9} {:>14} {:>9}",
        "N", "two_pass_tok/s", "fused_tok/s", "fusedX", "fused_mt_tok/s", "scaleX"
    );

    let mut rng = Rng::new(2026);
    let mut rows = Vec::new();
    let mut fused_speedup_at_8192 = f64::NAN;
    let mut scaling_at_8192 = f64::NAN;
    for &n in lengths {
        let q = rand_t(&[n, HEADS, DIM], &mut rng);
        let k = rand_t(&[n, HEADS, DIM], &mut rng);
        let v = rand_t(&[n, HEADS, DIM], &mut rng);

        // outputs first — the identity contract this bench relies on
        let two_pass = moba_attention_par(&q, &k, &v, BLOCK, TOPK, 1);
        let fused = fused_moba_attention(&q, &k, &v, BLOCK, TOPK, 1);
        let fused_mt = fused_moba_attention(&q, &k, &v, BLOCK, TOPK, multi);
        assert_eq!(fused.data, two_pass.data, "fused != two-pass at N={n}");
        assert_eq!(fused_mt.data, fused.data, "workers changed fused output at N={n}");

        let two_pass_s = time_best(reps, || {
            let _ = moba_attention_par(&q, &k, &v, BLOCK, TOPK, 1);
        });
        let fused_s = time_best(reps, || {
            let _ = fused_moba_attention(&q, &k, &v, BLOCK, TOPK, 1);
        });
        let fused_mt_s = time_best(reps, || {
            let _ = fused_moba_attention(&q, &k, &v, BLOCK, TOPK, multi);
        });

        let fused_x = two_pass_s / fused_s;
        let scale_x = fused_s / fused_mt_s;
        if n == 8192 {
            fused_speedup_at_8192 = fused_x;
            scaling_at_8192 = scale_x;
        }
        println!(
            "{:>8} {:>14.0} {:>14.0} {:>8.2}x {:>14.0} {:>8.2}x",
            n,
            n as f64 / two_pass_s,
            n as f64 / fused_s,
            fused_x,
            n as f64 / fused_mt_s,
            scale_x
        );
        rows.push(obj(vec![
            ("n", num(n as f64)),
            ("two_pass_tok_per_s", num(n as f64 / two_pass_s)),
            ("fused_tok_per_s", num(n as f64 / fused_s)),
            ("fused_mt_tok_per_s", num(n as f64 / fused_mt_s)),
            ("workers_mt", num(multi as f64)),
            ("fused_speedup_vs_two_pass", num(fused_x)),
            ("mt_scaling_vs_one_worker", num(scale_x)),
        ]));
    }

    // the trajectory entry is written in quick mode as well (flagged), so
    // CI can upload BENCH_prefill.json as an artifact from the smoke run
    let unix_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let entry = obj(vec![
        ("bench", s("prefill_throughput")),
        ("quick", Json::Bool(quick)),
        ("unix_secs", num(unix_secs)),
        ("heads", num(HEADS as f64)),
        ("head_dim", num(DIM as f64)),
        ("block", num(BLOCK as f64)),
        ("topk", num(TOPK as f64)),
        ("workers_multi", num(multi as f64)),
        ("rows", arr(rows)),
    ]);
    // trajectory file at the REPO ROOT regardless of bench cwd
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_prefill.json");
    let mut trajectory = match std::fs::read_to_string(path).ok().and_then(|t| Json::parse(&t).ok())
    {
        Some(Json::Arr(entries)) => entries,
        _ => Vec::new(),
    };
    trajectory.push(entry);
    // temp-file + rename: a crash mid-write cannot truncate the trajectory
    moba::metrics::atomic_write(std::path::Path::new(path), &Json::Arr(trajectory).to_string())
        .expect("writing BENCH_prefill.json");
    println!("-> {path}");

    if quick {
        println!("quick mode: outputs verified bit-identical; perf assertions skipped");
        return;
    }

    assert!(
        fused_speedup_at_8192 >= 1.3,
        "acceptance: fused single-pass must beat two-pass by >=1.3x at N=8192 \
         (got {fused_speedup_at_8192:.2}x)"
    );
    println!("acceptance OK: fused {fused_speedup_at_8192:.2}x >= 1.3x over two-pass at N=8192");
    if ncpu >= 4 {
        assert!(
            scaling_at_8192 >= 2.0,
            "acceptance: {ncpu}-worker prefill must scale >=2x over one worker at N=8192 \
             (got {scaling_at_8192:.2}x)"
        );
        println!("acceptance OK: {ncpu}-worker scaling {scaling_at_8192:.2}x >= 2x at N=8192");
    } else {
        println!("scaling acceptance skipped: only {ncpu} cores available (needs 4+)");
    }
}
