//! Device profiles for the cost model: a published-spec A100 profile
//! (the paper's testbed class) and a calibrated profile of *this* CPU,
//! fitted from the measured pure-Rust kernels so the model's crossover
//! predictions can be validated against wall-clock reality.

use crate::sparse;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: String,
    /// sustained attention FLOP/s (peak x achievable MFU)
    pub flops_per_s: f64,
    /// sustained memory bandwidth, bytes/s
    pub mem_bw: f64,
    /// per kernel-launch overhead, seconds
    pub kernel_overhead_s: f64,
    /// query tile used by the flash schedule
    pub tile_q: usize,
    pub elem_bytes: usize,
    /// pipeline-depth constant for varlen segments: a KV segment of
    /// length B runs at `B / (B + segment_pipeline)` of peak. Models the
    /// launch/drain cost of MoBA's many small varlen kernels — the reason
    /// the paper's Fig 2b inset shows near-parity at 32K despite 95%
    /// sparsity. 0 disables the penalty (CPU scalar loops don't pipeline).
    pub segment_pipeline: usize,
}

impl DeviceProfile {
    /// Efficiency multiplier for streaming KV segments of length `b`.
    pub fn segment_efficiency(&self, b: usize) -> f64 {
        if self.segment_pipeline == 0 {
            1.0
        } else {
            b as f64 / (b + self.segment_pipeline) as f64
        }
    }
}

/// A100-80GB class device running bf16 FlashAttention at ~40% MFU —
/// the regime of the paper's Fig 2 measurements.
pub fn a100_like() -> DeviceProfile {
    DeviceProfile {
        name: "a100-bf16".into(),
        flops_per_s: 312e12 * 0.40,
        mem_bw: 2.0e12 * 0.80,
        kernel_overhead_s: 8e-6,
        tile_q: 128,
        elem_bytes: 2,
        segment_pipeline: 2048,
    }
}

/// Calibrate a profile for the local CPU by timing the pure-Rust full
/// attention kernel at a modest size and backing out sustained FLOP/s.
pub fn calibrate_cpu(seed: u64) -> DeviceProfile {
    let (n, h, d) = (1024usize, 2usize, 32usize);
    let mut rng = Rng::new(seed);
    let mk = |rng: &mut Rng| {
        Tensor::from_vec(&[n, h, d], (0..n * h * d).map(|_| rng.normal_f32(1.0)).collect())
            .unwrap()
    };
    let q = mk(&mut rng);
    let k = mk(&mut rng);
    let v = mk(&mut rng);
    // warmup + timed run
    let _ = sparse::full_attention(&q, &k, &v);
    let t0 = std::time::Instant::now();
    let reps = 3;
    for _ in 0..reps {
        let _ = sparse::full_attention(&q, &k, &v);
    }
    let secs = t0.elapsed().as_secs_f64() / reps as f64;
    let flops = super::full_attention_flops(super::AttnShape::new(n, h, d));
    DeviceProfile {
        name: "cpu-calibrated".into(),
        flops_per_s: (flops / secs).max(1e8),
        mem_bw: 8e9,
        kernel_overhead_s: 0.0, // in-process function calls
        tile_q: 1,
        elem_bytes: 4,
        segment_pipeline: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_profile_sane() {
        let d = a100_like();
        assert!(d.flops_per_s > 1e13);
        assert!(d.mem_bw > 1e11);
    }

    #[test]
    fn cpu_calibration_positive() {
        let d = calibrate_cpu(1);
        assert!(d.flops_per_s > 1e7, "calibrated {} FLOP/s", d.flops_per_s);
        assert!(d.flops_per_s < 1e12, "implausibly fast CPU");
    }
}
