//! L1 kernel performance estimation for real TPU execution
//! (DESIGN.md §7: interpret=True gives CPU-numpy timings only, so the
//! Pallas kernel's TPU performance is *estimated* from its structure —
//! VMEM footprint, MXU tile efficiency, arithmetic intensity, and the
//! roofline ratio versus the paper's reported efficiency).
//!
//! The kernel under analysis is `python/compile/kernels/moba.py`: grid
//! (heads, N/q_tile); per grid step the VMEM working set is the q-tile,
//! one streamed KV block (double-buffered), the score tile and the
//! online-softmax accumulators.

/// One Pallas kernel configuration to price.
#[derive(Clone, Copy, Debug)]
pub struct KernelCfg {
    pub q_tile: usize,
    pub block: usize,
    pub head_dim: usize,
    pub topk: usize,
    /// element size in bytes (4 = f32 interpret path, 2 = bf16 MXU path)
    pub elem: usize,
}

/// TPU-core constants (TPUv4-class, per core).
pub const VMEM_BYTES: usize = 16 << 20;
pub const MXU_DIM: usize = 128;
pub const PEAK_BF16_FLOPS: f64 = 137.5e12; // per core
pub const HBM_BW: f64 = 0.6e12; // per core share

#[derive(Clone, Copy, Debug)]
pub struct KernelEstimate {
    /// VMEM working set per grid step, double-buffered KV
    pub vmem_bytes: usize,
    pub vmem_fraction: f64,
    /// fraction of MXU lanes used by the two matmuls (tile alignment)
    pub mxu_utilization: f64,
    /// FLOPs per HBM byte moved (arithmetic intensity)
    pub arith_intensity: f64,
    /// compute-bound? (intensity above the machine balance point)
    pub compute_bound: bool,
    /// predicted fraction of peak sustained (min of MXU util and
    /// bandwidth-derived ceiling)
    pub efficiency: f64,
}

fn mxu_tile_eff(rows: usize, cols: usize) -> f64 {
    // each matmul issues ceil(rows/128) x ceil(cols/128) MXU tiles; the
    // padded fraction is wasted
    let r_pad = (rows as f64 / MXU_DIM as f64).ceil() * MXU_DIM as f64;
    let c_pad = (cols as f64 / MXU_DIM as f64).ceil() * MXU_DIM as f64;
    (rows as f64 * cols as f64) / (r_pad * c_pad)
}

pub fn estimate(cfg: KernelCfg) -> KernelEstimate {
    let (bq, b, d, e) = (cfg.q_tile, cfg.block, cfg.head_dim, cfg.elem);
    // working set: q tile + 2x (double-buffered) K,V blocks + scores +
    // accumulator + m/l vectors
    let vmem = bq * d * e            // q tile
        + 2 * 2 * b * d * e          // K and V, double buffered
        + bq * b * 4                 // score tile (f32 accum)
        + bq * d * 4                 // output accumulator (f32)
        + 2 * bq * 4; // m, l
    // MXU: s = q @ k^T is [bq x d][d x b]; o += p @ v is [bq x b][b x d]
    let mxu = 0.5 * (mxu_tile_eff(bq, b) + mxu_tile_eff(bq, d));

    // per query tile: stream topk blocks; flops = 4 * bq * b * d * topk,
    // hbm bytes = topk * 2 * b * d * e (KV) + q/o traffic
    let flops = 4.0 * (bq * b * d * cfg.topk) as f64;
    let bytes = (cfg.topk * 2 * b * d * e + 2 * bq * d * e) as f64;
    let intensity = flops / bytes;
    let balance = PEAK_BF16_FLOPS / HBM_BW;
    let compute_bound = intensity >= balance;
    let bw_ceiling = (intensity / balance).min(1.0);
    KernelEstimate {
        vmem_bytes: vmem,
        vmem_fraction: vmem as f64 / VMEM_BYTES as f64,
        mxu_utilization: mxu,
        arith_intensity: intensity,
        compute_bound,
        efficiency: mxu.min(bw_ceiling),
    }
}

/// Print the L1 kernel report for the repo's shipped configurations.
pub fn report() -> String {
    let mut out = String::new();
    out.push_str("== L1 Pallas kernel TPU estimates (TPUv4-class core) ==\n");
    out.push_str(&format!(
        "{:<28} {:>10} {:>8} {:>8} {:>10} {:>8} {:>8}\n",
        "config", "vmem_KiB", "vmem%", "mxu%", "intensity", "bound", "eff%"
    ));
    let cases = [
        ("interpret f32 b=32 d=16", KernelCfg { q_tile: 128, block: 32, head_dim: 16, topk: 3, elem: 4 }),
        ("interpret f32 b=64 d=32", KernelCfg { q_tile: 128, block: 64, head_dim: 32, topk: 3, elem: 4 }),
        ("tpu bf16 b=512 d=128", KernelCfg { q_tile: 128, block: 512, head_dim: 128, topk: 3, elem: 2 }),
        ("tpu bf16 b=4096 d=128 k=12", KernelCfg { q_tile: 128, block: 4096, head_dim: 128, topk: 12, elem: 2 }),
        ("tpu bf16 b=4096 q=256", KernelCfg { q_tile: 256, block: 4096, head_dim: 128, topk: 12, elem: 2 }),
    ];
    for (name, cfg) in cases {
        let e = estimate(cfg);
        out.push_str(&format!(
            "{:<28} {:>10.1} {:>7.1}% {:>7.1}% {:>10.1} {:>8} {:>7.1}%\n",
            name,
            e.vmem_bytes as f64 / 1024.0,
            e.vmem_fraction * 100.0,
            e.mxu_utilization * 100.0,
            e.arith_intensity,
            if e.compute_bound { "compute" } else { "memory" },
            e.efficiency * 100.0,
        ));
    }
    out.push_str("\npaper reference: A100 FlashAttention sustains ~35-45% of peak on\n");
    out.push_str("long-context prefill; the b>=512 bf16 configs above land in the same\n");
    out.push_str("band, i.e. the kernel structure supports the paper's efficiency ratio.\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vmem_fits_for_all_shipped_configs() {
        for block in [32, 64, 512, 4096] {
            let e = estimate(KernelCfg { q_tile: 128, block, head_dim: 128, topk: 12, elem: 2 });
            assert!(e.vmem_fraction < 0.5, "block {block} uses {:.0}% VMEM", e.vmem_fraction * 100.0);
        }
    }

    #[test]
    fn mxu_full_for_aligned_tiles() {
        let e = estimate(KernelCfg { q_tile: 128, block: 512, head_dim: 128, topk: 3, elem: 2 });
        assert!((e.mxu_utilization - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mxu_penalized_for_small_head_dim() {
        let e = estimate(KernelCfg { q_tile: 128, block: 64, head_dim: 16, topk: 3, elem: 4 });
        assert!(e.mxu_utilization < 0.5);
    }

    #[test]
    fn big_blocks_are_compute_bound() {
        let e = estimate(KernelCfg { q_tile: 256, block: 4096, head_dim: 128, topk: 12, elem: 2 });
        assert!(e.arith_intensity > 100.0);
    }

    #[test]
    fn report_renders() {
        let r = report();
        assert!(r.contains("vmem_KiB"));
        assert!(r.lines().count() > 6);
    }
}
