//! Analytic attention cost model — the substrate behind the Fig-2
//! efficiency reproduction (DESIGN.md §4: the paper measured A100
//! clusters; we model the same FLOP/byte workloads and calibrate against
//! measured CPU kernels at small N, then sweep to 10M tokens).
//!
//! The model prices a *prefill attention forward pass* (the quantity
//! Fig 2 plots) as a roofline: `time = max(flops/peak_flops,
//! bytes/mem_bw) + per-kernel-launch overhead`, for
//!
//! - full attention (FlashAttention-style, causal): ~half the N^2 pairs;
//! - MoBA: gate (mean-pool + scores + top-k) + block-sparse pairs
//!   (`min(topk, available) * block_size` per query).

pub mod profiles;
pub mod tpu_estimate;

pub use profiles::DeviceProfile;

/// Workload description for one attention forward pass.
#[derive(Clone, Copy, Debug)]
pub struct AttnShape {
    pub n: usize,
    pub heads: usize,
    pub head_dim: usize,
}

impl AttnShape {
    pub fn new(n: usize, heads: usize, head_dim: usize) -> AttnShape {
        AttnShape { n, heads, head_dim }
    }
}

/// FLOPs of causal full attention (2 matmuls per pair: QK^T and PV).
pub fn full_attention_flops(s: AttnShape) -> f64 {
    // sum over t of (t+1) pairs = N(N+1)/2
    let pairs = (s.n as f64) * (s.n as f64 + 1.0) / 2.0;
    4.0 * pairs * (s.heads * s.head_dim) as f64
}

/// HBM traffic of flash-style full attention: Q read once, K/V streamed
/// once per query *tile* (tile size `tq`), O written once.
pub fn full_attention_bytes(s: AttnShape, tile_q: usize, elem: usize) -> f64 {
    let row = (s.heads * s.head_dim * elem) as f64;
    let q_io = 2.0 * s.n as f64 * row; // Q read + O write
    let tiles = (s.n as f64 / tile_q as f64).ceil();
    // each tile streams the causal prefix of K and V: average N/2
    let kv_io = tiles * (s.n as f64 / 2.0) * 2.0 * row;
    q_io + kv_io
}

/// Attention pairs MoBA computes: per query, the current block's causal
/// prefix plus up to (topk-1) full history blocks.
pub fn moba_pairs(n: usize, block: usize, topk: usize) -> f64 {
    let mut pairs = 0.0f64;
    let nb = n / block;
    for b in 0..nb {
        // queries in block b: current-block causal prefix averages (B+1)/2
        let cur = (block as f64 + 1.0) / 2.0 * block as f64;
        let hist_blocks = (topk - 1).min(b) as f64;
        pairs += cur + hist_blocks * (block * block) as f64;
    }
    pairs
}

pub fn moba_attention_flops(s: AttnShape, block: usize, topk: usize) -> f64 {
    4.0 * moba_pairs(s.n, block, topk) * (s.heads * s.head_dim) as f64
}

/// Gate cost: mean-pool (N*D reads) + scores Q x pooled (N * nb * D
/// MACs) + top-k selection (~ N * nb).
pub fn moba_gate_flops(s: AttnShape, block: usize) -> f64 {
    let nb = (s.n / block) as f64;
    let d = (s.heads * s.head_dim) as f64;
    let pool = s.n as f64 * d;
    let scores = 2.0 * s.n as f64 * nb * d;
    let select = s.n as f64 * nb;
    pool + scores + select
}

pub fn moba_bytes(s: AttnShape, block: usize, topk: usize, elem: usize) -> f64 {
    let row = (s.heads * s.head_dim * elem) as f64;
    let q_io = 2.0 * s.n as f64 * row;
    // per query tile (= one block of queries), stream topk KV blocks
    let nb = (s.n / block) as f64;
    let kv_io = nb * (topk as f64).min(nb) * block as f64 * 2.0 * row;
    // gate reads pooled keys
    let gate_io = nb * row * (s.n as f64 / block as f64);
    q_io + kv_io + gate_io
}

/// Roofline time for a workload on a device.
pub fn roofline_time(flops: f64, bytes: f64, dev: &DeviceProfile, kernels: f64) -> f64 {
    (flops / dev.flops_per_s).max(bytes / dev.mem_bw) + kernels * dev.kernel_overhead_s
}

/// Predicted full-attention prefill time.
pub fn full_time(s: AttnShape, dev: &DeviceProfile) -> f64 {
    let flops = full_attention_flops(s);
    let bytes = full_attention_bytes(s, dev.tile_q, dev.elem_bytes);
    let kernels = (s.n as f64 / dev.tile_q as f64).ceil();
    roofline_time(flops, bytes, dev, kernels)
}

/// Predicted MoBA prefill time (gate + sparse attention).
///
/// The attention FLOPs are divided by the *segment efficiency* of the
/// device: MoBA's varlen segments are only `block` long, so on pipelined
/// hardware they run below peak (paper Fig 2b inset: near-parity at 32K
/// where block=512 despite 95% sparsity; the advantage appears as blocks
/// grow with N). The gate is a dense matmul and runs at peak.
pub fn moba_time(s: AttnShape, block: usize, topk: usize, dev: &DeviceProfile) -> f64 {
    let eff = dev.segment_efficiency(block);
    let flops = moba_attention_flops(s, block, topk) / eff + moba_gate_flops(s, block);
    let bytes = moba_bytes(s, block, topk, dev.elem_bytes);
    // one varlen kernel per block segment pair + gate/rearrange kernels
    let kernels = 2.0 * (s.n / block) as f64 + 6.0;
    roofline_time(flops, bytes, dev, kernels)
}

/// Fig-2a style sweep row.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub n: usize,
    pub block: usize,
    pub topk: usize,
    pub full_ms: f64,
    pub moba_ms: f64,
    pub speedup: f64,
    pub sparsity: f64,
}

/// Sweep with fixed block/topk (Fig 2a: the 1M-model setting).
pub fn sweep_fixed_block(
    lengths: &[usize],
    block: usize,
    topk: usize,
    heads: usize,
    head_dim: usize,
    dev: &DeviceProfile,
) -> Vec<SweepRow> {
    lengths
        .iter()
        .map(|&n| {
            let s = AttnShape::new(n, heads, head_dim);
            let f = full_time(s, dev);
            let m = moba_time(s, block, topk, dev);
            SweepRow {
                n,
                block,
                topk,
                full_ms: f * 1e3,
                moba_ms: m * 1e3,
                speedup: f / m,
                // clamp: below the coverage point MoBA attends everything
                sparsity: (1.0 - (block * topk) as f64 / n as f64).max(0.0),
            }
        })
        .collect()
}

/// Sweep with fixed *block count* (Fig 2b: 64 blocks, top-3, sparsity
/// pinned at 95.31% while N scales to 10M).
pub fn sweep_fixed_nblocks(
    lengths: &[usize],
    n_blocks: usize,
    topk: usize,
    heads: usize,
    head_dim: usize,
    dev: &DeviceProfile,
) -> Vec<SweepRow> {
    lengths
        .iter()
        .map(|&n| {
            let block = n / n_blocks;
            let s = AttnShape::new(n, heads, head_dim);
            let f = full_time(s, dev);
            let m = moba_time(s, block, topk, dev);
            SweepRow {
                n,
                block,
                topk,
                full_ms: f * 1e3,
                moba_ms: m * 1e3,
                speedup: f / m,
                sparsity: 1.0 - (topk as f64 / n_blocks as f64),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use profiles::a100_like;

    #[test]
    fn full_flops_quadratic() {
        let s1 = AttnShape::new(1024, 8, 64);
        let s2 = AttnShape::new(2048, 8, 64);
        let r = full_attention_flops(s2) / full_attention_flops(s1);
        assert!((r - 4.0).abs() < 0.01, "r={r}");
    }

    #[test]
    fn moba_flops_linear_at_fixed_block() {
        let f1 = moba_attention_flops(AttnShape::new(1 << 16, 8, 64), 512, 3);
        let f2 = moba_attention_flops(AttnShape::new(1 << 17, 8, 64), 512, 3);
        let r = f2 / f1;
        assert!(r < 2.1, "should be ~linear, r={r}");
        assert!(r > 1.9);
    }

    #[test]
    fn moba_pairs_match_bruteforce() {
        // brute force per query t: causal prefix in the current block
        // plus min(topk-1, available) full history blocks
        let (n, b, k) = (256, 32, 3);
        let mut expect = 0.0;
        for t in 0..n {
            let cur = t / b;
            expect += (t % b + 1) as f64 + ((k - 1).min(cur) * b) as f64;
        }
        assert!((moba_pairs(n, b, k) - expect).abs() < 1e-6);
    }

    #[test]
    fn speedup_grows_with_length_fig2a() {
        // past the coverage point (N > topk*block) speedup grows with N
        let dev = a100_like();
        let rows = sweep_fixed_block(&[65536, 262144, 1 << 20], 4096, 12, 32, 128, &dev);
        assert!(rows[0].speedup < rows[1].speedup);
        assert!(rows[1].speedup < rows[2].speedup);
        // paper: ~6.5x at 1M with block 4096 top-12
        let s = rows[2].speedup;
        assert!(s > 4.0 && s < 12.0, "1M speedup {s} out of paper band");
    }

    #[test]
    fn covered_regime_near_parity_fig2a() {
        // at 8K with block 4096 top-12 MoBA covers the whole context:
        // same pairs as full attention, so near-parity (not a win)
        let dev = a100_like();
        let rows = sweep_fixed_block(&[8192], 4096, 12, 32, 128, &dev);
        assert!(rows[0].speedup > 0.5 && rows[0].speedup < 2.0,
                "8K speedup {}", rows[0].speedup);
    }

    #[test]
    fn fig2b_sparsity_constant() {
        let dev = a100_like();
        let rows = sweep_fixed_nblocks(&[1 << 20, 10 << 20], 64, 3, 32, 128, &dev);
        for r in &rows {
            assert!((r.sparsity - 0.953125).abs() < 1e-9);
        }
        // paper: 16x at 10M (same order; the pairs ratio bounds it at
        // ~12.8x for 64 blocks/top-3 before implementation effects)
        assert!(rows[1].speedup > rows[0].speedup);
        assert!(rows[1].speedup > 8.0, "10M speedup {}", rows[1].speedup);
    }

    #[test]
    fn short_lengths_comparable() {
        // paper inset: at 32K the two are comparable (block=512 segments
        // run far below peak, eating the 95% sparsity)
        let dev = a100_like();
        let rows = sweep_fixed_nblocks(&[32768], 64, 3, 32, 128, &dev);
        assert!(rows[0].speedup < 4.0, "32K speedup {}", rows[0].speedup);
    }
}
