//! Experiment presets: the scaled Table-1 ladder and per-experiment
//! step budgets (DESIGN.md §8 documents the scaling rationale).

use anyhow::Result;

use crate::runtime::Manifest;

/// One row of the scaled scaling-law ladder (paper Table 1).
#[derive(Clone, Debug)]
pub struct LadderEntry {
    pub name: &'static str,
    /// paper-analogue description
    pub paper_params: &'static str,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// Chinchilla-style token multiple (tokens = mult * params), scaled
    pub token_mult: f64,
}

/// The five model sizes (mirrors `python/compile/aot.py::LADDER`).
pub fn ladder_sizes() -> Vec<LadderEntry> {
    vec![
        LadderEntry { name: "s0", paper_params: "568M", d_model: 48, n_layers: 3, n_heads: 3, token_mult: 19.0 },
        LadderEntry { name: "s1", paper_params: "822M", d_model: 64, n_layers: 4, n_heads: 4, token_mult: 18.6 },
        LadderEntry { name: "s2", paper_params: "1.1B", d_model: 96, n_layers: 5, n_heads: 6, token_mult: 18.7 },
        LadderEntry { name: "s3", paper_params: "1.5B", d_model: 128, n_layers: 6, n_heads: 8, token_mult: 18.3 },
        LadderEntry { name: "s4", paper_params: "2.1B", d_model: 160, n_layers: 7, n_heads: 10, token_mult: 17.6 },
    ]
}

/// Render the scaled Table 1 (configuration of scaling-law experiments),
/// pulling live parameter counts from the manifest.
pub fn table1(manifest: &Manifest) -> Result<String> {
    let mut out = String::new();
    out.push_str("Table 1 (scaled): Configuration of Scaling Law Experiments\n");
    out.push_str("paper row -> this repo  (seq 512, block 32, top-3, 81.25% sparsity)\n\n");
    out.push_str(&format!(
        "{:<6} {:<10} {:>8} {:>6} {:>6} {:>7} {:>12} {:>10} {:>5}\n",
        "size", "paper", "params", "heads", "layers", "hidden", "tokens(opt)", "block", "topk"
    ));
    for e in ladder_sizes() {
        let art = manifest.get(&format!("scaling_{}_moba_train", e.name))?;
        let params = art.model.param_count;
        let tokens = (params as f64 * e.token_mult) as u64;
        out.push_str(&format!(
            "{:<6} {:<10} {:>8} {:>6} {:>6} {:>7} {:>12} {:>10} {:>5}\n",
            e.name,
            e.paper_params,
            params,
            art.model.n_heads,
            art.model.n_layers,
            art.model.d_model,
            tokens,
            art.model.block_size,
            art.model.topk,
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_is_monotone() {
        let l = ladder_sizes();
        assert_eq!(l.len(), 5);
        for w in l.windows(2) {
            assert!(w[0].d_model < w[1].d_model);
            assert!(w[0].n_layers < w[1].n_layers);
        }
    }

    #[test]
    fn table1_renders() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let Ok(m) = Manifest::load(&dir) else {
            eprintln!("artifacts missing — run `make artifacts` (skipping)");
            return;
        };
        if m.artifacts.contains_key("scaling_s0_moba_train") {
            let t = table1(&m).unwrap();
            assert!(t.contains("s4"));
            assert!(t.contains("2.1B"));
        }
    }
}
