//! Configuration system: typed run configs with JSON load/save and the
//! experiment presets (scaled Table-1 ladder, ablation grids).

pub mod presets;

use anyhow::Result;

use crate::util::json::{num, obj, Json};

/// Training-run hyperparameters owned by L3 (everything the AOT graphs
/// left as runtime inputs: step count, LR policy, seeding, data shape).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub steps: u64,
    pub base_lr: f64,
    /// linear warmup, as a fraction of total steps
    pub warmup_frac: f64,
    /// cosine floor, as a fraction of base_lr
    pub min_lr_frac: f64,
    pub seed: u64,
    pub batch: usize,
    pub seq: usize,
    pub log_every: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            base_lr: 3e-3,
            warmup_frac: 0.05,
            min_lr_frac: 0.1,
            seed: 42,
            batch: 1,
            seq: 512,
            log_every: 10,
        }
    }
}

impl TrainConfig {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("steps", num(self.steps as f64)),
            ("base_lr", num(self.base_lr)),
            ("warmup_frac", num(self.warmup_frac)),
            ("min_lr_frac", num(self.min_lr_frac)),
            ("seed", num(self.seed as f64)),
            ("batch", num(self.batch as f64)),
            ("seq", num(self.seq as f64)),
            ("log_every", num(self.log_every as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TrainConfig> {
        let d = TrainConfig::default();
        let g_u = |k: &str, dv: u64| -> Result<u64> {
            Ok(j.opt(k).map(|x| x.usize()).transpose()?.map(|v| v as u64).unwrap_or(dv))
        };
        let g_us = |k: &str, dv: usize| -> Result<usize> {
            Ok(j.opt(k).map(|x| x.usize()).transpose()?.unwrap_or(dv))
        };
        let g_f = |k: &str, dv: f64| -> Result<f64> {
            Ok(j.opt(k).map(|x| x.num()).transpose()?.unwrap_or(dv))
        };
        Ok(TrainConfig {
            steps: g_u("steps", d.steps)?,
            base_lr: g_f("base_lr", d.base_lr)?,
            warmup_frac: g_f("warmup_frac", d.warmup_frac)?,
            min_lr_frac: g_f("min_lr_frac", d.min_lr_frac)?,
            seed: g_u("seed", d.seed)?,
            batch: g_us("batch", d.batch)?,
            seq: g_us("seq", d.seq)?,
            log_every: g_u("log_every", d.log_every)?,
        })
    }

    /// Override from parsed CLI options (only keys that are present).
    pub fn apply_cli(&mut self, args: &crate::util::cli::Args) -> Result<()> {
        self.steps = args.get_u64("steps", self.steps)?;
        self.base_lr = args.get_f64("lr", self.base_lr)?;
        self.seed = args.get_u64("seed", self.seed)?;
        self.log_every = args.get_u64("log-every", self.log_every)?;
        Ok(())
    }

    /// Effective tokens consumed by this run.
    pub fn tokens(&self) -> u64 {
        self.steps * (self.batch * self.seq) as u64
    }
}

pub use presets::{ladder_sizes, table1, LadderEntry};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut c = TrainConfig::default();
        c.steps = 77;
        c.base_lr = 1.5e-3;
        let j = c.to_json();
        let back = TrainConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"steps": 5}"#).unwrap();
        let c = TrainConfig::from_json(&j).unwrap();
        assert_eq!(c.steps, 5);
        assert_eq!(c.batch, TrainConfig::default().batch);
    }

    #[test]
    fn cli_overrides() {
        let argv: Vec<String> = ["--steps", "9", "--lr", "0.01"]
            .iter().map(|s| s.to_string()).collect();
        let args = crate::util::cli::Args::parse(&argv, &[]).unwrap();
        let mut c = TrainConfig::default();
        c.apply_cli(&args).unwrap();
        assert_eq!(c.steps, 9);
        assert_eq!(c.base_lr, 0.01);
    }

    #[test]
    fn token_budget() {
        let c = TrainConfig { steps: 10, batch: 2, seq: 512, ..Default::default() };
        assert_eq!(c.tokens(), 10 * 1024);
    }
}
