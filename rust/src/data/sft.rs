//! Synthetic SFT data with prompt loss-masking (paper §3.2, Fig 5b/c).
//!
//! The paper attributes MoBA's SFT gap to *sparse gradients*: prompt
//! tokens are excluded from the loss, so gradient signal enters only at
//! a few response positions and must propagate back through sparse
//! attention. We reproduce that mechanism with a retrieval-style task:
//!
//! prompt:   `[KEY] k1 [VAL] v1 ... [KEY] kM [VAL] vM  filler`
//! response: `[QUERY] k_i [SEP] v_i` repeated for a few queried keys
//!
//! The response is supervised; the prompt is masked. Answering requires
//! attending from late (unmasked) positions to facts spread across the
//! masked prompt — exactly the gradient path the paper discusses.

use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;

use super::corpus::{Corpus, CorpusCfg};
use super::needle::{KEY_RANGE, TOK_KEY, TOK_QUERY, TOK_SEP, TOK_VAL, VAL_RANGE};

pub struct SftGen {
    corpus: Corpus,
    /// facts planted in the prompt
    pub n_facts: usize,
    /// queries in the response
    pub n_queries: usize,
}

impl SftGen {
    pub fn new(seed: u64) -> SftGen {
        SftGen { corpus: Corpus::new(CorpusCfg::default(), seed ^ 0x5F7), n_facts: 8, n_queries: 4 }
    }

    /// One (tokens, loss-mask) pair of total length `seq`.
    /// Mask is 1.0 only on response value positions (and the [SEP]
    /// structure tokens), 0.0 everywhere in the prompt.
    pub fn sample(&self, rng: &mut Rng, seq: usize) -> (Vec<i32>, Vec<f32>) {
        let resp_len = self.n_queries * 4;
        let prompt_len = seq - resp_len;
        assert!(prompt_len > self.n_facts * 4 + 8, "seq too short");

        // distinct keys
        let mut keys: Vec<i32> = (KEY_RANGE.0..KEY_RANGE.1).collect();
        rng.shuffle(&mut keys);
        keys.truncate(self.n_facts);
        let values: Vec<i32> = (0..self.n_facts)
            .map(|_| VAL_RANGE.0 + rng.below((VAL_RANGE.1 - VAL_RANGE.0) as u64) as i32)
            .collect();

        // prompt: filler with facts scattered through it
        let mut tokens = self.corpus.sequence(rng, prompt_len);
        for t in tokens.iter_mut() {
            if *t >= KEY_RANGE.0 {
                *t %= KEY_RANGE.0;
            }
        }
        // scatter facts at random non-overlapping offsets
        let slot = prompt_len / self.n_facts;
        for (i, (&k, &v)) in keys.iter().zip(&values).enumerate() {
            let lo = i * slot;
            let hi = (lo + slot - 4).max(lo + 1);
            let pos = rng.range(lo, hi);
            tokens[pos] = TOK_KEY;
            tokens[pos + 1] = k;
            tokens[pos + 2] = TOK_VAL;
            tokens[pos + 3] = v;
        }

        // response: queries over a random subset of facts
        let mut order: Vec<usize> = (0..self.n_facts).collect();
        rng.shuffle(&mut order);
        for &i in order.iter().take(self.n_queries) {
            tokens.push(TOK_QUERY);
            tokens.push(keys[i]);
            tokens.push(TOK_SEP);
            tokens.push(values[i]);
        }
        debug_assert_eq!(tokens.len(), seq);

        // mask: predictions made *from* positions >= prompt_len - 1 are
        // supervised (the response region), everything else is masked.
        let mut mask = vec![0.0f32; seq - 1];
        for i in (prompt_len - 1)..(seq - 1) {
            mask[i] = 1.0;
        }
        (tokens, mask)
    }

    pub fn batch(&self, seed: u64, stream: u64, batch: usize, seq: usize) -> (IntTensor, Tensor) {
        let mut toks = Vec::with_capacity(batch * seq);
        let mut mask = Vec::with_capacity(batch * (seq - 1));
        for b in 0..batch {
            let mut rng = Rng::new(seed ^ stream.wrapping_mul(0x1234_5677) ^ ((b as u64) << 36));
            let (t, m) = self.sample(&mut rng, seq);
            toks.extend(t);
            mask.extend(m);
        }
        (
            IntTensor::from_vec(&[batch, seq], toks).unwrap(),
            Tensor::from_vec(&[batch, seq - 1], mask).unwrap(),
        )
    }

    /// Fraction of supervised positions — the sparse-gradient severity.
    pub fn supervised_fraction(&self, seq: usize) -> f64 {
        (self.n_queries * 4) as f64 / (seq - 1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_layout() {
        let g = SftGen::new(1);
        let mut rng = Rng::new(2);
        let (t, m) = g.sample(&mut rng, 256);
        assert_eq!(t.len(), 256);
        assert_eq!(m.len(), 255);
        // response structure: last 16 tokens are 4 query quadruples
        for qi in 0..4 {
            let base = 240 + qi * 4;
            assert_eq!(t[base], TOK_QUERY);
            assert_eq!(t[base + 2], TOK_SEP);
        }
    }

    #[test]
    fn prompt_is_masked_response_is_not() {
        let g = SftGen::new(3);
        let mut rng = Rng::new(4);
        let (_, m) = g.sample(&mut rng, 256);
        let resp_start = 256 - 16 - 1;
        assert!(m[..resp_start].iter().all(|&x| x == 0.0));
        assert!(m[resp_start..].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn answers_match_planted_facts() {
        let g = SftGen::new(5);
        let mut rng = Rng::new(6);
        let (t, _) = g.sample(&mut rng, 512);
        // build fact table from the prompt
        let mut facts = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < 512 - 16 {
            if t[i] == TOK_KEY {
                facts.insert(t[i + 1], t[i + 3]);
                i += 4;
            } else {
                i += 1;
            }
        }
        // check each response answer
        for qi in 0..4 {
            let base = 512 - 16 + qi * 4;
            let key = t[base + 1];
            let val = t[base + 3];
            assert_eq!(facts[&key], val, "query {qi} answer mismatch");
        }
    }

    #[test]
    fn supervised_fraction_small() {
        let g = SftGen::new(7);
        assert!(g.supervised_fraction(512) < 0.05);
    }

    #[test]
    fn batch_shapes() {
        let g = SftGen::new(9);
        let (t, m) = g.batch(1, 0, 3, 128);
        assert_eq!(t.shape, vec![3, 128]);
        assert_eq!(m.shape, vec![3, 127]);
    }
}
