//! Needle-in-a-haystack generator (paper Fig 7, scaled per DESIGN.md §4).
//!
//! A sequence is filler text (drawn from the corpus generator) with one
//! key-value fact planted at a controllable depth:
//!
//! `... filler ... [KEY] k [VAL] v ... filler ... [QUERY] k [SEP] -> v`
//!
//! The model must emit `v` after `[SEP]`. Training samples randomize
//! depth and length; the Fig-7 evaluation sweeps (context length × depth)
//! and scores exact retrieval, producing the same heatmap the paper draws
//! at 1M scale.

use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;

use super::corpus::{Corpus, CorpusCfg};

/// Special token ids (top of the 512 vocab; base corpus stays below 500).
pub const TOK_KEY: i32 = 511;
pub const TOK_VAL: i32 = 510;
pub const TOK_QUERY: i32 = 509;
pub const TOK_SEP: i32 = 508;

/// keys and values are drawn from disjoint ordinary-token ranges so the
/// model cannot cheat via unigram statistics
pub const KEY_RANGE: (i32, i32) = (400, 450);
pub const VAL_RANGE: (i32, i32) = (450, 500);

#[derive(Clone, Debug)]
pub struct NeedleSample {
    pub tokens: Vec<i32>,
    /// position of the answer token (== value) — the model must predict
    /// `tokens[answer_pos]` from the prefix ending at `answer_pos - 1`
    pub answer_pos: usize,
    pub value: i32,
    /// where the needle was planted, as a fraction of the haystack
    pub depth: f64,
}

pub struct NeedleGen {
    corpus: Corpus,
}

impl NeedleGen {
    pub fn new(seed: u64) -> NeedleGen {
        NeedleGen { corpus: Corpus::new(CorpusCfg::default(), seed) }
    }

    /// One sample of total length `seq` with the needle at `depth` in
    /// [0, 1]. The trailing 4 positions hold `[QUERY] k [SEP] v`.
    pub fn sample(&self, rng: &mut Rng, seq: usize, depth: f64) -> NeedleSample {
        assert!(seq >= 16, "sequence too short for a needle");
        let key = KEY_RANGE.0 + rng.below((KEY_RANGE.1 - KEY_RANGE.0) as u64) as i32;
        let value = VAL_RANGE.0 + rng.below((VAL_RANGE.1 - VAL_RANGE.0) as u64) as i32;

        let haystack_len = seq - 4; // reserve the query suffix
        let mut tokens = self.corpus.sequence(rng, haystack_len);
        // avoid accidental needle-range collisions in the filler
        for t in tokens.iter_mut() {
            if *t >= KEY_RANGE.0 {
                *t %= KEY_RANGE.0;
            }
        }
        // plant [KEY] k [VAL] v at the depth-determined offset
        let max_pos = haystack_len - 4;
        let pos = ((max_pos as f64) * depth).round() as usize;
        tokens[pos] = TOK_KEY;
        tokens[pos + 1] = key;
        tokens[pos + 2] = TOK_VAL;
        tokens[pos + 3] = value;
        // query suffix
        tokens.push(TOK_QUERY);
        tokens.push(key);
        tokens.push(TOK_SEP);
        tokens.push(value);
        NeedleSample { tokens, answer_pos: seq - 1, value, depth }
    }

    /// Training batch: random depths; loss masked to *only* the answer
    /// position (retrieval supervision) plus a light LM weight elsewhere
    /// so representations keep improving.
    pub fn train_batch(
        &self,
        seed: u64,
        stream: u64,
        batch: usize,
        seq: usize,
        lm_weight: f32,
    ) -> (IntTensor, Tensor) {
        let mut toks = Vec::with_capacity(batch * seq);
        let mut mask = vec![lm_weight; batch * (seq - 1)];
        for b in 0..batch {
            let mut rng = Rng::new(seed ^ stream.wrapping_mul(0xABCD_EF01) ^ ((b as u64) << 40));
            let depth = rng.f64();
            let s = self.sample(&mut rng, seq, depth);
            // answer at seq-1 is predicted from position seq-2 -> mask idx seq-2
            mask[b * (seq - 1) + (s.answer_pos - 1)] = 1.0;
            toks.extend(s.tokens);
        }
        (
            IntTensor::from_vec(&[batch, seq], toks).unwrap(),
            Tensor::from_vec(&[batch, seq - 1], mask).unwrap(),
        )
    }

    /// Evaluation grid cell: `n_samples` needles at (seq, depth).
    pub fn eval_samples(
        &self,
        seed: u64,
        seq: usize,
        depth: f64,
        n_samples: usize,
    ) -> Vec<NeedleSample> {
        (0..n_samples)
            .map(|i| {
                let mut rng = Rng::new(seed ^ 0xEEE ^ ((i as u64) << 24) ^ (seq as u64));
                self.sample(&mut rng, seq, depth)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_structure() {
        let g = NeedleGen::new(1);
        let mut rng = Rng::new(2);
        let s = g.sample(&mut rng, 256, 0.5);
        assert_eq!(s.tokens.len(), 256);
        assert_eq!(s.tokens[252], TOK_QUERY);
        assert_eq!(s.tokens[254], TOK_SEP);
        assert_eq!(s.tokens[255], s.value);
        assert_eq!(s.answer_pos, 255);
    }

    #[test]
    fn needle_is_planted_and_consistent() {
        let g = NeedleGen::new(3);
        let mut rng = Rng::new(4);
        let s = g.sample(&mut rng, 128, 0.25);
        let kpos = s.tokens.iter().position(|&t| t == TOK_KEY).unwrap();
        assert_eq!(s.tokens[kpos + 2], TOK_VAL);
        assert_eq!(s.tokens[kpos + 3], s.value);
        // queried key matches planted key
        assert_eq!(s.tokens[kpos + 1], s.tokens[125]);
    }

    #[test]
    fn depth_zero_and_one() {
        let g = NeedleGen::new(5);
        let mut rng = Rng::new(6);
        let s0 = g.sample(&mut rng, 128, 0.0);
        assert_eq!(s0.tokens[0], TOK_KEY);
        let s1 = g.sample(&mut rng, 128, 1.0);
        let kpos = s1.tokens.iter().position(|&t| t == TOK_KEY).unwrap();
        assert_eq!(kpos, 128 - 4 - 4);
    }

    #[test]
    fn filler_never_collides_with_markers() {
        let g = NeedleGen::new(7);
        let mut rng = Rng::new(8);
        let s = g.sample(&mut rng, 512, 0.6);
        let kpos = s.tokens.iter().position(|&t| t == TOK_KEY).unwrap();
        for (i, &t) in s.tokens[..508].iter().enumerate() {
            if !(kpos..kpos + 4).contains(&i) {
                assert!(t < KEY_RANGE.0, "filler token {t} at {i} inside reserved range");
            }
        }
    }

    #[test]
    fn train_batch_mask_targets_answer() {
        let g = NeedleGen::new(9);
        let (toks, mask) = g.train_batch(1, 0, 2, 128, 0.1);
        assert_eq!(toks.shape, vec![2, 128]);
        assert_eq!(mask.shape, vec![2, 127]);
        for b in 0..2 {
            assert_eq!(mask.data[b * 127 + 126], 1.0);
        }
        let tenths = mask.data.iter().filter(|&&x| x == 0.1).count();
        assert_eq!(tenths, 2 * 126);
    }

    #[test]
    fn eval_samples_deterministic() {
        let g = NeedleGen::new(11);
        let a = g.eval_samples(5, 128, 0.5, 3);
        let b = g.eval_samples(5, 128, 0.5, 3);
        assert_eq!(a[0].tokens, b[0].tokens);
        assert_eq!(a.len(), 3);
    }
}
