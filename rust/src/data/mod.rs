//! Data pipeline: synthetic corpora and task generators (DESIGN.md §4
//! documents how each substitutes for the paper's proprietary data).
//!
//! - `corpus`: Zipf-Markov LM stream with long-range replay spans;
//! - `needle`: needle-in-a-haystack retrieval (Fig 7);
//! - `sft`: prompt-masked retrieval SFT (Fig 5b/c).
//!
//! All generators are deterministic functions of (seed, stream id), so
//! every experiment is exactly reproducible and train/val streams are
//! disjoint by construction.

pub mod corpus;
pub mod needle;
pub mod sft;

pub use corpus::{Corpus, CorpusCfg};
pub use needle::{NeedleGen, NeedleSample};
pub use sft::SftGen;

/// Stream-id convention shared by the experiment harnesses: training
/// batches use ids [0, 2^32), validation uses [2^32, ...), so the two
/// never collide for any step count.
pub const VAL_STREAM_BASE: u64 = 1 << 32;
