//! Synthetic Zipf-Markov corpus with long-range replay structure.
//!
//! Substitute for the paper's pre-training corpus (DESIGN.md §4). Three
//! ingredients give it learnable structure at every range:
//!
//! 1. **Zipf unigram prior** — realistic token frequencies;
//! 2. **Markov bigram dynamics** — local structure a small model can
//!    learn quickly (drives the bulk of the LM loss);
//! 3. **replay spans** — with probability `replay_prob` per position the
//!    stream switches to *copying a span emitted earlier in the same
//!    sequence*. Predicting inside a replay span requires attending far
//!    back, so trailing-token loss (paper Fig 3b) genuinely improves with
//!    effective context — this is what separates MoBA/full/window
//!    architectures in our scaled-down setting.

use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct CorpusCfg {
    pub vocab: usize,
    /// number of ordinary (non-special) tokens; ids >= this are reserved
    pub base_vocab: usize,
    pub zipf_exponent: f64,
    /// per-position probability of starting a replay of earlier content
    pub replay_prob: f64,
    pub replay_len: (usize, usize),
    /// markov state count (hidden "topics" that shift the bigram table)
    pub n_states: usize,
}

impl Default for CorpusCfg {
    fn default() -> Self {
        CorpusCfg {
            vocab: 512,
            base_vocab: 500,
            zipf_exponent: 1.1,
            replay_prob: 0.02,
            replay_len: (16, 64),
            n_states: 8,
        }
    }
}

/// Deterministic synthetic corpus generator.
pub struct Corpus {
    cfg: CorpusCfg,
    /// per-state permutation offsets: state s maps token t -> (t + off[s])
    state_offsets: Vec<usize>,
    zipf_weights: Vec<f64>,
}

impl Corpus {
    /// Corpus sized for a model's vocabulary: ordinary tokens stay below
    /// `vocab - 12` (leaving room for the special marker ids), capped at
    /// the default 500. Guards against out-of-range CE targets, which XLA
    /// turns into NaN losses.
    pub fn for_vocab(vocab: usize, seed: u64) -> Corpus {
        let base = CorpusCfg::default();
        let base_vocab = base.base_vocab.min(vocab.saturating_sub(12)).max(2);
        Corpus::new(CorpusCfg { vocab, base_vocab, ..base }, seed)
    }

    pub fn new(cfg: CorpusCfg, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed ^ 0xC0FFEE);
        let zipf_weights: Vec<f64> = (1..=cfg.base_vocab)
            .map(|r| 1.0 / (r as f64).powf(cfg.zipf_exponent))
            .collect();
        let state_offsets = (0..cfg.n_states)
            .map(|_| rng.range(1, cfg.base_vocab))
            .collect();
        Corpus { cfg, state_offsets, zipf_weights }
    }

    /// Generate one sequence of length `len` from a per-sequence RNG.
    pub fn sequence(&self, rng: &mut Rng, len: usize) -> Vec<i32> {
        let cfg = &self.cfg;
        let mut out: Vec<i32> = Vec::with_capacity(len);
        let mut state = rng.range(0, cfg.n_states);
        let mut replay: Option<(usize, usize)> = None; // (src_pos, remaining)

        while out.len() < len {
            // replay continuation
            if let Some((src, rem)) = replay {
                out.push(out[src]);
                replay = if rem > 1 { Some((src + 1, rem - 1)) } else { None };
                continue;
            }
            // maybe start a replay of an earlier span
            if out.len() > cfg.replay_len.1 * 2 && rng.f64() < cfg.replay_prob {
                let max_len = cfg.replay_len.1.min(len - out.len());
                if max_len >= cfg.replay_len.0 {
                    let rlen = rng.range(cfg.replay_len.0, max_len + 1);
                    let src = rng.range(0, out.len() - rlen);
                    replay = Some((src, rlen));
                    continue;
                }
            }
            // occasionally shift topic state
            if rng.f64() < 0.01 {
                state = rng.range(0, cfg.n_states);
            }
            // markov step: previous token + state offset perturbs a zipf draw
            let base = rng.weighted(&self.zipf_weights);
            let tok = match out.last() {
                Some(&prev) if rng.f64() < 0.5 => {
                    // bigram: deterministic successor of prev under the topic
                    ((prev as usize + self.state_offsets[state]) % cfg.base_vocab) as i32
                }
                _ => base as i32,
            };
            out.push(tok);
        }
        out
    }

    /// Generate a `[batch, seq]` token batch plus an all-ones loss mask
    /// `[batch, seq-1]`. `stream_id` selects a deterministic substream, so
    /// train/val splits never overlap (val uses a disjoint id range).
    pub fn batch(&self, seed: u64, stream_id: u64, batch: usize, seq: usize) -> (IntTensor, Tensor) {
        let mut data = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let mut rng = Rng::new(seed ^ (stream_id.wrapping_mul(0x9E37_79B9)) ^ ((b as u64) << 32));
            data.extend(self.sequence(&mut rng, seq));
        }
        let tokens = IntTensor::from_vec(&[batch, seq], data).unwrap();
        let mask = Tensor::ones(&[batch, seq - 1]);
        (tokens, mask)
    }

    pub fn cfg(&self) -> &CorpusCfg {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(CorpusCfg::default(), 42)
    }

    #[test]
    fn deterministic() {
        let c = corpus();
        let (a, _) = c.batch(1, 0, 2, 128);
        let (b, _) = c.batch(1, 0, 2, 128);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn streams_disjoint() {
        let c = corpus();
        let (a, _) = c.batch(1, 0, 1, 128);
        let (b, _) = c.batch(1, 1, 1, 128);
        assert_ne!(a.data, b.data);
    }

    #[test]
    fn tokens_in_base_vocab() {
        let c = corpus();
        let (t, _) = c.batch(7, 3, 2, 512);
        assert!(t.data.iter().all(|&x| x >= 0 && (x as usize) < c.cfg().base_vocab));
    }

    #[test]
    fn replay_spans_exist() {
        // long sequences should contain at least one exact repeat of a
        // 16-token window (the replay mechanism at work)
        let c = corpus();
        let mut rng = Rng::new(9);
        let s = c.sequence(&mut rng, 2048);
        let mut found = false;
        'outer: for i in 0..s.len() - 16 {
            for j in i + 16..s.len() - 16 {
                if s[i..i + 16] == s[j..j + 16] {
                    found = true;
                    break 'outer;
                }
            }
        }
        assert!(found, "no replay span found in 2048 tokens");
    }

    #[test]
    fn mask_shape() {
        let c = corpus();
        let (t, m) = c.batch(1, 0, 3, 64);
        assert_eq!(t.shape, vec![3, 64]);
        assert_eq!(m.shape, vec![3, 63]);
        assert!(m.data.iter().all(|&x| x == 1.0));
    }
}
