//! Metrics: run directories, CSV series, summary statistics and the
//! power-law fitting used by the scaling-law experiments (Fig 3c,
//! Table 3).

pub mod fit;
pub mod writer;

pub use fit::{fit_power_law, PowerLaw};
pub use writer::{atomic_write, CsvWriter, RunDir};

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-quantile (linear interpolation) of an unsorted slice.
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = p.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138).abs() < 1e-3);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
    }
}
