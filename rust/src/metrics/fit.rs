//! Power-law fitting: `y = a * x^b` via least squares in log-log space.
//!
//! Used for the fitted scaling curves (paper Fig 3c) and the per-position
//! loss fits `L(C) = a * C^b` of Table 3, where C is training compute.

/// A fitted `y = a * x^b` with goodness-of-fit.
#[derive(Clone, Copy, Debug)]
pub struct PowerLaw {
    pub a: f64,
    pub b: f64,
    pub r2: f64,
}

impl PowerLaw {
    pub fn eval(&self, x: f64) -> f64 {
        self.a * x.powf(self.b)
    }
}

/// Fit `y = a x^b` to positive samples. Returns None with fewer than two
/// valid points or degenerate x.
pub fn fit_power_law(xs: &[f64], ys: &[f64]) -> Option<PowerLaw> {
    let pts: Vec<(f64, f64)> = xs
        .iter()
        .zip(ys)
        .filter(|(&x, &y)| x > 0.0 && y > 0.0)
        .map(|(&x, &y)| (x.ln(), y.ln()))
        .collect();
    if pts.len() < 2 {
        return None;
    }
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return None;
    }
    let b = (n * sxy - sx * sy) / denom;
    let lna = (sy - b * sx) / n;

    // R^2 in log space
    let ybar = sy / n;
    let ss_tot: f64 = pts.iter().map(|p| (p.1 - ybar).powi(2)).sum();
    let ss_res: f64 = pts.iter().map(|p| (p.1 - (lna + b * p.0)).powi(2)).sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };

    Some(PowerLaw { a: lna.exp(), b, r2 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_power_law_recovered() {
        let xs: Vec<f64> = (1..=6).map(|i| 10f64.powi(i)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.1 * x.powf(-0.085)).collect();
        let f = fit_power_law(&xs, &ys).unwrap();
        assert!((f.a - 3.1).abs() < 1e-9, "a={}", f.a);
        assert!((f.b + 0.085).abs() < 1e-12, "b={}", f.b);
        assert!(f.r2 > 0.999999);
    }

    #[test]
    fn noisy_fit_reasonable() {
        let xs: Vec<f64> = (1..=20).map(|i| (i * i) as f64).collect();
        let mut rng = crate::util::rng::Rng::new(1);
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 2.0 * x.powf(0.5) * (1.0 + 0.01 * rng.normal()))
            .collect();
        let f = fit_power_law(&xs, &ys).unwrap();
        assert!((f.b - 0.5).abs() < 0.02);
        assert!(f.r2 > 0.99);
    }

    #[test]
    fn rejects_degenerate() {
        assert!(fit_power_law(&[1.0], &[2.0]).is_none());
        assert!(fit_power_law(&[1.0, 1.0], &[2.0, 3.0]).is_none());
        assert!(fit_power_law(&[-1.0, 2.0], &[2.0, -3.0]).is_none());
    }

    #[test]
    fn eval_matches() {
        let f = PowerLaw { a: 2.0, b: -0.5, r2: 1.0 };
        assert!((f.eval(4.0) - 1.0).abs() < 1e-12);
    }
}
