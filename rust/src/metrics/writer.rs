//! Run directories and CSV series writers.
//!
//! Every experiment writes into `runs/<experiment>/`: CSV series (loss
//! curves, sweeps) plus a JSON summary, so EXPERIMENTS.md numbers are
//! regenerable from disk.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// A run output directory, `runs/<name>` by default.
pub struct RunDir {
    pub path: PathBuf,
}

impl RunDir {
    pub fn create(name: &str) -> Result<RunDir> {
        let base = std::env::var("MOBA_RUNS").unwrap_or_else(|_| "runs".into());
        let path = Path::new(&base).join(name);
        std::fs::create_dir_all(&path)
            .with_context(|| format!("creating run dir {}", path.display()))?;
        Ok(RunDir { path })
    }

    pub fn csv(&self, name: &str, header: &[&str]) -> Result<CsvWriter> {
        CsvWriter::create(&self.path.join(name), header)
    }

    pub fn write_json(&self, name: &str, value: &Json) -> Result<()> {
        std::fs::write(self.path.join(name), value.to_string())?;
        Ok(())
    }

    pub fn write_text(&self, name: &str, text: &str) -> Result<()> {
        std::fs::write(self.path.join(name), text)?;
        Ok(())
    }
}

pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvWriter> {
        let mut w = BufWriter::new(
            File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w, cols: header.len() })
    }

    pub fn row(&mut self, values: &[f64]) -> Result<()> {
        debug_assert_eq!(values.len(), self.cols);
        let line: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.w, "{}", line.join(","))?;
        Ok(())
    }

    pub fn row_mixed(&mut self, values: &[String]) -> Result<()> {
        debug_assert_eq!(values.len(), self.cols);
        writeln!(self.w, "{}", values.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("moba_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.csv");
        {
            let mut w = CsvWriter::create(&p, &["step", "loss"]).unwrap();
            w.row(&[1.0, 2.5]).unwrap();
            w.row(&[2.0, 2.25]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "step,loss\n1,2.5\n2,2.25\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_dir_env_override() {
        let tmp = std::env::temp_dir().join("moba_runs_test");
        std::env::set_var("MOBA_RUNS", &tmp);
        let rd = RunDir::create("unit").unwrap();
        assert!(rd.path.starts_with(&tmp));
        rd.write_text("note.txt", "hello").unwrap();
        assert_eq!(std::fs::read_to_string(rd.path.join("note.txt")).unwrap(), "hello");
        std::env::remove_var("MOBA_RUNS");
        std::fs::remove_dir_all(&tmp).ok();
    }
}
