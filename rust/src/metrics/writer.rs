//! Run directories and CSV series writers.
//!
//! Every experiment writes into `runs/<experiment>/`: CSV series (loss
//! curves, sweeps) plus a JSON summary, so EXPERIMENTS.md numbers are
//! regenerable from disk.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Write `contents` to `path` atomically: write a sibling temp file,
/// then rename it into place. A crash (or a chaos-killed process)
/// mid-write can never leave a truncated file, and a concurrent reader
/// (CI artifact upload, a dashboard tailing `BENCH_*.json`) sees either
/// the old complete file or the new complete file — nothing in between.
pub fn atomic_write(path: &Path, contents: &str) -> Result<()> {
    // pid-suffixed temp name: two processes racing on the same target
    // each rename a complete file; last writer wins whole
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, contents).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

/// A run output directory, `runs/<name>` by default.
pub struct RunDir {
    pub path: PathBuf,
}

impl RunDir {
    pub fn create(name: &str) -> Result<RunDir> {
        let base = std::env::var("MOBA_RUNS").unwrap_or_else(|_| "runs".into());
        let path = Path::new(&base).join(name);
        std::fs::create_dir_all(&path)
            .with_context(|| format!("creating run dir {}", path.display()))?;
        Ok(RunDir { path })
    }

    pub fn csv(&self, name: &str, header: &[&str]) -> Result<CsvWriter> {
        CsvWriter::create(&self.path.join(name), header)
    }

    pub fn write_json(&self, name: &str, value: &Json) -> Result<()> {
        atomic_write(&self.path.join(name), &value.to_string())
    }

    pub fn write_text(&self, name: &str, text: &str) -> Result<()> {
        atomic_write(&self.path.join(name), text)
    }
}

pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvWriter> {
        let mut w = BufWriter::new(
            File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w, cols: header.len() })
    }

    pub fn row(&mut self, values: &[f64]) -> Result<()> {
        debug_assert_eq!(values.len(), self.cols);
        let line: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.w, "{}", line.join(","))?;
        Ok(())
    }

    pub fn row_mixed(&mut self, values: &[String]) -> Result<()> {
        debug_assert_eq!(values.len(), self.cols);
        writeln!(self.w, "{}", values.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("moba_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.csv");
        {
            let mut w = CsvWriter::create(&p, &["step", "loss"]).unwrap();
            w.row(&[1.0, 2.5]).unwrap();
            w.row(&[2.0, 2.25]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "step,loss\n1,2.5\n2,2.25\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn atomic_write_replaces_whole_file_and_cleans_temp() {
        let dir = std::env::temp_dir().join("moba_atomic_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("out.json");
        atomic_write(&p, "[1,2,3]").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "[1,2,3]");
        atomic_write(&p, "[4]").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "[4]");
        // no temp droppings left next to the target
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .filter(|n| n.contains("tmp"))
            .collect();
        assert!(leftovers.is_empty(), "stray temp files: {leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_dir_env_override() {
        let tmp = std::env::temp_dir().join("moba_runs_test");
        std::env::set_var("MOBA_RUNS", &tmp);
        let rd = RunDir::create("unit").unwrap();
        assert!(rd.path.starts_with(&tmp));
        rd.write_text("note.txt", "hello").unwrap();
        assert_eq!(std::fs::read_to_string(rd.path.join("note.txt")).unwrap(), "hello");
        std::env::remove_var("MOBA_RUNS");
        std::fs::remove_dir_all(&tmp).ok();
    }
}
