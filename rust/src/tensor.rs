//! Host-side tensors: the coordinator's working representation of model
//! state and batches before/after PJRT transfers.
//!
//! Deliberately minimal — f32 and i32 only (the dtypes the AOT graphs
//! use), row-major, shape-checked ops used by the pure-Rust attention
//! reference and the evaluation pipeline.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn scalar(x: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![x] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at3(&self, i: usize, j: usize, k: usize) -> f32 {
        debug_assert_eq!(self.rank(), 3);
        self.data[(i * self.shape[1] + j) * self.shape[2] + k]
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

impl IntTensor {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<IntTensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(IntTensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> IntTensor {
        IntTensor { shape: shape.to_vec(), data: vec![0; shape.iter().product()] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked_construction() {
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn indexing() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.at2(1, 2), 5.0);
        let t3 = Tensor::from_vec(&[2, 2, 2], (0..8).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t3.at3(1, 0, 1), 5.0);
    }

    #[test]
    fn stats() {
        let t = Tensor::from_vec(&[4], vec![3.0, 4.0, 0.0, 0.0]).unwrap();
        assert_eq!(t.l2_norm(), 5.0);
        assert_eq!(t.mean(), 1.75);
        let u = Tensor::from_vec(&[4], vec![3.0, 4.5, 0.0, 0.0]).unwrap();
        assert_eq!(t.max_abs_diff(&u), 0.5);
    }
}
