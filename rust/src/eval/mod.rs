//! Evaluation: position-wise loss aggregation (Fig 3b/5a/Table 3),
//! needle scoring (Fig 7) and the downstream task suite (Table 2).

pub mod losses;
pub mod needle_score;
pub mod suite;

pub use losses::{bucket_means, positionwise_mean, trailing_mean, PositionLosses};
pub use needle_score::score_needles;
pub use suite::{run_suite, SuiteResult};
