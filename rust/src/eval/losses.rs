//! Position-wise LM loss aggregation.
//!
//! The eval artifacts return masked per-position CE losses `[B, S-1]`.
//! This module accumulates them over validation batches and derives the
//! paper's three loss views:
//!
//! - mean LM loss (Fig 3a),
//! - trailing LM loss: mean over the last T positions (Fig 3b, 5c),
//! - position-wise LM loss / position-bucket means (Fig 5a, Table 3).

use anyhow::{bail, Result};

use crate::runtime::Engine;
use crate::tensor::{IntTensor, Tensor};

/// Accumulated per-position loss sums and counts.
#[derive(Clone, Debug)]
pub struct PositionLosses {
    pub sums: Vec<f64>,
    pub counts: Vec<f64>,
}

impl PositionLosses {
    pub fn new(positions: usize) -> PositionLosses {
        PositionLosses { sums: vec![0.0; positions], counts: vec![0.0; positions] }
    }

    /// Fold in one `[B, S-1]` masked loss tensor with its mask.
    pub fn add(&mut self, losses: &Tensor, mask: &Tensor) -> Result<()> {
        if losses.shape != mask.shape || losses.rank() != 2 {
            bail!("loss/mask shape mismatch: {:?} vs {:?}", losses.shape, mask.shape);
        }
        let (b, s) = (losses.shape[0], losses.shape[1]);
        if s != self.sums.len() {
            bail!("position count mismatch: {} vs {}", s, self.sums.len());
        }
        for bi in 0..b {
            for p in 0..s {
                let m = mask.at2(bi, p) as f64;
                if m > 0.0 {
                    self.sums[p] += losses.at2(bi, p) as f64;
                    self.counts[p] += m;
                }
            }
        }
        Ok(())
    }

    /// Mean loss per position (NaN-free: unobserved positions -> 0).
    pub fn per_position(&self) -> Vec<f64> {
        self.sums
            .iter()
            .zip(&self.counts)
            .map(|(&s, &c)| if c > 0.0 { s / c } else { 0.0 })
            .collect()
    }

    /// Overall mean.
    pub fn mean(&self) -> f64 {
        let total: f64 = self.sums.iter().sum();
        let n: f64 = self.counts.iter().sum();
        if n > 0.0 {
            total / n
        } else {
            0.0
        }
    }

    /// Mean over the last `t` positions (trailing LM loss).
    pub fn trailing(&self, t: usize) -> f64 {
        let start = self.sums.len().saturating_sub(t);
        let total: f64 = self.sums[start..].iter().sum();
        let n: f64 = self.counts[start..].iter().sum();
        if n > 0.0 {
            total / n
        } else {
            0.0
        }
    }

    /// Bucketed means: positions grouped into `bucket` wide ranges
    /// (Table 3 uses 2K-token buckets at 32K; we use scaled buckets).
    pub fn buckets(&self, bucket: usize) -> Vec<(usize, usize, f64)> {
        let mut out = Vec::new();
        let mut lo = 0;
        while lo < self.sums.len() {
            let hi = (lo + bucket).min(self.sums.len());
            let total: f64 = self.sums[lo..hi].iter().sum();
            let n: f64 = self.counts[lo..hi].iter().sum();
            out.push((lo, hi, if n > 0.0 { total / n } else { 0.0 }));
            lo = hi;
        }
        out
    }
}

/// Evaluate mean LM loss of `params` over `n_batches` validation batches.
pub fn positionwise_mean(
    engine: &Engine,
    eval_artifact: &str,
    params: &[Tensor],
    mut batches: impl FnMut(u64) -> (IntTensor, Tensor),
    n_batches: u64,
) -> Result<PositionLosses> {
    let art = engine.manifest.get(eval_artifact)?;
    let mut acc = PositionLosses::new(art.seq - 1);
    for i in 0..n_batches {
        let (tokens, mask) = batches(i);
        let losses = engine.eval_losses(eval_artifact, params, &tokens, &mask)?;
        acc.add(&losses, &mask)?;
    }
    Ok(acc)
}

/// Convenience: trailing mean over the last `frac` of the context.
pub fn trailing_mean(acc: &PositionLosses, frac: f64) -> f64 {
    let t = ((acc.sums.len() as f64) * frac).round().max(1.0) as usize;
    acc.trailing(t)
}

/// Convenience: bucket means with `n_buckets` equal ranges.
pub fn bucket_means(acc: &PositionLosses, n_buckets: usize) -> Vec<(usize, usize, f64)> {
    let w = (acc.sums.len() / n_buckets).max(1);
    acc.buckets(w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_means() {
        let mut acc = PositionLosses::new(4);
        let l = Tensor::from_vec(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let m = Tensor::ones(&[1, 4]);
        acc.add(&l, &m).unwrap();
        acc.add(&l, &m).unwrap();
        assert_eq!(acc.per_position(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(acc.mean(), 2.5);
        assert_eq!(acc.trailing(2), 3.5);
    }

    #[test]
    fn respects_mask() {
        let mut acc = PositionLosses::new(3);
        let l = Tensor::from_vec(&[1, 3], vec![5.0, 0.0, 1.0]).unwrap();
        let m = Tensor::from_vec(&[1, 3], vec![1.0, 0.0, 1.0]).unwrap();
        acc.add(&l, &m).unwrap();
        let pp = acc.per_position();
        assert_eq!(pp[1], 0.0); // unobserved
        assert_eq!(acc.mean(), 3.0);
    }

    #[test]
    fn buckets_cover_all_positions() {
        let mut acc = PositionLosses::new(10);
        let l = Tensor::from_vec(&[1, 10], (0..10).map(|x| x as f32).collect()).unwrap();
        let m = Tensor::ones(&[1, 10]);
        acc.add(&l, &m).unwrap();
        let b = acc.buckets(4);
        assert_eq!(b.len(), 3);
        assert_eq!(b[0], (0, 4, 1.5));
        assert_eq!(b[2].0, 8);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut acc = PositionLosses::new(4);
        let l = Tensor::ones(&[1, 3]);
        let m = Tensor::ones(&[1, 3]);
        assert!(acc.add(&l, &m).is_err());
    }
}
