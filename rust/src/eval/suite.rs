//! Downstream evaluation suite — the Table-2 substitute (DESIGN.md §4).
//!
//! The paper compares MoBA vs full checkpoints on public benchmarks and
//! finds parity at matched training. Our tiny models cannot express
//! AGIEval; the *claim under test* is the parity, so the suite measures
//! it on tasks a tiny model can express:
//!
//! - `heldout_ppl`  — perplexity on a disjoint corpus stream (LM quality);
//! - `needle_acc`   — exact retrieval at the trained context length;
//! - `copy_acc`     — verbatim continuation of a repeated span
//!                    (induction/copying circuit);
//! - `multiquery`   — SFT-style multi-fact recall accuracy.

use anyhow::Result;

use crate::data::{needle::NeedleGen, Corpus, VAL_STREAM_BASE};
use crate::eval::losses::positionwise_mean;
use crate::eval::needle_score::score_needles;
use crate::runtime::Engine;
use crate::tensor::{IntTensor, Tensor};

#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub heldout_ppl: f64,
    pub needle_acc: f64,
    pub copy_acc: f64,
    pub multiquery_acc: f64,
}

impl SuiteResult {
    pub fn rows(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("HeldoutPPL", self.heldout_ppl),
            ("NeedleRetrieval", self.needle_acc),
            ("CopySpan", self.copy_acc),
            ("MultiQueryRecall", self.multiquery_acc),
        ]
    }
}

/// Build a copy-task sequence: random span, separator, repeat. Scoring is
/// teacher-forced argmax accuracy over the repeated half.
fn copy_sample(rng: &mut crate::util::rng::Rng, seq: usize) -> (Vec<i32>, usize) {
    let half = (seq - 1) / 2;
    let mut toks = Vec::with_capacity(seq);
    for _ in 0..half {
        toks.push(rng.range(0, 380) as i32);
    }
    toks.push(crate::data::needle::TOK_SEP);
    let prefix: Vec<i32> = toks[..half].to_vec();
    toks.extend_from_slice(&prefix);
    while toks.len() < seq {
        toks.push(0);
    }
    (toks, half + 1) // copy region starts after the separator
}

/// Run the suite against one checkpoint through its eval + logits
/// artifacts (which must share geometry).
pub fn run_suite(
    engine: &Engine,
    eval_artifact: &str,
    logits_artifact: &str,
    params: &[Tensor],
    seed: u64,
    n_eval_batches: u64,
) -> Result<SuiteResult> {
    let eval_art = engine.manifest.get(eval_artifact)?;
    let (batch, seq) = (eval_art.batch, eval_art.seq);

    // --- held-out perplexity ---------------------------------------------
    let corpus = Corpus::for_vocab(eval_art.model.vocab, seed);
    let acc = positionwise_mean(
        engine,
        eval_artifact,
        params,
        |i| corpus.batch(seed, VAL_STREAM_BASE + i, batch, seq),
        n_eval_batches,
    )?;
    let heldout_ppl = acc.mean().exp();

    // --- needle retrieval --------------------------------------------------
    let logits_art = engine.manifest.get(logits_artifact)?;
    let gen = NeedleGen::new(seed);
    let mut needle_samples = Vec::new();
    for &depth in &[0.1, 0.5, 0.9] {
        needle_samples.extend(gen.eval_samples(seed ^ 77, logits_art.seq, depth, 4));
    }
    let needle_acc = score_needles(engine, logits_artifact, params, &needle_samples)?;

    // --- copy span ----------------------------------------------------------
    let vocab = logits_art.model.vocab;
    let mut rng = crate::util::rng::Rng::new(seed ^ 0xC0);
    let mut copy_correct = 0usize;
    let mut copy_total = 0usize;
    for _ in 0..6 {
        let (toks, copy_start) = copy_sample(&mut rng, logits_art.seq);
        let tokens = IntTensor::from_vec(&[1, logits_art.seq], toks.clone())?;
        let logits = engine.logits(logits_artifact, params, &tokens)?;
        // score the first 32 copied positions (teacher-forced)
        let span = 32.min(logits_art.seq - copy_start - 1);
        for p in copy_start..copy_start + span {
            let off = (p - 1) * vocab;
            let row = &logits.data[off..off + vocab];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap();
            if argmax == toks[p] {
                copy_correct += 1;
            }
            copy_total += 1;
        }
    }
    let copy_acc = copy_correct as f64 / copy_total.max(1) as f64;

    // --- multi-query recall ---------------------------------------------
    let sft = crate::data::SftGen::new(seed ^ 0x51);
    let mut mq_correct = 0usize;
    let mut mq_total = 0usize;
    for i in 0..6u64 {
        let mut rng = crate::util::rng::Rng::new(seed ^ 0x51F7 ^ (i << 16));
        let (toks, _) = sft.sample(&mut rng, logits_art.seq);
        let tokens = IntTensor::from_vec(&[1, logits_art.seq], toks.clone())?;
        let logits = engine.logits(logits_artifact, params, &tokens)?;
        // answers sit at positions seq-1-4q for q in 0..n_queries
        for q in 0..sft.n_queries {
            let pos = logits_art.seq - 1 - q * 4; // value positions from the end
            let off = (pos - 1) * vocab;
            let row = &logits.data[off..off + vocab];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap();
            if argmax == toks[pos] {
                mq_correct += 1;
            }
            mq_total += 1;
        }
    }
    let multiquery_acc = mq_correct as f64 / mq_total.max(1) as f64;

    Ok(SuiteResult { heldout_ppl, needle_acc, copy_acc, multiquery_acc })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copy_sample_structure() {
        let mut rng = crate::util::rng::Rng::new(1);
        let (toks, start) = copy_sample(&mut rng, 129);
        assert_eq!(toks.len(), 129);
        assert_eq!(toks[start - 1], crate::data::needle::TOK_SEP);
        let half = 64;
        assert_eq!(&toks[..half], &toks[start..start + half]);
    }
}
