//! Needle-in-a-haystack scoring (paper Fig 7).
//!
//! Runs a `logits` artifact over generated needle samples and checks
//! whether the model's argmax at the query position is the planted
//! value. One call scores one (context length, depth) heatmap cell.

use anyhow::{bail, Result};

use crate::data::needle::NeedleSample;
use crate::runtime::Engine;
use crate::tensor::IntTensor;

/// Accuracy of exact retrieval over `samples` (all of one seq length).
pub fn score_needles(
    engine: &Engine,
    logits_artifact: &str,
    params: &[crate::tensor::Tensor],
    samples: &[NeedleSample],
) -> Result<f64> {
    if samples.is_empty() {
        bail!("no needle samples");
    }
    let art = engine.manifest.get(logits_artifact)?;
    let seq = art.seq;
    let vocab = art.model.vocab;
    let mut correct = 0usize;
    for s in samples {
        if s.tokens.len() != seq {
            bail!("sample length {} != artifact seq {}", s.tokens.len(), seq);
        }
        let tokens = IntTensor::from_vec(&[1, seq], s.tokens.clone())?;
        let logits = engine.logits(logits_artifact, params, &tokens)?; // [1, S, V]
        // predict tokens[answer_pos] from logits at answer_pos - 1
        let off = (s.answer_pos - 1) * vocab;
        let row = &logits.data[off..off + vocab];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        if argmax == s.value {
            correct += 1;
        }
    }
    Ok(correct as f64 / samples.len() as f64)
}
