//! # MoBA: Mixture of Block Attention — reproduction library
//!
//! A three-layer reproduction of *MoBA: Mixture of Block Attention for
//! Long-Context LLMs* (Lu et al., 2025):
//!
//! - **L1** (build-time Python): Pallas MoBA / flash kernels, lowered AOT;
//! - **L2** (build-time Python): transformer train/eval graphs embedding
//!   the kernels, lowered to HLO text in `artifacts/`;
//! - **L3** (this crate): the coordinator — config, data pipeline,
//!   Algorithm-1 router, the pluggable attention-backend stack with its
//!   incremental KV/block-pool caches, the continuous-batching serving
//!   engine, training loop, cost-model simulator and every experiment
//!   harness of the paper.
//!
//! Attention is invoked everywhere through `sparse::AttentionBackend`
//! (see `sparse/README.md`); the PJRT runtime and the harnesses that
//! drive AOT artifacts sit behind the `xla` feature so a plain CPU box
//! builds and tests the full pure-Rust stack.
//!
//! See DESIGN.md for the full system inventory and experiment index.

// Index-loop style over flat tensor offsets is the local idiom: the Rust
// kernels must stay bit-identical with the Python oracles, and mirroring
// their loop structure is part of how that is audited.
#![allow(clippy::needless_range_loop)]
#![allow(unknown_lints)]
#![allow(clippy::manual_div_ceil)]

pub mod attn_sim;
pub mod config;
pub mod coordinator;
pub mod data;
#[cfg(feature = "xla")]
pub mod eval;
pub mod experiments;
pub mod metrics;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod tensor;
pub mod train;
pub mod util;
