//! # MoBA: Mixture of Block Attention — reproduction library
//!
//! A three-layer reproduction of *MoBA: Mixture of Block Attention for
//! Long-Context LLMs* (Lu et al., 2025):
//!
//! - **L1** (build-time Python): Pallas MoBA / flash kernels, lowered AOT;
//! - **L2** (build-time Python): transformer train/eval graphs embedding
//!   the kernels, lowered to HLO text in `artifacts/`;
//! - **L3** (this crate): the coordinator — config, data pipeline,
//!   Algorithm-1 router, training loop, serving engine, cost-model
//!   simulator and every experiment harness of the paper.
//!
//! See DESIGN.md for the full system inventory and experiment index.

pub mod attn_sim;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod metrics;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod tensor;
pub mod train;
pub mod util;
