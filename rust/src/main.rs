//! `repro` — the MoBA reproduction CLI (L3 leader entrypoint).
//!
//! ```text
//! repro info                         list artifacts + platform
//! repro table1                       print the scaled Table 1
//! repro quickstart [--steps N]       tiny end-to-end train/eval smoke
//! repro train --artifact A --steps N generic training run
//! repro serve [--requests N]         serving demo (MoBA prefill/full decode)
//! repro exp scaling [--long] [--steps N] [--sizes s0,s1,...]   Fig 3a/3b
//! repro exp granularity [--steps N]                            Fig 4
//! repro exp hybrid [--steps N]                                 Fig 5a
//! repro exp sft [--pretrain-steps N] [--sft-steps N]           Fig 5b/5c
//! repro exp needle [--full] [--stage-steps a,b,c]              Fig 6/7
//! repro exp table2 [--steps N]                                 Table 2
//! repro exp fits                                               Fig 3c + Table 3
//! repro exp efficiency [--measure-max N]                       Fig 2a/2b
//! repro exp all [--steps N]          every experiment at smoke scale
//! ```

use anyhow::{bail, Result};

use moba::config::{table1, TrainConfig};
use moba::coordinator::StageSchedule;
use moba::data::Corpus;
use moba::experiments as exp;
use moba::runtime::{artifacts_dir, Engine};
use moba::serve::ServeEngine;
use moba::train::{LrSchedule, Trainer};
use moba::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["long", "full", "quiet", "help"])?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "help" => {
            print!("{}", HELP);
            Ok(())
        }
        "info" => info(),
        "kernel-report" => {
            print!("{}", moba::attn_sim::tpu_estimate::report());
            Ok(())
        }
        "table1" => {
            let engine = Engine::new(&artifacts_dir())?;
            print!("{}", table1(&engine.manifest)?);
            Ok(())
        }
        "quickstart" => quickstart(&args),
        "train" => train_cmd(&args),
        "serve" => serve_cmd(&args),
        "exp" => exp_cmd(&args),
        other => bail!("unknown command '{other}' (try `repro help`)"),
    }
}

const HELP: &str = "\
repro — MoBA (Mixture of Block Attention) reproduction driver

commands:
  info | table1 | quickstart | train | serve | exp <name>
experiments (exp): scaling [--long], granularity, hybrid, sft, needle
  [--full], table2, fits, efficiency, all
common options: --steps N  --seed N  --sizes s0,s1  --artifact NAME
";

fn info() -> Result<()> {
    let engine = Engine::new(&artifacts_dir())?;
    println!("platform: {}", engine.platform());
    println!("artifacts ({}):", engine.manifest.artifacts.len());
    for a in engine.manifest.artifacts.values() {
        println!(
            "  {:<28} {:<12} {:<12} batch={} seq={} params={}",
            a.name, a.group, a.kind, a.batch, a.seq, a.model.param_count
        );
    }
    Ok(())
}

fn quickstart(args: &Args) -> Result<()> {
    let engine = Engine::new(&artifacts_dir())?;
    let steps = args.get_u64("steps", 30)?;
    println!("platform: {}", engine.platform());
    let art = engine.manifest.get("quickstart_train")?;
    let cfg = TrainConfig {
        steps,
        batch: art.batch,
        seq: art.seq,
        seed: args.get_u64("seed", 42)?,
        ..Default::default()
    };
    let corpus = Corpus::for_vocab(art.model.vocab, cfg.seed);
    let lr = LrSchedule::new(cfg.base_lr, steps, cfg.warmup_frac, cfg.min_lr_frac);
    let mut trainer = Trainer::new(&engine, StageSchedule::single("quickstart_train", steps), lr, cfg.seed)?;
    let seed = cfg.seed;
    let (batch, seq) = (cfg.batch, cfg.seq);
    let summary = trainer.run(
        |step| corpus.batch(seed, step, batch, seq),
        |info| {
            if info.step % 5 == 0 {
                println!("step {:>4}  loss {:.4}  lr {:.2e}", info.step, info.loss, info.lr);
            }
        },
    )?;
    println!(
        "trained {} steps in {:.1}s — loss {:.4} -> {:.4}",
        summary.steps,
        summary.total_secs,
        summary.losses.first().unwrap(),
        summary.final_loss
    );
    Ok(())
}

fn train_cmd(args: &Args) -> Result<()> {
    let engine = Engine::new(&artifacts_dir())?;
    let artifact = args
        .get("artifact")
        .ok_or_else(|| anyhow::anyhow!("--artifact NAME required"))?
        .to_string();
    let art = engine.manifest.get(&artifact)?;
    let mut cfg = TrainConfig { batch: art.batch, seq: art.seq, ..Default::default() };
    cfg.apply_cli(args)?;
    let corpus = Corpus::for_vocab(art.model.vocab, cfg.seed);
    let lr = LrSchedule::new(cfg.base_lr, cfg.steps, cfg.warmup_frac, cfg.min_lr_frac);
    let mut trainer = Trainer::new(&engine, StageSchedule::single(&artifact, cfg.steps), lr, cfg.seed)?;
    let seed = cfg.seed;
    let (batch, seq) = (cfg.batch, cfg.seq);
    let log_every = cfg.log_every;
    let summary = trainer.run(
        |step| corpus.batch(seed, step, batch, seq),
        |info| {
            if info.step % log_every == 0 {
                println!("step {:>5}  loss {:.4}  ({:.2}s)", info.step, info.loss, info.step_secs);
            }
        },
    )?;
    println!("final loss {:.4} ({} steps, {:.1}s)", summary.final_loss, summary.steps, summary.total_secs);
    if let Some(out) = args.get("save") {
        moba::runtime::checkpoint::save(&trainer.state, std::path::Path::new(out))?;
        println!("checkpoint -> {out}");
    }
    Ok(())
}

fn serve_cmd(args: &Args) -> Result<()> {
    let engine = Engine::new(&artifacts_dir())?;
    let n_requests = args.get_usize("requests", 4)?;
    // quick demo: a lightly trained needle model serving retrieval prompts
    let steps = args.get_u64("steps", 60)?;
    println!("training a small model for the demo ({steps} steps)...");
    let gen = moba::data::NeedleGen::new(7);
    let lr = LrSchedule::new(2e-3, steps, 0.05, 0.1);
    let mut trainer = Trainer::new(&engine, StageSchedule::single("needle_s0_train", steps), lr, 7)?;
    trainer.run(
        |step| gen.train_batch(7, step, 1, 512, 0.1),
        |info| {
            if info.step % 20 == 0 {
                println!("  step {:>4} loss {:.4}", info.step, info.loss);
            }
        },
    )?;
    let serve = ServeEngine::new(
        &engine,
        trainer.state.params.clone(),
        "needle_s0_logits",
        "needle_s0_full_logits",
    )?;
    println!("serving {n_requests} retrieval prompts (MoBA prefill, full decode):");
    let mut correct = 0;
    for i in 0..n_requests {
        let mut rng = moba::util::rng::Rng::new(1000 + i as u64);
        let sample = gen.eval_samples(55 + i as u64, 512, rng.f64(), 1).remove(0);
        let prompt = &sample.tokens[..sample.answer_pos];
        let (out, stats) = serve.generate(prompt, 1)?;
        let ok = out[0] == sample.value;
        correct += ok as usize;
        println!(
            "  req {i}: answer={} expect={} {}  prefill {:.0}ms decode {:.0}ms/tok",
            out[0],
            sample.value,
            if ok { "OK" } else { "MISS" },
            stats.prefill_secs * 1e3,
            if stats.decode_steps > 0 { stats.decode_secs * 1e3 / stats.decode_steps as f64 } else { 0.0 },
        );
    }
    println!("retrieval: {correct}/{n_requests}");
    Ok(())
}

fn exp_cmd(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("exp needs a name (try `repro help`)"))?;
    let needs_engine = !matches!(which, "fits" | "efficiency" | "gate-ablation");
    let engine = if needs_engine { Some(Engine::new(&artifacts_dir())?) } else { None };
    let run_one = |name: &str, engine: Option<&Engine>| -> Result<()> {
        match name {
            "scaling" => {
                let mut a = exp::scaling::ScalingArgs::default();
                a.long = args.flag("long");
                a.steps = args.get_u64("steps", if a.long { 80 } else { 120 })?;
                a.seed = args.get_u64("seed", a.seed)?;
                a.sizes = args.get_list("sizes", &["s0", "s1", "s2", "s3", "s4"]);
                exp::scaling::run(engine.unwrap(), &a)
            }
            "granularity" => {
                let mut a = exp::granularity::GranularityArgs::default();
                a.steps = args.get_u64("steps", a.steps)?;
                a.seed = args.get_u64("seed", a.seed)?;
                exp::granularity::run(engine.unwrap(), &a)
            }
            "hybrid" => {
                let mut a = exp::hybrid::HybridArgs::default();
                a.steps = args.get_u64("steps", a.steps)?;
                a.seed = args.get_u64("seed", a.seed)?;
                exp::hybrid::run(engine.unwrap(), &a)
            }
            "sft" => {
                let mut a = exp::sft::SftArgs::default();
                a.pretrain_steps = args.get_u64("pretrain-steps", a.pretrain_steps)?;
                a.sft_steps = args.get_u64("sft-steps", a.sft_steps)?;
                a.seed = args.get_u64("seed", a.seed)?;
                exp::sft::run(engine.unwrap(), &a)
            }
            "needle" => {
                let mut a = exp::needle::NeedleArgs::default();
                a.full = args.flag("full");
                a.seed = args.get_u64("seed", a.seed)?;
                a.lm_weight = args.get_f64("lm-weight", a.lm_weight as f64)? as f32;
                if let Some(ss) = args.get("stage-steps") {
                    a.stage_steps = ss
                        .split(',')
                        .map(|x| x.trim().parse::<u64>())
                        .collect::<std::result::Result<_, _>>()?;
                }
                exp::needle::run(engine.unwrap(), &a)
            }
            "table2" => {
                let mut a = exp::table2::Table2Args::default();
                a.steps = args.get_u64("steps", a.steps)?;
                a.seed = args.get_u64("seed", a.seed)?;
                exp::table2::run(engine.unwrap(), &a)
            }
            "fits" => exp::fits::run(),
            "gate-ablation" => {
                let mut a = exp::gate_ablation::GateAblationArgs::default();
                a.trials = args.get_usize("trials", a.trials)?;
                a.seed = args.get_u64("seed", a.seed)?;
                exp::gate_ablation::run(&a)
            }
            "efficiency" => {
                let mut a = exp::efficiency::EfficiencyArgs::default();
                a.measure_max = args.get_usize("measure-max", a.measure_max)?;
                exp::efficiency::run(&a)
            }
            other => bail!("unknown experiment '{other}'"),
        }
    };
    if which == "all" {
        // smoke-scale sweep of every harness, in dependency order
        let engine = Engine::new(&artifacts_dir())?;
        exp::efficiency::run(&exp::efficiency::EfficiencyArgs {
            measure_max: 1024,
            ..Default::default()
        })?;
        let steps = args.get_u64("steps", 25)?;
        exp::scaling::run(&engine, &exp::scaling::ScalingArgs { steps, ..Default::default() })?;
        exp::scaling::run(
            &engine,
            &exp::scaling::ScalingArgs { steps: steps / 2 + 1, long: true, ..Default::default() },
        )?;
        exp::fits::run()?;
        exp::granularity::run(&engine, &exp::granularity::GranularityArgs { steps, ..Default::default() })?;
        exp::hybrid::run(&engine, &exp::hybrid::HybridArgs { steps, ..Default::default() })?;
        exp::sft::run(
            &engine,
            &exp::sft::SftArgs { pretrain_steps: steps, sft_steps: steps / 2 + 1, ..Default::default() },
        )?;
        exp::needle::run(
            &engine,
            &exp::needle::NeedleArgs { stage_steps: vec![steps, steps / 2 + 1, steps / 4 + 1], ..Default::default() },
        )?;
        exp::table2::run(&engine, &exp::table2::Table2Args { steps, ..Default::default() })?;
        exp::gate_ablation::run(&exp::gate_ablation::GateAblationArgs::default())?;
        return Ok(());
    }
    run_one(which, engine.as_ref())
}
