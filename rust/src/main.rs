//! `repro` — the MoBA reproduction CLI (L3 leader entrypoint).
//!
//! ```text
//! repro info                         list artifacts + platform      [xla]
//! repro table1                       print the scaled Table 1       [xla]
//! repro quickstart [--steps N]       tiny end-to-end train/eval     [xla]
//! repro train --artifact A --steps N generic training run           [xla]
//! repro serve [--requests N] [--backend B]
//!     continuous-batching serving demo over the cached-decode stack
//! repro serve-artifact [--requests N]
//!     artifact serving demo (MoBA prefill/full decode)              [xla]
//! repro exp efficiency | fits | gate-ablation                       pure
//! repro exp scaling | granularity | hybrid | sft | needle | table2  [xla]
//! repro exp all [--steps N]          every available experiment
//! ```
//!
//! Commands marked `[xla]` drive AOT artifacts through PJRT and require
//! building with `--features xla`; everything else runs on the pure-Rust
//! attention-backend stack.

// the Args-then-assign-fields pattern is the local experiment-config idiom
#![allow(clippy::field_reassign_with_default)]

use anyhow::{bail, Result};

use moba::experiments as exp;
use moba::serve::{run_demo, DemoCfg};
use moba::sparse::BackendKind;
use moba::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["long", "full", "quiet", "help", "no-steal", "no-pin"])?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "help" => {
            print!("{}", HELP);
            Ok(())
        }
        "info" => engine_cmds::info(),
        "kernel-report" => {
            print!("{}", moba::attn_sim::tpu_estimate::report());
            Ok(())
        }
        "table1" => engine_cmds::table1(),
        "quickstart" => engine_cmds::quickstart(&args),
        "train" => engine_cmds::train_cmd(&args),
        "serve" => serve_cmd(&args),
        "serve-artifact" => engine_cmds::serve_artifact_cmd(&args),
        "exp" => exp_cmd(&args),
        other => bail!("unknown command '{other}' (try `repro help`)"),
    }
}

const HELP: &str = "\
repro — MoBA (Mixture of Block Attention) reproduction driver

commands:
  info | table1 | quickstart | train | serve | serve-artifact | exp <name>
experiments (exp): efficiency, fits, gate-ablation (pure Rust);
  scaling [--long], granularity, hybrid, sft, needle [--full], table2
  (need --features xla + artifacts); all
serve options: --requests N --max-batch M --prompt-len P --max-new K
  --backend full|moba|cached-full|cached-sparse|fused|paged --block B --topk K
  --layers L0,L1,... (per-layer attention flavors, each `moba` or `full`:
    the model grows one attention layer per entry and every session one
    backend per layer, with layer-summed pool accounting; empty = one
    layer of --backend's flavor; also settable via MOBA_LAYERS, e.g.
    MOBA_LAYERS=moba,moba,full,moba)
  --workers W (kernel threads, 0 = all cores)
  --decode-workers S (scheduler decode shards, 0 = all cores)
  --runtime persistent|tick (persistent pinned thread-per-core decode
    workers with bounded channels + work stealing, vs the legacy per-tick
    scoped-thread loop; served tokens are bitwise identical)
  --no-steal (keep persistent workers on their own shard; default steals;
    MOBA_STEAL=0 also disables)
  --no-pin (skip core pinning of persistent workers; MOBA_PIN=0 too)
  --shared-prefix L (L-token system prompt forked per request; needs paged)
  --pool-blocks N (paged pool capacity in blocks, 0 = unbounded; a bounded
    pool oversubscribes: LRU eviction + re-prefill resume, same tokens)
  --swap-blocks N (host swap-tier capacity in pool blocks, 0 = off:
    evictions snapshot victims byte-exact to host memory and resumes
    restore them instead of re-prefilling — same tokens, cheaper resume;
    also settable via MOBA_SWAP_BLOCKS)
  --chaos-seed N (seeded fault injection into persistent decode workers —
    panics, stalls, alloc failures; the supervisor re-homes the dead
    shard's sessions and served tokens stay bitwise identical; also
    settable via MOBA_CHAOS_SEED)
  --barrier-deadline S (seconds before a silent worker is declared dead
    and recovered; 0/unset waits forever, chaos runs default to 5s)
common options: --steps N  --seed N  --sizes s0,s1  --artifact NAME
";

/// Continuous-batching serving demo on the pure-Rust stack (shared
/// driver: `serve::demo`).
fn serve_cmd(args: &Args) -> Result<()> {
    let d = DemoCfg::default();
    // strict env validation: a typo'd MOBA_WORKERS / MOBA_STEAL /
    // MOBA_PIN / MOBA_CHAOS_SEED / MOBA_SWAP_BLOCKS / MOBA_LAYERS fails
    // loudly here with the name and offending value instead of being
    // silently coerced to a default (the library-level readers stay
    // lenient)
    let env_workers = moba::sparse::workers_from_env().map_err(|e| anyhow::anyhow!(e))?;
    let env_layers = moba::serve::layers_from_env_strict().map_err(|e| anyhow::anyhow!(e))?;
    let env_steal = moba::serve::runtime::steal_from_env_strict().map_err(|e| anyhow::anyhow!(e))?;
    let env_pin = moba::serve::runtime::pin_from_env_strict().map_err(|e| anyhow::anyhow!(e))?;
    let env_chaos = moba::serve::chaos::seed_from_env_strict().map_err(|e| anyhow::anyhow!(e))?;
    let env_swap =
        moba::serve::scheduler::swap_blocks_from_env_strict().map_err(|e| anyhow::anyhow!(e))?;
    // `--workers 0` / `--decode-workers 0` mean "all available cores"
    let resolve = move |n: usize| {
        if n == 0 {
            env_workers.unwrap_or_else(moba::sparse::default_workers)
        } else {
            n
        }
    };
    let cfg = DemoCfg {
        requests: args.get_usize("requests", d.requests)?,
        max_in_flight: args.get_usize("max-batch", d.max_in_flight)?,
        prompt_len: args.get_usize("prompt-len", d.prompt_len)?,
        max_new: args.get_usize("max-new", d.max_new)?,
        block_size: args.get_usize("block", d.block_size)?,
        topk: args.get_usize("topk", d.topk)?,
        backend: BackendKind::parse(args.get_str("backend", d.backend.label()))?,
        layers: match args.get("layers") {
            Some(v) => moba::serve::parse_layers("--layers", Some(v.to_string()))
                .map_err(|e| anyhow::anyhow!(e))?
                .unwrap_or_default(),
            None => env_layers.unwrap_or_default(), // strictly parsed MOBA_LAYERS
        },
        workers: resolve(args.get_usize("workers", d.workers)?),
        decode_workers: resolve(args.get_usize("decode-workers", d.decode_workers)?),
        runtime: moba::serve::RuntimeKind::parse(args.get_str("runtime", d.runtime.label()))?,
        steal: if args.flag("no-steal") { false } else { env_steal.unwrap_or(true) },
        pin: if args.flag("no-pin") { false } else { env_pin.unwrap_or(true) },
        shared_prefix: args.get_usize("shared-prefix", d.shared_prefix)?,
        pool_blocks: args.get_usize("pool-blocks", d.pool_blocks)?,
        swap_blocks: match args.get("swap-blocks") {
            Some(_) => args.get_usize("swap-blocks", 0)?,
            None => env_swap.unwrap_or(0), // strictly parsed MOBA_SWAP_BLOCKS
        },
        seed: args.get_u64("seed", d.seed)?,
        chaos_seed: match args.get("chaos-seed") {
            Some(_) => Some(args.get_u64("chaos-seed", 0)?),
            None => env_chaos, // strictly parsed MOBA_CHAOS_SEED, if set
        },
        barrier_deadline_secs: {
            let s = args.get_f64("barrier-deadline", 0.0)?;
            if s > 0.0 {
                Some(s)
            } else {
                d.barrier_deadline_secs
            }
        },
    };
    run_demo(&cfg)
}

fn exp_cmd(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("exp needs a name (try `repro help`)"))?;
    match which {
        "fits" => exp::fits::run(),
        "gate-ablation" => {
            let mut a = exp::gate_ablation::GateAblationArgs::default();
            a.trials = args.get_usize("trials", a.trials)?;
            a.seed = args.get_u64("seed", a.seed)?;
            exp::gate_ablation::run(&a)
        }
        "efficiency" => {
            let mut a = exp::efficiency::EfficiencyArgs::default();
            a.measure_max = args.get_usize("measure-max", a.measure_max)?;
            exp::efficiency::run(&a)
        }
        "all" => {
            exp::efficiency::run(&exp::efficiency::EfficiencyArgs {
                measure_max: 1024,
                ..Default::default()
            })?;
            exp::gate_ablation::run(&exp::gate_ablation::GateAblationArgs::default())?;
            engine_cmds::exp_all_engine(args)
        }
        other => engine_cmds::exp_engine(other, args),
    }
}

// ---------------------------------------------------------------------------
// artifact-driven commands: real implementations with the xla feature,
// clear build-time guidance without it
// ---------------------------------------------------------------------------

#[cfg(feature = "xla")]
mod engine_cmds {
    use anyhow::{bail, Result};

    use moba::config::{table1 as render_table1, TrainConfig};
    use moba::coordinator::StageSchedule;
    use moba::data::Corpus;
    use moba::experiments as exp;
    use moba::runtime::{artifacts_dir, Engine};
    use moba::serve::ArtifactServeEngine;
    use moba::train::{LrSchedule, Trainer};
    use moba::util::cli::Args;

    pub fn info() -> Result<()> {
        let engine = Engine::new(&artifacts_dir())?;
        println!("platform: {}", engine.platform());
        println!("artifacts ({}):", engine.manifest.artifacts.len());
        for a in engine.manifest.artifacts.values() {
            println!(
                "  {:<28} {:<12} {:<12} batch={} seq={} params={}",
                a.name, a.group, a.kind, a.batch, a.seq, a.model.param_count
            );
        }
        Ok(())
    }

    pub fn table1() -> Result<()> {
        let engine = Engine::new(&artifacts_dir())?;
        print!("{}", render_table1(&engine.manifest)?);
        Ok(())
    }

    pub fn quickstart(args: &Args) -> Result<()> {
        let engine = Engine::new(&artifacts_dir())?;
        let steps = args.get_u64("steps", 30)?;
        println!("platform: {}", engine.platform());
        let art = engine.manifest.get("quickstart_train")?;
        let cfg = TrainConfig {
            steps,
            batch: art.batch,
            seq: art.seq,
            seed: args.get_u64("seed", 42)?,
            ..Default::default()
        };
        let corpus = Corpus::for_vocab(art.model.vocab, cfg.seed);
        let lr = LrSchedule::new(cfg.base_lr, steps, cfg.warmup_frac, cfg.min_lr_frac);
        let mut trainer =
            Trainer::new(&engine, StageSchedule::single("quickstart_train", steps), lr, cfg.seed)?;
        let seed = cfg.seed;
        let (batch, seq) = (cfg.batch, cfg.seq);
        let summary = trainer.run(
            |step| corpus.batch(seed, step, batch, seq),
            |info| {
                if info.step % 5 == 0 {
                    println!("step {:>4}  loss {:.4}  lr {:.2e}", info.step, info.loss, info.lr);
                }
            },
        )?;
        println!(
            "trained {} steps in {:.1}s — loss {:.4} -> {:.4}",
            summary.steps,
            summary.total_secs,
            summary.losses.first().unwrap(),
            summary.final_loss
        );
        Ok(())
    }

    pub fn train_cmd(args: &Args) -> Result<()> {
        let engine = Engine::new(&artifacts_dir())?;
        let artifact = args
            .get("artifact")
            .ok_or_else(|| anyhow::anyhow!("--artifact NAME required"))?
            .to_string();
        let art = engine.manifest.get(&artifact)?;
        let mut cfg = TrainConfig { batch: art.batch, seq: art.seq, ..Default::default() };
        cfg.apply_cli(args)?;
        let corpus = Corpus::for_vocab(art.model.vocab, cfg.seed);
        let lr = LrSchedule::new(cfg.base_lr, cfg.steps, cfg.warmup_frac, cfg.min_lr_frac);
        let mut trainer =
            Trainer::new(&engine, StageSchedule::single(&artifact, cfg.steps), lr, cfg.seed)?;
        let seed = cfg.seed;
        let (batch, seq) = (cfg.batch, cfg.seq);
        let log_every = cfg.log_every;
        let summary = trainer.run(
            |step| corpus.batch(seed, step, batch, seq),
            |info| {
                if info.step % log_every == 0 {
                    println!(
                        "step {:>5}  loss {:.4}  ({:.2}s)",
                        info.step, info.loss, info.step_secs
                    );
                }
            },
        )?;
        println!(
            "final loss {:.4} ({} steps, {:.1}s)",
            summary.final_loss, summary.steps, summary.total_secs
        );
        if let Some(out) = args.get("save") {
            moba::runtime::checkpoint::save(&trainer.state, std::path::Path::new(out))?;
            println!("checkpoint -> {out}");
        }
        Ok(())
    }

    pub fn serve_artifact_cmd(args: &Args) -> Result<()> {
        let engine = Engine::new(&artifacts_dir())?;
        let n_requests = args.get_usize("requests", 4)?;
        // quick demo: a lightly trained needle model serving retrieval prompts
        let steps = args.get_u64("steps", 60)?;
        println!("training a small model for the demo ({steps} steps)...");
        let gen = moba::data::NeedleGen::new(7);
        let lr = LrSchedule::new(2e-3, steps, 0.05, 0.1);
        let mut trainer =
            Trainer::new(&engine, StageSchedule::single("needle_s0_train", steps), lr, 7)?;
        trainer.run(
            |step| gen.train_batch(7, step, 1, 512, 0.1),
            |info| {
                if info.step % 20 == 0 {
                    println!("  step {:>4} loss {:.4}", info.step, info.loss);
                }
            },
        )?;
        let serve = ArtifactServeEngine::new(
            &engine,
            trainer.state.params.clone(),
            "needle_s0_logits",
            "needle_s0_full_logits",
        )?;
        println!("serving {n_requests} retrieval prompts (MoBA prefill, full decode):");
        let mut correct = 0;
        for i in 0..n_requests {
            let mut rng = moba::util::rng::Rng::new(1000 + i as u64);
            let sample = gen.eval_samples(55 + i as u64, 512, rng.f64(), 1).remove(0);
            let prompt = &sample.tokens[..sample.answer_pos];
            let (out, stats) = serve.generate(prompt, 1)?;
            let ok = out[0] == sample.value;
            correct += ok as usize;
            println!(
                "  req {i}: answer={} expect={} {}  prefill {:.0}ms decode {:.0}ms/tok",
                out[0],
                sample.value,
                if ok { "OK" } else { "MISS" },
                stats.prefill_secs * 1e3,
                if stats.decode_steps > 0 {
                    stats.decode_secs * 1e3 / stats.decode_steps as f64
                } else {
                    0.0
                },
            );
        }
        println!("retrieval: {correct}/{n_requests}");
        Ok(())
    }

    pub fn exp_engine(which: &str, args: &Args) -> Result<()> {
        let engine = Engine::new(&artifacts_dir())?;
        match which {
            "scaling" => {
                let mut a = exp::scaling::ScalingArgs::default();
                a.long = args.flag("long");
                a.steps = args.get_u64("steps", if a.long { 80 } else { 120 })?;
                a.seed = args.get_u64("seed", a.seed)?;
                a.sizes = args.get_list("sizes", &["s0", "s1", "s2", "s3", "s4"]);
                exp::scaling::run(&engine, &a)
            }
            "granularity" => {
                let mut a = exp::granularity::GranularityArgs::default();
                a.steps = args.get_u64("steps", a.steps)?;
                a.seed = args.get_u64("seed", a.seed)?;
                exp::granularity::run(&engine, &a)
            }
            "hybrid" => {
                let mut a = exp::hybrid::HybridArgs::default();
                a.steps = args.get_u64("steps", a.steps)?;
                a.seed = args.get_u64("seed", a.seed)?;
                exp::hybrid::run(&engine, &a)
            }
            "sft" => {
                let mut a = exp::sft::SftArgs::default();
                a.pretrain_steps = args.get_u64("pretrain-steps", a.pretrain_steps)?;
                a.sft_steps = args.get_u64("sft-steps", a.sft_steps)?;
                a.seed = args.get_u64("seed", a.seed)?;
                exp::sft::run(&engine, &a)
            }
            "needle" => {
                let mut a = exp::needle::NeedleArgs::default();
                a.full = args.flag("full");
                a.seed = args.get_u64("seed", a.seed)?;
                a.lm_weight = args.get_f64("lm-weight", a.lm_weight as f64)? as f32;
                if let Some(ss) = args.get("stage-steps") {
                    a.stage_steps = ss
                        .split(',')
                        .map(|x| x.trim().parse::<u64>())
                        .collect::<std::result::Result<_, _>>()?;
                }
                exp::needle::run(&engine, &a)
            }
            "table2" => {
                let mut a = exp::table2::Table2Args::default();
                a.steps = args.get_u64("steps", a.steps)?;
                a.seed = args.get_u64("seed", a.seed)?;
                exp::table2::run(&engine, &a)
            }
            other => bail!("unknown experiment '{other}'"),
        }
    }

    /// The artifact-driven tail of `exp all` (the pure experiments have
    /// already run by the time this is called).
    pub fn exp_all_engine(args: &Args) -> Result<()> {
        let engine = Engine::new(&artifacts_dir())?;
        let steps = args.get_u64("steps", 25)?;
        exp::scaling::run(&engine, &exp::scaling::ScalingArgs { steps, ..Default::default() })?;
        exp::scaling::run(
            &engine,
            &exp::scaling::ScalingArgs { steps: steps / 2 + 1, long: true, ..Default::default() },
        )?;
        exp::fits::run()?;
        exp::granularity::run(
            &engine,
            &exp::granularity::GranularityArgs { steps, ..Default::default() },
        )?;
        exp::hybrid::run(&engine, &exp::hybrid::HybridArgs { steps, ..Default::default() })?;
        exp::sft::run(
            &engine,
            &exp::sft::SftArgs {
                pretrain_steps: steps,
                sft_steps: steps / 2 + 1,
                ..Default::default()
            },
        )?;
        exp::needle::run(
            &engine,
            &exp::needle::NeedleArgs {
                stage_steps: vec![steps, steps / 2 + 1, steps / 4 + 1],
                ..Default::default()
            },
        )?;
        exp::table2::run(&engine, &exp::table2::Table2Args { steps, ..Default::default() })?;
        Ok(())
    }
}

#[cfg(not(feature = "xla"))]
mod engine_cmds {
    use anyhow::{bail, Result};

    use moba::util::cli::Args;

    const NEEDS_XLA: &str =
        "this command drives AOT artifacts through PJRT — rebuild with `--features xla` \
         (and run `make artifacts`)";

    pub fn info() -> Result<()> {
        bail!(NEEDS_XLA)
    }

    pub fn table1() -> Result<()> {
        bail!(NEEDS_XLA)
    }

    pub fn quickstart(_args: &Args) -> Result<()> {
        bail!(NEEDS_XLA)
    }

    pub fn train_cmd(_args: &Args) -> Result<()> {
        bail!(NEEDS_XLA)
    }

    pub fn serve_artifact_cmd(_args: &Args) -> Result<()> {
        bail!(NEEDS_XLA)
    }

    pub fn exp_engine(which: &str, _args: &Args) -> Result<()> {
        match which {
            "scaling" | "granularity" | "hybrid" | "sft" | "needle" | "table2" => {
                bail!("experiment '{which}': {NEEDS_XLA}")
            }
            other => bail!("unknown experiment '{other}'"),
        }
    }

    pub fn exp_all_engine(_args: &Args) -> Result<()> {
        // `fits` is pure Rust but consumes `runs/scaling` summaries, which
        // only the xla-gated scaling experiment produces — run it
        // opportunistically against any existing output.
        match moba::experiments::fits::run() {
            Ok(()) => {}
            Err(e) => println!("(fits skipped: {e:#})"),
        }
        println!(
            "(artifact-driven experiments skipped: build with --features xla to include \
             scaling/granularity/hybrid/sft/needle/table2)"
        );
        Ok(())
    }
}
