//! Typed serving-runtime errors and fault accounting.
//!
//! `ServeError` replaces the `.expect("decode worker hung up")`-style
//! abort paths in `serve::runtime`: a worker fault becomes a value the
//! scheduler can match on and recover from (re-homing the dead shard's
//! sessions through the eviction/resume machinery) instead of a
//! process-wide panic. `FaultStats` surfaces what recovery did inside
//! `SchedStats`.

use std::fmt;

/// A fault in the persistent decode runtime, reported to the caller so
/// it can initiate recovery instead of aborting.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// A worker's step loop panicked; the panic payload (if it was a
    /// string) is preserved in `message`.
    WorkerPanicked { worker: usize, message: String },
    /// A worker's channel disconnected without a panic report — the
    /// thread died in a way that skipped the backstop handler.
    WorkerDisconnected { worker: usize },
    /// A worker missed the per-tick barrier deadline
    /// (`SchedulerCfg::barrier_deadline_secs`): stalled, livelocked, or
    /// wedged on a lock.
    BarrierTimeout { worker: usize, tick: u64, deadline_secs: f64 },
    /// Every decode worker is dead; the scheduler cannot make progress.
    AllWorkersDead,
    /// Overload control rejected the request instead of queueing it
    /// unboundedly: its deadline budget expired while queued, or its
    /// pool reservation can never fit the configured capacity.
    Shed { id: u64, reason: String },
    /// A resumed session's rebuilt state disagrees with its transcript —
    /// the re-prefill produced a different pending token, or a swap-in
    /// restored a different context length, than the session held when
    /// it was evicted. Serving on would emit wrong tokens; failing the
    /// tick is the only honest move.
    ResumeDiverged { what: &'static str, expected: i64, got: i64 },
    /// A serving-state invariant the scheduler relies on does not hold
    /// (e.g. a recovery-ledger entry vanished for an in-flight session).
    /// Previously these were `expect()` aborts; as a typed error the
    /// caller degrades — fails the tick, sheds, drains — instead of
    /// killing the process.
    Inconsistent { what: &'static str },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::WorkerPanicked { worker, message } => {
                write!(f, "decode worker {worker} panicked: {message}")
            }
            ServeError::WorkerDisconnected { worker } => {
                write!(f, "decode worker {worker} disconnected without a panic report")
            }
            ServeError::BarrierTimeout { worker, tick, deadline_secs } => write!(
                f,
                "decode worker {worker} missed the tick-{tick} barrier deadline ({deadline_secs}s)"
            ),
            ServeError::AllWorkersDead => write!(f, "all decode workers are dead"),
            ServeError::Shed { id, reason } => {
                write!(f, "request {id} shed by overload control: {reason}")
            }
            ServeError::ResumeDiverged { what, expected, got } => {
                write!(f, "resume diverged: {what} expected {expected}, got {got}")
            }
            ServeError::Inconsistent { what } => {
                write!(f, "serving-state inconsistency: {what}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Fault/recovery counters, surfaced in `SchedStats::fault`.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct FaultStats {
    /// Workers declared dead (panic report, disconnect, or barrier
    /// timeout).
    pub worker_deaths: usize,
    /// Sessions that lost their home shard and were re-homed to a
    /// surviving worker via the eviction/resume path.
    pub rehomed_sessions: usize,
    /// Barrier deadlines missed (each also counts one worker death).
    pub barrier_timeouts: usize,
    /// Re-prefill seconds spent resuming re-homed sessions (a subset of
    /// `EvictionStats::reprefill_secs`).
    pub recovery_reprefill_secs: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_worker() {
        let e = ServeError::WorkerPanicked { worker: 3, message: "chaos".into() };
        let s = e.to_string();
        assert!(s.contains("worker 3") && s.contains("chaos"), "{s}");
        assert!(ServeError::AllWorkersDead.to_string().contains("all decode workers"));
        let t = ServeError::BarrierTimeout { worker: 1, tick: 9, deadline_secs: 0.5 }.to_string();
        assert!(t.contains("worker 1") && t.contains("tick-9"), "{t}");
        let s = ServeError::Shed { id: 42, reason: "deadline 0.1s missed".into() }.to_string();
        assert!(s.contains("request 42") && s.contains("deadline"), "{s}");
        let d = ServeError::ResumeDiverged { what: "pending token", expected: 7, got: 9 };
        let s = d.to_string();
        assert!(s.contains("pending token") && s.contains("7") && s.contains("9"), "{s}");
        let s = ServeError::Inconsistent { what: "ledger entry missing" }.to_string();
        assert!(s.contains("inconsistency") && s.contains("ledger"), "{s}");
    }

    #[test]
    fn errors_convert_to_anyhow() {
        fn fails() -> anyhow::Result<()> {
            Err(ServeError::WorkerDisconnected { worker: 0 })?;
            Ok(())
        }
        assert!(fails().unwrap_err().to_string().contains("worker 0"));
    }
}
