//! Request admission: the queue in front of the serving engine.
//!
//! Requests arrive with timestamps; the batcher supports two serving
//! disciplines:
//!
//! - **batch mode** (`pop_batch` / `drain`): close a batch when full
//!   (`max_batch`) or when the oldest member has waited long enough
//!   (`max_wait_secs`) — the original vLLM-router-style accounting;
//! - **continuous mode** (`admit`): hand over up to `free_slots` arrived
//!   requests immediately, used by `serve::scheduler` to refill in-flight
//!   decode batches every tick without waiting for a batch boundary.
//!
//! Continuous admission is **priority-aware**: among arrived requests,
//! higher [`Priority`] classes are handed over first; within a class the
//! order is (arrival, id) — so a single-class stream degenerates exactly
//! to the original FIFO discipline. Requests may also carry a deadline
//! budget; [`Batcher::shed_expired`] drains the ones whose deadline
//! passed while they were still queued so the scheduler can shed them
//! explicitly instead of serving them uselessly late.
//!
//! Per-request latency is split into queue / prefill / decode components
//! in [`RequestResult`].

/// Multi-tenant priority class. Ordering is by urgency: `Batch <
/// Standard < Interactive`, so `Ord`/`max` pick the most urgent class.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Throughput traffic: evicted first, degraded first, admitted last.
    Batch,
    /// The default class.
    #[default]
    Standard,
    /// Latency-sensitive traffic: admitted first, evicted last, never
    /// degraded by the pressure dial.
    Interactive,
}

impl Priority {
    pub const ALL: [Priority; 3] = [Priority::Batch, Priority::Standard, Priority::Interactive];

    /// Stable numeric rank (0 = least urgent) — the index into
    /// per-class stats arrays like `EvictionStats::evictions_by_class`.
    pub fn rank(self) -> usize {
        match self {
            Priority::Batch => 0,
            Priority::Standard => 1,
            Priority::Interactive => 2,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Priority::Batch => "batch",
            Priority::Standard => "standard",
            Priority::Interactive => "interactive",
        }
    }
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// arrival time, seconds (simulation clock)
    pub arrival: f64,
    pub priority: Priority,
    /// Admission deadline budget, seconds after `arrival` (simulation
    /// clock): if the request is still queued past `arrival + deadline`
    /// it is shed with `ServeError::Shed` instead of served uselessly
    /// late. `None` = wait forever.
    pub deadline: Option<f64>,
    /// Streaming-pause cadence: a session skips one decode tick each
    /// time its output length reaches a multiple of `pause_every` (a
    /// client draining its stream). 0 = never pauses.
    pub pause_every: usize,
}

impl Request {
    /// A `Standard`-priority request with no deadline and no streaming
    /// pauses — the shape every pre-overload call site used.
    pub fn new(id: u64, prompt: Vec<i32>, max_new: usize, arrival: f64) -> Request {
        Request {
            id,
            prompt,
            max_new,
            arrival,
            priority: Priority::default(),
            deadline: None,
            pause_every: 0,
        }
    }

    pub fn with_priority(mut self, priority: Priority) -> Request {
        self.priority = priority;
        self
    }

    pub fn with_deadline(mut self, deadline_secs: f64) -> Request {
        self.deadline = Some(deadline_secs);
        self
    }

    pub fn with_pause_every(mut self, pause_every: usize) -> Request {
        self.pause_every = pause_every;
        self
    }

    /// Queued past its deadline budget at simulation time `now`?
    pub fn expired(&self, now: f64) -> bool {
        self.deadline.is_some_and(|d| now > self.arrival + d)
    }
}

/// Completed request with its latency breakdown.
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub output: Vec<i32>,
    /// arrival → admission (simulation clock)
    pub queue_secs: f64,
    /// measured prompt-ingest time (wall clock)
    pub prefill_secs: f64,
    /// measured total decode time (wall clock)
    pub decode_secs: f64,
    pub decode_steps: usize,
}

impl RequestResult {
    /// Total service time (prefill + decode).
    pub fn service_secs(&self) -> f64 {
        self.prefill_secs + self.decode_secs
    }
}

#[derive(Clone, Debug)]
pub struct BatcherCfg {
    pub max_batch: usize,
    pub max_wait_secs: f64,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        BatcherCfg { max_batch: 4, max_wait_secs: 0.05 }
    }
}

/// Deterministic priority-then-FIFO admission queue over a timestamped
/// request stream.
pub struct Batcher {
    cfg: BatcherCfg,
    queue: Vec<Request>,
}

impl Batcher {
    pub fn new(cfg: BatcherCfg) -> Batcher {
        Batcher { cfg, queue: Vec::new() }
    }

    pub fn push(&mut self, req: Request) {
        self.queue.push(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Index of the request continuous admission hands over next: the
    /// highest-priority arrived request, ties broken by (arrival, id) —
    /// exact FIFO within a class.
    fn best(&self, now: f64) -> Option<usize> {
        self.queue
            .iter()
            .enumerate()
            .filter(|(_, r)| r.arrival <= now)
            .min_by(|(_, a), (_, b)| {
                b.priority
                    .cmp(&a.priority)
                    .then(a.arrival.total_cmp(&b.arrival))
                    .then(a.id.cmp(&b.id))
            })
            .map(|(i, _)| i)
    }

    /// The request `admit(now, 1)` would hand over, without taking it —
    /// the probe a capacity-aware scheduler uses to check whether the
    /// next admission fits (pool blocks, decode slots) before committing.
    pub fn peek(&self, now: f64) -> Option<&Request> {
        self.best(now).map(|i| &self.queue[i])
    }

    /// Continuous admission: pop up to `free_slots` arrived requests in
    /// (priority desc, arrival, id) order. Never waits — a continuous
    /// scheduler calls this every tick to top up the in-flight batch.
    pub fn admit(&mut self, now: f64, free_slots: usize) -> Vec<Request> {
        let mut out = Vec::new();
        while out.len() < free_slots {
            match self.best(now) {
                Some(i) => out.push(self.queue.remove(i)),
                None => break,
            }
        }
        out
    }

    /// Remove and return every queued request whose deadline budget has
    /// expired at `now` — the scheduler sheds these with a typed error
    /// instead of ever admitting them.
    pub fn shed_expired(&mut self, now: f64) -> Vec<Request> {
        let mut shed = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            if self.queue[i].expired(now) {
                shed.push(self.queue.remove(i));
            } else {
                i += 1;
            }
        }
        shed
    }

    /// Batch mode: given the current clock, pop the next batch if either
    /// policy triggers; otherwise None (keep accumulating).
    pub fn pop_batch(&mut self, now: f64) -> Option<Vec<Request>> {
        if self.queue.is_empty() {
            return None;
        }
        let oldest_wait = now - self.queue[0].arrival;
        if self.queue.len() >= self.cfg.max_batch || oldest_wait >= self.cfg.max_wait_secs {
            let take = self.queue.len().min(self.cfg.max_batch);
            let batch: Vec<Request> = self.queue.drain(..take).collect();
            return Some(batch);
        }
        None
    }

    /// Drain everything regardless of policy (end of stream).
    pub fn drain(&mut self) -> Vec<Vec<Request>> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let take = self.queue.len().min(self.cfg.max_batch);
            out.push(self.queue.drain(..take).collect());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64) -> Request {
        Request::new(id, vec![1, 2, 3], 4, arrival)
    }

    #[test]
    fn batch_closes_when_full() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 2, max_wait_secs: 10.0 });
        b.push(req(1, 0.0));
        assert!(b.pop_batch(0.001).is_none());
        b.push(req(2, 0.002));
        let batch = b.pop_batch(0.003).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn batch_closes_on_timeout() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 8, max_wait_secs: 0.05 });
        b.push(req(1, 0.0));
        assert!(b.pop_batch(0.01).is_none());
        let batch = b.pop_batch(0.06).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 2, max_wait_secs: 0.0 });
        for i in 0..5 {
            b.push(req(i, i as f64 * 0.001));
        }
        let mut ids = Vec::new();
        while let Some(batch) = b.pop_batch(1.0) {
            ids.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn drain_takes_all() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 3, max_wait_secs: 100.0 });
        for i in 0..7 {
            b.push(req(i, 0.0));
        }
        let batches = b.drain();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches.iter().map(|x| x.len()).sum::<usize>(), 7);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn admit_respects_arrival_and_capacity() {
        let mut b = Batcher::new(BatcherCfg::default());
        for i in 0..4 {
            b.push(req(i, i as f64)); // arrivals at t = 0,1,2,3
        }
        // at t=1.5 only requests 0 and 1 have arrived
        let got = b.admit(1.5, 8);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.pending(), 2);
        // capacity caps admission even when more have arrived
        let got = b.admit(10.0, 1);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 2);
        // nothing ready → empty, queue untouched
        assert!(b.admit(-1.0, 8).is_empty());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn peek_mirrors_single_admission() {
        let mut b = Batcher::new(BatcherCfg::default());
        assert!(b.peek(0.0).is_none());
        b.push(req(7, 1.0));
        assert!(b.peek(0.5).is_none(), "not yet arrived");
        assert_eq!(b.peek(1.5).unwrap().id, 7);
        assert_eq!(b.pending(), 1, "peek must not consume");
        assert_eq!(b.admit(1.5, 1)[0].id, 7);
    }

    #[test]
    fn higher_priority_jumps_the_arrived_queue() {
        let mut b = Batcher::new(BatcherCfg::default());
        b.push(req(0, 0.0).with_priority(Priority::Batch));
        b.push(req(1, 0.1).with_priority(Priority::Interactive));
        b.push(req(2, 0.2)); // Standard
        b.push(req(3, 5.0).with_priority(Priority::Interactive)); // not arrived
        // arrived set {0,1,2}: interactive 1 first, then standard 2,
        // then batch 0; the unarrived interactive 3 cannot jump
        assert_eq!(b.peek(1.0).unwrap().id, 1);
        let ids: Vec<u64> = b.admit(1.0, 8).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 0]);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn same_class_admission_stays_fifo() {
        let mut b = Batcher::new(BatcherCfg::default());
        // exact-tie arrivals: id breaks the tie, i.e. submission order
        for i in 0..4 {
            b.push(req(i, 0.0).with_priority(Priority::Batch));
        }
        let ids: Vec<u64> = b.admit(0.0, 8).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn shed_expired_drains_only_deadline_misses() {
        let mut b = Batcher::new(BatcherCfg::default());
        b.push(req(0, 0.0).with_deadline(0.5));
        b.push(req(1, 0.0).with_deadline(5.0));
        b.push(req(2, 0.0)); // no deadline: waits forever
        assert!(b.shed_expired(0.4).is_empty(), "nothing expired yet");
        let shed = b.shed_expired(1.0);
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].id, 0);
        assert_eq!(b.pending(), 2);
        assert!(b.shed_expired(100.0).iter().map(|r| r.id).eq([1]));
        assert_eq!(b.pending(), 1, "deadline-free requests are never shed");
    }

    #[test]
    fn priority_orders_by_urgency() {
        assert!(Priority::Interactive > Priority::Standard);
        assert!(Priority::Standard > Priority::Batch);
        assert_eq!(Priority::default(), Priority::Standard);
        for (i, p) in Priority::ALL.iter().enumerate() {
            assert_eq!(p.rank(), i);
        }
    }

    #[test]
    fn result_service_time_is_prefill_plus_decode() {
        let r = RequestResult {
            id: 1,
            output: vec![],
            queue_secs: 0.5,
            prefill_secs: 0.2,
            decode_secs: 0.3,
            decode_steps: 3,
        };
        assert!((r.service_secs() - 0.5).abs() < 1e-12);
    }
}
