//! Request batching: a vLLM-router-style admission queue in miniature.
//!
//! Requests arrive with timestamps; the batcher forms batches under two
//! policies — `max_batch` (close a batch when full) and `max_wait`
//! (close a batch when its oldest member has waited long enough) — and
//! records queueing vs service latency per request. The serving example
//! drives this with a simulated arrival process and reports the latency
//! distribution, reproducing the paper's deployment-mode accounting.

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// arrival time, seconds (simulation clock)
    pub arrival: f64,
}

#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub output: Vec<i32>,
    pub queue_secs: f64,
    pub service_secs: f64,
}

#[derive(Clone, Debug)]
pub struct BatcherCfg {
    pub max_batch: usize,
    pub max_wait_secs: f64,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        BatcherCfg { max_batch: 4, max_wait_secs: 0.05 }
    }
}

/// Deterministic batch former over a timestamped request stream.
pub struct Batcher {
    cfg: BatcherCfg,
    queue: Vec<Request>,
}

impl Batcher {
    pub fn new(cfg: BatcherCfg) -> Batcher {
        Batcher { cfg, queue: Vec::new() }
    }

    pub fn push(&mut self, req: Request) {
        self.queue.push(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Given the current clock, pop the next batch if either policy
    /// triggers; otherwise None (keep accumulating).
    pub fn pop_batch(&mut self, now: f64) -> Option<Vec<Request>> {
        if self.queue.is_empty() {
            return None;
        }
        let oldest_wait = now - self.queue[0].arrival;
        if self.queue.len() >= self.cfg.max_batch || oldest_wait >= self.cfg.max_wait_secs {
            let take = self.queue.len().min(self.cfg.max_batch);
            let batch: Vec<Request> = self.queue.drain(..take).collect();
            return Some(batch);
        }
        None
    }

    /// Drain everything regardless of policy (end of stream).
    pub fn drain(&mut self) -> Vec<Vec<Request>> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let take = self.queue.len().min(self.cfg.max_batch);
            out.push(self.queue.drain(..take).collect());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64) -> Request {
        Request { id, prompt: vec![1, 2, 3], max_new: 4, arrival }
    }

    #[test]
    fn batch_closes_when_full() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 2, max_wait_secs: 10.0 });
        b.push(req(1, 0.0));
        assert!(b.pop_batch(0.001).is_none());
        b.push(req(2, 0.002));
        let batch = b.pop_batch(0.003).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn batch_closes_on_timeout() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 8, max_wait_secs: 0.05 });
        b.push(req(1, 0.0));
        assert!(b.pop_batch(0.01).is_none());
        let batch = b.pop_batch(0.06).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 2, max_wait_secs: 0.0 });
        for i in 0..5 {
            b.push(req(i, i as f64 * 0.001));
        }
        let mut ids = Vec::new();
        while let Some(batch) = b.pop_batch(1.0) {
            ids.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn drain_takes_all() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 3, max_wait_secs: 100.0 });
        for i in 0..7 {
            b.push(req(i, 0.0));
        }
        let batches = b.drain();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches.iter().map(|x| x.len()).sum::<usize>(), 7);
        assert_eq!(b.pending(), 0);
    }
}
