//! Request admission: the queue in front of the serving engine.
//!
//! Requests arrive with timestamps; the batcher supports two serving
//! disciplines:
//!
//! - **batch mode** (`pop_batch` / `drain`): close a batch when full
//!   (`max_batch`) or when the oldest member has waited long enough
//!   (`max_wait_secs`) — the original vLLM-router-style accounting;
//! - **continuous mode** (`admit`): hand over up to `free_slots` arrived
//!   requests immediately, used by `serve::scheduler` to refill in-flight
//!   decode batches every tick without waiting for a batch boundary.
//!
//! Per-request latency is split into queue / prefill / decode components
//! in [`RequestResult`].

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// arrival time, seconds (simulation clock)
    pub arrival: f64,
}

/// Completed request with its latency breakdown.
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub output: Vec<i32>,
    /// arrival → admission (simulation clock)
    pub queue_secs: f64,
    /// measured prompt-ingest time (wall clock)
    pub prefill_secs: f64,
    /// measured total decode time (wall clock)
    pub decode_secs: f64,
    pub decode_steps: usize,
}

impl RequestResult {
    /// Total service time (prefill + decode).
    pub fn service_secs(&self) -> f64 {
        self.prefill_secs + self.decode_secs
    }
}

#[derive(Clone, Debug)]
pub struct BatcherCfg {
    pub max_batch: usize,
    pub max_wait_secs: f64,
}

impl Default for BatcherCfg {
    fn default() -> Self {
        BatcherCfg { max_batch: 4, max_wait_secs: 0.05 }
    }
}

/// Deterministic FIFO admission queue over a timestamped request stream.
pub struct Batcher {
    cfg: BatcherCfg,
    queue: Vec<Request>,
}

impl Batcher {
    pub fn new(cfg: BatcherCfg) -> Batcher {
        Batcher { cfg, queue: Vec::new() }
    }

    pub fn push(&mut self, req: Request) {
        self.queue.push(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// The request `admit(now, 1)` would hand over, without taking it —
    /// the probe a capacity-aware scheduler uses to check whether the
    /// next admission fits (pool blocks, decode slots) before committing.
    pub fn peek(&self, now: f64) -> Option<&Request> {
        self.queue.first().filter(|r| r.arrival <= now)
    }

    /// Continuous admission: pop up to `free_slots` FIFO requests that
    /// have arrived by `now`. Never waits — a continuous scheduler calls
    /// this every tick to top up the in-flight batch. O(queue) total: the
    /// ready requests form a prefix (FIFO arrival order), so they are
    /// counted and drained in one pass.
    pub fn admit(&mut self, now: f64, free_slots: usize) -> Vec<Request> {
        let ready = self
            .queue
            .iter()
            .take(free_slots)
            .take_while(|r| r.arrival <= now)
            .count();
        self.queue.drain(..ready).collect()
    }

    /// Batch mode: given the current clock, pop the next batch if either
    /// policy triggers; otherwise None (keep accumulating).
    pub fn pop_batch(&mut self, now: f64) -> Option<Vec<Request>> {
        if self.queue.is_empty() {
            return None;
        }
        let oldest_wait = now - self.queue[0].arrival;
        if self.queue.len() >= self.cfg.max_batch || oldest_wait >= self.cfg.max_wait_secs {
            let take = self.queue.len().min(self.cfg.max_batch);
            let batch: Vec<Request> = self.queue.drain(..take).collect();
            return Some(batch);
        }
        None
    }

    /// Drain everything regardless of policy (end of stream).
    pub fn drain(&mut self) -> Vec<Vec<Request>> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let take = self.queue.len().min(self.cfg.max_batch);
            out.push(self.queue.drain(..take).collect());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64) -> Request {
        Request { id, prompt: vec![1, 2, 3], max_new: 4, arrival }
    }

    #[test]
    fn batch_closes_when_full() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 2, max_wait_secs: 10.0 });
        b.push(req(1, 0.0));
        assert!(b.pop_batch(0.001).is_none());
        b.push(req(2, 0.002));
        let batch = b.pop_batch(0.003).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn batch_closes_on_timeout() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 8, max_wait_secs: 0.05 });
        b.push(req(1, 0.0));
        assert!(b.pop_batch(0.01).is_none());
        let batch = b.pop_batch(0.06).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 2, max_wait_secs: 0.0 });
        for i in 0..5 {
            b.push(req(i, i as f64 * 0.001));
        }
        let mut ids = Vec::new();
        while let Some(batch) = b.pop_batch(1.0) {
            ids.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn drain_takes_all() {
        let mut b = Batcher::new(BatcherCfg { max_batch: 3, max_wait_secs: 100.0 });
        for i in 0..7 {
            b.push(req(i, 0.0));
        }
        let batches = b.drain();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches.iter().map(|x| x.len()).sum::<usize>(), 7);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn admit_respects_arrival_and_capacity() {
        let mut b = Batcher::new(BatcherCfg::default());
        for i in 0..4 {
            b.push(req(i, i as f64)); // arrivals at t = 0,1,2,3
        }
        // at t=1.5 only requests 0 and 1 have arrived
        let got = b.admit(1.5, 8);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.pending(), 2);
        // capacity caps admission even when more have arrived
        let got = b.admit(10.0, 1);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 2);
        // nothing ready → empty, queue untouched
        assert!(b.admit(-1.0, 8).is_empty());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn peek_mirrors_single_admission() {
        let mut b = Batcher::new(BatcherCfg::default());
        assert!(b.peek(0.0).is_none());
        b.push(req(7, 1.0));
        assert!(b.peek(0.5).is_none(), "not yet arrived");
        assert_eq!(b.peek(1.5).unwrap().id, 7);
        assert_eq!(b.pending(), 1, "peek must not consume");
        assert_eq!(b.admit(1.5, 1)[0].id, 7);
    }

    #[test]
    fn result_service_time_is_prefill_plus_decode() {
        let r = RequestResult {
            id: 1,
            output: vec![],
            queue_secs: 0.5,
            prefill_secs: 0.2,
            decode_secs: 0.3,
            decode_steps: 3,
        };
        assert!((r.service_secs() - 0.5).abs() < 1e-12);
    }
}
