//! Persistent thread-per-core decode runtime: N named, core-pinned OS
//! workers spawned once at scheduler start, each owning a shard of live
//! decode sessions, fed by bounded channels — replacing the tick-loop's
//! re-spawned scoped threads, whose per-tick spawn/join cost dominated
//! per-token latency once the O(k·B) kernels got cheap.
//!
//! Topology (see `serve/README.md` for the full architecture):
//!
//! - one bounded `sync_channel` **to** each worker carrying
//!   [`ToWorker`] messages (admission, eviction, step commands) — the
//!   bound is the backpressure that replaces the global lock-step tick;
//! - one shared unbounded channel **from** all workers back to the
//!   scheduler ([`FromWorker`]: step reports, eviction replies);
//! - a [`StealState`] shared by the workers: one work deque + done-box
//!   per shard, so idle workers pull sessions from the most-loaded
//!   shard's deque while skewed request lengths drain.
//!
//! Determinism contract (hard): served tokens are bitwise identical to
//! the tick-loop scheduler for every worker count and every stealing
//! schedule. The argument: a decode step's arithmetic is entirely
//! session-local, each session is stepped exactly once per step command
//! (by its owner or by a thief — never both: a session is *popped* off a
//! deque before it is stepped), and every stepped session returns to its
//! home shard's done box, where the owner re-sorts by session id before
//! reporting. So which thread stepped a session, and in which order, is
//! invisible in every session's bytes and in every scheduler decision.
//! `tests/thread_invariance.rs` and `tests/scheduler_fuzz.rs` pin this.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use super::engine::{DecodeSession, ServeEngine};
use super::model::TokenModel;

/// Which dispatch machinery steps the in-flight decode batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuntimeKind {
    /// the legacy baseline: scoped threads re-spawned every tick,
    /// joined at a global barrier
    TickLoop,
    /// persistent pinned decode workers fed by bounded channels, with
    /// work stealing between shards (the default)
    Persistent,
}

impl RuntimeKind {
    pub fn parse(s: &str) -> Result<RuntimeKind> {
        match s {
            "tick" | "tick-loop" | "tickloop" => Ok(RuntimeKind::TickLoop),
            "persistent" | "tpc" | "worker" => Ok(RuntimeKind::Persistent),
            other => bail!("unknown runtime '{other}' (expected 'tick' or 'persistent')"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RuntimeKind::TickLoop => "tick-loop",
            RuntimeKind::Persistent => "persistent",
        }
    }
}

fn env_flag(name: &str, default: bool) -> bool {
    match std::env::var(name) {
        Ok(v) => !matches!(v.trim(), "0" | "false" | "off" | "no"),
        Err(_) => default,
    }
}

/// Work stealing between decode shards: `MOBA_STEAL` env override
/// (`0`/`false`/`off`/`no` disable), default on.
pub fn steal_from_env() -> bool {
    env_flag("MOBA_STEAL", true)
}

/// Core pinning of decode workers: `MOBA_PIN` env override
/// (`0`/`false`/`off`/`no` disable), default on.
pub fn pin_from_env() -> bool {
    env_flag("MOBA_PIN", true)
}

/// Pin the calling thread to `core` via raw `sched_setaffinity` (no
/// external crate; cores ≥ 64 and non-x86_64-linux targets are left
/// unpinned). Returns whether the pin took effect. Purely a locality
/// hint — never affects results.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
pub fn pin_current_thread(core: usize) -> bool {
    if core >= 64 {
        return false;
    }
    let mask: u64 = 1u64 << core;
    let ret: i64;
    // SAFETY: sched_setaffinity(0, sizeof(mask), &mask) only reads the
    // mask and affects scheduling of the calling thread.
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203i64 => ret, // __NR_sched_setaffinity
            in("rdi") 0usize,               // pid 0 = calling thread
            in("rsi") std::mem::size_of::<u64>(),
            in("rdx") &mask as *const u64,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret == 0
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
pub fn pin_current_thread(_core: usize) -> bool {
    false
}

/// Whether this target can pin threads at all.
pub fn pin_supported() -> bool {
    cfg!(all(target_os = "linux", target_arch = "x86_64"))
}

/// One live request: its decode session plus the scheduler-side metadata
/// that must travel with it across worker threads.
pub(crate) struct Live {
    pub(crate) id: u64,
    pub(crate) queue_secs: f64,
    /// not-yet-materialized pool blocks this session's future decode
    /// steps may still allocate (`ServeEngine::remaining_reserve`,
    /// refreshed every tick; 0 when the engine has no bounded pool).
    /// Invariant: the scheduler's `reserved_total` is exactly the sum of
    /// this field over all running sessions.
    pub(crate) reserve_blocks: usize,
    /// tick this session was last stepped (or admitted/resumed) — the
    /// LRU key; sessions touched in the current tick are never evicted
    pub(crate) last_stepped: u64,
    /// owning shard: stepped results always return here, stealing never
    /// migrates ownership — that is what keeps the merge deterministic
    pub(crate) home: usize,
    pub(crate) session: DecodeSession,
}

/// Post-step snapshot of one surviving session, computed on the worker
/// so the scheduler's admission/eviction logic never has to reach into
/// worker-owned sessions. Exact until the session's next step: nothing
/// mutates a session between steps.
pub(crate) struct SessionMeta {
    pub(crate) id: u64,
    /// `ServeEngine::remaining_reserve` (0 when the pool is unbounded)
    pub(crate) reserve: usize,
    /// `ServeEngine::freeable_blocks` — the eviction feasibility input
    pub(crate) freeable: usize,
}

/// One worker's answer to a step command. The buffers round-trip through
/// the channels (scheduler → worker → scheduler) so steady-state ticks
/// allocate nothing — the `FusedScratch` discipline applied to the
/// scheduler.
#[derive(Default)]
pub(crate) struct StepReport {
    pub(crate) metas: Vec<SessionMeta>,
    pub(crate) finished: Vec<Live>,
    /// decode steps this WORKER performed (own + stolen sessions)
    pub(crate) steps: usize,
    pub(crate) busy_secs: f64,
    /// sessions pulled from another shard's deque
    pub(crate) steals: usize,
    /// decode tokens produced by those stolen sessions
    pub(crate) stolen_steps: usize,
    /// sessions this worker owned when the step command arrived
    pub(crate) owned: usize,
}

impl StepReport {
    fn clear(&mut self) {
        self.metas.clear();
        self.finished.clear();
        self.steps = 0;
        self.busy_secs = 0.0;
        self.steals = 0;
        self.stolen_steps = 0;
        self.owned = 0;
    }
}

/// Scheduler → worker commands.
pub(crate) enum ToWorker {
    /// take ownership of a freshly admitted or resumed session
    Admit(Box<Live>),
    /// release the identified session's pool blocks and hand it back
    Evict(u64),
    /// step every owned session one decode token (stealing from other
    /// shards when the local deque runs dry), then report
    Step { tick: u64, report: StepReport },
    Shutdown,
}

/// Worker → scheduler replies (one shared channel; the scheduler's
/// command flow guarantees replies are never interleaved across kinds:
/// evictions are round-trips on a quiet channel, step replies are
/// counted exactly).
pub(crate) enum FromWorker {
    Evicted { live: Box<Live>, freed: Result<usize> },
    StepDone { worker: usize, report: StepReport },
}

/// Cross-shard work stealing state: a deque + done-box per shard.
/// Per tick, each worker publishes its owned sessions into its deque,
/// pops them front-to-back, and — once dry — pops the *back* of the
/// most-loaded other deque. Every stepped session is pushed to its home
/// shard's done box, whose owner blocks until all of its sessions are
/// back, then re-sorts by id: arrival order on the done box is invisible.
struct StealState {
    deques: Vec<Mutex<VecDeque<Live>>>,
    /// advisory deque lengths for victim selection (the deque lock is
    /// the source of truth when actually popping)
    qlen: Vec<AtomicUsize>,
    done: Vec<(Mutex<Vec<Live>>, Condvar)>,
}

impl StealState {
    fn new(shards: usize) -> StealState {
        StealState {
            deques: (0..shards).map(|_| Mutex::new(VecDeque::new())).collect(),
            qlen: (0..shards).map(|_| AtomicUsize::new(0)).collect(),
            done: (0..shards).map(|_| (Mutex::new(Vec::new()), Condvar::new())).collect(),
        }
    }

    fn shards(&self) -> usize {
        self.deques.len()
    }

    /// Return a stepped session to its home shard's done box.
    fn finish(&self, live: Live) {
        let (lock, cv) = &self.done[live.home];
        lock.lock().expect("done box").push(live);
        cv.notify_one();
    }
}

fn step_one<M: TokenModel>(engine: &ServeEngine<M>, live: &mut Live, tick: u64) -> bool {
    live.last_stepped = tick;
    engine.step(&mut live.session).is_some()
}

/// The stealing step: publish owned sessions, drain own deque front to
/// back, then steal off the back of the most-loaded other shard (lowest
/// index on qlen ties) until every deque this worker can see is dry,
/// and finally wait for all owned sessions to come home.
fn step_stealing<M: TokenModel>(
    w: usize,
    engine: &ServeEngine<M>,
    shared: &StealState,
    owned: &mut Vec<Live>,
    report: &mut StepReport,
    tick: u64,
) {
    let expected = owned.len();
    {
        let mut dq = shared.deques[w].lock().expect("steal deque");
        dq.extend(owned.drain(..));
        shared.qlen[w].store(dq.len(), Ordering::SeqCst);
    }
    loop {
        // own work first
        let mine = {
            let mut dq = shared.deques[w].lock().expect("steal deque");
            let live = dq.pop_front();
            shared.qlen[w].store(dq.len(), Ordering::SeqCst);
            live
        };
        if let Some(mut live) = mine {
            if step_one(engine, &mut live, tick) {
                report.steps += 1;
            }
            shared.finish(live);
            continue;
        }
        // own deque dry: pick the most-loaded other shard (ties: lowest
        // index). Opportunistic — a shard that publishes after this scan
        // simply isn't stolen from this round.
        let victim = shared
            .qlen
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != w)
            .map(|(i, n)| (n.load(Ordering::SeqCst), i))
            .filter(|&(n, _)| n > 0)
            .max_by_key(|&(n, i)| (n, std::cmp::Reverse(i)))
            .map(|(_, i)| i);
        let Some(v) = victim else { break };
        let stolen = {
            let mut dq = shared.deques[v].lock().expect("steal deque");
            let live = dq.pop_back();
            shared.qlen[v].store(dq.len(), Ordering::SeqCst);
            live
        };
        if let Some(mut live) = stolen {
            report.steals += 1;
            if step_one(engine, &mut live, tick) {
                report.steps += 1;
                report.stolen_steps += 1;
            }
            shared.finish(live);
        }
        // a raced-away pop rescans: qlen was refreshed under the lock
    }
    // collect every owned session back (stepped here or by thieves)
    let (lock, cv) = &shared.done[w];
    let mut done = lock.lock().expect("done box");
    loop {
        owned.extend(done.drain(..));
        if owned.len() >= expected {
            break;
        }
        done = cv.wait(done).expect("done box");
    }
    debug_assert_eq!(owned.len(), expected, "lost or duplicated a session");
}

/// Worker thread body: own a shard of sessions, serve commands until
/// shutdown. Sessions die here on shutdown, releasing their pool blocks
/// through the backend's `Drop`.
fn run_worker<M: TokenModel + Send + Sync + 'static>(
    w: usize,
    engine: Arc<ServeEngine<M>>,
    rx: Receiver<ToWorker>,
    tx: Sender<FromWorker>,
    shared: Arc<StealState>,
    steal: bool,
) {
    let bounded = engine.pool_status().is_some_and(|p| p.capacity_blocks.is_some());
    let mut owned: Vec<Live> = Vec::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            ToWorker::Admit(live) => owned.push(*live),
            ToWorker::Evict(id) => {
                let idx = owned
                    .iter()
                    .position(|l| l.id == id)
                    .expect("evict command for a session this worker does not own");
                let mut live = owned.remove(idx);
                let freed = engine.evict_session(&mut live.session);
                let _ = tx.send(FromWorker::Evicted { live: Box::new(live), freed });
            }
            ToWorker::Step { tick, mut report } => {
                report.clear();
                report.owned = owned.len();
                let t0 = Instant::now();
                if steal && shared.shards() > 1 {
                    step_stealing(w, engine.as_ref(), &shared, &mut owned, &mut report, tick);
                } else {
                    for live in owned.iter_mut() {
                        if step_one(engine.as_ref(), live, tick) {
                            report.steps += 1;
                        }
                    }
                }
                report.busy_secs = t0.elapsed().as_secs_f64();
                // deterministic merge: id order, regardless of which
                // thread stepped what or when it came home
                owned.sort_by_key(|l| l.id);
                let mut i = 0;
                while i < owned.len() {
                    if owned[i].session.finished() {
                        report.finished.push(owned.remove(i));
                    } else {
                        i += 1;
                    }
                }
                for live in &owned {
                    report.metas.push(SessionMeta {
                        id: live.id,
                        reserve: if bounded {
                            engine.remaining_reserve(&live.session)
                        } else {
                            0
                        },
                        freeable: engine.freeable_blocks(&live.session),
                    });
                }
                if tx.send(FromWorker::StepDone { worker: w, report }).is_err() {
                    break; // scheduler gone
                }
            }
            ToWorker::Shutdown => break,
        }
    }
}

/// Handle to the persistent worker fleet: per-worker bounded command
/// channels, the shared reply channel, and the recycled step-report
/// buffers. Dropping it shuts the workers down and joins them.
pub(crate) struct DecodeRuntime {
    to: Vec<SyncSender<ToWorker>>,
    from: Receiver<FromWorker>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// per-worker report buffers, round-tripped through the channels
    spare: Vec<Option<StepReport>>,
    /// outstanding sends per worker channel since the last barrier — an
    /// upper bound on actual queue depth, tracked for `queue_depth_hwm`
    depth: Vec<usize>,
    depth_hwm: Vec<usize>,
}

impl DecodeRuntime {
    pub(crate) fn spawn<M: TokenModel + Send + Sync + 'static>(
        engine: Arc<ServeEngine<M>>,
        workers: usize,
        steal: bool,
        pin: bool,
        chan_cap: usize,
    ) -> DecodeRuntime {
        assert!(workers > 0);
        let shared = Arc::new(StealState::new(workers));
        let (from_tx, from_rx) = mpsc::channel();
        let ncores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut to = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = mpsc::sync_channel(chan_cap.max(2));
            let engine = engine.clone();
            let from = from_tx.clone();
            let shared = shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("moba-decode-{w}"))
                .spawn(move || {
                    if pin {
                        pin_current_thread(w % ncores);
                    }
                    run_worker(w, engine, rx, from, shared, steal);
                })
                .expect("spawn decode worker");
            to.push(tx);
            handles.push(handle);
        }
        DecodeRuntime {
            to,
            from: from_rx,
            handles,
            spare: (0..workers).map(|_| Some(StepReport::default())).collect(),
            depth: vec![0; workers],
            depth_hwm: vec![0; workers],
        }
    }

    pub(crate) fn workers(&self) -> usize {
        self.to.len()
    }

    fn note_send(&mut self, shard: usize) {
        self.depth[shard] += 1;
        self.depth_hwm[shard] = self.depth_hwm[shard].max(self.depth[shard]);
    }

    /// Hand a session to its home shard.
    pub(crate) fn admit(&mut self, shard: usize, live: Live) {
        debug_assert_eq!(live.home, shard);
        self.note_send(shard);
        self.to[shard].send(ToWorker::Admit(Box::new(live))).expect("decode worker hung up");
    }

    /// Synchronous eviction round-trip: the identified session comes back
    /// with its pool blocks released. Only called between step barriers,
    /// so the reply channel holds nothing else.
    pub(crate) fn evict(&mut self, shard: usize, id: u64) -> (Live, Result<usize>) {
        self.note_send(shard);
        self.to[shard].send(ToWorker::Evict(id)).expect("decode worker hung up");
        match self.from.recv().expect("decode worker hung up") {
            FromWorker::Evicted { live, freed } => {
                self.depth[shard] = 0;
                (*live, freed)
            }
            FromWorker::StepDone { .. } => {
                unreachable!("step reply on a quiet channel during eviction")
            }
        }
    }

    /// Step every shard once and collect all reports — the per-tick
    /// barrier. Reports land back in `spare` (read them via
    /// `reports_mut`); their buffers are reused next tick.
    pub(crate) fn step_all(&mut self, tick: u64) {
        let n = self.to.len();
        for w in 0..n {
            let report = self.spare[w].take().expect("report buffer in flight");
            self.depth[w] += 1;
            self.depth_hwm[w] = self.depth_hwm[w].max(self.depth[w]);
            self.to[w].send(ToWorker::Step { tick, report }).expect("decode worker hung up");
        }
        for _ in 0..n {
            match self.from.recv().expect("decode worker hung up") {
                FromWorker::StepDone { worker, report } => {
                    self.spare[worker] = Some(report);
                }
                FromWorker::Evicted { .. } => unreachable!("stray eviction reply"),
            }
        }
        for d in self.depth.iter_mut() {
            *d = 0;
        }
    }

    /// The per-worker reports from the last `step_all` (index = worker).
    pub(crate) fn report_mut(&mut self, w: usize) -> &mut StepReport {
        self.spare[w].as_mut().expect("report buffer in flight")
    }

    pub(crate) fn depth_hwm(&self, w: usize) -> usize {
        self.depth_hwm[w]
    }
}

impl Drop for DecodeRuntime {
    fn drop(&mut self) {
        for tx in &self.to {
            let _ = tx.send(ToWorker::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_kind_parses_and_labels() {
        assert_eq!(RuntimeKind::parse("tick").unwrap(), RuntimeKind::TickLoop);
        assert_eq!(RuntimeKind::parse("tick-loop").unwrap(), RuntimeKind::TickLoop);
        assert_eq!(RuntimeKind::parse("persistent").unwrap(), RuntimeKind::Persistent);
        assert_eq!(RuntimeKind::parse("tpc").unwrap(), RuntimeKind::Persistent);
        assert!(RuntimeKind::parse("bogus").is_err());
        assert_eq!(RuntimeKind::TickLoop.label(), "tick-loop");
        assert_eq!(RuntimeKind::Persistent.label(), "persistent");
    }

    #[test]
    fn pin_current_thread_is_safe_to_call() {
        // pin to core 0 (must exist); success depends on the platform,
        // but the call must never crash or corrupt anything
        let ok = pin_current_thread(0);
        if pin_supported() {
            assert!(ok, "pinning to core 0 should succeed on linux/x86_64");
        }
        assert!(!pin_current_thread(64), "cores >= 64 are out of mask range");
    }

    #[test]
    fn env_flag_semantics() {
        // defaults hold when unset (the suite does not set these vars)
        assert!(steal_from_env() || std::env::var("MOBA_STEAL").is_ok());
        assert!(pin_from_env() || std::env::var("MOBA_PIN").is_ok());
    }
}
