//! Persistent thread-per-core decode runtime: N named, core-pinned OS
//! workers spawned once at scheduler start, each owning a shard of live
//! decode sessions, fed by bounded channels — replacing the tick-loop's
//! re-spawned scoped threads, whose per-tick spawn/join cost dominated
//! per-token latency once the O(k·B) kernels got cheap.
//!
//! Topology (see `serve/README.md` for the full architecture):
//!
//! - one bounded `sync_channel` **to** each worker carrying
//!   [`ToWorker`] messages (admission, eviction, step commands) — the
//!   bound is the backpressure that replaces the global lock-step tick;
//! - one shared unbounded channel **from** all workers back to the
//!   scheduler ([`FromWorker`]: step reports, eviction replies);
//! - a [`StealState`] shared by the workers: one work deque + done-box
//!   per shard, so idle workers pull sessions from the most-loaded
//!   shard's deque while skewed request lengths drain.
//!
//! Determinism contract (hard): served tokens are bitwise identical to
//! the tick-loop scheduler for every worker count and every stealing
//! schedule. The argument: a decode step's arithmetic is entirely
//! session-local, each session is stepped exactly once per step command
//! (by its owner or by a thief — never both: a session is *popped* off a
//! deque before it is stepped), and every stepped session returns to its
//! home shard's done box, where the owner re-sorts by session id before
//! reporting. So which thread stepped a session, and in which order, is
//! invisible in every session's bytes and in every scheduler decision.
//! `tests/thread_invariance.rs` and `tests/scheduler_fuzz.rs` pin this.
//!
//! **Supervision / fault isolation** (see `serve/README.md` § Failure
//! model & recovery): a worker fault must degrade, never abort. Two
//! `catch_unwind` layers enforce that:
//!
//! - a *narrow* catch around each `ServeEngine::step` keeps the steal
//!   protocol alive through a panicking decode — the session is flagged
//!   [`Live::poisoned`], still returns to its home done-box (no condvar
//!   deadlock across workers), and is shipped back in
//!   [`StepReport::orphans`] for the scheduler to quarantine and resume;
//! - a *backstop* catch around the whole command loop turns any other
//!   panic into one final [`StepReport`] carrying the panic message and
//!   every session the worker still held, then lets the thread die.
//!
//! The scheduler-side [`DecodeRuntime`] detects deaths three ways — a
//! panic report, a closed channel, or a missed `recv_timeout` barrier
//! deadline — marks the shard dead (the shared flag makes a stalled
//! zombie exit instead of re-entering the steal protocol), scavenges any
//! intact sessions stranded in the dead shard's deque/done-box, and
//! hands a [`WorkerDeath`] to the scheduler, which re-homes the sessions
//! through the eviction/resume machinery. Injected faults
//! (`serve::chaos`) fire at the top of `Step` handling — before any
//! session is published — so chaos runs exercise exactly these paths.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::batcher::Priority;
use super::chaos::{self, FaultKind, FaultPlan};
use super::engine::{DecodeSession, ServeEngine, SwapBundle};
use super::error::ServeError;
use super::model::TokenModel;
use crate::util::sync;

/// Which dispatch machinery steps the in-flight decode batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuntimeKind {
    /// the legacy baseline: scoped threads re-spawned every tick,
    /// joined at a global barrier
    TickLoop,
    /// persistent pinned decode workers fed by bounded channels, with
    /// work stealing between shards (the default)
    Persistent,
}

impl RuntimeKind {
    pub fn parse(s: &str) -> Result<RuntimeKind> {
        match s {
            "tick" | "tick-loop" | "tickloop" => Ok(RuntimeKind::TickLoop),
            "persistent" | "tpc" | "worker" => Ok(RuntimeKind::Persistent),
            other => bail!("unknown runtime '{other}' (expected 'tick' or 'persistent')"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RuntimeKind::TickLoop => "tick-loop",
            RuntimeKind::Persistent => "persistent",
        }
    }
}

fn env_flag(name: &str, default: bool) -> bool {
    match std::env::var(name) {
        Ok(v) => !matches!(v.trim(), "0" | "false" | "off" | "no"),
        Err(_) => default,
    }
}

/// Work stealing between decode shards: `MOBA_STEAL` env override
/// (`0`/`false`/`off`/`no` disable), default on.
pub fn steal_from_env() -> bool {
    env_flag("MOBA_STEAL", true)
}

/// Core pinning of decode workers: `MOBA_PIN` env override
/// (`0`/`false`/`off`/`no` disable), default on.
pub fn pin_from_env() -> bool {
    env_flag("MOBA_PIN", true)
}

/// Strict boolean env parser for the CLI boundary (the `parse_workers`
/// pattern): the lenient `env_flag` default above treats any unknown
/// value as "on", which silently masks typos; `repro serve` routes
/// `MOBA_STEAL`/`MOBA_PIN` through this instead so a typo fails loudly
/// with the name and offending value.
pub fn parse_flag(name: &str, raw: Option<String>) -> Result<Option<bool>, String> {
    match raw {
        None => Ok(None),
        Some(v) => match v.trim() {
            "1" | "true" | "on" | "yes" => Ok(Some(true)),
            "0" | "false" | "off" | "no" => Ok(Some(false)),
            _ => Err(format!(
                "{name} must be one of 1/0/true/false/on/off/yes/no, got {v:?}"
            )),
        },
    }
}

/// Strict `MOBA_STEAL` read for the CLI boundary.
pub fn steal_from_env_strict() -> Result<Option<bool>, String> {
    parse_flag("MOBA_STEAL", std::env::var("MOBA_STEAL").ok())
}

/// Strict `MOBA_PIN` read for the CLI boundary.
pub fn pin_from_env_strict() -> Result<Option<bool>, String> {
    parse_flag("MOBA_PIN", std::env::var("MOBA_PIN").ok())
}

/// Pin the calling thread to `core` via raw `sched_setaffinity` (no
/// external crate; cores ≥ 64 and non-x86_64-linux targets are left
/// unpinned). Returns whether the pin took effect. Purely a locality
/// hint — never affects results.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
pub fn pin_current_thread(core: usize) -> bool {
    if core >= 64 {
        return false;
    }
    let mask: u64 = 1u64 << core;
    let ret: i64;
    // SAFETY: sched_setaffinity(0, sizeof(mask), &mask) only reads the
    // mask and affects scheduling of the calling thread.
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203i64 => ret, // __NR_sched_setaffinity
            in("rdi") 0usize,               // pid 0 = calling thread
            in("rsi") std::mem::size_of::<u64>(),
            in("rdx") &mask as *const u64,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret == 0
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
pub fn pin_current_thread(_core: usize) -> bool {
    false
}

/// Whether this target can pin threads at all.
pub fn pin_supported() -> bool {
    cfg!(all(target_os = "linux", target_arch = "x86_64"))
}

/// One live request: its decode session plus the scheduler-side metadata
/// that must travel with it across worker threads.
pub(crate) struct Live {
    pub(crate) id: u64,
    pub(crate) queue_secs: f64,
    /// not-yet-materialized pool blocks this session's future decode
    /// steps may still allocate (`ServeEngine::remaining_reserve`,
    /// refreshed every tick; 0 when the engine has no bounded pool).
    /// Invariant: the scheduler's `reserved_total` is exactly the sum of
    /// this field over all running sessions.
    pub(crate) reserve_blocks: usize,
    /// tick this session was last stepped (or admitted/resumed) — the
    /// LRU key; sessions touched in the current tick are never evicted
    pub(crate) last_stepped: u64,
    /// owning shard: stepped results always return here, stealing never
    /// migrates ownership — that is what keeps the merge deterministic
    pub(crate) home: usize,
    /// a decode step on this session panicked (caught by the narrow
    /// per-step handler): its in-memory state may be mid-mutation, so it
    /// must be quarantined + resumed via re-prefill before stepping again
    pub(crate) poisoned: bool,
    /// this session lost its home shard to a worker death and is being
    /// re-homed; its next resume is charged to
    /// `FaultStats::recovery_reprefill_secs`
    pub(crate) rehomed: bool,
    /// SLA class: the primary eviction/resume ordering key — a burst of
    /// low-priority arrivals cannot thrash a high-priority session's KV
    pub(crate) priority: Priority,
    /// admission deadline budget carried from the request (seconds after
    /// arrival, simulation clock); used only for SLA-violation stats
    /// once the session is admitted
    pub(crate) deadline: Option<f64>,
    /// streaming-pause cadence (`Request::pause_every`): skip one decode
    /// tick each time `out_len` reaches a multiple of this. 0 = never.
    pub(crate) pause_every: usize,
    /// the session skipped its previous step attempt at the current
    /// `out_len` (so the next attempt proceeds instead of pausing again)
    pub(crate) paused: bool,
    /// earliest tick a deferred resume may be retried (backoff gate —
    /// while in the future, the stuck resume stops blocking arrivals)
    pub(crate) retry_at: u64,
    /// current resume backoff in ticks (doubles per deferral, capped)
    pub(crate) backoff: u64,
    /// host-tier snapshot of this session's private tail blocks (one
    /// image per model layer), present while preempted-with-swap: the
    /// resume path restores it instead of re-prefilling (and falls back
    /// transparently if that fails). The bundle travels with the session
    /// — there is no separate swap store.
    pub(crate) swap: Option<SwapBundle>,
    pub(crate) session: DecodeSession,
}

impl Live {
    /// Streaming-pause rule, shared by both runtimes and the steal path:
    /// a session with `pause_every = p > 0` skips exactly one decode
    /// tick each time its output length reaches a multiple of p (a
    /// client draining its stream before accepting more tokens). A pure
    /// function of `out_len` + the one-shot `paused` latch — never of
    /// wall-clock or thread schedule — so the skip pattern is identical
    /// across runtimes, worker counts, and steal schedules, and the
    /// served tokens never change (a skipped step is just deferred).
    pub(crate) fn pause_this_tick(&mut self) -> bool {
        if self.pause_every > 0 && !self.paused && !self.session.finished() {
            let out = self.session.output().len();
            if out > 0 && out % self.pause_every == 0 {
                self.paused = true;
                return true;
            }
        }
        self.paused = false;
        false
    }
}

/// Post-step snapshot of one surviving session, computed on the worker
/// so the scheduler's admission/eviction logic never has to reach into
/// worker-owned sessions. Exact until the session's next step: nothing
/// mutates a session between steps.
pub(crate) struct SessionMeta {
    pub(crate) id: u64,
    /// `ServeEngine::remaining_reserve` (0 when the pool is unbounded)
    pub(crate) reserve: usize,
    /// `ServeEngine::freeable_blocks` — the eviction feasibility input
    pub(crate) freeable: usize,
    /// generated-token count after this step — with `last_token`, what
    /// the scheduler's recovery ledger needs to mirror the transcript
    pub(crate) out_len: usize,
    /// the most recent generated token (0 when none yet)
    pub(crate) last_token: i32,
    /// tick the session last actually stepped — a paused (idle) session
    /// keeps its old value, which is what makes the LRU/SLA eviction key
    /// differentiate sessions under the persistent mirror
    pub(crate) last_stepped: u64,
    /// SLA class, mirrored so main-side victim selection ranks it
    pub(crate) priority: Priority,
}

/// One worker's answer to a step command. The buffers round-trip through
/// the channels (scheduler → worker → scheduler) so steady-state ticks
/// allocate nothing — the `FusedScratch` discipline applied to the
/// scheduler.
#[derive(Default)]
pub(crate) struct StepReport {
    pub(crate) metas: Vec<SessionMeta>,
    pub(crate) finished: Vec<Live>,
    /// decode steps this WORKER performed (own + stolen sessions)
    pub(crate) steps: usize,
    pub(crate) busy_secs: f64,
    /// sessions pulled from another shard's deque
    pub(crate) steals: usize,
    /// decode tokens produced by those stolen sessions
    pub(crate) stolen_steps: usize,
    /// sessions this worker owned when the step command arrived
    pub(crate) owned: usize,
    /// set by the backstop handler when the worker's loop panicked —
    /// the worker is dead after a report carrying this
    pub(crate) panic: Option<String>,
    /// sessions that need a new home: every survivor of a dying worker,
    /// plus any session whose own step panicked (poisoned) on a healthy
    /// worker
    pub(crate) orphans: Vec<Live>,
}

impl StepReport {
    fn clear(&mut self) {
        self.metas.clear();
        self.finished.clear();
        self.steps = 0;
        self.busy_secs = 0.0;
        self.steals = 0;
        self.stolen_steps = 0;
        self.owned = 0;
        self.panic = None;
        self.orphans.clear();
    }
}

/// Scheduler → worker commands.
pub(crate) enum ToWorker {
    /// take ownership of a freshly admitted or resumed session
    Admit(Box<Live>),
    /// release the identified session's pool blocks and hand it back.
    /// With `swap`, snapshot the private tail into the host tier first
    /// (the image ships back attached to the `Live`); the scheduler
    /// decides swap-vs-drop BEFORE the round-trip, from its mirrored
    /// block counts, so the decision stays deterministic.
    Evict { id: u64, swap: bool },
    /// step every owned session one decode token (stealing from other
    /// shards when the local deque runs dry), then report
    Step { tick: u64, report: StepReport },
    Shutdown,
}

/// Worker → scheduler replies (one shared channel). Every variant names
/// its sender so replies from a worker already declared dead — a zombie
/// waking from a stall, a straggler finishing after a barrier timeout —
/// are recognized and dropped instead of corrupting the protocol.
pub(crate) enum FromWorker {
    Evicted { worker: usize, live: Box<Live>, freed: Result<usize> },
    StepDone { worker: usize, report: StepReport },
}

/// A worker death observed by the runtime, handed to the scheduler for
/// recovery. `orphans` holds every session whose struct survived (shipped
/// by the backstop handler, or scavenged from the dead shard's steal
/// state); sessions lost with the thread must be rebuilt from the
/// scheduler's recovery ledger.
pub(crate) struct WorkerDeath {
    pub(crate) worker: usize,
    pub(crate) error: ServeError,
    pub(crate) orphans: Vec<Live>,
}

/// Cross-shard work stealing state: a deque + done-box per shard.
/// Per tick, each worker publishes its owned sessions into its deque,
/// pops them front-to-back, and — once dry — pops the *back* of the
/// most-loaded other deque. Every stepped session is pushed to its home
/// shard's done box, whose owner blocks until all of its sessions are
/// back, then re-sorts by id: arrival order on the done box is invisible.
struct StealState {
    deques: Vec<Mutex<VecDeque<Live>>>,
    /// advisory deque lengths for victim selection (the deque lock is
    /// the source of truth when actually popping)
    qlen: Vec<AtomicUsize>,
    done: Vec<(Mutex<Vec<Live>>, Condvar)>,
    /// set by the scheduler when it declares a worker dead: the worker
    /// must exit at its next checkpoint instead of touching shared
    /// state, and no one steals from its deque anymore
    dead: Vec<AtomicBool>,
}

impl StealState {
    fn new(shards: usize) -> StealState {
        StealState {
            deques: (0..shards).map(|_| Mutex::new(VecDeque::new())).collect(),
            qlen: (0..shards).map(|_| AtomicUsize::new(0)).collect(),
            done: (0..shards).map(|_| (Mutex::new(Vec::new()), Condvar::new())).collect(),
            dead: (0..shards).map(|_| AtomicBool::new(false)).collect(),
        }
    }

    fn shards(&self) -> usize {
        self.deques.len()
    }

    fn is_dead(&self, w: usize) -> bool {
        self.dead[w].load(Ordering::SeqCst)
    }

    /// Return a stepped session to its home shard's done box.
    fn finish(&self, live: Live) {
        let (lock, cv) = &self.done[live.home];
        sync::lock(lock).push(live);
        cv.notify_one();
    }
}

/// One supervised decode step. A panic inside the engine is caught HERE
/// — narrowly — so the steal protocol always completes: the session
/// still returns home (no cross-worker done-box deadlock) flagged
/// poisoned, and the scheduler quarantines + re-prefills it. A
/// streaming-paused session skips the step and keeps its old
/// `last_stepped`, so idle sessions age toward eviction.
fn step_one<M: TokenModel>(engine: &ServeEngine<M>, live: &mut Live, tick: u64) -> bool {
    if live.pause_this_tick() {
        return false;
    }
    live.last_stepped = tick;
    match catch_unwind(AssertUnwindSafe(|| engine.step(&mut live.session))) {
        Ok(emitted) => emitted.is_some(),
        Err(_) => {
            live.poisoned = true;
            false
        }
    }
}

/// The stealing step: publish owned sessions, drain own deque front to
/// back, then steal off the back of the most-loaded other live shard
/// (lowest index on qlen ties) until every deque this worker can see is
/// dry, and finally wait for all owned sessions to come home.
fn step_stealing<M: TokenModel>(
    w: usize,
    engine: &ServeEngine<M>,
    shared: &StealState,
    owned: &mut Vec<Live>,
    report: &mut StepReport,
    tick: u64,
) {
    let expected = owned.len();
    {
        let mut dq = sync::lock(&shared.deques[w]);
        dq.extend(owned.drain(..));
        shared.qlen[w].store(dq.len(), Ordering::SeqCst);
    }
    loop {
        // own work first
        let mine = {
            let mut dq = sync::lock(&shared.deques[w]);
            let live = dq.pop_front();
            shared.qlen[w].store(dq.len(), Ordering::SeqCst);
            live
        };
        if let Some(mut live) = mine {
            if step_one(engine, &mut live, tick) {
                report.steps += 1;
            }
            shared.finish(live);
            continue;
        }
        // own deque dry: pick the most-loaded other live shard (ties:
        // lowest index). Opportunistic — a shard that publishes after
        // this scan simply isn't stolen from this round; a dead shard's
        // stranded sessions belong to the scheduler's recovery, not to
        // thieves.
        let victim = shared
            .qlen
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != w && !shared.is_dead(i))
            .map(|(i, n)| (n.load(Ordering::SeqCst), i))
            .filter(|&(n, _)| n > 0)
            .max_by_key(|&(n, i)| (n, std::cmp::Reverse(i)))
            .map(|(_, i)| i);
        let Some(v) = victim else { break };
        let stolen = {
            let mut dq = sync::lock(&shared.deques[v]);
            let live = dq.pop_back();
            shared.qlen[v].store(dq.len(), Ordering::SeqCst);
            live
        };
        if let Some(mut live) = stolen {
            report.steals += 1;
            if step_one(engine, &mut live, tick) {
                report.steps += 1;
                report.stolen_steps += 1;
            }
            shared.finish(live);
        }
        // a raced-away pop rescans: qlen was refreshed under the lock
    }
    // collect every owned session back (stepped here or by thieves). The
    // wait wakes periodically to check the dead flag: if the scheduler
    // gave up on this worker (or on a thief holding one of its sessions)
    // it panics out to the backstop instead of blocking forever — that
    // is what keeps `Drop`'s join from hanging on a wedged barrier.
    let (lock, cv) = &shared.done[w];
    let mut done = sync::lock(lock);
    loop {
        owned.extend(done.drain(..));
        if owned.len() >= expected {
            break;
        }
        if shared.is_dead(w) {
            drop(done);
            panic!("worker {w} declared dead while waiting on its done box (tick {tick})");
        }
        done = cv
            .wait_timeout(done, Duration::from_millis(50))
            .unwrap_or_else(|e| e.into_inner())
            .0;
    }
    debug_assert_eq!(owned.len(), expected, "lost or duplicated a session");
}

/// Stringify a panic payload for the death report.
fn panic_text(payload: Box<dyn std::any::Any + Send>) -> String {
    match payload.downcast_ref::<&str>() {
        Some(s) => (*s).to_string(),
        None => match payload.downcast_ref::<String>() {
            Some(s) => s.clone(),
            None => "non-string panic payload".to_string(),
        },
    }
}

/// The worker's command loop. Panics unwind to the backstop in
/// [`run_worker`], which ships `owned` home — which is why `owned` lives
/// outside this function.
#[allow(clippy::too_many_arguments)]
fn worker_loop<M: TokenModel>(
    w: usize,
    engine: &ServeEngine<M>,
    rx: &Receiver<ToWorker>,
    tx: &Sender<FromWorker>,
    shared: &StealState,
    steal: bool,
    chaos: Option<&FaultPlan>,
    owned: &mut Vec<Live>,
) {
    let bounded = engine.pool_status().is_some_and(|p| p.capacity_blocks.is_some());
    while let Ok(msg) = rx.recv() {
        if shared.is_dead(w) {
            // declared dead while this command sat in the queue (e.g. a
            // stall outlived the barrier deadline): exit without touching
            // the steal state — our sessions were already rebuilt
            owned.clear();
            return;
        }
        match msg {
            ToWorker::Admit(live) => owned.push(*live),
            ToWorker::Evict { id, swap } => {
                let idx = owned
                    .iter()
                    .position(|l| l.id == id)
                    .expect("evict command for a session this worker does not own");
                // evict in place so a panicking eviction still leaves the
                // session in `owned` for the backstop to ship home
                let freed = if swap {
                    // swap-out = snapshot + evict; if the snapshot fails
                    // (non-paged backend, unknown pending) demote to a
                    // plain drop — the scheduler sees the missing image
                    // and counts the fallback
                    match engine.swap_out_session(&mut owned[idx].session) {
                        Ok((freed, image)) => {
                            owned[idx].swap = Some(image);
                            Ok(freed)
                        }
                        Err(_) => engine.evict_session(&mut owned[idx].session),
                    }
                } else {
                    engine.evict_session(&mut owned[idx].session)
                };
                let live = owned.remove(idx);
                let _ =
                    tx.send(FromWorker::Evicted { worker: w, live: Box::new(live), freed });
            }
            ToWorker::Step { tick, mut report } => {
                // chaos fires HERE — the safe point: nothing published to
                // the steal deques yet, every owned session intact, so an
                // injected panic exercises the real backstop + recovery
                // path without wedging other workers
                if let Some(fault) = chaos.and_then(|p| p.fault_for(w, tick)) {
                    match fault.kind {
                        FaultKind::Stall { millis } => {
                            std::thread::sleep(Duration::from_millis(millis));
                            if shared.is_dead(w) {
                                owned.clear();
                                return;
                            }
                        }
                        // slow-but-alive: lag (short of the barrier
                        // deadline), then step normally — thieves drain
                        // this shard's deque meanwhile, and no death may
                        // be declared
                        FaultKind::Slow { millis } => {
                            std::thread::sleep(Duration::from_millis(millis));
                        }
                        // poison the pool's RwLock mid-serve: every
                        // later access recovers through util::sync, so
                        // this must be a non-event
                        FaultKind::PoisonPool => engine.poison_pool_for_chaos(),
                        // swap-image corruption is applied scheduler-side
                        // (the images live on preempted sessions, which a
                        // worker never holds) — a no-op here, NOT a panic:
                        // the catchall below would kill the worker
                        FaultKind::SwapCorrupt => {}
                        kind => panic!("{}", chaos::panic_message(kind, w, tick)),
                    }
                }
                report.clear();
                report.owned = owned.len();
                let t0 = Instant::now();
                if steal && shared.shards() > 1 {
                    step_stealing(w, engine, shared, owned, &mut report, tick);
                } else {
                    for live in owned.iter_mut() {
                        if step_one(engine, live, tick) {
                            report.steps += 1;
                        }
                    }
                }
                report.busy_secs = t0.elapsed().as_secs_f64();
                // deterministic merge: id order, regardless of which
                // thread stepped what or when it came home
                owned.sort_by_key(|l| l.id);
                let mut i = 0;
                while i < owned.len() {
                    if owned[i].poisoned {
                        // its step panicked: hand it back for quarantine
                        report.orphans.push(owned.remove(i));
                    } else if owned[i].session.finished() {
                        report.finished.push(owned.remove(i));
                    } else {
                        i += 1;
                    }
                }
                for live in owned.iter() {
                    report.metas.push(SessionMeta {
                        id: live.id,
                        reserve: if bounded {
                            engine.remaining_reserve(&live.session)
                        } else {
                            0
                        },
                        freeable: engine.freeable_blocks(&live.session),
                        out_len: live.session.output().len(),
                        last_token: live.session.output().last().copied().unwrap_or(0),
                        last_stepped: live.last_stepped,
                        priority: live.priority,
                    });
                }
                if tx.send(FromWorker::StepDone { worker: w, report }).is_err() {
                    return; // scheduler gone
                }
            }
            ToWorker::Shutdown => return,
        }
    }
}

/// Worker thread body: the command loop wrapped in the backstop
/// `catch_unwind`. On a panic, one final report ships the panic message
/// and every still-held session back to the scheduler; on a clean exit,
/// sessions die here, releasing their pool blocks through the backend's
/// `Drop`.
fn run_worker<M: TokenModel + Send + Sync + 'static>(
    w: usize,
    engine: Arc<ServeEngine<M>>,
    rx: Receiver<ToWorker>,
    tx: Sender<FromWorker>,
    shared: Arc<StealState>,
    steal: bool,
    chaos: Option<FaultPlan>,
) {
    let mut owned: Vec<Live> = Vec::new();
    let res = catch_unwind(AssertUnwindSafe(|| {
        worker_loop(w, engine.as_ref(), &rx, &tx, shared.as_ref(), steal, chaos.as_ref(), &mut owned)
    }));
    if let Err(payload) = res {
        let report = StepReport {
            panic: Some(panic_text(payload)),
            orphans: std::mem::take(&mut owned),
            ..Default::default()
        };
        let _ = tx.send(FromWorker::StepDone { worker: w, report });
    }
}

/// Handle to the persistent worker fleet: per-worker bounded command
/// channels, the shared reply channel, and the recycled step-report
/// buffers. Worker faults surface as [`WorkerDeath`]s (drained via
/// `take_deaths`) instead of aborting; dead shards keep their slots but
/// accept no further commands. Dropping the handle closes every channel
/// and joins the workers.
pub(crate) struct DecodeRuntime {
    /// command senders; `None` = worker declared dead (closing the
    /// channel is what makes a stalled zombie drain and exit)
    to: Vec<Option<SyncSender<ToWorker>>>,
    from: Receiver<FromWorker>,
    handles: Vec<std::thread::JoinHandle<()>>,
    shared: Arc<StealState>,
    /// per-worker report buffers, round-tripped through the channels
    spare: Vec<Option<StepReport>>,
    /// outstanding sends per worker channel since the last barrier — an
    /// upper bound on actual queue depth, tracked for `queue_depth_hwm`
    depth: Vec<usize>,
    depth_hwm: Vec<usize>,
    /// scheduler-side view of `StealState::dead`
    dead: Vec<bool>,
    /// step-barrier reply bookkeeping, reused every tick
    awaiting: Vec<bool>,
    /// deaths observed but not yet handed to the scheduler
    deaths: Vec<WorkerDeath>,
    /// how long `step_all` waits for a worker's reply before declaring
    /// it dead (`None` = wait forever; panics still report immediately
    /// through the backstop — the deadline only catches stalls)
    deadline: Option<Duration>,
}

impl DecodeRuntime {
    pub(crate) fn spawn<M: TokenModel + Send + Sync + 'static>(
        engine: Arc<ServeEngine<M>>,
        workers: usize,
        steal: bool,
        pin: bool,
        chan_cap: usize,
        chaos: Option<FaultPlan>,
        barrier_deadline: Option<Duration>,
    ) -> DecodeRuntime {
        assert!(workers > 0);
        let shared = Arc::new(StealState::new(workers));
        let (from_tx, from_rx) = mpsc::channel();
        let ncores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut to = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = mpsc::sync_channel(chan_cap.max(2));
            let engine = engine.clone();
            let from = from_tx.clone();
            let shared = shared.clone();
            let chaos = chaos.clone();
            let handle = std::thread::Builder::new()
                .name(format!("moba-decode-{w}"))
                .spawn(move || {
                    if pin {
                        pin_current_thread(w % ncores);
                    }
                    run_worker(w, engine, rx, from, shared, steal, chaos);
                })
                .expect("spawn decode worker");
            to.push(Some(tx));
            handles.push(handle);
        }
        DecodeRuntime {
            to,
            from: from_rx,
            handles,
            shared,
            spare: (0..workers).map(|_| Some(StepReport::default())).collect(),
            depth: vec![0; workers],
            depth_hwm: vec![0; workers],
            dead: vec![false; workers],
            awaiting: vec![false; workers],
            deaths: Vec::new(),
            deadline: barrier_deadline,
        }
    }

    pub(crate) fn workers(&self) -> usize {
        self.to.len()
    }

    /// Whether worker `w` is still serving commands.
    pub(crate) fn alive(&self, w: usize) -> bool {
        !self.dead[w]
    }

    pub(crate) fn alive_count(&self) -> usize {
        self.dead.iter().filter(|&&d| !d).count()
    }

    /// Deaths observed since the last call — the scheduler's recovery
    /// input. Orphans carry every session struct the runtime could save.
    pub(crate) fn take_deaths(&mut self) -> Vec<WorkerDeath> {
        std::mem::take(&mut self.deaths)
    }

    /// Declare `worker` dead: close its channel (so a zombie drains and
    /// exits), raise the shared flag (so it exits at its next checkpoint
    /// and no one steals from it), scavenge intact sessions stranded in
    /// its steal state, and queue the death for the scheduler.
    fn mark_dead(&mut self, worker: usize, error: ServeError, mut orphans: Vec<Live>) {
        if std::mem::replace(&mut self.dead[worker], true) {
            // already dead — keep any late-surfacing structs for recovery
            if !orphans.is_empty() {
                match self.deaths.iter_mut().find(|d| d.worker == worker) {
                    Some(d) => d.orphans.append(&mut orphans),
                    None => self.deaths.push(WorkerDeath { worker, error, orphans }),
                }
            }
            return;
        }
        self.shared.dead[worker].store(true, Ordering::SeqCst);
        self.to[worker] = None;
        {
            let mut dq = sync::lock(&self.shared.deques[worker]);
            orphans.extend(dq.drain(..));
            self.shared.qlen[worker].store(0, Ordering::SeqCst);
        }
        orphans.extend(sync::lock(&self.shared.done[worker].0).drain(..));
        self.deaths.push(WorkerDeath { worker, error, orphans });
    }

    fn note_send(&mut self, shard: usize) {
        self.depth[shard] += 1;
        self.depth_hwm[shard] = self.depth_hwm[shard].max(self.depth[shard]);
    }

    /// Hand a session to its home shard. On failure (the worker died
    /// without the runtime noticing yet) the session comes back with the
    /// error so the caller can re-place it.
    pub(crate) fn admit(
        &mut self,
        shard: usize,
        live: Live,
    ) -> std::result::Result<(), Box<(Live, ServeError)>> {
        debug_assert_eq!(live.home, shard);
        let Some(tx) = &self.to[shard] else {
            return Err(Box::new((live, ServeError::WorkerDisconnected { worker: shard })));
        };
        let sent = tx.send(ToWorker::Admit(Box::new(live)));
        match sent {
            Ok(()) => {
                self.note_send(shard);
                Ok(())
            }
            Err(mpsc::SendError(msg)) => {
                let err = ServeError::WorkerDisconnected { worker: shard };
                self.mark_dead(shard, err.clone(), Vec::new());
                let ToWorker::Admit(live) = msg else {
                    unreachable!("admit send bounced a different message")
                };
                Err(Box::new((*live, err)))
            }
        }
    }

    /// Synchronous eviction round-trip: the identified session comes back
    /// with its pool blocks released (and, with `swap`, its private tail
    /// snapshotted onto `Live::swap`). Only called between step barriers,
    /// so the only other traffic possible on the reply channel is a
    /// death report or a zombie's stale reply — both handled here.
    pub(crate) fn evict(
        &mut self,
        shard: usize,
        id: u64,
        swap: bool,
    ) -> std::result::Result<(Live, Result<usize>), Box<ServeError>> {
        let Some(tx) = &self.to[shard] else {
            return Err(Box::new(ServeError::WorkerDisconnected { worker: shard }));
        };
        let sent = tx.send(ToWorker::Evict { id, swap });
        if sent.is_err() {
            let err = ServeError::WorkerDisconnected { worker: shard };
            self.mark_dead(shard, err.clone(), Vec::new());
            return Err(Box::new(err));
        }
        self.note_send(shard);
        loop {
            match self.from.recv() {
                Ok(FromWorker::Evicted { worker, live, freed }) => {
                    if self.dead[worker] {
                        continue; // zombie answering an old command: drop
                    }
                    debug_assert_eq!(worker, shard, "eviction reply from the wrong worker");
                    self.depth[shard] = 0;
                    return Ok((*live, freed));
                }
                Ok(FromWorker::StepDone { worker, mut report }) => {
                    if self.dead[worker] {
                        continue; // straggler finishing a timed-out barrier
                    }
                    if let Some(message) = report.panic.take() {
                        // a worker dying outside a barrier still sends one
                        // final report through its backstop
                        let orphans = std::mem::take(&mut report.orphans);
                        let err = ServeError::WorkerPanicked { worker, message };
                        self.mark_dead(worker, err.clone(), orphans);
                        self.spare[worker] = Some(report);
                        if worker == shard {
                            return Err(Box::new(err));
                        }
                        continue;
                    }
                    unreachable!("step reply on a quiet channel during eviction");
                }
                Err(_) => {
                    let err = ServeError::WorkerDisconnected { worker: shard };
                    self.mark_dead(shard, err.clone(), Vec::new());
                    return Err(Box::new(err));
                }
            }
        }
    }

    /// Step every live shard once and collect all reports — the per-tick
    /// barrier. Reports land back in `spare` (read them via
    /// `report_mut`); their buffers are reused next tick. Workers that
    /// report a panic, close their channel, or (with a configured
    /// deadline) fail to reply in time are declared dead; the deaths are
    /// queued for `take_deaths`, and the barrier completes with the
    /// survivors.
    pub(crate) fn step_all(&mut self, tick: u64) {
        let n = self.to.len();
        self.awaiting.fill(false);
        let mut expected = 0usize;
        for w in 0..n {
            if self.to[w].is_none() {
                continue;
            }
            let report = self.spare[w].take().unwrap_or_default();
            self.depth[w] += 1;
            self.depth_hwm[w] = self.depth_hwm[w].max(self.depth[w]);
            let sent = match &self.to[w] {
                Some(tx) => tx.send(ToWorker::Step { tick, report }).is_ok(),
                None => false,
            };
            if sent {
                self.awaiting[w] = true;
                expected += 1;
            } else {
                self.mark_dead(w, ServeError::WorkerDisconnected { worker: w }, Vec::new());
            }
        }
        let deadline = self.deadline.map(|d| Instant::now() + d);
        let mut received = 0usize;
        while received < expected {
            let msg = match deadline {
                Some(dl) => {
                    match self.from.recv_timeout(dl.saturating_duration_since(Instant::now())) {
                        Ok(m) => m,
                        Err(_) => break, // deadline passed (or all gone)
                    }
                }
                None => match self.from.recv() {
                    Ok(m) => m,
                    Err(_) => break, // every worker is gone
                },
            };
            match msg {
                FromWorker::StepDone { worker, mut report } => {
                    if self.dead[worker] || !self.awaiting[worker] {
                        continue; // zombie's late reply: drop it
                    }
                    self.awaiting[worker] = false;
                    received += 1;
                    if let Some(message) = report.panic.take() {
                        let orphans = std::mem::take(&mut report.orphans);
                        self.spare[worker] = Some(report);
                        self.mark_dead(
                            worker,
                            ServeError::WorkerPanicked { worker, message },
                            orphans,
                        );
                    } else {
                        self.spare[worker] = Some(report);
                    }
                }
                FromWorker::Evicted { worker, .. } => {
                    // only a zombie can reply to an eviction here; its
                    // session was already rebuilt from the ledger
                    debug_assert!(self.dead[worker], "stray eviction reply at the barrier");
                }
            }
        }
        if received < expected {
            // stragglers missed the barrier: stalled, wedged, or silently
            // gone. Their sessions are rebuilt from the scheduler ledger.
            let secs = self.deadline.map(|d| d.as_secs_f64()).unwrap_or(0.0);
            for w in 0..n {
                if std::mem::replace(&mut self.awaiting[w], false) && !self.dead[w] {
                    let error = if self.deadline.is_some() {
                        ServeError::BarrierTimeout { worker: w, tick, deadline_secs: secs }
                    } else {
                        ServeError::WorkerDisconnected { worker: w }
                    };
                    self.mark_dead(w, error, Vec::new());
                }
            }
        }
        for d in self.depth.iter_mut() {
            *d = 0;
        }
    }

    /// The report from the last `step_all` for worker `w` (`None` for a
    /// dead worker, whose final report was consumed by its death).
    pub(crate) fn report_mut(&mut self, w: usize) -> Option<&mut StepReport> {
        if self.dead[w] {
            return None;
        }
        self.spare[w].as_mut()
    }

    pub(crate) fn depth_hwm(&self, w: usize) -> usize {
        self.depth_hwm[w]
    }
}

impl Drop for DecodeRuntime {
    fn drop(&mut self) {
        // try_send: never block on a full channel to a stalled worker —
        // closing the channels below is what guarantees every worker
        // (including zombies) drains and exits
        for tx in self.to.iter().flatten() {
            let _ = tx.try_send(ToWorker::Shutdown);
        }
        self.to.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_kind_parses_and_labels() {
        assert_eq!(RuntimeKind::parse("tick").unwrap(), RuntimeKind::TickLoop);
        assert_eq!(RuntimeKind::parse("tick-loop").unwrap(), RuntimeKind::TickLoop);
        assert_eq!(RuntimeKind::parse("persistent").unwrap(), RuntimeKind::Persistent);
        assert_eq!(RuntimeKind::parse("tpc").unwrap(), RuntimeKind::Persistent);
        assert!(RuntimeKind::parse("bogus").is_err());
        assert_eq!(RuntimeKind::TickLoop.label(), "tick-loop");
        assert_eq!(RuntimeKind::Persistent.label(), "persistent");
    }

    #[test]
    fn pin_current_thread_is_safe_to_call() {
        // pin to core 0 (must exist); success depends on the platform,
        // but the call must never crash or corrupt anything
        let ok = pin_current_thread(0);
        if pin_supported() {
            assert!(ok, "pinning to core 0 should succeed on linux/x86_64");
        }
        assert!(!pin_current_thread(64), "cores >= 64 are out of mask range");
    }

    #[test]
    fn env_flag_semantics() {
        // defaults hold when unset (the suite does not set these vars)
        assert!(steal_from_env() || std::env::var("MOBA_STEAL").is_ok());
        assert!(pin_from_env() || std::env::var("MOBA_PIN").is_ok());
    }

    #[test]
    fn strict_flag_parsing_rejects_typos_with_context() {
        assert_eq!(parse_flag("MOBA_STEAL", None), Ok(None));
        for on in ["1", "true", "on", "yes", " on "] {
            assert_eq!(parse_flag("MOBA_STEAL", Some(on.into())), Ok(Some(true)), "{on}");
        }
        for off in ["0", "false", "off", "no"] {
            assert_eq!(parse_flag("MOBA_PIN", Some(off.into())), Ok(Some(false)), "{off}");
        }
        // the lenient env_flag would read "offf" as ON; strict refuses
        let err = parse_flag("MOBA_STEAL", Some("offf".into())).unwrap_err();
        assert!(err.contains("MOBA_STEAL") && err.contains("offf"), "{err}");
    }

    #[test]
    fn panic_text_handles_all_payload_shapes() {
        let s = catch_unwind(|| panic!("static message")).unwrap_err();
        assert_eq!(panic_text(s), "static message");
        let owned = catch_unwind(|| panic!("formatted {}", 7)).unwrap_err();
        assert_eq!(panic_text(owned), "formatted 7");
        let odd = catch_unwind(|| std::panic::panic_any(42i32)).unwrap_err();
        assert_eq!(panic_text(odd), "non-string panic payload");
    }
}
