//! Deterministic chaos injection for the persistent decode runtime.
//!
//! A `FaultPlan` is a seeded, pre-computed schedule of faults — "panic
//! worker 2 at tick 7", "stall worker 0's step for 40ms at tick 3",
//! "fail worker 1's next pool allocation at tick 5" — injected into the
//! worker step loop through `SchedulerCfg::chaos`. Faults fire at a
//! *safe point* (the top of `Step` command handling, before the steal
//! protocol publishes any session), so an injected panic exercises the
//! real supervision path: the worker's backstop `catch_unwind` ships its
//! owned sessions back in the final `StepReport` and the scheduler
//! re-homes them through eviction/resume.
//!
//! Plans are plain data (`Clone + Debug`), independent of wall-clock and
//! thread scheduling, so a chaos run is reproducible from
//! `(MOBA_CHAOS_SEED, worker count, horizon)` alone. The tick-loop
//! runtime ignores chaos entirely — it is the fault-free oracle the
//! chaos tests compare served tokens against.

use crate::util::rng::Rng;

/// What a fault does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic the worker's step loop (caught by the backstop handler;
    /// the worker reports the panic, ships its sessions home and
    /// exits).
    Panic,
    /// Simulate a failed pool allocation: the worker panics with the
    /// paged pool's exhaustion message, exercising the same death path
    /// as a real allocator bug.
    AllocFail,
    /// Stall the worker for `millis` before it processes the step. With
    /// a stall longer than `SchedulerCfg::barrier_deadline_secs` the
    /// supervisor declares the worker dead and the zombie later drains
    /// and exits on its own.
    Stall { millis: u64 },
    /// A slow-but-alive worker: sleep `millis` (intended to stay well
    /// under `barrier_deadline_secs`), then step normally. The lag
    /// interleaves with work stealing — other workers drain the slow
    /// shard's deque — and must never trip spurious death detection.
    Slow { millis: u64 },
    /// Poison the shared paged pool's `RwLock` (a throwaway thread
    /// panics while holding the write guard). Every later pool access
    /// goes through `util::sync`'s poison-recovering helpers, so serving
    /// must continue as if nothing happened. No-op for unpooled
    /// backends.
    PoisonPool,
    /// Corrupt a preempted session's host-tier swap image (flip its
    /// checksum), as if the cold copy rotted while offloaded. The next
    /// swap-in fails its checksum verification and the scheduler falls
    /// back to a re-prefill resume transparently — served tokens must
    /// still match the fault-free oracle. Applied scheduler-side (the
    /// images live on preempted sessions, not on workers); a no-op when
    /// nothing is swapped out. Survivable by design.
    SwapCorrupt,
}

/// One scheduled fault: `kind` fires on worker `worker` at tick `tick`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fault {
    pub worker: usize,
    pub tick: u64,
    pub kind: FaultKind,
}

impl Fault {
    /// Fatal faults permanently remove the worker (Panic/AllocFail, and
    /// Stall once the supervisor gives up on the barrier). Slow and
    /// PoisonPool are survivable by design and never count as fatal.
    pub fn is_fatal(&self) -> bool {
        matches!(self.kind, FaultKind::Panic | FaultKind::AllocFail)
    }
}

/// A deterministic schedule of faults for one serving run.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    faults: Vec<Fault>,
}

impl FaultPlan {
    /// An explicit plan (tests name exact faults).
    pub fn new(faults: Vec<Fault>) -> Self {
        FaultPlan { faults }
    }

    /// A seeded random plan over `workers` workers and `horizon` ticks.
    /// At most `workers - 1` distinct workers receive a *fatal* fault,
    /// so the scheduler always keeps at least one live shard and every
    /// request still finishes; stalls may hit any worker. Fault count
    /// scales gently with the grid so small runs see 1-3 faults.
    pub fn seeded(seed: u64, workers: usize, horizon: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xC4A0_5CA0_DEAD_BEEF);
        let mut faults = Vec::new();
        if workers == 0 || horizon == 0 {
            return FaultPlan { faults };
        }
        let n = 1 + rng.range(0, 3);
        let mut fatal_workers: Vec<usize> = Vec::new();
        for _ in 0..n {
            let tick = rng.below(horizon);
            let kind = match rng.range(0, 7) {
                0 => FaultKind::Panic,
                1 => FaultKind::AllocFail,
                2 | 3 => FaultKind::Stall { millis: 5 + rng.below(40) },
                4 => FaultKind::Slow { millis: 1 + rng.below(10) },
                5 => FaultKind::SwapCorrupt,
                _ => FaultKind::PoisonPool,
            };
            let worker = rng.range(0, workers);
            let fatal = matches!(kind, FaultKind::Panic | FaultKind::AllocFail);
            if fatal {
                // keep at least one worker alive across the whole plan
                if !fatal_workers.contains(&worker) && fatal_workers.len() + 1 >= workers {
                    continue;
                }
                if !fatal_workers.contains(&worker) {
                    fatal_workers.push(worker);
                }
            }
            faults.push(Fault { worker, tick, kind });
        }
        FaultPlan { faults }
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// The first fault scheduled for `(worker, tick)`, if any.
    pub fn fault_for(&self, worker: usize, tick: u64) -> Option<Fault> {
        self.faults.iter().copied().find(|f| f.worker == worker && f.tick == tick)
    }

    /// How many distinct workers this plan kills outright.
    pub fn fatal_workers(&self) -> usize {
        let mut seen: Vec<usize> = Vec::new();
        for f in &self.faults {
            if f.is_fatal() && !seen.contains(&f.worker) {
                seen.push(f.worker);
            }
        }
        seen.len()
    }
}

/// The panic message an injected fault raises — tests and the demo can
/// recognize injected faults in `ServeError::WorkerPanicked::message`.
pub fn panic_message(kind: FaultKind, worker: usize, tick: u64) -> String {
    match kind {
        FaultKind::Panic => format!("chaos: injected panic on worker {worker} at tick {tick}"),
        FaultKind::AllocFail => {
            format!("chaos: injected pool allocation failure on worker {worker} at tick {tick}")
        }
        FaultKind::Stall { millis } => {
            format!("chaos: injected {millis}ms stall on worker {worker} at tick {tick}")
        }
        FaultKind::Slow { millis } => {
            format!("chaos: injected {millis}ms slowdown on worker {worker} at tick {tick}")
        }
        FaultKind::PoisonPool => {
            format!("chaos: injected pool-lock poisoning on worker {worker} at tick {tick}")
        }
        FaultKind::SwapCorrupt => {
            format!("chaos: injected swap-image corruption on worker {worker} at tick {tick}")
        }
    }
}

/// Chaos seed from `MOBA_CHAOS_SEED` (unset or unparsable → no chaos).
/// Library default stays lenient; the CLI boundary validates through
/// [`parse_seed`] so a typo fails loudly instead.
pub fn seed_from_env() -> Option<u64> {
    std::env::var("MOBA_CHAOS_SEED").ok().and_then(|v| v.trim().parse().ok())
}

/// Strict `MOBA_CHAOS_SEED` parser (the `parse_workers` pattern): unset
/// is fine, but a set-and-unparsable value is a contextful error rather
/// than silently running without chaos.
pub fn parse_seed(raw: Option<String>) -> Result<Option<u64>, String> {
    match raw {
        None => Ok(None),
        Some(v) => match v.trim().parse::<u64>() {
            Ok(seed) => Ok(Some(seed)),
            Err(_) => Err(format!("MOBA_CHAOS_SEED must be a non-negative integer, got {v:?}")),
        },
    }
}

/// Strict env read for the CLI boundary.
pub fn seed_from_env_strict() -> Result<Option<u64>, String> {
    parse_seed(std::env::var("MOBA_CHAOS_SEED").ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_deterministic() {
        let a = FaultPlan::seeded(42, 4, 50);
        let b = FaultPlan::seeded(42, 4, 50);
        assert_eq!(a.faults(), b.faults());
        assert!(
            !(FaultPlan::seeded(42, 4, 50).is_empty() && FaultPlan::seeded(43, 4, 50).is_empty()),
            "two seeds should not both be empty"
        );
    }

    #[test]
    fn seeded_plans_spare_one_worker() {
        for seed in 0..200u64 {
            for workers in 1..5usize {
                let plan = FaultPlan::seeded(seed, workers, 40);
                assert!(
                    plan.fatal_workers() < workers.max(1),
                    "seed={seed} workers={workers} kills everyone: {:?}",
                    plan.faults()
                );
            }
        }
    }

    #[test]
    fn fault_lookup_matches_worker_and_tick() {
        let f = Fault { worker: 1, tick: 3, kind: FaultKind::Panic };
        let plan = FaultPlan::new(vec![f]);
        assert_eq!(plan.fault_for(1, 3), Some(f));
        assert_eq!(plan.fault_for(1, 4), None);
        assert_eq!(plan.fault_for(0, 3), None);
        assert!(f.is_fatal());
        assert!(!Fault { worker: 0, tick: 0, kind: FaultKind::Stall { millis: 5 } }.is_fatal());
        assert!(!Fault { worker: 0, tick: 0, kind: FaultKind::Slow { millis: 5 } }.is_fatal());
        assert!(!Fault { worker: 0, tick: 0, kind: FaultKind::PoisonPool }.is_fatal());
        assert!(!Fault { worker: 0, tick: 0, kind: FaultKind::SwapCorrupt }.is_fatal());
    }

    #[test]
    fn panic_messages_are_recognizable() {
        assert!(panic_message(FaultKind::Panic, 2, 9).contains("chaos"));
        assert!(panic_message(FaultKind::AllocFail, 0, 1).contains("allocation"));
        assert!(panic_message(FaultKind::Stall { millis: 7 }, 1, 2).contains("7ms"));
        assert!(panic_message(FaultKind::Slow { millis: 3 }, 1, 2).contains("slowdown"));
        assert!(panic_message(FaultKind::PoisonPool, 1, 2).contains("poison"));
        assert!(panic_message(FaultKind::SwapCorrupt, 1, 2).contains("swap-image"));
    }

    #[test]
    fn strict_seed_parsing_rejects_typos_with_context() {
        assert_eq!(parse_seed(None), Ok(None));
        assert_eq!(parse_seed(Some(" 42 ".into())), Ok(Some(42)));
        let err = parse_seed(Some("4o4".into())).unwrap_err();
        assert!(err.contains("MOBA_CHAOS_SEED") && err.contains("4o4"), "{err}");
    }
}
