//! Generation engine over pluggable attention backends — prefill once,
//! then incremental decode against the KV/block-pool caches.
//!
//! The old caveat ("decode is recompute-based, no KV cache") is gone:
//! each request owns a [`DecodeSession`] whose backend ingests the prompt
//! once (`AttentionBackend::prefill`, MoBA block-sparse by default — the
//! paper's prefill mode) and then appends one token per decode step
//! (`AttentionBackend::decode`). With the default
//! `BackendKind::CachedSparse` a decode step costs O(N/B·D) gating +
//! O(k·B·D) attention instead of the old O(N²) whole-graph recompute;
//! `BackendKind::CachedFull` gives the paper's §3.3 full-attention-decode
//! deployment mode at O(N·D) per token. The recompute kinds (`full`,
//! `moba`) remain selectable as baselines — same API, same outputs,
//! bit-for-bit (see `sparse/README.md`).
//!
//! Sessions are independent and stepped one token at a time, which is
//! what lets `serve::scheduler` interleave many requests in a continuous
//! batch. The model behind the projections is abstracted as
//! [`TokenModel`]; the artifact/PJRT path lives in `serve::artifact`
//! behind the `xla` feature.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::sparse::{
    build_backend_par, shared_pool, AttentionBackend, BackendKind, PagedMobaAttention,
    SharedKvPool, SwapImage,
};
use crate::tensor::Tensor;
use crate::util::sync;

use super::error::ServeError;
use super::model::TokenModel;

/// Per-request serving statistics.
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub decode_steps: usize,
    /// times this session was evicted and rebuilt via re-prefill
    pub resumes: usize,
    /// wall-clock seconds spent re-prefilling after evictions
    pub reprefill_secs: f64,
}

/// Serving configuration: attention geometry + backend selection.
#[derive(Clone, Debug)]
pub struct ServeCfg {
    pub block_size: usize,
    pub topk: usize,
    pub max_seq: usize,
    pub backend: BackendKind,
    /// Intra-request kernel threads for prefill row partitioning (see
    /// `sparse::parallel`). Outputs are bit-identical for every value.
    /// 1 = serial. Decode steps always run inline — per-token work is far
    /// below spawn cost; inter-request decode parallelism belongs to the
    /// scheduler's decode shards instead.
    pub workers: usize,
    /// Physical-block capacity of the shared paged KV pool (only
    /// meaningful with `backend == BackendKind::Paged`; every paged
    /// session of this engine allocates from one pool). 0 = unbounded.
    pub pool_blocks: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            block_size: 64,
            topk: 3,
            max_seq: 4096,
            backend: BackendKind::CachedSparse,
            workers: 1,
            pool_blocks: 0,
        }
    }
}

/// Occupancy snapshot of the engine's shared paged pool.
#[derive(Clone, Copy, Debug)]
pub struct PoolStatus {
    /// physical blocks currently referenced by at least one session
    pub used_blocks: usize,
    /// allocation ceiling (`None` = unbounded)
    pub capacity_blocks: Option<usize>,
    /// unique K/V payload bytes resident in the pool
    pub payload_bytes: usize,
}

/// One in-flight request: its backend state (caches), token history and
/// latency accounting. Created by `ServeEngine::start` (prefill), then
/// advanced one token per `ServeEngine::step`.
pub struct DecodeSession {
    backend: Box<dyn AttentionBackend>,
    prompt_len: usize,
    /// the tokens THIS session ingested itself (the whole prompt, or just
    /// the continuation for a forked session) — together with `generated`
    /// this is exactly the state a transparent re-prefill resume needs
    own_prompt: Vec<i32>,
    /// context length at fork time (0 = not forked): re-prefill of a
    /// forked session re-forks its prefix parent instead of starting cold
    fork_ctx: usize,
    /// blocks released back to the pool; must be resumed before stepping
    evicted: bool,
    max_seq: usize,
    max_new: usize,
    /// next token to emit (argmax of the last computed logits). `None`
    /// for an adopted or quarantined session rebuilt after a worker
    /// fault, where the last-computed logits died with the worker:
    /// `resume_session` recomputes the real value from the transcript
    /// (there is nothing to compare against, but the recomputed token IS
    /// the one a fault-free run would hold — it is a pure function of
    /// the re-ingested tokens). An `Option` instead of a sentinel value,
    /// so unknown-ness can never be confused with a real token.
    pending: Option<i32>,
    generated: Vec<i32>,
    /// MoBA top-k this session's backend gates with — normally
    /// `ServeCfg::topk`, downshifted by the scheduler's pressure dial
    /// for degraded low-priority sessions. Carried on the session so
    /// evict/resume/adopt rebuild the backend with the SAME sparsity
    /// (a degraded session must stay self-consistent across re-prefill).
    topk: usize,
    pub stats: GenStats,
}

impl DecodeSession {
    pub fn finished(&self) -> bool {
        self.generated.len() >= self.max_new
            || self.prompt_len + self.generated.len() >= self.max_seq
    }

    pub fn output(&self) -> &[i32] {
        &self.generated
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    /// Tokens currently resident in the backend's incremental state.
    pub fn context_len(&self) -> usize {
        self.backend.seq_len()
    }

    /// True between `ServeEngine::evict_session` and `resume_session`:
    /// the session's pool blocks are released and it must not be stepped.
    pub fn evicted(&self) -> bool {
        self.evicted
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The tokens this session ingested itself (whole prompt, or the
    /// post-fork continuation) — what a recovery ledger must mirror to
    /// rebuild the session if its worker dies with the struct.
    pub fn own_prompt(&self) -> &[i32] {
        &self.own_prompt
    }

    /// Context length at fork time (0 = not forked).
    pub fn fork_ctx(&self) -> usize {
        self.fork_ctx
    }

    pub fn max_new(&self) -> usize {
        self.max_new
    }

    /// The MoBA top-k this session gates with (see the `topk` field).
    pub fn topk(&self) -> usize {
        self.topk
    }

    /// False after a fault wiped the pending token (quarantine with
    /// `pending_valid == false`, or adoption from a ledger transcript):
    /// only a re-prefill resume can recompute it, so a swap-in — which
    /// restores cached state but computes no logits — must not be used.
    pub fn pending_known(&self) -> bool {
        self.pending.is_some()
    }

    /// Tag this session's future pool allocations with its decode
    /// shard's arena (paged backend; a locality no-op elsewhere). Never
    /// changes any served token — block ids are invisible to the math.
    pub fn set_arena(&mut self, arena: usize) {
        self.backend.set_arena(arena);
    }
}

fn argmax(xs: &[f32]) -> i32 {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

/// Backend-based generation engine. Stateless across requests — every
/// request gets a fresh backend in its session — except for the paged
/// backend, whose sessions all allocate from one shared copy-on-write
/// pool (which is what makes prefix sharing across requests possible).
pub struct ServeEngine<M: TokenModel> {
    model: M,
    cfg: ServeCfg,
    /// the shared pool, present iff `cfg.backend == BackendKind::Paged`
    pool: Option<SharedKvPool>,
}

impl<M: TokenModel> ServeEngine<M> {
    pub fn new(model: M, cfg: ServeCfg) -> ServeEngine<M> {
        let pool = (cfg.backend == BackendKind::Paged).then(|| {
            let cap = (cfg.pool_blocks > 0).then_some(cfg.pool_blocks);
            shared_pool(cfg.block_size, model.heads(), model.head_dim(), cap)
        });
        ServeEngine { model, cfg, pool }
    }

    pub fn cfg(&self) -> &ServeCfg {
        &self.cfg
    }

    pub fn model(&self) -> &M {
        &self.model
    }

    /// Occupancy of the shared paged pool (`None` for private-cache
    /// backends) — what the continuous scheduler admits against.
    pub fn pool_status(&self) -> Option<PoolStatus> {
        self.pool.as_ref().map(|pool| {
            // poison-resistant: a worker panicking mid-allocation must not
            // take the whole scheduler's pool accounting down with it
            let p = sync::read(pool);
            PoolStatus {
                used_blocks: p.used_blocks(),
                capacity_blocks: p.capacity_blocks(),
                payload_bytes: p.payload_bytes(),
            }
        })
    }

    /// Worst-case physical blocks a session forked at context length
    /// `ctx` can allocate while appending `tokens` more: the blocks
    /// spanning `[ctx, ctx + tokens)`. This is exact — when the session
    /// shares a partial tail, the copy-on-write duplicate *is* the first
    /// spanned block, not an extra one. Zero tokens allocate nothing.
    pub fn block_reserve(&self, ctx: usize, tokens: usize) -> usize {
        if tokens == 0 {
            return 0;
        }
        let b = self.cfg.block_size;
        (ctx % b + tokens + b - 1) / b
    }

    /// Decode steps this session will still run that APPEND a token: it
    /// emits until budget/max_seq, and the final emission is never
    /// appended (no successor is computed).
    fn appends_left(&self, s: &DecodeSession) -> usize {
        if s.finished() {
            return 0;
        }
        let emitted = s.generated.len();
        let budget = s.max_new - emitted;
        let seq_room = s.max_seq.saturating_sub(s.prompt_len + emitted);
        budget.min(seq_room).saturating_sub(1)
    }

    /// Pool blocks a LIVE session's remaining decode steps can still
    /// allocate beyond what it already holds — the not-yet-materialized
    /// delta of its admission reservation. Shrinks to 0 as the session
    /// fills its tail / finishes, which is what lets the scheduler admit
    /// into the freed headroom instead of holding the admission-time
    /// worst case for the whole session lifetime.
    pub fn remaining_reserve(&self, s: &DecodeSession) -> usize {
        let appends = self.appends_left(s);
        if appends == 0 {
            return 0;
        }
        let ctx = s.backend.seq_len();
        let b = self.cfg.block_size;
        if s.fork_ctx == 0 || ctx > s.fork_ctx {
            // the session owns its tail block: open slots absorb appends
            // without allocating (already counted in pool used_blocks)
            let slots = (b - ctx % b) % b;
            (appends.saturating_sub(slots) + b - 1) / b
        } else {
            // still exactly the forked prefix: the first append must CoW
            // a shared partial tail (or open a fresh block)
            self.block_reserve(ctx, appends)
        }
    }

    /// Worst-case pool blocks an EVICTED session needs to resume and run
    /// to completion: re-materializing its own tokens plus the same
    /// future appends `remaining_reserve` would cover.
    pub fn resume_reserve(&self, s: &DecodeSession) -> usize {
        let own = s.own_prompt.len() + s.generated.len();
        self.block_reserve(s.fork_ctx, own + self.appends_left(s))
    }

    /// Physical blocks evicting `s` would actually reclaim: the blocks
    /// spanning its own tokens, including its copy-on-write duplicate of
    /// a shared partial prefix tail. Blocks fully inside the forked
    /// prefix are shared with the prefix parent and survive; a fork that
    /// has not yet appended anything of its own frees nothing. Exact for
    /// serving sessions, which only ever fork off the engine's shared
    /// prefix (never off each other) — the scheduler's eviction
    /// feasibility check relies on this.
    pub fn freeable_blocks(&self, s: &DecodeSession) -> usize {
        let ctx = s.backend.seq_len();
        if ctx <= s.fork_ctx {
            return 0;
        }
        let b = self.cfg.block_size;
        (ctx + b - 1) / b - s.fork_ctx / b
    }

    /// A fresh backend for one session — paged sessions share THE engine
    /// pool (that is what makes cross-request prefix sharing work),
    /// everything else builds private caches. `topk` is normally
    /// `ServeCfg::topk`; the scheduler's pressure dial passes a smaller
    /// value for degraded low-priority sessions.
    fn fresh_backend_with(&self, topk: usize) -> Box<dyn AttentionBackend> {
        let workers = self.cfg.workers.max(1);
        match &self.pool {
            Some(pool) => {
                Box::new(PagedMobaAttention::new(pool.clone(), topk).with_workers(workers))
            }
            None => build_backend_par(
                self.cfg.backend,
                self.model.heads(),
                self.model.head_dim(),
                self.cfg.block_size,
                topk,
                workers,
            ),
        }
    }

    /// Chaos hook (`FaultKind::PoisonPool`): poison the shared pool's
    /// `RwLock` by panicking a throwaway thread while it holds the write
    /// guard. Every pool access in the serving stack goes through
    /// `util::sync`'s poison-recovering helpers, so this must be
    /// survivable end to end — the chaos tests assert serving continues
    /// bit-identically. No-op for unpooled backends.
    pub fn poison_pool_for_chaos(&self) {
        if let Some(pool) = &self.pool {
            let pool = pool.clone();
            let t = std::thread::spawn(move || {
                let _guard = sync::write(&pool);
                panic!("chaos: poisoning the paged pool lock");
            });
            let _ = t.join(); // the Err is the point
        }
    }

    /// Prefill `tokens` at positions `0..n` through `backend` and return
    /// the pending next token. Shared by `start` and non-forked resume so
    /// a resumed session goes through the exact same path (bit-identical
    /// outputs) as one that was never evicted.
    fn prefill_tokens(&self, backend: &mut dyn AttentionBackend, tokens: &[i32]) -> Result<i32> {
        let (h, d) = (self.model.heads(), self.model.head_dim());
        let n = tokens.len();
        let w = h * d;
        let (mut qs, mut ks, mut vs) =
            (Vec::with_capacity(n * w), Vec::with_capacity(n * w), Vec::with_capacity(n * w));
        for (pos, &tok) in tokens.iter().enumerate() {
            let (q, k, v) = self.model.qkv(tok, pos);
            qs.extend_from_slice(&q);
            ks.extend_from_slice(&k);
            vs.extend_from_slice(&v);
        }
        let q = Tensor::from_vec(&[n, h, d], qs)?;
        let k = Tensor::from_vec(&[n, h, d], ks)?;
        let v = Tensor::from_vec(&[n, h, d], vs)?;
        let out = backend.prefill(&q, &k, &v);
        Ok(argmax(&self.model.logits(&out.data[(n - 1) * w..n * w])))
    }

    /// Fork `parent`'s backend and ingest `tokens` one decode row at a
    /// time (positions continue from the parent's context). Returns the
    /// forked backend and the pending next token. Shared by
    /// `fork_session` and forked-session resume.
    fn fork_ingest(
        &self,
        parent: &DecodeSession,
        tokens: &[i32],
    ) -> Result<(Box<dyn AttentionBackend>, i32)> {
        let ctx = parent.backend.seq_len();
        let mut backend = parent.backend.fork()?;
        let mut last_out = None;
        for (i, &tok) in tokens.iter().enumerate() {
            let (q, k, v) = self.model.qkv(tok, ctx + i);
            last_out = Some(backend.decode(&q, &k, &v));
        }
        // only the final position's logits decide the pending token — an
        // empty continuation is a pure clone of the parent's
        let pending = match last_out {
            Some(out) => argmax(&self.model.logits(&out)),
            None => match parent.pending {
                Some(p) => p,
                None => bail!("empty-continuation fork of a session with no pending token"),
            },
        };
        Ok((backend, pending))
    }

    /// Prefill `prompt` through a fresh backend and return the live
    /// session with its first pending token.
    pub fn start(&self, prompt: &[i32], max_new: usize) -> Result<DecodeSession> {
        self.start_with_topk(prompt, max_new, self.cfg.topk)
    }

    /// `start` with an explicit MoBA top-k — the degradation-dial entry
    /// point. The session remembers `topk`, so later evict/resume cycles
    /// rebuild it with the same sparsity.
    pub fn start_with_topk(
        &self,
        prompt: &[i32],
        max_new: usize,
        topk: usize,
    ) -> Result<DecodeSession> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() + max_new > self.cfg.max_seq {
            bail!(
                "prompt {} + max_new {} exceeds max_seq {}",
                prompt.len(),
                max_new,
                self.cfg.max_seq
            );
        }
        let mut backend = self.fresh_backend_with(topk);
        let t0 = Instant::now();
        let pending = self.prefill_tokens(backend.as_mut(), prompt)?;
        let stats = GenStats { prefill_secs: t0.elapsed().as_secs_f64(), ..Default::default() };

        Ok(DecodeSession {
            backend,
            prompt_len: prompt.len(),
            own_prompt: prompt.to_vec(),
            fork_ctx: 0,
            evicted: false,
            max_seq: self.cfg.max_seq,
            max_new,
            pending: Some(pending),
            generated: Vec::with_capacity(max_new),
            topk,
            stats,
        })
    }

    /// Fork `parent`'s state copy-on-write (paged backend only) and
    /// ingest `continuation` on the fork — the shared-system-prompt
    /// serving scenario: S sessions share one physical prefix, each pays
    /// only its own divergent tail. Token-identical to
    /// `start(prefix ++ continuation)` on a private backend: the decode
    /// rows that ingest the continuation are bit-equal to the prefill
    /// rows a private session would compute (the prefill/decode boundary
    /// is invisible — `tests/property_invariants.rs`).
    pub fn fork_session(
        &self,
        parent: &DecodeSession,
        continuation: &[i32],
        max_new: usize,
    ) -> Result<DecodeSession> {
        let ctx = parent.backend.seq_len();
        if ctx + continuation.len() + max_new > self.cfg.max_seq {
            bail!(
                "prefix {} + continuation {} + max_new {} exceeds max_seq {}",
                ctx,
                continuation.len(),
                max_new,
                self.cfg.max_seq
            );
        }
        let t0 = Instant::now();
        let (backend, pending) = self.fork_ingest(parent, continuation)?;
        let stats = GenStats { prefill_secs: t0.elapsed().as_secs_f64(), ..Default::default() };
        Ok(DecodeSession {
            backend,
            prompt_len: ctx + continuation.len(),
            own_prompt: continuation.to_vec(),
            fork_ctx: ctx,
            evicted: false,
            max_seq: self.cfg.max_seq,
            max_new,
            pending: Some(pending),
            generated: Vec::with_capacity(max_new),
            // the forked backend IS a fork of the parent's gating state, so
            // the fork inherits the parent's sparsity, not `cfg.topk`
            topk: parent.topk,
            stats,
        })
    }

    /// Preempt `s`: release its pool blocks back to the shared paged pool
    /// and return how many were actually reclaimed (blocks a live table
    /// still shares — a system prefix under other sessions — survive).
    /// The session keeps its prompt, generated tokens and pending token,
    /// which is exactly enough for `resume_session` to rebuild it
    /// bit-identically. Paged backend only.
    pub fn evict_session(&self, s: &mut DecodeSession) -> Result<usize> {
        if s.evicted {
            bail!("session is already evicted");
        }
        let freed = s.backend.evict()?;
        s.evicted = true;
        Ok(freed)
    }

    /// Preempt `s` into the host swap tier: snapshot its private tail —
    /// every block from the fork point on (for an unforked session, the
    /// whole context) — into a byte-exact, checksummed [`SwapImage`],
    /// then release its pool blocks exactly like `evict_session`. The
    /// refcounted shared prefix is NOT captured: it stays resident under
    /// the prefix parent, so a swapped fork resumes via `fork_prefix` +
    /// block restore with no `fork_ingest` recompute. Returns
    /// `(blocks freed, image)`. Paged backend only; the caller owns the
    /// image (the engine is stateless across requests).
    pub fn swap_out_session(&self, s: &mut DecodeSession) -> Result<(usize, SwapImage)> {
        if s.evicted {
            bail!("swap-out of a session that is already evicted");
        }
        if s.pending.is_none() {
            bail!("swap-out of a session with no pending token");
        }
        let from_block = s.fork_ctx / self.cfg.block_size;
        let image = s.backend.swap_out(from_block)?;
        let freed = s.backend.evict()?;
        s.evicted = true;
        Ok((freed, image))
    }

    /// Resume a swapped-out session by restoring its [`SwapImage`] bytes
    /// into freshly allocated pool blocks instead of re-prefilling — the
    /// restored state is byte-identical to the pre-swap state, so every
    /// token served afterwards is bit-identical to a session that was
    /// never preempted. A forked session re-forks `parent`'s resident
    /// full-block prefix (`fork_prefix`); the restore then allocates
    /// exactly the blocks a re-prefill resume would, so pool occupancy —
    /// and every downstream scheduling decision — is identical between
    /// the two resume paths. On ANY failure (checksum mismatch, prefix
    /// mismatch, allocation failure) the session is left evicted with
    /// its transcript intact, so the caller can fall back to
    /// `resume_session` transparently.
    pub fn swap_in_session(
        &self,
        s: &mut DecodeSession,
        parent: Option<&DecodeSession>,
        image: &SwapImage,
    ) -> Result<()> {
        if !s.evicted {
            bail!("swap-in of a session that was never evicted");
        }
        if s.pending.is_none() {
            // restore rebuilds cached state but computes no logits: a
            // session whose pending token died with its worker can only
            // come back through the re-prefill path
            bail!("swap-in of a session with no pending token");
        }
        let mut backend = if s.fork_ctx > 0 {
            let Some(parent) = parent else {
                bail!("swap-in of a forked session needs its prefix parent");
            };
            if parent.backend.seq_len() != s.fork_ctx {
                bail!(
                    "prefix parent context {} does not match fork point {}",
                    parent.backend.seq_len(),
                    s.fork_ctx
                );
            }
            parent.backend.fork_prefix(s.fork_ctx / self.cfg.block_size)?
        } else {
            self.fresh_backend_with(s.topk)
        };
        backend.swap_in(image)?;
        let want = s.prompt_len + s.generated.len();
        let got = backend.seq_len();
        if got != want {
            // dropping the local backend releases whatever it allocated;
            // `s` stays evicted so the re-prefill fallback still works
            return Err(ServeError::ResumeDiverged {
                what: "restored context length",
                expected: want as i64,
                got: got as i64,
            }
            .into());
        }
        s.backend = backend;
        s.evicted = false;
        s.stats.resumes += 1;
        // reprefill_secs intentionally untouched: it prices re-prefill
        // work specifically, and the bench compares the two resume paths
        Ok(())
    }

    /// Force-preempt a session recovered from a faulted worker: release
    /// whatever pool blocks its backend can still release (best-effort —
    /// a private-cache backend frees nothing here; its caches are
    /// replaced wholesale at resume) and mark it evicted so the only way
    /// forward is `resume_session`'s re-prefill. With
    /// `pending_valid == false` (the session's own step panicked, so its
    /// in-memory pending token may be mid-mutation garbage) the pending
    /// token is cleared to `None` and recomputed at resume from the
    /// transcript, which a panic cannot corrupt: tokens are appended
    /// only after a fully completed step.
    pub fn quarantine_session(&self, s: &mut DecodeSession, pending_valid: bool) -> usize {
        let freed = s.backend.evict().unwrap_or(0);
        s.evicted = true;
        if !pending_valid {
            s.pending = None;
        }
        freed
    }

    /// Rebuild a session lost with a dead worker from its ledger
    /// transcript: the identity (own prompt, fork point, budget) plus the
    /// tokens generated so far. The result is evicted-with-no-blocks
    /// (placeholder backend, pending unknown); `resume_session` turns it
    /// back into a live session bit-identical to one that never died —
    /// same argument as any other re-prefill resume, the transcript is
    /// the whole state. Per-session latency stats die with the worker;
    /// `queue_secs` survives on the scheduler side.
    pub fn adopt_session(
        &self,
        own_prompt: Vec<i32>,
        fork_ctx: usize,
        generated: Vec<i32>,
        max_new: usize,
        topk: usize,
    ) -> DecodeSession {
        DecodeSession {
            backend: self.fresh_backend_with(topk),
            prompt_len: fork_ctx + own_prompt.len(),
            own_prompt,
            fork_ctx,
            evicted: true,
            max_seq: self.cfg.max_seq,
            max_new,
            pending: None,
            generated,
            topk,
            stats: GenStats::default(),
        }
    }

    /// Rebuild an evicted session's incremental state by re-ingesting
    /// `own_prompt ++ generated` through the same prefill/fork-decode
    /// path it was originally built with. A forked session re-forks
    /// `parent` (the shared prefix whose blocks survived eviction), so
    /// the prefix is still never duplicated. The rebuilt state — and
    /// every token served afterwards — is bit-identical to a session
    /// that was never evicted: the prefill/decode boundary is invisible
    /// and both paths share the kernels' fixed accumulation orders.
    pub fn resume_session(
        &self,
        s: &mut DecodeSession,
        parent: Option<&DecodeSession>,
    ) -> Result<()> {
        if !s.evicted {
            bail!("resume of a session that was never evicted");
        }
        let t0 = Instant::now();
        let tokens: Vec<i32> = s.own_prompt.iter().chain(&s.generated).copied().collect();
        let pending = if s.fork_ctx > 0 {
            let Some(parent) = parent else {
                bail!("resume of a forked session needs its prefix parent");
            };
            if parent.backend.seq_len() != s.fork_ctx {
                bail!(
                    "prefix parent context {} does not match fork point {}",
                    parent.backend.seq_len(),
                    s.fork_ctx
                );
            }
            let (backend, pending) = self.fork_ingest(parent, &tokens)?;
            s.backend = backend;
            pending
        } else {
            let mut backend = self.fresh_backend_with(s.topk);
            let pending = self.prefill_tokens(backend.as_mut(), &tokens)?;
            s.backend = backend;
            pending
        };
        // a real check, not a debug_assert: in release builds a divergent
        // resume would otherwise silently serve wrong tokens. `None`
        // (fault-wiped pending) has nothing to compare against — the
        // recomputed token is authoritative there.
        if let Some(prev) = s.pending {
            if pending != prev {
                return Err(ServeError::ResumeDiverged {
                    what: "re-prefill pending token",
                    expected: prev as i64,
                    got: pending as i64,
                }
                .into());
            }
        }
        s.pending = Some(pending);
        s.evicted = false;
        s.stats.resumes += 1;
        s.stats.reprefill_secs += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// One decode step: emit the session's pending token, append it to the
    /// incremental state and compute the next. Returns the emitted token,
    /// or `None` if the session is already finished.
    pub fn step(&self, s: &mut DecodeSession) -> Option<i32> {
        debug_assert!(!s.evicted, "stepping an evicted session (resume it first)");
        debug_assert!(s.pending.is_some(), "stepping a session with no pending token");
        if s.finished() {
            return None;
        }
        let tok = s.pending?;
        s.generated.push(tok);
        if s.finished() {
            return Some(tok); // budget exhausted: no need to compute a successor
        }
        let t0 = Instant::now();
        let pos = s.prompt_len + s.generated.len() - 1;
        let (q, k, v) = self.model.qkv(tok, pos);
        let out = s.backend.decode(&q, &k, &v);
        s.pending = Some(argmax(&self.model.logits(&out)));
        s.stats.decode_secs += t0.elapsed().as_secs_f64();
        s.stats.decode_steps += 1;
        Some(tok)
    }

    /// Greedy generation, single request: prefill + run the session to
    /// completion. Returns (generated tokens, stats).
    pub fn generate(&self, prompt: &[i32], max_new: usize) -> Result<(Vec<i32>, GenStats)> {
        let mut session = self.start(prompt, max_new)?;
        while self.step(&mut session).is_some() {}
        let DecodeSession { generated, stats, .. } = session;
        Ok((generated, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::model::ToyModel;

    fn engine(backend: BackendKind) -> ServeEngine<ToyModel> {
        ServeEngine::new(
            ToyModel::new(48, 2, 8, 11),
            ServeCfg { block_size: 16, topk: 2, max_seq: 256, backend, ..Default::default() },
        )
    }

    #[test]
    fn generates_requested_tokens() {
        let e = engine(BackendKind::CachedSparse);
        let prompt: Vec<i32> = (0..40).map(|i| i % 48).collect();
        let (out, stats) = e.generate(&prompt, 6).unwrap();
        assert_eq!(out.len(), 6);
        assert_eq!(stats.decode_steps, 5); // last token needs no successor
        assert!(stats.prefill_secs > 0.0);
    }

    #[test]
    fn cached_decode_matches_recompute_decode() {
        // the serving-level restatement of the kernel parity: same tokens
        // out of the cached backend and the recompute baselines
        let prompt: Vec<i32> = (0..50).map(|i| (i * 7) % 48).collect();
        let reference = engine(BackendKind::RecomputeFull).generate(&prompt, 8).unwrap().0;
        let cached = engine(BackendKind::CachedFull).generate(&prompt, 8).unwrap().0;
        assert_eq!(cached, reference);
        let sparse_ref = engine(BackendKind::RecomputeMoba).generate(&prompt, 8).unwrap().0;
        let sparse_cached = engine(BackendKind::CachedSparse).generate(&prompt, 8).unwrap().0;
        assert_eq!(sparse_cached, sparse_ref);
        let fused = engine(BackendKind::Fused).generate(&prompt, 8).unwrap().0;
        assert_eq!(fused, sparse_ref);
        let paged = engine(BackendKind::Paged).generate(&prompt, 8).unwrap().0;
        assert_eq!(paged, sparse_ref);
    }

    #[test]
    fn forked_session_tokens_match_private_full_prompt() {
        // shared system prefix + divergent continuations through the
        // pool == private sessions over the concatenated prompts
        let e = engine(BackendKind::Paged);
        let prefix: Vec<i32> = (0..40).map(|i| (i * 3) % 48).collect();
        let parent = e.start(&prefix, 0).unwrap();
        let private = engine(BackendKind::CachedSparse);
        for salt in [1i32, 2, 3] {
            let cont: Vec<i32> = (0..9).map(|i| (i * 5 + salt) % 48).collect();
            let mut forked = e.fork_session(&parent, &cont, 6).unwrap();
            let mut got = Vec::new();
            while let Some(tok) = e.step(&mut forked) {
                got.push(tok);
            }
            let full: Vec<i32> = prefix.iter().chain(&cont).copied().collect();
            let want = private.generate(&full, 6).unwrap().0;
            assert_eq!(got, want, "salt={salt}");
        }
        // S sessions shared one prefix: the pool holds the prefix once
        let status = e.pool_status().unwrap();
        assert!(status.used_blocks >= prefix.len() / 16);
        assert!(status.payload_bytes > 0);
    }

    #[test]
    fn fork_rejects_private_backends_and_overflow() {
        let e = engine(BackendKind::CachedSparse);
        let parent = e.start(&[1, 2, 3], 0).unwrap();
        assert!(e.fork_session(&parent, &[4, 5], 4).is_err());
        let p = engine(BackendKind::Paged);
        let parent = p.start(&[1, 2, 3], 0).unwrap();
        assert!(p.fork_session(&parent, &[4, 5], 300).is_err(), "max_seq overflow");
        // empty continuation is a pure clone: same pending token
        let clone = p.fork_session(&parent, &[], 4).unwrap();
        assert_eq!(clone.context_len(), parent.context_len());
    }

    #[test]
    fn block_reserve_is_conservative() {
        let e = engine(BackendKind::Paged);
        // block 16: tokens [40, 60) span blocks 2..4 — the first spanned
        // block is the CoW copy of the shared 8-token tail, not an extra
        assert_eq!(e.block_reserve(40, 20), 2);
        assert_eq!(e.block_reserve(0, 16), 1);
        assert_eq!(e.block_reserve(0, 17), 2);
        // zero appends allocate zero blocks, even mid-block
        assert_eq!(e.block_reserve(40, 0), 0);
        let status = e.pool_status().unwrap();
        assert_eq!(status.capacity_blocks, None);
        assert_eq!(status.used_blocks, 0);
    }

    #[test]
    fn remaining_reserve_shrinks_to_the_unmaterialized_delta() {
        let e = engine(BackendKind::Paged);
        // prompt 4 + max_new 13: worst case 2 blocks at admission, but
        // after prefill the private tail's 12 open slots absorb all 12
        // future appends — nothing left to reserve
        let prompt: Vec<i32> = (0..4).collect();
        let mut s = e.start(&prompt, 13).unwrap();
        assert_eq!(e.block_reserve(0, 4 + 13), 2);
        assert_eq!(e.remaining_reserve(&s), 0, "open tail slots absorb all appends");
        // prompt 14 + max_new 8: 7 appends, 2 open slots -> 1 new block
        let s2 = e.start(&(0..14).collect::<Vec<i32>>(), 8).unwrap();
        assert_eq!(e.remaining_reserve(&s2), 1);
        // a finished session reserves nothing
        while e.step(&mut s).is_some() {}
        assert_eq!(e.remaining_reserve(&s), 0);
    }

    #[test]
    fn forked_remaining_reserve_counts_the_cow_tail_once() {
        let e = engine(BackendKind::Paged);
        let prefix: Vec<i32> = (0..40).map(|i| i % 48).collect(); // 8-token shared tail
        let parent = e.start(&prefix, 0).unwrap();
        // freshly forked, no own tokens yet: first append must CoW the
        // shared partial tail, so the spanned-block count applies
        let f = e.fork_session(&parent, &[], 9).unwrap();
        assert_eq!(e.remaining_reserve(&f), e.block_reserve(40, 8));
        // after ingesting its own continuation the tail is private: open
        // slots absorb appends (44 tokens -> 4 open slots, 5 appends)
        let f2 = e.fork_session(&parent, &[1, 2, 3, 4], 6).unwrap();
        assert_eq!(e.remaining_reserve(&f2), 1);
    }

    #[test]
    fn evicted_session_resumes_bit_identically() {
        let e = engine(BackendKind::Paged);
        let prompt: Vec<i32> = (0..30).map(|i| (i * 7) % 48).collect();
        let (want, _) = e.generate(&prompt, 8).unwrap();
        let mut s = e.start(&prompt, 8).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(e.step(&mut s).unwrap());
        }
        let used_before = e.pool_status().unwrap().used_blocks;
        let freed = e.evict_session(&mut s).unwrap();
        assert!(freed > 0);
        assert!(s.evicted());
        assert_eq!(e.pool_status().unwrap().used_blocks, used_before - freed);
        // the resume reservation covers re-materializing prompt+generated
        assert_eq!(e.resume_reserve(&s), e.block_reserve(0, prompt.len() + 3 + 4));
        assert!(e.evict_session(&mut s).is_err(), "double eviction");
        e.resume_session(&mut s, None).unwrap();
        assert!(!s.evicted());
        assert_eq!(s.stats.resumes, 1);
        assert!(s.stats.reprefill_secs > 0.0);
        while let Some(tok) = e.step(&mut s) {
            got.push(tok);
        }
        assert_eq!(got, want, "resume changed the served tokens");
        assert!(e.resume_session(&mut s, None).is_err(), "resume of a live session");
    }

    #[test]
    fn evicted_fork_resumes_off_its_prefix_parent() {
        let e = engine(BackendKind::Paged);
        let prefix: Vec<i32> = (0..40).map(|i| (i * 3) % 48).collect();
        let parent = e.start(&prefix, 0).unwrap();
        let cont: Vec<i32> = (0..9).map(|i| (i * 5 + 1) % 48).collect();
        let mut twin = e.fork_session(&parent, &cont, 7).unwrap();
        let mut victim = e.fork_session(&parent, &cont, 7).unwrap();
        let mut want = Vec::new();
        let mut got = Vec::new();
        for _ in 0..2 {
            want.push(e.step(&mut twin).unwrap());
            got.push(e.step(&mut victim).unwrap());
        }
        let prefix_blocks = (prefix.len() + 15) / 16;
        e.evict_session(&mut victim).unwrap();
        assert!(
            e.pool_status().unwrap().used_blocks >= prefix_blocks,
            "shared prefix blocks must survive the forker's eviction"
        );
        // resume requires the parent (and the right one)
        assert!(e.resume_session(&mut victim, None).is_err());
        e.resume_session(&mut victim, Some(&parent)).unwrap();
        loop {
            match (e.step(&mut twin), e.step(&mut victim)) {
                (Some(a), Some(b)) => {
                    want.push(a);
                    got.push(b);
                }
                (None, None) => break,
                _ => panic!("twin and resumed fork disagree on length"),
            }
        }
        assert_eq!(got, want, "resumed fork diverged from its never-evicted twin");
    }

    #[test]
    fn swapped_session_resumes_bit_identically() {
        let e = engine(BackendKind::Paged);
        let prompt: Vec<i32> = (0..30).map(|i| (i * 7) % 48).collect();
        let (want, _) = e.generate(&prompt, 8).unwrap();
        let mut s = e.start(&prompt, 8).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(e.step(&mut s).unwrap());
        }
        let used_before = e.pool_status().unwrap().used_blocks;
        let (freed, image) = e.swap_out_session(&mut s).unwrap();
        assert!(freed > 0);
        assert!(s.evicted());
        assert_eq!(e.pool_status().unwrap().used_blocks, used_before - freed);
        // the whole context is private (unforked), so the image holds it all
        assert_eq!(image.tokens(), prompt.len() + 3);
        assert!(image.payload_bytes() > 0);
        assert!(e.swap_out_session(&mut s).is_err(), "double swap-out");
        e.swap_in_session(&mut s, None, &image).unwrap();
        assert!(!s.evicted());
        assert_eq!(s.stats.resumes, 1);
        assert_eq!(s.stats.reprefill_secs, 0.0, "swap-in must not be billed as re-prefill");
        // restore allocates exactly what eviction freed: occupancy parity
        // with a re-prefill resume (and with never having been preempted)
        assert_eq!(e.pool_status().unwrap().used_blocks, used_before);
        while let Some(tok) = e.step(&mut s) {
            got.push(tok);
        }
        assert_eq!(got, want, "swap round-trip changed the served tokens");
    }

    #[test]
    fn swapped_fork_resumes_off_its_resident_prefix() {
        let e = engine(BackendKind::Paged);
        let prefix: Vec<i32> = (0..40).map(|i| (i * 3) % 48).collect();
        let parent = e.start(&prefix, 0).unwrap();
        let cont: Vec<i32> = (0..9).map(|i| (i * 5 + 1) % 48).collect();
        let mut twin = e.fork_session(&parent, &cont, 7).unwrap();
        let mut victim = e.fork_session(&parent, &cont, 7).unwrap();
        let mut want = Vec::new();
        let mut got = Vec::new();
        for _ in 0..2 {
            want.push(e.step(&mut twin).unwrap());
            got.push(e.step(&mut victim).unwrap());
        }
        let (freed, image) = e.swap_out_session(&mut victim).unwrap();
        assert!(freed > 0);
        // suffix-only: the image starts at the fork point's block, the
        // shared prefix stays resident under the parent
        assert_eq!(image.first_block(), prefix.len() / 16);
        assert!(
            e.pool_status().unwrap().used_blocks >= (prefix.len() + 15) / 16,
            "shared prefix blocks must survive the forker's swap-out"
        );
        // swap-in requires the parent, exactly like a re-prefill resume
        assert!(e.swap_in_session(&mut victim, None, &image).is_err());
        assert!(victim.evicted(), "failed swap-in must leave the session evicted");
        e.swap_in_session(&mut victim, Some(&parent), &image).unwrap();
        loop {
            match (e.step(&mut twin), e.step(&mut victim)) {
                (Some(a), Some(b)) => {
                    want.push(a);
                    got.push(b);
                }
                (None, None) => break,
                _ => panic!("twin and swapped fork disagree on length"),
            }
        }
        assert_eq!(got, want, "swapped fork diverged from its never-preempted twin");
    }

    #[test]
    fn corrupted_swap_image_falls_back_to_reprefill() {
        let e = engine(BackendKind::Paged);
        let prompt: Vec<i32> = (0..30).map(|i| (i * 7) % 48).collect();
        let (want, _) = e.generate(&prompt, 8).unwrap();
        let mut s = e.start(&prompt, 8).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(e.step(&mut s).unwrap());
        }
        let (_, mut image) = e.swap_out_session(&mut s).unwrap();
        let used_evicted = e.pool_status().unwrap().used_blocks;
        image.corrupt_for_chaos();
        let err = e.swap_in_session(&mut s, None, &image).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        assert!(s.evicted(), "failed swap-in must leave the session evicted");
        assert_eq!(
            e.pool_status().unwrap().used_blocks,
            used_evicted,
            "failed swap-in must not leak pool blocks"
        );
        // the transparent fallback: plain re-prefill resume still works
        e.resume_session(&mut s, None).unwrap();
        while let Some(tok) = e.step(&mut s) {
            got.push(tok);
        }
        assert_eq!(got, want, "fallback resume changed the served tokens");
    }

    #[test]
    fn quarantined_session_resumes_bit_identically() {
        let e = engine(BackendKind::Paged);
        let prompt: Vec<i32> = (0..30).map(|i| (i * 7) % 48).collect();
        let (want, _) = e.generate(&prompt, 8).unwrap();
        let mut s = e.start(&prompt, 8).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(e.step(&mut s).unwrap());
        }
        // pending treated as mid-mutation garbage: quarantine wipes it and
        // resume recomputes it from the transcript
        let freed = e.quarantine_session(&mut s, false);
        assert!(freed > 0);
        assert!(s.evicted());
        e.resume_session(&mut s, None).unwrap();
        while let Some(t) = e.step(&mut s) {
            got.push(t);
        }
        assert_eq!(got, want, "quarantine + resume changed the served tokens");
    }

    #[test]
    fn quarantine_works_on_private_backends() {
        let e = engine(BackendKind::CachedSparse);
        let prompt: Vec<i32> = (0..20).collect();
        let (want, _) = e.generate(&prompt, 6).unwrap();
        let mut s = e.start(&prompt, 6).unwrap();
        let mut got = vec![e.step(&mut s).unwrap()];
        assert_eq!(e.quarantine_session(&mut s, false), 0, "private caches free no pool blocks");
        e.resume_session(&mut s, None).unwrap();
        while let Some(t) = e.step(&mut s) {
            got.push(t);
        }
        assert_eq!(got, want);
    }

    #[test]
    fn adopted_session_resumes_from_transcript_alone() {
        let e = engine(BackendKind::Paged);
        let prompt: Vec<i32> = (0..25).map(|i| (i * 5) % 48).collect();
        let (want, _) = e.generate(&prompt, 7).unwrap();
        // a fault-free twin ran 4 steps before its worker died with the
        // struct, leaving only the ledger transcript
        let mut adopted = e.adopt_session(prompt.clone(), 0, want[..4].to_vec(), 7, 2);
        assert!(adopted.evicted());
        e.resume_session(&mut adopted, None).unwrap();
        let mut got = want[..4].to_vec();
        while let Some(t) = e.step(&mut adopted) {
            got.push(t);
        }
        assert_eq!(got, want, "adoption lost or corrupted transcript state");
    }

    #[test]
    fn eviction_rejects_private_backends() {
        let e = engine(BackendKind::CachedSparse);
        let mut s = e.start(&[1, 2, 3], 4).unwrap();
        assert!(e.evict_session(&mut s).is_err());
        assert!(!s.evicted());
    }

    #[test]
    fn stepwise_equals_generate() {
        let e = engine(BackendKind::CachedSparse);
        let prompt: Vec<i32> = (0..33).map(|i| i % 48).collect();
        let (out, _) = e.generate(&prompt, 5).unwrap();
        let mut s = e.start(&prompt, 5).unwrap();
        let mut stepped = Vec::new();
        while let Some(tok) = e.step(&mut s) {
            stepped.push(tok);
        }
        assert_eq!(stepped, out);
        assert!(s.finished());
        assert_eq!(s.output(), out.as_slice());
        // context = prompt + generated minus the final (never-appended) token
        assert_eq!(s.context_len(), prompt.len() + 4);
    }

    #[test]
    fn rejects_bad_requests() {
        let e = engine(BackendKind::CachedSparse);
        assert!(e.start(&[], 4).is_err());
        let long: Vec<i32> = vec![1; 300];
        assert!(e.start(&long, 4).is_err());
    }

    #[test]
    fn degraded_topk_session_matches_a_lower_topk_engine_and_survives_eviction() {
        // start_with_topk(k') must serve exactly what an engine configured
        // with topk=k' serves, and an evict/resume cycle must rebuild the
        // degraded session with the SAME sparsity (not cfg.topk)
        let e = engine(BackendKind::Paged);
        let lower = ServeEngine::new(
            ToyModel::new(48, 2, 8, 11),
            ServeCfg {
                block_size: 16,
                topk: 1,
                max_seq: 256,
                backend: BackendKind::Paged,
                ..Default::default()
            },
        );
        let prompt: Vec<i32> = (0..50).map(|i| (i * 7) % 48).collect();
        let (want, _) = lower.generate(&prompt, 8).unwrap();
        let mut s = e.start_with_topk(&prompt, 8, 1).unwrap();
        assert_eq!(s.topk(), 1);
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(e.step(&mut s).unwrap());
        }
        e.evict_session(&mut s).unwrap();
        e.resume_session(&mut s, None).unwrap();
        assert_eq!(s.topk(), 1, "resume must keep the degraded sparsity");
        while let Some(t) = e.step(&mut s) {
            got.push(t);
        }
        assert_eq!(got, want, "degraded session diverged from a topk=1 engine");
        // sanity: degradation actually changes tokens on this geometry,
        // otherwise the parity above proves nothing
        assert_ne!(want, e.generate(&prompt, 8).unwrap().0);
    }

    #[test]
    fn poisoned_pool_lock_is_survivable() {
        let e = engine(BackendKind::Paged);
        let prompt: Vec<i32> = (0..30).map(|i| (i * 7) % 48).collect();
        let (want, _) = e.generate(&prompt, 8).unwrap();
        let mut s = e.start(&prompt, 8).unwrap();
        let mut got = vec![e.step(&mut s).unwrap()];
        e.poison_pool_for_chaos();
        // pool accounting and stepping go through the poison-recovering
        // sync helpers, so everything keeps working bit-identically
        assert!(e.pool_status().unwrap().used_blocks > 0);
        while let Some(t) = e.step(&mut s) {
            got.push(t);
        }
        assert_eq!(got, want, "pool poisoning changed served tokens");
        // no-op on unpooled engines
        engine(BackendKind::CachedSparse).poison_pool_for_chaos();
    }

    #[test]
    fn zero_budget_session_is_finished_immediately() {
        let e = engine(BackendKind::CachedSparse);
        let mut s = e.start(&[1, 2, 3], 0).unwrap();
        assert!(s.finished());
        assert_eq!(e.step(&mut s), None);
        assert!(s.output().is_empty());
    }
}
