//! Generation engine over pluggable attention backends — prefill once,
//! then incremental decode against the KV/block-pool caches.
//!
//! The old caveat ("decode is recompute-based, no KV cache") is gone:
//! each request owns a [`DecodeSession`] whose backend ingests the prompt
//! once (`AttentionBackend::prefill`, MoBA block-sparse by default — the
//! paper's prefill mode) and then appends one token per decode step
//! (`AttentionBackend::decode`). With the default
//! `BackendKind::CachedSparse` a decode step costs O(N/B·D) gating +
//! O(k·B·D) attention instead of the old O(N²) whole-graph recompute;
//! `BackendKind::CachedFull` gives the paper's §3.3 full-attention-decode
//! deployment mode at O(N·D) per token. The recompute kinds (`full`,
//! `moba`) remain selectable as baselines — same API, same outputs,
//! bit-for-bit (see `sparse/README.md`).
//!
//! Sessions are independent and stepped one token at a time, which is
//! what lets `serve::scheduler` interleave many requests in a continuous
//! batch. The model behind the projections is abstracted as
//! [`TokenModel`]; the artifact/PJRT path lives in `serve::artifact`
//! behind the `xla` feature.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::sparse::{
    build_backend_par, shared_pool, AttentionBackend, BackendKind, PagedMobaAttention,
    SharedKvPool,
};
use crate::tensor::Tensor;

use super::model::TokenModel;

/// Per-request serving statistics.
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub decode_steps: usize,
}

/// Serving configuration: attention geometry + backend selection.
#[derive(Clone, Debug)]
pub struct ServeCfg {
    pub block_size: usize,
    pub topk: usize,
    pub max_seq: usize,
    pub backend: BackendKind,
    /// Intra-request kernel threads for prefill row partitioning (see
    /// `sparse::parallel`). Outputs are bit-identical for every value.
    /// 1 = serial. Decode steps always run inline — per-token work is far
    /// below spawn cost; inter-request decode parallelism belongs to the
    /// scheduler's decode shards instead.
    pub workers: usize,
    /// Physical-block capacity of the shared paged KV pool (only
    /// meaningful with `backend == BackendKind::Paged`; every paged
    /// session of this engine allocates from one pool). 0 = unbounded.
    pub pool_blocks: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            block_size: 64,
            topk: 3,
            max_seq: 4096,
            backend: BackendKind::CachedSparse,
            workers: 1,
            pool_blocks: 0,
        }
    }
}

/// Occupancy snapshot of the engine's shared paged pool.
#[derive(Clone, Copy, Debug)]
pub struct PoolStatus {
    /// physical blocks currently referenced by at least one session
    pub used_blocks: usize,
    /// allocation ceiling (`None` = unbounded)
    pub capacity_blocks: Option<usize>,
    /// unique K/V payload bytes resident in the pool
    pub payload_bytes: usize,
}

/// One in-flight request: its backend state (caches), token history and
/// latency accounting. Created by `ServeEngine::start` (prefill), then
/// advanced one token per `ServeEngine::step`.
pub struct DecodeSession {
    backend: Box<dyn AttentionBackend>,
    prompt_len: usize,
    max_seq: usize,
    max_new: usize,
    /// next token to emit (argmax of the last computed logits)
    pending: i32,
    generated: Vec<i32>,
    pub stats: GenStats,
}

impl DecodeSession {
    pub fn finished(&self) -> bool {
        self.generated.len() >= self.max_new
            || self.prompt_len + self.generated.len() >= self.max_seq
    }

    pub fn output(&self) -> &[i32] {
        &self.generated
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    /// Tokens currently resident in the backend's incremental state.
    pub fn context_len(&self) -> usize {
        self.backend.seq_len()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }
}

fn argmax(xs: &[f32]) -> i32 {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

/// Backend-based generation engine. Stateless across requests — every
/// request gets a fresh backend in its session — except for the paged
/// backend, whose sessions all allocate from one shared copy-on-write
/// pool (which is what makes prefix sharing across requests possible).
pub struct ServeEngine<M: TokenModel> {
    model: M,
    cfg: ServeCfg,
    /// the shared pool, present iff `cfg.backend == BackendKind::Paged`
    pool: Option<SharedKvPool>,
}

impl<M: TokenModel> ServeEngine<M> {
    pub fn new(model: M, cfg: ServeCfg) -> ServeEngine<M> {
        let pool = (cfg.backend == BackendKind::Paged).then(|| {
            let cap = (cfg.pool_blocks > 0).then_some(cfg.pool_blocks);
            shared_pool(cfg.block_size, model.heads(), model.head_dim(), cap)
        });
        ServeEngine { model, cfg, pool }
    }

    pub fn cfg(&self) -> &ServeCfg {
        &self.cfg
    }

    pub fn model(&self) -> &M {
        &self.model
    }

    /// Occupancy of the shared paged pool (`None` for private-cache
    /// backends) — what the continuous scheduler admits against.
    pub fn pool_status(&self) -> Option<PoolStatus> {
        self.pool.as_ref().map(|pool| {
            let p = pool.read().expect("paged pool lock");
            PoolStatus {
                used_blocks: p.used_blocks(),
                capacity_blocks: p.capacity_blocks(),
                payload_bytes: p.payload_bytes(),
            }
        })
    }

    /// Worst-case physical blocks a session forked at context length
    /// `ctx` can allocate while appending `tokens` more: the blocks
    /// spanning `[ctx, ctx + tokens)`. This is exact — when the session
    /// shares a partial tail, the copy-on-write duplicate *is* the first
    /// spanned block, not an extra one.
    pub fn block_reserve(&self, ctx: usize, tokens: usize) -> usize {
        let b = self.cfg.block_size;
        (ctx % b + tokens + b - 1) / b
    }

    /// Prefill `prompt` through a fresh backend and return the live
    /// session with its first pending token.
    pub fn start(&self, prompt: &[i32], max_new: usize) -> Result<DecodeSession> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() + max_new > self.cfg.max_seq {
            bail!(
                "prompt {} + max_new {} exceeds max_seq {}",
                prompt.len(),
                max_new,
                self.cfg.max_seq
            );
        }
        let (h, d) = (self.model.heads(), self.model.head_dim());
        let workers = self.cfg.workers.max(1);
        let mut backend: Box<dyn AttentionBackend> = match &self.pool {
            // paged sessions must share THE engine pool, not build their
            // own — that is what makes cross-request prefix sharing work
            Some(pool) => Box::new(
                PagedMobaAttention::new(pool.clone(), self.cfg.topk).with_workers(workers),
            ),
            None => build_backend_par(
                self.cfg.backend,
                h,
                d,
                self.cfg.block_size,
                self.cfg.topk,
                workers,
            ),
        };

        let t0 = Instant::now();
        let n = prompt.len();
        let w = h * d;
        let (mut qs, mut ks, mut vs) =
            (Vec::with_capacity(n * w), Vec::with_capacity(n * w), Vec::with_capacity(n * w));
        for (pos, &tok) in prompt.iter().enumerate() {
            let (q, k, v) = self.model.qkv(tok, pos);
            qs.extend_from_slice(&q);
            ks.extend_from_slice(&k);
            vs.extend_from_slice(&v);
        }
        let q = Tensor::from_vec(&[n, h, d], qs)?;
        let k = Tensor::from_vec(&[n, h, d], ks)?;
        let v = Tensor::from_vec(&[n, h, d], vs)?;
        let out = backend.prefill(&q, &k, &v);
        let pending = argmax(&self.model.logits(&out.data[(n - 1) * w..n * w]));
        let stats = GenStats { prefill_secs: t0.elapsed().as_secs_f64(), ..Default::default() };

        Ok(DecodeSession {
            backend,
            prompt_len: n,
            max_seq: self.cfg.max_seq,
            max_new,
            pending,
            generated: Vec::with_capacity(max_new),
            stats,
        })
    }

    /// Fork `parent`'s state copy-on-write (paged backend only) and
    /// ingest `continuation` on the fork — the shared-system-prompt
    /// serving scenario: S sessions share one physical prefix, each pays
    /// only its own divergent tail. Token-identical to
    /// `start(prefix ++ continuation)` on a private backend: the decode
    /// rows that ingest the continuation are bit-equal to the prefill
    /// rows a private session would compute (the prefill/decode boundary
    /// is invisible — `tests/property_invariants.rs`).
    pub fn fork_session(
        &self,
        parent: &DecodeSession,
        continuation: &[i32],
        max_new: usize,
    ) -> Result<DecodeSession> {
        let ctx = parent.backend.seq_len();
        if ctx + continuation.len() + max_new > self.cfg.max_seq {
            bail!(
                "prefix {} + continuation {} + max_new {} exceeds max_seq {}",
                ctx,
                continuation.len(),
                max_new,
                self.cfg.max_seq
            );
        }
        let t0 = Instant::now();
        let mut backend = parent.backend.fork()?;
        let mut last_out = None;
        for (i, &tok) in continuation.iter().enumerate() {
            let (q, k, v) = self.model.qkv(tok, ctx + i);
            last_out = Some(backend.decode(&q, &k, &v));
        }
        // only the final position's logits decide the pending token — an
        // empty continuation is a pure clone of the parent's
        let pending = match last_out {
            Some(out) => argmax(&self.model.logits(&out)),
            None => parent.pending,
        };
        let stats = GenStats { prefill_secs: t0.elapsed().as_secs_f64(), ..Default::default() };
        Ok(DecodeSession {
            backend,
            prompt_len: ctx + continuation.len(),
            max_seq: self.cfg.max_seq,
            max_new,
            pending,
            generated: Vec::with_capacity(max_new),
            stats,
        })
    }

    /// One decode step: emit the session's pending token, append it to the
    /// incremental state and compute the next. Returns the emitted token,
    /// or `None` if the session is already finished.
    pub fn step(&self, s: &mut DecodeSession) -> Option<i32> {
        if s.finished() {
            return None;
        }
        let tok = s.pending;
        s.generated.push(tok);
        if s.finished() {
            return Some(tok); // budget exhausted: no need to compute a successor
        }
        let t0 = Instant::now();
        let pos = s.prompt_len + s.generated.len() - 1;
        let (q, k, v) = self.model.qkv(tok, pos);
        let out = s.backend.decode(&q, &k, &v);
        s.pending = argmax(&self.model.logits(&out));
        s.stats.decode_secs += t0.elapsed().as_secs_f64();
        s.stats.decode_steps += 1;
        Some(tok)
    }

    /// Greedy generation, single request: prefill + run the session to
    /// completion. Returns (generated tokens, stats).
    pub fn generate(&self, prompt: &[i32], max_new: usize) -> Result<(Vec<i32>, GenStats)> {
        let mut session = self.start(prompt, max_new)?;
        while self.step(&mut session).is_some() {}
        let DecodeSession { generated, stats, .. } = session;
        Ok((generated, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::model::ToyModel;

    fn engine(backend: BackendKind) -> ServeEngine<ToyModel> {
        ServeEngine::new(
            ToyModel::new(48, 2, 8, 11),
            ServeCfg { block_size: 16, topk: 2, max_seq: 256, backend, ..Default::default() },
        )
    }

    #[test]
    fn generates_requested_tokens() {
        let e = engine(BackendKind::CachedSparse);
        let prompt: Vec<i32> = (0..40).map(|i| i % 48).collect();
        let (out, stats) = e.generate(&prompt, 6).unwrap();
        assert_eq!(out.len(), 6);
        assert_eq!(stats.decode_steps, 5); // last token needs no successor
        assert!(stats.prefill_secs > 0.0);
    }

    #[test]
    fn cached_decode_matches_recompute_decode() {
        // the serving-level restatement of the kernel parity: same tokens
        // out of the cached backend and the recompute baselines
        let prompt: Vec<i32> = (0..50).map(|i| (i * 7) % 48).collect();
        let reference = engine(BackendKind::RecomputeFull).generate(&prompt, 8).unwrap().0;
        let cached = engine(BackendKind::CachedFull).generate(&prompt, 8).unwrap().0;
        assert_eq!(cached, reference);
        let sparse_ref = engine(BackendKind::RecomputeMoba).generate(&prompt, 8).unwrap().0;
        let sparse_cached = engine(BackendKind::CachedSparse).generate(&prompt, 8).unwrap().0;
        assert_eq!(sparse_cached, sparse_ref);
        let fused = engine(BackendKind::Fused).generate(&prompt, 8).unwrap().0;
        assert_eq!(fused, sparse_ref);
        let paged = engine(BackendKind::Paged).generate(&prompt, 8).unwrap().0;
        assert_eq!(paged, sparse_ref);
    }

    #[test]
    fn forked_session_tokens_match_private_full_prompt() {
        // shared system prefix + divergent continuations through the
        // pool == private sessions over the concatenated prompts
        let e = engine(BackendKind::Paged);
        let prefix: Vec<i32> = (0..40).map(|i| (i * 3) % 48).collect();
        let parent = e.start(&prefix, 0).unwrap();
        let private = engine(BackendKind::CachedSparse);
        for salt in [1i32, 2, 3] {
            let cont: Vec<i32> = (0..9).map(|i| (i * 5 + salt) % 48).collect();
            let mut forked = e.fork_session(&parent, &cont, 6).unwrap();
            let mut got = Vec::new();
            while let Some(tok) = e.step(&mut forked) {
                got.push(tok);
            }
            let full: Vec<i32> = prefix.iter().chain(&cont).copied().collect();
            let want = private.generate(&full, 6).unwrap().0;
            assert_eq!(got, want, "salt={salt}");
        }
        // S sessions shared one prefix: the pool holds the prefix once
        let status = e.pool_status().unwrap();
        assert!(status.used_blocks >= prefix.len() / 16);
        assert!(status.payload_bytes > 0);
    }

    #[test]
    fn fork_rejects_private_backends_and_overflow() {
        let e = engine(BackendKind::CachedSparse);
        let parent = e.start(&[1, 2, 3], 0).unwrap();
        assert!(e.fork_session(&parent, &[4, 5], 4).is_err());
        let p = engine(BackendKind::Paged);
        let parent = p.start(&[1, 2, 3], 0).unwrap();
        assert!(p.fork_session(&parent, &[4, 5], 300).is_err(), "max_seq overflow");
        // empty continuation is a pure clone: same pending token
        let clone = p.fork_session(&parent, &[], 4).unwrap();
        assert_eq!(clone.context_len(), parent.context_len());
    }

    #[test]
    fn block_reserve_is_conservative() {
        let e = engine(BackendKind::Paged);
        // block 16: tokens [40, 60) span blocks 2..4 — the first spanned
        // block is the CoW copy of the shared 8-token tail, not an extra
        assert_eq!(e.block_reserve(40, 20), 2);
        assert_eq!(e.block_reserve(0, 16), 1);
        assert_eq!(e.block_reserve(0, 17), 2);
        let status = e.pool_status().unwrap();
        assert_eq!(status.capacity_blocks, None);
        assert_eq!(status.used_blocks, 0);
    }

    #[test]
    fn stepwise_equals_generate() {
        let e = engine(BackendKind::CachedSparse);
        let prompt: Vec<i32> = (0..33).map(|i| i % 48).collect();
        let (out, _) = e.generate(&prompt, 5).unwrap();
        let mut s = e.start(&prompt, 5).unwrap();
        let mut stepped = Vec::new();
        while let Some(tok) = e.step(&mut s) {
            stepped.push(tok);
        }
        assert_eq!(stepped, out);
        assert!(s.finished());
        assert_eq!(s.output(), out.as_slice());
        // context = prompt + generated minus the final (never-appended) token
        assert_eq!(s.context_len(), prompt.len() + 4);
    }

    #[test]
    fn rejects_bad_requests() {
        let e = engine(BackendKind::CachedSparse);
        assert!(e.start(&[], 4).is_err());
        let long: Vec<i32> = vec![1; 300];
        assert!(e.start(&long, 4).is_err());
    }

    #[test]
    fn zero_budget_session_is_finished_immediately() {
        let e = engine(BackendKind::CachedSparse);
        let mut s = e.start(&[1, 2, 3], 0).unwrap();
        assert!(s.finished());
        assert_eq!(e.step(&mut s), None);
        assert!(s.output().is_empty());
    }
}
