//! Generation engine over pluggable attention backends — prefill once,
//! then incremental decode against the KV/block-pool caches.
//!
//! The old caveat ("decode is recompute-based, no KV cache") is gone:
//! each request owns a [`DecodeSession`] whose backends ingest the prompt
//! once (`AttentionBackend::prefill`, MoBA block-sparse by default — the
//! paper's prefill mode) and then append one token per decode step
//! (`AttentionBackend::decode`). With the default
//! `BackendKind::CachedSparse` a decode step costs O(N/B·D) gating +
//! O(k·B·D) attention instead of the old O(N²) whole-graph recompute;
//! `BackendKind::CachedFull` gives the paper's §3.3 full-attention-decode
//! deployment mode at O(N·D) per token. The recompute kinds (`full`,
//! `moba`) remain selectable as baselines — same API, same outputs,
//! bit-for-bit (see `sparse/README.md`).
//!
//! Sessions are **multi-layer**: a [`TokenModel`] reports its layer count
//! and each session holds one backend per layer, threading a residual
//! hidden stream through the stack (layer 0 projects from token ids,
//! deeper layers from the hidden row, `hidden += attn_out` per layer).
//! [`ServeCfg::layers`] mixes full-attention layers among MoBA ones —
//! the hybrid recipe of MiniMax-01 (arXiv:2501.08313) and "A Little Goes
//! a Long Way" (arXiv:2410.01485) — while an L=1 model stays bitwise
//! identical to the historical single-attention path. Pool accounting
//! (`block_reserve` & co.) sums over layers; preemption snapshots become
//! per-layer [`SwapBundle`]s restored atomically.
//!
//! Sessions are independent and stepped one token at a time, which is
//! what lets `serve::scheduler` interleave many requests in a continuous
//! batch. The model behind the projections is abstracted as
//! [`TokenModel`]; the artifact/PJRT path lives in `serve::artifact`
//! behind the `xla` feature.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::sparse::{
    build_backend_par, shared_pool, AttentionBackend, BackendKind, PagedMobaAttention,
    SharedKvPool, SwapImage,
};
use crate::tensor::Tensor;
use crate::util::sync;

use super::error::ServeError;
use super::model::TokenModel;

/// MoBA top-k that covers every block: the kernels clamp the per-row
/// top-k to the row's block count, so gating with this IS full attention,
/// bit-for-bit (the `*_covering_topk_equals_full` kernel tests pin the
/// equivalence). A paged `full` layer is `PagedMobaAttention` with this.
const FULL_LAYER_TOPK: usize = usize::MAX;

/// Per-request serving statistics.
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub decode_steps: usize,
    /// times this session was evicted and rebuilt via re-prefill
    pub resumes: usize,
    /// wall-clock seconds spent re-prefilling after evictions
    pub reprefill_secs: f64,
}

/// Attention flavor of one model layer in a hybrid stack. The robust
/// recipe in the MoBA paper (and MiniMax-01, arXiv:2501.08313) keeps a
/// few `Full` layers among mostly-`Moba` ones.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// MoBA block-sparse gating with the session's top-k.
    Moba,
    /// Dense causal attention (covering top-k on gated backends).
    Full,
}

impl LayerKind {
    /// The spec token this kind parses from (`moba` / `full`).
    pub fn label(self) -> &'static str {
        match self {
            LayerKind::Moba => "moba",
            LayerKind::Full => "full",
        }
    }
}

/// Strict layer-spec parser shared by `--layers` and `MOBA_LAYERS`: a
/// comma-separated list of `moba` / `full` (e.g. `moba,moba,full,moba`),
/// one entry per model layer. `None` / blank means "unset" (every layer
/// follows `ServeCfg::backend`). Errors carry the source (`what`) and
/// the offending token, matching the `MOBA_WORKERS` / `MOBA_SWAP_BLOCKS`
/// CLI-boundary convention.
pub fn parse_layers(what: &str, raw: Option<String>) -> Result<Option<Vec<LayerKind>>, String> {
    let Some(v) = raw else {
        return Ok(None);
    };
    if v.trim().is_empty() {
        return Ok(None);
    }
    let mut kinds = Vec::new();
    for tok in v.split(',') {
        match tok.trim() {
            "moba" => kinds.push(LayerKind::Moba),
            "full" => kinds.push(LayerKind::Full),
            other => {
                return Err(format!(
                    "{what}: invalid layer kind {other:?} in {v:?} \
                     (expected a comma-separated list of `moba` / `full`)"
                ))
            }
        }
    }
    Ok(Some(kinds))
}

/// Lenient `MOBA_LAYERS` reader (unset or unparsable -> unset) for
/// defaults structs; `repro serve` and the example reject garbage at the
/// CLI boundary through [`layers_from_env_strict`] first.
pub fn layers_from_env() -> Option<Vec<LayerKind>> {
    parse_layers("MOBA_LAYERS", std::env::var("MOBA_LAYERS").ok()).unwrap_or(None)
}

/// Strict `MOBA_LAYERS` reader: unset -> `Ok(None)`, garbage -> a
/// contextful error naming the variable and the bad token.
pub fn layers_from_env_strict() -> Result<Option<Vec<LayerKind>>, String> {
    parse_layers("MOBA_LAYERS", std::env::var("MOBA_LAYERS").ok())
}

/// Serving configuration: attention geometry + backend selection.
#[derive(Clone, Debug)]
pub struct ServeCfg {
    pub block_size: usize,
    pub topk: usize,
    pub max_seq: usize,
    pub backend: BackendKind,
    /// Intra-request kernel threads for prefill row partitioning (see
    /// `sparse::parallel`). Outputs are bit-identical for every value.
    /// 1 = serial. Decode steps always run inline — per-token work is far
    /// below spawn cost; inter-request decode parallelism belongs to the
    /// scheduler's decode shards instead.
    pub workers: usize,
    /// Physical-block capacity of the shared paged KV pool (only
    /// meaningful with `backend == BackendKind::Paged`; every paged
    /// session of this engine allocates from one pool). 0 = unbounded.
    pub pool_blocks: usize,
    /// Per-layer attention flavors for hybrid stacks. Empty = every model
    /// layer uses `backend`'s own flavor (the historical single-flavor
    /// path, bit-for-bit). Non-empty must have exactly one entry per
    /// model layer; `Full` entries attend densely regardless of `topk`.
    pub layers: Vec<LayerKind>,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            block_size: 64,
            topk: 3,
            max_seq: 4096,
            backend: BackendKind::CachedSparse,
            workers: 1,
            pool_blocks: 0,
            layers: Vec::new(),
        }
    }
}

/// Occupancy snapshot of the engine's shared paged pool.
#[derive(Clone, Copy, Debug)]
pub struct PoolStatus {
    /// physical blocks currently referenced by at least one session
    pub used_blocks: usize,
    /// allocation ceiling (`None` = unbounded)
    pub capacity_blocks: Option<usize>,
    /// unique K/V payload bytes resident in the pool
    pub payload_bytes: usize,
}

/// Per-layer [`SwapImage`]s of one preempted session — one image per
/// model layer, layer 0 first. `swap_in_session` restores a bundle
/// atomically: either every layer comes back byte-exact or the session
/// stays evicted and falls back to transparent re-prefill.
#[derive(Clone, Debug)]
pub struct SwapBundle {
    images: Vec<SwapImage>,
}

impl SwapBundle {
    /// Number of layer images (== the session's layer count).
    pub fn layers(&self) -> usize {
        self.images.len()
    }

    /// The per-layer images, layer 0 first.
    pub fn images(&self) -> &[SwapImage] {
        &self.images
    }

    /// Total snapshot blocks across all layers — the swap-tier capacity
    /// this bundle charges, and exactly what swap-in will allocate.
    pub fn n_blocks(&self) -> usize {
        self.images.iter().map(|i| i.n_blocks()).sum()
    }

    /// Total host-tier payload bytes across all layers.
    pub fn payload_bytes(&self) -> usize {
        self.images.iter().map(|i| i.payload_bytes()).sum()
    }

    /// Tokens captured (identical across layers — all tables span the
    /// same token range).
    pub fn tokens(&self) -> usize {
        self.images.first().map_or(0, |i| i.tokens())
    }

    /// First captured logical block (identical across layers).
    pub fn first_block(&self) -> usize {
        self.images.first().map_or(0, |i| i.first_block())
    }

    /// Chaos hook: corrupt the LAST layer's image, so a failing restore
    /// hits after earlier layers already allocated blocks — exercising
    /// the all-or-nothing rollback, not just a first-image early-out.
    pub fn corrupt_for_chaos(&mut self) {
        if let Some(img) = self.images.last_mut() {
            img.corrupt_for_chaos();
        }
    }
}

/// Reusable per-session decode buffers: the q/k/v rows, the residual
/// hidden row threaded through the layer stack, and the logits row.
/// Lives on the session so the per-token hot path allocates nothing.
#[derive(Default)]
struct StepScratch {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    hidden: Vec<f32>,
    logits: Vec<f32>,
}

/// One in-flight request: its backend state (caches), token history and
/// latency accounting. Created by `ServeEngine::start` (prefill), then
/// advanced one token per `ServeEngine::step`.
pub struct DecodeSession {
    /// one attention backend per model layer, layer 0 first — a hybrid
    /// stack mixes dense layers among MoBA ones per `ServeCfg::layers`.
    /// All layers always hold the same context length.
    backends: Vec<Box<dyn AttentionBackend>>,
    prompt_len: usize,
    /// the tokens THIS session ingested itself (the whole prompt, or just
    /// the continuation for a forked session) — together with `generated`
    /// this is exactly the state a transparent re-prefill resume needs
    own_prompt: Vec<i32>,
    /// context length at fork time (0 = not forked): re-prefill of a
    /// forked session re-forks its prefix parent instead of starting cold
    fork_ctx: usize,
    /// blocks released back to the pool; must be resumed before stepping
    evicted: bool,
    max_seq: usize,
    max_new: usize,
    /// next token to emit (argmax of the last computed logits). `None`
    /// for an adopted or quarantined session rebuilt after a worker
    /// fault, where the last-computed logits died with the worker:
    /// `resume_session` recomputes the real value from the transcript
    /// (there is nothing to compare against, but the recomputed token IS
    /// the one a fault-free run would hold — it is a pure function of
    /// the re-ingested tokens). An `Option` instead of a sentinel value,
    /// so unknown-ness can never be confused with a real token.
    pending: Option<i32>,
    generated: Vec<i32>,
    /// MoBA top-k this session's backends gate with — normally
    /// `ServeCfg::topk`, downshifted by the scheduler's pressure dial
    /// for degraded low-priority sessions. Carried on the session so
    /// evict/resume/adopt rebuild the backends with the SAME sparsity
    /// (a degraded session must stay self-consistent across re-prefill).
    /// `Full` layers ignore it — they attend densely at every dial.
    topk: usize,
    /// per-token decode buffers, reused across steps
    scratch: StepScratch,
    pub stats: GenStats,
}

impl DecodeSession {
    pub fn finished(&self) -> bool {
        self.generated.len() >= self.max_new
            || self.prompt_len + self.generated.len() >= self.max_seq
    }

    pub fn output(&self) -> &[i32] {
        &self.generated
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    /// Context length of the layer stack (all layers always agree; the
    /// engine appends to every layer in the same step).
    fn ctx(&self) -> usize {
        self.backends[0].seq_len()
    }

    /// Tokens currently resident in the backends' incremental state.
    pub fn context_len(&self) -> usize {
        debug_assert!(
            self.backends.iter().all(|b| b.seq_len() == self.backends[0].seq_len()),
            "layer backends disagree on context length"
        );
        self.ctx()
    }

    /// Number of model layers (== backends) this session holds.
    pub fn layers(&self) -> usize {
        self.backends.len()
    }

    /// True between `ServeEngine::evict_session` and `resume_session`:
    /// the session's pool blocks are released and it must not be stepped.
    pub fn evicted(&self) -> bool {
        self.evicted
    }

    pub fn backend_name(&self) -> &'static str {
        self.backends[0].name()
    }

    /// The tokens this session ingested itself (whole prompt, or the
    /// post-fork continuation) — what a recovery ledger must mirror to
    /// rebuild the session if its worker dies with the struct.
    pub fn own_prompt(&self) -> &[i32] {
        &self.own_prompt
    }

    /// Context length at fork time (0 = not forked).
    pub fn fork_ctx(&self) -> usize {
        self.fork_ctx
    }

    pub fn max_new(&self) -> usize {
        self.max_new
    }

    /// The MoBA top-k this session gates with (see the `topk` field).
    pub fn topk(&self) -> usize {
        self.topk
    }

    /// False after a fault wiped the pending token (quarantine with
    /// `pending_valid == false`, or adoption from a ledger transcript):
    /// only a re-prefill resume can recompute it, so a swap-in — which
    /// restores cached state but computes no logits — must not be used.
    pub fn pending_known(&self) -> bool {
        self.pending.is_some()
    }

    /// Tag this session's future pool allocations with its decode
    /// shard's arena (paged backend; a locality no-op elsewhere). Every
    /// layer backend is tagged — blocks of all layers should stay local
    /// to the owning worker. Never changes any served token — block ids
    /// are invisible to the math.
    pub fn set_arena(&mut self, arena: usize) {
        for b in &mut self.backends {
            b.set_arena(arena);
        }
    }
}

fn argmax(xs: &[f32]) -> i32 {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

/// Backend-based generation engine. Stateless across requests — every
/// request gets a fresh backend stack in its session — except for the
/// paged backend, whose sessions all allocate from one shared
/// copy-on-write pool (which is what makes prefix sharing across
/// requests possible; tables are layer-tagged for per-layer accounting).
pub struct ServeEngine<M: TokenModel> {
    model: M,
    cfg: ServeCfg,
    /// the shared pool, present iff `cfg.backend == BackendKind::Paged`
    pool: Option<SharedKvPool>,
}

impl<M: TokenModel> ServeEngine<M> {
    pub fn new(model: M, cfg: ServeCfg) -> ServeEngine<M> {
        assert!(
            cfg.layers.is_empty() || cfg.layers.len() == model.layers(),
            "ServeCfg::layers has {} entries but the model has {} layers",
            cfg.layers.len(),
            model.layers()
        );
        let pool = (cfg.backend == BackendKind::Paged).then(|| {
            let cap = (cfg.pool_blocks > 0).then_some(cfg.pool_blocks);
            shared_pool(cfg.block_size, model.heads(), model.head_dim(), cap)
        });
        ServeEngine { model, cfg, pool }
    }

    pub fn cfg(&self) -> &ServeCfg {
        &self.cfg
    }

    pub fn model(&self) -> &M {
        &self.model
    }

    /// Layers in the model — every session holds this many backends and
    /// all pool arithmetic sums over them.
    fn n_layers(&self) -> usize {
        self.model.layers()
    }

    /// Occupancy of the shared paged pool (`None` for private-cache
    /// backends) — what the continuous scheduler admits against. Counts
    /// aggregate over all layers (each layer's table charges the pool).
    pub fn pool_status(&self) -> Option<PoolStatus> {
        self.pool.as_ref().map(|pool| {
            // poison-resistant: a worker panicking mid-allocation must not
            // take the whole scheduler's pool accounting down with it
            let p = sync::read(pool);
            PoolStatus {
                used_blocks: p.used_blocks(),
                capacity_blocks: p.capacity_blocks(),
                payload_bytes: p.payload_bytes(),
            }
        })
    }

    /// Per-layer used-block counts of the shared paged pool (`None` for
    /// private-cache backends); index = model layer. Sums to
    /// `PoolStatus::used_blocks` — the layered bench arm writes this
    /// next to the aggregate stats.
    pub fn pool_layer_usage(&self) -> Option<Vec<usize>> {
        self.pool.as_ref().map(|pool| sync::read(pool).used_blocks_by_layer().to_vec())
    }

    /// Worst-case physical blocks a session forked at context length
    /// `ctx` can allocate while appending `tokens` more, summed over
    /// every model layer: per layer, the blocks spanning
    /// `[ctx, ctx + tokens)`. This is exact — when the session shares a
    /// partial tail, the copy-on-write duplicate *is* the first spanned
    /// block, not an extra one, and every layer's table spans the same
    /// token range. Zero tokens allocate nothing.
    pub fn block_reserve(&self, ctx: usize, tokens: usize) -> usize {
        self.n_layers() * self.block_reserve_per_layer(ctx, tokens)
    }

    fn block_reserve_per_layer(&self, ctx: usize, tokens: usize) -> usize {
        if tokens == 0 {
            return 0;
        }
        let b = self.cfg.block_size;
        (ctx % b + tokens + b - 1) / b
    }

    /// Decode steps this session will still run that APPEND a token: it
    /// emits until budget/max_seq, and the final emission is never
    /// appended (no successor is computed).
    fn appends_left(&self, s: &DecodeSession) -> usize {
        if s.finished() {
            return 0;
        }
        let emitted = s.generated.len();
        let budget = s.max_new - emitted;
        let seq_room = s.max_seq.saturating_sub(s.prompt_len + emitted);
        budget.min(seq_room).saturating_sub(1)
    }

    /// Pool blocks a LIVE session's remaining decode steps can still
    /// allocate beyond what it already holds — the not-yet-materialized
    /// delta of its admission reservation, summed over layers (every
    /// layer appends the same rows, so the per-layer geometry is
    /// identical). Shrinks to 0 as the session fills its tail /
    /// finishes, which is what lets the scheduler admit into the freed
    /// headroom instead of holding the admission-time worst case for the
    /// whole session lifetime.
    pub fn remaining_reserve(&self, s: &DecodeSession) -> usize {
        let appends = self.appends_left(s);
        if appends == 0 {
            return 0;
        }
        let ctx = s.ctx();
        let b = self.cfg.block_size;
        let per_layer = if s.fork_ctx == 0 || ctx > s.fork_ctx {
            // the session owns its tail block: open slots absorb appends
            // without allocating (already counted in pool used_blocks)
            let slots = (b - ctx % b) % b;
            (appends.saturating_sub(slots) + b - 1) / b
        } else {
            // still exactly the forked prefix: the first append must CoW
            // a shared partial tail (or open a fresh block)
            self.block_reserve_per_layer(ctx, appends)
        };
        s.backends.len() * per_layer
    }

    /// Worst-case pool blocks an EVICTED session needs to resume and run
    /// to completion: re-materializing its own tokens plus the same
    /// future appends `remaining_reserve` would cover, over all layers.
    pub fn resume_reserve(&self, s: &DecodeSession) -> usize {
        let own = s.own_prompt.len() + s.generated.len();
        self.block_reserve(s.fork_ctx, own + self.appends_left(s))
    }

    /// Physical blocks evicting `s` would actually reclaim, summed over
    /// layers: per layer, the blocks spanning its own tokens, including
    /// its copy-on-write duplicate of a shared partial prefix tail.
    /// Blocks fully inside the forked prefix are shared with the prefix
    /// parent and survive; a fork that has not yet appended anything of
    /// its own frees nothing. Exact for serving sessions, which only
    /// ever fork off the engine's shared prefix (never off each other) —
    /// the scheduler's eviction feasibility check relies on this.
    pub fn freeable_blocks(&self, s: &DecodeSession) -> usize {
        let ctx = s.ctx();
        if ctx <= s.fork_ctx {
            return 0;
        }
        let b = self.cfg.block_size;
        s.backends.len() * ((ctx + b - 1) / b - s.fork_ctx / b)
    }

    /// The attention flavor of `layer`: the `ServeCfg::layers` spec when
    /// present, else every layer follows `cfg.backend`'s own flavor.
    fn layer_kind(&self, layer: usize) -> LayerKind {
        if self.cfg.layers.is_empty() {
            match self.cfg.backend {
                BackendKind::RecomputeFull | BackendKind::CachedFull => LayerKind::Full,
                _ => LayerKind::Moba,
            }
        } else {
            self.cfg.layers[layer]
        }
    }

    /// A fresh backend for one layer of one session — paged sessions
    /// share THE engine pool (that is what makes cross-request prefix
    /// sharing work), with the table layer-tagged for per-layer
    /// accounting; everything else builds private caches. A `Full` layer
    /// on gated kinds uses [`FULL_LAYER_TOPK`], which the kernels clamp
    /// to every block — bit-identical to dense attention. `topk` is
    /// normally `ServeCfg::topk`; the scheduler's pressure dial passes a
    /// smaller value for degraded low-priority sessions (only `Moba`
    /// layers downshift — `Full` layers stay dense at every dial).
    fn layer_backend_with(&self, layer: usize, topk: usize) -> Box<dyn AttentionBackend> {
        let workers = self.cfg.workers.max(1);
        let kind = self.layer_kind(layer);
        if let Some(pool) = &self.pool {
            let k = match kind {
                LayerKind::Moba => topk,
                LayerKind::Full => FULL_LAYER_TOPK,
            };
            return Box::new(
                PagedMobaAttention::new(pool.clone(), k).with_workers(workers).with_layer(layer),
            );
        }
        let backend = if self.cfg.layers.is_empty() {
            // no spec: the historical single-flavor path, bit-for-bit
            self.cfg.backend
        } else {
            match kind {
                LayerKind::Moba => match self.cfg.backend {
                    BackendKind::RecomputeFull => BackendKind::RecomputeMoba,
                    BackendKind::CachedFull => BackendKind::CachedSparse,
                    other => other,
                },
                LayerKind::Full => match self.cfg.backend {
                    BackendKind::RecomputeFull | BackendKind::RecomputeMoba => {
                        BackendKind::RecomputeFull
                    }
                    _ => BackendKind::CachedFull,
                },
            }
        };
        build_backend_par(
            backend,
            self.model.heads(),
            self.model.head_dim(),
            self.cfg.block_size,
            topk,
            workers,
        )
    }

    /// One fresh backend per model layer — a session's full stack.
    fn session_backends_with(&self, topk: usize) -> Vec<Box<dyn AttentionBackend>> {
        (0..self.n_layers()).map(|l| self.layer_backend_with(l, topk)).collect()
    }

    /// Chaos hook (`FaultKind::PoisonPool`): poison the shared pool's
    /// `RwLock` by panicking a throwaway thread while it holds the write
    /// guard. Every pool access in the serving stack goes through
    /// `util::sync`'s poison-recovering helpers, so this must be
    /// survivable end to end — the chaos tests assert serving continues
    /// bit-identically. No-op for unpooled backends.
    pub fn poison_pool_for_chaos(&self) {
        if let Some(pool) = &self.pool {
            let pool = pool.clone();
            let t = std::thread::spawn(move || {
                let _guard = sync::write(&pool);
                panic!("chaos: poisoning the paged pool lock");
            });
            let _ = t.join(); // the Err is the point
        }
    }

    /// Prefill `tokens` at positions `0..n` through the whole layer
    /// stack and return the pending next token. Layer 0 projects from
    /// token ids; each deeper layer projects q/k/v from the residual
    /// hidden stream and adds its attention output back in. Shared by
    /// `start` and non-forked resume so a resumed session goes through
    /// the exact same path (bit-identical outputs) as one that was never
    /// evicted.
    fn prefill_tokens(
        &self,
        backends: &mut [Box<dyn AttentionBackend>],
        tokens: &[i32],
    ) -> Result<i32> {
        let (h, d) = (self.model.heads(), self.model.head_dim());
        let n = tokens.len();
        let w = h * d;
        let (mut qs, mut ks, mut vs) =
            (Vec::with_capacity(n * w), Vec::with_capacity(n * w), Vec::with_capacity(n * w));
        let (mut qr, mut kr, mut vr) = (Vec::new(), Vec::new(), Vec::new());
        for (pos, &tok) in tokens.iter().enumerate() {
            self.model.qkv_into(tok, pos, &mut qr, &mut kr, &mut vr);
            qs.extend_from_slice(&qr);
            ks.extend_from_slice(&kr);
            vs.extend_from_slice(&vr);
        }
        let (first, rest) = backends.split_first_mut().expect("session has at least one layer");
        let q = Tensor::from_vec(&[n, h, d], qs)?;
        let k = Tensor::from_vec(&[n, h, d], ks)?;
        let v = Tensor::from_vec(&[n, h, d], vs)?;
        let mut hidden = first.prefill(&q, &k, &v).data;
        for (li, backend) in rest.iter_mut().enumerate() {
            let layer = li + 1;
            let (mut qs, mut ks, mut vs) =
                (Vec::with_capacity(n * w), Vec::with_capacity(n * w), Vec::with_capacity(n * w));
            for pos in 0..n {
                let row = &hidden[pos * w..(pos + 1) * w];
                self.model.qkv_layer_into(layer, pos, row, &mut qr, &mut kr, &mut vr);
                qs.extend_from_slice(&qr);
                ks.extend_from_slice(&kr);
                vs.extend_from_slice(&vr);
            }
            let q = Tensor::from_vec(&[n, h, d], qs)?;
            let k = Tensor::from_vec(&[n, h, d], ks)?;
            let v = Tensor::from_vec(&[n, h, d], vs)?;
            let out = backend.prefill(&q, &k, &v);
            for (hx, ox) in hidden.iter_mut().zip(&out.data) {
                *hx += ox;
            }
        }
        Ok(argmax(&self.model.logits(&hidden[(n - 1) * w..n * w])))
    }

    /// Advance every layer by one token row. Layer 0 projects from the
    /// token id, deeper layers from the residual hidden stream;
    /// `sc.hidden` ends as the final residual row (what logits read).
    /// Row-wise identical to `prefill_tokens`: the prefill/decode
    /// boundary is invisible per layer (the kernel parity contract), so
    /// it stays invisible through the whole stack by induction on the
    /// hidden stream.
    fn decode_row(
        &self,
        backends: &mut [Box<dyn AttentionBackend>],
        tok: i32,
        pos: usize,
        sc: &mut StepScratch,
    ) {
        let (first, rest) = backends.split_first_mut().expect("session has at least one layer");
        self.model.qkv_into(tok, pos, &mut sc.q, &mut sc.k, &mut sc.v);
        let out = first.decode(&sc.q, &sc.k, &sc.v);
        sc.hidden.clear();
        sc.hidden.extend_from_slice(&out);
        for (li, backend) in rest.iter_mut().enumerate() {
            self.model.qkv_layer_into(li + 1, pos, &sc.hidden, &mut sc.q, &mut sc.k, &mut sc.v);
            let out = backend.decode(&sc.q, &sc.k, &sc.v);
            for (hx, ox) in sc.hidden.iter_mut().zip(&out) {
                *hx += ox;
            }
        }
    }

    /// Fork every layer of `parent`'s stack and ingest `tokens` one
    /// decode row at a time (positions continue from the parent's
    /// context). Returns the forked stack and the pending next token.
    /// Shared by `fork_session` and forked-session resume.
    fn fork_ingest(
        &self,
        parent: &DecodeSession,
        tokens: &[i32],
    ) -> Result<(Vec<Box<dyn AttentionBackend>>, i32)> {
        let ctx = parent.ctx();
        let mut backends = Vec::with_capacity(parent.backends.len());
        for b in &parent.backends {
            backends.push(b.fork()?);
        }
        let mut sc = StepScratch::default();
        for (i, &tok) in tokens.iter().enumerate() {
            self.decode_row(&mut backends, tok, ctx + i, &mut sc);
        }
        // only the final position's logits decide the pending token — an
        // empty continuation is a pure clone of the parent's
        let pending = if tokens.is_empty() {
            match parent.pending {
                Some(p) => p,
                None => bail!("empty-continuation fork of a session with no pending token"),
            }
        } else {
            self.model.logits_into(&sc.hidden, &mut sc.logits);
            argmax(&sc.logits)
        };
        Ok((backends, pending))
    }

    /// Prefill `prompt` through a fresh backend stack and return the
    /// live session with its first pending token.
    pub fn start(&self, prompt: &[i32], max_new: usize) -> Result<DecodeSession> {
        self.start_with_topk(prompt, max_new, self.cfg.topk)
    }

    /// `start` with an explicit MoBA top-k — the degradation-dial entry
    /// point. The session remembers `topk`, so later evict/resume cycles
    /// rebuild it with the same sparsity.
    pub fn start_with_topk(
        &self,
        prompt: &[i32],
        max_new: usize,
        topk: usize,
    ) -> Result<DecodeSession> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if prompt.len() + max_new > self.cfg.max_seq {
            bail!(
                "prompt {} + max_new {} exceeds max_seq {}",
                prompt.len(),
                max_new,
                self.cfg.max_seq
            );
        }
        let mut backends = self.session_backends_with(topk);
        let t0 = Instant::now();
        let pending = self.prefill_tokens(&mut backends, prompt)?;
        let stats = GenStats { prefill_secs: t0.elapsed().as_secs_f64(), ..Default::default() };

        Ok(DecodeSession {
            backends,
            prompt_len: prompt.len(),
            own_prompt: prompt.to_vec(),
            fork_ctx: 0,
            evicted: false,
            max_seq: self.cfg.max_seq,
            max_new,
            pending: Some(pending),
            generated: Vec::with_capacity(max_new),
            topk,
            scratch: StepScratch::default(),
            stats,
        })
    }

    /// Fork `parent`'s state copy-on-write (paged backend only) and
    /// ingest `continuation` on the fork — the shared-system-prompt
    /// serving scenario: S sessions share one physical prefix per layer,
    /// each pays only its own divergent tail. Token-identical to
    /// `start(prefix ++ continuation)` on a private backend: the decode
    /// rows that ingest the continuation are bit-equal to the prefill
    /// rows a private session would compute (the prefill/decode boundary
    /// is invisible — `tests/property_invariants.rs`).
    pub fn fork_session(
        &self,
        parent: &DecodeSession,
        continuation: &[i32],
        max_new: usize,
    ) -> Result<DecodeSession> {
        let ctx = parent.ctx();
        if ctx + continuation.len() + max_new > self.cfg.max_seq {
            bail!(
                "prefix {} + continuation {} + max_new {} exceeds max_seq {}",
                ctx,
                continuation.len(),
                max_new,
                self.cfg.max_seq
            );
        }
        let t0 = Instant::now();
        let (backends, pending) = self.fork_ingest(parent, continuation)?;
        let stats = GenStats { prefill_secs: t0.elapsed().as_secs_f64(), ..Default::default() };
        Ok(DecodeSession {
            backends,
            prompt_len: ctx + continuation.len(),
            own_prompt: continuation.to_vec(),
            fork_ctx: ctx,
            evicted: false,
            max_seq: self.cfg.max_seq,
            max_new,
            pending: Some(pending),
            generated: Vec::with_capacity(max_new),
            // the forked backends ARE forks of the parent's gating state,
            // so the fork inherits the parent's sparsity, not `cfg.topk`
            topk: parent.topk,
            scratch: StepScratch::default(),
            stats,
        })
    }

    /// Preempt `s`: release every layer's pool blocks back to the shared
    /// paged pool and return how many were actually reclaimed (blocks a
    /// live table still shares — a system prefix under other sessions —
    /// survive). The session keeps its prompt, generated tokens and
    /// pending token, which is exactly enough for `resume_session` to
    /// rebuild it bit-identically. Paged backend only — a stack is
    /// homogeneous in pooled-ness, so if layer 0 refuses nothing has
    /// been released when the error propagates.
    pub fn evict_session(&self, s: &mut DecodeSession) -> Result<usize> {
        if s.evicted {
            bail!("session is already evicted");
        }
        let mut freed = 0;
        for b in &mut s.backends {
            freed += b.evict()?;
        }
        s.evicted = true;
        Ok(freed)
    }

    /// Preempt `s` into the host swap tier: snapshot every layer's
    /// private tail — each block from the fork point on (for an unforked
    /// session, the whole context) — into a byte-exact, checksummed
    /// per-layer [`SwapBundle`], then release its pool blocks exactly
    /// like `evict_session`. The refcounted shared prefixes are NOT
    /// captured: they stay resident under the prefix parent, so a
    /// swapped fork resumes via `fork_prefix` + block restore with no
    /// `fork_ingest` recompute. Snapshots happen before any release
    /// (copy-only), so a failure part-way leaves the session live and
    /// untouched. Returns `(blocks freed, bundle)`. Paged backend only;
    /// the caller owns the bundle (the engine is stateless across
    /// requests).
    pub fn swap_out_session(&self, s: &mut DecodeSession) -> Result<(usize, SwapBundle)> {
        if s.evicted {
            bail!("swap-out of a session that is already evicted");
        }
        if s.pending.is_none() {
            bail!("swap-out of a session with no pending token");
        }
        let from_block = s.fork_ctx / self.cfg.block_size;
        let mut images = Vec::with_capacity(s.backends.len());
        for b in &s.backends {
            images.push(b.swap_out(from_block)?);
        }
        let mut freed = 0;
        for b in &mut s.backends {
            freed += b.evict()?;
        }
        s.evicted = true;
        Ok((freed, SwapBundle { images }))
    }

    /// Resume a swapped-out session by restoring its [`SwapBundle`]
    /// bytes into freshly allocated pool blocks instead of re-prefilling
    /// — the restored state is byte-identical to the pre-swap state, so
    /// every token served afterwards is bit-identical to a session that
    /// was never preempted. A forked session re-forks each layer of
    /// `parent`'s resident full-block prefix (`fork_prefix`); the
    /// restore then allocates exactly the blocks a re-prefill resume
    /// would, so pool occupancy — and every downstream scheduling
    /// decision — is identical between the two resume paths. The bundle
    /// restores atomically: the whole replacement stack is built before
    /// the session is touched, so on ANY failure (checksum mismatch,
    /// prefix mismatch, allocation failure — at any layer) the partial
    /// stack drops, its blocks release, and the session is left evicted
    /// with its transcript intact for the transparent `resume_session`
    /// fallback.
    pub fn swap_in_session(
        &self,
        s: &mut DecodeSession,
        parent: Option<&DecodeSession>,
        bundle: &SwapBundle,
    ) -> Result<()> {
        if !s.evicted {
            bail!("swap-in of a session that was never evicted");
        }
        if s.pending.is_none() {
            // restore rebuilds cached state but computes no logits: a
            // session whose pending token died with its worker can only
            // come back through the re-prefill path
            bail!("swap-in of a session with no pending token");
        }
        if bundle.layers() != s.backends.len() {
            bail!(
                "swap bundle has {} layer images but the session has {} layers",
                bundle.layers(),
                s.backends.len()
            );
        }
        let parent = if s.fork_ctx > 0 {
            let Some(parent) = parent else {
                bail!("swap-in of a forked session needs its prefix parent");
            };
            if parent.ctx() != s.fork_ctx {
                bail!(
                    "prefix parent context {} does not match fork point {}",
                    parent.ctx(),
                    s.fork_ctx
                );
            }
            if parent.backends.len() != s.backends.len() {
                bail!(
                    "prefix parent has {} layers but the session has {}",
                    parent.backends.len(),
                    s.backends.len()
                );
            }
            Some(parent)
        } else {
            None
        };
        let want = s.prompt_len + s.generated.len();
        let mut backends = Vec::with_capacity(s.backends.len());
        for (layer, image) in bundle.images().iter().enumerate() {
            let mut backend = match parent {
                Some(p) => p.backends[layer].fork_prefix(s.fork_ctx / self.cfg.block_size)?,
                None => self.layer_backend_with(layer, s.topk),
            };
            backend.swap_in(image)?;
            let got = backend.seq_len();
            if got != want {
                // dropping the partial stack releases whatever it
                // allocated; `s` stays evicted so re-prefill still works
                return Err(ServeError::ResumeDiverged {
                    what: "restored context length",
                    expected: want as i64,
                    got: got as i64,
                }
                .into());
            }
            backends.push(backend);
        }
        s.backends = backends;
        s.evicted = false;
        s.stats.resumes += 1;
        // reprefill_secs intentionally untouched: it prices re-prefill
        // work specifically, and the bench compares the two resume paths
        Ok(())
    }

    /// Force-preempt a session recovered from a faulted worker: release
    /// whatever pool blocks its backends can still release (best-effort,
    /// every layer — a private-cache backend frees nothing here; its
    /// caches are replaced wholesale at resume) and mark it evicted so
    /// the only way forward is `resume_session`'s re-prefill. With
    /// `pending_valid == false` (the session's own step panicked, so its
    /// in-memory pending token may be mid-mutation garbage) the pending
    /// token is cleared to `None` and recomputed at resume from the
    /// transcript, which a panic cannot corrupt: tokens are appended
    /// only after a fully completed step.
    pub fn quarantine_session(&self, s: &mut DecodeSession, pending_valid: bool) -> usize {
        let mut freed = 0;
        for b in &mut s.backends {
            freed += b.evict().unwrap_or(0);
        }
        s.evicted = true;
        if !pending_valid {
            s.pending = None;
        }
        freed
    }

    /// Rebuild a session lost with a dead worker from its ledger
    /// transcript: the identity (own prompt, fork point, budget) plus the
    /// tokens generated so far. The result is evicted-with-no-blocks
    /// (placeholder backend stack, pending unknown); `resume_session`
    /// turns it back into a live session bit-identical to one that never
    /// died — same argument as any other re-prefill resume, the
    /// transcript is the whole state. Per-session latency stats die with
    /// the worker; `queue_secs` survives on the scheduler side.
    pub fn adopt_session(
        &self,
        own_prompt: Vec<i32>,
        fork_ctx: usize,
        generated: Vec<i32>,
        max_new: usize,
        topk: usize,
    ) -> DecodeSession {
        DecodeSession {
            backends: self.session_backends_with(topk),
            prompt_len: fork_ctx + own_prompt.len(),
            own_prompt,
            fork_ctx,
            evicted: true,
            max_seq: self.cfg.max_seq,
            max_new,
            pending: None,
            generated,
            topk,
            scratch: StepScratch::default(),
            stats: GenStats::default(),
        }
    }

    /// Rebuild an evicted session's incremental state by re-ingesting
    /// `own_prompt ++ generated` through the same prefill/fork-decode
    /// path it was originally built with. A forked session re-forks
    /// `parent` (the shared per-layer prefixes whose blocks survived
    /// eviction), so the prefix is still never duplicated. The rebuilt
    /// state — and every token served afterwards — is bit-identical to a
    /// session that was never evicted: the prefill/decode boundary is
    /// invisible and both paths share the kernels' fixed accumulation
    /// orders.
    pub fn resume_session(
        &self,
        s: &mut DecodeSession,
        parent: Option<&DecodeSession>,
    ) -> Result<()> {
        if !s.evicted {
            bail!("resume of a session that was never evicted");
        }
        let t0 = Instant::now();
        let tokens: Vec<i32> = s.own_prompt.iter().chain(&s.generated).copied().collect();
        let pending = if s.fork_ctx > 0 {
            let Some(parent) = parent else {
                bail!("resume of a forked session needs its prefix parent");
            };
            if parent.ctx() != s.fork_ctx {
                bail!(
                    "prefix parent context {} does not match fork point {}",
                    parent.ctx(),
                    s.fork_ctx
                );
            }
            let (backends, pending) = self.fork_ingest(parent, &tokens)?;
            s.backends = backends;
            pending
        } else {
            let mut backends = self.session_backends_with(s.topk);
            let pending = self.prefill_tokens(&mut backends, &tokens)?;
            s.backends = backends;
            pending
        };
        // a real check, not a debug_assert: in release builds a divergent
        // resume would otherwise silently serve wrong tokens. `None`
        // (fault-wiped pending) has nothing to compare against — the
        // recomputed token is authoritative there.
        if let Some(prev) = s.pending {
            if pending != prev {
                return Err(ServeError::ResumeDiverged {
                    what: "re-prefill pending token",
                    expected: prev as i64,
                    got: pending as i64,
                }
                .into());
            }
        }
        s.pending = Some(pending);
        s.evicted = false;
        s.stats.resumes += 1;
        s.stats.reprefill_secs += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// One decode step: emit the session's pending token, append it to
    /// every layer's incremental state and compute the next. Returns the
    /// emitted token, or `None` if the session is already finished.
    pub fn step(&self, s: &mut DecodeSession) -> Option<i32> {
        debug_assert!(!s.evicted, "stepping an evicted session (resume it first)");
        debug_assert!(s.pending.is_some(), "stepping a session with no pending token");
        if s.finished() {
            return None;
        }
        let tok = s.pending?;
        s.generated.push(tok);
        if s.finished() {
            return Some(tok); // budget exhausted: no need to compute a successor
        }
        let t0 = Instant::now();
        let pos = s.prompt_len + s.generated.len() - 1;
        self.decode_row(&mut s.backends, tok, pos, &mut s.scratch);
        self.model.logits_into(&s.scratch.hidden, &mut s.scratch.logits);
        s.pending = Some(argmax(&s.scratch.logits));
        s.stats.decode_secs += t0.elapsed().as_secs_f64();
        s.stats.decode_steps += 1;
        Some(tok)
    }

    /// Greedy generation, single request: prefill + run the session to
    /// completion. Returns (generated tokens, stats).
    pub fn generate(&self, prompt: &[i32], max_new: usize) -> Result<(Vec<i32>, GenStats)> {
        let mut session = self.start(prompt, max_new)?;
        while self.step(&mut session).is_some() {}
        let DecodeSession { generated, stats, .. } = session;
        Ok((generated, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::model::ToyModel;

    fn engine(backend: BackendKind) -> ServeEngine<ToyModel> {
        ServeEngine::new(
            ToyModel::new(48, 2, 8, 11),
            ServeCfg { block_size: 16, topk: 2, max_seq: 256, backend, ..Default::default() },
        )
    }

    /// A paged engine over an `layers.len()`-deep stacked model with an
    /// explicit per-layer spec (same geometry/seed as [`engine`]).
    fn stacked_engine(layers: Vec<LayerKind>, pool_blocks: usize) -> ServeEngine<ToyModel> {
        ServeEngine::new(
            ToyModel::stacked(48, 2, 8, 11, layers.len().max(1)),
            ServeCfg {
                block_size: 16,
                topk: 2,
                max_seq: 256,
                backend: BackendKind::Paged,
                pool_blocks,
                layers,
                ..Default::default()
            },
        )
    }

    #[test]
    fn generates_requested_tokens() {
        let e = engine(BackendKind::CachedSparse);
        let prompt: Vec<i32> = (0..40).map(|i| i % 48).collect();
        let (out, stats) = e.generate(&prompt, 6).unwrap();
        assert_eq!(out.len(), 6);
        assert_eq!(stats.decode_steps, 5); // last token needs no successor
        assert!(stats.prefill_secs > 0.0);
    }

    #[test]
    fn cached_decode_matches_recompute_decode() {
        // the serving-level restatement of the kernel parity: same tokens
        // out of the cached backend and the recompute baselines
        let prompt: Vec<i32> = (0..50).map(|i| (i * 7) % 48).collect();
        let reference = engine(BackendKind::RecomputeFull).generate(&prompt, 8).unwrap().0;
        let cached = engine(BackendKind::CachedFull).generate(&prompt, 8).unwrap().0;
        assert_eq!(cached, reference);
        let sparse_ref = engine(BackendKind::RecomputeMoba).generate(&prompt, 8).unwrap().0;
        let sparse_cached = engine(BackendKind::CachedSparse).generate(&prompt, 8).unwrap().0;
        assert_eq!(sparse_cached, sparse_ref);
        let fused = engine(BackendKind::Fused).generate(&prompt, 8).unwrap().0;
        assert_eq!(fused, sparse_ref);
        let paged = engine(BackendKind::Paged).generate(&prompt, 8).unwrap().0;
        assert_eq!(paged, sparse_ref);
    }

    #[test]
    fn forked_session_tokens_match_private_full_prompt() {
        // shared system prefix + divergent continuations through the
        // pool == private sessions over the concatenated prompts
        let e = engine(BackendKind::Paged);
        let prefix: Vec<i32> = (0..40).map(|i| (i * 3) % 48).collect();
        let parent = e.start(&prefix, 0).unwrap();
        let private = engine(BackendKind::CachedSparse);
        for salt in [1i32, 2, 3] {
            let cont: Vec<i32> = (0..9).map(|i| (i * 5 + salt) % 48).collect();
            let mut forked = e.fork_session(&parent, &cont, 6).unwrap();
            let mut got = Vec::new();
            while let Some(tok) = e.step(&mut forked) {
                got.push(tok);
            }
            let full: Vec<i32> = prefix.iter().chain(&cont).copied().collect();
            let want = private.generate(&full, 6).unwrap().0;
            assert_eq!(got, want, "salt={salt}");
        }
        // S sessions shared one prefix: the pool holds the prefix once
        let status = e.pool_status().unwrap();
        assert!(status.used_blocks >= prefix.len() / 16);
        assert!(status.payload_bytes > 0);
    }

    #[test]
    fn fork_rejects_private_backends_and_overflow() {
        let e = engine(BackendKind::CachedSparse);
        let parent = e.start(&[1, 2, 3], 0).unwrap();
        assert!(e.fork_session(&parent, &[4, 5], 4).is_err());
        let p = engine(BackendKind::Paged);
        let parent = p.start(&[1, 2, 3], 0).unwrap();
        assert!(p.fork_session(&parent, &[4, 5], 300).is_err(), "max_seq overflow");
        // empty continuation is a pure clone: same pending token
        let clone = p.fork_session(&parent, &[], 4).unwrap();
        assert_eq!(clone.context_len(), parent.context_len());
    }

    #[test]
    fn block_reserve_is_conservative() {
        let e = engine(BackendKind::Paged);
        // block 16: tokens [40, 60) span blocks 2..4 — the first spanned
        // block is the CoW copy of the shared 8-token tail, not an extra
        assert_eq!(e.block_reserve(40, 20), 2);
        assert_eq!(e.block_reserve(0, 16), 1);
        assert_eq!(e.block_reserve(0, 17), 2);
        // zero appends allocate zero blocks, even mid-block
        assert_eq!(e.block_reserve(40, 0), 0);
        let status = e.pool_status().unwrap();
        assert_eq!(status.capacity_blocks, None);
        assert_eq!(status.used_blocks, 0);
    }

    #[test]
    fn remaining_reserve_shrinks_to_the_unmaterialized_delta() {
        let e = engine(BackendKind::Paged);
        // prompt 4 + max_new 13: worst case 2 blocks at admission, but
        // after prefill the private tail's 12 open slots absorb all 12
        // future appends — nothing left to reserve
        let prompt: Vec<i32> = (0..4).collect();
        let mut s = e.start(&prompt, 13).unwrap();
        assert_eq!(e.block_reserve(0, 4 + 13), 2);
        assert_eq!(e.remaining_reserve(&s), 0, "open tail slots absorb all appends");
        // prompt 14 + max_new 8: 7 appends, 2 open slots -> 1 new block
        let s2 = e.start(&(0..14).collect::<Vec<i32>>(), 8).unwrap();
        assert_eq!(e.remaining_reserve(&s2), 1);
        // a finished session reserves nothing
        while e.step(&mut s).is_some() {}
        assert_eq!(e.remaining_reserve(&s), 0);
    }

    #[test]
    fn forked_remaining_reserve_counts_the_cow_tail_once() {
        let e = engine(BackendKind::Paged);
        let prefix: Vec<i32> = (0..40).map(|i| i % 48).collect(); // 8-token shared tail
        let parent = e.start(&prefix, 0).unwrap();
        // freshly forked, no own tokens yet: first append must CoW the
        // shared partial tail, so the spanned-block count applies
        let f = e.fork_session(&parent, &[], 9).unwrap();
        assert_eq!(e.remaining_reserve(&f), e.block_reserve(40, 8));
        // after ingesting its own continuation the tail is private: open
        // slots absorb appends (44 tokens -> 4 open slots, 5 appends)
        let f2 = e.fork_session(&parent, &[1, 2, 3, 4], 6).unwrap();
        assert_eq!(e.remaining_reserve(&f2), 1);
    }

    #[test]
    fn evicted_session_resumes_bit_identically() {
        let e = engine(BackendKind::Paged);
        let prompt: Vec<i32> = (0..30).map(|i| (i * 7) % 48).collect();
        let (want, _) = e.generate(&prompt, 8).unwrap();
        let mut s = e.start(&prompt, 8).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(e.step(&mut s).unwrap());
        }
        let used_before = e.pool_status().unwrap().used_blocks;
        let freed = e.evict_session(&mut s).unwrap();
        assert!(freed > 0);
        assert!(s.evicted());
        assert_eq!(e.pool_status().unwrap().used_blocks, used_before - freed);
        // the resume reservation covers re-materializing prompt+generated
        assert_eq!(e.resume_reserve(&s), e.block_reserve(0, prompt.len() + 3 + 4));
        assert!(e.evict_session(&mut s).is_err(), "double eviction");
        e.resume_session(&mut s, None).unwrap();
        assert!(!s.evicted());
        assert_eq!(s.stats.resumes, 1);
        assert!(s.stats.reprefill_secs > 0.0);
        while let Some(tok) = e.step(&mut s) {
            got.push(tok);
        }
        assert_eq!(got, want, "resume changed the served tokens");
        assert!(e.resume_session(&mut s, None).is_err(), "resume of a live session");
    }

    #[test]
    fn evicted_fork_resumes_off_its_prefix_parent() {
        let e = engine(BackendKind::Paged);
        let prefix: Vec<i32> = (0..40).map(|i| (i * 3) % 48).collect();
        let parent = e.start(&prefix, 0).unwrap();
        let cont: Vec<i32> = (0..9).map(|i| (i * 5 + 1) % 48).collect();
        let mut twin = e.fork_session(&parent, &cont, 7).unwrap();
        let mut victim = e.fork_session(&parent, &cont, 7).unwrap();
        let mut want = Vec::new();
        let mut got = Vec::new();
        for _ in 0..2 {
            want.push(e.step(&mut twin).unwrap());
            got.push(e.step(&mut victim).unwrap());
        }
        let prefix_blocks = (prefix.len() + 15) / 16;
        e.evict_session(&mut victim).unwrap();
        assert!(
            e.pool_status().unwrap().used_blocks >= prefix_blocks,
            "shared prefix blocks must survive the forker's eviction"
        );
        // resume requires the parent (and the right one)
        assert!(e.resume_session(&mut victim, None).is_err());
        e.resume_session(&mut victim, Some(&parent)).unwrap();
        loop {
            match (e.step(&mut twin), e.step(&mut victim)) {
                (Some(a), Some(b)) => {
                    want.push(a);
                    got.push(b);
                }
                (None, None) => break,
                _ => panic!("twin and resumed fork disagree on length"),
            }
        }
        assert_eq!(got, want, "resumed fork diverged from its never-evicted twin");
    }

    #[test]
    fn swapped_session_resumes_bit_identically() {
        let e = engine(BackendKind::Paged);
        let prompt: Vec<i32> = (0..30).map(|i| (i * 7) % 48).collect();
        let (want, _) = e.generate(&prompt, 8).unwrap();
        let mut s = e.start(&prompt, 8).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(e.step(&mut s).unwrap());
        }
        let used_before = e.pool_status().unwrap().used_blocks;
        let (freed, image) = e.swap_out_session(&mut s).unwrap();
        assert!(freed > 0);
        assert!(s.evicted());
        assert_eq!(e.pool_status().unwrap().used_blocks, used_before - freed);
        // the whole context is private (unforked), so the image holds it all
        assert_eq!(image.tokens(), prompt.len() + 3);
        assert!(image.payload_bytes() > 0);
        assert_eq!(image.layers(), 1, "L=1 session swaps a single-image bundle");
        assert!(e.swap_out_session(&mut s).is_err(), "double swap-out");
        e.swap_in_session(&mut s, None, &image).unwrap();
        assert!(!s.evicted());
        assert_eq!(s.stats.resumes, 1);
        assert_eq!(s.stats.reprefill_secs, 0.0, "swap-in must not be billed as re-prefill");
        // restore allocates exactly what eviction freed: occupancy parity
        // with a re-prefill resume (and with never having been preempted)
        assert_eq!(e.pool_status().unwrap().used_blocks, used_before);
        while let Some(tok) = e.step(&mut s) {
            got.push(tok);
        }
        assert_eq!(got, want, "swap round-trip changed the served tokens");
    }

    #[test]
    fn swapped_fork_resumes_off_its_resident_prefix() {
        let e = engine(BackendKind::Paged);
        let prefix: Vec<i32> = (0..40).map(|i| (i * 3) % 48).collect();
        let parent = e.start(&prefix, 0).unwrap();
        let cont: Vec<i32> = (0..9).map(|i| (i * 5 + 1) % 48).collect();
        let mut twin = e.fork_session(&parent, &cont, 7).unwrap();
        let mut victim = e.fork_session(&parent, &cont, 7).unwrap();
        let mut want = Vec::new();
        let mut got = Vec::new();
        for _ in 0..2 {
            want.push(e.step(&mut twin).unwrap());
            got.push(e.step(&mut victim).unwrap());
        }
        let (freed, image) = e.swap_out_session(&mut victim).unwrap();
        assert!(freed > 0);
        // suffix-only: the image starts at the fork point's block, the
        // shared prefix stays resident under the parent
        assert_eq!(image.first_block(), prefix.len() / 16);
        assert!(
            e.pool_status().unwrap().used_blocks >= (prefix.len() + 15) / 16,
            "shared prefix blocks must survive the forker's swap-out"
        );
        // swap-in requires the parent, exactly like a re-prefill resume
        assert!(e.swap_in_session(&mut victim, None, &image).is_err());
        assert!(victim.evicted(), "failed swap-in must leave the session evicted");
        e.swap_in_session(&mut victim, Some(&parent), &image).unwrap();
        loop {
            match (e.step(&mut twin), e.step(&mut victim)) {
                (Some(a), Some(b)) => {
                    want.push(a);
                    got.push(b);
                }
                (None, None) => break,
                _ => panic!("twin and swapped fork disagree on length"),
            }
        }
        assert_eq!(got, want, "swapped fork diverged from its never-preempted twin");
    }

    #[test]
    fn corrupted_swap_image_falls_back_to_reprefill() {
        let e = engine(BackendKind::Paged);
        let prompt: Vec<i32> = (0..30).map(|i| (i * 7) % 48).collect();
        let (want, _) = e.generate(&prompt, 8).unwrap();
        let mut s = e.start(&prompt, 8).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(e.step(&mut s).unwrap());
        }
        let (_, mut image) = e.swap_out_session(&mut s).unwrap();
        let used_evicted = e.pool_status().unwrap().used_blocks;
        image.corrupt_for_chaos();
        let err = e.swap_in_session(&mut s, None, &image).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        assert!(s.evicted(), "failed swap-in must leave the session evicted");
        assert_eq!(
            e.pool_status().unwrap().used_blocks,
            used_evicted,
            "failed swap-in must not leak pool blocks"
        );
        // the transparent fallback: plain re-prefill resume still works
        e.resume_session(&mut s, None).unwrap();
        while let Some(tok) = e.step(&mut s) {
            got.push(tok);
        }
        assert_eq!(got, want, "fallback resume changed the served tokens");
    }

    #[test]
    fn quarantined_session_resumes_bit_identically() {
        let e = engine(BackendKind::Paged);
        let prompt: Vec<i32> = (0..30).map(|i| (i * 7) % 48).collect();
        let (want, _) = e.generate(&prompt, 8).unwrap();
        let mut s = e.start(&prompt, 8).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(e.step(&mut s).unwrap());
        }
        // pending treated as mid-mutation garbage: quarantine wipes it and
        // resume recomputes it from the transcript
        let freed = e.quarantine_session(&mut s, false);
        assert!(freed > 0);
        assert!(s.evicted());
        e.resume_session(&mut s, None).unwrap();
        while let Some(t) = e.step(&mut s) {
            got.push(t);
        }
        assert_eq!(got, want, "quarantine + resume changed the served tokens");
    }

    #[test]
    fn quarantine_works_on_private_backends() {
        let e = engine(BackendKind::CachedSparse);
        let prompt: Vec<i32> = (0..20).collect();
        let (want, _) = e.generate(&prompt, 6).unwrap();
        let mut s = e.start(&prompt, 6).unwrap();
        let mut got = vec![e.step(&mut s).unwrap()];
        assert_eq!(e.quarantine_session(&mut s, false), 0, "private caches free no pool blocks");
        e.resume_session(&mut s, None).unwrap();
        while let Some(t) = e.step(&mut s) {
            got.push(t);
        }
        assert_eq!(got, want);
    }

    #[test]
    fn adopted_session_resumes_from_transcript_alone() {
        let e = engine(BackendKind::Paged);
        let prompt: Vec<i32> = (0..25).map(|i| (i * 5) % 48).collect();
        let (want, _) = e.generate(&prompt, 7).unwrap();
        // a fault-free twin ran 4 steps before its worker died with the
        // struct, leaving only the ledger transcript
        let mut adopted = e.adopt_session(prompt.clone(), 0, want[..4].to_vec(), 7, 2);
        assert!(adopted.evicted());
        e.resume_session(&mut adopted, None).unwrap();
        let mut got = want[..4].to_vec();
        while let Some(t) = e.step(&mut adopted) {
            got.push(t);
        }
        assert_eq!(got, want, "adoption lost or corrupted transcript state");
    }

    #[test]
    fn eviction_rejects_private_backends() {
        let e = engine(BackendKind::CachedSparse);
        let mut s = e.start(&[1, 2, 3], 4).unwrap();
        assert!(e.evict_session(&mut s).is_err());
        assert!(!s.evicted());
    }

    #[test]
    fn stepwise_equals_generate() {
        let e = engine(BackendKind::CachedSparse);
        let prompt: Vec<i32> = (0..33).map(|i| i % 48).collect();
        let (out, _) = e.generate(&prompt, 5).unwrap();
        let mut s = e.start(&prompt, 5).unwrap();
        let mut stepped = Vec::new();
        while let Some(tok) = e.step(&mut s) {
            stepped.push(tok);
        }
        assert_eq!(stepped, out);
        assert!(s.finished());
        assert_eq!(s.output(), out.as_slice());
        // context = prompt + generated minus the final (never-appended) token
        assert_eq!(s.context_len(), prompt.len() + 4);
    }

    #[test]
    fn rejects_bad_requests() {
        let e = engine(BackendKind::CachedSparse);
        assert!(e.start(&[], 4).is_err());
        let long: Vec<i32> = vec![1; 300];
        assert!(e.start(&long, 4).is_err());
    }

    #[test]
    fn degraded_topk_session_matches_a_lower_topk_engine_and_survives_eviction() {
        // start_with_topk(k') must serve exactly what an engine configured
        // with topk=k' serves, and an evict/resume cycle must rebuild the
        // degraded session with the SAME sparsity (not cfg.topk)
        let e = engine(BackendKind::Paged);
        let lower = ServeEngine::new(
            ToyModel::new(48, 2, 8, 11),
            ServeCfg {
                block_size: 16,
                topk: 1,
                max_seq: 256,
                backend: BackendKind::Paged,
                ..Default::default()
            },
        );
        let prompt: Vec<i32> = (0..50).map(|i| (i * 7) % 48).collect();
        let (want, _) = lower.generate(&prompt, 8).unwrap();
        let mut s = e.start_with_topk(&prompt, 8, 1).unwrap();
        assert_eq!(s.topk(), 1);
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(e.step(&mut s).unwrap());
        }
        e.evict_session(&mut s).unwrap();
        e.resume_session(&mut s, None).unwrap();
        assert_eq!(s.topk(), 1, "resume must keep the degraded sparsity");
        while let Some(t) = e.step(&mut s) {
            got.push(t);
        }
        assert_eq!(got, want, "degraded session diverged from a topk=1 engine");
        // sanity: degradation actually changes tokens on this geometry,
        // otherwise the parity above proves nothing
        assert_ne!(want, e.generate(&prompt, 8).unwrap().0);
    }

    #[test]
    fn poisoned_pool_lock_is_survivable() {
        let e = engine(BackendKind::Paged);
        let prompt: Vec<i32> = (0..30).map(|i| (i * 7) % 48).collect();
        let (want, _) = e.generate(&prompt, 8).unwrap();
        let mut s = e.start(&prompt, 8).unwrap();
        let mut got = vec![e.step(&mut s).unwrap()];
        e.poison_pool_for_chaos();
        // pool accounting and stepping go through the poison-recovering
        // sync helpers, so everything keeps working bit-identically
        assert!(e.pool_status().unwrap().used_blocks > 0);
        while let Some(t) = e.step(&mut s) {
            got.push(t);
        }
        assert_eq!(got, want, "pool poisoning changed served tokens");
        // no-op on unpooled engines
        engine(BackendKind::CachedSparse).poison_pool_for_chaos();
    }

    #[test]
    fn zero_budget_session_is_finished_immediately() {
        let e = engine(BackendKind::CachedSparse);
        let mut s = e.start(&[1, 2, 3], 0).unwrap();
        assert!(s.finished());
        assert_eq!(e.step(&mut s), None);
        assert!(s.output().is_empty());
    }

    // ------------------------------------------------------------------
    // multi-layer hybrid stacks
    // ------------------------------------------------------------------

    #[test]
    fn explicit_single_moba_layer_spec_is_bitwise_identical() {
        // the --layers compatibility anchor: an explicit L=1 `moba` spec
        // serves exactly what the unspecced historical path serves
        let prompt: Vec<i32> = (0..50).map(|i| (i * 7) % 48).collect();
        let want = engine(BackendKind::Paged).generate(&prompt, 8).unwrap().0;
        let speced = stacked_engine(vec![LayerKind::Moba], 0);
        assert_eq!(speced.generate(&prompt, 8).unwrap().0, want);
    }

    #[test]
    fn hybrid_stack_accounts_blocks_per_layer() {
        let layers = vec![LayerKind::Moba, LayerKind::Moba, LayerKind::Full, LayerKind::Moba];
        let e = stacked_engine(layers, 0);
        let prompt: Vec<i32> = (0..40).map(|i| i % 48).collect();
        let mut s = e.start(&prompt, 16).unwrap();
        assert_eq!(s.layers(), 4);
        for _ in 0..3 {
            e.step(&mut s).unwrap();
        }
        let per_layer = e.pool_layer_usage().unwrap();
        let status = e.pool_status().unwrap();
        assert_eq!(per_layer.len(), 4);
        assert_eq!(per_layer.iter().sum::<usize>(), status.used_blocks);
        // every layer appends the same rows: identical per-layer counts
        assert!(per_layer.iter().all(|&u| u == per_layer[0]), "{per_layer:?}");
        // 40 prompt + 2 appended decode rows = 42 tokens -> 3 blocks/layer
        assert_eq!(per_layer[0], (prompt.len() + 2 + 15) / 16);
        // reserves and freeable counts are layer-summed
        assert_eq!(e.block_reserve(0, 16), 4);
        assert_eq!(e.freeable_blocks(&s), 4 * per_layer[0]);
        drop(s);
        assert_eq!(e.pool_status().unwrap().used_blocks, 0);
        assert_eq!(e.pool_layer_usage().unwrap().iter().sum::<usize>(), 0);
    }

    #[test]
    fn hybrid_session_evicts_and_resumes_bit_identically() {
        let e = stacked_engine(vec![LayerKind::Moba, LayerKind::Full], 0);
        let prompt: Vec<i32> = (0..30).map(|i| (i * 7) % 48).collect();
        let (want, _) = e.generate(&prompt, 8).unwrap();
        let mut s = e.start(&prompt, 8).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(e.step(&mut s).unwrap());
        }
        let used_before = e.pool_status().unwrap().used_blocks;
        let freed = e.evict_session(&mut s).unwrap();
        assert_eq!(freed, used_before, "an unshared hybrid stack frees every layer's blocks");
        assert_eq!(e.pool_status().unwrap().used_blocks, 0);
        e.resume_session(&mut s, None).unwrap();
        assert_eq!(e.pool_status().unwrap().used_blocks, used_before);
        while let Some(tok) = e.step(&mut s) {
            got.push(tok);
        }
        assert_eq!(got, want, "hybrid evict/resume changed the served tokens");
    }

    #[test]
    fn hybrid_swap_bundle_restores_all_layers_or_none() {
        let layers = vec![LayerKind::Moba, LayerKind::Moba, LayerKind::Full, LayerKind::Moba];
        let e = stacked_engine(layers, 0);
        let prompt: Vec<i32> = (0..30).map(|i| (i * 7) % 48).collect();
        let (want, _) = e.generate(&prompt, 8).unwrap();
        let mut s = e.start(&prompt, 8).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(e.step(&mut s).unwrap());
        }
        let used_before = e.pool_status().unwrap().used_blocks;
        let (freed, bundle) = e.swap_out_session(&mut s).unwrap();
        assert_eq!(freed, used_before);
        assert_eq!(bundle.layers(), 4);
        assert_eq!(bundle.n_blocks(), used_before, "bundle captures every layer's blocks");
        // corrupt_for_chaos hits the LAST image, so the failing restore
        // happens after earlier layers already allocated: the partial
        // stack must roll back to zero used blocks (all-or-nothing)
        let mut bad = bundle.clone();
        bad.corrupt_for_chaos();
        let err = e.swap_in_session(&mut s, None, &bad).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        assert!(s.evicted(), "failed swap-in must leave the session evicted");
        assert_eq!(e.pool_status().unwrap().used_blocks, 0, "partial restore leaked blocks");
        // the intact bundle restores every layer byte-exactly
        e.swap_in_session(&mut s, None, &bundle).unwrap();
        assert_eq!(e.pool_status().unwrap().used_blocks, used_before);
        let per_layer = e.pool_layer_usage().unwrap();
        assert!(per_layer.iter().all(|&u| u == per_layer[0]), "{per_layer:?}");
        while let Some(tok) = e.step(&mut s) {
            got.push(tok);
        }
        assert_eq!(got, want, "hybrid swap round-trip changed the served tokens");
    }

    #[test]
    fn hybrid_stack_works_on_private_cached_backends() {
        // the serving-level covering-topk equivalence: a private hybrid
        // stack (CachedSparse + CachedFull per the spec) serves the same
        // tokens as the paged hybrid stack, whose `full` layer gates
        // with FULL_LAYER_TOPK
        let layers = vec![LayerKind::Moba, LayerKind::Full];
        let paged = stacked_engine(layers.clone(), 0);
        let private = ServeEngine::new(
            ToyModel::stacked(48, 2, 8, 11, 2),
            ServeCfg {
                block_size: 16,
                topk: 2,
                max_seq: 256,
                backend: BackendKind::CachedSparse,
                layers,
                ..Default::default()
            },
        );
        let prompt: Vec<i32> = (0..50).map(|i| (i * 7) % 48).collect();
        let want = paged.generate(&prompt, 8).unwrap().0;
        assert_eq!(private.generate(&prompt, 8).unwrap().0, want);
        // the mix is real: an all-moba stack serves different tokens on
        // this geometry, otherwise the hybrid parity proves nothing
        let all_moba = stacked_engine(vec![LayerKind::Moba, LayerKind::Moba], 0);
        assert_ne!(all_moba.generate(&prompt, 8).unwrap().0, want);
    }

    #[test]
    fn hybrid_forks_share_every_layers_prefix() {
        let layers = vec![LayerKind::Moba, LayerKind::Full];
        let e = stacked_engine(layers.clone(), 0);
        let prefix: Vec<i32> = (0..32).map(|i| (i * 3) % 48).collect();
        let parent = e.start(&prefix, 0).unwrap();
        // 32 tokens = 2 full blocks per layer
        assert_eq!(e.pool_status().unwrap().used_blocks, 4);
        let cont: Vec<i32> = (0..3).map(|i| (i * 5 + 1) % 48).collect();
        let mut forked = e.fork_session(&parent, &cont, 6).unwrap();
        // the fork pays only its divergent tail: one new block per layer
        assert_eq!(e.pool_status().unwrap().used_blocks, 6);
        let mut got = Vec::new();
        while let Some(tok) = e.step(&mut forked) {
            got.push(tok);
        }
        let private = ServeEngine::new(
            ToyModel::stacked(48, 2, 8, 11, 2),
            ServeCfg {
                block_size: 16,
                topk: 2,
                max_seq: 256,
                backend: BackendKind::CachedSparse,
                layers,
                ..Default::default()
            },
        );
        let full: Vec<i32> = prefix.iter().chain(&cont).copied().collect();
        let want = private.generate(&full, 6).unwrap().0;
        assert_eq!(got, want, "hybrid fork diverged from the concatenated private prompt");
    }

    #[test]
    #[should_panic(expected = "ServeCfg::layers has 3 entries but the model has 2 layers")]
    fn layer_spec_must_match_model_depth() {
        let _ = ServeEngine::new(
            ToyModel::stacked(48, 2, 8, 11, 2),
            ServeCfg {
                layers: vec![LayerKind::Moba, LayerKind::Full, LayerKind::Moba],
                ..Default::default()
            },
        );
    }

    #[test]
    fn layer_spec_parser_accepts_lists_and_rejects_garbage() {
        use LayerKind::{Full, Moba};
        assert_eq!(
            parse_layers("MOBA_LAYERS", Some("moba, full,moba".into())).unwrap(),
            Some(vec![Moba, Full, Moba])
        );
        assert_eq!(parse_layers("MOBA_LAYERS", None).unwrap(), None);
        assert_eq!(parse_layers("MOBA_LAYERS", Some("  ".into())).unwrap(), None);
        let err = parse_layers("MOBA_LAYERS", Some("moba,dense".into())).unwrap_err();
        assert!(err.contains("MOBA_LAYERS") && err.contains("dense"), "{err}");
        assert_eq!(LayerKind::Moba.label(), "moba");
        assert_eq!(LayerKind::Full.label(), "full");
    }
}
