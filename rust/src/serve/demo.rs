//! Shared continuous-serving demo driver: a Poisson-ish arrival stream of
//! synthetic prompts decoded through the cached-incremental stack under
//! the continuous-batching scheduler, with a queue/prefill/decode latency
//! report. One implementation serves both `repro serve` and
//! `examples/serve_continuous.rs` so the two cannot drift.

use anyhow::Result;

use crate::metrics::{mean, quantile};
use crate::sparse::BackendKind;
use crate::util::rng::Rng;

use super::batcher::Request;
use super::chaos::{self, FaultPlan};
use super::engine::{layers_from_env, LayerKind, ServeCfg, ServeEngine};
use super::model::ToyModel;
use super::runtime::{pin_from_env, steal_from_env, RuntimeKind};
use super::scheduler::{self, ContinuousScheduler, SchedulerCfg};

/// Demo parameters (CLI flags map 1:1 onto these).
#[derive(Clone, Debug)]
pub struct DemoCfg {
    pub requests: usize,
    pub max_in_flight: usize,
    pub prompt_len: usize,
    pub max_new: usize,
    pub block_size: usize,
    pub topk: usize,
    pub backend: BackendKind,
    /// per-layer attention flavors for a multi-layer hybrid stack: the
    /// model gets one attention layer (and each session one backend) per
    /// entry. Empty = a single layer of `backend`'s flavor. Defaults
    /// from `MOBA_LAYERS` (e.g. `moba,moba,full,moba`)
    pub layers: Vec<LayerKind>,
    /// intra-request kernel threads (prefill partitioning)
    pub workers: usize,
    /// scheduler decode shards stepping sessions concurrently
    pub decode_workers: usize,
    /// decode runtime: persistent pinned thread-per-core workers, or the
    /// legacy per-tick scoped-thread loop (tokens are bitwise identical)
    pub runtime: RuntimeKind,
    /// let idle persistent workers steal queued sessions from the most
    /// loaded shard (never changes served tokens)
    pub steal: bool,
    /// pin persistent workers to cores (Linux; a no-op elsewhere)
    pub pin: bool,
    /// shared system-prompt tokens every request forks off copy-on-write
    /// (0 = off; requires `backend: paged`)
    pub shared_prefix: usize,
    /// physical-block capacity of the paged pool (0 = unbounded). A
    /// bounded pool may OVERSUBSCRIBE: when a candidate's reservation
    /// does not fit, the scheduler evicts the least-recently-stepped
    /// session's blocks and transparently re-prefills it later — tokens
    /// are bit-identical either way
    pub pool_blocks: usize,
    /// host swap-tier capacity in pool blocks (0 = off): evictions
    /// snapshot victims byte-exact to host memory and resumes restore
    /// them instead of re-prefilling (defaults from `MOBA_SWAP_BLOCKS`)
    pub swap_blocks: usize,
    pub seed: u64,
    /// seeded chaos injection: kill/stall persistent decode workers
    /// mid-run and prove the supervisor recovers (None = no chaos;
    /// defaults from `MOBA_CHAOS_SEED`; the tick-loop runtime ignores it)
    pub chaos_seed: Option<u64>,
    /// declare a persistent worker dead if a step barrier exceeds this
    /// many seconds (None = wait forever; chaos runs default to 5s)
    pub barrier_deadline_secs: Option<f64>,
}

impl Default for DemoCfg {
    fn default() -> Self {
        DemoCfg {
            requests: 16,
            max_in_flight: 4,
            prompt_len: 192,
            max_new: 24,
            block_size: 32,
            topk: 3,
            backend: BackendKind::CachedSparse,
            layers: layers_from_env().unwrap_or_default(),
            workers: 1,
            decode_workers: 1,
            runtime: RuntimeKind::Persistent,
            steal: steal_from_env(),
            pin: pin_from_env(),
            shared_prefix: 0,
            pool_blocks: 0,
            swap_blocks: scheduler::swap_blocks_from_env(),
            seed: 42,
            chaos_seed: chaos::seed_from_env(),
            barrier_deadline_secs: None,
        }
    }
}

/// Run the demo: build the toy model + scheduler, synthesize the arrival
/// stream, serve it to completion and print the latency report.
pub fn run_demo(cfg: &DemoCfg) -> Result<()> {
    let (heads, head_dim) = (2usize, 16usize);
    let model = ToyModel::stacked(64, heads, head_dim, cfg.seed, cfg.layers.len().max(1));
    let serve_cfg = ServeCfg {
        block_size: cfg.block_size,
        topk: cfg.topk,
        max_seq: 8192,
        backend: cfg.backend,
        workers: cfg.workers.max(1),
        pool_blocks: cfg.pool_blocks,
        layers: cfg.layers.clone(),
    };
    println!(
        "== continuous serving demo: backend={} block={} topk={} max_in_flight={} ==",
        cfg.backend.label(),
        cfg.block_size,
        cfg.topk,
        cfg.max_in_flight
    );
    println!(
        "   kernel workers={}  decode shards={}  runtime={}{}{}",
        cfg.workers.max(1),
        cfg.decode_workers.max(1),
        cfg.runtime.label(),
        if cfg.runtime == RuntimeKind::Persistent && cfg.steal { " +steal" } else { "" },
        if cfg.runtime == RuntimeKind::Persistent && cfg.pin { " +pin" } else { "" }
    );
    if !cfg.layers.is_empty() {
        let spec: Vec<&str> = cfg.layers.iter().map(|l| l.label()).collect();
        println!("   layers: {} ({} backends per session)", spec.join(","), cfg.layers.len());
    }
    // seeded chaos: only the persistent runtime has workers to kill, and
    // a seeded plan always spares at least one shard so the run finishes
    let chaos: Option<FaultPlan> = match cfg.chaos_seed {
        Some(seed) if cfg.runtime == RuntimeKind::Persistent => {
            let horizon = ((cfg.requests * cfg.max_new) as u64
                / cfg.max_in_flight.max(1) as u64)
                .max(8);
            let plan = FaultPlan::seeded(seed, cfg.decode_workers.max(1), horizon);
            println!(
                "   chaos: seed {seed} -> {} fault(s), {} worker(s) killed outright",
                plan.faults().len(),
                plan.fatal_workers()
            );
            Some(plan)
        }
        _ => None,
    };
    let barrier_deadline_secs = cfg
        .barrier_deadline_secs
        .or(if chaos.is_some() { Some(5.0) } else { None });
    let engine = ServeEngine::new(model, serve_cfg);
    let mut sched = ContinuousScheduler::new(
        engine,
        SchedulerCfg {
            max_in_flight: cfg.max_in_flight,
            decode_workers: cfg.decode_workers.max(1),
            runtime: cfg.runtime,
            steal: cfg.steal,
            pin: cfg.pin,
            chaos,
            barrier_deadline_secs,
            // the demo's uniform-priority stream never trips the dial
            degrade: None,
            swap_blocks: cfg.swap_blocks,
        },
    );

    // shared system prompt, prefilled once and forked per request
    let mut rng = Rng::new(cfg.seed ^ 0x5E12);
    if cfg.shared_prefix > 0 {
        let prefix: Vec<i32> =
            (0..cfg.shared_prefix).map(|_| rng.range(0, 64) as i32).collect();
        sched.set_shared_prefix(&prefix)?;
        println!(
            "   shared prefix: {} tokens held once in the paged pool",
            cfg.shared_prefix
        );
    }

    // simulated arrival process
    let mut arrivals = Vec::with_capacity(cfg.requests);
    let mut prompt_tokens = 0usize;
    let mut t = 0.0f64;
    for id in 0..cfg.requests as u64 {
        t += -0.05 * (1.0 - rng.f64()).ln(); // exp(50ms) inter-arrival
        let len = cfg.prompt_len / 2 + rng.range(0, cfg.prompt_len / 2 + 1);
        let prompt: Vec<i32> = (0..len).map(|_| rng.range(0, 64) as i32).collect();
        prompt_tokens += len;
        arrivals.push(Request::new(id, prompt, cfg.max_new, t));
    }

    let t0 = std::time::Instant::now();
    let results = sched.run_stream(arrivals, 0.001)?;
    let wall = t0.elapsed().as_secs_f64();

    let queues: Vec<f64> = results.iter().map(|r| r.queue_secs * 1e3).collect();
    let prefills: Vec<f64> = results.iter().map(|r| r.prefill_secs * 1e3).collect();
    let per_tok: Vec<f64> = results
        .iter()
        .filter(|r| r.decode_steps > 0)
        .map(|r| r.decode_secs * 1e3 / r.decode_steps as f64)
        .collect();
    let total_tokens: usize = results.iter().map(|r| r.output.len()).sum();

    println!("\n== serving report ==");
    println!(
        "completed {} requests, {} tokens in {:.2}s wall",
        results.len(),
        total_tokens,
        wall
    );
    println!(
        "queue   ms: mean {:.1}  p50 {:.1}  p95 {:.1}",
        mean(&queues),
        quantile(&queues, 0.5),
        quantile(&queues, 0.95)
    );
    println!(
        "prefill ms: mean {:.1}  p50 {:.1}  p95 {:.1}",
        mean(&prefills),
        quantile(&prefills, 0.5),
        quantile(&prefills, 0.95)
    );
    println!(
        "decode  ms/token: mean {:.3}  p50 {:.3}  p95 {:.3}",
        mean(&per_tok),
        quantile(&per_tok, 0.5),
        quantile(&per_tok, 0.95)
    );
    println!(
        "scheduler: admitted {}  decode rounds {}  steps {}  peak in-flight {}",
        sched.stats.admitted,
        sched.stats.decode_rounds,
        sched.stats.decode_steps_total,
        sched.stats.peak_in_flight
    );
    let fs = &sched.stats.fault;
    if fs.worker_deaths > 0 || fs.barrier_timeouts > 0 {
        println!(
            "faults: {} worker death(s) ({} via barrier deadline), {} session(s) re-homed, \
             recovery re-prefill {:.1} ms",
            fs.worker_deaths,
            fs.barrier_timeouts,
            fs.rehomed_sessions,
            fs.recovery_reprefill_secs * 1e3
        );
    }
    let ov = &sched.stats.overload;
    if ov.shed_infeasible + ov.shed_deadline > 0 {
        println!(
            "overload: {} request(s) shed ({} infeasible, {} past deadline), {} resume retries",
            ov.shed_infeasible + ov.shed_deadline,
            ov.shed_infeasible,
            ov.shed_deadline,
            ov.resume_retries
        );
    }
    println!(
        "throughput: {:.1} tok/s ({:.1} req/s)",
        total_tokens as f64 / wall.max(1e-9),
        results.len() as f64 / wall.max(1e-9)
    );
    let persistent = sched.runtime() == RuntimeKind::Persistent;
    for (i, w) in sched.worker_stats().iter().enumerate() {
        print!(
            "shard {i}: admitted {}  rounds {}  steps {}  busy {:.3}s  peak {}",
            w.admitted, w.decode_rounds, w.decode_steps, w.busy_secs, w.peak_in_flight
        );
        if persistent {
            print!(
                "  steals {} ({} tok)  idle {}  queue-hwm {}",
                w.steals, w.stolen_steps, w.idle_ticks, w.queue_depth_hwm
            );
        }
        println!();
    }
    if let Some(pool) = sched.engine().pool_status() {
        // unique KV bytes at the pool's high-water mark vs what private
        // per-session caches would have held for the same sequences
        let row_bytes = heads * head_dim * 2 * std::mem::size_of::<f32>();
        let block_bytes = cfg.block_size * row_bytes;
        let peak_bytes = sched.stats.peak_pool_blocks * block_bytes;
        // what the same peak batch would hold with a private cache per
        // session: peak_in_flight full contexts, prefix duplicated S times
        let avg_ctx = (prompt_tokens + total_tokens.saturating_sub(results.len()))
            / results.len().max(1);
        let private_peak_bytes = sched.stats.peak_in_flight
            * (sched.shared_prefix_len() + avg_ctx)
            * row_bytes;
        let cap = match pool.capacity_blocks {
            Some(c) => format!("{c}"),
            None => "unbounded".to_string(),
        };
        println!(
            "paged pool: peak {} blocks ({:.1} KiB unique KV), capacity {}, deferrals {}",
            sched.stats.peak_pool_blocks,
            peak_bytes as f64 / 1024.0,
            cap,
            sched.stats.pool_deferrals
        );
        let ev = &sched.stats.eviction;
        if ev.evictions > 0 {
            println!(
                "  eviction: {} preemptions ({} blocks reclaimed), {} resumes \
                 ({} blocked ticks), re-prefill {:.1} ms total",
                ev.evictions,
                ev.blocks_reclaimed,
                ev.resumes,
                ev.resume_deferrals,
                ev.reprefill_secs * 1e3
            );
        }
        let sw = &sched.stats.swap;
        if sw.swap_outs > 0 || sw.fallbacks > 0 {
            println!(
                "  swap tier: {} swap-outs ({:.1} KiB), {} swap-ins ({:.1} ms), \
                 {} fallback(s) to re-prefill",
                sw.swap_outs,
                sw.bytes as f64 / 1024.0,
                sw.swap_ins,
                sw.swapin_secs * 1e3,
                sw.fallbacks
            );
        }
        println!(
            "  peak batch: {:.1} KiB shared pool vs ~{:.1} KiB private caches ({:.1}x)",
            peak_bytes as f64 / 1024.0,
            private_peak_bytes as f64 / 1024.0,
            private_peak_bytes as f64 / peak_bytes.max(1) as f64
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_runs_to_completion_on_every_backend() {
        for backend in [
            BackendKind::CachedSparse,
            BackendKind::CachedFull,
            BackendKind::RecomputeMoba,
            BackendKind::Fused,
            BackendKind::Paged,
        ] {
            let cfg = DemoCfg {
                requests: 3,
                prompt_len: 48,
                max_new: 4,
                backend,
                ..Default::default()
            };
            run_demo(&cfg).unwrap();
        }
    }

    #[test]
    fn demo_runs_sharded_and_threaded() {
        let cfg = DemoCfg {
            requests: 4,
            prompt_len: 48,
            max_new: 4,
            backend: BackendKind::Fused,
            workers: 2,
            decode_workers: 2,
            ..Default::default()
        };
        run_demo(&cfg).unwrap();
    }

    #[test]
    fn demo_runs_on_both_runtimes() {
        for runtime in [RuntimeKind::TickLoop, RuntimeKind::Persistent] {
            let cfg = DemoCfg {
                requests: 3,
                prompt_len: 48,
                max_new: 4,
                backend: BackendKind::Fused,
                decode_workers: 2,
                runtime,
                steal: true,
                pin: false,
                ..Default::default()
            };
            run_demo(&cfg).unwrap();
        }
    }

    #[test]
    fn demo_runs_shared_prefix_over_bounded_pool() {
        let cfg = DemoCfg {
            requests: 4,
            prompt_len: 48,
            max_new: 4,
            backend: BackendKind::Paged,
            shared_prefix: 96,
            pool_blocks: 64,
            decode_workers: 2,
            ..Default::default()
        };
        run_demo(&cfg).unwrap();
    }

    #[test]
    fn demo_runs_oversubscribed_pool_with_eviction() {
        // pool far below the concurrent working set: the scheduler must
        // preempt and re-prefill instead of wedging, and still finish
        let cfg = DemoCfg {
            requests: 4,
            max_in_flight: 4,
            prompt_len: 48,
            max_new: 6,
            backend: BackendKind::Paged,
            pool_blocks: 4, // each request needs <= 2 of 32-token blocks
            swap_blocks: 0, // independent of MOBA_SWAP_BLOCKS
            ..Default::default()
        };
        run_demo(&cfg).unwrap();
    }

    #[test]
    fn demo_runs_oversubscribed_pool_with_swap_tier() {
        let cfg = DemoCfg {
            requests: 4,
            max_in_flight: 4,
            prompt_len: 48,
            max_new: 6,
            backend: BackendKind::Paged,
            pool_blocks: 4,
            swap_blocks: 64,
            ..Default::default()
        };
        run_demo(&cfg).unwrap();
    }

    #[test]
    fn demo_runs_hybrid_layer_stack_over_bounded_pool() {
        // four-layer hybrid: every session carries one paged backend per
        // layer, and an undersized pool still drains via eviction/resume
        let cfg = DemoCfg {
            requests: 3,
            prompt_len: 48,
            max_new: 4,
            backend: BackendKind::Paged,
            layers: vec![LayerKind::Moba, LayerKind::Moba, LayerKind::Full, LayerKind::Moba],
            pool_blocks: 24,
            swap_blocks: 0, // independent of MOBA_SWAP_BLOCKS
            decode_workers: 2,
            ..Default::default()
        };
        run_demo(&cfg).unwrap();
    }

    #[test]
    fn demo_survives_seeded_chaos() {
        // explicit seed (independent of MOBA_CHAOS_SEED): workers may be
        // killed mid-run; the demo must still retire every request
        let cfg = DemoCfg {
            requests: 4,
            prompt_len: 48,
            max_new: 6,
            backend: BackendKind::Fused,
            decode_workers: 2,
            runtime: RuntimeKind::Persistent,
            chaos_seed: Some(7),
            barrier_deadline_secs: Some(2.0),
            ..Default::default()
        };
        run_demo(&cfg).unwrap();
    }

    #[test]
    fn demo_shared_prefix_rejects_private_backends() {
        let cfg = DemoCfg {
            requests: 2,
            prompt_len: 32,
            max_new: 2,
            shared_prefix: 32,
            ..Default::default()
        };
        assert!(run_demo(&cfg).is_err(), "cached-sparse cannot share a prefix");
    }
}
