//! Shared continuous-serving demo driver: a Poisson-ish arrival stream of
//! synthetic prompts decoded through the cached-incremental stack under
//! the continuous-batching scheduler, with a queue/prefill/decode latency
//! report. One implementation serves both `repro serve` and
//! `examples/serve_continuous.rs` so the two cannot drift.

use anyhow::Result;

use crate::metrics::{mean, quantile};
use crate::sparse::BackendKind;
use crate::util::rng::Rng;

use super::batcher::Request;
use super::engine::{ServeCfg, ServeEngine};
use super::model::ToyModel;
use super::scheduler::{ContinuousScheduler, SchedulerCfg};

/// Demo parameters (CLI flags map 1:1 onto these).
#[derive(Clone, Debug)]
pub struct DemoCfg {
    pub requests: usize,
    pub max_in_flight: usize,
    pub prompt_len: usize,
    pub max_new: usize,
    pub block_size: usize,
    pub topk: usize,
    pub backend: BackendKind,
    /// intra-request kernel threads (prefill partitioning)
    pub workers: usize,
    /// scheduler decode shards stepping sessions concurrently
    pub decode_workers: usize,
    pub seed: u64,
}

impl Default for DemoCfg {
    fn default() -> Self {
        DemoCfg {
            requests: 16,
            max_in_flight: 4,
            prompt_len: 192,
            max_new: 24,
            block_size: 32,
            topk: 3,
            backend: BackendKind::CachedSparse,
            workers: 1,
            decode_workers: 1,
            seed: 42,
        }
    }
}

/// Run the demo: build the toy model + scheduler, synthesize the arrival
/// stream, serve it to completion and print the latency report.
pub fn run_demo(cfg: &DemoCfg) -> Result<()> {
    let model = ToyModel::new(64, 2, 16, cfg.seed);
    let serve_cfg = ServeCfg {
        block_size: cfg.block_size,
        topk: cfg.topk,
        max_seq: 8192,
        backend: cfg.backend,
        workers: cfg.workers.max(1),
    };
    println!(
        "== continuous serving demo: backend={} block={} topk={} max_in_flight={} ==",
        cfg.backend.label(),
        cfg.block_size,
        cfg.topk,
        cfg.max_in_flight
    );
    println!(
        "   kernel workers={}  decode shards={}",
        cfg.workers.max(1),
        cfg.decode_workers.max(1)
    );
    let engine = ServeEngine::new(model, serve_cfg);
    let mut sched = ContinuousScheduler::new(
        engine,
        SchedulerCfg {
            max_in_flight: cfg.max_in_flight,
            decode_workers: cfg.decode_workers.max(1),
        },
    );

    // simulated arrival process
    let mut rng = Rng::new(cfg.seed ^ 0x5E12);
    let mut arrivals = Vec::with_capacity(cfg.requests);
    let mut t = 0.0f64;
    for id in 0..cfg.requests as u64 {
        t += -0.05 * (1.0 - rng.f64()).ln(); // exp(50ms) inter-arrival
        let len = cfg.prompt_len / 2 + rng.range(0, cfg.prompt_len / 2 + 1);
        let prompt: Vec<i32> = (0..len).map(|_| rng.range(0, 64) as i32).collect();
        arrivals.push(Request { id, prompt, max_new: cfg.max_new, arrival: t });
    }

    let t0 = std::time::Instant::now();
    let results = sched.run_stream(arrivals, 0.001)?;
    let wall = t0.elapsed().as_secs_f64();

    let queues: Vec<f64> = results.iter().map(|r| r.queue_secs * 1e3).collect();
    let prefills: Vec<f64> = results.iter().map(|r| r.prefill_secs * 1e3).collect();
    let per_tok: Vec<f64> = results
        .iter()
        .filter(|r| r.decode_steps > 0)
        .map(|r| r.decode_secs * 1e3 / r.decode_steps as f64)
        .collect();
    let total_tokens: usize = results.iter().map(|r| r.output.len()).sum();

    println!("\n== serving report ==");
    println!(
        "completed {} requests, {} tokens in {:.2}s wall",
        results.len(),
        total_tokens,
        wall
    );
    println!(
        "queue   ms: mean {:.1}  p50 {:.1}  p95 {:.1}",
        mean(&queues),
        quantile(&queues, 0.5),
        quantile(&queues, 0.95)
    );
    println!(
        "prefill ms: mean {:.1}  p50 {:.1}  p95 {:.1}",
        mean(&prefills),
        quantile(&prefills, 0.5),
        quantile(&prefills, 0.95)
    );
    println!(
        "decode  ms/token: mean {:.3}  p50 {:.3}  p95 {:.3}",
        mean(&per_tok),
        quantile(&per_tok, 0.5),
        quantile(&per_tok, 0.95)
    );
    println!(
        "scheduler: admitted {}  decode rounds {}  steps {}  peak in-flight {}",
        sched.stats.admitted,
        sched.stats.decode_rounds,
        sched.stats.decode_steps_total,
        sched.stats.peak_in_flight
    );
    println!(
        "throughput: {:.1} tok/s ({:.1} req/s)",
        total_tokens as f64 / wall.max(1e-9),
        results.len() as f64 / wall.max(1e-9)
    );
    for (i, w) in sched.worker_stats().iter().enumerate() {
        println!(
            "shard {i}: admitted {}  rounds {}  steps {}  busy {:.3}s  peak {}",
            w.admitted, w.decode_rounds, w.decode_steps, w.busy_secs, w.peak_in_flight
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_runs_to_completion_on_every_backend() {
        for backend in [
            BackendKind::CachedSparse,
            BackendKind::CachedFull,
            BackendKind::RecomputeMoba,
            BackendKind::Fused,
        ] {
            let cfg = DemoCfg {
                requests: 3,
                prompt_len: 48,
                max_new: 4,
                backend,
                ..Default::default()
            };
            run_demo(&cfg).unwrap();
        }
    }

    #[test]
    fn demo_runs_sharded_and_threaded() {
        let cfg = DemoCfg {
            requests: 4,
            prompt_len: 48,
            max_new: 4,
            backend: BackendKind::Fused,
            workers: 2,
            decode_workers: 2,
            ..Default::default()
        };
        run_demo(&cfg).unwrap();
    }
}
