//! Artifact-backed generation engine (requires the `xla` feature):
//! drives AOT logits graphs through PJRT.
//!
//! Prefill runs the **MoBA** logits graph once over the padded prompt
//! (block-sparse — the paper's speedup target); each decode step runs the
//! **full-attention** logits graph (the paper switches to full attention
//! for generation quality). Causality makes right-padding safe: logits at
//! position p never see the pad region beyond p.
//!
//! The AOT graphs are fixed-shape and expose no KV cache, so *this* path
//! still recomputes per decode step — it exists for parity with the
//! L1/L2 artifacts. The crate's serving default is `serve::engine`, which
//! decodes incrementally over `sparse::KvCache` through any
//! `AttentionBackend`; lowering a cache-carrying decode graph so the
//! artifact path can join it is tracked in ROADMAP.md.

use anyhow::{bail, Result};

use crate::runtime::Engine;
use crate::tensor::{IntTensor, Tensor};

use super::engine::GenStats;

/// Generation over a (MoBA-prefill, full-decode) pair of logits artifacts.
pub struct ArtifactServeEngine<'e> {
    engine: &'e Engine,
    params: Vec<Tensor>,
    /// MoBA logits artifact used for prefill
    prefill_artifact: String,
    /// full-attention logits artifact used for decode
    decode_artifact: String,
    seq: usize,
    vocab: usize,
}

impl<'e> ArtifactServeEngine<'e> {
    pub fn new(
        engine: &'e Engine,
        params: Vec<Tensor>,
        prefill_artifact: &str,
        decode_artifact: &str,
    ) -> Result<ArtifactServeEngine<'e>> {
        let pa = engine.manifest.get(prefill_artifact)?;
        let da = engine.manifest.get(decode_artifact)?;
        if pa.kind != "logits" || da.kind != "logits" {
            bail!("serve artifacts must be kind=logits");
        }
        if pa.seq != da.seq || pa.model.vocab != da.model.vocab {
            bail!("prefill/decode artifact geometry mismatch");
        }
        Ok(ArtifactServeEngine {
            engine,
            params,
            prefill_artifact: prefill_artifact.into(),
            decode_artifact: decode_artifact.into(),
            seq: pa.seq,
            vocab: pa.model.vocab,
        })
    }

    pub fn max_seq(&self) -> usize {
        self.seq
    }

    fn argmax_at(&self, logits: &Tensor, pos: usize) -> i32 {
        let off = pos * self.vocab;
        let row = &logits.data[off..off + self.vocab];
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as i32)
            .unwrap()
    }

    /// Greedy generation: returns (generated tokens, stats).
    pub fn generate(&self, prompt: &[i32], max_new: usize) -> Result<(Vec<i32>, GenStats)> {
        if prompt.is_empty() || prompt.len() + max_new > self.seq {
            bail!(
                "prompt {} + max_new {} exceeds artifact seq {}",
                prompt.len(),
                max_new,
                self.seq
            );
        }
        let mut buf = vec![0i32; self.seq];
        buf[..prompt.len()].copy_from_slice(prompt);
        let mut stats = GenStats::default();

        // prefill with the MoBA graph: logits for the whole prompt
        let t0 = std::time::Instant::now();
        let tokens = IntTensor::from_vec(&[1, self.seq], buf.clone())?;
        let logits = self
            .engine
            .logits(&self.prefill_artifact, &self.params, &tokens)?;
        stats.prefill_secs = t0.elapsed().as_secs_f64();
        let mut next = self.argmax_at(&logits, prompt.len() - 1);

        let mut out = Vec::with_capacity(max_new);
        let mut cursor = prompt.len();
        for _ in 0..max_new {
            out.push(next);
            if cursor >= self.seq {
                break;
            }
            buf[cursor] = next;
            cursor += 1;
            if out.len() == max_new {
                break;
            }
            // decode step with the full-attention graph (whole-sequence
            // recompute: the graph carries no cache)
            let t1 = std::time::Instant::now();
            let tokens = IntTensor::from_vec(&[1, self.seq], buf.clone())?;
            let logits = self
                .engine
                .logits(&self.decode_artifact, &self.params, &tokens)?;
            stats.decode_secs += t1.elapsed().as_secs_f64();
            stats.decode_steps += 1;
            next = self.argmax_at(&logits, cursor - 1);
        }
        Ok((out, stats))
    }

    pub fn engine(&self) -> &Engine {
        self.engine
    }

    pub fn params(&self) -> &[Tensor] {
        &self.params
    }
}
