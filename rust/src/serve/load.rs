//! Trace-driven storm workloads: seeded overload traffic for the
//! serving scheduler.
//!
//! A "storm" is a deterministic request trace with the failure-inducing
//! shapes real serving fleets see all at once: bursty arrivals (whole
//! groups land at one instant), long-tail prompt lengths (Pareto — most
//! prompts are short, a few are whales), a multi-tenant priority mix,
//! interactive deadline budgets, streaming pauses, and
//! conversation-resume patterns (a later request re-submits an earlier
//! prompt plus a continuation, so its KV re-prefill overlaps a prior
//! session's blocks). Everything derives from [`StormCfg::seed`] through
//! `util::rng` — two calls with the same config produce bit-identical
//! traces, so an overload run is reproducible from the config alone and
//! the persistent runtime can be diffed against the tick-loop oracle on
//! the exact same traffic.
//!
//! [`summarize`] folds scheduler results back into the SLA view:
//! p50/p99 queue/prefill/decode latency, per-class completion counts,
//! shed totals, and deadline violations among requests that *did*
//! complete (shed requests are accounted separately — a shed is overload
//! control working, a violation is it failing).

use super::batcher::{Priority, Request, RequestResult};
use crate::metrics::quantile;
use crate::util::rng::Rng;

/// Shape of a storm trace. All randomness flows from `seed`.
#[derive(Clone, Copy, Debug)]
pub struct StormCfg {
    /// total requests in the trace
    pub requests: usize,
    pub seed: u64,
    /// token-id vocabulary for generated prompts
    pub vocab: usize,
    /// long-run mean arrival rate, requests per simulated second
    /// (<= 0 = everything arrives at t=0)
    pub rate: f64,
    /// burst ceiling: arrivals land in groups of 1..=burst at a single
    /// instant, with exponential gaps between groups sized so the
    /// long-run rate stays `rate`
    pub burst: usize,
    /// base (median-ish) prompt length
    pub prompt_len: usize,
    /// Pareto tail index for prompt lengths; smaller = heavier tail.
    /// Lengths are capped at `8 * prompt_len`.
    pub tail_alpha: f64,
    /// decode budget ceiling: each request decodes 1..=max_new tokens
    pub max_new: usize,
    /// priority mix weights, indexed by `Priority::rank()`
    /// (batch, standard, interactive)
    pub mix: [f64; 3],
    /// fraction of requests that resume an earlier conversation: their
    /// prompt is an earlier request's prompt plus a fresh continuation
    pub resume_frac: f64,
    /// fraction of requests that pause their output stream every
    /// `pause_every` tokens (0 disables)
    pub pause_frac: f64,
    pub pause_every: usize,
    /// deadline budget ceiling for interactive requests, seconds; each
    /// interactive request gets a budget in [deadline_secs/2,
    /// 3*deadline_secs/2] (<= 0 = no deadlines)
    pub deadline_secs: f64,
}

impl Default for StormCfg {
    fn default() -> Self {
        StormCfg {
            requests: 64,
            seed: 0,
            vocab: 64,
            rate: 40.0,
            burst: 6,
            prompt_len: 48,
            tail_alpha: 2.0,
            max_new: 12,
            mix: [0.3, 0.5, 0.2],
            resume_frac: 0.2,
            pause_frac: 0.15,
            pause_every: 3,
            deadline_secs: 0.0,
        }
    }
}

/// Generate the deterministic request trace for `cfg`. Arrivals are
/// nondecreasing and ids are dense `0..requests`, so the trace can be
/// fed straight to `ContinuousScheduler::run_stream`.
pub fn storm(cfg: &StormCfg) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed ^ 0x5708_4A11_0AD5_0081);
    let mut reqs: Vec<Request> = Vec::with_capacity(cfg.requests);
    let cap = cfg.prompt_len.max(1) * 8;
    let mut now = 0.0f64;
    while reqs.len() < cfg.requests {
        // one burst: `size` requests at the same instant, then an
        // exponential gap scaled by the burst size so the long-run
        // arrival rate stays `cfg.rate`
        let size = 1 + rng.below(cfg.burst.max(1) as u64) as usize;
        if cfg.rate > 0.0 && !reqs.is_empty() {
            now += -(1.0 - rng.f64()).ln() * size as f64 / cfg.rate;
        }
        for _ in 0..size {
            if reqs.len() >= cfg.requests {
                break;
            }
            let id = reqs.len() as u64;
            let resume = !reqs.is_empty() && rng.f64() < cfg.resume_frac;
            let prompt: Vec<i32> = if resume {
                // conversation resume: an earlier prompt plus a fresh
                // continuation — re-prefill overlaps the parent's blocks
                let parent = &reqs[rng.below(id) as usize];
                let extra = 1 + rng.below((cfg.prompt_len / 2 + 1) as u64) as usize;
                let mut p = parent.prompt.clone();
                p.extend((0..extra).map(|_| rng.below(cfg.vocab.max(2) as u64) as i32));
                p.truncate(cap);
                p
            } else {
                // Pareto long tail: mostly near prompt_len, rare whales
                let u = rng.f64();
                let len = (cfg.prompt_len.max(1) as f64 * (1.0 - u).powf(-1.0 / cfg.tail_alpha))
                    .min(cap as f64) as usize;
                (0..len.max(1)).map(|_| rng.below(cfg.vocab.max(2) as u64) as i32).collect()
            };
            let priority = Priority::ALL[rng.weighted(&cfg.mix)];
            let max_new = 1 + rng.below(cfg.max_new.max(1) as u64) as usize;
            let mut req = Request::new(id, prompt, max_new, now).with_priority(priority);
            if priority == Priority::Interactive && cfg.deadline_secs > 0.0 {
                req = req.with_deadline(cfg.deadline_secs * (0.5 + rng.f64()));
            }
            if cfg.pause_every > 0 && rng.f64() < cfg.pause_frac {
                req = req.with_pause_every(cfg.pause_every);
            }
            reqs.push(req);
        }
    }
    reqs
}

/// SLA-oriented digest of one storm run.
#[derive(Clone, Copy, Debug, Default)]
pub struct StormSummary {
    pub completed: usize,
    /// requests rejected by overload control (deadline or infeasible)
    pub shed: usize,
    pub queue_p50: f64,
    pub queue_p99: f64,
    pub prefill_p50: f64,
    pub prefill_p99: f64,
    pub decode_p50: f64,
    pub decode_p99: f64,
    /// completed requests whose queue+prefill+decode exceeded their
    /// deadline budget — overload control failing, unlike a shed
    pub sla_violations: usize,
    /// completions indexed by `Priority::rank()`
    pub completed_by_class: [usize; 3],
}

/// Fold scheduler results back against the trace they came from.
/// `shed` is the scheduler's total overload rejections for the run.
pub fn summarize(trace: &[Request], results: &[RequestResult], shed: usize) -> StormSummary {
    let queue: Vec<f64> = results.iter().map(|r| r.queue_secs).collect();
    let prefill: Vec<f64> = results.iter().map(|r| r.prefill_secs).collect();
    let decode: Vec<f64> = results.iter().map(|r| r.decode_secs).collect();
    let mut summary = StormSummary {
        completed: results.len(),
        shed,
        queue_p50: quantile(&queue, 0.5),
        queue_p99: quantile(&queue, 0.99),
        prefill_p50: quantile(&prefill, 0.5),
        prefill_p99: quantile(&prefill, 0.99),
        decode_p50: quantile(&decode, 0.5),
        decode_p99: quantile(&decode, 0.99),
        ..StormSummary::default()
    };
    for r in results {
        let Some(req) = trace.iter().find(|q| q.id == r.id) else { continue };
        summary.completed_by_class[req.priority.rank()] += 1;
        if let Some(budget) = req.deadline {
            if r.queue_secs + r.prefill_secs + r.decode_secs > budget {
                summary.sla_violations += 1;
            }
        }
    }
    summary
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fingerprint(reqs: &[Request]) -> Vec<(u64, u64, Vec<i32>, usize, usize, u64, usize)> {
        reqs.iter()
            .map(|r| {
                (
                    r.id,
                    r.arrival.to_bits(),
                    r.prompt.clone(),
                    r.max_new,
                    r.priority.rank(),
                    r.deadline.unwrap_or(-1.0).to_bits(),
                    r.pause_every,
                )
            })
            .collect()
    }

    #[test]
    fn storms_are_deterministic_and_seed_sensitive() {
        let cfg = StormCfg { requests: 80, deadline_secs: 0.5, ..StormCfg::default() };
        assert_eq!(fingerprint(&storm(&cfg)), fingerprint(&storm(&cfg)));
        let other = StormCfg { seed: 1, ..cfg };
        assert_ne!(fingerprint(&storm(&cfg)), fingerprint(&storm(&other)));
    }

    #[test]
    fn storms_have_dense_ids_and_sorted_arrivals() {
        let reqs = storm(&StormCfg { requests: 100, ..StormCfg::default() });
        assert_eq!(reqs.len(), 100);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(!r.prompt.is_empty() && r.max_new >= 1);
            if i > 0 {
                assert!(r.arrival >= reqs[i - 1].arrival, "arrivals must be nondecreasing");
            }
        }
        assert!(reqs.last().unwrap().arrival > 0.0, "a 100-request storm spans time");
    }

    #[test]
    fn storms_burst_and_long_tail() {
        let cfg = StormCfg { requests: 200, ..StormCfg::default() };
        let reqs = storm(&cfg);
        let same_instant = reqs.windows(2).filter(|w| w[0].arrival == w[1].arrival).count();
        assert!(same_instant > 0, "bursts must put several arrivals at one instant");
        let longest = reqs.iter().map(|r| r.prompt.len()).max().unwrap();
        let shortest = reqs.iter().map(|r| r.prompt.len()).min().unwrap();
        assert!(longest >= 2 * cfg.prompt_len, "the tail must produce whales, got {longest}");
        assert!(longest <= 8 * cfg.prompt_len, "whales are capped");
        assert!(shortest <= cfg.prompt_len, "most prompts stay near the base length");
    }

    #[test]
    fn storms_mix_tenants_resumes_and_deadlines() {
        let cfg = StormCfg { requests: 200, deadline_secs: 0.4, ..StormCfg::default() };
        let reqs = storm(&cfg);
        for p in Priority::ALL {
            assert!(
                reqs.iter().any(|r| r.priority == p),
                "class {} missing from the mix",
                p.label()
            );
        }
        for r in &reqs {
            match r.priority {
                Priority::Interactive => {
                    let d = r.deadline.expect("interactive requests carry deadlines");
                    assert!((0.2..=0.6).contains(&d), "budget {d} outside [1/2, 3/2] x base");
                }
                _ => assert!(r.deadline.is_none()),
            }
        }
        assert!(reqs.iter().any(|r| r.pause_every > 0), "some streams pause");
        let resumes = reqs
            .iter()
            .enumerate()
            .filter(|(i, r)| {
                reqs[..*i].iter().any(|p| {
                    r.prompt.len() > p.prompt.len() && r.prompt[..p.prompt.len()] == p.prompt[..]
                })
            })
            .count();
        assert!(resumes > 0, "conversation resumes must extend earlier prompts");
    }

    #[test]
    fn summarize_splits_sheds_from_sla_violations() {
        let trace = vec![
            Request::new(0, vec![1, 2], 4, 0.0)
                .with_priority(Priority::Interactive)
                .with_deadline(0.5),
            Request::new(1, vec![3], 4, 0.0)
                .with_priority(Priority::Interactive)
                .with_deadline(10.0),
            Request::new(2, vec![4], 4, 0.0).with_priority(Priority::Batch),
        ];
        let res = |id: u64, queue: f64| RequestResult {
            id,
            output: vec![0; 4],
            queue_secs: queue,
            prefill_secs: 0.1,
            decode_secs: 0.2,
            decode_steps: 4,
        };
        // request 2 was shed, request 0 finished but blew its budget
        let s = summarize(&trace, &[res(0, 1.0), res(1, 0.0)], 1);
        assert_eq!((s.completed, s.shed, s.sla_violations), (2, 1, 1));
        assert_eq!(s.completed_by_class, [0, 0, 2]);
        assert!(s.queue_p99 >= s.queue_p50 && s.queue_p50 >= 0.0);
        assert!((s.decode_p50 - 0.2).abs() < 1e-12);
    }
}
