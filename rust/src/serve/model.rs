//! Token-level model abstraction for the serving engine.
//!
//! The serving stack needs exactly two things from a model: per-token
//! q/k/v projections and a map from an attention output back to vocab
//! logits. [`TokenModel`] captures that contract so the engine, scheduler
//! and benches are independent of where the projections come from.
//!
//! [`ToyModel`] is the CPU-testbed implementation: deterministic seeded
//! embedding tables (one per role) plus an additive sinusoidal position
//! signal, with logits by value-embedding similarity. It is *not* a
//! trained transformer — it exists so the cache/backend/scheduler
//! machinery runs end-to-end, deterministically, with real attention
//! arithmetic and no AOT artifacts. The artifact-backed path (real
//! trained models through PJRT) lives in `serve::artifact` behind the
//! `xla` feature.

use crate::util::rng::Rng;

/// A model the serving engine can decode with.
pub trait TokenModel {
    fn heads(&self) -> usize;
    fn head_dim(&self) -> usize;
    fn vocab(&self) -> usize;

    /// Projections for `token` at absolute position `pos`: (q, k, v) rows,
    /// each `[heads * head_dim]`.
    fn qkv(&self, token: i32, pos: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>);

    /// Vocab logits from one attention output row `[heads * head_dim]`.
    fn logits(&self, attn_row: &[f32]) -> Vec<f32>;
}

/// Deterministic stand-in model: seeded per-role embedding tables.
pub struct ToyModel {
    heads: usize,
    head_dim: usize,
    vocab: usize,
    /// `[vocab, heads * head_dim]` row-major, one table per role
    eq: Vec<f32>,
    ek: Vec<f32>,
    ev: Vec<f32>,
}

impl ToyModel {
    pub fn new(vocab: usize, heads: usize, head_dim: usize, seed: u64) -> ToyModel {
        assert!(vocab > 0 && heads > 0 && head_dim > 0);
        let w = heads * head_dim;
        let mut root = Rng::new(seed);
        let mut table = |tag: u64| -> Vec<f32> {
            let mut rng = root.split(tag);
            (0..vocab * w).map(|_| rng.normal_f32(1.0)).collect()
        };
        ToyModel {
            heads,
            head_dim,
            vocab,
            eq: table(1),
            ek: table(2),
            ev: table(3),
        }
    }

    fn row<'a>(table: &'a [f32], tok: usize, w: usize) -> &'a [f32] {
        &table[tok * w..(tok + 1) * w]
    }
}

impl TokenModel for ToyModel {
    fn heads(&self) -> usize {
        self.heads
    }

    fn head_dim(&self) -> usize {
        self.head_dim
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn qkv(&self, token: i32, pos: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let w = self.heads * self.head_dim;
        let tok = (token.max(0) as usize) % self.vocab;
        let mut q = Self::row(&self.eq, tok, w).to_vec();
        let mut k = Self::row(&self.ek, tok, w).to_vec();
        let v = Self::row(&self.ev, tok, w).to_vec();
        // additive sinusoidal position signal (queries and keys only)
        for i in 0..w {
            let phase = pos as f32 / (1.0 + i as f32);
            q[i] += 0.25 * phase.sin();
            k[i] += 0.25 * phase.cos();
        }
        (q, k, v)
    }

    fn logits(&self, attn_row: &[f32]) -> Vec<f32> {
        let w = self.heads * self.head_dim;
        debug_assert_eq!(attn_row.len(), w);
        (0..self.vocab)
            .map(|tok| {
                let e = Self::row(&self.ev, tok, w);
                let mut s = 0.0f32;
                for i in 0..w {
                    s += attn_row[i] * e[i];
                }
                s
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let a = ToyModel::new(32, 2, 8, 7);
        let b = ToyModel::new(32, 2, 8, 7);
        assert_eq!(a.qkv(5, 3), b.qkv(5, 3));
        let c = ToyModel::new(32, 2, 8, 8);
        assert_ne!(a.qkv(5, 3).0, c.qkv(5, 3).0);
    }

    #[test]
    fn position_moves_q_and_k_but_not_v() {
        let m = ToyModel::new(16, 1, 4, 1);
        let (q0, k0, v0) = m.qkv(3, 0);
        let (q9, k9, v9) = m.qkv(3, 9);
        assert_ne!(q0, q9);
        assert_ne!(k0, k9);
        assert_eq!(v0, v9);
    }

    #[test]
    fn logits_have_vocab_width() {
        let m = ToyModel::new(24, 2, 4, 1);
        let attn = vec![0.5; 8];
        assert_eq!(m.logits(&attn).len(), 24);
    }

    #[test]
    fn token_ids_wrap_into_vocab() {
        let m = ToyModel::new(8, 1, 4, 1);
        assert_eq!(m.qkv(2, 0), m.qkv(10, 0));
        // negative ids clamp to 0
        assert_eq!(m.qkv(-3, 0), m.qkv(0, 0));
    }
}
