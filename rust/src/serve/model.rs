//! Token-level model abstraction for the serving engine.
//!
//! The serving stack needs exactly two things from a model: per-token
//! q/k/v projections and a map from an attention output back to vocab
//! logits. [`TokenModel`] captures that contract so the engine, scheduler
//! and benches are independent of where the projections come from.
//!
//! Models may be **stacked**: [`TokenModel::layers`] reports how many
//! attention layers the model has. Layer 0 projects from token ids
//! ([`TokenModel::qkv`]); deeper layers project from the residual hidden
//! stream ([`TokenModel::qkv_layer_into`]). The serving engine threads one
//! attention backend per layer and accumulates `hidden += attn_out` after
//! each layer, so an L=1 model is bitwise identical to the historical
//! single-attention path (logits straight off the layer-0 output).
//!
//! [`ToyModel`] is the CPU-testbed implementation: deterministic seeded
//! embedding tables (one per role) plus an additive sinusoidal position
//! signal, with logits by value-embedding similarity; deeper layers use
//! seeded dense projection matrices over the hidden stream. It is *not* a
//! trained transformer — it exists so the cache/backend/scheduler
//! machinery runs end-to-end, deterministically, with real attention
//! arithmetic and no AOT artifacts. The artifact-backed path (real
//! trained models through PJRT) lives in `serve::artifact` behind the
//! `xla` feature.

use crate::util::rng::Rng;

/// A model the serving engine can decode with.
pub trait TokenModel {
    fn heads(&self) -> usize;
    fn head_dim(&self) -> usize;
    fn vocab(&self) -> usize;

    /// Number of attention layers in the stack. The engine builds one
    /// backend per layer; layer 0 consumes token ids, layers `1..` consume
    /// the residual hidden stream.
    fn layers(&self) -> usize {
        1
    }

    /// Projections for `token` at absolute position `pos`: (q, k, v) rows,
    /// each `[heads * head_dim]`. Layer 0 of the stack.
    fn qkv(&self, token: i32, pos: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>);

    /// Scratch-reusing variant of [`TokenModel::qkv`]: clears and fills the
    /// provided buffers instead of allocating. The decode hot path calls
    /// this once per token, so implementations should override the default
    /// (which delegates to `qkv` and copies).
    fn qkv_into(
        &self,
        token: i32,
        pos: usize,
        q: &mut Vec<f32>,
        k: &mut Vec<f32>,
        v: &mut Vec<f32>,
    ) {
        let (qq, kk, vv) = self.qkv(token, pos);
        q.clear();
        q.extend_from_slice(&qq);
        k.clear();
        k.extend_from_slice(&kk);
        v.clear();
        v.extend_from_slice(&vv);
    }

    /// Projections for layer `layer` (>= 1) at absolute position `pos`,
    /// computed from the residual hidden row `[heads * head_dim]`. Models
    /// with `layers() == 1` never receive this call.
    fn qkv_layer_into(
        &self,
        _layer: usize,
        _pos: usize,
        _hidden: &[f32],
        _q: &mut Vec<f32>,
        _k: &mut Vec<f32>,
        _v: &mut Vec<f32>,
    ) {
        unimplemented!("this model has a single attention layer")
    }

    /// Vocab logits from one attention output row `[heads * head_dim]`.
    fn logits(&self, attn_row: &[f32]) -> Vec<f32>;

    /// Scratch-reusing variant of [`TokenModel::logits`]: clears and fills
    /// `out` instead of allocating.
    fn logits_into(&self, attn_row: &[f32], out: &mut Vec<f32>) {
        let l = self.logits(attn_row);
        out.clear();
        out.extend_from_slice(&l);
    }
}

/// Deterministic stand-in model: seeded per-role embedding tables, plus
/// seeded dense projection matrices for each layer past the first.
pub struct ToyModel {
    heads: usize,
    head_dim: usize,
    vocab: usize,
    layers: usize,
    /// `[vocab, heads * head_dim]` row-major, one table per role
    eq: Vec<f32>,
    ek: Vec<f32>,
    ev: Vec<f32>,
    /// per deeper layer (index `l-1` for layer `l >= 1`): a `[w, w]`
    /// row-major projection matrix per role over the hidden stream
    wq: Vec<Vec<f32>>,
    wk: Vec<Vec<f32>>,
    wv: Vec<Vec<f32>>,
}

impl ToyModel {
    /// The historical single-attention model; `stacked(.., 1)`.
    pub fn new(vocab: usize, heads: usize, head_dim: usize, seed: u64) -> ToyModel {
        Self::stacked(vocab, heads, head_dim, seed, 1)
    }

    /// An `layers`-deep stack. The layer-0 embedding tables are derived
    /// from the same rng split tags as [`ToyModel::new`] *before* any
    /// per-layer matrices, so `stacked(.., 1)` is bitwise identical to
    /// `new(..)` — the L=1 compatibility anchor the serving parity tests
    /// rely on.
    pub fn stacked(
        vocab: usize,
        heads: usize,
        head_dim: usize,
        seed: u64,
        layers: usize,
    ) -> ToyModel {
        assert!(vocab > 0 && heads > 0 && head_dim > 0 && layers > 0);
        let w = heads * head_dim;
        let mut root = Rng::new(seed);
        let mut table = |tag: u64| -> Vec<f32> {
            let mut rng = root.split(tag);
            (0..vocab * w).map(|_| rng.normal_f32(1.0)).collect()
        };
        let eq = table(1);
        let ek = table(2);
        let ev = table(3);
        let mut mat = |tag: u64| -> Vec<f32> {
            let mut rng = root.split(tag);
            (0..w * w).map(|_| rng.normal_f32(1.0)).collect()
        };
        let (mut wq, mut wk, mut wv) = (Vec::new(), Vec::new(), Vec::new());
        for l in 1..layers {
            let t = 3 * l as u64;
            wq.push(mat(t + 1));
            wk.push(mat(t + 2));
            wv.push(mat(t + 3));
        }
        ToyModel { heads, head_dim, vocab, layers, eq, ek, ev, wq, wk, wv }
    }

    fn row<'a>(table: &'a [f32], tok: usize, w: usize) -> &'a [f32] {
        &table[tok * w..(tok + 1) * w]
    }

    /// `out = mat @ hidden / sqrt(w)`, reusing `out`'s allocation.
    fn project_into(mat: &[f32], hidden: &[f32], out: &mut Vec<f32>, w: usize) {
        out.clear();
        let inv = 1.0 / (w as f32).sqrt();
        for r in 0..w {
            let mrow = &mat[r * w..(r + 1) * w];
            let mut s = 0.0f32;
            for i in 0..w {
                s += mrow[i] * hidden[i];
            }
            out.push(s * inv);
        }
    }

    /// Additive sinusoidal position signal (queries and keys only).
    fn add_phase(q: &mut [f32], k: &mut [f32], pos: usize) {
        for (i, (qi, ki)) in q.iter_mut().zip(k.iter_mut()).enumerate() {
            let phase = pos as f32 / (1.0 + i as f32);
            *qi += 0.25 * phase.sin();
            *ki += 0.25 * phase.cos();
        }
    }
}

impl TokenModel for ToyModel {
    fn heads(&self) -> usize {
        self.heads
    }

    fn head_dim(&self) -> usize {
        self.head_dim
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn layers(&self) -> usize {
        self.layers
    }

    fn qkv(&self, token: i32, pos: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (mut q, mut k, mut v) = (Vec::new(), Vec::new(), Vec::new());
        self.qkv_into(token, pos, &mut q, &mut k, &mut v);
        (q, k, v)
    }

    fn qkv_into(
        &self,
        token: i32,
        pos: usize,
        q: &mut Vec<f32>,
        k: &mut Vec<f32>,
        v: &mut Vec<f32>,
    ) {
        let w = self.heads * self.head_dim;
        let tok = (token.max(0) as usize) % self.vocab;
        q.clear();
        q.extend_from_slice(Self::row(&self.eq, tok, w));
        k.clear();
        k.extend_from_slice(Self::row(&self.ek, tok, w));
        v.clear();
        v.extend_from_slice(Self::row(&self.ev, tok, w));
        Self::add_phase(q, k, pos);
    }

    fn qkv_layer_into(
        &self,
        layer: usize,
        pos: usize,
        hidden: &[f32],
        q: &mut Vec<f32>,
        k: &mut Vec<f32>,
        v: &mut Vec<f32>,
    ) {
        assert!(
            layer >= 1 && layer < self.layers,
            "qkv_layer_into: layer {layer} out of range for a {}-layer model",
            self.layers
        );
        let w = self.heads * self.head_dim;
        debug_assert_eq!(hidden.len(), w);
        let l = layer - 1;
        Self::project_into(&self.wq[l], hidden, q, w);
        Self::project_into(&self.wk[l], hidden, k, w);
        Self::project_into(&self.wv[l], hidden, v, w);
        Self::add_phase(q, k, pos);
    }

    fn logits(&self, attn_row: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.logits_into(attn_row, &mut out);
        out
    }

    fn logits_into(&self, attn_row: &[f32], out: &mut Vec<f32>) {
        let w = self.heads * self.head_dim;
        debug_assert_eq!(attn_row.len(), w);
        out.clear();
        for tok in 0..self.vocab {
            let e = Self::row(&self.ev, tok, w);
            let mut s = 0.0f32;
            for i in 0..w {
                s += attn_row[i] * e[i];
            }
            out.push(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let a = ToyModel::new(32, 2, 8, 7);
        let b = ToyModel::new(32, 2, 8, 7);
        assert_eq!(a.qkv(5, 3), b.qkv(5, 3));
        let c = ToyModel::new(32, 2, 8, 8);
        assert_ne!(a.qkv(5, 3).0, c.qkv(5, 3).0);
    }

    #[test]
    fn position_moves_q_and_k_but_not_v() {
        let m = ToyModel::new(16, 1, 4, 1);
        let (q0, k0, v0) = m.qkv(3, 0);
        let (q9, k9, v9) = m.qkv(3, 9);
        assert_ne!(q0, q9);
        assert_ne!(k0, k9);
        assert_eq!(v0, v9);
    }

    #[test]
    fn logits_have_vocab_width() {
        let m = ToyModel::new(24, 2, 4, 1);
        let attn = vec![0.5; 8];
        assert_eq!(m.logits(&attn).len(), 24);
    }

    #[test]
    fn token_ids_wrap_into_vocab() {
        let m = ToyModel::new(8, 1, 4, 1);
        assert_eq!(m.qkv(2, 0), m.qkv(10, 0));
        // negative ids clamp to 0
        assert_eq!(m.qkv(-3, 0), m.qkv(0, 0));
    }

    #[test]
    fn stacked_one_layer_is_bitwise_identical_to_new() {
        // the compatibility anchor: per-layer matrices are split off the
        // root rng AFTER the layer-0 tables, so L=1 draws nothing extra
        let a = ToyModel::new(32, 2, 8, 7);
        let b = ToyModel::stacked(32, 2, 8, 7, 1);
        assert_eq!(a.eq, b.eq);
        assert_eq!(a.ek, b.ek);
        assert_eq!(a.ev, b.ev);
        assert_eq!(a.qkv(5, 3), b.qkv(5, 3));
        assert_eq!(a.logits(&a.qkv(5, 3).0), b.logits(&b.qkv(5, 3).0));
        assert_eq!(b.layers(), 1);
    }

    #[test]
    fn stacked_layer0_tables_do_not_depend_on_depth() {
        let a = ToyModel::stacked(32, 2, 8, 7, 1);
        let b = ToyModel::stacked(32, 2, 8, 7, 4);
        assert_eq!(a.eq, b.eq);
        assert_eq!(a.ek, b.ek);
        assert_eq!(a.ev, b.ev);
        assert_eq!(b.layers(), 4);
        assert_eq!(b.wq.len(), 3);
    }

    #[test]
    fn into_variants_match_allocating_variants() {
        let m = ToyModel::stacked(24, 2, 4, 9, 3);
        let (q, k, v) = m.qkv(5, 7);
        // seed the scratch with garbage to prove it is cleared, not appended
        let (mut qs, mut ks, mut vs) = (vec![9.0; 3], vec![9.0; 99], Vec::new());
        m.qkv_into(5, 7, &mut qs, &mut ks, &mut vs);
        assert_eq!((qs, ks, vs), (q.clone(), k, v));
        let l = m.logits(&q);
        let mut ls = vec![1.0; 2];
        m.logits_into(&q, &mut ls);
        assert_eq!(ls, l);
    }

    #[test]
    fn deeper_layers_project_from_hidden_deterministically() {
        let m1 = ToyModel::stacked(16, 1, 8, 3, 3);
        let m2 = ToyModel::stacked(16, 1, 8, 3, 3);
        let hidden: Vec<f32> = (0..8).map(|i| (i as f32 * 0.37).sin()).collect();
        let (mut q1, mut k1, mut v1) = (Vec::new(), Vec::new(), Vec::new());
        let (mut q2, mut k2, mut v2) = (Vec::new(), Vec::new(), Vec::new());
        m1.qkv_layer_into(1, 4, &hidden, &mut q1, &mut k1, &mut v1);
        m2.qkv_layer_into(1, 4, &hidden, &mut q2, &mut k2, &mut v2);
        assert_eq!((&q1, &k1, &v1), (&q2, &k2, &v2));
        // distinct layers use distinct matrices
        m2.qkv_layer_into(2, 4, &hidden, &mut q2, &mut k2, &mut v2);
        assert_ne!(q1, q2);
        // the projection actually depends on the hidden row
        let other: Vec<f32> = hidden.iter().map(|x| x + 1.0).collect();
        m1.qkv_layer_into(1, 4, &other, &mut q2, &mut k2, &mut v2);
        assert_ne!(q1, q2);
    }

    #[test]
    #[should_panic]
    fn layer_zero_is_not_a_hidden_layer() {
        let m = ToyModel::stacked(16, 1, 4, 3, 2);
        let hidden = vec![0.0; 4];
        let (mut q, mut k, mut v) = (Vec::new(), Vec::new(), Vec::new());
        m.qkv_layer_into(0, 0, &hidden, &mut q, &mut k, &mut v);
    }
}
