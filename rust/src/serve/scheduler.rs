//! Continuous-batching scheduler: admit new requests into the in-flight
//! decode batch every tick, step every live session one token, retire
//! finished requests — vLLM-style iteration-level scheduling over the
//! incremental-decode sessions of `serve::engine`.
//!
//! Contrast with the original batch mode (`Batcher::pop_batch`), which
//! ran each closed batch to completion before admitting anyone else: here
//! a short request admitted late still finishes early, and prefill of a
//! new request overlaps (in schedule order) with decode of older ones.
//!
//! **Decode runtimes** ([`SchedulerCfg::runtime`]): the in-flight set is
//! partitioned across `decode_workers` shards. Admission balances across
//! shards (least loaded wins, lowest index on ties — deterministic), and
//! each tick steps every shard concurrently, one decode token per live
//! session. Two dispatch mechanisms implement that step:
//!
//! - [`RuntimeKind::Persistent`] (default): N named, core-pinned OS
//!   workers spawned once (`serve::runtime`), each owning its shard's
//!   sessions, fed by bounded channels; idle workers *steal* sessions
//!   off the back of the most-loaded shard's deque when request lengths
//!   skew. Per-tick cost is two channel messages per worker instead of a
//!   thread spawn + join.
//! - [`RuntimeKind::TickLoop`]: the legacy baseline — scoped threads
//!   re-spawned every tick (kept as the reference the persistent runtime
//!   is benched and parity-tested against).
//!
//! Sessions are independent and each is stepped exactly once per tick
//! with the same session-local arithmetic, so neither the runtime, the
//! worker count, nor any stealing schedule can change any request's
//! tokens — `tests/thread_invariance.rs` and `tests/scheduler_fuzz.rs`
//! pin the served tokens across all of them. Per-worker counters
//! (including steal/idle/queue-depth metrics on the persistent runtime)
//! are exposed via [`ContinuousScheduler::worker_stats`].
//!
//! **Paged-pool admission**: with a bounded paged KV pool
//! (`ServeCfg::pool_blocks`), admission is against *pool capacity*, not
//! just decode slots — a candidate is admitted only when its worst-case
//! block reservation (`ServeEngine::block_reserve`) fits beside the pool's
//! materialized blocks plus the *not-yet-materialized* remainder of every
//! live session's reservation (`ServeEngine::remaining_reserve` — the
//! delta shrinks as sessions fill their tails and drops to zero when they
//! finish, so already-allocated blocks are never counted twice and freed
//! headroom admits immediately). A decode step can thus never hit an
//! exhausted pool. With [`ContinuousScheduler::set_shared_prefix`], every
//! admission *forks* one prefilled system-prompt session copy-on-write
//! instead of prefilling from scratch; tokens are identical either way.
//! On the persistent runtime the scheduler tracks a *metadata mirror*
//! (id, shard, reservation, freeable blocks) of the worker-owned
//! sessions, refreshed from each step report, so every admission and
//! eviction decision is computed from exactly the values the tick-loop
//! would see — session state never changes between steps, so the
//! mirrored numbers are exact, not approximations.
//!
//! **Eviction / oversubscription**: when a candidate's reservation does
//! not fit, the scheduler *evicts* instead of deferring — it preempts the
//! SLA-ranked victim (lowest priority class first, then
//! least-recently-stepped, then cheapest to re-prefill; see
//! `sla_victim`; sessions admitted, resumed or stepped this tick are
//! protected, and a candidate never evicts a victim of a strictly
//! higher class), releases its pool blocks (`ServeEngine::evict_session`
//! — blocks shared with a live table, e.g. the system prefix, survive
//! via refcounts) and parks it on a preempted queue. On the persistent
//! runtime this is a synchronous round-trip to the owning worker, which
//! hands the session back with its blocks released. A feasibility check
//! runs before any eviction — if preempting every eligible session
//! still could not fit the candidate, it defers without destroying
//! state. Preempted sessions resume *before* same-or-lower-class
//! admissions, most urgent class first and lowest id within a class, by
//! transparent re-prefill (`ServeEngine::resume_session`): the rebuilt
//! state and every token served afterwards are bit-identical to a
//! never-evicted run. All eviction decisions derive from (priority
//! class, last-stepped tick, freeable blocks, session id) and pool
//! counts — no map iteration order, no wall clock — so they are
//! deterministic and invariant to the decode worker count and runtime.
//! [`EvictionStats`] counts evictions (per class), reclaimed blocks,
//! resumes and re-prefill time.
//!
//! **Tiered KV swap** ([`SchedulerCfg::swap_blocks`] > 0): an eviction
//! may *swap out* instead of dropping — the victim's private tail blocks
//! are snapshotted byte-exact into a bounded host tier
//! (`ServeEngine::swap_out_session`) while any refcounted shared prefix
//! stays resident, and its resume *restores* the snapshot
//! (`swap_in_session`) instead of re-prefilling whenever the
//! deterministic cost model says restore is cheaper
//! ([`SWAP_IN_COST_PER_BLOCK`] vs [`REPREFILL_COST_PER_BLOCK`] — pure
//! block-count arithmetic, so the schedule stays bitwise identical
//! across runtimes × workers × steal plans). Victims that do not fit the
//! tier, and images whose checksum no longer verifies (chaos
//! `SwapCorrupt`), demote transparently to the drop/re-prefill path.
//! [`SwapStats`] counts offloads, restores, bytes and fallbacks; the
//! default `swap_blocks = 0` keeps bitwise parity with older releases.
//!
//! **Overload control**: every request carries a [`Priority`] class and
//! an optional deadline budget ([`Request::deadline`]). Admission is
//! urgency-ordered (class first, FIFO within a class); a queued request
//! whose budget expires — or whose reservation can *never* fit the pool
//! — is **shed** with a typed [`ServeError::Shed`] (collected via
//! [`ContinuousScheduler::sheds`]) instead of waiting forever or
//! aborting the scheduler. A preempted session whose resume cannot fit
//! backs off exponentially (deterministic tick arithmetic) instead of
//! head-of-line-blocking arrivals: while it waits, strictly
//! higher-class arrivals are still admitted (with uniform priorities
//! this degenerates to the old strict resumes-before-arrivals rule).
//! The optional pressure dial ([`SchedulerCfg::degrade`]) downshifts
//! MoBA top-k for non-interactive admissions once deterministic pool
//! occupancy crosses a threshold — off by default, preserving bitwise
//! parity with previous releases. Completed requests that overran their
//! budget count as SLA violations in [`OverloadStats`] (stats only —
//! wall-clock never drives a decision).
//!
//! **Fault tolerance** (persistent runtime): a decode-worker fault —
//! panic report, closed channel, or a missed
//! [`SchedulerCfg::barrier_deadline_secs`] barrier — degrades into the
//! eviction/resume machinery instead of aborting. The scheduler keeps a
//! *recovery ledger* (per worker-owned session: request identity + the
//! token transcript so far, advanced from each step report); on a death
//! it quarantines every session struct the runtime saved, rebuilds the
//! rest from the ledger (`ServeEngine::adopt_session`), and parks them
//! all on the preempted queue, where the ordinary re-prefill resume
//! re-homes them onto surviving shards. Served tokens stay bitwise
//! identical to a fault-free run — greedy decode is a pure function of
//! (prompt, generated-so-far), and the transcript is the whole state.
//! [`FaultStats`] (in `SchedStats::fault`) counts deaths, re-homed
//! sessions, barrier timeouts and recovery re-prefill time; the seeded
//! chaos harness (`SchedulerCfg::chaos`, `serve::chaos`) injects
//! deterministic faults to prove all of this under test.
//!
//! The scheduler is driven by a simulation clock (`tick(now)`), like the
//! batcher, so arrival/queueing behavior is deterministic and testable;
//! prefill/decode times are measured wall clock from the engine.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::batcher::{Batcher, BatcherCfg, Priority, Request, RequestResult};
use super::chaos::{FaultKind, FaultPlan};
use super::engine::{DecodeSession, ServeEngine};
use super::error::{FaultStats, ServeError};
use super::model::TokenModel;
use super::runtime::{pin_from_env, steal_from_env, DecodeRuntime, Live, RuntimeKind};

/// Scheduler limits and dispatch selection.
#[derive(Clone, Debug)]
pub struct SchedulerCfg {
    /// decode-batch capacity: max sessions stepped per tick (across all
    /// shards)
    pub max_in_flight: usize,
    /// decode worker shards stepping the in-flight set concurrently;
    /// 1 = the single-threaded scheduler
    pub decode_workers: usize,
    /// how decode work is dispatched: persistent pinned workers
    /// (default) or the legacy per-tick scoped-thread loop
    pub runtime: RuntimeKind,
    /// work stealing between shards (persistent runtime only); default
    /// from `MOBA_STEAL`, on unless disabled
    pub steal: bool,
    /// pin decode workers to cores (persistent runtime only); default
    /// from `MOBA_PIN`, on unless disabled
    pub pin: bool,
    /// deterministic fault-injection schedule (persistent runtime only;
    /// the tick-loop ignores it — it is the fault-free oracle chaos runs
    /// are compared against). `None` = no injected faults.
    pub chaos: Option<FaultPlan>,
    /// how long the per-tick step barrier waits for a worker's reply
    /// before declaring it dead and recovering its sessions (persistent
    /// runtime only). `None` = wait forever (panics and disconnects are
    /// still detected immediately; the deadline only catches stalls).
    pub barrier_deadline_secs: Option<f64>,
    /// pressure-tiered degradation dial: downshift MoBA top-k for
    /// non-interactive admissions once deterministic pool occupancy
    /// crosses a threshold. `None` (default) = off — served tokens stay
    /// bitwise identical to a scheduler without the dial.
    pub degrade: Option<DegradeCfg>,
    /// host swap-tier capacity in pool blocks (0 = swap disabled —
    /// bitwise parity with a scheduler without a tier). When > 0, an
    /// eviction snapshots the victim's private tail into host memory
    /// (`ServeEngine::swap_out_session`) instead of dropping it whenever
    /// the tail fits the remaining tier capacity, and its resume
    /// restores the snapshot instead of re-prefilling when the
    /// deterministic cost model ([`SWAP_IN_COST_PER_BLOCK`] vs
    /// [`REPREFILL_COST_PER_BLOCK`]) says restore is cheaper. A victim
    /// that does not fit demotes to a full drop (counted in
    /// `SwapStats::fallbacks`). NOT read from the environment here —
    /// `DemoCfg` and the CLI wire `MOBA_SWAP_BLOCKS` through explicitly,
    /// so library defaults never flip under an exported variable.
    pub swap_blocks: usize,
}

impl Default for SchedulerCfg {
    fn default() -> Self {
        SchedulerCfg {
            max_in_flight: 8,
            decode_workers: 1,
            runtime: RuntimeKind::Persistent,
            steal: steal_from_env(),
            pin: pin_from_env(),
            chaos: None,
            barrier_deadline_secs: None,
            degrade: None,
            swap_blocks: 0,
        }
    }
}

/// Deterministic resume-cost model, in abstract units per pool block:
/// restoring one swapped block is a memcpy; re-prefilling it recomputes
/// QKV + attention for `block_size` tokens — an order of magnitude more
/// work. The exact ratio does not matter for correctness, only that both
/// costs are *pure block-count arithmetic* at fixed rates: the swap-vs-
/// recompute choice is then a function of the simulation state alone, so
/// shed/token sets stay bitwise identical across runtimes × worker
/// counts × steal schedules (wall-clock `reprefill_secs`/`swapin_secs`
/// stay reporting-only, exactly like the SLA latency accounting).
pub const SWAP_IN_COST_PER_BLOCK: u64 = 1;
/// See [`SWAP_IN_COST_PER_BLOCK`].
pub const REPREFILL_COST_PER_BLOCK: u64 = 8;

/// Host swap-tier capacity from `MOBA_SWAP_BLOCKS` (unset or unparsable
/// → 0 = swap disabled). Lenient like `chaos::seed_from_env`; the CLI
/// boundary validates through [`parse_swap_blocks`] so a typo fails
/// loudly there instead.
pub fn swap_blocks_from_env() -> usize {
    std::env::var("MOBA_SWAP_BLOCKS").ok().and_then(|v| v.trim().parse().ok()).unwrap_or(0)
}

/// Strict `MOBA_SWAP_BLOCKS` parser (the `MOBA_STEAL` pattern): unset is
/// fine, but a set-and-unparsable value is a contextful error rather
/// than silently serving without a swap tier.
pub fn parse_swap_blocks(raw: Option<String>) -> Result<Option<usize>, String> {
    match raw {
        None => Ok(None),
        Some(v) => match v.trim().parse::<usize>() {
            Ok(n) => Ok(Some(n)),
            Err(_) => {
                Err(format!("MOBA_SWAP_BLOCKS must be a non-negative integer, got {v:?}"))
            }
        },
    }
}

/// Strict env read for the CLI boundary.
pub fn swap_blocks_from_env_strict() -> Result<Option<usize>, String> {
    parse_swap_blocks(std::env::var("MOBA_SWAP_BLOCKS").ok())
}

/// Pressure-tiered degradation dial (`SchedulerCfg::degrade`). The
/// trigger is `used + reserved >= occupancy * capacity` on the bounded
/// paged pool — deterministic block arithmetic, never wall-clock — so a
/// degraded run is reproducible tick for tick. Interactive requests are
/// never degraded, and forked (shared-prefix) sessions inherit their
/// parent's sparsity, so the dial only touches private non-interactive
/// admissions.
#[derive(Clone, Copy, Debug)]
pub struct DegradeCfg {
    /// occupancy fraction of the bounded pool at/above which new
    /// non-interactive admissions decode with the downshifted top-k
    pub occupancy: f64,
    /// the downshifted MoBA top-k (clamped to `[1, ServeCfg::topk]`)
    pub topk: usize,
}

/// Aggregate counters over the scheduler's lifetime.
#[derive(Clone, Debug, Default)]
pub struct SchedStats {
    pub admitted: usize,
    pub completed: usize,
    pub decode_rounds: usize,
    pub decode_steps_total: usize,
    pub peak_in_flight: usize,
    /// NEW admissions deferred because the paged pool could not cover the
    /// candidate's worst-case block reservation even after evicting every
    /// unprotected session (blocked resumes of already-preempted sessions
    /// count under `EvictionStats::resume_deferrals` instead)
    pub pool_deferrals: usize,
    /// peak physical blocks resident in the shared paged pool (0 for
    /// private-cache backends)
    pub peak_pool_blocks: usize,
    /// preemption counters for the oversubscribed paged pool
    pub eviction: EvictionStats,
    /// worker-fault and recovery counters (persistent runtime)
    pub fault: FaultStats,
    /// overload-control counters: sheds, SLA violations, degradations
    pub overload: OverloadStats,
    /// host swap-tier counters: offloads, restores, demote-to-drop
    /// fallbacks (bounded tier or corrupted image)
    pub swap: SwapStats,
}

/// Host swap-tier counters (`SchedStats::swap`). All zero when
/// `SchedulerCfg::swap_blocks == 0`.
#[derive(Clone, Debug, Default)]
pub struct SwapStats {
    /// evictions that snapshotted the victim's private tail to the host
    /// tier instead of dropping it
    pub swap_outs: usize,
    /// resumes restored from a host-tier image instead of re-prefilled
    pub swap_ins: usize,
    /// total K/V payload bytes offloaded to the host tier
    pub bytes: usize,
    /// swaps demoted to the drop/re-prefill path: tier capacity
    /// exhausted at eviction, snapshot/restore failed, or the image's
    /// checksum no longer verified (e.g. chaos `SwapCorrupt`)
    pub fallbacks: usize,
    /// wall-clock seconds spent restoring swapped images — the memcpy
    /// cost the tier trades against re-prefill recompute
    /// (reporting-only, like `EvictionStats::reprefill_secs`)
    pub swapin_secs: f64,
}

/// Overload-control counters (`SchedStats::overload`).
#[derive(Clone, Debug, Default)]
pub struct OverloadStats {
    /// requests shed at admission because their worst-case reservation
    /// can never fit the pool (deferral would hang forever)
    pub shed_infeasible: usize,
    /// requests shed from the queue after their deadline budget expired
    pub shed_deadline: usize,
    /// completed requests whose queue + prefill + decode latency
    /// overran their deadline budget (accounting only — wall-clock
    /// latencies never drive a scheduling decision)
    pub sla_violations: usize,
    /// sessions admitted with a downshifted MoBA top-k (pressure dial)
    pub degraded_sessions: usize,
    /// deferred resumes re-attempted after an exponential-backoff window
    pub resume_retries: usize,
}

/// Counters for LRU eviction / re-prefill resume on a bounded paged pool.
#[derive(Clone, Debug, Default)]
pub struct EvictionStats {
    /// live sessions preempted to make room for a candidate
    pub evictions: usize,
    /// physical blocks actually reclaimed by those evictions (blocks a
    /// live table still shares — e.g. the system prefix — not counted)
    pub blocks_reclaimed: usize,
    /// preempted sessions rebuilt via transparent re-prefill
    pub resumes: usize,
    /// ticks a blocked resume kept waiting for room (counted separately
    /// from `SchedStats::pool_deferrals`, which covers new admissions)
    pub resume_deferrals: usize,
    /// wall-clock seconds spent re-prefilling resumed sessions — the
    /// recompute cost oversubscription trades against resident KV
    pub reprefill_secs: f64,
    /// evictions per priority class, indexed by `Priority::rank()` —
    /// the SLA-aware victim policy's observable: under mixed-priority
    /// thrash, high classes must take strictly fewer hits than low ones
    pub evictions_by_class: [usize; 3],
}

/// Per-worker counters: admission balance, decode-latency accounting and
/// (persistent runtime) steal/idle/queue-depth metrics for one decode
/// worker. Per-worker decode *tokens* equal `decode_steps` — every step
/// emits exactly one token.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub admitted: usize,
    pub decode_rounds: usize,
    pub decode_steps: usize,
    /// wall-clock seconds this worker spent stepping sessions
    pub busy_secs: f64,
    pub peak_in_flight: usize,
    /// sessions this worker pulled from another shard's deque
    /// (persistent runtime with stealing; 0 otherwise)
    pub steals: usize,
    /// decode tokens this worker produced from stolen sessions
    pub stolen_steps: usize,
    /// step rounds this worker entered with no owned sessions and found
    /// nothing to steal (persistent runtime)
    pub idle_ticks: usize,
    /// high-water mark of outstanding commands on this worker's channel,
    /// observed at send time — an upper bound on actual queue depth
    /// (persistent runtime)
    pub queue_depth_hwm: usize,
}

struct Shard {
    running: Vec<Live>,
    stats: WorkerStats,
}

impl Shard {
    /// Step every live session one decode token; returns nothing — all
    /// accounting lands in the shard's own stats (no shared state).
    fn step_all<M: TokenModel>(&mut self, engine: &ServeEngine<M>, tick: u64) {
        if self.running.is_empty() {
            return;
        }
        let t0 = Instant::now();
        let mut steps = 0;
        for live in self.running.iter_mut() {
            // a pausing session keeps its stale `last_stepped`, which is
            // what lets the SLA victim key tell an idle stream from an
            // active one (same rule as the persistent runtime's step_one)
            if live.pause_this_tick() {
                continue;
            }
            live.last_stepped = tick;
            if engine.step(&mut live.session).is_some() {
                steps += 1;
            }
        }
        self.stats.decode_rounds += 1;
        self.stats.decode_steps += steps;
        self.stats.busy_secs += t0.elapsed().as_secs_f64();
    }
}

/// Scheduler-side metadata mirror of one worker-owned session
/// (persistent runtime). Exact between steps: nothing mutates a session
/// while it sits on its worker, so the values reported after its last
/// step are the values a fresh engine query would return.
struct Remote {
    id: u64,
    shard: usize,
    last_stepped: u64,
    reserve: usize,
    freeable: usize,
    /// SLA class, mirrored for victim ranking without a worker round-trip
    priority: Priority,
}

/// Everything needed to rebuild a worker-owned session if its worker
/// dies with the struct: the request identity plus the transcript of
/// tokens generated so far, kept in lockstep with the step reports'
/// `(out_len, last_token)`. Recovery via `ServeEngine::adopt_session` +
/// re-prefill resume is then bit-identical to a fault-free run — greedy
/// tokens are a pure function of (prompt, generated-so-far).
struct LedgerEntry {
    own_prompt: Vec<i32>,
    fork_ctx: usize,
    max_new: usize,
    queue_secs: f64,
    generated: Vec<i32>,
    /// overload-control identity, so a rebuilt session keeps its SLA
    /// class, deadline budget, pause cadence and (degraded) sparsity
    priority: Priority,
    deadline: Option<f64>,
    pause_every: usize,
    topk: usize,
}

/// Where the in-flight sessions physically live.
enum Dispatch {
    /// legacy: sessions held here, scoped threads re-spawned per tick
    Tick { shards: Vec<Shard> },
    /// persistent workers own the sessions; the scheduler keeps the
    /// metadata mirror and merged per-worker stats
    Persistent {
        rt: DecodeRuntime,
        mirror: Vec<Remote>,
        wstats: Vec<WorkerStats>,
        /// per-shard occupancy scratch (placement + peak tracking),
        /// reused every tick
        counts: Vec<usize>,
        /// recovery ledger: one transcript per worker-owned session
        /// (inserted at placement, advanced from step reports, removed
        /// at eviction/retirement/recovery)
        ledger: BTreeMap<u64, LedgerEntry>,
    },
}

/// An eviction target, addressed per dispatch mode.
enum Victim {
    Shard { si: usize, idx: usize },
    Mirror { idx: usize },
}

/// Iteration-level scheduler over a `ServeEngine`, sharded across decode
/// workers. `M: Send + Sync + 'static` because the persistent runtime's
/// worker threads step sessions against the shared engine concurrently
/// (and outlive any single borrow).
pub struct ContinuousScheduler<M: TokenModel> {
    engine: Arc<ServeEngine<M>>,
    cfg: SchedulerCfg,
    queue: Batcher,
    dispatch: Dispatch,
    /// sessions preempted by pool-pressure eviction, awaiting re-prefill
    /// resume; they hold no pool blocks and no decode slot while here
    preempted: Vec<Live>,
    /// running sum of every live session's `reserve_blocks` — the O(1)
    /// admission-side view of future pool demand (kept in lockstep on
    /// admit/step/evict/retire; a debug assert recounts it)
    reserved_total: usize,
    /// monotonic tick counter driving the recency half of the SLA
    /// eviction key (and the resume-backoff arithmetic)
    tick_no: u64,
    /// shared-system-prompt session every admission forks from (paged
    /// backend): its physical blocks are held once for all requests
    prefix: Option<DecodeSession>,
    /// pool blocks held by the shared prefix itself
    prefix_blocks: usize,
    /// retirement scratch, reused across ticks (no per-tick allocation)
    finished_scratch: Vec<Live>,
    /// overload-control rejections `(id, ServeError::Shed)`, in shed
    /// order — callers account for every request as result OR shed
    sheds: Vec<(u64, ServeError)>,
    /// pool blocks currently resident in the host swap tier (the sum of
    /// `n_blocks()` over every preempted session's image); bounded by
    /// `SchedulerCfg::swap_blocks`
    swap_used: usize,
    pub stats: SchedStats,
}

impl<M: TokenModel + Send + Sync + 'static> ContinuousScheduler<M> {
    pub fn new(engine: ServeEngine<M>, cfg: SchedulerCfg) -> ContinuousScheduler<M> {
        assert!(cfg.max_in_flight > 0);
        assert!(cfg.decode_workers > 0);
        let engine = Arc::new(engine);
        let dispatch = match cfg.runtime {
            RuntimeKind::TickLoop => Dispatch::Tick {
                shards: (0..cfg.decode_workers)
                    .map(|_| Shard { running: Vec::new(), stats: WorkerStats::default() })
                    .collect(),
            },
            RuntimeKind::Persistent => Dispatch::Persistent {
                rt: DecodeRuntime::spawn(
                    engine.clone(),
                    cfg.decode_workers,
                    cfg.steal,
                    cfg.pin,
                    cfg.max_in_flight + 2,
                    cfg.chaos.clone(),
                    cfg.barrier_deadline_secs.map(Duration::from_secs_f64),
                ),
                mirror: Vec::new(),
                wstats: vec![WorkerStats::default(); cfg.decode_workers],
                counts: vec![0; cfg.decode_workers],
                ledger: BTreeMap::new(),
            },
        };
        ContinuousScheduler {
            engine,
            cfg,
            // admission policy fields are unused in continuous mode
            queue: Batcher::new(BatcherCfg::default()),
            dispatch,
            preempted: Vec::new(),
            reserved_total: 0,
            tick_no: 0,
            prefix: None,
            prefix_blocks: 0,
            finished_scratch: Vec::new(),
            sheds: Vec::new(),
            swap_used: 0,
            stats: SchedStats::default(),
        }
    }

    /// Prefill `prompt` once as the shared system prefix: every request
    /// admitted afterwards forks it copy-on-write (O(1) in data moved)
    /// and decodes only its own continuation. Requires the paged backend
    /// — private caches cannot share state across sessions.
    pub fn set_shared_prefix(&mut self, prompt: &[i32]) -> Result<()> {
        let Some(pool) = self.engine.pool_status() else {
            bail!("shared-prefix serving requires the 'paged' backend");
        };
        let b = self.engine.cfg().block_size;
        let need = (prompt.len() + b - 1) / b;
        if let Some(cap) = pool.capacity_blocks {
            if need >= cap {
                bail!(
                    "shared prefix needs {need} of {cap} pool blocks, leaving none for requests"
                );
            }
        }
        let session = self.engine.start(prompt, 0)?;
        self.prefix_blocks = need;
        self.prefix = Some(session);
        Ok(())
    }

    /// Tokens in the shared prefix every admission forks from (0 = none).
    pub fn shared_prefix_len(&self) -> usize {
        self.prefix.as_ref().map(|s| s.context_len()).unwrap_or(0)
    }

    /// Recount of every live session's remaining reservation — only for
    /// the debug assertion that the running counter never drifts (the
    /// hot path uses `reserved_total`, not this scan).
    fn recount_reserved(&self) -> usize {
        match &self.dispatch {
            Dispatch::Tick { shards } => {
                shards.iter().flat_map(|s| s.running.iter()).map(|l| l.reserve_blocks).sum()
            }
            Dispatch::Persistent { mirror, .. } => mirror.iter().map(|r| r.reserve).sum(),
        }
    }

    /// Physical blocks currently resident in the paged pool (0 without
    /// one) — the materialized half of the admission check.
    fn pool_used(&self) -> usize {
        self.engine.pool_status().map(|p| p.used_blocks).unwrap_or(0)
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.pending()
    }

    pub fn in_flight(&self) -> usize {
        match &self.dispatch {
            Dispatch::Tick { shards } => shards.iter().map(|s| s.running.len()).sum(),
            Dispatch::Persistent { mirror, .. } => mirror.len(),
        }
    }

    /// Sessions preempted by pool-pressure eviction, awaiting resume.
    pub fn preempted(&self) -> usize {
        self.preempted.len()
    }

    /// Requests rejected by overload control — deadline expiry or a
    /// can-never-fit reservation — each with its typed
    /// [`ServeError::Shed`]. Every submitted request ends up exactly
    /// once as a tick result or an entry here.
    pub fn sheds(&self) -> &[(u64, ServeError)] {
        &self.sheds
    }

    pub fn idle(&self) -> bool {
        self.in_flight() == 0 && self.queue.pending() == 0 && self.preempted.is_empty()
    }

    pub fn engine(&self) -> &ServeEngine<M> {
        &self.engine
    }

    /// The configured decode runtime.
    pub fn runtime(&self) -> RuntimeKind {
        self.cfg.runtime
    }

    /// Per-worker admission/latency/steal counters, one entry per decode
    /// worker.
    pub fn worker_stats(&self) -> Vec<WorkerStats> {
        match &self.dispatch {
            Dispatch::Tick { shards } => shards.iter().map(|s| s.stats.clone()).collect(),
            Dispatch::Persistent { rt, wstats, .. } => wstats
                .iter()
                .enumerate()
                .map(|(w, s)| {
                    let mut s = s.clone();
                    s.queue_depth_hwm = rt.depth_hwm(w);
                    s
                })
                .collect(),
        }
    }

    /// The SLA-aware eviction victim for a candidate of rank
    /// `max_rank`: lowest priority class first (batch absorbs pressure
    /// before standard, standard before interactive), then
    /// least-recently-stepped (a paused/idle stream is staler than an
    /// active one), then fewest freeable blocks — the deterministic
    /// re-prefill-cost proxy: a session's freeable blocks are exactly
    /// the tokens a resume must re-ingest, and the measured per-block
    /// re-prefill rate (`EvictionStats::reprefill_secs /
    /// blocks_reclaimed`) scales every candidate equally, so ranking by
    /// the block count IS ranking by measured cost without consulting
    /// wall-clock — with a stable tie-break on HIGHEST session id (the
    /// youngest request is preempted first, so the oldest always makes
    /// progress — no livelock). Sessions touched this tick (admitted,
    /// resumed or already stepped) are protected, and a victim of a
    /// class strictly above `max_rank` is never offered — a batch
    /// arrival cannot thrash an interactive session's KV. The key is
    /// unique and independent of shard layout, so the choice is
    /// deterministic and invariant to `decode_workers`, the runtime,
    /// and any stealing schedule.
    fn sla_victim(&self, max_rank: usize) -> Option<Victim> {
        type Key = (usize, u64, usize, std::cmp::Reverse<u64>);
        let mut best: Option<(Key, Victim)> = None;
        let mut offer = |key: Key, at: Victim| {
            let better = match &best {
                None => true,
                Some((k, _)) => key < *k,
            };
            if better {
                best = Some((key, at));
            }
        };
        match &self.dispatch {
            Dispatch::Tick { shards } => {
                for (si, shard) in shards.iter().enumerate() {
                    for (i, live) in shard.running.iter().enumerate() {
                        if live.last_stepped >= self.tick_no || live.priority.rank() > max_rank {
                            continue; // protected, or outranks the candidate
                        }
                        offer(
                            (
                                live.priority.rank(),
                                live.last_stepped,
                                self.engine.freeable_blocks(&live.session),
                                std::cmp::Reverse(live.id),
                            ),
                            Victim::Shard { si, idx: i },
                        );
                    }
                }
            }
            Dispatch::Persistent { mirror, .. } => {
                for (i, r) in mirror.iter().enumerate() {
                    if r.last_stepped >= self.tick_no || r.priority.rank() > max_rank {
                        continue;
                    }
                    offer(
                        (r.priority.rank(), r.last_stepped, r.freeable, std::cmp::Reverse(r.id)),
                        Victim::Mirror { idx: i },
                    );
                }
            }
        }
        drop(offer);
        best.map(|(_, at)| at)
    }

    /// Preempt the addressed live session: release its pool blocks
    /// (shared blocks survive via refcounts) and park it on the
    /// preempted queue for a later re-prefill resume. On the persistent
    /// runtime this is a synchronous round-trip to the owning worker.
    fn evict_live(&mut self, victim: Victim) -> Result<()> {
        match victim {
            Victim::Shard { si, idx } => {
                let Dispatch::Tick { shards } = &mut self.dispatch else {
                    unreachable!("shard victim without tick dispatch")
                };
                let mut live = shards[si].running.swap_remove(idx);
                // finished sessions retire the same tick they finish, so
                // a victim is always mid-decode and will be resumed
                // before it can retire
                debug_assert!(!live.session.finished(), "evicting a finished session");
                self.reserved_total -= live.reserve_blocks;
                live.reserve_blocks = 0;
                // swap-vs-drop is pure block-count arithmetic on
                // simulation state (`freeable`, `swap_used`, the cfg
                // bound) — identical across runtimes and schedules. The
                // `freeable > 0` gate skips un-diverged forks whose tail
                // is fully shared: restoring them would allocate a block
                // re-prefill fork-sharing would not, breaking occupancy
                // parity with the swap-disabled schedule.
                let freeable = self.engine.freeable_blocks(&live.session);
                let want_swap = self.cfg.swap_blocks > 0 && freeable > 0;
                let do_swap = want_swap && self.swap_used + freeable <= self.cfg.swap_blocks;
                let freed = if do_swap {
                    match self.engine.swap_out_session(&mut live.session) {
                        Ok((freed, image)) => {
                            live.swap = Some(image);
                            freed
                        }
                        Err(_) => self.engine.evict_session(&mut live.session)?,
                    }
                } else {
                    self.engine.evict_session(&mut live.session)?
                };
                self.stats.eviction.evictions += 1;
                self.stats.eviction.evictions_by_class[live.priority.rank()] += 1;
                self.stats.eviction.blocks_reclaimed += freed;
                if let Some(img) = &live.swap {
                    self.swap_used += img.n_blocks();
                    self.stats.swap.swap_outs += 1;
                    self.stats.swap.bytes += img.payload_bytes();
                } else if want_swap {
                    self.stats.swap.fallbacks += 1;
                }
                self.preempted.push(live);
            }
            Victim::Mirror { idx } => {
                let owner_died;
                {
                    let Dispatch::Persistent { rt, mirror, ledger, .. } = &mut self.dispatch
                    else {
                        unreachable!("mirror victim without persistent dispatch")
                    };
                    // decide swap-vs-drop BEFORE the round-trip, from the
                    // mirrored freeable count (exact between steps); the
                    // worker snapshots or drops accordingly and ships the
                    // image back on the Live.
                    let freeable = mirror[idx].freeable;
                    let want_swap = self.cfg.swap_blocks > 0 && freeable > 0;
                    let do_swap =
                        want_swap && self.swap_used + freeable <= self.cfg.swap_blocks;
                    match rt.evict(mirror[idx].shard, mirror[idx].id, do_swap) {
                        Ok((mut live, freed)) => {
                            let freed = freed?;
                            let remote = mirror.swap_remove(idx);
                            ledger.remove(&remote.id);
                            debug_assert!(
                                !live.session.finished(),
                                "evicting a finished session"
                            );
                            self.reserved_total -= remote.reserve;
                            live.reserve_blocks = 0;
                            self.stats.eviction.evictions += 1;
                            self.stats.eviction.evictions_by_class[remote.priority.rank()] += 1;
                            self.stats.eviction.blocks_reclaimed += freed;
                            if let Some(img) = &live.swap {
                                self.swap_used += img.n_blocks();
                                self.stats.swap.swap_outs += 1;
                                self.stats.swap.bytes += img.payload_bytes();
                            } else if want_swap {
                                // the worker's snapshot failed and it fell
                                // back to a plain drop
                                self.stats.swap.fallbacks += 1;
                            }
                            self.preempted.push(live);
                            owner_died = false;
                        }
                        // the owning worker died before answering: no
                        // eviction happened — recover the whole dead
                        // shard (including this victim) below, and let
                        // the caller re-check fit / re-pick a victim
                        Err(_) => owner_died = true,
                    }
                }
                if owner_died {
                    let recovered = self.recover_deaths()?;
                    debug_assert!(recovered > 0, "evict failed but no death was recorded");
                }
            }
        }
        Ok(())
    }

    /// Process every worker death the runtime has observed: quarantine
    /// the intact session structs it saved (orphans), rebuild the rest
    /// from the recovery ledger, park all of them on the preempted queue
    /// (resume re-prefills them bit-identically — the transcript is the
    /// whole state), and strip the dead shard from the mirror. Must run
    /// while the mirror still describes the dead worker's ownership —
    /// i.e. any time EXCEPT between the post-step `mirror.clear()` and
    /// its rebuild. Returns how many deaths were processed.
    fn recover_deaths(&mut self) -> Result<usize> {
        let Dispatch::Persistent { rt, mirror, ledger, .. } = &mut self.dispatch else {
            return Ok(0);
        };
        let deaths = rt.take_deaths();
        let n = deaths.len();
        for death in deaths {
            self.stats.fault.worker_deaths += 1;
            if matches!(death.error, ServeError::BarrierTimeout { .. }) {
                self.stats.fault.barrier_timeouts += 1;
            }
            // intact structs first: quarantine (release whatever blocks
            // they still hold) and park for resume. A session whose own
            // step panicked gets its pending token wiped — resume
            // recomputes it from the transcript, which a mid-step panic
            // cannot corrupt.
            let mut orphan_ids: Vec<u64> = Vec::with_capacity(death.orphans.len());
            for mut live in death.orphans {
                orphan_ids.push(live.id);
                ledger.remove(&live.id);
                live.reserve_blocks = 0;
                if !live.poisoned && live.session.finished() {
                    // stepped to completion by a thief before the owner
                    // died: nothing to recover, just retire it
                    self.finished_scratch.push(live);
                    continue;
                }
                self.engine.quarantine_session(&mut live.session, !live.poisoned);
                live.poisoned = false;
                live.rehomed = true;
                self.stats.fault.rehomed_sessions += 1;
                self.preempted.push(live);
            }
            // sessions lost with the thread: rebuild from the ledger
            // transcript (recovery-as-eviction — the adopted session is
            // evicted-with-no-blocks and resumes like any preemptee)
            for i in (0..mirror.len()).rev() {
                if mirror[i].shard != death.worker {
                    continue;
                }
                let remote = mirror.swap_remove(i);
                self.reserved_total -= remote.reserve;
                if orphan_ids.contains(&remote.id) {
                    continue; // recovered via its struct above
                }
                let Some(entry) = ledger.remove(&remote.id) else {
                    bail!(ServeError::Inconsistent {
                        what: "recovery ledger entry missing for a session lost with its worker"
                    });
                };
                let session = self.engine.adopt_session(
                    entry.own_prompt,
                    entry.fork_ctx,
                    entry.generated,
                    entry.max_new,
                    entry.topk,
                );
                self.preempted.push(Live {
                    id: remote.id,
                    queue_secs: entry.queue_secs,
                    reserve_blocks: 0,
                    last_stepped: 0,
                    home: 0,
                    poisoned: false,
                    rehomed: true,
                    priority: entry.priority,
                    deadline: entry.deadline,
                    pause_every: entry.pause_every,
                    paused: false,
                    retry_at: 0,
                    backoff: 1,
                    swap: None,
                    session,
                });
                self.stats.fault.rehomed_sessions += 1;
            }
        }
        Ok(n)
    }

    /// Make room for a candidate of rank `max_rank` needing `need`
    /// not-yet-materialized blocks: evict SLA-ranked victims one at a
    /// time until `used + reserved + need` fits under `cap`, or defer.
    /// A feasibility check runs BEFORE any eviction — preempting every
    /// eligible (unprotected, not-outranking) session must suffice,
    /// otherwise the candidate defers without destroying anyone's state
    /// (each pointless eviction would cost a full re-prefill later). On
    /// the persistent runtime the freeable counts come from the
    /// metadata mirror, which is exact: session state is static between
    /// steps.
    fn fit_or_evict(&mut self, need: usize, cap: usize, max_rank: usize) -> Result<bool> {
        debug_assert_eq!(self.reserved_total, self.recount_reserved(), "reservation drift");
        if self.pool_used() + self.reserved_total + need <= cap {
            return Ok(true);
        }
        let (mut freeable, mut victim_reserve) = (0usize, 0usize);
        match &self.dispatch {
            Dispatch::Tick { shards } => {
                for shard in shards {
                    for live in &shard.running {
                        if live.last_stepped < self.tick_no && live.priority.rank() <= max_rank {
                            freeable += self.engine.freeable_blocks(&live.session);
                            victim_reserve += live.reserve_blocks;
                        }
                    }
                }
            }
            Dispatch::Persistent { mirror, .. } => {
                for r in mirror {
                    if r.last_stepped < self.tick_no && r.priority.rank() <= max_rank {
                        freeable += r.freeable;
                        victim_reserve += r.reserve;
                    }
                }
            }
        }
        let best_used = self.pool_used().saturating_sub(freeable);
        if best_used + (self.reserved_total - victim_reserve) + need > cap {
            return Ok(false);
        }
        loop {
            if self.pool_used() + self.reserved_total + need <= cap {
                return Ok(true);
            }
            let Some(victim) = self.sla_victim(max_rank) else { return Ok(false) };
            self.evict_live(victim)?;
        }
    }

    /// Push a freshly admitted or resumed session onto the least-loaded
    /// shard (lowest index on ties — deterministic, and identical across
    /// runtimes: both count exactly the live sessions per shard),
    /// protected from eviction for the rest of this tick. Reservations
    /// are only tracked for a bounded pool — nothing ever reads them
    /// otherwise. The session's pool allocations are tagged with its
    /// shard's arena so its blocks stay local to its decode worker.
    fn place(&mut self, mut live: Live, resumed: bool, bounded: bool) -> Result<()> {
        live.last_stepped = self.tick_no;
        live.reserve_blocks =
            if bounded { self.engine.remaining_reserve(&live.session) } else { 0 };
        self.reserved_total += live.reserve_blocks;
        match &mut self.dispatch {
            Dispatch::Tick { shards } => {
                let Some(si) = shards
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, s)| s.running.len())
                    .map(|(i, _)| i)
                else {
                    bail!(ServeError::Inconsistent {
                        what: "no decode shards to place a session on"
                    });
                };
                live.home = si;
                live.session.set_arena(si);
                if !resumed {
                    shards[si].stats.admitted += 1;
                }
                shards[si].running.push(live);
            }
            Dispatch::Persistent { rt, mirror, wstats, counts, ledger } => {
                // placement retries if the chosen worker turns out to be
                // dead at the handoff: its other sessions recover at the
                // next death-processing point, but THIS session just
                // bounces to the next-least-loaded live shard
                loop {
                    counts.fill(0);
                    for r in mirror.iter() {
                        counts[r.shard] += 1;
                    }
                    let Some(si) =
                        (0..counts.len()).filter(|&i| rt.alive(i)).min_by_key(|&i| counts[i])
                    else {
                        bail!(ServeError::AllWorkersDead);
                    };
                    live.home = si;
                    live.session.set_arena(si);
                    let remote = Remote {
                        id: live.id,
                        shard: si,
                        last_stepped: live.last_stepped,
                        reserve: live.reserve_blocks,
                        freeable: self.engine.freeable_blocks(&live.session),
                        priority: live.priority,
                    };
                    let entry = LedgerEntry {
                        own_prompt: live.session.own_prompt().to_vec(),
                        fork_ctx: live.session.fork_ctx(),
                        max_new: live.session.max_new(),
                        queue_secs: live.queue_secs,
                        generated: live.session.output().to_vec(),
                        priority: live.priority,
                        deadline: live.deadline,
                        pause_every: live.pause_every,
                        topk: live.session.topk(),
                    };
                    match rt.admit(si, live) {
                        Ok(()) => {
                            if !resumed {
                                wstats[si].admitted += 1;
                            }
                            ledger.insert(remote.id, entry);
                            mirror.push(remote);
                            break;
                        }
                        Err(bounced) => live = bounced.0,
                    }
                }
            }
        }
        Ok(())
    }

    /// One scheduler tick at simulation time `now`:
    /// 1. resume preempted sessions (lowest id first), then admit arrived
    ///    requests into free decode slots (prefill them, or fork them off
    ///    the shared prefix), balancing across the least-loaded shards —
    ///    admission is against POOL CAPACITY when the engine runs a
    ///    bounded paged pool: a candidate enters only if its worst-case
    ///    not-yet-materialized reservation fits next to the pool's used
    ///    blocks plus the remaining reservations of every live session,
    ///    evicting LRU victims when it does not, so a decode step can
    ///    never hit an exhausted pool;
    /// 2. step every live session one decode token — persistent workers
    ///    (with stealing) or per-tick scoped threads, per the runtime;
    /// 3. retire finished sessions as `RequestResult`s (session-id order
    ///    within the tick, so the result order is deterministic across
    ///    runtimes and stealing schedules), then refresh every live
    ///    session's remaining reservation (materialized blocks and
    ///    finished-early slack return to the admission headroom; the
    ///    persistent runtime gets these refreshed values directly from
    ///    the step reports).
    pub fn tick(&mut self, now: f64) -> Result<Vec<RequestResult>> {
        self.tick_no += 1;
        let pool_cap = self.engine.pool_status().and_then(|p| p.capacity_blocks);

        // chaos: SwapCorrupt fires scheduler-side (swap images live on
        // preempted sessions, not workers) and only on the persistent
        // runtime — the tick loop stays the chaos-blind oracle. The
        // lowest-id image rots; its swap-in then fails checksum and the
        // resume falls back to re-prefill, which must serve identical
        // tokens.
        if matches!(self.dispatch, Dispatch::Persistent { .. }) {
            if let Some(plan) = &self.cfg.chaos {
                let corrupt = plan
                    .faults()
                    .iter()
                    .any(|f| f.tick == self.tick_no && f.kind == FaultKind::SwapCorrupt);
                if corrupt {
                    if let Some(img) = self
                        .preempted
                        .iter_mut()
                        .filter(|l| l.swap.is_some())
                        .min_by_key(|l| l.id)
                        .and_then(|l| l.swap.as_mut())
                    {
                        img.corrupt_for_chaos();
                    }
                }
            }
        }

        // 0. deadline shedding: queued requests whose budget expired are
        // rejected with a typed error instead of being served uselessly
        // late (or clogging the queue forever)
        for req in self.queue.shed_expired(now) {
            self.stats.overload.shed_deadline += 1;
            let reason = format!(
                "deadline {:.3}s expired after {:.3}s queued",
                req.deadline.unwrap_or(0.0),
                (now - req.arrival).max(0.0)
            );
            self.sheds.push((req.id, ServeError::Shed { id: req.id, reason }));
        }

        // 1a. resume preempted sessions — most urgent class first,
        // lowest id within a class. A resume that cannot fit backs off
        // exponentially (`retry_at`, pure tick arithmetic) instead of
        // holding the door shut: while it waits, STRICTLY higher classes
        // may still be admitted in 1b, so a stuck low-priority resume
        // cannot head-of-line-block interactive traffic. With uniform
        // priorities this degenerates to the old strict
        // resumes-before-arrivals rule.
        let mut blocked_rank: Option<usize> = None;
        while self.in_flight() < self.cfg.max_in_flight {
            let Some(idx) = self
                .preempted
                .iter()
                .enumerate()
                .filter(|(_, l)| l.retry_at <= self.tick_no)
                .min_by_key(|(_, l)| (std::cmp::Reverse(l.priority), l.id))
                .map(|(i, _)| i)
            else {
                break; // nothing resumable: empty, or all backing off
            };
            if self.preempted[idx].retry_at > 0 {
                self.stats.overload.resume_retries += 1;
            }
            let need = self.engine.resume_reserve(&self.preempted[idx].session);
            let rank = self.preempted[idx].priority.rank();
            if let Some(cap) = pool_cap {
                if !self.fit_or_evict(need, cap, rank)? {
                    self.stats.eviction.resume_deferrals += 1;
                    let l = &mut self.preempted[idx];
                    l.retry_at = self.tick_no + l.backoff;
                    l.backoff = (l.backoff * 2).min(32);
                    blocked_rank = Some(l.priority.rank());
                    break;
                }
                // the fit may have parked a more urgent victim: it
                // outranks the current candidate, so re-select before
                // committing
                let key =
                    (std::cmp::Reverse(self.preempted[idx].priority), self.preempted[idx].id);
                let Some(best) = self
                    .preempted
                    .iter()
                    .filter(|l| l.retry_at <= self.tick_no)
                    .map(|l| (std::cmp::Reverse(l.priority), l.id))
                    .min()
                else {
                    bail!(ServeError::Inconsistent {
                        what: "preempted queue emptied during resume fit"
                    });
                };
                if best != key {
                    continue;
                }
            }
            let mut live = self.preempted.swap_remove(idx);
            live.retry_at = 0;
            live.backoff = 1;
            // swap-in vs recompute: both costs are block counts at fixed
            // rates (simulation-clock arithmetic), so the choice — and
            // with it the schedule — is identical across runtimes ×
            // workers × steal plans. Ties go to swap-in (it is never
            // slower). A failed restore (e.g. a chaos-corrupted image)
            // falls through to the re-prefill path transparently.
            let mut swapped_in = false;
            if let Some(image) = live.swap.take() {
                self.swap_used -= image.n_blocks();
                let swap_cost = image.n_blocks() as u64 * SWAP_IN_COST_PER_BLOCK;
                let re_cost =
                    self.engine.resume_reserve(&live.session) as u64 * REPREFILL_COST_PER_BLOCK;
                if swap_cost <= re_cost {
                    let t0 = Instant::now();
                    match self.engine.swap_in_session(
                        &mut live.session,
                        self.prefix.as_ref(),
                        &image,
                    ) {
                        Ok(()) => {
                            swapped_in = true;
                            self.stats.swap.swap_ins += 1;
                            self.stats.swap.swapin_secs += t0.elapsed().as_secs_f64();
                        }
                        Err(_) => self.stats.swap.fallbacks += 1,
                    }
                }
            }
            if !swapped_in {
                let t0 = Instant::now();
                self.engine.resume_session(&mut live.session, self.prefix.as_ref())?;
                let dt = t0.elapsed().as_secs_f64();
                self.stats.eviction.resumes += 1;
                self.stats.eviction.reprefill_secs += dt;
                if live.rehomed {
                    // this re-prefill is recovery work, not pool pressure
                    live.rehomed = false;
                    self.stats.fault.recovery_reprefill_secs += dt;
                }
            } else {
                live.rehomed = false;
            }
            self.place(live, true, pool_cap.is_some())?;
        }

        // 1b. admission — new requests join the in-flight batch
        // mid-stream, most urgent class first, each pinned to the
        // currently least-loaded shard. While a deferred resume backs
        // off, only strictly more urgent classes slip past it
        // (`blocked_rank`); a request whose reservation can NEVER fit is
        // shed with a typed error instead of aborting the scheduler.
        while self.in_flight() < self.cfg.max_in_flight {
            let (next_id, next_rank, next_tokens) = match self.queue.peek(now) {
                Some(r) => (r.id, r.priority.rank(), r.prompt.len() + r.max_new),
                None => break,
            };
            if blocked_rank.is_some_and(|r| next_rank <= r) {
                // the blocked resume outranks (or ties) every arrival
                // left — peek() already returned the most urgent one
                break;
            }
            if let Some(cap) = pool_cap {
                let ctx = self.shared_prefix_len();
                let need = self.engine.block_reserve(ctx, next_tokens);
                if self.prefix_blocks + need > cap {
                    let Some(req) = self.queue.admit(now, 1).pop() else {
                        bail!(ServeError::Inconsistent {
                            what: "peeked request vanished from the queue"
                        });
                    };
                    debug_assert_eq!(req.id, next_id);
                    self.stats.overload.shed_infeasible += 1;
                    let reason = format!(
                        "needs {need} pool blocks beyond the {}-block shared prefix, \
                         capacity {cap}",
                        self.prefix_blocks
                    );
                    self.sheds.push((req.id, ServeError::Shed { id: req.id, reason }));
                    continue;
                }
                if !self.fit_or_evict(need, cap, next_rank)? {
                    // wait for retirements/evictions to hand blocks back
                    self.stats.pool_deferrals += 1;
                    break;
                }
            }
            let Some(req) = self.queue.admit(now, 1).pop() else {
                bail!(ServeError::Inconsistent { what: "peeked request vanished from the queue" });
            };
            // pressure-tiered degradation: at/above the occupancy
            // threshold, non-interactive private admissions decode with
            // a downshifted top-k. Forks inherit their prefix parent's
            // sparsity and are never degraded; the trigger is pure block
            // arithmetic, so degraded runs stay deterministic.
            let topk = match (self.cfg.degrade, pool_cap) {
                (Some(d), Some(cap))
                    if req.priority != Priority::Interactive
                        && self.prefix.is_none()
                        && (self.pool_used() + self.reserved_total) as f64
                            >= d.occupancy * cap as f64 =>
                {
                    self.stats.overload.degraded_sessions += 1;
                    d.topk.clamp(1, self.engine.cfg().topk)
                }
                _ => self.engine.cfg().topk,
            };
            let session = match &self.prefix {
                Some(parent) => self.engine.fork_session(parent, &req.prompt, req.max_new)?,
                None => self.engine.start_with_topk(&req.prompt, req.max_new, topk)?,
            };
            self.stats.admitted += 1;
            self.place(
                Live {
                    id: req.id,
                    queue_secs: (now - req.arrival).max(0.0),
                    reserve_blocks: 0,
                    last_stepped: self.tick_no,
                    home: 0,
                    poisoned: false,
                    rehomed: false,
                    priority: req.priority,
                    deadline: req.deadline,
                    pause_every: req.pause_every,
                    paused: false,
                    retry_at: 0,
                    backoff: 1,
                    swap: None,
                    session,
                },
                false,
                pool_cap.is_some(),
            )?;
        }
        self.stats.peak_in_flight = self.stats.peak_in_flight.max(self.in_flight());
        match &mut self.dispatch {
            Dispatch::Tick { shards } => {
                for shard in shards.iter_mut() {
                    shard.stats.peak_in_flight =
                        shard.stats.peak_in_flight.max(shard.running.len());
                }
            }
            Dispatch::Persistent { mirror, wstats, counts, .. } => {
                counts.fill(0);
                for r in mirror.iter() {
                    counts[r.shard] += 1;
                }
                for (w, &c) in counts.iter().enumerate() {
                    wstats[w].peak_in_flight = wstats[w].peak_in_flight.max(c);
                }
            }
        }

        // 2. one decode step per live session — the continuous batch
        if self.in_flight() > 0 {
            self.stats.decode_rounds += 1;
        }
        let tick = self.tick_no;
        if let Dispatch::Tick { shards } = &mut self.dispatch {
            let steps_before: usize = shards.iter().map(|s| s.stats.decode_steps).sum();
            let engine = self.engine.as_ref();
            // Scoped threads are re-spawned per tick — the legacy
            // baseline the persistent runtime replaces (kept for
            // parity tests and as the bench reference). Outputs are
            // identical either way.
            if self.cfg.decode_workers > 1 {
                std::thread::scope(|scope| {
                    for shard in shards.iter_mut() {
                        if !shard.running.is_empty() {
                            scope.spawn(move || shard.step_all(engine, tick));
                        }
                    }
                });
            } else {
                for shard in shards.iter_mut() {
                    shard.step_all(engine, tick);
                }
            }
            let steps_after: usize = shards.iter().map(|s| s.stats.decode_steps).sum();
            self.stats.decode_steps_total += steps_after - steps_before;
        } else {
            // one step command per worker, one report back — the
            // per-tick barrier. Workers steal between shards while
            // draining; every stepped session returns to its home
            // shard, so the merge below is order-independent.
            {
                let Dispatch::Persistent { rt, .. } = &mut self.dispatch else { unreachable!() };
                rt.step_all(tick);
            }
            // deaths recover BEFORE the mirror rebuild: the pre-rebuild
            // mirror (last tick's survivors + this tick's placements) is
            // the complete ownership map of every dead shard
            self.recover_deaths()?;
            let Dispatch::Persistent { rt, mirror, wstats, ledger, .. } = &mut self.dispatch
            else {
                unreachable!()
            };
            mirror.clear();
            let mut reserved = 0usize;
            for w in 0..rt.workers() {
                let Some(rep) = rt.report_mut(w) else {
                    continue; // dead worker: stats frozen at death values
                };
                let ws = &mut wstats[w];
                if rep.owned > 0 {
                    ws.decode_rounds += 1;
                }
                if rep.owned == 0 && rep.steals == 0 {
                    ws.idle_ticks += 1;
                } else {
                    ws.busy_secs += rep.busy_secs;
                }
                ws.decode_steps += rep.steps;
                ws.steals += rep.steals;
                ws.stolen_steps += rep.stolen_steps;
                self.stats.decode_steps_total += rep.steps;
                for m in &rep.metas {
                    reserved += m.reserve;
                    mirror.push(Remote {
                        id: m.id,
                        shard: w,
                        // the worker reports the tick the session REALLY
                        // last stepped — a paused session keeps its stale
                        // value, so the SLA victim key sees it as idle
                        last_stepped: m.last_stepped,
                        reserve: m.reserve,
                        freeable: m.freeable,
                        priority: m.priority,
                    });
                    // advance the recovery transcript: every live
                    // session appends exactly one token per step
                    if let Some(entry) = ledger.get_mut(&m.id) {
                        if m.out_len == entry.generated.len() + 1 {
                            entry.generated.push(m.last_token);
                        } else {
                            debug_assert_eq!(
                                m.out_len,
                                entry.generated.len(),
                                "recovery ledger transcript drift"
                            );
                        }
                    }
                }
                for live in rep.finished.iter_mut() {
                    // the mirror rebuild re-derives reserved_total
                    // without retirees, so their reservations are
                    // already released
                    live.reserve_blocks = 0;
                    ledger.remove(&live.id);
                }
                self.finished_scratch.append(&mut rep.finished);
                // a session whose own decode step panicked on a healthy
                // worker: quarantine + re-prefill it like a dead shard's
                // survivor (its transcript is intact; its pending token
                // may not be)
                for mut live in rep.orphans.drain(..) {
                    ledger.remove(&live.id);
                    live.reserve_blocks = 0;
                    if !live.poisoned && live.session.finished() {
                        self.finished_scratch.push(live);
                        continue;
                    }
                    self.engine.quarantine_session(&mut live.session, !live.poisoned);
                    live.poisoned = false;
                    live.rehomed = true;
                    self.stats.fault.rehomed_sessions += 1;
                    self.preempted.push(live);
                }
            }
            self.reserved_total = reserved;
        }

        // pool high-water mark, sampled after the decode growth and
        // before retirement frees blocks (deterministic: every session
        // appends a fixed token count per tick regardless of the worker
        // count or stealing schedule; finished sessions still hold their
        // blocks here in both runtimes)
        if let Some(p) = self.engine.pool_status() {
            self.stats.peak_pool_blocks = self.stats.peak_pool_blocks.max(p.used_blocks);
        }

        // 3. retirement — a retiring session hands its reservation (and,
        // on drop, its pool blocks) back the same tick it finishes, so
        // budget slack never lingers as phantom demand. Results are
        // emitted in session-id order within the tick: deterministic
        // across runtimes, worker counts and stealing schedules.
        if let Dispatch::Tick { shards } = &mut self.dispatch {
            for shard in shards.iter_mut() {
                let mut i = 0;
                while i < shard.running.len() {
                    if shard.running[i].session.finished() {
                        self.finished_scratch.push(shard.running.swap_remove(i));
                    } else {
                        i += 1;
                    }
                }
            }
        }
        self.finished_scratch.sort_by_key(|l| l.id);
        let mut finished = Vec::with_capacity(self.finished_scratch.len());
        for live in self.finished_scratch.drain(..) {
            self.reserved_total -= live.reserve_blocks;
            self.stats.completed += 1;
            let result = RequestResult {
                id: live.id,
                output: live.session.output().to_vec(),
                queue_secs: live.queue_secs,
                prefill_secs: live.session.stats.prefill_secs,
                decode_secs: live.session.stats.decode_secs,
                decode_steps: live.session.stats.decode_steps,
            };
            // SLA accounting only — wall-clock latencies never feed back
            // into a scheduling decision, so determinism is untouched
            if let Some(budget) = live.deadline {
                if result.queue_secs + result.prefill_secs + result.decode_secs > budget {
                    self.stats.overload.sla_violations += 1;
                }
            }
            finished.push(result);
        }

        // refresh every survivor's remaining reservation: blocks its
        // decode step just materialized move from "reserved" to "used",
        // so the next tick's admission sees them exactly once (only a
        // bounded pool reads reservations; the persistent runtime's
        // mirror was already rebuilt from post-step reports above)
        if pool_cap.is_some() {
            if let Dispatch::Tick { shards } = &mut self.dispatch {
                for shard in shards.iter_mut() {
                    for live in shard.running.iter_mut() {
                        let fresh = self.engine.remaining_reserve(&live.session);
                        self.reserved_total -= live.reserve_blocks;
                        self.reserved_total += fresh;
                        live.reserve_blocks = fresh;
                    }
                }
            }
        }
        debug_assert_eq!(self.reserved_total, self.recount_reserved(), "reservation drift");
        Ok(finished)
    }

    /// Drive a whole arrival stream to completion. `requests` must be
    /// sorted by arrival; the clock advances by `tick_secs` per tick and
    /// jumps forward to the next arrival when the system goes idle.
    /// Every request is accounted exactly once: as a returned result or
    /// as an overload-control rejection in [`Self::sheds`].
    pub fn run_stream(
        &mut self,
        requests: Vec<Request>,
        tick_secs: f64,
    ) -> Result<Vec<RequestResult>> {
        let total = requests.len();
        let shed0 = self.sheds.len();
        let mut results = Vec::with_capacity(total);
        let mut pending = requests.into_iter().peekable();
        let mut now = 0.0f64;
        while results.len() + (self.sheds.len() - shed0) < total {
            while pending.peek().is_some_and(|r| r.arrival <= now) {
                let Some(req) = pending.next() else {
                    bail!(ServeError::Inconsistent { what: "peeked arrival vanished" });
                };
                self.submit(req);
            }
            results.extend(self.tick(now)?);
            if self.idle() {
                match pending.peek() {
                    Some(r) => now = now.max(r.arrival),
                    None => break,
                }
            } else {
                now += tick_secs;
            }
        }
        Ok(results)
    }

    /// Graceful drain-and-shutdown: run ticks until every in-flight,
    /// preempted and queued request has completed, and return their
    /// results. The clock starts at `now` and advances by `tick_secs`
    /// per tick (must be > 0 if queued arrivals lie in the future).
    /// Dropping the scheduler afterwards joins every decode worker —
    /// all runtime blocking points are bounded, so shutdown cannot hang
    /// on a dead or stalled worker.
    pub fn drain(&mut self, mut now: f64, tick_secs: f64) -> Result<Vec<RequestResult>> {
        let mut results = Vec::new();
        while !self.idle() {
            results.extend(self.tick(now)?);
            now += tick_secs;
        }
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::ServeCfg;
    use crate::serve::model::ToyModel;
    use crate::sparse::BackendKind;

    fn engine() -> ServeEngine<ToyModel> {
        engine_with(BackendKind::CachedSparse, 0)
    }

    fn engine_with(backend: BackendKind, pool_blocks: usize) -> ServeEngine<ToyModel> {
        ServeEngine::new(
            ToyModel::new(48, 2, 8, 5),
            ServeCfg {
                block_size: 16,
                topk: 2,
                max_seq: 512,
                backend,
                workers: 1,
                pool_blocks,
                ..Default::default()
            },
        )
    }

    fn req(id: u64, arrival: f64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(
            id,
            (0..prompt_len as i32).map(|i| (i * 5 + id as i32) % 48).collect(),
            max_new,
            arrival,
        )
    }

    fn sched_cfg(max_in_flight: usize, decode_workers: usize) -> SchedulerCfg {
        SchedulerCfg { max_in_flight, decode_workers, ..SchedulerCfg::default() }
    }

    #[test]
    fn completes_all_requests_with_correct_outputs() {
        let mut sched = ContinuousScheduler::new(engine(), sched_cfg(3, 1));
        let requests: Vec<Request> =
            (0..7).map(|i| req(i, i as f64 * 0.1, 20 + i as usize, 4 + (i as usize % 3))).collect();
        // reference: every request served alone, outside the scheduler
        let solo = engine();
        let expected: Vec<Vec<i32>> = requests
            .iter()
            .map(|r| solo.generate(&r.prompt, r.max_new).unwrap().0)
            .collect();

        let mut results = sched.run_stream(requests, 0.05).unwrap();
        assert_eq!(results.len(), 7);
        results.sort_by_key(|r| r.id);
        for (r, want) in results.iter().zip(&expected) {
            assert_eq!(&r.output, want, "req {} output changed under batching", r.id);
            assert_eq!(r.decode_steps, r.output.len().saturating_sub(1));
            assert!(r.queue_secs >= 0.0);
        }
        assert_eq!(sched.stats.completed, 7);
        assert!(sched.stats.peak_in_flight <= 3);
        assert!(sched.idle());
    }

    #[test]
    fn capacity_limits_in_flight_and_late_arrivals_wait() {
        let mut sched = ContinuousScheduler::new(engine(), sched_cfg(2, 1));
        for i in 0..4 {
            sched.submit(req(i, 0.0, 16, 8));
        }
        let done = sched.tick(0.0).unwrap();
        assert!(done.is_empty());
        assert_eq!(sched.in_flight(), 2);
        assert_eq!(sched.pending(), 2);
        // not-yet-arrived requests are never admitted
        sched.submit(req(9, 100.0, 16, 2));
        sched.tick(0.1).unwrap();
        assert_eq!(sched.pending(), 3);
    }

    #[test]
    fn new_request_joins_inflight_decode_batch() {
        // continuous batching: request 1 is admitted while request 0 is
        // mid-decode, and both make progress in the same ticks
        let mut sched = ContinuousScheduler::new(engine(), sched_cfg(4, 1));
        sched.submit(req(0, 0.0, 16, 10));
        sched.tick(0.0).unwrap();
        assert_eq!(sched.in_flight(), 1);
        sched.submit(req(1, 0.0, 16, 2));
        let mut done = Vec::new();
        let mut ticks = 0;
        while !sched.idle() {
            done.extend(sched.tick(0.1 * ticks as f64).unwrap());
            ticks += 1;
        }
        assert_eq!(done.len(), 2);
        // the short request retired before the long one despite arriving later
        assert_eq!(done[0].id, 1);
        assert_eq!(done[1].id, 0);
        assert_eq!(sched.stats.peak_in_flight, 2);
    }

    #[test]
    fn queue_latency_reflects_admission_delay() {
        let mut sched = ContinuousScheduler::new(engine(), sched_cfg(1, 1));
        sched.submit(req(0, 0.0, 16, 3));
        sched.submit(req(1, 0.0, 16, 3));
        let mut all = Vec::new();
        let mut now = 0.0;
        while !sched.idle() {
            all.extend(sched.tick(now).unwrap());
            now += 1.0;
        }
        all.sort_by_key(|r| r.id);
        assert!(all[0].queue_secs < all[1].queue_secs, "second request queued longer");
    }

    #[test]
    fn sharded_outputs_match_single_worker() {
        // the tentpole invariant at the serving layer: the worker count
        // is invisible in every request's tokens and aggregate counts
        let make_stream = || -> Vec<Request> {
            (0..9).map(|i| req(i, i as f64 * 0.07, 18 + i as usize, 3 + (i as usize % 4))).collect()
        };
        let mut solo = ContinuousScheduler::new(engine(), sched_cfg(4, 1));
        let mut baseline = solo.run_stream(make_stream(), 0.05).unwrap();
        baseline.sort_by_key(|r| r.id);
        for workers in [2usize, 3] {
            let mut sched = ContinuousScheduler::new(engine(), sched_cfg(4, workers));
            let mut results = sched.run_stream(make_stream(), 0.05).unwrap();
            results.sort_by_key(|r| r.id);
            assert_eq!(results.len(), baseline.len(), "workers={workers}");
            for (r, b) in results.iter().zip(&baseline) {
                assert_eq!(r.id, b.id);
                assert_eq!(r.output, b.output, "req {} workers={workers}", r.id);
            }
            assert_eq!(sched.stats.completed, solo.stats.completed);
            assert_eq!(sched.stats.decode_steps_total, solo.stats.decode_steps_total);
        }
    }

    #[test]
    fn tick_loop_and_persistent_runtimes_serve_identical_tokens() {
        // the tentpole contract, stated directly: both runtimes, all
        // steal settings, same tokens and same scheduler decisions
        let make_stream = || -> Vec<Request> {
            (0..8).map(|i| req(i, i as f64 * 0.06, 16 + i as usize, 3 + (i as usize % 4))).collect()
        };
        let run = |runtime: RuntimeKind, workers: usize, steal: bool| {
            let cfg = SchedulerCfg {
                max_in_flight: 4,
                decode_workers: workers,
                runtime,
                steal,
                ..SchedulerCfg::default()
            };
            let mut sched = ContinuousScheduler::new(engine(), cfg);
            let mut out = sched.run_stream(make_stream(), 0.05).unwrap();
            out.sort_by_key(|r| r.id);
            let tokens: Vec<Vec<i32>> = out.iter().map(|r| r.output.clone()).collect();
            (tokens, sched.stats.decode_steps_total, sched.stats.admitted)
        };
        let base = run(RuntimeKind::TickLoop, 1, false);
        for (workers, steal) in [(1, false), (1, true), (2, false), (2, true), (3, true)] {
            let got = run(RuntimeKind::Persistent, workers, steal);
            assert_eq!(got, base, "persistent workers={workers} steal={steal}");
            let got_tick = run(RuntimeKind::TickLoop, workers, steal);
            assert_eq!(got_tick, base, "tick-loop workers={workers}");
        }
    }

    #[test]
    fn admission_balances_across_shards() {
        // steal disabled: this test pins per-shard step counts, which
        // stealing deliberately blurs (tokens stay identical either way)
        let cfg = SchedulerCfg {
            max_in_flight: 6,
            decode_workers: 3,
            steal: false,
            ..SchedulerCfg::default()
        };
        let mut sched = ContinuousScheduler::new(engine(), cfg);
        for i in 0..6 {
            sched.submit(req(i, 0.0, 16, 12));
        }
        sched.tick(0.0).unwrap();
        assert_eq!(sched.in_flight(), 6);
        let stats = sched.worker_stats();
        assert_eq!(stats.len(), 3);
        for (i, w) in stats.iter().enumerate() {
            assert_eq!(w.admitted, 2, "shard {i} admission imbalance");
            assert_eq!(w.peak_in_flight, 2, "shard {i}");
            assert_eq!(w.decode_rounds, 1, "shard {i}");
            assert!(w.decode_steps > 0, "shard {i}");
        }
    }

    #[test]
    fn persistent_worker_metrics_cover_steals_and_queues() {
        // skewed lengths on 2 shards with stealing on: every steal and
        // stolen token is accounted, queue depth high-water mark is sane
        let cfg = SchedulerCfg {
            max_in_flight: 4,
            decode_workers: 2,
            runtime: RuntimeKind::Persistent,
            steal: true,
            ..SchedulerCfg::default()
        };
        let mut sched = ContinuousScheduler::new(engine(), cfg);
        // shard 0 gets a long request, shard 1 a burst of short ones
        sched.submit(req(0, 0.0, 24, 24));
        sched.submit(req(1, 0.0, 16, 2));
        sched.submit(req(2, 0.0, 16, 2));
        sched.submit(req(3, 0.0, 16, 2));
        let mut now = 0.0;
        while !sched.idle() {
            sched.tick(now).unwrap();
            now += 0.01;
        }
        let workers = sched.worker_stats();
        assert_eq!(workers.len(), 2);
        let steps: usize = workers.iter().map(|w| w.decode_steps).sum();
        assert_eq!(steps, sched.stats.decode_steps_total);
        let stolen: usize = workers.iter().map(|w| w.stolen_steps).sum();
        let steals: usize = workers.iter().map(|w| w.steals).sum();
        assert!(stolen <= steps);
        assert!(stolen <= steals, "a stolen step implies a steal");
        for w in &workers {
            assert!(w.queue_depth_hwm >= 1, "step commands must register in the hwm");
        }
    }

    #[test]
    fn shared_prefix_stream_matches_private_full_prompts() {
        // forked admission is invisible in the tokens: a paged scheduler
        // forking every request off one shared prefix serves exactly what
        // private sessions over prefix ++ continuation would
        let prefix: Vec<i32> = (0..40).map(|i| (i * 3) % 48).collect();
        let conts: Vec<Vec<i32>> =
            (0..5).map(|i| (0..10).map(|j| (j * 7 + i) % 48).collect()).collect();
        let mut sched =
            ContinuousScheduler::new(engine_with(BackendKind::Paged, 0), sched_cfg(3, 1));
        sched.set_shared_prefix(&prefix).unwrap();
        let stream: Vec<Request> = conts
            .iter()
            .enumerate()
            .map(|(i, c)| Request::new(i as u64, c.clone(), 4 + i % 3, i as f64 * 0.05))
            .collect();
        let mut results = sched.run_stream(stream, 0.02).unwrap();
        results.sort_by_key(|r| r.id);
        let solo = engine();
        for (r, c) in results.iter().zip(&conts) {
            let full: Vec<i32> = prefix.iter().chain(c).copied().collect();
            let want = solo.generate(&full, r.output.len()).unwrap().0;
            assert_eq!(r.output, want, "req {}", r.id);
        }
        assert!(sched.stats.peak_pool_blocks > 0);
        // the prefix is resident once, not once per request
        let naive = conts.len() * ((prefix.len() + 15) / 16);
        assert!(
            sched.stats.peak_pool_blocks < naive,
            "no sharing: peak {} vs naive {naive}",
            sched.stats.peak_pool_blocks
        );
    }

    #[test]
    fn pool_capacity_gates_admission_without_changing_tokens() {
        let stream = || -> Vec<Request> { (0..6).map(|i| req(i, 0.0, 20, 6)).collect() };
        // unbounded pool: all six run concurrently
        let mut wide =
            ContinuousScheduler::new(engine_with(BackendKind::Paged, 0), sched_cfg(6, 1));
        let mut base = wide.run_stream(stream(), 0.01).unwrap();
        base.sort_by_key(|r| r.id);
        assert_eq!(wide.stats.peak_in_flight, 6);
        // each session reserves ceil((20 + 6)/16) = 2 blocks; capacity
        // 5 admits at most two at a time — same tokens, later admissions
        let mut tight =
            ContinuousScheduler::new(engine_with(BackendKind::Paged, 5), sched_cfg(6, 1));
        let mut got = tight.run_stream(stream(), 0.01).unwrap();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), base.len());
        for (g, b) in got.iter().zip(&base) {
            assert_eq!(g.output, b.output, "req {} changed under pool pressure", g.id);
        }
        assert_eq!(tight.stats.peak_in_flight, 2, "capacity should cap concurrency");
        assert!(tight.stats.pool_deferrals > 0);
        assert!(tight.stats.peak_pool_blocks <= 5);
    }

    #[test]
    fn oversubscribed_pool_evicts_resumes_and_serves_identically() {
        // pool far below the concurrent working set: each request needs
        // 2 blocks, capacity 5 holds ~2 sessions, but 6 run "at once" —
        // the scheduler must preempt LRU sessions and re-prefill them,
        // serving exactly the uncapped run's tokens — on both runtimes
        let stream = || -> Vec<Request> { (0..6).map(|i| req(i, 0.0, 20, 8)).collect() };
        let mut wide =
            ContinuousScheduler::new(engine_with(BackendKind::Paged, 0), sched_cfg(6, 1));
        let mut base = wide.run_stream(stream(), 0.01).unwrap();
        base.sort_by_key(|r| r.id);
        assert_eq!(wide.stats.eviction.evictions, 0, "unbounded pool never evicts");
        for (runtime, workers) in [
            (RuntimeKind::Persistent, 1usize),
            (RuntimeKind::Persistent, 3),
            (RuntimeKind::TickLoop, 1),
            (RuntimeKind::TickLoop, 3),
        ] {
            let cfg = SchedulerCfg {
                max_in_flight: 6,
                decode_workers: workers,
                runtime,
                ..SchedulerCfg::default()
            };
            let mut tight =
                ContinuousScheduler::new(engine_with(BackendKind::Paged, 5), cfg);
            let mut got = tight.run_stream(stream(), 0.01).unwrap();
            got.sort_by_key(|r| r.id);
            let tag = format!("{} workers={workers}", runtime.label());
            assert_eq!(got.len(), base.len(), "{tag} lost requests");
            for (g, b) in got.iter().zip(&base) {
                assert_eq!(g.id, b.id);
                assert_eq!(g.output, b.output, "req {} changed under eviction ({tag})", g.id);
            }
            let ev = &tight.stats.eviction;
            assert!(ev.evictions > 0, "{tag}: oversubscription must evict");
            assert!(ev.blocks_reclaimed > 0, "{tag}");
            assert_eq!(
                ev.resumes, ev.evictions,
                "{tag}: every preempted session resumed exactly once per eviction"
            );
            assert!(tight.stats.peak_pool_blocks <= 5, "{tag}");
            assert!(tight.idle(), "{tag}: no session left behind");
        }
    }

    #[test]
    fn swap_tier_serves_identical_tokens_across_runtimes_and_workers() {
        // the tentpole contract with the host tier on: an oversubscribed
        // pool swaps victims out instead of dropping them, restores them
        // at resume, and the served tokens stay bitwise identical to the
        // unbounded pool — across both runtimes and worker counts
        let stream = || -> Vec<Request> { (0..6).map(|i| req(i, 0.0, 20, 8)).collect() };
        let mut wide =
            ContinuousScheduler::new(engine_with(BackendKind::Paged, 0), sched_cfg(6, 1));
        let mut base = wide.run_stream(stream(), 0.01).unwrap();
        base.sort_by_key(|r| r.id);
        // swap-disabled bounded reference: the swap tier must not change
        // WHICH sessions get evicted, only how their state survives
        let mut dropper = ContinuousScheduler::new(
            engine_with(BackendKind::Paged, 5),
            SchedulerCfg { max_in_flight: 6, ..SchedulerCfg::default() },
        );
        dropper.run_stream(stream(), 0.01).unwrap();
        let drop_evictions = dropper.stats.eviction.evictions;
        for (runtime, workers) in [
            (RuntimeKind::Persistent, 1usize),
            (RuntimeKind::Persistent, 3),
            (RuntimeKind::TickLoop, 1),
            (RuntimeKind::TickLoop, 3),
        ] {
            let cfg = SchedulerCfg {
                max_in_flight: 6,
                decode_workers: workers,
                runtime,
                swap_blocks: 64,
                ..SchedulerCfg::default()
            };
            let mut tiered = ContinuousScheduler::new(engine_with(BackendKind::Paged, 5), cfg);
            let mut got = tiered.run_stream(stream(), 0.01).unwrap();
            got.sort_by_key(|r| r.id);
            let tag = format!("{} workers={workers} swap", runtime.label());
            assert_eq!(got.len(), base.len(), "{tag} lost requests");
            for (g, b) in got.iter().zip(&base) {
                assert_eq!(g.id, b.id);
                assert_eq!(g.output, b.output, "req {} changed under swap ({tag})", g.id);
            }
            let sw = &tiered.stats.swap;
            assert!(sw.swap_outs > 0, "{tag}: oversubscription must swap out");
            assert!(sw.swap_ins > 0, "{tag}: swapped sessions must restore");
            assert!(sw.bytes > 0, "{tag}");
            assert_eq!(sw.fallbacks, 0, "{tag}: ample tier never demotes");
            assert_eq!(
                tiered.stats.eviction.evictions, drop_evictions,
                "{tag}: the tier must not change the eviction schedule"
            );
            assert_eq!(
                tiered.stats.eviction.resumes + sw.swap_ins,
                tiered.stats.eviction.evictions,
                "{tag}: every preemption resumed exactly once, one way or the other"
            );
            assert!(tiered.stats.peak_pool_blocks <= 5, "{tag}");
            assert!(tiered.idle(), "{tag}: no session left behind");
        }
    }

    #[test]
    fn exhausted_swap_tier_demotes_to_drop_and_still_serves() {
        // swap_blocks = 1 cannot hold any 2-block victim: every eviction
        // wants to swap, none fit, all demote to the re-prefill path —
        // tokens must still match the unbounded pool exactly
        let stream = || -> Vec<Request> { (0..6).map(|i| req(i, 0.0, 20, 8)).collect() };
        let mut wide =
            ContinuousScheduler::new(engine_with(BackendKind::Paged, 0), sched_cfg(6, 1));
        let mut base = wide.run_stream(stream(), 0.01).unwrap();
        base.sort_by_key(|r| r.id);
        let cfg = SchedulerCfg { max_in_flight: 6, swap_blocks: 1, ..SchedulerCfg::default() };
        let mut tiny = ContinuousScheduler::new(engine_with(BackendKind::Paged, 5), cfg);
        let mut got = tiny.run_stream(stream(), 0.01).unwrap();
        got.sort_by_key(|r| r.id);
        for (g, b) in got.iter().zip(&base) {
            assert_eq!(g.output, b.output, "req {} changed under tier exhaustion", g.id);
        }
        let sw = &tiny.stats.swap;
        assert_eq!(sw.swap_outs, 0, "no 2-block victim fits a 1-block tier");
        assert_eq!(sw.swap_ins, 0);
        assert!(sw.fallbacks > 0, "each wanted-but-demoted swap must be counted");
        assert_eq!(
            tiny.stats.eviction.resumes, tiny.stats.eviction.evictions,
            "every demoted preemption re-prefills"
        );
        assert!(tiny.idle());
    }

    #[test]
    fn swapped_forks_resume_off_the_resident_prefix() {
        // suffix-only eviction: a forked victim's private tail swaps out
        // while the refcounted shared prefix stays resident; the restore
        // re-attaches to the prefix without re-ingesting anything
        let prefix: Vec<i32> = (0..40).map(|i| (i * 3) % 48).collect();
        let conts: Vec<Vec<i32>> =
            (0..4).map(|i| (0..10).map(|j| (j * 7 + i) % 48).collect()).collect();
        let stream = |conts: &[Vec<i32>]| -> Vec<Request> {
            conts
                .iter()
                .enumerate()
                .map(|(i, c)| Request::new(i as u64, c.clone(), 6, 0.0))
                .collect()
        };
        let mut wide =
            ContinuousScheduler::new(engine_with(BackendKind::Paged, 0), sched_cfg(4, 1));
        wide.set_shared_prefix(&prefix).unwrap();
        let mut base = wide.run_stream(stream(&conts), 0.01).unwrap();
        base.sort_by_key(|r| r.id);
        let cfg = SchedulerCfg { max_in_flight: 4, swap_blocks: 64, ..SchedulerCfg::default() };
        let mut tight = ContinuousScheduler::new(engine_with(BackendKind::Paged, 6), cfg);
        tight.set_shared_prefix(&prefix).unwrap();
        let mut got = tight.run_stream(stream(&conts), 0.01).unwrap();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), base.len());
        for (g, b) in got.iter().zip(&base) {
            assert_eq!(g.output, b.output, "req {} changed under fork swap", g.id);
        }
        assert!(tight.stats.eviction.evictions > 0, "pool pressure must evict forks");
        assert!(tight.stats.swap.swap_outs > 0, "fork tails must swap, not drop");
        assert!(tight.stats.swap.swap_ins > 0, "fork tails must restore off the prefix");
        assert!(tight.stats.peak_pool_blocks >= 3, "the prefix never leaves the pool");
        assert!(tight.stats.peak_pool_blocks <= 6);
    }

    #[test]
    fn strict_swap_blocks_parsing_rejects_typos_with_context() {
        assert_eq!(parse_swap_blocks(None), Ok(None));
        assert_eq!(parse_swap_blocks(Some(" 64 ".into())), Ok(Some(64)));
        let err = parse_swap_blocks(Some("6a".into())).unwrap_err();
        assert!(err.contains("MOBA_SWAP_BLOCKS") && err.contains("6a"), "{err}");
    }

    #[test]
    fn admission_fills_headroom_freed_by_materialized_blocks() {
        // the double-count regression: each request's worst case is 2
        // blocks (prompt 4 + max_new 13), but after prefill its single
        // materialized block has 12 open slots absorbing all 12 future
        // appends — remaining reservation 0. Four such sessions fit a
        // 5-block pool TOGETHER (used 4 + reservations 0), where
        // lifetime-worst-case accounting (4 x 2 = 8 > 5) spuriously
        // deferred half of them against a half-full pool.
        let reqs: Vec<Request> = (0..4).map(|i| req(i, 0.0, 4, 13)).collect();
        let solo = engine_with(BackendKind::Paged, 0);
        let want: Vec<Vec<i32>> =
            reqs.iter().map(|r| solo.generate(&r.prompt, r.max_new).unwrap().0).collect();
        let mut sched =
            ContinuousScheduler::new(engine_with(BackendKind::Paged, 5), sched_cfg(4, 1));
        for r in reqs {
            sched.submit(r);
        }
        sched.tick(0.0).unwrap();
        assert_eq!(sched.in_flight(), 4, "all four must admit into the freed headroom");
        assert_eq!(sched.stats.pool_deferrals, 0);
        let mut got = Vec::new();
        let mut now = 0.0;
        while !sched.idle() {
            got.extend(sched.tick(now).unwrap());
            now += 0.1;
        }
        assert_eq!(sched.stats.eviction.evictions, 0, "everything fit; nothing to evict");
        got.sort_by_key(|r| r.id);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(&g.output, w, "req {}", g.id);
        }
    }

    #[test]
    fn eviction_preserves_shared_prefix_and_tokens() {
        // forked sessions get evicted under pool pressure; the shared
        // system prefix must stay resident (the parent holds it) and the
        // resumed forks must serve exactly the unbounded-pool tokens
        let prefix: Vec<i32> = (0..40).map(|i| (i * 3) % 48).collect();
        let conts: Vec<Vec<i32>> =
            (0..4).map(|i| (0..10).map(|j| (j * 7 + i) % 48).collect()).collect();
        let stream = |conts: &[Vec<i32>]| -> Vec<Request> {
            conts
                .iter()
                .enumerate()
                .map(|(i, c)| Request::new(i as u64, c.clone(), 6, 0.0))
                .collect()
        };
        let mut wide =
            ContinuousScheduler::new(engine_with(BackendKind::Paged, 0), sched_cfg(4, 1));
        wide.set_shared_prefix(&prefix).unwrap();
        let mut base = wide.run_stream(stream(&conts), 0.01).unwrap();
        base.sort_by_key(|r| r.id);
        // prefix = 3 blocks; each fork's tail needs ceil((8+16)/16) = 2:
        // capacity 6 holds the prefix plus ~1.5 forks -> heavy eviction
        let mut tight =
            ContinuousScheduler::new(engine_with(BackendKind::Paged, 6), sched_cfg(4, 1));
        tight.set_shared_prefix(&prefix).unwrap();
        let mut got = tight.run_stream(stream(&conts), 0.01).unwrap();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), base.len());
        for (g, b) in got.iter().zip(&base) {
            assert_eq!(g.output, b.output, "req {} changed under prefix eviction", g.id);
        }
        assert!(tight.stats.eviction.evictions > 0, "pool pressure must evict forks");
        // the prefix was never reclaimed: the pool always holds >= its 3
        // blocks while sessions churn around it
        assert!(tight.stats.peak_pool_blocks >= 3);
        assert!(tight.stats.peak_pool_blocks <= 6);
    }

    #[test]
    fn impossible_pool_request_is_shed_with_a_typed_error() {
        let mut sched =
            ContinuousScheduler::new(engine_with(BackendKind::Paged, 2), sched_cfg(2, 1));
        let reqs = vec![
            req(0, 0.0, 40, 8), // needs 3 blocks, capacity 2: can NEVER fit
            req(1, 0.0, 16, 4), // feasible: must still be served
        ];
        let results = sched.run_stream(reqs, 0.01).unwrap();
        assert_eq!(results.len(), 1, "the feasible request must complete");
        assert_eq!(results[0].id, 1);
        assert_eq!(sched.stats.overload.shed_infeasible, 1);
        let sheds = sched.sheds();
        assert_eq!(sheds.len(), 1);
        assert!(matches!(&sheds[0].1, ServeError::Shed { id: 0, .. }), "{:?}", sheds[0].1);
        assert!(sheds[0].1.to_string().contains("shed by overload control"));
        assert!(sched.idle(), "a shed request must not linger anywhere");
    }

    #[test]
    fn deadline_doomed_request_is_shed_not_deferred() {
        // max_in_flight 1: request 1's deadline expires while it queues
        // behind request 0 — it must come back as a typed shed, not sit
        // in the queue forever (and run_stream must still terminate)
        let mut sched = ContinuousScheduler::new(engine(), sched_cfg(1, 1));
        let reqs = vec![req(0, 0.0, 16, 24), req(1, 0.0, 16, 4).with_deadline(0.25)];
        let results = sched.run_stream(reqs, 0.1).unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].id, 0);
        assert_eq!(sched.stats.overload.shed_deadline, 1);
        assert!(matches!(&sched.sheds()[0].1, ServeError::Shed { id: 1, .. }));
        let msg = sched.sheds()[0].1.to_string();
        assert!(msg.contains("deadline"), "{msg}");
        // a generous deadline on a COMPLETED request is an SLA stat, not a shed
        let mut ok = ContinuousScheduler::new(engine(), sched_cfg(1, 1));
        let done = ok.run_stream(vec![req(0, 0.0, 16, 3).with_deadline(1e6)], 0.01).unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(ok.stats.overload.shed_deadline, 0);
        assert_eq!(ok.stats.overload.sla_violations, 0);
    }

    #[test]
    fn interactive_arrivals_jump_the_admission_queue() {
        // one decode slot, same arrival instant: the interactive request
        // is admitted first even though the standard one has a lower id
        let mut sched = ContinuousScheduler::new(engine(), sched_cfg(1, 1));
        let reqs = vec![
            req(0, 0.0, 16, 3),
            req(1, 0.0, 16, 3).with_priority(Priority::Interactive),
        ];
        let mut all = sched.run_stream(reqs, 0.5).unwrap();
        all.sort_by_key(|r| r.id);
        assert_eq!(all.len(), 2);
        assert!(
            all[0].queue_secs > all[1].queue_secs,
            "standard queued {}s, interactive {}s — urgency order violated",
            all[0].queue_secs,
            all[1].queue_secs
        );
    }

    #[test]
    fn sla_eviction_prefers_low_priority_victims() {
        // mixed-priority thrash: two interactive sessions fit the pool
        // outright; four batch requests churn through what is left. The
        // SLA victim policy must aim every eviction at the batch class —
        // a batch candidate is never allowed to thrash interactive KV —
        // while serving everyone the exact solo-run tokens.
        let stream = || -> Vec<Request> {
            (0..6)
                .map(|i| {
                    let p = if i < 2 { Priority::Interactive } else { Priority::Batch };
                    req(i, 0.0, 20, 8).with_priority(p)
                })
                .collect()
        };
        let solo = engine_with(BackendKind::Paged, 0);
        let want: Vec<Vec<i32>> =
            stream().iter().map(|r| solo.generate(&r.prompt, r.max_new).unwrap().0).collect();
        let mut sched =
            ContinuousScheduler::new(engine_with(BackendKind::Paged, 5), sched_cfg(6, 1));
        let mut got = sched.run_stream(stream(), 0.01).unwrap();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), 6, "nothing may be lost to eviction churn");
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(&g.output, w, "req {} changed under SLA eviction", g.id);
        }
        let by_class = sched.stats.eviction.evictions_by_class;
        assert_eq!(by_class.iter().sum::<usize>(), sched.stats.eviction.evictions);
        assert!(sched.stats.eviction.evictions > 0, "oversubscription must evict");
        assert!(
            by_class[Priority::Interactive.rank()] < by_class[Priority::Batch.rank()],
            "interactive took {} evictions vs batch {} — SLA policy inverted",
            by_class[Priority::Interactive.rank()],
            by_class[Priority::Batch.rank()]
        );
        assert_eq!(
            by_class[Priority::Interactive.rank()],
            0,
            "the interactive working set fits: no interactive session may be evicted"
        );
    }

    #[test]
    fn idle_pauses_steer_eviction_to_the_stale_session() {
        // regression for the mirror's last_stepped: session 0 pauses on
        // tick 3 (stale recency), session 1 streams on. The arrival on
        // tick 4 must evict the PAUSED session — one eviction, done. The
        // old mirror hardcoded last_stepped to the current tick, which
        // tied recency and (via the freeable/id tie-breaks) evicted the
        // streaming session first, then needed a second eviction anyway.
        let mut sched =
            ContinuousScheduler::new(engine_with(BackendKind::Paged, 4), sched_cfg(4, 1));
        let pauser = req(0, 0.0, 40, 6).with_pause_every(2); // 3 blocks resident
        let streamer = req(1, 0.0, 8, 6); // 1 block resident
        let solo = engine_with(BackendKind::Paged, 0);
        let want: Vec<Vec<i32>> = [&pauser, &streamer, &req(2, 0.0, 24, 4)]
            .iter()
            .map(|r| solo.generate(&r.prompt, r.max_new).unwrap().0)
            .collect();
        sched.submit(pauser);
        sched.submit(streamer);
        let mut done = Vec::new();
        for t in 0..3 {
            done.extend(sched.tick(t as f64 * 0.1).unwrap()); // pauser skips tick 3
        }
        sched.submit(req(2, 0.0, 24, 4)); // needs 2 blocks: forces eviction
        done.extend(sched.tick(0.3).unwrap());
        assert_eq!(
            sched.stats.eviction.evictions,
            1,
            "evicting the stale 3-block pauser alone must make room"
        );
        assert_eq!(sched.in_flight(), 2, "streamer + newcomer stay live");
        assert_eq!(sched.preempted(), 1, "the pauser sits parked");
        let mut now = 0.4;
        while !sched.idle() {
            done.extend(sched.tick(now).unwrap());
            now += 0.1;
        }
        done.sort_by_key(|r| r.id);
        assert_eq!(done.len(), 3);
        for (d, w) in done.iter().zip(&want) {
            assert_eq!(&d.output, w, "req {} changed under pause-aware eviction", d.id);
        }
    }

    #[test]
    fn pressure_dial_degrades_low_priority_but_never_interactive() {
        // occupancy threshold 0.0 = always degrade eligible admissions:
        // the standard request must serve a topk=1 engine's tokens, the
        // interactive one the full topk=2 tokens
        let degraded_engine = || {
            ServeEngine::new(
                ToyModel::new(48, 2, 8, 5),
                ServeCfg {
                    block_size: 16,
                    topk: 1,
                    max_seq: 512,
                    backend: BackendKind::Paged,
                    workers: 1,
                    pool_blocks: 0,
                    ..Default::default()
                },
            )
        };
        let reqs = || {
            vec![req(0, 0.0, 50, 8), req(1, 0.0, 50, 8).with_priority(Priority::Interactive)]
        };
        let want_degraded = degraded_engine().generate(&reqs()[0].prompt, 8).unwrap().0;
        let want_full =
            engine_with(BackendKind::Paged, 0).generate(&reqs()[1].prompt, 8).unwrap().0;
        let cfg = SchedulerCfg {
            max_in_flight: 2,
            decode_workers: 1,
            degrade: Some(DegradeCfg { occupancy: 0.0, topk: 1 }),
            ..SchedulerCfg::default()
        };
        let mut sched = ContinuousScheduler::new(engine_with(BackendKind::Paged, 16), cfg);
        let mut got = sched.run_stream(reqs(), 0.01).unwrap();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].output, want_degraded, "standard request must run at topk=1");
        assert_eq!(got[1].output, want_full, "interactive request must never degrade");
        assert_eq!(sched.stats.overload.degraded_sessions, 1);
        // dial off: bitwise parity with the undialed scheduler
        let mut plain =
            ContinuousScheduler::new(engine_with(BackendKind::Paged, 16), sched_cfg(2, 1));
        let mut base = plain.run_stream(reqs(), 0.01).unwrap();
        base.sort_by_key(|r| r.id);
        assert_eq!(base[0].output, want_full);
        assert_eq!(plain.stats.overload.degraded_sessions, 0);
    }

    #[test]
    fn shared_prefix_requires_paged_backend() {
        let mut sched = ContinuousScheduler::new(engine(), sched_cfg(2, 1));
        assert!(sched.set_shared_prefix(&[1, 2, 3]).is_err());
    }

    #[test]
    fn worker_death_recovers_and_serves_identical_tokens() {
        use crate::serve::chaos::{Fault, FaultKind};
        // the acceptance test: kill one of two decode workers mid-run;
        // every session re-homes to the survivor and the served tokens
        // are bitwise identical to the fault-free tick-loop oracle
        let make_stream = || -> Vec<Request> {
            (0..6).map(|i| req(i, i as f64 * 0.05, 16 + i as usize, 4 + (i as usize % 3))).collect()
        };
        for backend in [BackendKind::CachedSparse, BackendKind::Paged] {
            let mut oracle = ContinuousScheduler::new(
                engine_with(backend, 0),
                SchedulerCfg {
                    max_in_flight: 4,
                    decode_workers: 2,
                    runtime: RuntimeKind::TickLoop,
                    ..SchedulerCfg::default()
                },
            );
            let mut base = oracle.run_stream(make_stream(), 0.05).unwrap();
            base.sort_by_key(|r| r.id);
            let cfg = SchedulerCfg {
                max_in_flight: 4,
                decode_workers: 2,
                runtime: RuntimeKind::Persistent,
                chaos: Some(FaultPlan::new(vec![Fault {
                    worker: 1,
                    tick: 3,
                    kind: FaultKind::Panic,
                }])),
                ..SchedulerCfg::default()
            };
            let mut sched = ContinuousScheduler::new(engine_with(backend, 0), cfg);
            let mut got = sched.run_stream(make_stream(), 0.05).unwrap();
            got.sort_by_key(|r| r.id);
            assert_eq!(got.len(), base.len(), "{backend:?}: lost requests to the fault");
            for (g, b) in got.iter().zip(&base) {
                assert_eq!(g.id, b.id);
                assert_eq!(g.output, b.output, "req {} changed after recovery ({backend:?})", g.id);
            }
            assert_eq!(sched.stats.fault.worker_deaths, 1, "{backend:?}");
            assert!(sched.stats.fault.rehomed_sessions >= 1, "{backend:?}");
            assert_eq!(sched.stats.fault.barrier_timeouts, 0, "{backend:?}");
            assert!(sched.idle(), "{backend:?}: no session left behind");
        }
    }

    #[test]
    fn barrier_deadline_converts_a_stall_into_recovery() {
        use crate::serve::chaos::{Fault, FaultKind};
        // a stalled worker never reports: the barrier deadline declares
        // it dead and its sessions — whose structs die with the zombie —
        // are rebuilt from the recovery ledger alone
        let cfg = SchedulerCfg {
            max_in_flight: 4,
            decode_workers: 2,
            runtime: RuntimeKind::Persistent,
            chaos: Some(FaultPlan::new(vec![Fault {
                worker: 1,
                tick: 2,
                kind: FaultKind::Stall { millis: 1500 },
            }])),
            barrier_deadline_secs: Some(0.3),
            ..SchedulerCfg::default()
        };
        let mut sched = ContinuousScheduler::new(engine(), cfg);
        let stream: Vec<Request> = (0..4).map(|i| req(i, 0.0, 16, 5)).collect();
        let solo = engine();
        let want: Vec<Vec<i32>> =
            stream.iter().map(|r| solo.generate(&r.prompt, r.max_new).unwrap().0).collect();
        let mut got = sched.run_stream(stream, 0.01).unwrap();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), 4);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(&g.output, w, "req {} changed after timeout recovery", g.id);
        }
        assert_eq!(sched.stats.fault.worker_deaths, 1);
        assert_eq!(sched.stats.fault.barrier_timeouts, 1);
        assert!(sched.stats.fault.rehomed_sessions >= 1);
        assert!(sched.stats.fault.recovery_reprefill_secs > 0.0);
    }

    #[test]
    fn killing_every_worker_errors_with_all_workers_dead() {
        use crate::serve::chaos::{Fault, FaultKind};
        let cfg = SchedulerCfg {
            max_in_flight: 4,
            decode_workers: 2,
            runtime: RuntimeKind::Persistent,
            chaos: Some(FaultPlan::new(vec![
                Fault { worker: 0, tick: 2, kind: FaultKind::Panic },
                Fault { worker: 1, tick: 2, kind: FaultKind::AllocFail },
            ])),
            ..SchedulerCfg::default()
        };
        let mut sched = ContinuousScheduler::new(engine(), cfg);
        for i in 0..4 {
            sched.submit(req(i, 0.0, 16, 8));
        }
        let mut found = None;
        for _ in 0..5 {
            if let Err(e) = sched.tick(0.0) {
                found = Some(e);
                break;
            }
        }
        let err = found.expect("a run with every worker dead must error, not hang");
        assert_eq!(
            err.downcast_ref::<ServeError>(),
            Some(&ServeError::AllWorkersDead),
            "typed error must survive the anyhow boundary: {err}"
        );
        assert_eq!(sched.stats.fault.worker_deaths, 2);
    }

    #[test]
    fn worker_stats_account_all_steps() {
        let mut sched = ContinuousScheduler::new(engine(), sched_cfg(4, 2));
        for i in 0..4 {
            sched.submit(req(i, 0.0, 20, 5));
        }
        let mut now = 0.0;
        while !sched.idle() {
            sched.tick(now).unwrap();
            now += 0.1;
        }
        let per_shard: usize = sched.worker_stats().iter().map(|w| w.decode_steps).sum();
        assert_eq!(per_shard, sched.stats.decode_steps_total);
        assert!(sched.worker_stats().iter().all(|w| w.busy_secs >= 0.0));
    }
}
