//! Continuous-batching scheduler: admit new requests into the in-flight
//! decode batch every tick, step every live session one token, retire
//! finished requests — vLLM-style iteration-level scheduling over the
//! incremental-decode sessions of `serve::engine`.
//!
//! Contrast with the original batch mode (`Batcher::pop_batch`), which
//! ran each closed batch to completion before admitting anyone else: here
//! a short request admitted late still finishes early, and prefill of a
//! new request overlaps (in schedule order) with decode of older ones.
//! Sessions are independent — interleaving cannot change any request's
//! tokens, which `tests` pin against the one-request-at-a-time engine.
//!
//! The scheduler is driven by a simulation clock (`tick(now)`), like the
//! batcher, so arrival/queueing behavior is deterministic and testable;
//! prefill/decode times are measured wall clock from the engine.

use anyhow::Result;

use super::batcher::{Batcher, BatcherCfg, Request, RequestResult};
use super::engine::{DecodeSession, ServeEngine};
use super::model::TokenModel;

/// Scheduler limits.
#[derive(Clone, Debug)]
pub struct SchedulerCfg {
    /// decode-batch capacity: max sessions stepped per tick
    pub max_in_flight: usize,
}

impl Default for SchedulerCfg {
    fn default() -> Self {
        SchedulerCfg { max_in_flight: 8 }
    }
}

/// Aggregate counters over the scheduler's lifetime.
#[derive(Clone, Debug, Default)]
pub struct SchedStats {
    pub admitted: usize,
    pub completed: usize,
    pub decode_rounds: usize,
    pub decode_steps_total: usize,
    pub peak_in_flight: usize,
}

struct Live {
    id: u64,
    queue_secs: f64,
    session: DecodeSession,
}

/// Iteration-level scheduler over a `ServeEngine`.
pub struct ContinuousScheduler<M: TokenModel> {
    engine: ServeEngine<M>,
    cfg: SchedulerCfg,
    queue: Batcher,
    running: Vec<Live>,
    pub stats: SchedStats,
}

impl<M: TokenModel> ContinuousScheduler<M> {
    pub fn new(engine: ServeEngine<M>, cfg: SchedulerCfg) -> ContinuousScheduler<M> {
        assert!(cfg.max_in_flight > 0);
        ContinuousScheduler {
            engine,
            cfg,
            // admission policy fields are unused in continuous mode
            queue: Batcher::new(BatcherCfg::default()),
            running: Vec::new(),
            stats: SchedStats::default(),
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.pending()
    }

    pub fn in_flight(&self) -> usize {
        self.running.len()
    }

    pub fn idle(&self) -> bool {
        self.running.is_empty() && self.queue.pending() == 0
    }

    pub fn engine(&self) -> &ServeEngine<M> {
        &self.engine
    }

    /// One scheduler tick at simulation time `now`:
    /// 1. admit arrived requests into free decode slots (prefill them);
    /// 2. step every live session one decode token;
    /// 3. retire finished sessions as `RequestResult`s.
    pub fn tick(&mut self, now: f64) -> Result<Vec<RequestResult>> {
        // 1. admission — new requests join the in-flight batch mid-stream
        let free = self.cfg.max_in_flight - self.running.len();
        for req in self.queue.admit(now, free) {
            let session = self.engine.start(&req.prompt, req.max_new)?;
            self.stats.admitted += 1;
            self.running.push(Live {
                id: req.id,
                queue_secs: (now - req.arrival).max(0.0),
                session,
            });
        }
        self.stats.peak_in_flight = self.stats.peak_in_flight.max(self.running.len());

        // 2. one decode step per live session (the continuous batch)
        if !self.running.is_empty() {
            self.stats.decode_rounds += 1;
        }
        let engine = &self.engine;
        for live in self.running.iter_mut() {
            if engine.step(&mut live.session).is_some() {
                self.stats.decode_steps_total += 1;
            }
        }

        // 3. retirement
        let mut finished = Vec::new();
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].session.finished() {
                let live = self.running.swap_remove(i);
                self.stats.completed += 1;
                finished.push(RequestResult {
                    id: live.id,
                    output: live.session.output().to_vec(),
                    queue_secs: live.queue_secs,
                    prefill_secs: live.session.stats.prefill_secs,
                    decode_secs: live.session.stats.decode_secs,
                    decode_steps: live.session.stats.decode_steps,
                });
            } else {
                i += 1;
            }
        }
        Ok(finished)
    }

    /// Drive a whole arrival stream to completion. `requests` must be
    /// sorted by arrival; the clock advances by `tick_secs` per tick and
    /// jumps forward to the next arrival when the system goes idle.
    pub fn run_stream(
        &mut self,
        requests: Vec<Request>,
        tick_secs: f64,
    ) -> Result<Vec<RequestResult>> {
        let total = requests.len();
        let mut results = Vec::with_capacity(total);
        let mut pending = requests.into_iter().peekable();
        let mut now = 0.0f64;
        while results.len() < total {
            while pending.peek().is_some_and(|r| r.arrival <= now) {
                let req = pending.next().expect("peeked");
                self.submit(req);
            }
            results.extend(self.tick(now)?);
            if self.idle() {
                match pending.peek() {
                    Some(r) => now = now.max(r.arrival),
                    None => break,
                }
            } else {
                now += tick_secs;
            }
        }
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::ServeCfg;
    use crate::serve::model::ToyModel;
    use crate::sparse::BackendKind;

    fn engine() -> ServeEngine<ToyModel> {
        ServeEngine::new(
            ToyModel::new(48, 2, 8, 5),
            ServeCfg {
                block_size: 16,
                topk: 2,
                max_seq: 512,
                backend: BackendKind::CachedSparse,
            },
        )
    }

    fn req(id: u64, arrival: f64, prompt_len: usize, max_new: usize) -> Request {
        Request {
            id,
            prompt: (0..prompt_len as i32).map(|i| (i * 5 + id as i32) % 48).collect(),
            max_new,
            arrival,
        }
    }

    #[test]
    fn completes_all_requests_with_correct_outputs() {
        let mut sched = ContinuousScheduler::new(engine(), SchedulerCfg { max_in_flight: 3 });
        let requests: Vec<Request> =
            (0..7).map(|i| req(i, i as f64 * 0.1, 20 + i as usize, 4 + (i as usize % 3))).collect();
        // reference: every request served alone, outside the scheduler
        let solo = engine();
        let expected: Vec<Vec<i32>> = requests
            .iter()
            .map(|r| solo.generate(&r.prompt, r.max_new).unwrap().0)
            .collect();

        let mut results = sched.run_stream(requests, 0.05).unwrap();
        assert_eq!(results.len(), 7);
        results.sort_by_key(|r| r.id);
        for (r, want) in results.iter().zip(&expected) {
            assert_eq!(&r.output, want, "req {} output changed under batching", r.id);
            assert_eq!(r.decode_steps, r.output.len().saturating_sub(1));
            assert!(r.queue_secs >= 0.0);
        }
        assert_eq!(sched.stats.completed, 7);
        assert!(sched.stats.peak_in_flight <= 3);
        assert!(sched.idle());
    }

    #[test]
    fn capacity_limits_in_flight_and_late_arrivals_wait() {
        let mut sched = ContinuousScheduler::new(engine(), SchedulerCfg { max_in_flight: 2 });
        for i in 0..4 {
            sched.submit(req(i, 0.0, 16, 8));
        }
        let done = sched.tick(0.0).unwrap();
        assert!(done.is_empty());
        assert_eq!(sched.in_flight(), 2);
        assert_eq!(sched.pending(), 2);
        // not-yet-arrived requests are never admitted
        sched.submit(req(9, 100.0, 16, 2));
        sched.tick(0.1).unwrap();
        assert_eq!(sched.pending(), 3);
    }

    #[test]
    fn new_request_joins_inflight_decode_batch() {
        // continuous batching: request 1 is admitted while request 0 is
        // mid-decode, and both make progress in the same ticks
        let mut sched = ContinuousScheduler::new(engine(), SchedulerCfg { max_in_flight: 4 });
        sched.submit(req(0, 0.0, 16, 10));
        sched.tick(0.0).unwrap();
        assert_eq!(sched.in_flight(), 1);
        sched.submit(req(1, 0.0, 16, 2));
        let mut done = Vec::new();
        let mut ticks = 0;
        while !sched.idle() {
            done.extend(sched.tick(0.1 * ticks as f64).unwrap());
            ticks += 1;
        }
        assert_eq!(done.len(), 2);
        // the short request retired before the long one despite arriving later
        assert_eq!(done[0].id, 1);
        assert_eq!(done[1].id, 0);
        assert_eq!(sched.stats.peak_in_flight, 2);
    }

    #[test]
    fn queue_latency_reflects_admission_delay() {
        let mut sched = ContinuousScheduler::new(engine(), SchedulerCfg { max_in_flight: 1 });
        sched.submit(req(0, 0.0, 16, 3));
        sched.submit(req(1, 0.0, 16, 3));
        let mut all = Vec::new();
        let mut now = 0.0;
        while !sched.idle() {
            all.extend(sched.tick(now).unwrap());
            now += 1.0;
        }
        all.sort_by_key(|r| r.id);
        assert!(all[0].queue_secs < all[1].queue_secs, "second request queued longer");
    }
}
