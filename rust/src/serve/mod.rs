//! Serving path: MoBA-prefill / full-attention-decode, the paper's
//! deployment mode (§3.3: "MoBA is used for prefill only, while we
//! switch to full attention during generation").
//!
//! - `engine`: generation over logits artifacts (prefill scoring with the
//!   MoBA graph, per-token decode with the full-attention graph);
//! - `batcher`: request queue + batch former with latency accounting.

pub mod batcher;
pub mod engine;

pub use batcher::{Batcher, BatcherCfg, Request, RequestResult};
pub use engine::{GenStats, ServeEngine};
