//! Serving stack: prefill-once / incremental-decode sessions over the
//! pluggable attention backends, behind a continuous-batching scheduler.
//!
//! - `model`: the [`TokenModel`] contract (per-token q/k/v + logits) and
//!   the deterministic `ToyModel` CPU-testbed implementation;
//! - `engine`: [`ServeEngine`] + per-request [`DecodeSession`] — prompt
//!   ingested once through `AttentionBackend::prefill`, then O(k·B)
//!   cached decode steps (paper §3.3's deployment modes, selectable via
//!   `BackendKind`); sessions hold one backend per model layer, so a
//!   hybrid [`ServeCfg::layers`] spec ([`LayerKind`], `--layers` /
//!   `MOBA_LAYERS`) mixes full-attention layers among MoBA ones with
//!   layer-summed pool accounting and per-layer [`SwapBundle`] swaps;
//! - `batcher`: timestamped admission queue (batch + continuous modes)
//!   with queue/prefill/decode latency accounting;
//! - `scheduler`: [`ContinuousScheduler`] — iteration-level scheduling:
//!   admit into the in-flight decode batch, step every session one token,
//!   retire finished requests; on a bounded paged pool it oversubscribes
//!   via LRU eviction + transparent re-prefill resume (bit-identical
//!   tokens, [`EvictionStats`] accounting), optionally backed by a
//!   bounded host swap tier ([`SchedulerCfg::swap_blocks`]) that
//!   snapshots victims byte-exact and restores them at a fraction of
//!   the re-prefill cost ([`SwapStats`]);
//! - `runtime`: the thread-per-core decode runtime — persistent named,
//!   core-pinned workers fed by bounded channels, with work stealing
//!   between shards ([`RuntimeKind`] selects it vs the legacy per-tick
//!   scoped-thread loop; served tokens are bitwise identical either way);
//! - `error` / `chaos`: typed [`ServeError`] worker faults +
//!   [`FaultStats`] recovery accounting, and the seeded [`FaultPlan`]
//!   chaos-injection harness that proves a dead decode worker degrades
//!   into the eviction/resume path bit-identically;
//! - `load`: trace-driven storm workloads — seeded bursty multi-tenant
//!   request traces ([`StormCfg`]/[`storm`]) plus the SLA digest
//!   ([`summarize`]) behind the overload bench arm; pairs with the
//!   scheduler's overload controls (priority classes, deadline shedding,
//!   SLA-aware eviction, the [`DegradeCfg`] pressure dial);
//! - `demo`: the shared arrival-stream demo driver behind `repro serve`
//!   and `examples/serve_continuous.rs`;
//! - `artifact` (feature `xla`): the AOT-graph generation path through
//!   PJRT (MoBA-prefill / full-decode logits artifacts).

pub mod batcher;
pub mod chaos;
pub mod demo;
pub mod engine;
pub mod error;
pub mod load;
pub mod model;
pub mod runtime;
pub mod scheduler;

#[cfg(feature = "xla")]
pub mod artifact;

pub use batcher::{Batcher, BatcherCfg, Priority, Request, RequestResult};
pub use chaos::{Fault, FaultKind, FaultPlan};
pub use demo::{run_demo, DemoCfg};
pub use engine::{
    layers_from_env, layers_from_env_strict, parse_layers, DecodeSession, GenStats, LayerKind,
    PoolStatus, ServeCfg, ServeEngine, SwapBundle,
};
pub use error::{FaultStats, ServeError};
pub use load::{storm, summarize, StormCfg, StormSummary};
pub use model::{TokenModel, ToyModel};
pub use runtime::{pin_from_env, pin_supported, steal_from_env, RuntimeKind};
pub use scheduler::{
    ContinuousScheduler, DegradeCfg, EvictionStats, OverloadStats, SchedStats, SchedulerCfg,
    SwapStats, WorkerStats,
};

#[cfg(feature = "xla")]
pub use artifact::ArtifactServeEngine;
