//! Poison-resistant synchronization helpers.
//!
//! A panic while holding a `Mutex`/`RwLock` poisons it; the default
//! `.lock().unwrap()` idiom then propagates that panic to every other
//! thread that touches the lock, turning one worker fault into a
//! process-wide cascade. For the data these locks guard (steal deques,
//! done-boxes, the paged block pool, the kernel-pool queue) the
//! invariant is maintained *across* critical sections, not within them
//! — every mutation is complete before a panic can occur or is
//! idempotent on retry — so the right recovery is to take the lock
//! anyway and let the supervision layer deal with the fault that caused
//! the poisoning.

use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Acquire a read guard, recovering from poisoning.
pub fn read<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

/// Acquire a write guard, recovering from poisoning.
pub fn write<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

/// Block on a condvar, recovering the guard if the lock was poisoned
/// while we slept.
pub fn wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock(&m), 7);
        *lock(&m) = 9;
        assert_eq!(*lock(&m), 9);
    }

    #[test]
    fn rwlock_recovers_from_poison() {
        let l = Arc::new(RwLock::new(1i32));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        *write(&l) += 1;
        assert_eq!(*read(&l), 2);
    }
}
