//! Deterministic PRNG for data generation and parameter initialization.
//!
//! The image has no network access to crates.io, so instead of `rand` we
//! ship a small, well-tested splitmix64/xoshiro256** stack. Everything in
//! the repo that needs randomness (corpus synthesis, needle placement,
//! parameter init, serving workloads) goes through this module so runs
//! are reproducible from a single seed.

/// splitmix64: used for seeding and cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller output
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-worker / per-leaf seeding).
    pub fn split(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless bounded sampling
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal(0, std) as f32.
    #[inline]
    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut r = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Rng::new(3);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
