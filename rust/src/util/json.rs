//! Minimal JSON parser/writer (std-only; no network access for serde).
//!
//! Used to read `artifacts/manifest.json` (written by `python -m
//! compile.aot`) and to write run summaries / experiment outputs. Supports
//! the full JSON grammar minus `\u` surrogate pairs outside the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn usize(&self) -> Result<usize> {
        let x = self.num()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building output documents.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code)
                                .ok_or_else(|| anyhow!("bad \\u escape {code:#x}"))?);
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence starting at c
                    let start = self.i - 1;
                    let len = utf8_len(c)?;
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>()
            .map_err(|e| anyhow!("bad number '{txt}' at byte {start}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    Ok(match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => bail!("invalid UTF-8 lead byte {first:#x}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" 3.5 ").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().arr().unwrap()[2].get("b").unwrap().str().unwrap(),
            "x"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"o":{"k":true}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(j.str().unwrap(), "café é");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"artifacts": [{"name": "x", "inputs":
            [{"name": "p.embed", "shape": [256, 32], "dtype": "float32"}]}]}"#;
        let j = Json::parse(src).unwrap();
        let a = &j.get("artifacts").unwrap().arr().unwrap()[0];
        let shape = a.get("inputs").unwrap().arr().unwrap()[0]
            .get("shape").unwrap().arr().unwrap();
        assert_eq!(shape[0].usize().unwrap(), 256);
    }
}
