//! Tiny argument parser (std-only; clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse a raw argv slice (without the program name).
    /// `flag_names` lists options that take no value.
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else {
                    i += 1;
                    if i >= argv.len() {
                        bail!("option --{rest} needs a value");
                    }
                    out.options.insert(rest.to_string(), argv[i].clone());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a non-negative integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a non-negative integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| anyhow::anyhow!("--{name} expects a number, got {v:?}"))
            }
        }
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn mixed_forms() {
        let a = Args::parse(&sv(&["train", "--steps", "50", "--quiet",
                                  "--lr=0.003", "extra"]),
                            &["quiet"]).unwrap();
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get_usize("steps", 0).unwrap(), 50);
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.003);
        assert!(a.flag("quiet"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["--steps"]), &[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&[]), &[]).unwrap();
        assert_eq!(a.get_usize("x", 7).unwrap(), 7);
        assert_eq!(a.get_str("y", "d"), "d");
        assert_eq!(a.get_list("l", &["a", "b"]), vec!["a", "b"]);
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&sv(&["--sizes", "s0, s1,s2"]), &[]).unwrap();
        assert_eq!(a.get_list("sizes", &[]), vec!["s0", "s1", "s2"]);
    }

    #[test]
    fn parse_errors_name_the_flag_and_value() {
        let a = Args::parse(&sv(&["--requests", "lots", "--seed", "-1", "--rate", "fast"]), &[])
            .unwrap();
        let e = a.get_usize("requests", 1).unwrap_err().to_string();
        assert!(e.contains("--requests") && e.contains("lots"), "unhelpful: {e}");
        let e = a.get_u64("seed", 1).unwrap_err().to_string();
        assert!(e.contains("--seed") && e.contains("-1"), "unhelpful: {e}");
        let e = a.get_f64("rate", 1.0).unwrap_err().to_string();
        assert!(e.contains("--rate") && e.contains("fast"), "unhelpful: {e}");
    }
}
