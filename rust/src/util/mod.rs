//! Std-only substrates: RNG, JSON, CLI parsing. The build image has no
//! registry access beyond the vendored `xla` dep tree, so these replace
//! `rand`, `serde_json` and `clap`.

pub mod cli;
pub mod json;
pub mod rng;
pub mod sync;
