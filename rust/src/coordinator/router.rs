//! Algorithm-1 router: the MoE-style dispatch plan for MoBA.
//!
//! The paper's high-performance implementation (§2.3, Algorithm 1 lines
//! 9-12) arranges query tokens by their assigned KV block so each block's
//! attention runs as one varlen FlashAttention segment, then scatters the
//! partial outputs back and merges them with online softmax. On TPU the
//! merge lives inside the kernel (see `python/compile/kernels/moba.py`),
//! but the *dispatch plan* — which queries visit which blocks, in what
//! packed order — is coordinator logic, and this module owns it.
//!
//! It produces, per KV block: the self-attention segment (queries whose
//! *current* block it is; causal) and the history segment (queries routed
//! here by the gate; non-causal), plus varlen offsets (`cu_seqlens`-style)
//! and the inverse permutation for the scatter-back. Property tests pin
//! the invariants; the serving engine uses the same plan to batch prefill
//! chunks.

use crate::sparse::parallel::parallel_map;
use crate::sparse::{AttentionBackend, Gate};
use crate::tensor::Tensor;

/// One KV block's share of the dispatch.
#[derive(Clone, Debug, Default)]
pub struct BlockAssignment {
    /// queries (token indices) for which this is the current block —
    /// attended with a causal mask (Algorithm 1 line 13)
    pub self_queries: Vec<u32>,
    /// queries routed here as a *history* block — non-causal
    /// (Algorithm 1 line 14)
    pub hist_queries: Vec<u32>,
}

/// The full dispatch plan for one head.
#[derive(Clone, Debug)]
pub struct RoutingPlan {
    pub block_size: usize,
    pub n: usize,
    pub blocks: Vec<BlockAssignment>,
    /// varlen offsets over the packed history segments:
    /// `hist_offsets[i]..hist_offsets[i+1]` indexes block i's queries in
    /// `packed_hist`
    pub hist_offsets: Vec<u32>,
    /// concatenation of all history segments (the "arranged" query order,
    /// Algorithm 1 line 11)
    pub packed_hist: Vec<u32>,
}

impl RoutingPlan {
    /// Build the plan for head `h` of a gate.
    pub fn build(gate: &Gate, h: usize, block_size: usize) -> RoutingPlan {
        let nb = gate.n_blocks;
        let mut blocks = vec![BlockAssignment::default(); nb];
        for t in 0..gate.n {
            let cur = t / block_size;
            for i in 0..=cur.min(nb - 1) {
                if gate.get(h, t, i) {
                    if i == cur {
                        blocks[i].self_queries.push(t as u32);
                    } else {
                        blocks[i].hist_queries.push(t as u32);
                    }
                }
            }
        }
        let mut hist_offsets = Vec::with_capacity(nb + 1);
        let mut packed_hist = Vec::new();
        hist_offsets.push(0u32);
        for b in &blocks {
            packed_hist.extend_from_slice(&b.hist_queries);
            hist_offsets.push(packed_hist.len() as u32);
        }
        RoutingPlan { block_size, n: gate.n, blocks, hist_offsets, packed_hist }
    }

    /// Dispatch plans for all heads, gated by an attention backend: the
    /// serving/experiment layers ask the *backend* which blocks each query
    /// visits instead of calling `moba_gate` directly, so dense backends
    /// (which return no gate — every query visits every causal block)
    /// yield `None` and sparse backends yield one plan per head.
    pub fn from_backend(
        backend: &dyn AttentionBackend,
        q: &Tensor,
        k: &Tensor,
        block_size: usize,
    ) -> Option<Vec<RoutingPlan>> {
        Self::from_backend_par(backend, q, k, block_size, 1)
    }

    /// [`RoutingPlan::from_backend`] with the per-head plan builds spread
    /// over `workers` threads. Heads are independent, so the returned
    /// plans are identical to the serial build for any worker count.
    ///
    /// Only the plan-construction stage parallelizes; the gate itself is
    /// computed by the backend serially (the trait's `gate` takes no
    /// worker count) and usually dominates. Threading workers through the
    /// gate is future work — it needs a generic-element `for_each_slot`
    /// so `moba_gate`'s bit-set fills per-head in parallel.
    pub fn from_backend_par(
        backend: &dyn AttentionBackend,
        q: &Tensor,
        k: &Tensor,
        block_size: usize,
        workers: usize,
    ) -> Option<Vec<RoutingPlan>> {
        let gate = backend.gate(q, k)?;
        Some(parallel_map(gate.heads, workers, |h| RoutingPlan::build(&gate, h, block_size)))
    }

    /// Total (query, block) attention pairs — proportional to FLOPs.
    pub fn total_pairs(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| b.self_queries.len() + b.hist_queries.len())
            .sum()
    }

    /// Inverse map: for each query, how many partial outputs will be
    /// produced (current block + gated history blocks). The online-softmax
    /// combine (Algorithm 1 line 16) merges exactly this many partials.
    pub fn partials_per_query(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.n];
        for b in &self.blocks {
            for &q in &b.self_queries {
                counts[q as usize] += 1;
            }
            for &q in &b.hist_queries {
                counts[q as usize] += 1;
            }
        }
        counts
    }

    /// Expert-utilization statistics: per-block history load (how many
    /// queries routed to each block). The MoE load-balance lens on MoBA.
    pub fn block_loads(&self) -> Vec<usize> {
        self.blocks.iter().map(|b| b.hist_queries.len()).collect()
    }

    /// Load-imbalance factor: max/mean history load over *routable*
    /// blocks (blocks that at least one later query could select).
    pub fn imbalance(&self) -> f64 {
        let loads = self.block_loads();
        // the last block can never be a history target
        let routable = &loads[..loads.len().saturating_sub(1)];
        if routable.is_empty() {
            return 1.0;
        }
        let max = *routable.iter().max().unwrap() as f64;
        let mean = routable.iter().sum::<usize>() as f64 / routable.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::moba_gate;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn rand_t(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
    }

    fn plan(seed: u64, n: usize, bs: usize, topk: usize) -> (RoutingPlan, Gate) {
        let q = rand_t(&[n, 1, 8], seed);
        let k = rand_t(&[n, 1, 8], seed + 1);
        let g = moba_gate(&q, &k, bs, topk);
        (RoutingPlan::build(&g, 0, bs), g)
    }

    #[test]
    fn every_query_in_exactly_one_self_segment() {
        let (p, _) = plan(1, 128, 16, 3);
        let mut seen = vec![0; 128];
        for (i, b) in p.blocks.iter().enumerate() {
            for &q in &b.self_queries {
                seen[q as usize] += 1;
                assert_eq!(q as usize / 16, i, "query in wrong self block");
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn hist_segments_respect_causality() {
        let (p, _) = plan(2, 128, 16, 3);
        for (i, b) in p.blocks.iter().enumerate() {
            for &q in &b.hist_queries {
                assert!(
                    q as usize / 16 > i,
                    "history block {i} got query {q} from a non-later block"
                );
            }
        }
    }

    #[test]
    fn pairs_match_gate_totals() {
        let (p, g) = plan(3, 256, 32, 3);
        assert_eq!(p.total_pairs(), g.total_selected());
    }

    #[test]
    fn partials_equal_topk_bounded() {
        let topk = 3;
        let (p, _) = plan(4, 256, 32, topk);
        for (t, &c) in p.partials_per_query().iter().enumerate() {
            let avail = t / 32 + 1;
            assert_eq!(c as usize, topk.min(avail), "t={t}");
        }
    }

    #[test]
    fn varlen_offsets_consistent() {
        let (p, _) = plan(5, 128, 16, 2);
        assert_eq!(p.hist_offsets.len(), p.blocks.len() + 1);
        for (i, b) in p.blocks.iter().enumerate() {
            let lo = p.hist_offsets[i] as usize;
            let hi = p.hist_offsets[i + 1] as usize;
            assert_eq!(&p.packed_hist[lo..hi], b.hist_queries.as_slice());
        }
        assert_eq!(*p.hist_offsets.last().unwrap() as usize, p.packed_hist.len());
    }

    #[test]
    fn last_block_gets_no_history_queries() {
        let (p, _) = plan(6, 128, 16, 3);
        assert!(p.blocks.last().unwrap().hist_queries.is_empty());
    }

    #[test]
    fn imbalance_at_least_one() {
        let (p, _) = plan(7, 512, 32, 3);
        assert!(p.imbalance() >= 1.0);
    }

    #[test]
    fn from_backend_matches_direct_gate_and_skips_dense() {
        use crate::sparse::{FullAttention, MobaAttention};
        let q = rand_t(&[128, 2, 8], 8);
        let k = rand_t(&[128, 2, 8], 9);
        let backend = MobaAttention::new(2, 8, 16, 3);
        let plans = RoutingPlan::from_backend(&backend, &q, &k, 16).unwrap();
        assert_eq!(plans.len(), 2);
        let g = moba_gate(&q, &k, 16, 3);
        for (h, p) in plans.iter().enumerate() {
            let direct = RoutingPlan::build(&g, h, 16);
            assert_eq!(p.total_pairs(), direct.total_pairs());
            assert_eq!(p.packed_hist, direct.packed_hist);
            assert_eq!(p.hist_offsets, direct.hist_offsets);
        }
        assert!(RoutingPlan::from_backend(&FullAttention::new(2, 8), &q, &k, 16).is_none());
    }

    #[test]
    fn parallel_plan_build_matches_serial() {
        use crate::sparse::FusedMobaAttention;
        let q = rand_t(&[96, 4, 8], 10);
        let k = rand_t(&[96, 4, 8], 11);
        // the fused backend exposes the same gate as the two-pass one,
        // so plans built from it match the direct gate
        let backend = FusedMobaAttention::new(4, 8, 16, 3);
        let serial = RoutingPlan::from_backend(&backend, &q, &k, 16).unwrap();
        for workers in [2usize, 4, 8] {
            let par = RoutingPlan::from_backend_par(&backend, &q, &k, 16, workers).unwrap();
            assert_eq!(par.len(), serial.len());
            for (a, b) in par.iter().zip(&serial) {
                assert_eq!(a.packed_hist, b.packed_hist, "workers={workers}");
                assert_eq!(a.hist_offsets, b.hist_offsets, "workers={workers}");
                assert_eq!(a.total_pairs(), b.total_pairs(), "workers={workers}");
            }
        }
    }
}
