//! L3 coordination: the paper's system contribution.
//!
//! - `router`: Algorithm-1 MoE-style dispatch — query→KV-block assignment,
//!   varlen packing, scatter-back bookkeeping, load statistics; plans are
//!   built from any gated `sparse::AttentionBackend`
//!   (`RoutingPlan::from_backend`) rather than a hard-wired gate call;
//! - `stages`: MoBA↔full executable scheduling (hybrid training recipes,
//!   continual pre-training stages).
//!
//! Request-level batching for the serving path lives in `crate::serve`.

pub mod router;
pub mod stages;

pub use router::{BlockAssignment, RoutingPlan};
pub use stages::{Stage, StageSchedule};
