//! Stage scheduling: the paper's seamless MoBA <-> full-attention
//! switching, expressed as a training-time executable schedule.
//!
//! Because MoBA adds no parameters, the *same* `ModelState` can be fed to
//! the MoBA train-step executable for the first 90% of tokens and the
//! full-attention executable for the last 10% (the paper's MoBA/full
//! hybrid recipe, Fig 5a), or to any layer-wise hybrid artifact. The
//! scheduler maps a global step index to the artifact that should run it.

use anyhow::{bail, Result};

/// One training stage: run `artifact` for `steps` optimizer steps.
#[derive(Clone, Debug, PartialEq)]
pub struct Stage {
    pub artifact: String,
    pub steps: u64,
}

#[derive(Clone, Debug)]
pub struct StageSchedule {
    stages: Vec<Stage>,
}

impl StageSchedule {
    /// Single-stage schedule (plain MoBA or plain full training).
    pub fn single(artifact: &str, steps: u64) -> StageSchedule {
        StageSchedule { stages: vec![Stage { artifact: artifact.into(), steps }] }
    }

    /// The paper's hybrid recipe: `frac` of the steps on `first`, the
    /// remainder on `second` (e.g. 0.9 MoBA then 0.1 full).
    pub fn hybrid(first: &str, second: &str, total: u64, frac: f64) -> Result<StageSchedule> {
        if !(0.0..=1.0).contains(&frac) {
            bail!("fraction {frac} outside [0,1]");
        }
        let first_steps = ((total as f64) * frac).round() as u64;
        let stages = vec![
            Stage { artifact: first.into(), steps: first_steps },
            Stage { artifact: second.into(), steps: total - first_steps },
        ];
        Ok(StageSchedule { stages })
    }

    /// Multi-stage (continual pre-training recipe, Fig 6): arbitrary
    /// (artifact, steps) list, e.g. 512-ctx -> 1024-ctx(PI) -> 2048-ctx(PI).
    pub fn stages(stages: Vec<Stage>) -> StageSchedule {
        StageSchedule { stages }
    }

    pub fn total_steps(&self) -> u64 {
        self.stages.iter().map(|s| s.steps).sum()
    }

    /// Artifact for 0-based global step, or None past the end.
    pub fn artifact_for(&self, step: u64) -> Option<&str> {
        let mut acc = 0;
        for st in &self.stages {
            acc += st.steps;
            if step < acc {
                return Some(&st.artifact);
            }
        }
        None
    }

    /// Global steps at which the executable switches (for loss-spike
    /// inspection around the transition, paper §3.2).
    pub fn switch_points(&self) -> Vec<u64> {
        let mut pts = Vec::new();
        let mut acc = 0;
        for st in &self.stages[..self.stages.len().saturating_sub(1)] {
            acc += st.steps;
            pts.push(acc);
        }
        pts
    }

    pub fn stage_list(&self) -> &[Stage] {
        &self.stages
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_covers_all_steps() {
        let s = StageSchedule::single("a", 10);
        assert_eq!(s.artifact_for(0), Some("a"));
        assert_eq!(s.artifact_for(9), Some("a"));
        assert_eq!(s.artifact_for(10), None);
    }

    #[test]
    fn hybrid_90_10() {
        let s = StageSchedule::hybrid("moba", "full", 100, 0.9).unwrap();
        assert_eq!(s.artifact_for(89), Some("moba"));
        assert_eq!(s.artifact_for(90), Some("full"));
        assert_eq!(s.switch_points(), vec![90]);
        assert_eq!(s.total_steps(), 100);
    }

    #[test]
    fn hybrid_rejects_bad_fraction() {
        assert!(StageSchedule::hybrid("a", "b", 10, 1.5).is_err());
    }

    #[test]
    fn multi_stage_boundaries() {
        let s = StageSchedule::stages(vec![
            Stage { artifact: "s512".into(), steps: 5 },
            Stage { artifact: "s1024".into(), steps: 3 },
            Stage { artifact: "s2048".into(), steps: 2 },
        ]);
        assert_eq!(s.artifact_for(4), Some("s512"));
        assert_eq!(s.artifact_for(5), Some("s1024"));
        assert_eq!(s.artifact_for(8), Some("s2048"));
        assert_eq!(s.switch_points(), vec![5, 8]);
    }
}
