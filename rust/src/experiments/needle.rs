//! Fig 6 recipe + Fig 7 heatmap: continual context extension and
//! needle-in-a-haystack retrieval.
//!
//! Stages mirror the paper's 128K→1M continual pre-training (scaled:
//! 512 → 1024 → 2048 with position-interpolation artifacts), training on
//! needle-bearing data with MoBA throughout. Evaluation sweeps (context
//! length × needle depth) and scores exact retrieval with:
//!
//! - the pure-MoBA logits graph,
//! - the layer-wise hybrid deployment graph (last layer full — the
//!   paper's serving configuration).

use anyhow::Result;

use crate::coordinator::{Stage, StageSchedule};
use crate::data::NeedleGen;
use crate::eval::needle_score::score_needles;
use crate::metrics::writer::RunDir;
use crate::runtime::{checkpoint, Engine};
use crate::train::{LrSchedule, Trainer};
use crate::util::json::{num, obj, s, Json};

pub struct NeedleArgs {
    pub stage_steps: Vec<u64>,
    pub seed: u64,
    pub samples_per_cell: usize,
    pub lm_weight: f32,
    /// use the full-attention twin instead of MoBA (baseline comparison)
    pub full: bool,
}

impl Default for NeedleArgs {
    fn default() -> Self {
        NeedleArgs {
            stage_steps: vec![220, 60, 40],
            seed: 42,
            samples_per_cell: 5,
            lm_weight: 0.1,
            full: false,
        }
    }
}

/// (stage artifact suffix, context length) triples for the recipe
const STAGES: [(&str, usize); 3] = [("s0", 512), ("s1", 1024), ("s2", 2048)];

pub fn run(engine: &Engine, args: &NeedleArgs) -> Result<()> {
    let variant = if args.full { "full" } else { "moba" };
    let dir = RunDir::create(&format!("needle/{variant}"))?;
    println!("== Fig 6/7 — continual context extension + needle retrieval ({variant}) ==");

    let infix = if args.full { "_full" } else { "" };
    // ---- Fig 6: staged continual pre-training ---------------------------
    let stages: Vec<Stage> = STAGES
        .iter()
        .zip(&args.stage_steps)
        .map(|((suffix, _), &steps)| Stage {
            artifact: format!("needle_{suffix}{infix}_train"),
            steps,
        })
        .collect();
    let schedule = StageSchedule::stages(stages);
    let total = schedule.total_steps();
    let gen = NeedleGen::new(args.seed);
    let lr = LrSchedule::new(2e-3, total, 0.05, 0.1);
    let mut trainer = Trainer::new(engine, schedule, lr, args.seed)?;
    let seed = args.seed;
    let lm_weight = args.lm_weight;
    let engine_ref = engine;
    let mut csv = dir.csv("train_loss.csv", &["step", "loss", "lr"])?;
    trainer.run(
        |step| {
            // the active stage determines the sequence length
            let art_name = trainer_artifact_for(step, &args.stage_steps, infix);
            let seq = engine_ref.manifest.get(&art_name).map(|a| a.seq).unwrap_or(512);
            gen.train_batch(seed, step, 1, seq, lm_weight)
        },
        |info| {
            let _ = csv.row(&[info.step as f64, info.loss as f64, info.lr]);
            if info.step % 25 == 0 {
                eprintln!(
                    "    step {:>4} loss {:.4} [{}]",
                    info.step, info.loss, info.artifact
                );
            }
        },
    )?;
    csv.flush()?;
    checkpoint::save(&trainer.state, &dir.path.join("model.ckpt"))?;

    // ---- Fig 7: (length x depth) heatmap ------------------------------
    let depths = [0.0, 0.25, 0.5, 0.75, 1.0];
    let lengths = [256usize, 512, 1024, 2048];
    println!("\nretrieval accuracy (rows = depth, cols = context length)");
    print!("{:>6}", "depth");
    for &l in &lengths {
        print!("{l:>8}");
    }
    println!();
    let mut cells = Vec::new();
    for &depth in &depths {
        print!("{depth:>6.2}");
        for &len in &lengths {
            // pick the smallest stage artifact that fits this length
            let (suffix, art_seq) = STAGES
                .iter()
                .find(|(_, s)| *s >= len)
                .copied()
                .unwrap_or(("s2", 2048));
            let logits_name = format!("needle_{suffix}{infix}_logits");
            // generate needles at the artifact length but with the fact
            // constrained to the first `len` tokens: we emulate shorter
            // contexts by sampling at exactly len == artifact seq when
            // possible; otherwise scale depth into the shorter prefix.
            let samples = if len == art_seq {
                gen.eval_samples(seed ^ 0xF7, len, depth, args.samples_per_cell)
            } else {
                // shorter-than-artifact grid cell: place haystack in a
                // len-sized window by generating at artifact length with
                // depth scaled into [0, len/art_seq]
                let scaled = depth * (len as f64 / art_seq as f64);
                gen.eval_samples(seed ^ 0xF7, art_seq, scaled, args.samples_per_cell)
            };
            let acc = score_needles(engine, &logits_name, &trainer.state.params, &samples)?;
            print!("{:>8.2}", acc);
            cells.push(obj(vec![
                ("depth", num(depth)),
                ("length", num(len as f64)),
                ("accuracy", num(acc)),
                ("artifact", s(&logits_name)),
            ]));
        }
        println!();
    }

    // hybrid deployment graph (last layer full) at the longest context
    if !args.full {
        let samples = gen.eval_samples(seed ^ 0xF7, 2048, 0.5, args.samples_per_cell);
        let acc_hybrid =
            score_needles(engine, "needle_hybrid_logits", &trainer.state.params, &samples)?;
        println!("\nlayer-wise hybrid deployment graph @2048 depth 0.5: {acc_hybrid:.2}");
        cells.push(obj(vec![
            ("depth", num(0.5)),
            ("length", num(2048.0)),
            ("accuracy", num(acc_hybrid)),
            ("artifact", s("needle_hybrid_logits")),
        ]));
    }

    dir.write_json("heatmap.json", &Json::Arr(cells))?;
    println!("-> runs/needle/{variant}/heatmap.json");
    Ok(())
}

/// Map a global step to its stage's train artifact name (helper shared
/// with the batch closure, which cannot borrow the trainer).
fn trainer_artifact_for(step: u64, stage_steps: &[u64], infix: &str) -> String {
    let mut acc = 0;
    for ((suffix, _), &steps) in STAGES.iter().zip(stage_steps) {
        acc += steps;
        if step < acc {
            return format!("needle_{suffix}{infix}_train");
        }
    }
    format!("needle_s2{infix}_train")
}
