//! Fig 3a / 3b: scaling-law comparison of MoBA vs full attention.
//!
//! Trains the five-model ladder under both attention regimes at matched
//! hyperparameters (the only difference is the attention module — same
//! guarantee the paper makes), evaluates validation LM loss (Fig 3a) and
//! trailing-token loss at the long context (Fig 3b), and writes the
//! per-run loss curves + a summary CSV that `fits` consumes for Fig 3c
//! and Table 3.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::StageSchedule;
use crate::eval::losses::trailing_mean;
use crate::metrics::writer::RunDir;
use crate::runtime::Engine;
use crate::util::json::{arr, num, obj, s, Json};

use super::common::{compute_flops, train_and_eval};

pub struct ScalingArgs {
    pub sizes: Vec<String>,
    pub steps: u64,
    /// long-context (Fig 3b) mode: seq 2048 @ 95.31% sparsity artifacts
    pub long: bool,
    pub seed: u64,
    pub eval_batches: u64,
}

impl Default for ScalingArgs {
    fn default() -> Self {
        ScalingArgs {
            sizes: ["s0", "s1", "s2", "s3", "s4"].iter().map(|x| x.to_string()).collect(),
            steps: 120,
            long: false,
            seed: 42,
            eval_batches: 4,
        }
    }
}

pub fn run(engine: &Engine, args: &ScalingArgs) -> Result<()> {
    let tag = if args.long { "fig3b_long" } else { "fig3a" };
    let dir = RunDir::create(&format!("scaling/{tag}"))?;
    let prefix = if args.long { "long" } else { "scaling" };
    let mut summary_rows = Vec::new();

    println!("== Fig 3{} — scaling law: MoBA vs full ==", if args.long { "b" } else { "a" });
    println!(
        "{:<6} {:<6} {:>10} {:>12} {:>10} {:>10} {:>8}",
        "size", "attn", "params", "compute", "val_loss", "trailing", "secs"
    );

    for size in &args.sizes {
        for variant in ["moba", "full"] {
            let train_name = format!("{prefix}_{size}_{variant}_train");
            let eval_name = format!("{prefix}_{size}_{variant}_eval");
            let art = engine.manifest.get(&train_name)?;
            let cfg = TrainConfig {
                steps: args.steps,
                seed: args.seed,
                batch: art.batch,
                seq: art.seq,
                ..Default::default()
            };
            let mut csv = dir.csv(&format!("{size}_{variant}_loss.csv"), &["step", "loss", "lr"])?;
            let schedule = StageSchedule::single(&train_name, cfg.steps);
            let out = train_and_eval(engine, schedule, &eval_name, &cfg, args.eval_batches, Some(&mut csv))?;

            let val_loss = out.eval.mean();
            // paper Fig 3b: last 1K of 32K = last 1/32 of the context
            let trailing = trailing_mean(&out.eval, 1.0 / 32.0);
            let compute = compute_flops(art.model.param_count, cfg.tokens());
            println!(
                "{:<6} {:<6} {:>10} {:>12.3e} {:>10.4} {:>10.4} {:>8.1}",
                size, variant, art.model.param_count, compute, val_loss, trailing, out.train_secs
            );
            summary_rows.push(obj(vec![
                ("size", s(size)),
                ("variant", s(variant)),
                ("params", num(art.model.param_count as f64)),
                ("compute", num(compute)),
                ("val_loss", num(val_loss)),
                ("trailing_loss", num(trailing)),
                (
                    "positionwise",
                    arr(out.eval.per_position().iter().map(|&x| num(x)).collect()),
                ),
                ("train_secs", num(out.train_secs)),
            ]));
        }
    }
    dir.write_json("summary.json", &Json::Arr(summary_rows))?;
    println!("-> runs/scaling/{tag}/summary.json");
    Ok(())
}
