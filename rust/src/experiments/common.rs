//! Shared experiment plumbing: train-then-eval runs over corpus data.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::StageSchedule;
use crate::data::{Corpus, VAL_STREAM_BASE};
use crate::eval::losses::{positionwise_mean, PositionLosses};
use crate::metrics::writer::CsvWriter;
use crate::runtime::{Engine, ModelState};
use crate::train::{LrSchedule, Trainer};

/// Outcome of one train+eval run.
pub struct RunOutcome {
    pub state: ModelState,
    pub train_losses: Vec<f32>,
    pub eval: PositionLosses,
    pub train_secs: f64,
}

/// Train on the synthetic corpus under `schedule`, then evaluate
/// position-wise losses on held-out streams with `eval_artifact`.
pub fn train_and_eval(
    engine: &Engine,
    schedule: StageSchedule,
    eval_artifact: &str,
    cfg: &TrainConfig,
    n_eval_batches: u64,
    mut loss_csv: Option<&mut CsvWriter>,
) -> Result<RunOutcome> {
    let first = schedule.stage_list()[0].artifact.clone();
    let train_art = engine.manifest.get(&first)?;
    let corpus = Corpus::for_vocab(train_art.model.vocab, cfg.seed);
    let (batch, seq) = (train_art.batch, train_art.seq);

    let lr = LrSchedule::new(cfg.base_lr, schedule.total_steps(), cfg.warmup_frac, cfg.min_lr_frac);
    let mut trainer = Trainer::new(engine, schedule, lr, cfg.seed)?;
    let seed = cfg.seed;
    let log_every = cfg.log_every;
    let summary = trainer.run(
        |step| corpus.batch(seed, step, batch, seq),
        |info| {
            if let Some(csv) = loss_csv.as_deref_mut() {
                let _ = csv.row(&[info.step as f64, info.loss as f64, info.lr]);
            }
            if info.step % log_every == 0 {
                eprintln!(
                    "    step {:>5}  loss {:.4}  lr {:.2e}  ({:.2}s/step)  [{}]",
                    info.step, info.loss, info.lr, info.step_secs, info.artifact
                );
            }
        },
    )?;
    if let Some(csv) = loss_csv.as_deref_mut() {
        csv.flush()?;
    }

    let eval_art = engine.manifest.get(eval_artifact)?;
    let (eb, es) = (eval_art.batch, eval_art.seq);
    let eval = positionwise_mean(
        engine,
        eval_artifact,
        &trainer.state.params,
        |i| corpus.batch(seed, VAL_STREAM_BASE + i, eb, es),
        n_eval_batches,
    )?;
    Ok(RunOutcome {
        state: trainer.state,
        train_losses: summary.losses,
        eval,
        train_secs: summary.total_secs,
    })
}

/// Training compute proxy C = 6 * params * tokens (Chinchilla convention),
/// used as the x-axis of the scaling fits.
pub fn compute_flops(param_count: usize, tokens: u64) -> f64 {
    6.0 * param_count as f64 * tokens as f64
}
