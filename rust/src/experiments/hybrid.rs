//! Fig 5a: MoBA/full hybrid training.
//!
//! Three recipes at matched budget (paper §3.2): (1) MoBA-only, (2) full
//! attention throughout, (3) the hybrid — MoBA for the first 90% of
//! steps, full attention for the last 10%. Because MoBA adds no
//! parameters, the hybrid just swaps the train-step *executable* at the
//! switch point (the stage scheduler) with the optimizer state untouched.
//! Output: position-wise LM loss for all three recipes + the loss series
//! around the switch (checking the paper's "no loss spike" observation).

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::StageSchedule;
use crate::metrics::writer::RunDir;
use crate::runtime::Engine;
use crate::util::json::{arr, num, obj, s, Json};

use super::common::train_and_eval;

pub struct HybridArgs {
    pub steps: u64,
    pub seed: u64,
    pub eval_batches: u64,
    pub moba_frac: f64,
}

impl Default for HybridArgs {
    fn default() -> Self {
        HybridArgs { steps: 150, seed: 42, eval_batches: 4, moba_frac: 0.9 }
    }
}

pub fn run(engine: &Engine, args: &HybridArgs) -> Result<()> {
    let dir = RunDir::create("hybrid")?;
    let moba_train = "hybrid_moba_train";
    let full_train = "hybrid_full_train";
    let art = engine.manifest.get(moba_train)?;
    let cfg = TrainConfig {
        steps: args.steps,
        seed: args.seed,
        batch: art.batch,
        seq: art.seq,
        ..Default::default()
    };

    let recipes: Vec<(&str, StageSchedule)> = vec![
        ("moba", StageSchedule::single(moba_train, args.steps)),
        ("full", StageSchedule::single(full_train, args.steps)),
        (
            "hybrid",
            StageSchedule::hybrid(moba_train, full_train, args.steps, args.moba_frac)?,
        ),
    ];

    println!("== Fig 5a — MoBA/full hybrid training (switch at {:.0}%) ==", args.moba_frac * 100.0);
    println!("{:<8} {:>10} {:>10} {:>12}", "recipe", "val_loss", "trailing", "switch_spike");
    let mut rows = Vec::new();
    for (name, schedule) in recipes {
        let switch_points = schedule.switch_points();
        // evaluate every recipe with the FULL-attention eval graph so the
        // positionwise comparison isolates what training built into the
        // weights (paper evaluates all recipes identically)
        let eval_name = "hybrid_full_eval";
        let mut csv = dir.csv(&format!("{name}_loss.csv"), &["step", "loss", "lr"])?;
        let out = train_and_eval(engine, schedule, eval_name, &cfg, args.eval_batches, Some(&mut csv))?;
        let val_loss = out.eval.mean();
        let trailing = out.eval.trailing(out.eval.sums.len() / 8);

        // loss spike at the switch: |mean(5 after) - mean(5 before)|
        let spike = switch_points
            .first()
            .map(|&sp| {
                let sp = sp as usize;
                let lo = sp.saturating_sub(5);
                let hi = (sp + 5).min(out.train_losses.len());
                if sp > lo && hi > sp {
                    let before: f64 =
                        out.train_losses[lo..sp].iter().map(|&x| x as f64).sum::<f64>()
                            / (sp - lo) as f64;
                    let after: f64 = out.train_losses[sp..hi].iter().map(|&x| x as f64).sum::<f64>()
                        / (hi - sp) as f64;
                    after - before
                } else {
                    0.0
                }
            })
            .unwrap_or(0.0);

        println!("{:<8} {:>10.4} {:>10.4} {:>12.4}", name, val_loss, trailing, spike);
        rows.push(obj(vec![
            ("recipe", s(name)),
            ("val_loss", num(val_loss)),
            ("trailing_loss", num(trailing)),
            ("switch_spike", num(spike)),
            (
                "positionwise",
                arr(out.eval.per_position().iter().map(|&x| num(x)).collect()),
            ),
        ]));
    }
    dir.write_json("summary.json", &Json::Arr(rows))?;
    println!("-> runs/hybrid/summary.json");
    Ok(())
}
