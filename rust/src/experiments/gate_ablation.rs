//! Gate-policy ablation (paper §2.2's expressiveness discussion).
//!
//! The paper argues sliding-window attention and attention-sink are
//! special cases of MoBA with degenerate gates, and that the learned
//! (affinity-based) gate is strictly more expressive. This harness makes
//! that concrete without training: plant a high-affinity KV block at a
//! random historical position (the "relevant memory") and measure how
//! often each gating policy routes the final query to it, at matched
//! sparsity:
//!
//! - `moba`  — affinity top-k (paper Eq. 5-6);
//! - `swa`   — always the most recent k blocks;
//! - `sink`  — first block + most recent k-1 blocks;
//! - `random`— k random causal blocks (floor).
//!
//! MoBA's recall should approach 1 while the static policies scale like
//! k / n_blocks, reproducing the §2.2 claim quantitatively.

use anyhow::Result;

use crate::metrics::writer::RunDir;
use crate::sparse::{AttentionBackend, MobaAttention};
use crate::tensor::Tensor;
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Rng;

pub struct GateAblationArgs {
    pub trials: usize,
    pub seed: u64,
}

impl Default for GateAblationArgs {
    fn default() -> Self {
        GateAblationArgs { trials: 200, seed: 42 }
    }
}

/// One trial: does the policy select the planted block for the last query?
fn trial(rng: &mut Rng, nb: usize, block: usize, topk: usize) -> (bool, bool, bool, bool) {
    let n = nb * block;
    let (h, d) = (1usize, 8usize);
    // background keys ~ N(0,1); planted block's keys biased toward the
    // final query's direction
    let mut k = Tensor::from_vec(
        &[n, h, d],
        (0..n * h * d).map(|_| rng.normal_f32(1.0)).collect(),
    )
    .unwrap();
    let mut q = Tensor::zeros(&[n, h, d]);
    for x in q.data.iter_mut() {
        *x = rng.normal_f32(1.0);
    }
    // plant into a random historical block (not current, not adjacent)
    let cur = nb - 1;
    let target = rng.range(0, cur.saturating_sub(1).max(1));
    let t = n - 1;
    for j in target * block..(target + 1) * block {
        for dd in 0..d {
            // key rows aligned with the final query direction
            k.data[(j * h) * d + dd] = q.data[(t * h) * d + dd] + rng.normal_f32(0.3);
        }
    }

    let backend = MobaAttention::new(h, d, block, topk);
    let gate = backend.gate(&q, &k).expect("moba backend exposes its gate");
    let moba_hit = gate.get(0, t, target);

    // static policies at the same budget (current block + k-1 others)
    let swa_hit = target >= cur.saturating_sub(topk - 1);
    let sink_hit = target == 0 || target >= cur.saturating_sub(topk.saturating_sub(2));
    let mut rand_blocks: Vec<usize> = (0..cur).collect();
    rng.shuffle(&mut rand_blocks);
    let random_hit = rand_blocks[..(topk - 1).min(rand_blocks.len())].contains(&target);
    (moba_hit, swa_hit, sink_hit, random_hit)
}

pub fn run(args: &GateAblationArgs) -> Result<()> {
    let dir = RunDir::create("gate_ablation")?;
    println!("== gate-policy ablation (§2.2): recall of the relevant block ==");
    println!(
        "{:>9} {:>6} {:>8} {:>8} {:>8} {:>8}",
        "n_blocks", "topk", "moba", "window", "sink", "random"
    );
    let mut rows = Vec::new();
    for &(nb, block, topk) in &[(8usize, 32usize, 3usize), (16, 32, 3), (32, 16, 3), (32, 16, 5)] {
        let mut hits = [0usize; 4];
        let mut rng = Rng::new(args.seed ^ ((nb * 31 + topk) as u64));
        for _ in 0..args.trials {
            let (a, b, c, d) = trial(&mut rng, nb, block, topk);
            hits[0] += a as usize;
            hits[1] += b as usize;
            hits[2] += c as usize;
            hits[3] += d as usize;
        }
        let f = |h: usize| h as f64 / args.trials as f64;
        println!(
            "{:>9} {:>6} {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
            nb, topk, f(hits[0]), f(hits[1]), f(hits[2]), f(hits[3])
        );
        rows.push(obj(vec![
            ("n_blocks", num(nb as f64)),
            ("topk", num(topk as f64)),
            ("moba", num(f(hits[0]))),
            ("window", num(f(hits[1]))),
            ("sink", num(f(hits[2]))),
            ("random", num(f(hits[3]))),
            ("policy", s("recall-of-planted-block")),
        ]));
    }
    dir.write_json("summary.json", &Json::Arr(rows))?;
    println!("-> runs/gate_ablation/summary.json");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moba_beats_static_policies() {
        let mut rng = Rng::new(7);
        let (mut moba, mut swa) = (0, 0);
        for _ in 0..50 {
            let (a, b, _, _) = trial(&mut rng, 16, 16, 3);
            moba += a as usize;
            swa += b as usize;
        }
        assert!(moba > swa, "moba {moba} vs window {swa}");
        assert!(moba >= 45, "moba recall too low: {moba}/50");
    }
}
