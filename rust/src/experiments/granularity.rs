//! Fig 4: fine-grained block segmentation ablation.
//!
//! The paper trains a 1.5B model at 32K context and varies block
//! granularity {8,16,32,64,128 blocks} at pinned 75% sparsity. We run
//! the scaled analogue (s2 at 1024 ctx, same block counts, same
//! sparsity) and report validation LM loss per granularity — the claim
//! under test is that finer segmentation improves loss by ~1e-2 between
//! the coarsest and finest settings.

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::StageSchedule;
use crate::metrics::writer::RunDir;
use crate::runtime::Engine;
use crate::util::json::{num, obj, s, Json};

use super::common::train_and_eval;

pub struct GranularityArgs {
    pub steps: u64,
    pub seed: u64,
    pub eval_batches: u64,
}

impl Default for GranularityArgs {
    fn default() -> Self {
        GranularityArgs { steps: 120, seed: 42, eval_batches: 4 }
    }
}

pub const BLOCK_COUNTS: [usize; 5] = [8, 16, 32, 64, 128];

pub fn run(engine: &Engine, args: &GranularityArgs) -> Result<()> {
    let dir = RunDir::create("granularity")?;
    println!("== Fig 4 — fine-grained block segmentation (75% sparsity) ==");
    println!(
        "{:<10} {:>10} {:>6} {:>10} {:>8}",
        "n_blocks", "block_size", "topk", "val_loss", "secs"
    );
    let mut rows = Vec::new();
    for nb in BLOCK_COUNTS {
        let train_name = format!("gran_nb{nb:03}_train");
        let eval_name = format!("gran_nb{nb:03}_eval");
        let art = engine.manifest.get(&train_name)?;
        let cfg = TrainConfig {
            steps: args.steps,
            seed: args.seed,
            batch: art.batch,
            seq: art.seq,
            ..Default::default()
        };
        let mut csv = dir.csv(&format!("nb{nb:03}_loss.csv"), &["step", "loss", "lr"])?;
        let schedule = StageSchedule::single(&train_name, cfg.steps);
        let out = train_and_eval(engine, schedule, &eval_name, &cfg, args.eval_batches, Some(&mut csv))?;
        let val_loss = out.eval.mean();
        println!(
            "{:<10} {:>10} {:>6} {:>10.4} {:>8.1}",
            nb, art.model.block_size, art.model.topk, val_loss, out.train_secs
        );
        rows.push(obj(vec![
            ("n_blocks", num(nb as f64)),
            ("block_size", num(art.model.block_size as f64)),
            ("topk", num(art.model.topk as f64)),
            ("val_loss", num(val_loss)),
            ("variant", s("moba")),
        ]));
    }
    dir.write_json("summary.json", &Json::Arr(rows))?;
    println!("-> runs/granularity/summary.json");
    Ok(())
}
