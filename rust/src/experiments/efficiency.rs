//! Fig 2a/2b: MoBA vs FlashAttention efficiency.
//!
//! Two evidence layers (DESIGN.md §4):
//!
//! 1. **Cost model at paper scale** — the calibrated roofline model
//!    sweeps 8K→1M (Fig 2a, block 4096 top-12, the paper's 1M-model
//!    setting) and 8K→10M at fixed 64 blocks/top-3 (Fig 2b), on an
//!    A100-class profile. The claim under test is the *shape*: a
//!    crossover after which MoBA wins, growing to ~6.5x at 1M and ~16x
//!    at 10M.
//! 2. **Measured CPU kernels at small scale** — the pure-Rust MoBA and
//!    full-attention kernels are timed head-to-head (256→4096 tokens),
//!    verifying the crossover direction empirically and validating the
//!    cost model's CPU-profile predictions against wall clock.

use anyhow::Result;
use std::time::Instant;

use crate::attn_sim::{
    self,
    profiles::{a100_like, calibrate_cpu},
    AttnShape,
};
use crate::metrics::writer::RunDir;
use crate::sparse::{
    default_workers, AttentionBackend, FullAttention, FusedMobaAttention, MobaAttention,
};
use crate::tensor::Tensor;
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Rng;

pub struct EfficiencyArgs {
    /// max measured length for the CPU comparison
    pub measure_max: usize,
    pub seed: u64,
}

impl Default for EfficiencyArgs {
    fn default() -> Self {
        EfficiencyArgs { measure_max: 4096, seed: 42 }
    }
}

fn rand_qkv(n: usize, h: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
    let mut rng = Rng::new(seed);
    let mut mk = || {
        Tensor::from_vec(&[n, h, d], (0..n * h * d).map(|_| rng.normal_f32(1.0)).collect())
            .unwrap()
    };
    (mk(), mk(), mk())
}

pub fn run(args: &EfficiencyArgs) -> Result<()> {
    let dir = RunDir::create("efficiency")?;
    let mut rows_json = Vec::new();

    // ---- Fig 2a: cost model, 1M-model setting --------------------------
    let dev = a100_like();
    println!("== Fig 2a — MoBA vs FlashAttention, 1M-model setting (cost model, {}) ==", dev.name);
    println!("block 4096, top-12 (paper §3.3); H=32, D=128");
    println!("{:>10} {:>12} {:>12} {:>9} {:>10}", "N", "flash_ms", "moba_ms", "speedup", "sparsity");
    let lengths_2a: Vec<usize> =
        [8, 16, 32, 64, 128, 256, 512, 1024].iter().map(|k| k * 1024).collect();
    for r in attn_sim::sweep_fixed_block(&lengths_2a, 4096, 12, 32, 128, &dev) {
        println!(
            "{:>10} {:>12.2} {:>12.2} {:>9.2} {:>9.1}%",
            r.n, r.full_ms, r.moba_ms, r.speedup, r.sparsity * 100.0
        );
        rows_json.push(obj(vec![
            ("figure", s("2a")),
            ("n", num(r.n as f64)),
            ("full_ms", num(r.full_ms)),
            ("moba_ms", num(r.moba_ms)),
            ("speedup", num(r.speedup)),
        ]));
    }

    // ---- Fig 2b: fixed 64 blocks, top-3, to 10M ------------------------
    println!("\n== Fig 2b — fixed 95.31% sparsity (64 blocks, top-3) to 10M ==");
    println!("{:>10} {:>12} {:>12} {:>9}", "N", "flash_ms", "moba_ms", "speedup");
    let lengths_2b: Vec<usize> = [
        8 * 1024, 32 * 1024, 128 * 1024, 512 * 1024, 1 << 20, 2 << 20, 5 << 20, 10 << 20,
    ]
    .to_vec();
    for r in attn_sim::sweep_fixed_nblocks(&lengths_2b, 64, 3, 32, 128, &dev) {
        println!("{:>10} {:>12.2} {:>12.2} {:>9.2}", r.n, r.full_ms, r.moba_ms, r.speedup);
        rows_json.push(obj(vec![
            ("figure", s("2b")),
            ("n", num(r.n as f64)),
            ("full_ms", num(r.full_ms)),
            ("moba_ms", num(r.moba_ms)),
            ("speedup", num(r.speedup)),
        ]));
    }

    // ---- measured CPU kernels -------------------------------------------
    let ncpu = default_workers();
    println!("\n== measured CPU kernels (pure-Rust, H=2 D=32, block 64 top-3) ==");
    println!("fused = single-pass gate+attend; _mt = {ncpu} workers (bit-identical outputs)");
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>12} {:>9} {:>12} {:>12}",
        "N", "full_ms", "moba_ms", "fused_ms", "fused_mt_ms", "speedup", "pred_full", "pred_moba"
    );
    let cpu = calibrate_cpu(args.seed);
    let (h, d, block, topk) = (2usize, 32usize, 64usize, 3usize);
    // measured through the backend trait — the same objects the serving
    // stack dispatches on, so these numbers price the deployed path
    let full_backend = FullAttention::new(h, d);
    let moba_backend = MobaAttention::new(h, d, block, topk);
    let fused_backend = FusedMobaAttention::new(h, d, block, topk);
    let fused_mt_backend = FusedMobaAttention::new(h, d, block, topk).with_workers(ncpu);
    let mut n = 256usize;
    while n <= args.measure_max {
        let (q, k, v) = rand_qkv(n, h, d, args.seed ^ n as u64);
        let reps = if n <= 1024 { 3 } else { 1 };
        let time_ms = |b: &dyn AttentionBackend| {
            let t0 = Instant::now();
            for _ in 0..reps {
                let _ = b.forward(&q, &k, &v);
            }
            t0.elapsed().as_secs_f64() * 1e3 / reps as f64
        };
        let full_ms = time_ms(&full_backend);
        let moba_ms = time_ms(&moba_backend);
        let fused_ms = time_ms(&fused_backend);
        let fused_mt_ms = time_ms(&fused_mt_backend);
        let shape = AttnShape::new(n, h, d);
        let pred_full = attn_sim::full_time(shape, &cpu) * 1e3;
        let pred_moba = attn_sim::moba_time(shape, block, topk, &cpu) * 1e3;
        println!(
            "{:>8} {:>12.2} {:>12.2} {:>12.2} {:>12.2} {:>9.2} {:>12.2} {:>12.2}",
            n,
            full_ms,
            moba_ms,
            fused_ms,
            fused_mt_ms,
            full_ms / moba_ms,
            pred_full,
            pred_moba
        );
        rows_json.push(obj(vec![
            ("figure", s("2_measured")),
            ("n", num(n as f64)),
            ("full_ms", num(full_ms)),
            ("moba_ms", num(moba_ms)),
            ("fused_ms", num(fused_ms)),
            ("fused_mt_ms", num(fused_mt_ms)),
            ("workers_mt", num(ncpu as f64)),
            ("speedup", num(full_ms / moba_ms)),
            ("pred_full_ms", num(pred_full)),
            ("pred_moba_ms", num(pred_moba)),
        ]));
        n *= 2;
    }
    println!("\ncpu profile: {:.2} GFLOP/s sustained", cpu.flops_per_s / 1e9);

    dir.write_json("fig2.json", &Json::Arr(rows_json))?;
    println!("-> runs/efficiency/fig2.json");
    Ok(())
}
