//! Table 2: downstream parity of MoBA vs full attention at matched
//! training (scaled per DESIGN.md §4 — the claim under test is parity,
//! measured on tasks a tiny model can express).
//!
//! Trains the MoBA and full-attention twins of the needle-stage-0 model
//! on identical mixed data (corpus + needles), then runs the evaluation
//! suite (held-out PPL, needle retrieval, copy span, multi-query recall)
//! on both and prints the side-by-side table.

use anyhow::Result;

use crate::coordinator::StageSchedule;
use crate::data::{Corpus, NeedleGen};
use crate::eval::suite::run_suite;
use crate::metrics::writer::RunDir;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::train::{LrSchedule, Trainer};
use crate::util::json::{num, obj, s, Json};

pub struct Table2Args {
    pub steps: u64,
    pub seed: u64,
    pub eval_batches: u64,
}

impl Default for Table2Args {
    fn default() -> Self {
        Table2Args { steps: 200, seed: 42, eval_batches: 3 }
    }
}

fn train_twin(
    engine: &Engine,
    train_name: &str,
    args: &Table2Args,
) -> Result<Vec<Tensor>> {
    let art = engine.manifest.get(train_name)?;
    let (batch, seq) = (art.batch, art.seq);
    let corpus = Corpus::for_vocab(art.model.vocab, args.seed);
    let needles = NeedleGen::new(args.seed);
    let lr = LrSchedule::new(2e-3, args.steps, 0.05, 0.1);
    let mut trainer = Trainer::new(engine, StageSchedule::single(train_name, args.steps), lr, args.seed)?;
    let seed = args.seed;
    trainer.run(
        |step| {
            // 2:1 mixture of LM corpus and needle batches
            if step % 3 == 2 {
                needles.train_batch(seed, step, batch, seq, 0.1)
            } else {
                corpus.batch(seed, step, batch, seq)
            }
        },
        |info| {
            if info.step % 50 == 0 {
                eprintln!("    [{train_name}] step {:>4} loss {:.4}", info.step, info.loss);
            }
        },
    )?;
    Ok(trainer.state.params)
}

pub fn run(engine: &Engine, args: &Table2Args) -> Result<()> {
    let dir = RunDir::create("table2")?;
    println!("== Table 2 — MoBA vs full attention, downstream parity ==");

    eprintln!("  training MoBA twin...");
    let moba_params = train_twin(engine, "needle_s0_train", args)?;
    eprintln!("  training full twin...");
    let full_params = train_twin(engine, "needle_s0_full_train", args)?;

    // eval artifacts: sft_full* share the s2 geometry at seq 512, so reuse
    // the needle logits graphs for scoring and the scaling eval for PPL
    let moba_suite = run_suite(
        engine,
        "scaling_s2_moba_eval",
        "needle_s0_logits",
        &moba_params,
        args.seed,
        args.eval_batches,
    )?;
    let full_suite = run_suite(
        engine,
        "scaling_s2_full_eval",
        "needle_s0_full_logits",
        &full_params,
        args.seed,
        args.eval_batches,
    )?;

    println!("\n{:<20} {:>14} {:>14}", "Benchmark", "MoBA", "Full");
    let mut rows = Vec::new();
    for ((name, mv), (_, fv)) in moba_suite.rows().iter().zip(full_suite.rows().iter()) {
        println!("{:<20} {:>14.4} {:>14.4}", name, mv, fv);
        rows.push(obj(vec![
            ("benchmark", s(name)),
            ("moba", num(*mv)),
            ("full", num(*fv)),
        ]));
    }
    dir.write_json("summary.json", &Json::Arr(rows))?;
    println!("-> runs/table2/summary.json");
    Ok(())
}
