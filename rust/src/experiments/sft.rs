//! Fig 5b/5c: layer-wise hybrid for supervised fine-tuning.
//!
//! The paper observes MoBA underperforming during SFT (prompt tokens are
//! loss-masked → sparse gradients through sparse attention) and fixes it
//! by switching the *last k* layers to full attention. We pretrain one
//! base model, then SFT it under layer-wise hybrids with k ∈
//! {0,1,2,3,all} full layers, reporting SFT LM loss (5b) and trailing
//! loss (5c) as functions of k.

use anyhow::Result;

use crate::coordinator::StageSchedule;
use crate::data::{Corpus, SftGen, VAL_STREAM_BASE};
use crate::eval::losses::positionwise_mean;
use crate::metrics::writer::RunDir;
use crate::runtime::Engine;
use crate::train::{LrSchedule, Trainer};
use crate::util::json::{num, obj, Json};

pub struct SftArgs {
    pub pretrain_steps: u64,
    pub sft_steps: u64,
    pub seed: u64,
    pub eval_batches: u64,
    /// number of trailing positions for Fig 5c (paper: last 2K of 32K)
    pub trailing_frac: f64,
}

impl Default for SftArgs {
    fn default() -> Self {
        SftArgs {
            pretrain_steps: 150,
            sft_steps: 60,
            seed: 42,
            eval_batches: 4,
            trailing_frac: 1.0 / 16.0,
        }
    }
}

/// full-last-k values matching the artifacts lowered by aot.py
pub const FULL_LAST: [usize; 5] = [0, 1, 2, 3, 5];

pub fn run(engine: &Engine, args: &SftArgs) -> Result<()> {
    let dir = RunDir::create("sft")?;
    println!("== Fig 5b/5c — layer-wise hybrid SFT ==");

    // ---- shared pretraining (pure MoBA, matching geometry) --------------
    let base_train = "sft_full0_train"; // all-MoBA artifact
    let art = engine.manifest.get(base_train)?;
    let corpus = Corpus::for_vocab(art.model.vocab, args.seed);
    let (batch, seq) = (art.batch, art.seq);
    eprintln!("  pretraining base model ({} steps)...", args.pretrain_steps);
    let lr = LrSchedule::new(3e-3, args.pretrain_steps, 0.05, 0.1);
    let mut trainer = Trainer::new(
        engine,
        StageSchedule::single(base_train, args.pretrain_steps),
        lr,
        args.seed,
    )?;
    let seed = args.seed;
    trainer.run(
        |step| corpus.batch(seed, step, batch, seq),
        |info| {
            if info.step % 25 == 0 {
                eprintln!("    pretrain step {:>4} loss {:.4}", info.step, info.loss);
            }
        },
    )?;
    let base_state = trainer.state.clone();

    // ---- SFT under each layer-wise hybrid --------------------------------
    let sft_gen = SftGen::new(args.seed ^ 0xAB);
    println!(
        "{:<12} {:>12} {:>12} {:>12}",
        "full_layers", "sft_loss", "eval_loss", "trailing"
    );
    let mut rows = Vec::new();
    for k in FULL_LAST {
        let train_name = format!("sft_full{k}_train");
        let eval_name = format!("sft_full{k}_eval");
        let lr = LrSchedule::new(1e-3, args.sft_steps, 0.1, 0.1);
        let mut t = Trainer::with_state(
            engine,
            base_state.clone(),
            StageSchedule::single(&train_name, args.sft_steps),
            lr,
        );
        let mut csv = dir.csv(&format!("sft_full{k}_loss.csv"), &["step", "loss", "lr"])?;
        let summary = t.run(
            |step| sft_gen.batch(seed, step, batch, seq),
            |info| {
                let _ = csv.row(&[info.step as f64, info.loss as f64, info.lr]);
            },
        )?;
        csv.flush()?;

        // held-out SFT eval (masked like training: response-only loss)
        let eval = positionwise_mean(
            engine,
            &eval_name,
            &t.state.params,
            |i| sft_gen.batch(seed, VAL_STREAM_BASE + i, batch, seq),
            args.eval_batches,
        )?;
        let eval_loss = eval.mean();
        let trailing = eval.trailing(((seq as f64) * args.trailing_frac) as usize);
        println!(
            "{:<12} {:>12.4} {:>12.4} {:>12.4}",
            k, summary.mean_last_quarter, eval_loss, trailing
        );
        rows.push(obj(vec![
            ("full_layers", num(k as f64)),
            ("sft_train_loss", num(summary.mean_last_quarter)),
            ("sft_eval_loss", num(eval_loss)),
            ("trailing_loss", num(trailing)),
        ]));
    }
    dir.write_json("summary.json", &Json::Arr(rows))?;
    println!("-> runs/sft/summary.json");
    Ok(())
}
