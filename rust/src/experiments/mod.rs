//! Experiment harnesses: one module per paper table/figure
//! (see DESIGN.md §5 for the full index).
//!
//! | module        | reproduces                                   |
//! |---------------|----------------------------------------------|
//! | `efficiency`  | Fig 2a/2b (cost model + measured CPU kernels)|
//! | `scaling`     | Fig 3a (LM loss), Fig 3b (trailing loss)     |
//! | `fits`        | Fig 3c + Table 3 (power-law fits)            |
//! | `granularity` | Fig 4 (block segmentation ablation)          |
//! | `hybrid`      | Fig 5a (MoBA/full hybrid training)           |
//! | `sft`         | Fig 5b/5c (layer-wise hybrid SFT)            |
//! | `needle`      | Fig 6 recipe + Fig 7 heatmap                 |
//! | `table2`      | Table 2 (downstream parity suite)            |
//!
//! Every harness writes CSV + JSON into `runs/<name>/` and prints a
//! paper-shaped table to stdout. Harnesses that train or evaluate through
//! AOT artifacts require the `xla` feature; `efficiency`, `fits` and
//! `gate_ablation` run on the pure-Rust backend stack alone.

#[cfg(feature = "xla")]
pub mod common;
pub mod efficiency;
pub mod fits;
pub mod gate_ablation;
#[cfg(feature = "xla")]
pub mod granularity;
#[cfg(feature = "xla")]
pub mod hybrid;
#[cfg(feature = "xla")]
pub mod needle;
#[cfg(feature = "xla")]
pub mod scaling;
#[cfg(feature = "xla")]
pub mod sft;
#[cfg(feature = "xla")]
pub mod table2;
