//! Fig 3c + Table 3: power-law fits over the scaling runs.
//!
//! Consumes `runs/scaling/*/summary.json` (produced by the `scaling`
//! harness), fits `L = a * C^b` per attention variant for the overall
//! validation loss (Fig 3c) and per position bucket (Table 3), and
//! prints the paper-shaped table of `a * C^b` entries for MoBA vs full.

use anyhow::{bail, Context, Result};

use crate::metrics::fit::fit_power_law;
use crate::metrics::writer::RunDir;
use crate::util::json::{num, obj, s, Json};

struct RunRow {
    variant: String,
    compute: f64,
    val_loss: f64,
    trailing: f64,
    positionwise: Vec<f64>,
}

fn load_summary(path: &std::path::Path) -> Result<Vec<RunRow>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {} — run `repro exp scaling` first", path.display()))?;
    let j = Json::parse(&text)?;
    let mut rows = Vec::new();
    for r in j.arr()? {
        rows.push(RunRow {
            variant: r.get("variant")?.str()?.to_string(),
            compute: r.get("compute")?.num()?,
            val_loss: r.get("val_loss")?.num()?,
            trailing: r.get("trailing_loss")?.num()?,
            positionwise: r
                .get("positionwise")?
                .arr()?
                .iter()
                .map(|x| x.num())
                .collect::<Result<_>>()?,
        });
    }
    Ok(rows)
}

fn fit_variant(rows: &[RunRow], variant: &str, y: impl Fn(&RunRow) -> f64) -> Option<(f64, f64, f64)> {
    let xs: Vec<f64> = rows.iter().filter(|r| r.variant == variant).map(|r| r.compute).collect();
    let ys: Vec<f64> = rows.iter().filter(|r| r.variant == variant).map(&y).collect();
    fit_power_law(&xs, &ys).map(|f| (f.a, f.b, f.r2))
}

pub fn run() -> Result<()> {
    let runs_base = std::env::var("MOBA_RUNS").unwrap_or_else(|_| "runs".into());
    let dir = RunDir::create("fits")?;
    let mut out_rows = Vec::new();

    // ---- Fig 3c: overall validation-loss scaling curve ----------------
    let short = std::path::Path::new(&runs_base).join("scaling/fig3a/summary.json");
    if short.exists() {
        let rows = load_summary(&short)?;
        println!("== Fig 3c — fitted scaling curves (seq 512 runs) ==");
        println!("{:<8} {:>26} {:>8}", "variant", "fit  L = a * C^b", "R^2");
        for v in ["moba", "full"] {
            if let Some((a, b, r2)) = fit_variant(&rows, v, |r| r.val_loss) {
                println!("{:<8} {:>14.3} * C^{:<8.4} {:>8.3}", v, a, b, r2);
                out_rows.push(obj(vec![
                    ("figure", s("3c")),
                    ("variant", s(v)),
                    ("a", num(a)),
                    ("b", num(b)),
                    ("r2", num(r2)),
                ]));
            }
        }
    } else {
        println!("(skipping Fig 3c: {} not found)", short.display());
    }

    // ---- Table 3: position-bucket fits over the long-context runs ------
    let long = std::path::Path::new(&runs_base).join("scaling/fig3b_long/summary.json");
    if long.exists() {
        let rows = load_summary(&long)?;
        let n_pos = rows
            .first()
            .map(|r| r.positionwise.len())
            .unwrap_or(0);
        if n_pos == 0 {
            bail!("summary has no positionwise data");
        }
        let n_buckets = 16; // paper: 16 x 2K buckets over 32K; scaled: 16 x 128 over 2048
        let w = n_pos / n_buckets;
        println!("\n== Table 3 — loss scaling with different positions ==");
        println!(
            "{:<16} {:>24} {:>24}",
            "position range", "MoBA  a * C^b", "Full  a * C^b"
        );
        for bidx in 0..n_buckets {
            let lo = bidx * w;
            let hi = ((bidx + 1) * w).min(n_pos);
            let bucket_mean = |r: &RunRow| -> f64 {
                let xs = &r.positionwise[lo..hi];
                xs.iter().sum::<f64>() / xs.len().max(1) as f64
            };
            let fm = fit_variant(&rows, "moba", bucket_mean);
            let ff = fit_variant(&rows, "full", bucket_mean);
            let fmt = |f: Option<(f64, f64, f64)>| match f {
                Some((a, b, _)) => format!("{a:.3} * C^{b:.3}"),
                None => "-".into(),
            };
            println!("{:<16} {:>24} {:>24}", format!("{lo} - {hi}"), fmt(fm), fmt(ff));
            if let (Some((ma, mb, mr)), Some((fa, fb, fr))) = (fm, ff) {
                out_rows.push(obj(vec![
                    ("figure", s("table3")),
                    ("bucket_lo", num(lo as f64)),
                    ("bucket_hi", num(hi as f64)),
                    ("moba_a", num(ma)),
                    ("moba_b", num(mb)),
                    ("moba_r2", num(mr)),
                    ("full_a", num(fa)),
                    ("full_b", num(fb)),
                    ("full_r2", num(fr)),
                ]));
            }
        }
        // trailing-loss fits (the Fig 3b companion claim)
        println!("\ntrailing-loss fits:");
        for v in ["moba", "full"] {
            if let Some((a, b, r2)) = fit_variant(&rows, v, |r| r.trailing) {
                println!("  {v:<6} {a:.3} * C^{b:.4}   (R^2 {r2:.3})");
            }
        }
    } else {
        println!("(skipping Table 3: {} not found)", long.display());
    }

    dir.write_json("fits.json", &Json::Arr(out_rows))?;
    println!("-> runs/fits/fits.json");
    Ok(())
}
