//! Multi-core execution for the attention stack: a head×query-tile work
//! partitioner over scoped threads (no external thread-pool dependency).
//!
//! Determinism contract: parallelism NEVER changes results. Work is
//! partitioned at (head, query)-row granularity — each output row is
//! computed by exactly one thread with exactly the arithmetic the
//! single-threaded kernel uses, so outputs are bit-identical for every
//! worker count (`tests/thread_invariance.rs` pins this). Threads write
//! disjoint contiguous output ranges; no locks, no atomics, no sharing.
//!
//! Worker counts resolve through [`default_workers`]: the `MOBA_WORKERS`
//! environment variable if set, else `std::thread::available_parallelism`.
//! Passing `workers <= 1` (or having fewer slots than workers would
//! justify) runs inline on the calling thread with zero spawn overhead.

use std::ops::Range;

/// Resolved default worker count: `MOBA_WORKERS` env override if set and
/// positive, else the machine's available parallelism, else 1.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("MOBA_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `0..total` into at most `parts` contiguous, near-equal,
/// non-empty ranges (the first `total % parts` ranges get one extra
/// item). Deterministic for a given (total, parts).
pub fn split_ranges(total: usize, parts: usize) -> Vec<Range<usize>> {
    if total == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, total);
    let base = total / parts;
    let extra = total % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Partition `out` into `out.len() / slot_width` fixed-width slots and
/// apply `work(scratch, slot_index, slot)` to every slot, spreading
/// contiguous slot ranges over `workers` scoped threads. `init` builds
/// one scratch value per worker, so kernels can reuse accumulators and
/// score buffers across the queries of their tile instead of allocating
/// per row.
///
/// For a `[N, H, D]` attention output, `slot_width = D` makes slot `i`
/// the (head, query) row `(t, hh) = (i / H, i % H)` — range boundaries
/// can cut between the heads of one query, which is exactly the
/// head×query-tile partitioning the kernels want.
pub fn for_each_slot<S, I, F>(out: &mut [f32], slot_width: usize, workers: usize, init: I, work: F)
where
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &mut [f32]) + Sync,
{
    assert!(slot_width > 0, "slot_width must be positive");
    assert_eq!(out.len() % slot_width, 0, "output not a whole number of slots");
    let total = out.len() / slot_width;
    if total == 0 {
        return;
    }
    if workers.min(total) <= 1 {
        let mut scratch = init();
        for (i, slot) in out.chunks_exact_mut(slot_width).enumerate() {
            work(&mut scratch, i, slot);
        }
        return;
    }
    let ranges = split_ranges(total, workers);
    std::thread::scope(|scope| {
        let mut rest = out;
        for range in ranges {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(range.len() * slot_width);
            rest = tail;
            let (init, work) = (&init, &work);
            scope.spawn(move || {
                let mut scratch = init();
                for (j, slot) in chunk.chunks_exact_mut(slot_width).enumerate() {
                    work(&mut scratch, range.start + j, slot);
                }
            });
        }
    });
}

/// `(0..n).map(f)` with the index range spread over `workers` scoped
/// threads. Results come back in index order regardless of which thread
/// produced them or when it finished.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    if workers.min(n) <= 1 {
        return (0..n).map(f).collect();
    }
    let ranges = split_ranges(n, workers);
    std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|range| {
                let f = &f;
                scope.spawn(move || range.map(f).collect::<Vec<T>>())
            })
            .collect();
        let mut out = Vec::with_capacity(n);
        for h in handles {
            out.extend(h.join().expect("parallel_map worker panicked"));
        }
        out
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_cover_exactly() {
        for total in [0usize, 1, 2, 7, 8, 100] {
            for parts in [1usize, 2, 3, 8, 200] {
                let ranges = split_ranges(total, parts);
                assert!(ranges.len() <= parts.max(1));
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next, "total={total} parts={parts}");
                    assert!(r.end > r.start, "empty range");
                    next = r.end;
                }
                assert_eq!(next, total, "total={total} parts={parts}");
            }
        }
    }

    #[test]
    fn split_ranges_balanced() {
        let ranges = split_ranges(10, 4);
        let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
        assert_eq!(lens, vec![3, 3, 2, 2]);
    }

    #[test]
    fn for_each_slot_matches_serial_for_any_worker_count() {
        let n_slots = 37;
        let width = 3;
        let run = |workers: usize| {
            let mut out = vec![0.0f32; n_slots * width];
            for_each_slot(
                &mut out,
                width,
                workers,
                || 0usize, // scratch: per-worker call counter
                |calls, i, slot| {
                    *calls += 1;
                    for (d, x) in slot.iter_mut().enumerate() {
                        *x = (i * width + d) as f32 * 0.5;
                    }
                },
            );
            out
        };
        let serial = run(1);
        for workers in [2usize, 3, 8, 64] {
            assert_eq!(run(workers), serial, "workers={workers}");
        }
    }

    #[test]
    fn for_each_slot_empty_is_noop() {
        let mut out: Vec<f32> = Vec::new();
        for_each_slot(&mut out, 4, 8, || (), |_, _, _| panic!("no slots"));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let serial: Vec<usize> = (0..23).map(|i| i * i).collect();
        for workers in [1usize, 2, 5, 23, 100] {
            assert_eq!(parallel_map(23, workers, |i| i * i), serial, "workers={workers}");
        }
        assert!(parallel_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn default_workers_positive() {
        assert!(default_workers() >= 1);
    }
}
