//! Multi-core execution for the attention stack: a head×query-tile work
//! partitioner over a persistent kernel thread pool (no external
//! thread-pool dependency).
//!
//! Determinism contract: parallelism NEVER changes results. Work is
//! partitioned at (head, query)-row granularity — each output row is
//! computed by exactly one task with exactly the arithmetic the
//! single-threaded kernel uses, so outputs are bit-identical for every
//! worker count (`tests/thread_invariance.rs` pins this). Tasks write
//! disjoint contiguous output ranges; the only synchronization is the
//! completion latch at the end of each call.
//!
//! Execution model: a process-wide pool of named `moba-kernel-{i}`
//! threads is spawned lazily on first use and reused for every
//! subsequent prefill/batch call — the per-call cost is pushing closures
//! onto a queue instead of `thread::scope` spawn+join churn. The caller
//! participates too (it drains the same queue while waiting), so a call
//! with `workers = W` gets up to `W` lanes even when the pool is busy or
//! smaller. The PARTITIONING is chosen by `workers` alone — never by
//! pool occupancy — so which thread runs a task can vary, but what each
//! task computes cannot.
//!
//! Worker counts resolve through [`default_workers`]: the `MOBA_WORKERS`
//! environment variable if set, else `std::thread::available_parallelism`.
//! Passing `workers <= 1` (or having fewer slots than workers would
//! justify) runs inline on the calling thread with zero dispatch
//! overhead.

use std::collections::VecDeque;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

use crate::util::sync;

/// Resolved default worker count: `MOBA_WORKERS` env override if set and
/// positive, else the machine's available parallelism, else 1. Lenient
/// by design (library callers always get a usable count); binaries that
/// want a loud failure on a typo'd override call [`workers_from_env`]
/// first.
pub fn default_workers() -> usize {
    if let Ok(Some(n)) = workers_from_env() {
        return n;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Strict `MOBA_WORKERS` parse: `Ok(None)` when unset, `Ok(Some(n))` for
/// a positive integer, `Err` (carrying the offending text) otherwise —
/// so `repro serve` and the demo can reject `MOBA_WORKERS=lots` with a
/// friendly error instead of silently falling back to all cores.
pub fn workers_from_env() -> Result<Option<usize>, String> {
    match std::env::var("MOBA_WORKERS") {
        Err(_) => Ok(None),
        Ok(v) => parse_workers(&v).map(Some),
    }
}

fn parse_workers(v: &str) -> Result<usize, String> {
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Ok(n),
        _ => Err(format!("MOBA_WORKERS must be a positive integer, got {v:?}")),
    }
}

/// Split `0..total` into at most `parts` contiguous, near-equal,
/// non-empty ranges (the first `total % parts` ranges get one extra
/// item). Deterministic for a given (total, parts).
pub fn split_ranges(total: usize, parts: usize) -> Vec<Range<usize>> {
    if total == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, total);
    let base = total / parts;
    let extra = total % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

// ---------------------------------------------------------------------------
// persistent kernel pool
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolShared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
}

struct KernelPool {
    shared: &'static PoolShared,
}

/// Completion latch for one `run_scoped` call: counts outstanding tasks
/// down to zero and remembers whether any of them panicked (the panic is
/// re-raised on the caller, preserving the scoped-thread behavior).
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { remaining: Mutex::new(n), cv: Condvar::new(), panicked: AtomicBool::new(false) }
    }

    fn task_done(&self) {
        // poison-resistant: the count must reach zero even if some task
        // panicked between lock acquisitions, or `wait` deadlocks
        let mut left = sync::lock(&self.remaining);
        *left -= 1;
        if *left == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut left = sync::lock(&self.remaining);
        while *left > 0 {
            left = sync::wait(&self.cv, left);
        }
    }
}

fn kernel_pool() -> &'static KernelPool {
    static POOL: OnceLock<KernelPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let shared: &'static PoolShared = Box::leak(Box::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
        }));
        // enough lanes that caller + pool cover a typical `workers`
        // request even on small machines; the caller always helps, so
        // the pool can be one short of the largest worker count
        let threads =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).max(4) - 1;
        for i in 0..threads {
            std::thread::Builder::new()
                .name(format!("moba-kernel-{i}"))
                .spawn(move || loop {
                    let job = {
                        let mut q = sync::lock(&shared.queue);
                        loop {
                            if let Some(job) = q.pop_front() {
                                break job;
                            }
                            q = sync::wait(&shared.cv, q);
                        }
                    };
                    job();
                })
                .expect("spawn kernel pool thread");
        }
        KernelPool { shared }
    })
}

/// Run `tasks` to completion across the kernel pool plus the calling
/// thread. Blocks until every task has finished; if any task panicked,
/// panics on the caller.
///
/// SAFETY of the lifetime erasure: tasks may borrow from the caller's
/// stack (`'a`), and pool threads are `'static` — sound because this
/// function does not return until the latch counts every task done, so
/// no borrow outlives the frame it points into. The panic flag (rather
/// than unwinding across the pool) keeps a panicking task from poisoning
/// the queue for unrelated callers.
fn run_scoped<'a>(tasks: Vec<Box<dyn FnOnce() + Send + 'a>>) {
    if tasks.is_empty() {
        return;
    }
    let pool = kernel_pool();
    let latch = Latch::new(tasks.len());
    let latch_ref: &Latch = &latch;
    {
        let mut q = sync::lock(&pool.shared.queue);
        for task in tasks {
            // erase 'a -> 'static; see SAFETY above
            let task: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Job>(task)
            };
            let latch: &'static Latch = unsafe { std::mem::transmute(latch_ref) };
            q.push_back(Box::new(move || {
                if catch_unwind(AssertUnwindSafe(task)).is_err() {
                    latch.panicked.store(true, Ordering::SeqCst);
                }
                latch.task_done();
            }));
        }
        pool.shared.cv.notify_all();
    }
    // caller helps: drain whatever is queued (ours or another caller's)
    // until the queue is dry, then wait out our stragglers
    loop {
        let job = sync::lock(&pool.shared.queue).pop_front();
        match job {
            Some(job) => job(),
            None => break,
        }
    }
    latch.wait();
    if latch.panicked.load(Ordering::SeqCst) {
        panic!("kernel pool task panicked");
    }
}

/// Partition `out` into `out.len() / slot_width` fixed-width slots and
/// apply `work(scratch, slot_index, slot)` to every slot, spreading
/// contiguous slot ranges over `workers` kernel-pool lanes. `init`
/// builds one scratch value per lane, so kernels can reuse accumulators
/// and score buffers across the queries of their tile instead of
/// allocating per row.
///
/// For a `[N, H, D]` attention output, `slot_width = D` makes slot `i`
/// the (head, query) row `(t, hh) = (i / H, i % H)` — range boundaries
/// can cut between the heads of one query, which is exactly the
/// head×query-tile partitioning the kernels want.
pub fn for_each_slot<S, I, F>(out: &mut [f32], slot_width: usize, workers: usize, init: I, work: F)
where
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &mut [f32]) + Sync,
{
    assert!(slot_width > 0, "slot_width must be positive");
    assert_eq!(out.len() % slot_width, 0, "output not a whole number of slots");
    let total = out.len() / slot_width;
    if total == 0 {
        return;
    }
    if workers.min(total) <= 1 {
        let mut scratch = init();
        for (i, slot) in out.chunks_exact_mut(slot_width).enumerate() {
            work(&mut scratch, i, slot);
        }
        return;
    }
    let ranges = split_ranges(total, workers);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
    let mut rest = out;
    for range in ranges {
        let (chunk, tail) = rest.split_at_mut(range.len() * slot_width);
        rest = tail;
        let (init, work) = (&init, &work);
        tasks.push(Box::new(move || {
            let mut scratch = init();
            for (j, slot) in chunk.chunks_exact_mut(slot_width).enumerate() {
                work(&mut scratch, range.start + j, slot);
            }
        }));
    }
    run_scoped(tasks);
}

/// `(0..n).map(f)` with the index range spread over `workers`
/// kernel-pool lanes. Results come back in index order regardless of
/// which lane produced them or when it finished.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    if workers.min(n) <= 1 {
        return (0..n).map(f).collect();
    }
    let ranges = split_ranges(n, workers);
    let mut parts: Vec<Vec<T>> = Vec::new();
    parts.resize_with(ranges.len(), Vec::new);
    {
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
        for (range, slot) in ranges.into_iter().zip(parts.iter_mut()) {
            let f = &f;
            tasks.push(Box::new(move || {
                *slot = range.map(f).collect();
            }));
        }
        run_scoped(tasks);
    }
    let mut out = Vec::with_capacity(n);
    for part in parts {
        out.extend(part);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_cover_exactly() {
        for total in [0usize, 1, 2, 7, 8, 100] {
            for parts in [1usize, 2, 3, 8, 200] {
                let ranges = split_ranges(total, parts);
                assert!(ranges.len() <= parts.max(1));
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next, "total={total} parts={parts}");
                    assert!(r.end > r.start, "empty range");
                    next = r.end;
                }
                assert_eq!(next, total, "total={total} parts={parts}");
            }
        }
    }

    #[test]
    fn split_ranges_balanced() {
        let ranges = split_ranges(10, 4);
        let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
        assert_eq!(lens, vec![3, 3, 2, 2]);
    }

    #[test]
    fn for_each_slot_matches_serial_for_any_worker_count() {
        let n_slots = 37;
        let width = 3;
        let run = |workers: usize| {
            let mut out = vec![0.0f32; n_slots * width];
            for_each_slot(
                &mut out,
                width,
                workers,
                || 0usize, // scratch: per-lane call counter
                |calls, i, slot| {
                    *calls += 1;
                    for (d, x) in slot.iter_mut().enumerate() {
                        *x = (i * width + d) as f32 * 0.5;
                    }
                },
            );
            out
        };
        let serial = run(1);
        for workers in [2usize, 3, 8, 64] {
            assert_eq!(run(workers), serial, "workers={workers}");
        }
    }

    #[test]
    fn for_each_slot_empty_is_noop() {
        let mut out: Vec<f32> = Vec::new();
        for_each_slot(&mut out, 4, 8, || (), |_, _, _| panic!("no slots"));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let serial: Vec<usize> = (0..23).map(|i| i * i).collect();
        for workers in [1usize, 2, 5, 23, 100] {
            assert_eq!(parallel_map(23, workers, |i| i * i), serial, "workers={workers}");
        }
        assert!(parallel_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn pool_survives_repeated_and_nested_style_calls() {
        // the persistent pool must be reusable back-to-back (no one-shot
        // scope state) and from several caller threads at once
        for round in 0..50 {
            let got = parallel_map(17, 4, |i| i + round);
            let want: Vec<usize> = (0..17).map(|i| i + round).collect();
            assert_eq!(got, want, "round={round}");
        }
        std::thread::scope(|scope| {
            for t in 0..4 {
                scope.spawn(move || {
                    for round in 0..20 {
                        let got = parallel_map(11, 3, |i| i * t + round);
                        let want: Vec<usize> = (0..11).map(|i| i * t + round).collect();
                        assert_eq!(got, want, "t={t} round={round}");
                    }
                });
            }
        });
    }

    #[test]
    fn pool_task_panic_propagates_to_caller() {
        let result = std::panic::catch_unwind(|| {
            parallel_map(8, 4, |i| {
                if i == 5 {
                    panic!("boom");
                }
                i
            })
        });
        assert!(result.is_err(), "task panic must reach the caller");
        // and the pool still works afterwards
        assert_eq!(parallel_map(6, 3, |i| i * 2), vec![0, 2, 4, 6, 8, 10]);
    }

    #[test]
    fn panic_recovery_leaves_no_poisoned_state() {
        // repeated panicking calls interleaved with healthy ones: each
        // panic must re-raise exactly once on its own caller, and the
        // shared queue/latch machinery must stay usable (no poison
        // cascade into unrelated calls)
        for round in 0..3usize {
            let result = std::panic::catch_unwind(|| {
                let mut out = vec![0.0f32; 24];
                for_each_slot(&mut out, 2, 4, || (), |_, i, slot| {
                    if i % 3 == round % 3 {
                        panic!("chaos slot {i}");
                    }
                    slot[0] = i as f32;
                });
            });
            assert!(result.is_err(), "round={round}: panic must reach the caller");
            let want: Vec<usize> = (0..5).map(|i| i + round).collect();
            assert_eq!(parallel_map(5, 4, |i| i + round), want, "round={round}");
        }
    }

    #[test]
    fn multiple_panicking_tasks_raise_a_single_panic() {
        // every task panics; the caller still sees exactly one panic
        // (flag-based re-raise, not unwind-per-task) and the pool keeps
        // serving afterwards
        let result = std::panic::catch_unwind(|| {
            parallel_map(16, 8, |i| -> usize { panic!("task {i}") })
        });
        assert!(result.is_err());
        assert_eq!(parallel_map(4, 2, |i| i), vec![0, 1, 2, 3]);
    }

    #[test]
    fn concurrent_caller_unaffected_by_anothers_panic() {
        std::thread::scope(|scope| {
            let chaos = scope.spawn(|| {
                for _ in 0..10 {
                    let r = std::panic::catch_unwind(|| {
                        parallel_map(8, 4, |i| -> usize { panic!("boom {i}") })
                    });
                    assert!(r.is_err());
                }
            });
            let healthy = scope.spawn(|| {
                let want: Vec<usize> = (0..13).map(|i| i * 2).collect();
                for _ in 0..10 {
                    assert_eq!(parallel_map(13, 4, |i| i * 2), want);
                }
            });
            chaos.join().expect("chaos caller itself must not die");
            healthy.join().expect("healthy caller poisoned by a neighbor's panic");
        });
    }

    #[test]
    fn default_workers_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn parse_workers_accepts_positive_integers_only() {
        assert_eq!(parse_workers("4"), Ok(4));
        assert_eq!(parse_workers(" 2 "), Ok(2));
        assert!(parse_workers("0").is_err());
        assert!(parse_workers("-3").is_err());
        assert!(parse_workers("lots").is_err());
        assert!(parse_workers("").is_err());
        let msg = parse_workers("lots").unwrap_err();
        assert!(msg.contains("MOBA_WORKERS") && msg.contains("lots"), "unhelpful: {msg}");
    }
}
