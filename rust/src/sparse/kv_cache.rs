//! Incremental KV + block-pool caches — the serving-side state behind
//! `CachedDecodeBackend`.
//!
//! - [`KvCache`] holds appended K/V rows in the same `[N, H, D]` row-major
//!   layout the batch kernels use, so a cached sequence can be handed back
//!   to `full_attention` / `moba_attention` for parity checks at zero
//!   translation cost.
//! - [`BlockPoolCache`] maintains the per-block mean-pooled key
//!   representatives of `gate::mean_pool_blocks` *incrementally*: one
//!   running-sum update per appended token, no re-pooling. The
//!   accumulation order matches `mean_pool_blocks` exactly (tokens in
//!   order, then one multiply by `1/count`), so gating against cached
//!   representatives is bit-identical to gating against recomputed ones.
//!
//! Together they turn a decode step from O(N²) full recompute into
//! O(N/B · D) gating + O(k · B · D) attention.

use crate::tensor::Tensor;

/// `out = sums * (1/count)` — THE block-representative mean formula: one
/// reciprocal, then one multiply per element (never a per-element
/// divide). Every cache that materializes means from running sums
/// (`BlockPoolCache` here, `PagedKvPool` in `sparse::paged`) goes through
/// this helper, so equal sums always yield bit-identical means.
#[inline]
pub(crate) fn write_mean(sums: &[f32], count: usize, out: &mut [f32]) {
    let inv = 1.0 / count as f32;
    for (o, &s) in out.iter_mut().zip(sums) {
        *o = s * inv;
    }
}

/// Append-only K/V store for one sequence, `[len, H, D]` row-major.
#[derive(Clone, Debug)]
pub struct KvCache {
    heads: usize,
    head_dim: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    len: usize,
}

impl KvCache {
    pub fn new(heads: usize, head_dim: usize) -> KvCache {
        assert!(heads > 0 && head_dim > 0);
        KvCache { heads, head_dim, k: Vec::new(), v: Vec::new(), len: 0 }
    }

    /// Tokens currently cached.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Floats per token row (`H * D`).
    pub fn row_width(&self) -> usize {
        self.heads * self.head_dim
    }

    /// Append one token's K and V rows (each `[H * D]`).
    pub fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        let w = self.row_width();
        assert_eq!(k_row.len(), w, "k row width");
        assert_eq!(v_row.len(), w, "v row width");
        self.k.extend_from_slice(k_row);
        self.v.extend_from_slice(v_row);
        self.len += 1;
    }

    /// Append a whole `[N, H, D]` prefix (prefill path).
    pub fn append_tensors(&mut self, k: &Tensor, v: &Tensor) {
        assert_eq!(k.shape, v.shape, "k/v shape mismatch");
        assert_eq!(k.rank(), 3, "expected [N, H, D]");
        assert_eq!(k.shape[1], self.heads, "head count");
        assert_eq!(k.shape[2], self.head_dim, "head dim");
        self.k.extend_from_slice(&k.data);
        self.v.extend_from_slice(&v.data);
        self.len += k.shape[0];
    }

    /// The whole cached key payload as a `[len, H, D]` row-major slab —
    /// the exact layout the batch kernels index, so the fused decode row
    /// can run directly over cache storage with zero translation.
    #[inline]
    pub(crate) fn k_data(&self) -> &[f32] {
        &self.k
    }

    /// The whole cached value payload as a `[len, H, D]` row-major slab.
    #[inline]
    pub(crate) fn v_data(&self) -> &[f32] {
        &self.v
    }

    /// Key slice `[D]` for (token, head).
    #[inline]
    pub fn k_at(&self, t: usize, h: usize) -> &[f32] {
        let off = (t * self.heads + h) * self.head_dim;
        &self.k[off..off + self.head_dim]
    }

    /// Value slice `[D]` for (token, head).
    #[inline]
    pub fn v_at(&self, t: usize, h: usize) -> &[f32] {
        let off = (t * self.heads + h) * self.head_dim;
        &self.v[off..off + self.head_dim]
    }

    /// Materialize the cached keys as a `[len, H, D]` tensor (recompute
    /// baselines and parity tests).
    pub fn k_tensor(&self) -> Tensor {
        Tensor::from_vec(&[self.len, self.heads, self.head_dim], self.k.clone())
            .expect("cache layout is always consistent")
    }

    /// Materialize the cached values as a `[len, H, D]` tensor.
    pub fn v_tensor(&self) -> Tensor {
        Tensor::from_vec(&[self.len, self.heads, self.head_dim], self.v.clone())
            .expect("cache layout is always consistent")
    }

    pub fn clear(&mut self) {
        self.k.clear();
        self.v.clear();
        self.len = 0;
    }

    /// Resident bytes of the cached K/V payload.
    pub fn payload_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }
}

/// Incrementally maintained per-block mean-pooled key representatives
/// (`[n_blocks, H, D]` running sums + per-block counts).
#[derive(Clone, Debug)]
pub struct BlockPoolCache {
    block_size: usize,
    heads: usize,
    head_dim: usize,
    /// running sums, `[n_blocks, H, D]` row-major, growing by whole blocks
    sums: Vec<f32>,
    /// tokens accumulated into each block (last entry may be partial)
    counts: Vec<usize>,
    len: usize,
}

impl BlockPoolCache {
    pub fn new(block_size: usize, heads: usize, head_dim: usize) -> BlockPoolCache {
        assert!(block_size > 0 && heads > 0 && head_dim > 0);
        BlockPoolCache {
            block_size,
            heads,
            head_dim,
            sums: Vec::new(),
            counts: Vec::new(),
            len: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Tokens folded in so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Blocks currently represented (`ceil(len / block_size)`).
    pub fn n_blocks(&self) -> usize {
        self.counts.len()
    }

    /// Tokens accumulated into block `b`.
    pub fn count(&self, b: usize) -> usize {
        self.counts[b]
    }

    /// Fold one key row `[H * D]` into its block's running sum — O(H·D),
    /// independent of sequence length; no re-pooling of earlier blocks.
    pub fn append(&mut self, k_row: &[f32]) {
        let w = self.heads * self.head_dim;
        assert_eq!(k_row.len(), w, "k row width");
        let b = self.len / self.block_size;
        if b == self.counts.len() {
            self.counts.push(0);
            self.sums.extend(std::iter::repeat(0.0).take(w));
        }
        let off = b * w;
        for (s, &x) in self.sums[off..off + w].iter_mut().zip(k_row) {
            *s += x;
        }
        self.counts[b] += 1;
        self.len += 1;
    }

    /// Append a whole `[N, H, D]` prefix (prefill path).
    pub fn append_tensor(&mut self, k: &Tensor) {
        assert_eq!(k.rank(), 3, "expected [N, H, D]");
        assert_eq!(k.shape[1], self.heads, "head count");
        assert_eq!(k.shape[2], self.head_dim, "head dim");
        let w = self.heads * self.head_dim;
        for t in 0..k.shape[0] {
            self.append(&k.data[t * w..(t + 1) * w]);
        }
    }

    /// Mean representative of block `b`, head `h`, written into `out`
    /// (`[D]`). Bit-identical to `mean_pool_blocks` on the same prefix:
    /// same accumulation order, one multiply by `1/count`.
    pub fn mean_into(&self, b: usize, h: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.head_dim);
        let off = (b * self.heads + h) * self.head_dim;
        write_mean(&self.sums[off..off + self.head_dim], self.counts[b], out);
    }

    /// All of head `h`'s block representatives written contiguously into
    /// `out` (`[n_blocks, D]`) — the per-head slab the fused decode gate
    /// scans. Each element is the same `sum * (1/count)` as
    /// [`BlockPoolCache::mean_into`], bit-for-bit.
    pub fn means_for_head_into(&self, h: usize, out: &mut [f32]) {
        let (nb, d) = (self.n_blocks(), self.head_dim);
        debug_assert_eq!(out.len(), nb * d);
        for b in 0..nb {
            let src = (b * self.heads + h) * d;
            write_mean(&self.sums[src..src + d], self.counts[b], &mut out[b * d..(b + 1) * d]);
        }
    }

    /// Materialize all representatives as `[n_blocks, H, D]` (diagnostics
    /// and parity tests).
    pub fn pooled_tensor(&self) -> Tensor {
        let nb = self.n_blocks();
        let mut out = Tensor::zeros(&[nb, self.heads, self.head_dim]);
        for b in 0..nb {
            for h in 0..self.heads {
                let off = (b * self.heads + h) * self.head_dim;
                self.mean_into(b, h, &mut out.data[off..off + self.head_dim]);
            }
        }
        out
    }

    pub fn clear(&mut self) {
        self.sums.clear();
        self.counts.clear();
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gate::mean_pool_blocks;
    use crate::util::rng::Rng;

    fn rand_t(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
    }

    #[test]
    fn kv_roundtrip_row_by_row() {
        let k = rand_t(&[7, 2, 4], 1);
        let v = rand_t(&[7, 2, 4], 2);
        let mut cache = KvCache::new(2, 4);
        for t in 0..7 {
            cache.append(&k.data[t * 8..(t + 1) * 8], &v.data[t * 8..(t + 1) * 8]);
        }
        assert_eq!(cache.len(), 7);
        assert_eq!(cache.k_tensor(), k);
        assert_eq!(cache.v_tensor(), v);
        assert_eq!(cache.k_at(3, 1), &k.data[(3 * 2 + 1) * 4..(3 * 2 + 1) * 4 + 4]);
    }

    #[test]
    fn kv_bulk_equals_row_appends() {
        let k = rand_t(&[6, 2, 4], 3);
        let v = rand_t(&[6, 2, 4], 4);
        let mut bulk = KvCache::new(2, 4);
        bulk.append_tensors(&k, &v);
        let mut rows = KvCache::new(2, 4);
        for t in 0..6 {
            rows.append(&k.data[t * 8..(t + 1) * 8], &v.data[t * 8..(t + 1) * 8]);
        }
        assert_eq!(bulk.k_tensor(), rows.k_tensor());
        assert_eq!(bulk.v_tensor(), rows.v_tensor());
        assert!(bulk.payload_bytes() > 0);
    }

    #[test]
    fn pool_matches_batch_mean_pool_bitwise() {
        // divisible and ragged lengths; incremental means must equal the
        // batch pooling exactly (same accumulation order)
        for &n in &[32usize, 37, 48, 5] {
            let k = rand_t(&[n, 2, 8], 100 + n as u64);
            let mut pool = BlockPoolCache::new(16, 2, 8);
            pool.append_tensor(&k);
            let batch = mean_pool_blocks(&k, 16);
            let inc = pool.pooled_tensor();
            assert_eq!(inc.shape, batch.shape, "n={n}");
            assert_eq!(inc.data, batch.data, "n={n}: pooled means differ");
        }
    }

    #[test]
    fn pool_grows_incrementally() {
        let mut pool = BlockPoolCache::new(4, 1, 2);
        assert_eq!(pool.n_blocks(), 0);
        for i in 0..9 {
            pool.append(&[i as f32, 1.0]);
        }
        assert_eq!(pool.len(), 9);
        assert_eq!(pool.n_blocks(), 3);
        assert_eq!(pool.count(0), 4);
        assert_eq!(pool.count(2), 1);
        let mut mean = [0.0f32; 2];
        pool.mean_into(2, 0, &mut mean);
        assert_eq!(mean, [8.0, 1.0]);
    }

    #[test]
    fn per_head_means_match_mean_into() {
        let k = rand_t(&[29, 3, 8], 7);
        let mut pool = BlockPoolCache::new(8, 3, 8);
        pool.append_tensor(&k);
        let nb = pool.n_blocks();
        let mut slab = vec![0.0f32; nb * 8];
        let mut one = [0.0f32; 8];
        for h in 0..3 {
            pool.means_for_head_into(h, &mut slab);
            for b in 0..nb {
                pool.mean_into(b, h, &mut one);
                assert_eq!(&slab[b * 8..(b + 1) * 8], &one, "h={h} b={b}");
            }
        }
    }

    #[test]
    fn clear_resets_both_caches() {
        let mut cache = KvCache::new(1, 2);
        cache.append(&[1.0, 2.0], &[3.0, 4.0]);
        cache.clear();
        assert!(cache.is_empty());
        let mut pool = BlockPoolCache::new(2, 1, 2);
        pool.append(&[1.0, 2.0]);
        pool.clear();
        assert!(pool.is_empty());
        assert_eq!(pool.n_blocks(), 0);
    }
}
