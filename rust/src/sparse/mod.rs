//! Pure-Rust MoBA attention stack: gating (paper Eq. 5-6), block-sparse
//! streaming attention (paper Eq. 2 / Algorithm 1), the causal full
//! attention baseline, and — new with the serving rewrite — the pluggable
//! [`AttentionBackend`] trait plus the incremental KV/block-pool caches
//! behind O(k·B) decode. See `README.md` in this directory for the
//! backend + cache design.
//!
//! Roles:
//! 1. correctness oracle for property tests and golden parity with the
//!    Python kernels;
//! 2. the measured CPU kernel pair for the Fig-2 efficiency benches;
//! 3. the attention engine of the serving path (`crate::serve`).

pub mod attention;
pub mod backend;
pub mod gate;
pub mod kv_cache;

pub use attention::{full_attention, moba_attention, moba_attention_gated};
pub use backend::{
    build_backend, AttentionBackend, BackendKind, CachedDecodeBackend, DecodePolicy,
    FullAttention, MobaAttention,
};
pub use gate::{affinity_scores, mean_pool_blocks, moba_gate, Gate};
pub use kv_cache::{BlockPoolCache, KvCache};
