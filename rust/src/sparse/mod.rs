//! Pure-Rust MoBA reference: gating (paper Eq. 5-6) and block-sparse
//! streaming attention (paper Eq. 2 / Algorithm 1), plus the causal full
//! attention baseline. Oracle for property tests, golden parity with the
//! Python kernels, and the measured CPU kernel pair for Fig-2 benches.

pub mod attention;
pub mod gate;

pub use attention::{full_attention, moba_attention, moba_attention_gated};
pub use gate::{affinity_scores, mean_pool_blocks, moba_gate, Gate};
