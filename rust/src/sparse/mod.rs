//! Pure-Rust MoBA attention stack: gating (paper Eq. 5-6), block-sparse
//! streaming attention (paper Eq. 2 / Algorithm 1) in two-pass and fused
//! single-pass (Flash-MoBA style) forms, the causal full attention
//! baseline, the pluggable [`AttentionBackend`] trait with the
//! incremental KV/block-pool caches behind O(k·B) decode, the paged
//! shared KV pool with copy-on-write prefix sharing (`paged`), and the
//! head×query-tile multi-core partitioner (`parallel`). See `README.md`
//! in this directory for the backend/cache design and the
//! threading/determinism model.
//!
//! Roles:
//! 1. correctness oracle for property tests and golden parity with the
//!    Python kernels;
//! 2. the measured CPU kernel pair for the Fig-2 efficiency benches;
//! 3. the attention engine of the serving path (`crate::serve`).

pub mod attention;
pub mod backend;
pub mod gate;
pub mod kv_cache;
pub mod paged;
pub mod parallel;

pub use attention::{
    full_attention, full_attention_par, fused_moba_attention, moba_attention,
    moba_attention_gated, moba_attention_gated_par, moba_attention_par,
};
pub use backend::{
    build_backend, build_backend_par, AttentionBackend, BackendKind, CachedDecodeBackend,
    DecodePolicy, FullAttention, FusedMobaAttention, MobaAttention,
};
pub use gate::{affinity_scores, mean_pool_blocks, moba_gate, Gate};
pub use kv_cache::{BlockPoolCache, KvCache};
pub use paged::{shared_pool, BlockTable, PagedKvPool, PagedMobaAttention, SharedKvPool, SwapImage};
pub use parallel::{default_workers, workers_from_env};
