//! Pure-Rust attention kernels: causal full attention (flash-style
//! streaming) and MoBA block-sparse attention.
//!
//! Two roles:
//! 1. correctness oracle for property tests and golden parity with the
//!    Python reference;
//! 2. the *measured* CPU kernels behind the Fig-2 efficiency benches —
//!    both use the same online-softmax inner loop, so their runtime
//!    ratio isolates the sparsity effect exactly as the paper's A100
//!    measurement isolates it against FlashAttention.
//!
//! Layout: q, k, v are `[N, H, D]` row-major f32 (Algorithm 1's layout).

use crate::tensor::Tensor;

use super::gate::{moba_gate, Gate};

pub(crate) const NEG_INF: f32 = -1e30;

#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    // simple 4-lane unroll; autovectorizes well at opt-level 3
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[inline]
fn axpy(acc: &mut [f32], alpha: f32, x: &[f32]) {
    for (a, &xv) in acc.iter_mut().zip(x) {
        *a += alpha * xv;
    }
}

/// Streaming softmax state for one query row. Shared with the incremental
/// decode backends (`sparse::backend`), which must fold scores in the same
/// order with the same arithmetic to stay bit-identical with these batch
/// kernels.
pub(crate) struct OnlineRow {
    m: f32,
    l: f32,
    acc: Vec<f32>,
}

impl OnlineRow {
    pub(crate) fn new(d: usize) -> Self {
        OnlineRow { m: NEG_INF, l: 0.0, acc: vec![0.0; d] }
    }

    /// Fold in one (score, value-row) pair.
    #[inline]
    pub(crate) fn push(&mut self, s: f32, v: &[f32]) {
        if s > self.m {
            let alpha = (self.m - s).exp();
            self.l *= alpha;
            for a in self.acc.iter_mut() {
                *a *= alpha;
            }
            self.m = s;
        }
        let p = (s - self.m).exp();
        self.l += p;
        axpy(&mut self.acc, p, v);
    }

    pub(crate) fn finish(self, out: &mut [f32]) {
        let inv = 1.0 / self.l;
        for (o, a) in out.iter_mut().zip(self.acc) {
            *o = a * inv;
        }
    }
}

/// Causal full attention, flash-style streaming (no N^2 materialization).
pub fn full_attention(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let (n, h, d) = (q.shape[0], q.shape[1], q.shape[2]);
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Tensor::zeros(&[n, h, d]);
    for hh in 0..h {
        for t in 0..n {
            let qrow = &q.data[(t * h + hh) * d..(t * h + hh) * d + d];
            let mut row = OnlineRow::new(d);
            for j in 0..=t {
                let koff = (j * h + hh) * d;
                let s = dot(qrow, &k.data[koff..koff + d]) * scale;
                row.push(s, &v.data[koff..koff + d]);
            }
            let ooff = (t * h + hh) * d;
            row.finish(&mut out.data[ooff..ooff + d]);
        }
    }
    out
}

/// MoBA attention with a precomputed gate (used by benches to separate
/// gating cost from attention cost).
pub fn moba_attention_gated(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    gate: &Gate,
    block_size: usize,
) -> Tensor {
    let (n, h, d) = (q.shape[0], q.shape[1], q.shape[2]);
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Tensor::zeros(&[n, h, d]);
    for hh in 0..h {
        for t in 0..n {
            let qrow = &q.data[(t * h + hh) * d..(t * h + hh) * d + d];
            let mut row = OnlineRow::new(d);
            for b in 0..gate.n_blocks {
                if !gate.get(hh, t, b) {
                    continue;
                }
                let hi = ((b + 1) * block_size).min(t + 1); // causal inside current block
                for j in b * block_size..hi {
                    let koff = (j * h + hh) * d;
                    let s = dot(qrow, &k.data[koff..koff + d]) * scale;
                    row.push(s, &v.data[koff..koff + d]);
                }
            }
            let ooff = (t * h + hh) * d;
            row.finish(&mut out.data[ooff..ooff + d]);
        }
    }
    out
}

/// MoBA attention end-to-end: gate + block-sparse streaming attention.
/// N need not be divisible by the block size (the trailing partial block
/// is the current block of its own queries), which is what the
/// append-one-token incremental decode parity tests exercise.
pub fn moba_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    block_size: usize,
    topk: usize,
) -> Tensor {
    let gate = moba_gate(q, k, block_size, topk);
    moba_attention_gated(q, k, v, &gate, block_size)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_t(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
    }

    /// Naive O(N^2) masked softmax reference to check the streaming paths.
    fn naive_masked(q: &Tensor, k: &Tensor, v: &Tensor, allow: impl Fn(usize, usize, usize) -> bool) -> Tensor {
        let (n, h, d) = (q.shape[0], q.shape[1], q.shape[2]);
        let scale = 1.0 / (d as f32).sqrt();
        let mut out = Tensor::zeros(&[n, h, d]);
        for hh in 0..h {
            for t in 0..n {
                let mut scores = Vec::new();
                for j in 0..n {
                    if allow(hh, t, j) {
                        let mut s = 0.0;
                        for dd in 0..d {
                            s += q.at3(t, hh, dd) * k.at3(j, hh, dd);
                        }
                        scores.push((j, s * scale));
                    }
                }
                let m = scores.iter().map(|x| x.1).fold(NEG_INF, f32::max);
                let z: f32 = scores.iter().map(|x| (x.1 - m).exp()).sum();
                for (j, s) in scores {
                    let p = (s - m).exp() / z;
                    for dd in 0..d {
                        out.data[(t * h + hh) * d + dd] += p * v.at3(j, hh, dd);
                    }
                }
            }
        }
        out
    }

    #[test]
    fn full_matches_naive() {
        let q = rand_t(&[32, 2, 8], 1);
        let k = rand_t(&[32, 2, 8], 2);
        let v = rand_t(&[32, 2, 8], 3);
        let a = full_attention(&q, &k, &v);
        let b = naive_masked(&q, &k, &v, |_, t, j| j <= t);
        assert!(a.max_abs_diff(&b) < 1e-5, "diff={}", a.max_abs_diff(&b));
    }

    #[test]
    fn moba_matches_naive_with_gate_mask() {
        let q = rand_t(&[64, 2, 8], 4);
        let k = rand_t(&[64, 2, 8], 5);
        let v = rand_t(&[64, 2, 8], 6);
        let bs = 16;
        let gate = moba_gate(&q, &k, bs, 2);
        let a = moba_attention_gated(&q, &k, &v, &gate, bs);
        let b = naive_masked(&q, &k, &v, |h, t, j| j <= t && gate.get(h, t, j / bs));
        assert!(a.max_abs_diff(&b) < 1e-5, "diff={}", a.max_abs_diff(&b));
    }

    #[test]
    fn moba_covering_topk_equals_full() {
        let q = rand_t(&[48, 1, 8], 7);
        let k = rand_t(&[48, 1, 8], 8);
        let v = rand_t(&[48, 1, 8], 9);
        let a = moba_attention(&q, &k, &v, 16, 3); // 3 blocks, topk=3 covers all
        let b = full_attention(&q, &k, &v);
        assert!(a.max_abs_diff(&b) < 1e-5);
    }

    #[test]
    fn first_block_rows_equal_full() {
        let q = rand_t(&[64, 2, 8], 10);
        let k = rand_t(&[64, 2, 8], 11);
        let v = rand_t(&[64, 2, 8], 12);
        let a = moba_attention(&q, &k, &v, 16, 1);
        let b = full_attention(&q, &k, &v);
        for idx in 0..16 * 2 * 8 {
            assert!((a.data[idx] - b.data[idx]).abs() < 1e-5);
        }
    }

    #[test]
    fn rows_are_convex_combinations() {
        let q = rand_t(&[32, 1, 8], 13);
        let k = rand_t(&[32, 1, 8], 14);
        let v = Tensor::ones(&[32, 1, 8]);
        let a = moba_attention(&q, &k, &v, 8, 2);
        for &x in &a.data {
            assert!((x - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn moba_ragged_length_matches_naive() {
        // N=52 with block 16: 3 full blocks + a 4-token tail block
        let q = rand_t(&[52, 2, 8], 18);
        let k = rand_t(&[52, 2, 8], 19);
        let v = rand_t(&[52, 2, 8], 20);
        let bs = 16;
        let gate = moba_gate(&q, &k, bs, 2);
        let a = moba_attention_gated(&q, &k, &v, &gate, bs);
        let b = naive_masked(&q, &k, &v, |h, t, j| j <= t && gate.get(h, t, j / bs));
        assert!(a.max_abs_diff(&b) < 1e-5, "diff={}", a.max_abs_diff(&b));
    }

    #[test]
    fn online_softmax_stable_at_large_scores() {
        let mut q = rand_t(&[32, 1, 8], 15);
        for x in q.data.iter_mut() {
            *x *= 50.0;
        }
        let k = rand_t(&[32, 1, 8], 16);
        let v = rand_t(&[32, 1, 8], 17);
        let a = moba_attention(&q, &k, &v, 8, 2);
        assert!(a.data.iter().all(|x| x.is_finite()));
    }
}
