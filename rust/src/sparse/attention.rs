//! Pure-Rust attention kernels: causal full attention (flash-style
//! streaming), MoBA block-sparse attention (two-pass gate + attend and
//! the fused single-pass variant), all with optional multi-core
//! execution over the head×query-tile partitioner in [`super::parallel`].
//!
//! Three roles:
//! 1. correctness oracle for property tests and golden parity with the
//!    Python reference;
//! 2. the *measured* CPU kernels behind the Fig-2 efficiency benches —
//!    full and MoBA share the same online-softmax inner loop, so their
//!    runtime ratio isolates the sparsity effect exactly as the paper's
//!    A100 measurement isolates it against FlashAttention;
//! 3. the prefill engine of the serving path (`crate::serve`), via the
//!    backends in `super::backend`.
//!
//! Determinism: every output row `(t, hh)` is computed with a fixed
//! arithmetic order that does not depend on the worker count, so the
//! `_par` variants and `fused_moba_attention` are bit-identical to the
//! single-threaded kernels (`tests/thread_invariance.rs`).
//!
//! Layout: q, k, v are `[N, H, D]` row-major f32 (Algorithm 1's layout).

use crate::tensor::Tensor;

use super::gate::{mean_pool_blocks, moba_gate, Gate, BIG};
use super::parallel::for_each_slot;

pub(crate) const NEG_INF: f32 = -1e30;

#[inline]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    // simple 4-lane unroll; autovectorizes well at opt-level 3
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Two independent dot products with interleaved accumulator chains.
/// Each result carries out *exactly* the operation sequence of
/// [`dot`] — interleaving independent chains changes instruction-level
/// parallelism, not any chain's accumulation order — so `(dot2(a,b0,b1))
/// == (dot(a,b0), dot(a,b1))` bit-for-bit.
#[inline]
pub(crate) fn dot2(a: &[f32], b0: &[f32], b1: &[f32]) -> (f32, f32) {
    let mut x = [0.0f32; 4];
    let mut y = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        x[0] += a[i] * b0[i];
        y[0] += a[i] * b1[i];
        x[1] += a[i + 1] * b0[i + 1];
        y[1] += a[i + 1] * b1[i + 1];
        x[2] += a[i + 2] * b0[i + 2];
        y[2] += a[i + 2] * b1[i + 2];
        x[3] += a[i + 3] * b0[i + 3];
        y[3] += a[i + 3] * b1[i + 3];
    }
    let mut s0 = x[0] + x[1] + x[2] + x[3];
    let mut s1 = y[0] + y[1] + y[2] + y[3];
    for i in chunks * 4..a.len() {
        s0 += a[i] * b0[i];
        s1 += a[i] * b1[i];
    }
    (s0, s1)
}

#[inline]
fn axpy(acc: &mut [f32], alpha: f32, x: &[f32]) {
    for (a, &xv) in acc.iter_mut().zip(x) {
        *a += alpha * xv;
    }
}

/// Streaming softmax state for one query row. Shared with the incremental
/// decode backends (`sparse::backend`), which must fold scores in the same
/// order with the same arithmetic to stay bit-identical with these batch
/// kernels. Reusable across rows via [`OnlineRow::reset`], so the batch
/// kernels allocate one per worker instead of one per query.
pub(crate) struct OnlineRow {
    m: f32,
    l: f32,
    acc: Vec<f32>,
}

impl OnlineRow {
    pub(crate) fn new(d: usize) -> Self {
        OnlineRow { m: NEG_INF, l: 0.0, acc: vec![0.0; d] }
    }

    /// Back to the freshly-constructed state, keeping the allocation.
    pub(crate) fn reset(&mut self) {
        self.m = NEG_INF;
        self.l = 0.0;
        self.acc.fill(0.0);
    }

    /// Fold in one (score, value-row) pair.
    #[inline]
    pub(crate) fn push(&mut self, s: f32, v: &[f32]) {
        if s > self.m {
            let alpha = (self.m - s).exp();
            self.l *= alpha;
            for a in self.acc.iter_mut() {
                *a *= alpha;
            }
            self.m = s;
        }
        let p = (s - self.m).exp();
        self.l += p;
        axpy(&mut self.acc, p, v);
    }

    /// Write the normalized row into `out` without consuming the state
    /// (callers reusing the row must `reset` before the next query).
    pub(crate) fn finish_into(&mut self, out: &mut [f32]) {
        let inv = 1.0 / self.l;
        for (o, a) in out.iter_mut().zip(&self.acc) {
            *o = a * inv;
        }
    }
}

/// Causal full attention, flash-style streaming (no N^2 materialization),
/// head×query rows spread over `workers` threads.
pub fn full_attention_par(q: &Tensor, k: &Tensor, v: &Tensor, workers: usize) -> Tensor {
    let (n, h, d) = (q.shape[0], q.shape[1], q.shape[2]);
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Tensor::zeros(&[n, h, d]);
    for_each_slot(
        &mut out.data,
        d,
        workers,
        || OnlineRow::new(d),
        |row, slot, out_row| {
            let (t, hh) = (slot / h, slot % h);
            let qrow = &q.data[(t * h + hh) * d..(t * h + hh) * d + d];
            row.reset();
            for j in 0..=t {
                let koff = (j * h + hh) * d;
                let s = dot(qrow, &k.data[koff..koff + d]) * scale;
                row.push(s, &v.data[koff..koff + d]);
            }
            row.finish_into(out_row);
        },
    );
    out
}

/// Causal full attention on the calling thread (the parity oracle).
pub fn full_attention(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    full_attention_par(q, k, v, 1)
}

/// MoBA attention with a precomputed gate (used by benches to separate
/// gating cost from attention cost), parallel over head×query rows.
pub fn moba_attention_gated_par(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    gate: &Gate,
    block_size: usize,
    workers: usize,
) -> Tensor {
    let (n, h, d) = (q.shape[0], q.shape[1], q.shape[2]);
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Tensor::zeros(&[n, h, d]);
    for_each_slot(
        &mut out.data,
        d,
        workers,
        || OnlineRow::new(d),
        |row, slot, out_row| {
            let (t, hh) = (slot / h, slot % h);
            let qrow = &q.data[(t * h + hh) * d..(t * h + hh) * d + d];
            row.reset();
            for b in gate.selected_iter(hh, t) {
                let hi = ((b + 1) * block_size).min(t + 1); // causal inside current block
                for j in b * block_size..hi {
                    let koff = (j * h + hh) * d;
                    let s = dot(qrow, &k.data[koff..koff + d]) * scale;
                    row.push(s, &v.data[koff..koff + d]);
                }
            }
            row.finish_into(out_row);
        },
    );
    out
}

/// MoBA attention with a precomputed gate, single-threaded.
pub fn moba_attention_gated(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    gate: &Gate,
    block_size: usize,
) -> Tensor {
    moba_attention_gated_par(q, k, v, gate, block_size, 1)
}

/// Two-pass MoBA end-to-end (gate materialized, then block-sparse
/// attention), parallel over head×query rows.
pub fn moba_attention_par(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    block_size: usize,
    topk: usize,
    workers: usize,
) -> Tensor {
    let gate = moba_gate(q, k, block_size, topk);
    moba_attention_gated_par(q, k, v, &gate, block_size, workers)
}

/// MoBA attention end-to-end: gate + block-sparse streaming attention.
/// N need not be divisible by the block size (the trailing partial block
/// is the current block of its own queries), which is what the
/// append-one-token incremental decode parity tests exercise.
pub fn moba_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    block_size: usize,
    topk: usize,
) -> Tensor {
    moba_attention_par(q, k, v, block_size, topk, 1)
}

// ---------------------------------------------------------------------------
// fused single-pass MoBA (Flash-MoBA style)
// ---------------------------------------------------------------------------

/// Per-worker scratch for the fused kernel: one softmax state, the causal
/// affinity scores, the select-nth workspace and a per-block token-score
/// buffer — no allocation happens per query row. Shared with the fused
/// decode path in `sparse::backend`.
pub(crate) struct FusedScratch {
    row: OnlineRow,
    scores: Vec<f32>,
    select: Vec<f32>,
    sbuf: Vec<f32>,
}

impl FusedScratch {
    pub(crate) fn new(d: usize, nb: usize, block_size: usize) -> FusedScratch {
        FusedScratch {
            row: OnlineRow::new(d),
            scores: vec![0.0; nb],
            select: vec![0.0; nb],
            sbuf: vec![0.0; block_size],
        }
    }

    /// Grow the per-block buffers to hold `nb` blocks — lets a scratch
    /// stored on a decode backend live across tokens as the sequence (and
    /// block count) grows, instead of reallocating per token.
    pub(crate) fn ensure_blocks(&mut self, nb: usize) {
        if self.scores.len() < nb {
            self.scores.resize(nb, 0.0);
            self.select.resize(nb, 0.0);
        }
    }
}

/// Fused gate+attention: representative scoring, top-k selection and
/// online-softmax block streaming interleaved in ONE pass per query row —
/// no materialized `Gate`, no `[H, N, nb]` affinity tensor, nothing
/// retained between rows beyond the per-worker scratch.
///
/// Bit-identical to `moba_attention` (the two-pass path):
///
/// - pooling is the shared `mean_pool_blocks` (one O(N·D) pass over K);
/// - each history score runs the same sequential multiply-add chain as
///   `gate::affinity_scores`, with the same `-i·1e-6` tie-break bias
///   (four chains are interleaved for ILP; each chain's internal order
///   is unchanged);
/// - scores are computed for *causal* blocks only. This cannot change
///   the selection: every future block's biased score is `-BIG` (the
///   `-i·1e-6` bias is absorbed at f32 precision), strictly below any
///   causal score, so the top-k of the full row is the top-k of its
///   causal prefix with `k` clamped to the causal count — the same
///   clamp `moba_gate`'s threshold test performs implicitly. (Like the
///   bias scheme itself, this assumes affinity magnitudes stay below
///   1e30.)
/// - the threshold is the same `select_nth_unstable_by`/`total_cmp`
///   k-th-largest, the selection test the same `score >= kth`;
/// - selected blocks stream in ascending order through the same
///   `dot`·scale / `OnlineRow::push` sequence (token scores for a block
///   are precomputed into a buffer via [`dot2`] pairs — identical values,
///   then folded in the identical order).
pub fn fused_moba_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    block_size: usize,
    topk: usize,
    workers: usize,
) -> Tensor {
    let (n, h, d) = (q.shape[0], q.shape[1], q.shape[2]);
    assert!(block_size > 0);
    let nb = (n + block_size - 1) / block_size;
    let pooled = mean_pool_blocks(k, block_size);
    // transpose representatives to per-head contiguous rows
    // ([nb, H, D] -> [H, nb, D]): pure data movement for gate-scan
    // locality; every arithmetic op still sees the same operands.
    let mut poolh = vec![0.0f32; h * nb * d];
    for i in 0..nb {
        for hh in 0..h {
            let src = (i * h + hh) * d;
            let dst = (hh * nb + i) * d;
            poolh[dst..dst + d].copy_from_slice(&pooled.data[src..src + d]);
        }
    }
    fused_moba_attention_with_reps(q, k, v, block_size, topk, workers, &poolh, nb)
}

/// The fused pass against *precomputed* per-head representative slabs:
/// `reps[hh * reps_stride * D ..]` holds head `hh`'s `[nb, D]` means,
/// `reps_stride >= nb` blocks. The values must equal
/// `mean_pool_blocks`'s bit-for-bit — the `BlockPoolCache` running-sum
/// means satisfy this (pinned by its tests), which is how the fused
/// backend's prefill reuses its cache pooling instead of pooling K a
/// second time.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fused_moba_attention_with_reps(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    block_size: usize,
    topk: usize,
    workers: usize,
    reps: &[f32],
    reps_stride: usize,
) -> Tensor {
    assert!(block_size > 0 && topk > 0);
    let (n, h, d) = (q.shape[0], q.shape[1], q.shape[2]);
    let scale = 1.0 / (d as f32).sqrt();
    let nb = (n + block_size - 1) / block_size;
    debug_assert!(reps_stride >= nb && reps.len() >= h * reps_stride * d);
    let kk = topk.min(nb);
    let mut out = Tensor::zeros(&[n, h, d]);
    for_each_slot(
        &mut out.data,
        d,
        workers,
        || FusedScratch::new(d, nb, block_size),
        |scratch, slot, out_row| {
            let (t, hh) = (slot / h, slot % h);
            let qrow = &q.data[(t * h + hh) * d..(t * h + hh) * d + d];
            let head = hh * reps_stride * d;
            let reps_h = &reps[head..head + nb * d];
            let (kd, vd) = (&k.data[..], &v.data[..]);
            fused_row(
                qrow, kd, vd, reps_h, h, hh, d, block_size, kk, t, scale, scratch, out_row,
            );
        },
    );
    out
}

/// One fused query row over *contiguous* `[*, H, D]` K/V slabs — the
/// batch kernels pass tensor data, the cached decode path passes the KV
/// cache's backing storage (same layout by design). Thin wrapper over
/// [`fused_row_blocks`]: block `b`'s slab is just the contiguous storage
/// starting at its first token.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fused_row<'s>(
    qrow: &[f32],
    k: &'s [f32],
    v: &'s [f32],
    reps: &[f32],
    h: usize,
    hh: usize,
    d: usize,
    block_size: usize,
    kk: usize,
    t: usize,
    scale: f32,
    scratch: &mut FusedScratch,
    out_row: &mut [f32],
) {
    let w = h * d;
    fused_row_blocks(
        qrow, reps, h, hh, d, block_size, kk, t, scale, scratch, out_row,
        |b| (&k[b * block_size * w..], &v[b * block_size * w..]),
    );
}

/// One fused query row against block-granular K/V storage: causal-only
/// gate scores → k-th-largest threshold → selected-block streaming, all
/// against the per-head representative slab `reps` (`[nb, D]`
/// contiguous). `block_kv(b)` hands back logical block `b`'s K and V
/// slabs (`[len_b, H, D]` row-major, the block's first token at offset
/// 0). The contiguous-cache path ([`fused_row`]) and the paged pool's
/// block-table indirection (`sparse::paged`) both route through this one
/// routine, so the gate arithmetic, the NaN-safe `>=` selection and the
/// streaming order cannot drift between them.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fused_row_blocks<'s>(
    qrow: &[f32],
    reps: &[f32],
    h: usize,
    hh: usize,
    d: usize,
    block_size: usize,
    kk: usize,
    t: usize,
    scale: f32,
    scratch: &mut FusedScratch,
    out_row: &mut [f32],
    block_kv: impl Fn(usize) -> (&'s [f32], &'s [f32]),
) {
    let cur = t / block_size;
    let nc = cur + 1; // causal block count for this row
    let kk = kk.min(nc);

    // gate scores over history blocks, four interleaved chains for ILP
    let scores = &mut scratch.scores[..nc];
    let mut i = 0;
    while i + 4 <= cur {
        let p0 = &reps[i * d..(i + 1) * d];
        let p1 = &reps[(i + 1) * d..(i + 2) * d];
        let p2 = &reps[(i + 2) * d..(i + 3) * d];
        let p3 = &reps[(i + 3) * d..(i + 4) * d];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for (dd, &qv) in qrow.iter().enumerate() {
            a0 += qv * p0[dd];
            a1 += qv * p1[dd];
            a2 += qv * p2[dd];
            a3 += qv * p3[dd];
        }
        scores[i] = a0 - i as f32 * 1e-6;
        scores[i + 1] = a1 - (i + 1) as f32 * 1e-6;
        scores[i + 2] = a2 - (i + 2) as f32 * 1e-6;
        scores[i + 3] = a3 - (i + 3) as f32 * 1e-6;
        i += 4;
    }
    while i < cur {
        let p = &reps[i * d..(i + 1) * d];
        let mut a = 0.0f32;
        for (dd, &qv) in qrow.iter().enumerate() {
            a += qv * p[dd];
        }
        scores[i] = a - i as f32 * 1e-6;
        i += 1;
    }
    scores[cur] = BIG - cur as f32 * 1e-6; // current block forced

    // k-th-largest threshold, exactly moba_gate's selection arithmetic
    let select = &mut scratch.select[..nc];
    select.copy_from_slice(scores);
    let (_, kth, _) = select.select_nth_unstable_by(kk - 1, |a, b| b.total_cmp(a));
    let kth = *kth;

    // stream the selected blocks in ascending order; the selection test
    // is the same *positive* `>=` as `moba_gate`'s, so NaN scores fall
    // out unselected in both paths (a negated `< kth` skip would invert
    // NaN handling and break the bit-identity contract)
    let row = &mut scratch.row;
    row.reset();
    for b in 0..nc {
        if scores[b] >= kth {
            let lo = b * block_size;
            let hi = ((b + 1) * block_size).min(t + 1); // causal inside current block
            let cnt = hi - lo;
            let (kb, vb) = block_kv(b);
            // token scores for the whole block first (independent dot
            // pairs overlap their latency chains), then fold in token
            // order — exactly the two-pass dot·scale / push sequence.
            let sbuf = &mut scratch.sbuf[..cnt];
            let mut j = 0;
            while j + 2 <= cnt {
                let o0 = (j * h + hh) * d;
                let o1 = ((j + 1) * h + hh) * d;
                let (s0, s1) = dot2(qrow, &kb[o0..o0 + d], &kb[o1..o1 + d]);
                sbuf[j] = s0 * scale;
                sbuf[j + 1] = s1 * scale;
                j += 2;
            }
            if j < cnt {
                let o = (j * h + hh) * d;
                sbuf[j] = dot(qrow, &kb[o..o + d]) * scale;
            }
            for (jj, &s) in sbuf.iter().enumerate() {
                let voff = (jj * h + hh) * d;
                row.push(s, &vb[voff..voff + d]);
            }
        }
    }
    row.finish_into(out_row);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_t(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
    }

    /// Naive O(N^2) masked softmax reference to check the streaming paths.
    fn naive_masked(q: &Tensor, k: &Tensor, v: &Tensor, allow: impl Fn(usize, usize, usize) -> bool) -> Tensor {
        let (n, h, d) = (q.shape[0], q.shape[1], q.shape[2]);
        let scale = 1.0 / (d as f32).sqrt();
        let mut out = Tensor::zeros(&[n, h, d]);
        for hh in 0..h {
            for t in 0..n {
                let mut scores = Vec::new();
                for j in 0..n {
                    if allow(hh, t, j) {
                        let mut s = 0.0;
                        for dd in 0..d {
                            s += q.at3(t, hh, dd) * k.at3(j, hh, dd);
                        }
                        scores.push((j, s * scale));
                    }
                }
                let m = scores.iter().map(|x| x.1).fold(NEG_INF, f32::max);
                let z: f32 = scores.iter().map(|x| (x.1 - m).exp()).sum();
                for (j, s) in scores {
                    let p = (s - m).exp() / z;
                    for dd in 0..d {
                        out.data[(t * h + hh) * d + dd] += p * v.at3(j, hh, dd);
                    }
                }
            }
        }
        out
    }

    #[test]
    fn full_matches_naive() {
        let q = rand_t(&[32, 2, 8], 1);
        let k = rand_t(&[32, 2, 8], 2);
        let v = rand_t(&[32, 2, 8], 3);
        let a = full_attention(&q, &k, &v);
        let b = naive_masked(&q, &k, &v, |_, t, j| j <= t);
        assert!(a.max_abs_diff(&b) < 1e-5, "diff={}", a.max_abs_diff(&b));
    }

    #[test]
    fn moba_matches_naive_with_gate_mask() {
        let q = rand_t(&[64, 2, 8], 4);
        let k = rand_t(&[64, 2, 8], 5);
        let v = rand_t(&[64, 2, 8], 6);
        let bs = 16;
        let gate = moba_gate(&q, &k, bs, 2);
        let a = moba_attention_gated(&q, &k, &v, &gate, bs);
        let b = naive_masked(&q, &k, &v, |h, t, j| j <= t && gate.get(h, t, j / bs));
        assert!(a.max_abs_diff(&b) < 1e-5, "diff={}", a.max_abs_diff(&b));
    }

    #[test]
    fn moba_covering_topk_equals_full() {
        let q = rand_t(&[48, 1, 8], 7);
        let k = rand_t(&[48, 1, 8], 8);
        let v = rand_t(&[48, 1, 8], 9);
        let a = moba_attention(&q, &k, &v, 16, 3); // 3 blocks, topk=3 covers all
        let b = full_attention(&q, &k, &v);
        assert!(a.max_abs_diff(&b) < 1e-5);
    }

    #[test]
    fn first_block_rows_equal_full() {
        let q = rand_t(&[64, 2, 8], 10);
        let k = rand_t(&[64, 2, 8], 11);
        let v = rand_t(&[64, 2, 8], 12);
        let a = moba_attention(&q, &k, &v, 16, 1);
        let b = full_attention(&q, &k, &v);
        for idx in 0..16 * 2 * 8 {
            assert!((a.data[idx] - b.data[idx]).abs() < 1e-5);
        }
    }

    #[test]
    fn rows_are_convex_combinations() {
        let q = rand_t(&[32, 1, 8], 13);
        let k = rand_t(&[32, 1, 8], 14);
        let v = Tensor::ones(&[32, 1, 8]);
        let a = moba_attention(&q, &k, &v, 8, 2);
        for &x in &a.data {
            assert!((x - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn moba_ragged_length_matches_naive() {
        // N=52 with block 16: 3 full blocks + a 4-token tail block
        let q = rand_t(&[52, 2, 8], 18);
        let k = rand_t(&[52, 2, 8], 19);
        let v = rand_t(&[52, 2, 8], 20);
        let bs = 16;
        let gate = moba_gate(&q, &k, bs, 2);
        let a = moba_attention_gated(&q, &k, &v, &gate, bs);
        let b = naive_masked(&q, &k, &v, |h, t, j| j <= t && gate.get(h, t, j / bs));
        assert!(a.max_abs_diff(&b) < 1e-5, "diff={}", a.max_abs_diff(&b));
    }

    #[test]
    fn online_softmax_stable_at_large_scores() {
        let mut q = rand_t(&[32, 1, 8], 15);
        for x in q.data.iter_mut() {
            *x *= 50.0;
        }
        let k = rand_t(&[32, 1, 8], 16);
        let v = rand_t(&[32, 1, 8], 17);
        let a = moba_attention(&q, &k, &v, 8, 2);
        assert!(a.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn dot2_bitwise_matches_two_dots() {
        // every length, including non-multiples of the 4-lane unroll
        for d in 1..=19usize {
            let a = rand_t(&[d, 1, 1], 100 + d as u64);
            let b0 = rand_t(&[d, 1, 1], 200 + d as u64);
            let b1 = rand_t(&[d, 1, 1], 300 + d as u64);
            let (s0, s1) = dot2(&a.data, &b0.data, &b1.data);
            assert_eq!(s0.to_bits(), dot(&a.data, &b0.data).to_bits(), "d={d}");
            assert_eq!(s1.to_bits(), dot(&a.data, &b1.data).to_bits(), "d={d}");
        }
    }

    #[test]
    fn fused_bitwise_matches_two_pass() {
        // divisible and ragged lengths, several (block, topk) geometries
        for &(n, bs, topk, seed) in
            &[(64usize, 16usize, 2usize, 21u64), (52, 16, 2, 24), (96, 32, 3, 27), (37, 8, 4, 30)]
        {
            let q = rand_t(&[n, 2, 8], seed);
            let k = rand_t(&[n, 2, 8], seed + 1);
            let v = rand_t(&[n, 2, 8], seed + 2);
            let two_pass = moba_attention(&q, &k, &v, bs, topk);
            let fused = fused_moba_attention(&q, &k, &v, bs, topk, 1);
            assert_eq!(fused.data, two_pass.data, "n={n} bs={bs} topk={topk}");
        }
    }

    #[test]
    fn parallel_kernels_bitwise_match_single_thread() {
        let q = rand_t(&[52, 3, 8], 40);
        let k = rand_t(&[52, 3, 8], 41);
        let v = rand_t(&[52, 3, 8], 42);
        let gate = moba_gate(&q, &k, 16, 2);
        for workers in [2usize, 4, 16] {
            assert_eq!(
                full_attention_par(&q, &k, &v, workers).data,
                full_attention(&q, &k, &v).data,
                "full workers={workers}"
            );
            assert_eq!(
                moba_attention_par(&q, &k, &v, 16, 2, workers).data,
                moba_attention(&q, &k, &v, 16, 2).data,
                "moba workers={workers}"
            );
            assert_eq!(
                moba_attention_gated_par(&q, &k, &v, &gate, 16, workers).data,
                moba_attention_gated(&q, &k, &v, &gate, 16).data,
                "gated workers={workers}"
            );
            assert_eq!(
                fused_moba_attention(&q, &k, &v, 16, 2, workers).data,
                fused_moba_attention(&q, &k, &v, 16, 2, 1).data,
                "fused workers={workers}"
            );
        }
    }

    #[test]
    fn fused_covering_topk_equals_full() {
        let q = rand_t(&[48, 1, 8], 50);
        let k = rand_t(&[48, 1, 8], 51);
        let v = rand_t(&[48, 1, 8], 52);
        let a = fused_moba_attention(&q, &k, &v, 16, 3, 1);
        let b = full_attention(&q, &k, &v);
        assert!(a.max_abs_diff(&b) < 1e-5);
    }
}
