//! MoBA gating in pure Rust (paper Eq. 5-6 + causality rules).
//!
//! Bit-for-bit mirror of `python/compile/kernels/ref.py::moba_gate`
//! (including the deterministic low-index tie-break), checked against
//! golden files in `rust/tests/golden_parity.rs`. The router
//! (`coordinator::router`) and the serving gate statistics build on this.

use crate::tensor::Tensor;

/// Forced-selection / future-exclusion magnitude of the affinity bias
/// scheme (current block `+BIG`, future blocks `-BIG`). Shared by the
/// two-pass gate, the fused streaming kernel and the cached decode path
/// so the three stay bit-identical.
pub(crate) const BIG: f32 = 1e30;

/// Boolean gate for all heads/queries: `gate[h][t][i]` says whether query
/// t of head h attends KV block i.
#[derive(Clone, Debug)]
pub struct Gate {
    pub heads: usize,
    pub n: usize,
    pub n_blocks: usize,
    bits: Vec<bool>,
}

impl Gate {
    #[inline]
    pub fn get(&self, h: usize, t: usize, i: usize) -> bool {
        self.bits[(h * self.n + t) * self.n_blocks + i]
    }

    /// Selected block indices for one (head, query), ascending, without
    /// allocating — the form the streaming kernels iterate.
    pub fn selected_iter(&self, h: usize, t: usize) -> impl Iterator<Item = usize> + '_ {
        let off = (h * self.n + t) * self.n_blocks;
        self.bits[off..off + self.n_blocks]
            .iter()
            .enumerate()
            .filter_map(|(i, &b)| b.then_some(i))
    }

    /// Selected block indices for one (head, query), materialized
    /// (diagnostics and tests; hot paths use [`Gate::selected_iter`]).
    pub fn selected(&self, h: usize, t: usize) -> Vec<usize> {
        self.selected_iter(h, t).collect()
    }

    /// Total selected (query, block) pairs — the routing workload size.
    pub fn total_selected(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }
}

/// Mean-pool keys into per-block representatives.
/// k: [N, H, D] -> pooled [ceil(N/block), H, D].
///
/// N need not be divisible by the block size: the trailing partial block
/// (the in-progress *current* block during incremental decode) is averaged
/// over its actual length. For divisible N this is bit-identical to the
/// historical divisible-only version, which keeps the Python golden parity
/// intact; the `BlockPoolCache` running-sum update mirrors the exact
/// accumulation order here so cached and recomputed pooling agree
/// bit-for-bit.
pub fn mean_pool_blocks(k: &Tensor, block_size: usize) -> Tensor {
    let (n, h, d) = (k.shape[0], k.shape[1], k.shape[2]);
    assert!(block_size > 0, "block_size must be positive");
    let nb = (n + block_size - 1) / block_size;
    let mut out = Tensor::zeros(&[nb, h, d]);
    for b in 0..nb {
        let hi = ((b + 1) * block_size).min(n);
        for t in b * block_size..hi {
            for hh in 0..h {
                let src = (t * h + hh) * d;
                let dst = (b * h + hh) * d;
                for dd in 0..d {
                    out.data[dst + dd] += k.data[src + dd];
                }
            }
        }
        let inv = 1.0 / (hi - b * block_size) as f32;
        for hh in 0..h {
            let dst = (b * h + hh) * d;
            for x in out.data[dst..dst + d].iter_mut() {
                *x *= inv;
            }
        }
    }
    out
}

/// Affinity scores `s[h][t][i] = <q[t,h], pooled[i,h]>` with the causal
/// rules applied: current block forced (+1e30), future blocks excluded
/// (-1e30), and the low-index tie-break bias (-i * 1e-6) — identical to
/// the Python oracle so selections agree bit-for-bit.
pub fn affinity_scores(q: &Tensor, pooled: &Tensor, block_size: usize) -> Tensor {
    let (n, h, d) = (q.shape[0], q.shape[1], q.shape[2]);
    let nb = pooled.shape[0];
    let mut s = Tensor::zeros(&[h, n, nb]);
    for t in 0..n {
        let cur = t / block_size;
        for hh in 0..h {
            let qoff = (t * h + hh) * d;
            for i in 0..nb {
                let idx = (hh * n + t) * nb + i;
                if i == cur {
                    s.data[idx] = BIG - i as f32 * 1e-6;
                } else if i > cur {
                    s.data[idx] = -BIG - i as f32 * 1e-6;
                } else {
                    let poff = (i * h + hh) * d;
                    let mut dot = 0.0f32;
                    for dd in 0..d {
                        dot += q.data[qoff + dd] * pooled.data[poff + dd];
                    }
                    s.data[idx] = dot - i as f32 * 1e-6;
                }
            }
        }
    }
    s
}

/// The MoBA gate: top-k over the biased scores, future blocks excluded.
///
/// The k-th-largest threshold uses `select_nth_unstable_by` with
/// `f32::total_cmp` — O(nb) expected per row instead of the previous
/// O(nb log nb) full sort, and total over NaN instead of panicking.
/// Selections are unchanged: the k-th largest value is the same threshold
/// either way (`rust/benches/router_bench.rs` asserts the counts).
pub fn moba_gate(q: &Tensor, k: &Tensor, block_size: usize, topk: usize) -> Gate {
    let (n, h, _) = (q.shape[0], q.shape[1], q.shape[2]);
    let nb = (n + block_size - 1) / block_size;
    let pooled = mean_pool_blocks(k, block_size);
    let s = affinity_scores(q, &pooled, block_size);
    let kk = topk.min(nb);
    let mut bits = vec![false; h * n * nb];
    let mut row = vec![0.0f32; nb];
    let mut scratch = vec![0.0f32; nb];
    for hh in 0..h {
        for t in 0..n {
            let cur = t / block_size;
            let off = (hh * n + t) * nb;
            row.copy_from_slice(&s.data[off..off + nb]);
            scratch.copy_from_slice(&row);
            let (_, kth, _) =
                scratch.select_nth_unstable_by(kk - 1, |a, b| b.total_cmp(a));
            let kth = *kth;
            for i in 0..nb {
                bits[off + i] = row[i] >= kth && i <= cur;
            }
        }
    }
    Gate { heads: h, n, n_blocks: nb, bits }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_t(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
    }

    #[test]
    fn mean_pool_correct() {
        // two blocks of size 2, one head, d=1
        let k = Tensor::from_vec(&[4, 1, 1], vec![1.0, 3.0, 5.0, 9.0]).unwrap();
        let p = mean_pool_blocks(&k, 2);
        assert_eq!(p.shape, vec![2, 1, 1]);
        assert_eq!(p.data, vec![2.0, 7.0]);
    }

    #[test]
    fn mean_pool_ragged_tail() {
        // 5 tokens, block 2: tail block of one token pools to itself
        let k = Tensor::from_vec(&[5, 1, 1], vec![1.0, 3.0, 5.0, 9.0, 4.0]).unwrap();
        let p = mean_pool_blocks(&k, 2);
        assert_eq!(p.shape, vec![3, 1, 1]);
        assert_eq!(p.data, vec![2.0, 7.0, 4.0]);
    }

    #[test]
    fn gate_handles_partial_current_block() {
        // N not divisible by block: the in-progress tail block is the
        // current block for its queries and must still be forced-selected.
        let q = rand_t(&[37, 2, 8], 21);
        let k = rand_t(&[37, 2, 8], 22);
        let g = moba_gate(&q, &k, 16, 2);
        assert_eq!(g.n_blocks, 3);
        for h in 0..2 {
            for t in 0..37 {
                assert!(g.get(h, t, t / 16), "h={h} t={t}");
                let avail = t / 16 + 1;
                assert_eq!(g.selected(h, t).len(), 2usize.min(avail), "h={h} t={t}");
            }
        }
    }

    #[test]
    fn current_block_always_selected() {
        let q = rand_t(&[64, 2, 8], 1);
        let k = rand_t(&[64, 2, 8], 2);
        let g = moba_gate(&q, &k, 16, 2);
        for h in 0..2 {
            for t in 0..64 {
                assert!(g.get(h, t, t / 16), "h={h} t={t}");
            }
        }
    }

    #[test]
    fn no_future_blocks() {
        let q = rand_t(&[64, 2, 8], 3);
        let k = rand_t(&[64, 2, 8], 4);
        let g = moba_gate(&q, &k, 16, 3);
        for h in 0..2 {
            for t in 0..64 {
                for i in (t / 16 + 1)..4 {
                    assert!(!g.get(h, t, i), "future block selected h={h} t={t} i={i}");
                }
            }
        }
    }

    #[test]
    fn selection_count_exact() {
        let q = rand_t(&[128, 1, 8], 5);
        let k = rand_t(&[128, 1, 8], 6);
        let topk = 3;
        let g = moba_gate(&q, &k, 32, topk);
        for t in 0..128 {
            let avail = t / 32 + 1;
            assert_eq!(g.selected(0, t).len(), topk.min(avail), "t={t}");
        }
    }

    #[test]
    fn topk_one_is_current_only() {
        let q = rand_t(&[64, 1, 4], 7);
        let k = rand_t(&[64, 1, 4], 8);
        let g = moba_gate(&q, &k, 16, 1);
        for t in 0..64 {
            assert_eq!(g.selected(0, t), vec![t / 16]);
        }
    }

    #[test]
    fn gate_selects_highest_affinity_history() {
        // keys constant within block: pooled == key value, so history
        // selection must follow the constructed ordering.
        let n = 64;
        let bs = 16;
        let mut kdat = vec![0.0f32; n * 1 * 1];
        // block means 1, 9, 5, 3 — for the last query (cur=3) with topk=3,
        // history picks blocks 1 (9) and 2 (5).
        let means = [1.0, 9.0, 5.0, 3.0];
        for (i, row) in kdat.iter_mut().enumerate() {
            *row = means[i / bs];
        }
        let k = Tensor::from_vec(&[n, 1, 1], kdat).unwrap();
        let q = Tensor::ones(&[n, 1, 1]);
        let g = moba_gate(&q, &k, bs, 3);
        assert_eq!(g.selected(0, n - 1), vec![1, 2, 3]);
    }
}
