//! Pluggable attention backends: every layer of the crate that invokes
//! attention (serving engine, router, experiments, benches) goes through
//! the [`AttentionBackend`] trait instead of hard-wired kernel calls.
//!
//! Three implementations:
//!
//! - [`FullAttention`] — causal full attention; decode *recomputes* the
//!   whole sequence per token (O(N²·D) per step), the honest model of a
//!   serving path with no KV cache.
//! - [`MobaAttention`] — the existing gated block-sparse kernel; decode
//!   also recomputes (gate + sparse attention over the whole prefix).
//! - [`CachedDecodeBackend`] — prefill once, then O(k·B·D) incremental
//!   decode against [`KvCache`] + [`BlockPoolCache`]: each step gates
//!   against the cached block representatives (O(N/B·D)) and attends only
//!   the top-k selected blocks. Its outputs are bit-identical to the
//!   recompute backends (same arithmetic in the same order), which the
//!   parity tests in `tests/property_invariants.rs` and
//!   `tests/golden_parity.rs` pin down.
//!
//! The trait exposes both the batch path (`forward`, prefill-shaped) and
//! the incremental path (`prefill` + `decode`), plus the gate for
//! dispatch-plan construction (`coordinator::RoutingPlan::from_backend`).

use anyhow::{bail, Result};

use crate::tensor::Tensor;

use super::attention::{dot, full_attention, moba_attention, OnlineRow};
use super::gate::{moba_gate, Gate};
use super::kv_cache::{BlockPoolCache, KvCache};

/// Forced-selection / exclusion magnitude — must match `gate::affinity_scores`.
const BIG: f32 = 1e30;

/// A swappable attention implementation with an incremental decode state.
pub trait AttentionBackend {
    /// Stable identifier for logs, benches and CLI selection.
    fn name(&self) -> &'static str;

    /// Stateless batch attention over a full sequence: q, k, v `[N, H, D]`
    /// → out `[N, H, D]`. Does not touch the incremental state.
    fn forward(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor;

    /// The block gate this backend would apply to a batch input, if it is
    /// a gated (sparse) backend; `None` for dense backends.
    fn gate(&self, _q: &Tensor, _k: &Tensor) -> Option<Gate> {
        None
    }

    /// Drop all incremental state.
    fn reset(&mut self);

    /// Ingest a prompt into the incremental state (must be empty) and
    /// return per-position outputs `[N, H, D]`.
    fn prefill(&mut self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor;

    /// Append one token (q/k/v rows, each `[H * D]`) and return its
    /// attention output row `[H * D]`.
    fn decode(&mut self, q_row: &[f32], k_row: &[f32], v_row: &[f32]) -> Vec<f32>;

    /// Tokens currently held in the incremental state.
    fn seq_len(&self) -> usize;
}

fn last_row(out: &Tensor) -> Vec<f32> {
    let (n, h, d) = (out.shape[0], out.shape[1], out.shape[2]);
    out.data[(n - 1) * h * d..n * h * d].to_vec()
}

// ---------------------------------------------------------------------------
// recompute backends: keep the raw q/k/v streams, re-run the batch kernel
// ---------------------------------------------------------------------------

/// Causal full attention; decode recomputes the entire prefix each step.
pub struct FullAttention {
    heads: usize,
    head_dim: usize,
    q_hist: Vec<f32>,
    cache: KvCache,
}

impl FullAttention {
    pub fn new(heads: usize, head_dim: usize) -> FullAttention {
        FullAttention { heads, head_dim, q_hist: Vec::new(), cache: KvCache::new(heads, head_dim) }
    }

    fn history_tensors(&self) -> (Tensor, Tensor, Tensor) {
        let n = self.cache.len();
        let q = Tensor::from_vec(&[n, self.heads, self.head_dim], self.q_hist.clone())
            .expect("query history layout is always consistent");
        (q, self.cache.k_tensor(), self.cache.v_tensor())
    }
}

impl AttentionBackend for FullAttention {
    fn name(&self) -> &'static str {
        "full"
    }

    fn forward(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        full_attention(q, k, v)
    }

    fn reset(&mut self) {
        self.q_hist.clear();
        self.cache.clear();
    }

    fn prefill(&mut self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        debug_assert!(self.cache.is_empty(), "prefill on non-empty state");
        self.q_hist.extend_from_slice(&q.data);
        self.cache.append_tensors(k, v);
        full_attention(q, k, v)
    }

    fn decode(&mut self, q_row: &[f32], k_row: &[f32], v_row: &[f32]) -> Vec<f32> {
        self.q_hist.extend_from_slice(q_row);
        self.cache.append(k_row, v_row);
        let (q, k, v) = self.history_tensors();
        last_row(&full_attention(&q, &k, &v))
    }

    fn seq_len(&self) -> usize {
        self.cache.len()
    }
}

/// MoBA gate + block-sparse attention; decode recomputes gate and
/// attention over the entire prefix each step.
pub struct MobaAttention {
    heads: usize,
    head_dim: usize,
    block_size: usize,
    topk: usize,
    q_hist: Vec<f32>,
    cache: KvCache,
}

impl MobaAttention {
    pub fn new(heads: usize, head_dim: usize, block_size: usize, topk: usize) -> MobaAttention {
        assert!(block_size > 0 && topk > 0);
        MobaAttention {
            heads,
            head_dim,
            block_size,
            topk,
            q_hist: Vec::new(),
            cache: KvCache::new(heads, head_dim),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn topk(&self) -> usize {
        self.topk
    }
}

impl AttentionBackend for MobaAttention {
    fn name(&self) -> &'static str {
        "moba"
    }

    fn forward(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        moba_attention(q, k, v, self.block_size, self.topk)
    }

    fn gate(&self, q: &Tensor, k: &Tensor) -> Option<Gate> {
        Some(moba_gate(q, k, self.block_size, self.topk))
    }

    fn reset(&mut self) {
        self.q_hist.clear();
        self.cache.clear();
    }

    fn prefill(&mut self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        debug_assert!(self.cache.is_empty(), "prefill on non-empty state");
        self.q_hist.extend_from_slice(&q.data);
        self.cache.append_tensors(k, v);
        moba_attention(q, k, v, self.block_size, self.topk)
    }

    fn decode(&mut self, q_row: &[f32], k_row: &[f32], v_row: &[f32]) -> Vec<f32> {
        self.q_hist.extend_from_slice(q_row);
        self.cache.append(k_row, v_row);
        let n = self.cache.len();
        let q = Tensor::from_vec(&[n, self.heads, self.head_dim], self.q_hist.clone())
            .expect("query history layout is always consistent");
        let out = moba_attention(
            &q,
            &self.cache.k_tensor(),
            &self.cache.v_tensor(),
            self.block_size,
            self.topk,
        );
        last_row(&out)
    }

    fn seq_len(&self) -> usize {
        self.cache.len()
    }
}

// ---------------------------------------------------------------------------
// cached incremental decode
// ---------------------------------------------------------------------------

/// What a cached decode step computes per token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodePolicy {
    /// Dense row over the whole cache — O(N·D) per token. Matches
    /// `full_attention` recompute bit-for-bit (the paper's §3.3
    /// full-attention-decode deployment mode, now without the recompute).
    Full,
    /// Gate against cached block representatives, attend top-k blocks —
    /// O(N/B·D + k·B·D) per token. Matches `moba_attention` recompute
    /// bit-for-bit.
    Sparse,
}

/// Prefill-once / incremental-decode backend over `KvCache` +
/// `BlockPoolCache`. Stores no query history: decode cost is independent
/// of how many tokens were generated before (given a fixed context size).
pub struct CachedDecodeBackend {
    policy: DecodePolicy,
    block_size: usize,
    topk: usize,
    cache: KvCache,
    pool: BlockPoolCache,
}

impl CachedDecodeBackend {
    pub fn new(
        heads: usize,
        head_dim: usize,
        block_size: usize,
        topk: usize,
        policy: DecodePolicy,
    ) -> CachedDecodeBackend {
        assert!(block_size > 0 && topk > 0);
        CachedDecodeBackend {
            policy,
            block_size,
            topk,
            cache: KvCache::new(heads, head_dim),
            pool: BlockPoolCache::new(block_size, heads, head_dim),
        }
    }

    pub fn policy(&self) -> DecodePolicy {
        self.policy
    }

    /// Resident bytes of the cached decode state (KV payload; the block
    /// pool adds `1/block_size` of that again).
    pub fn payload_bytes(&self) -> usize {
        self.cache.payload_bytes()
    }

    /// Dense decode row: stream every cached position, same arithmetic and
    /// order as `full_attention`'s inner loop for the last query row.
    fn decode_dense(&self, q_row: &[f32], out: &mut [f32]) {
        let (h, d) = (self.cache.heads(), self.cache.head_dim());
        let t = self.cache.len() - 1;
        let scale = 1.0 / (d as f32).sqrt();
        for hh in 0..h {
            let qh = &q_row[hh * d..(hh + 1) * d];
            let mut row = OnlineRow::new(d);
            for j in 0..=t {
                let s = dot(qh, self.cache.k_at(j, hh)) * scale;
                row.push(s, self.cache.v_at(j, hh));
            }
            row.finish(&mut out[hh * d..(hh + 1) * d]);
        }
    }

    /// Sparse decode row: biased affinity against cached block means
    /// (plain sequential dot, exactly `gate::affinity_scores`), the same
    /// `select_nth_unstable_by` threshold as `gate::moba_gate`, then the
    /// block-sparse streaming loop of `moba_attention_gated`.
    fn decode_sparse(&self, q_row: &[f32], out: &mut [f32]) {
        let (h, d) = (self.cache.heads(), self.cache.head_dim());
        let t = self.cache.len() - 1;
        let scale = 1.0 / (d as f32).sqrt();
        let nb = self.pool.n_blocks();
        let cur = t / self.block_size;
        let kk = self.topk.min(nb);
        let mut mean = vec![0.0f32; d];
        let mut scores = vec![0.0f32; nb];
        let mut scratch = vec![0.0f32; nb];
        for hh in 0..h {
            let qh = &q_row[hh * d..(hh + 1) * d];
            for (i, score) in scores.iter_mut().enumerate() {
                *score = if i == cur {
                    BIG - i as f32 * 1e-6
                } else if i > cur {
                    -BIG - i as f32 * 1e-6
                } else {
                    self.pool.mean_into(i, hh, &mut mean);
                    let mut aff = 0.0f32;
                    for dd in 0..d {
                        aff += qh[dd] * mean[dd];
                    }
                    aff - i as f32 * 1e-6
                };
            }
            scratch.copy_from_slice(&scores);
            let (_, kth, _) = scratch.select_nth_unstable_by(kk - 1, |a, b| b.total_cmp(a));
            let kth = *kth;
            let mut row = OnlineRow::new(d);
            for (b, &score) in scores.iter().enumerate() {
                if score >= kth && b <= cur {
                    let hi = ((b + 1) * self.block_size).min(t + 1);
                    for j in b * self.block_size..hi {
                        let s = dot(qh, self.cache.k_at(j, hh)) * scale;
                        row.push(s, self.cache.v_at(j, hh));
                    }
                }
            }
            row.finish(&mut out[hh * d..(hh + 1) * d]);
        }
    }
}

impl AttentionBackend for CachedDecodeBackend {
    fn name(&self) -> &'static str {
        match self.policy {
            DecodePolicy::Full => "cached-full",
            DecodePolicy::Sparse => "cached-sparse",
        }
    }

    fn forward(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        match self.policy {
            DecodePolicy::Full => full_attention(q, k, v),
            DecodePolicy::Sparse => moba_attention(q, k, v, self.block_size, self.topk),
        }
    }

    fn gate(&self, q: &Tensor, k: &Tensor) -> Option<Gate> {
        match self.policy {
            DecodePolicy::Full => None,
            DecodePolicy::Sparse => Some(moba_gate(q, k, self.block_size, self.topk)),
        }
    }

    fn reset(&mut self) {
        self.cache.clear();
        self.pool.clear();
    }

    fn prefill(&mut self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        debug_assert!(self.cache.is_empty(), "prefill on non-empty state");
        self.cache.append_tensors(k, v);
        self.pool.append_tensor(k);
        self.forward(q, k, v)
    }

    fn decode(&mut self, q_row: &[f32], k_row: &[f32], v_row: &[f32]) -> Vec<f32> {
        self.cache.append(k_row, v_row);
        self.pool.append(k_row);
        let w = self.cache.row_width();
        let mut out = vec![0.0f32; w];
        match self.policy {
            DecodePolicy::Full => self.decode_dense(q_row, &mut out),
            DecodePolicy::Sparse => self.decode_sparse(q_row, &mut out),
        }
        out
    }

    fn seq_len(&self) -> usize {
        self.cache.len()
    }
}

// ---------------------------------------------------------------------------
// construction by name (CLI / config selection)
// ---------------------------------------------------------------------------

/// Named backend kinds, for CLI flags and serving configs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// `FullAttention` (recompute decode)
    RecomputeFull,
    /// `MobaAttention` (recompute decode)
    RecomputeMoba,
    /// `CachedDecodeBackend` with `DecodePolicy::Full`
    CachedFull,
    /// `CachedDecodeBackend` with `DecodePolicy::Sparse`
    CachedSparse,
}

impl BackendKind {
    pub fn parse(name: &str) -> Result<BackendKind> {
        Ok(match name {
            "full" => BackendKind::RecomputeFull,
            "moba" => BackendKind::RecomputeMoba,
            "cached-full" => BackendKind::CachedFull,
            "cached-sparse" | "cached" => BackendKind::CachedSparse,
            other => bail!(
                "unknown backend '{other}' (expected full | moba | cached-full | cached-sparse)"
            ),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::RecomputeFull => "full",
            BackendKind::RecomputeMoba => "moba",
            BackendKind::CachedFull => "cached-full",
            BackendKind::CachedSparse => "cached-sparse",
        }
    }
}

/// Build a boxed backend of the given kind and geometry.
pub fn build_backend(
    kind: BackendKind,
    heads: usize,
    head_dim: usize,
    block_size: usize,
    topk: usize,
) -> Box<dyn AttentionBackend> {
    match kind {
        BackendKind::RecomputeFull => Box::new(FullAttention::new(heads, head_dim)),
        BackendKind::RecomputeMoba => {
            Box::new(MobaAttention::new(heads, head_dim, block_size, topk))
        }
        BackendKind::CachedFull => Box::new(CachedDecodeBackend::new(
            heads,
            head_dim,
            block_size,
            topk,
            DecodePolicy::Full,
        )),
        BackendKind::CachedSparse => Box::new(CachedDecodeBackend::new(
            heads,
            head_dim,
            block_size,
            topk,
            DecodePolicy::Sparse,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_t(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
    }

    fn row(t: &Tensor, i: usize) -> &[f32] {
        let w = t.shape[1] * t.shape[2];
        &t.data[i * w..(i + 1) * w]
    }

    fn sub(t: &Tensor, n: usize) -> Tensor {
        let w = t.shape[1] * t.shape[2];
        Tensor::from_vec(&[n, t.shape[1], t.shape[2]], t.data[..n * w].to_vec()).unwrap()
    }

    #[test]
    fn forward_matches_free_kernels() {
        let (q, k, v) = (rand_t(&[48, 2, 8], 1), rand_t(&[48, 2, 8], 2), rand_t(&[48, 2, 8], 3));
        let full = FullAttention::new(2, 8);
        assert_eq!(full.forward(&q, &k, &v).data, full_attention(&q, &k, &v).data);
        let moba = MobaAttention::new(2, 8, 16, 2);
        assert_eq!(
            moba.forward(&q, &k, &v).data,
            moba_attention(&q, &k, &v, 16, 2).data
        );
        let cached = CachedDecodeBackend::new(2, 8, 16, 2, DecodePolicy::Sparse);
        assert_eq!(
            cached.forward(&q, &k, &v).data,
            moba_attention(&q, &k, &v, 16, 2).data
        );
    }

    #[test]
    fn cached_full_decode_bitwise_matches_batch_rows() {
        let n = 41; // deliberately ragged
        let (q, k, v) = (rand_t(&[n, 2, 8], 4), rand_t(&[n, 2, 8], 5), rand_t(&[n, 2, 8], 6));
        let mut cached = CachedDecodeBackend::new(2, 8, 16, 2, DecodePolicy::Full);
        for t in 0..n {
            let got = cached.decode(row(&q, t), row(&k, t), row(&v, t));
            let prefix = full_attention(&sub(&q, t + 1), &sub(&k, t + 1), &sub(&v, t + 1));
            assert_eq!(got.as_slice(), row(&prefix, t), "t={t}");
        }
        assert_eq!(cached.seq_len(), n);
    }

    #[test]
    fn cached_sparse_decode_bitwise_matches_batch_rows() {
        let n = 53;
        let (bs, topk) = (16, 2);
        let (q, k, v) = (rand_t(&[n, 2, 8], 7), rand_t(&[n, 2, 8], 8), rand_t(&[n, 2, 8], 9));
        let mut cached = CachedDecodeBackend::new(2, 8, bs, topk, DecodePolicy::Sparse);
        for t in 0..n {
            let got = cached.decode(row(&q, t), row(&k, t), row(&v, t));
            let prefix =
                moba_attention(&sub(&q, t + 1), &sub(&k, t + 1), &sub(&v, t + 1), bs, topk);
            assert_eq!(got.as_slice(), row(&prefix, t), "t={t}");
        }
    }

    #[test]
    fn recompute_backends_match_batch_rows() {
        let n = 24;
        let (q, k, v) = (rand_t(&[n, 1, 8], 10), rand_t(&[n, 1, 8], 11), rand_t(&[n, 1, 8], 12));
        let mut full = FullAttention::new(1, 8);
        let mut moba = MobaAttention::new(1, 8, 8, 2);
        for t in 0..n {
            let gf = full.decode(row(&q, t), row(&k, t), row(&v, t));
            let gm = moba.decode(row(&q, t), row(&k, t), row(&v, t));
            let pf = full_attention(&sub(&q, t + 1), &sub(&k, t + 1), &sub(&v, t + 1));
            let pm = moba_attention(&sub(&q, t + 1), &sub(&k, t + 1), &sub(&v, t + 1), 8, 2);
            assert_eq!(gf.as_slice(), row(&pf, t), "full t={t}");
            assert_eq!(gm.as_slice(), row(&pm, t), "moba t={t}");
        }
    }

    #[test]
    fn prefill_then_decode_matches_all_decode() {
        let n = 40;
        let split = 25; // ragged prefill boundary
        let (q, k, v) = (rand_t(&[n, 2, 8], 13), rand_t(&[n, 2, 8], 14), rand_t(&[n, 2, 8], 15));
        let mut a = CachedDecodeBackend::new(2, 8, 16, 2, DecodePolicy::Sparse);
        let out = a.prefill(&sub(&q, split), &sub(&k, split), &sub(&v, split));
        assert_eq!(out.shape, vec![split, 2, 8]);
        let mut b = CachedDecodeBackend::new(2, 8, 16, 2, DecodePolicy::Sparse);
        for t in 0..split {
            b.decode(row(&q, t), row(&k, t), row(&v, t));
        }
        for t in split..n {
            let ra = a.decode(row(&q, t), row(&k, t), row(&v, t));
            let rb = b.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(ra, rb, "t={t}");
        }
    }

    #[test]
    fn gate_exposed_only_by_sparse_backends() {
        let (q, k) = (rand_t(&[32, 1, 8], 16), rand_t(&[32, 1, 8], 17));
        assert!(FullAttention::new(1, 8).gate(&q, &k).is_none());
        let g = MobaAttention::new(1, 8, 16, 2).gate(&q, &k).unwrap();
        assert_eq!(g.n_blocks, 2);
        assert!(CachedDecodeBackend::new(1, 8, 16, 2, DecodePolicy::Full)
            .gate(&q, &k)
            .is_none());
        assert!(CachedDecodeBackend::new(1, 8, 16, 2, DecodePolicy::Sparse)
            .gate(&q, &k)
            .is_some());
    }

    #[test]
    fn reset_clears_state() {
        let (q, k, v) = (rand_t(&[8, 1, 4], 18), rand_t(&[8, 1, 4], 19), rand_t(&[8, 1, 4], 20));
        for kind in [
            BackendKind::RecomputeFull,
            BackendKind::RecomputeMoba,
            BackendKind::CachedFull,
            BackendKind::CachedSparse,
        ] {
            let mut b = build_backend(kind, 1, 4, 4, 2);
            b.prefill(&q, &k, &v);
            assert_eq!(b.seq_len(), 8, "{}", b.name());
            b.reset();
            assert_eq!(b.seq_len(), 0, "{}", b.name());
        }
    }

    #[test]
    fn backend_kind_parse_roundtrip() {
        for kind in [
            BackendKind::RecomputeFull,
            BackendKind::RecomputeMoba,
            BackendKind::CachedFull,
            BackendKind::CachedSparse,
        ] {
            assert_eq!(BackendKind::parse(kind.label()).unwrap(), kind);
        }
        assert_eq!(BackendKind::parse("cached").unwrap(), BackendKind::CachedSparse);
        assert!(BackendKind::parse("nope").is_err());
    }
}
