//! Pluggable attention backends: every layer of the crate that invokes
//! attention (serving engine, router, experiments, benches) goes through
//! the [`AttentionBackend`] trait instead of hard-wired kernel calls.
//!
//! Four implementations here (a fifth, the paged-pool backend
//! `sparse::paged::PagedMobaAttention`, lives with its pool):
//!
//! - [`FullAttention`] — causal full attention; decode *recomputes* the
//!   whole sequence per token (O(N²·D) per step), the honest model of a
//!   serving path with no KV cache.
//! - [`MobaAttention`] — the two-pass gated block-sparse kernel; decode
//!   also recomputes (gate + sparse attention over the whole prefix).
//! - [`CachedDecodeBackend`] — prefill once, then O(k·B·D) incremental
//!   decode against [`KvCache`] + [`BlockPoolCache`]: each step gates
//!   against the cached block representatives (O(N/B·D)) and attends only
//!   the top-k selected blocks.
//! - [`FusedMobaAttention`] — the Flash-MoBA-style hot path: prefill runs
//!   the fused single-pass kernel (scoring, top-k selection and
//!   online-softmax streaming interleaved per query row, no materialized
//!   `Gate`), decode runs the same fused row against the caches.
//!
//! All backends take a `workers` count (see `sparse::parallel`); outputs
//! are bit-identical across worker counts AND across backends of the same
//! math (fused vs two-pass, cached vs recompute) — same arithmetic in the
//! same order — which the parity tests in `tests/property_invariants.rs`,
//! `tests/thread_invariance.rs` and `tests/golden_parity.rs` pin down.
//!
//! The trait exposes both the batch path (`forward`, prefill-shaped) and
//! the incremental path (`prefill` + `decode`), plus the gate for
//! dispatch-plan construction (`coordinator::RoutingPlan::from_backend`).

use anyhow::{bail, Result};

use crate::tensor::Tensor;

use super::attention::{
    dot, full_attention_par, fused_moba_attention, fused_moba_attention_with_reps, fused_row,
    moba_attention_par, FusedScratch, OnlineRow,
};
use super::gate::{moba_gate, Gate};
use super::kv_cache::{BlockPoolCache, KvCache};
use super::paged::{PagedMobaAttention, SwapImage};

/// A swappable attention implementation with an incremental decode state.
/// `Send` so whole decode sessions can migrate onto scheduler worker
/// threads (`serve::scheduler`).
pub trait AttentionBackend: Send {
    /// Stable identifier for logs, benches and CLI selection.
    fn name(&self) -> &'static str;

    /// Stateless batch attention over a full sequence: q, k, v `[N, H, D]`
    /// → out `[N, H, D]`. Does not touch the incremental state.
    fn forward(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor;

    /// The block gate this backend would apply to a batch input, if it is
    /// a gated (sparse) backend; `None` for dense backends.
    fn gate(&self, _q: &Tensor, _k: &Tensor) -> Option<Gate> {
        None
    }

    /// Drop all incremental state.
    fn reset(&mut self);

    /// Ingest a prompt into the incremental state (must be empty) and
    /// return per-position outputs `[N, H, D]`.
    fn prefill(&mut self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor;

    /// Append one token (q/k/v rows, each `[H * D]`) and return its
    /// attention output row `[H * D]`.
    fn decode(&mut self, q_row: &[f32], k_row: &[f32], v_row: &[f32]) -> Vec<f32>;

    /// Tokens currently held in the incremental state.
    fn seq_len(&self) -> usize;

    /// Duplicate the incremental state into an independent session that
    /// shares the ingested prefix — O(1) copy-on-write where the backend
    /// supports it (`sparse::paged`). Private-cache backends refuse:
    /// cloning their state would double memory, which is exactly what
    /// the paged pool exists to avoid.
    fn fork(&self) -> Result<Box<dyn AttentionBackend>> {
        bail!(
            "backend '{}' has no copy-on-write state; use 'paged' for prefix sharing",
            self.name()
        )
    }

    /// Drop the incremental state and hand its memory back to whatever
    /// shared store backs it, returning the number of pool blocks
    /// actually reclaimed — the preemption hook behind serving-layer
    /// eviction (`serve::ServeEngine::evict_session`). Re-ingesting the
    /// same token stream afterwards must reproduce the pre-eviction
    /// state bit-for-bit (the re-prefill resume contract). Only backends
    /// over a shared pool support this; private-cache backends refuse —
    /// their memory frees with the session, there is nothing to reclaim
    /// early.
    fn evict(&mut self) -> Result<usize> {
        bail!(
            "backend '{}' holds private caches; eviction requires the 'paged' pool",
            self.name()
        )
    }

    /// Tag this session's future pool allocations with an arena affinity
    /// (e.g. the decode shard that owns it), so a shared block store can
    /// keep a session's blocks local to its worker. Purely a locality
    /// hint: it never changes which bytes are stored or any attention
    /// output. Backends without a shared pool ignore it.
    fn set_arena(&mut self, _arena: usize) {}

    /// Like [`fork`], but share only the first `blocks` *full* pool
    /// blocks — the suffix-only eviction hook: a swapped session's
    /// shared prefix is re-attached through this while its private tail
    /// comes back from a [`SwapImage`] via [`swap_in`]. Only pool-backed
    /// backends support it.
    ///
    /// [`fork`]: AttentionBackend::fork
    /// [`swap_in`]: AttentionBackend::swap_in
    fn fork_prefix(&self, _blocks: usize) -> Result<Box<dyn AttentionBackend>> {
        bail!(
            "backend '{}' has no copy-on-write state; use 'paged' for prefix sharing",
            self.name()
        )
    }

    /// Copy-only, checksummed snapshot of this backend's pool blocks
    /// from logical block `from_block` on — the host-tier swap-out hook
    /// behind `serve::ServeEngine::swap_out_session`. The backend state
    /// is untouched; callers [`evict`] afterwards and later restore the
    /// bytes with [`swap_in`] instead of re-prefilling. Only backends
    /// over a shared pool support this.
    ///
    /// [`evict`]: AttentionBackend::evict
    /// [`swap_in`]: AttentionBackend::swap_in
    fn swap_out(&self, _from_block: usize) -> Result<SwapImage> {
        bail!(
            "backend '{}' has no pool-backed state to swap out; use 'paged'",
            self.name()
        )
    }

    /// Restore a [`swap_out`] image onto this backend, which must hold
    /// exactly the image's prefix blocks (nothing for a whole-session
    /// image, or a [`fork_prefix`]-ed shared prefix). Verifies the
    /// image checksum and returns the pool blocks allocated; every
    /// subsequent decode must match the re-prefill resume bit-for-bit.
    ///
    /// [`swap_out`]: AttentionBackend::swap_out
    /// [`fork_prefix`]: AttentionBackend::fork_prefix
    fn swap_in(&mut self, _image: &SwapImage) -> Result<usize> {
        bail!(
            "backend '{}' has no pool-backed state to swap in; use 'paged'",
            self.name()
        )
    }
}

fn last_row(out: &Tensor) -> Vec<f32> {
    let (n, h, d) = (out.shape[0], out.shape[1], out.shape[2]);
    out.data[(n - 1) * h * d..n * h * d].to_vec()
}

// ---------------------------------------------------------------------------
// recompute backends: keep the raw q/k/v streams, re-run the batch kernel
// ---------------------------------------------------------------------------

/// Causal full attention; decode recomputes the entire prefix each step.
pub struct FullAttention {
    heads: usize,
    head_dim: usize,
    workers: usize,
    q_hist: Vec<f32>,
    cache: KvCache,
}

impl FullAttention {
    pub fn new(heads: usize, head_dim: usize) -> FullAttention {
        FullAttention {
            heads,
            head_dim,
            workers: 1,
            q_hist: Vec::new(),
            cache: KvCache::new(heads, head_dim),
        }
    }

    /// Spread batch/prefill rows over `workers` threads (bit-identical
    /// output for any count).
    pub fn with_workers(mut self, workers: usize) -> FullAttention {
        self.workers = workers.max(1);
        self
    }

    fn history_tensors(&self) -> (Tensor, Tensor, Tensor) {
        let n = self.cache.len();
        let q = Tensor::from_vec(&[n, self.heads, self.head_dim], self.q_hist.clone())
            .expect("query history layout is always consistent");
        (q, self.cache.k_tensor(), self.cache.v_tensor())
    }
}

impl AttentionBackend for FullAttention {
    fn name(&self) -> &'static str {
        "full"
    }

    fn forward(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        full_attention_par(q, k, v, self.workers)
    }

    fn reset(&mut self) {
        self.q_hist.clear();
        self.cache.clear();
    }

    fn prefill(&mut self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        debug_assert!(self.cache.is_empty(), "prefill on non-empty state");
        self.q_hist.extend_from_slice(&q.data);
        self.cache.append_tensors(k, v);
        full_attention_par(q, k, v, self.workers)
    }

    fn decode(&mut self, q_row: &[f32], k_row: &[f32], v_row: &[f32]) -> Vec<f32> {
        self.q_hist.extend_from_slice(q_row);
        self.cache.append(k_row, v_row);
        let (q, k, v) = self.history_tensors();
        last_row(&full_attention_par(&q, &k, &v, self.workers))
    }

    fn seq_len(&self) -> usize {
        self.cache.len()
    }
}

/// MoBA gate + block-sparse attention (two passes); decode recomputes
/// gate and attention over the entire prefix each step.
pub struct MobaAttention {
    heads: usize,
    head_dim: usize,
    block_size: usize,
    topk: usize,
    workers: usize,
    q_hist: Vec<f32>,
    cache: KvCache,
}

impl MobaAttention {
    pub fn new(heads: usize, head_dim: usize, block_size: usize, topk: usize) -> MobaAttention {
        assert!(block_size > 0 && topk > 0);
        MobaAttention {
            heads,
            head_dim,
            block_size,
            topk,
            workers: 1,
            q_hist: Vec::new(),
            cache: KvCache::new(heads, head_dim),
        }
    }

    /// Spread batch/prefill rows over `workers` threads (bit-identical
    /// output for any count).
    pub fn with_workers(mut self, workers: usize) -> MobaAttention {
        self.workers = workers.max(1);
        self
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn topk(&self) -> usize {
        self.topk
    }
}

impl AttentionBackend for MobaAttention {
    fn name(&self) -> &'static str {
        "moba"
    }

    fn forward(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        moba_attention_par(q, k, v, self.block_size, self.topk, self.workers)
    }

    fn gate(&self, q: &Tensor, k: &Tensor) -> Option<Gate> {
        Some(moba_gate(q, k, self.block_size, self.topk))
    }

    fn reset(&mut self) {
        self.q_hist.clear();
        self.cache.clear();
    }

    fn prefill(&mut self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        debug_assert!(self.cache.is_empty(), "prefill on non-empty state");
        self.q_hist.extend_from_slice(&q.data);
        self.cache.append_tensors(k, v);
        moba_attention_par(q, k, v, self.block_size, self.topk, self.workers)
    }

    fn decode(&mut self, q_row: &[f32], k_row: &[f32], v_row: &[f32]) -> Vec<f32> {
        self.q_hist.extend_from_slice(q_row);
        self.cache.append(k_row, v_row);
        let n = self.cache.len();
        let q = Tensor::from_vec(&[n, self.heads, self.head_dim], self.q_hist.clone())
            .expect("query history layout is always consistent");
        let out = moba_attention_par(
            &q,
            &self.cache.k_tensor(),
            &self.cache.v_tensor(),
            self.block_size,
            self.topk,
            self.workers,
        );
        last_row(&out)
    }

    fn seq_len(&self) -> usize {
        self.cache.len()
    }
}

// ---------------------------------------------------------------------------
// cached incremental decode
// ---------------------------------------------------------------------------

/// Materialized per-head block-representative slabs (`[H, cap, D]`) kept
/// in sync with a `BlockPoolCache` — the slabs the fused gate scans.
/// Steady-state decode sync is O(H·D): a token append changes exactly one
/// block's running sum (the last), so only that block's means refresh; a
/// full refill happens only when the block capacity grows. Every value is
/// `sum * (1/count)` from the pool, so the slab always equals what
/// `means_for_head_into` would recompute, bit-for-bit.
struct RepsCache {
    /// per-head block capacity of `data` (grows in powers of two)
    cap: usize,
    data: Vec<f32>,
}

impl RepsCache {
    fn new() -> RepsCache {
        RepsCache { cap: 0, data: Vec::new() }
    }

    fn clear(&mut self) {
        self.cap = 0;
        self.data.clear();
    }

    fn stride(&self) -> usize {
        self.cap
    }

    /// Head `hh`'s `[nb, D]` slab.
    fn head_slab(&self, hh: usize, nb: usize, d: usize) -> &[f32] {
        let off = hh * self.cap * d;
        &self.data[off..off + nb * d]
    }

    /// Refresh after pool appends. `full` forces rebuilding every block
    /// (prefill); otherwise only the last block — the only one a single
    /// appended token can touch — is refreshed, unless capacity grew.
    fn sync(&mut self, pool: &BlockPoolCache, heads: usize, d: usize, full: bool) {
        let nb = pool.n_blocks();
        if nb == 0 {
            return;
        }
        if full || nb > self.cap {
            self.cap = self.cap.max(nb.next_power_of_two());
            self.data.clear();
            self.data.resize(heads * self.cap * d, 0.0);
            for hh in 0..heads {
                let off = hh * self.cap * d;
                pool.means_for_head_into(hh, &mut self.data[off..off + nb * d]);
            }
        } else {
            for hh in 0..heads {
                let off = (hh * self.cap + (nb - 1)) * d;
                pool.mean_into(nb - 1, hh, &mut self.data[off..off + d]);
            }
        }
    }
}

/// The fused-decode state bundle: KV storage, running-sum pooling, the
/// materialized representative slabs and the per-token scratch, with the
/// append→sync ordering encapsulated in one place. Shared by
/// `CachedDecodeBackend` and `FusedMobaAttention` so their lifecycles
/// cannot drift (the `RepsCache` contract — sync after every append,
/// full rebuild after bulk ingest — lives here and nowhere else).
struct FusedDecodeState {
    cache: KvCache,
    pool: BlockPoolCache,
    reps: RepsCache,
    scratch: FusedScratch,
}

impl FusedDecodeState {
    fn new(heads: usize, head_dim: usize, block_size: usize) -> FusedDecodeState {
        FusedDecodeState {
            cache: KvCache::new(heads, head_dim),
            pool: BlockPoolCache::new(block_size, heads, head_dim),
            reps: RepsCache::new(),
            scratch: FusedScratch::new(head_dim, 0, block_size),
        }
    }

    fn clear(&mut self) {
        self.cache.clear();
        self.pool.clear();
        self.reps.clear();
    }

    /// Bulk-ingest a prompt. `sync_reps` is false for dense-decode
    /// backends that never gate (the pool still accumulates so a later
    /// policy could resume, matching the previous behavior).
    fn ingest_prompt(&mut self, k: &Tensor, v: &Tensor, sync_reps: bool) {
        self.cache.append_tensors(k, v);
        self.pool.append_tensor(k);
        if sync_reps {
            let (h, d) = (self.cache.heads(), self.cache.head_dim());
            self.reps.sync(&self.pool, h, d, true);
        }
    }

    /// Append one token's K/V and keep the representative slabs current.
    fn append_token(&mut self, k_row: &[f32], v_row: &[f32], sync_reps: bool) {
        self.cache.append(k_row, v_row);
        self.pool.append(k_row);
        if sync_reps {
            let (h, d) = (self.cache.heads(), self.cache.head_dim());
            self.reps.sync(&self.pool, h, d, false);
        }
    }

    /// The representative slabs + per-head stride (in blocks), for the
    /// fused prefill to reuse instead of pooling K a second time.
    fn reps_slab(&self) -> (&[f32], usize) {
        (&self.reps.data, self.reps.stride())
    }

    /// One fused decode row: gate against the cached representatives,
    /// select top-k, stream the selected blocks — all in a single pass
    /// per head (`attention::fused_row` running directly over the cache's
    /// `[len, H, D]` storage). Runs inline on the calling thread: a
    /// decode row is microseconds of work, far below thread-spawn cost
    /// (the `workers` knob applies to prefill; inter-request decode
    /// parallelism belongs to the scheduler's shards). The scratch lives
    /// here, so nothing is allocated per token. Bit-identical to
    /// recomputing `moba_attention` over the whole prefix and taking the
    /// last row.
    fn decode_row(&mut self, topk: usize, q_row: &[f32]) -> Vec<f32> {
        let (h, d) = (self.cache.heads(), self.cache.head_dim());
        let block_size = self.pool.block_size();
        let t = self.cache.len() - 1;
        let scale = 1.0 / (d as f32).sqrt();
        let nb = self.pool.n_blocks();
        let kk = topk.min(nb);
        let (kd, vd) = (self.cache.k_data(), self.cache.v_data());
        let mut out = vec![0.0f32; self.cache.row_width()];
        self.scratch.ensure_blocks(nb);
        for hh in 0..h {
            let qh = &q_row[hh * d..(hh + 1) * d];
            let out_row = &mut out[hh * d..(hh + 1) * d];
            let reps_h = self.reps.head_slab(hh, nb, d);
            fused_row(
                qh, kd, vd, reps_h, h, hh, d, block_size, kk, t, scale, &mut self.scratch,
                out_row,
            );
        }
        out
    }
}

/// What a cached decode step computes per token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodePolicy {
    /// Dense row over the whole cache — O(N·D) per token. Matches
    /// `full_attention` recompute bit-for-bit (the paper's §3.3
    /// full-attention-decode deployment mode, now without the recompute).
    Full,
    /// Gate against cached block representatives, attend top-k blocks —
    /// O(N/B·D + k·B·D) per token. Matches `moba_attention` recompute
    /// bit-for-bit.
    Sparse,
}

/// Prefill-once / incremental-decode backend over `KvCache` +
/// `BlockPoolCache`. Stores no query history: decode cost is independent
/// of how many tokens were generated before (given a fixed context size).
pub struct CachedDecodeBackend {
    policy: DecodePolicy,
    block_size: usize,
    topk: usize,
    workers: usize,
    state: FusedDecodeState,
}

impl CachedDecodeBackend {
    pub fn new(
        heads: usize,
        head_dim: usize,
        block_size: usize,
        topk: usize,
        policy: DecodePolicy,
    ) -> CachedDecodeBackend {
        assert!(block_size > 0 && topk > 0);
        CachedDecodeBackend {
            policy,
            block_size,
            topk,
            workers: 1,
            state: FusedDecodeState::new(heads, head_dim, block_size),
        }
    }

    /// Spread batch/prefill rows over `workers` threads (bit-identical
    /// output for any count; decode rows run inline — too little work per
    /// token to pay a spawn).
    pub fn with_workers(mut self, workers: usize) -> CachedDecodeBackend {
        self.workers = workers.max(1);
        self
    }

    pub fn policy(&self) -> DecodePolicy {
        self.policy
    }

    /// Resident bytes of the cached decode state (KV payload; the block
    /// pool adds `1/block_size` of that again).
    pub fn payload_bytes(&self) -> usize {
        self.state.cache.payload_bytes()
    }

    /// Dense decode row: stream every cached position, same arithmetic and
    /// order as `full_attention`'s inner loop for the last query row.
    /// Inline, like the fused decode row.
    fn decode_dense(&self, q_row: &[f32], out: &mut [f32]) {
        let cache = &self.state.cache;
        let (h, d) = (cache.heads(), cache.head_dim());
        let t = cache.len() - 1;
        let scale = 1.0 / (d as f32).sqrt();
        let mut row = OnlineRow::new(d);
        for hh in 0..h {
            let qh = &q_row[hh * d..(hh + 1) * d];
            row.reset();
            for j in 0..=t {
                let s = dot(qh, cache.k_at(j, hh)) * scale;
                row.push(s, cache.v_at(j, hh));
            }
            row.finish_into(&mut out[hh * d..(hh + 1) * d]);
        }
    }
}

impl AttentionBackend for CachedDecodeBackend {
    fn name(&self) -> &'static str {
        match self.policy {
            DecodePolicy::Full => "cached-full",
            DecodePolicy::Sparse => "cached-sparse",
        }
    }

    fn forward(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        match self.policy {
            DecodePolicy::Full => full_attention_par(q, k, v, self.workers),
            DecodePolicy::Sparse => {
                moba_attention_par(q, k, v, self.block_size, self.topk, self.workers)
            }
        }
    }

    fn gate(&self, q: &Tensor, k: &Tensor) -> Option<Gate> {
        match self.policy {
            DecodePolicy::Full => None,
            DecodePolicy::Sparse => Some(moba_gate(q, k, self.block_size, self.topk)),
        }
    }

    fn reset(&mut self) {
        self.state.clear();
    }

    fn prefill(&mut self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        debug_assert!(self.state.cache.is_empty(), "prefill on non-empty state");
        self.state.ingest_prompt(k, v, self.policy == DecodePolicy::Sparse);
        self.forward(q, k, v)
    }

    fn decode(&mut self, q_row: &[f32], k_row: &[f32], v_row: &[f32]) -> Vec<f32> {
        self.state.append_token(k_row, v_row, self.policy == DecodePolicy::Sparse);
        match self.policy {
            DecodePolicy::Full => {
                let mut out = vec![0.0f32; self.state.cache.row_width()];
                self.decode_dense(q_row, &mut out);
                out
            }
            DecodePolicy::Sparse => self.state.decode_row(self.topk, q_row),
        }
    }

    fn seq_len(&self) -> usize {
        self.state.cache.len()
    }
}

// ---------------------------------------------------------------------------
// fused single-pass backend (Flash-MoBA style)
// ---------------------------------------------------------------------------

/// The fused hot path: batch/prefill through `fused_moba_attention`
/// (gating, selection and streaming interleaved in one pass — no
/// materialized `Gate`), incremental decode through the same fused row
/// over [`KvCache`] + [`BlockPoolCache`]. Outputs are bit-identical to
/// `MobaAttention` / `CachedDecodeBackend(Sparse)`; only the schedule
/// differs.
pub struct FusedMobaAttention {
    block_size: usize,
    topk: usize,
    workers: usize,
    state: FusedDecodeState,
}

impl FusedMobaAttention {
    pub fn new(
        heads: usize,
        head_dim: usize,
        block_size: usize,
        topk: usize,
    ) -> FusedMobaAttention {
        assert!(block_size > 0 && topk > 0);
        FusedMobaAttention {
            block_size,
            topk,
            workers: 1,
            state: FusedDecodeState::new(heads, head_dim, block_size),
        }
    }

    /// Spread batch/prefill rows over `workers` threads (bit-identical
    /// output for any count; decode rows run inline — too little work per
    /// token to pay a spawn).
    pub fn with_workers(mut self, workers: usize) -> FusedMobaAttention {
        self.workers = workers.max(1);
        self
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn topk(&self) -> usize {
        self.topk
    }

    /// Resident bytes of the cached decode state.
    pub fn payload_bytes(&self) -> usize {
        self.state.cache.payload_bytes()
    }
}

impl AttentionBackend for FusedMobaAttention {
    fn name(&self) -> &'static str {
        "fused"
    }

    fn forward(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        fused_moba_attention(q, k, v, self.block_size, self.topk, self.workers)
    }

    /// The gate the fused pass applies implicitly, materialized for
    /// dispatch-plan construction (off the hot path: the fused kernel
    /// itself never builds this).
    fn gate(&self, q: &Tensor, k: &Tensor) -> Option<Gate> {
        Some(moba_gate(q, k, self.block_size, self.topk))
    }

    fn reset(&mut self) {
        self.state.clear();
    }

    fn prefill(&mut self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        debug_assert!(self.state.cache.is_empty(), "prefill on non-empty state");
        self.state.ingest_prompt(k, v, true);
        // reuse the cache's running-sum pooling as the fused pass's
        // representatives (bit-identical to mean_pool_blocks) instead of
        // pooling K a second time
        let (reps, stride) = self.state.reps_slab();
        fused_moba_attention_with_reps(
            q,
            k,
            v,
            self.block_size,
            self.topk,
            self.workers,
            reps,
            stride,
        )
    }

    fn decode(&mut self, q_row: &[f32], k_row: &[f32], v_row: &[f32]) -> Vec<f32> {
        self.state.append_token(k_row, v_row, true);
        self.state.decode_row(self.topk, q_row)
    }

    fn seq_len(&self) -> usize {
        self.state.cache.len()
    }
}

// ---------------------------------------------------------------------------
// construction by name (CLI / config selection)
// ---------------------------------------------------------------------------

/// Named backend kinds, for CLI flags and serving configs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// `FullAttention` (recompute decode)
    RecomputeFull,
    /// `MobaAttention` (recompute decode)
    RecomputeMoba,
    /// `CachedDecodeBackend` with `DecodePolicy::Full`
    CachedFull,
    /// `CachedDecodeBackend` with `DecodePolicy::Sparse`
    CachedSparse,
    /// `FusedMobaAttention` (fused single-pass prefill + cached decode)
    Fused,
    /// `sparse::paged::PagedMobaAttention` (block-table decode over a
    /// shared copy-on-write pool; standalone construction gets a private
    /// unbounded pool)
    Paged,
}

impl BackendKind {
    pub fn parse(name: &str) -> Result<BackendKind> {
        Ok(match name {
            "full" => BackendKind::RecomputeFull,
            "moba" => BackendKind::RecomputeMoba,
            "cached-full" => BackendKind::CachedFull,
            "cached-sparse" | "cached" => BackendKind::CachedSparse,
            "fused" => BackendKind::Fused,
            "paged" => BackendKind::Paged,
            other => bail!(
                "unknown backend '{other}' \
                 (expected full|moba|cached-full|cached-sparse|fused|paged)"
            ),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::RecomputeFull => "full",
            BackendKind::RecomputeMoba => "moba",
            BackendKind::CachedFull => "cached-full",
            BackendKind::CachedSparse => "cached-sparse",
            BackendKind::Fused => "fused",
            BackendKind::Paged => "paged",
        }
    }
}

/// Build a boxed backend of the given kind and geometry with an explicit
/// worker count for its batch/prefill (and cached-decode head) loops.
pub fn build_backend_par(
    kind: BackendKind,
    heads: usize,
    head_dim: usize,
    block_size: usize,
    topk: usize,
    workers: usize,
) -> Box<dyn AttentionBackend> {
    match kind {
        BackendKind::RecomputeFull => {
            Box::new(FullAttention::new(heads, head_dim).with_workers(workers))
        }
        BackendKind::RecomputeMoba => {
            Box::new(MobaAttention::new(heads, head_dim, block_size, topk).with_workers(workers))
        }
        BackendKind::CachedFull => Box::new(
            CachedDecodeBackend::new(heads, head_dim, block_size, topk, DecodePolicy::Full)
                .with_workers(workers),
        ),
        BackendKind::CachedSparse => Box::new(
            CachedDecodeBackend::new(heads, head_dim, block_size, topk, DecodePolicy::Sparse)
                .with_workers(workers),
        ),
        BackendKind::Fused => Box::new(
            FusedMobaAttention::new(heads, head_dim, block_size, topk).with_workers(workers),
        ),
        BackendKind::Paged => Box::new(
            PagedMobaAttention::with_private_pool(heads, head_dim, block_size, topk)
                .with_workers(workers),
        ),
    }
}

/// Build a boxed backend of the given kind and geometry, single-threaded.
pub fn build_backend(
    kind: BackendKind,
    heads: usize,
    head_dim: usize,
    block_size: usize,
    topk: usize,
) -> Box<dyn AttentionBackend> {
    build_backend_par(kind, heads, head_dim, block_size, topk, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::attention::{full_attention, moba_attention};
    use crate::util::rng::Rng;

    fn rand_t(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
    }

    fn row(t: &Tensor, i: usize) -> &[f32] {
        let w = t.shape[1] * t.shape[2];
        &t.data[i * w..(i + 1) * w]
    }

    fn sub(t: &Tensor, n: usize) -> Tensor {
        let w = t.shape[1] * t.shape[2];
        Tensor::from_vec(&[n, t.shape[1], t.shape[2]], t.data[..n * w].to_vec()).unwrap()
    }

    #[test]
    fn forward_matches_free_kernels() {
        let (q, k, v) = (rand_t(&[48, 2, 8], 1), rand_t(&[48, 2, 8], 2), rand_t(&[48, 2, 8], 3));
        let full = FullAttention::new(2, 8);
        assert_eq!(full.forward(&q, &k, &v).data, full_attention(&q, &k, &v).data);
        let moba = MobaAttention::new(2, 8, 16, 2);
        assert_eq!(
            moba.forward(&q, &k, &v).data,
            moba_attention(&q, &k, &v, 16, 2).data
        );
        let cached = CachedDecodeBackend::new(2, 8, 16, 2, DecodePolicy::Sparse);
        assert_eq!(
            cached.forward(&q, &k, &v).data,
            moba_attention(&q, &k, &v, 16, 2).data
        );
        let fused = FusedMobaAttention::new(2, 8, 16, 2);
        assert_eq!(
            fused.forward(&q, &k, &v).data,
            moba_attention(&q, &k, &v, 16, 2).data
        );
    }

    #[test]
    fn cached_full_decode_bitwise_matches_batch_rows() {
        let n = 41; // deliberately ragged
        let (q, k, v) = (rand_t(&[n, 2, 8], 4), rand_t(&[n, 2, 8], 5), rand_t(&[n, 2, 8], 6));
        let mut cached = CachedDecodeBackend::new(2, 8, 16, 2, DecodePolicy::Full);
        for t in 0..n {
            let got = cached.decode(row(&q, t), row(&k, t), row(&v, t));
            let prefix = full_attention(&sub(&q, t + 1), &sub(&k, t + 1), &sub(&v, t + 1));
            assert_eq!(got.as_slice(), row(&prefix, t), "t={t}");
        }
        assert_eq!(cached.seq_len(), n);
    }

    #[test]
    fn cached_sparse_decode_bitwise_matches_batch_rows() {
        let n = 53;
        let (bs, topk) = (16, 2);
        let (q, k, v) = (rand_t(&[n, 2, 8], 7), rand_t(&[n, 2, 8], 8), rand_t(&[n, 2, 8], 9));
        let mut cached = CachedDecodeBackend::new(2, 8, bs, topk, DecodePolicy::Sparse);
        for t in 0..n {
            let got = cached.decode(row(&q, t), row(&k, t), row(&v, t));
            let prefix =
                moba_attention(&sub(&q, t + 1), &sub(&k, t + 1), &sub(&v, t + 1), bs, topk);
            assert_eq!(got.as_slice(), row(&prefix, t), "t={t}");
        }
    }

    #[test]
    fn fused_decode_bitwise_matches_batch_rows() {
        // the fused backend's decode must ALSO reproduce the two-pass
        // batch kernel's last row bit-for-bit at every (ragged) length
        let n = 53;
        let (bs, topk) = (16, 2);
        let (q, k, v) =
            (rand_t(&[n, 2, 8], 31), rand_t(&[n, 2, 8], 32), rand_t(&[n, 2, 8], 33));
        let mut fused = FusedMobaAttention::new(2, 8, bs, topk);
        for t in 0..n {
            let got = fused.decode(row(&q, t), row(&k, t), row(&v, t));
            let prefix =
                moba_attention(&sub(&q, t + 1), &sub(&k, t + 1), &sub(&v, t + 1), bs, topk);
            assert_eq!(got.as_slice(), row(&prefix, t), "t={t}");
        }
        assert_eq!(fused.seq_len(), n);
    }

    #[test]
    fn recompute_backends_match_batch_rows() {
        let n = 24;
        let (q, k, v) = (rand_t(&[n, 1, 8], 10), rand_t(&[n, 1, 8], 11), rand_t(&[n, 1, 8], 12));
        let mut full = FullAttention::new(1, 8);
        let mut moba = MobaAttention::new(1, 8, 8, 2);
        for t in 0..n {
            let gf = full.decode(row(&q, t), row(&k, t), row(&v, t));
            let gm = moba.decode(row(&q, t), row(&k, t), row(&v, t));
            let pf = full_attention(&sub(&q, t + 1), &sub(&k, t + 1), &sub(&v, t + 1));
            let pm = moba_attention(&sub(&q, t + 1), &sub(&k, t + 1), &sub(&v, t + 1), 8, 2);
            assert_eq!(gf.as_slice(), row(&pf, t), "full t={t}");
            assert_eq!(gm.as_slice(), row(&pm, t), "moba t={t}");
        }
    }

    #[test]
    fn prefill_then_decode_matches_all_decode() {
        let n = 40;
        let split = 25; // ragged prefill boundary
        let (q, k, v) = (rand_t(&[n, 2, 8], 13), rand_t(&[n, 2, 8], 14), rand_t(&[n, 2, 8], 15));
        let mut a = CachedDecodeBackend::new(2, 8, 16, 2, DecodePolicy::Sparse);
        let out = a.prefill(&sub(&q, split), &sub(&k, split), &sub(&v, split));
        assert_eq!(out.shape, vec![split, 2, 8]);
        let mut b = CachedDecodeBackend::new(2, 8, 16, 2, DecodePolicy::Sparse);
        for t in 0..split {
            b.decode(row(&q, t), row(&k, t), row(&v, t));
        }
        for t in split..n {
            let ra = a.decode(row(&q, t), row(&k, t), row(&v, t));
            let rb = b.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(ra, rb, "t={t}");
        }
    }

    #[test]
    fn fused_prefill_then_decode_matches_all_decode() {
        let n = 40;
        let split = 25; // ragged prefill boundary
        let (q, k, v) = (rand_t(&[n, 2, 8], 34), rand_t(&[n, 2, 8], 35), rand_t(&[n, 2, 8], 36));
        let mut a = FusedMobaAttention::new(2, 8, 16, 2);
        let out = a.prefill(&sub(&q, split), &sub(&k, split), &sub(&v, split));
        assert_eq!(out.shape, vec![split, 2, 8]);
        let mut b = FusedMobaAttention::new(2, 8, 16, 2);
        for t in 0..split {
            b.decode(row(&q, t), row(&k, t), row(&v, t));
        }
        for t in split..n {
            let ra = a.decode(row(&q, t), row(&k, t), row(&v, t));
            let rb = b.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(ra, rb, "t={t}");
        }
    }

    #[test]
    fn gate_exposed_only_by_sparse_backends() {
        let (q, k) = (rand_t(&[32, 1, 8], 16), rand_t(&[32, 1, 8], 17));
        assert!(FullAttention::new(1, 8).gate(&q, &k).is_none());
        let g = MobaAttention::new(1, 8, 16, 2).gate(&q, &k).unwrap();
        assert_eq!(g.n_blocks, 2);
        assert!(CachedDecodeBackend::new(1, 8, 16, 2, DecodePolicy::Full)
            .gate(&q, &k)
            .is_none());
        assert!(CachedDecodeBackend::new(1, 8, 16, 2, DecodePolicy::Sparse)
            .gate(&q, &k)
            .is_some());
        assert!(FusedMobaAttention::new(1, 8, 16, 2).gate(&q, &k).is_some());
    }

    #[test]
    fn reset_clears_state() {
        let (q, k, v) = (rand_t(&[8, 1, 4], 18), rand_t(&[8, 1, 4], 19), rand_t(&[8, 1, 4], 20));
        for kind in [
            BackendKind::RecomputeFull,
            BackendKind::RecomputeMoba,
            BackendKind::CachedFull,
            BackendKind::CachedSparse,
            BackendKind::Fused,
            BackendKind::Paged,
        ] {
            let mut b = build_backend(kind, 1, 4, 4, 2);
            b.prefill(&q, &k, &v);
            assert_eq!(b.seq_len(), 8, "{}", b.name());
            b.reset();
            assert_eq!(b.seq_len(), 0, "{}", b.name());
        }
    }

    #[test]
    fn backend_kind_parse_roundtrip() {
        for kind in [
            BackendKind::RecomputeFull,
            BackendKind::RecomputeMoba,
            BackendKind::CachedFull,
            BackendKind::CachedSparse,
            BackendKind::Fused,
            BackendKind::Paged,
        ] {
            assert_eq!(BackendKind::parse(kind.label()).unwrap(), kind);
        }
        assert_eq!(BackendKind::parse("cached").unwrap(), BackendKind::CachedSparse);
        assert!(BackendKind::parse("nope").is_err());
    }

    #[test]
    fn workers_do_not_change_backend_outputs() {
        let (q, k, v) = (rand_t(&[37, 2, 8], 60), rand_t(&[37, 2, 8], 61), rand_t(&[37, 2, 8], 62));
        for kind in [
            BackendKind::RecomputeFull,
            BackendKind::RecomputeMoba,
            BackendKind::CachedFull,
            BackendKind::CachedSparse,
            BackendKind::Fused,
            BackendKind::Paged,
        ] {
            let mut one = build_backend_par(kind, 2, 8, 16, 2, 1);
            let mut many = build_backend_par(kind, 2, 8, 16, 2, 4);
            assert_eq!(
                one.prefill(&q, &k, &v).data,
                many.prefill(&q, &k, &v).data,
                "{} prefill",
                one.name()
            );
            let (qe, ke, ve) =
                (rand_t(&[1, 2, 8], 63), rand_t(&[1, 2, 8], 64), rand_t(&[1, 2, 8], 65));
            assert_eq!(
                one.decode(&qe.data, &ke.data, &ve.data),
                many.decode(&qe.data, &ke.data, &ve.data),
                "{} decode",
                one.name()
            );
        }
    }
}
