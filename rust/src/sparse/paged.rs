//! Paged shared KV pool with copy-on-write prefix sharing — the
//! vLLM-style scaling move for heavy-traffic serving over MoBA's
//! block-granular cache layout.
//!
//! MoBA already partitions the KV cache into fixed-size blocks (the gate
//! pools keys per block), so the physical page size of a paged pool *is*
//! the MoBA block size `B`:
//!
//! - [`PagedKvPool`] owns refcounted physical KV blocks (`[B, H, D]`
//!   K and V slabs plus the block's key running sum — the same running
//!   sum `BlockPoolCache` keeps, so representative means stay
//!   bit-identical to `mean_pool_blocks`);
//! - [`BlockTable`] maps one session's logical blocks to physical ids;
//! - [`PagedKvPool::fork`] shares a whole prefix in O(blocks) refcount
//!   bumps and zero data copies; a write into a *shared* tail block
//!   copies that one block first (copy-on-write), so S sessions sharing
//!   an N-token prefix hold O(N + S·tail) memory, not O(S·N);
//! - [`PagedMobaAttention`] is the [`AttentionBackend`] over a pool
//!   handle: fused single-pass prefill, and a decode row that streams
//!   K/V and representative means *through the block table*
//!   (`attention::fused_row_blocks`) — bit-identical to the
//!   private-cache backends (same `dot`/`dot2` accumulation order, same
//!   NaN-safe `>=` top-k selection, same `sum * (1/count)` means);
//! - [`PagedKvPool::evict`] is the preemption primitive behind
//!   oversubscribed serving: it reclaims exactly the blocks no live
//!   table references (a shared prefix survives the eviction of any
//!   forker), and re-ingesting the same token stream afterwards rebuilds
//!   the session bit-identically (the scheduler's re-prefill resume).
//!
//! Concurrency: the pool handle is `Arc<RwLock<..>>` so whole sessions
//! can migrate across scheduler decode shards (`serve::scheduler`).
//! Appends (and fork/release refcounting) take the write lock briefly;
//! the expensive attention row then streams under a *read* lock, so
//! decode shards run concurrently. This is sound because copy-on-write
//! guarantees a session's mapped blocks are immutable while it holds
//! references to them (another session's append can only CoW *its own*
//! tail, never rewrite a block someone else maps), so lock order cannot
//! change any session's bytes — outputs stay shard-count-invariant.

use std::sync::{Arc, RwLock};

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::sync;

use super::attention::{
    fused_moba_attention, fused_moba_attention_with_reps, fused_row_blocks, FusedScratch,
};
use super::backend::AttentionBackend;
use super::gate::{moba_gate, Gate};
use super::kv_cache::write_mean;

/// Pool handle shared by many sessions (and scheduler shards).
pub type SharedKvPool = Arc<RwLock<PagedKvPool>>;

/// Build a shareable pool handle. `capacity_blocks = None` is unbounded;
/// `Some(n)` makes allocation past `n` physical blocks an error (the
/// continuous scheduler admits against this capacity).
pub fn shared_pool(
    block_size: usize,
    heads: usize,
    head_dim: usize,
    capacity_blocks: Option<usize>,
) -> SharedKvPool {
    Arc::new(RwLock::new(PagedKvPool::new(block_size, heads, head_dim, capacity_blocks)))
}

/// Per-session logical→physical block mapping. Obtained from
/// [`PagedKvPool::fork`] or built empty; deliberately NOT `Clone` — the
/// only way to duplicate one is through the pool, which keeps refcounts
/// honest.
#[derive(Debug, Default)]
pub struct BlockTable {
    blocks: Vec<usize>,
    len: usize,
    /// arena affinity for future allocations (see [`PagedKvPool::alloc`])
    arena: usize,
    /// model layer this table's blocks are accounted under (multi-layer
    /// sessions hold one table per layer in the same shared pool)
    layer: usize,
}

impl BlockTable {
    pub fn new() -> BlockTable {
        BlockTable::default()
    }

    /// Arena this table's future allocations prefer (e.g. its decode
    /// worker's shard). Purely a locality hint — block ids never enter
    /// any attention arithmetic, so the arena cannot change outputs.
    pub fn arena(&self) -> usize {
        self.arena
    }

    pub fn set_arena(&mut self, arena: usize) {
        self.arena = arena;
    }

    /// Model layer this table's blocks are charged to in the pool's
    /// per-layer accounting. Purely bookkeeping — like the arena, the
    /// layer tag never enters any attention arithmetic.
    pub fn layer(&self) -> usize {
        self.layer
    }

    pub fn set_layer(&mut self, layer: usize) {
        self.layer = layer;
    }

    /// Tokens in this session's sequence.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Logical blocks currently mapped (`ceil(len / B)`).
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Physical id of logical block `b` (diagnostics and sharing tests).
    pub fn physical(&self, b: usize) -> usize {
        self.blocks[b]
    }
}

/// One block's byte-exact snapshot inside a [`SwapImage`]: the filled
/// K/V rows plus the block's key running sum, so a restore reproduces
/// the pool state (and therefore every later representative mean and
/// attention row) bit-for-bit.
#[derive(Clone, Debug)]
struct SwapBlock {
    fill: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    ksum: Vec<f32>,
}

/// Byte-exact, checksummed snapshot of a table's block suffix — the
/// host-memory swap tier's unit of storage. Produced by
/// [`PagedKvPool::extract_blocks`] (copy-only; the pool is untouched),
/// consumed by [`PagedKvPool::restore_blocks`] after the original
/// blocks were evicted. `first_block > 0` is the suffix-only case: the
/// refcounted shared prefix below it never left the pool and is
/// re-attached via [`PagedKvPool::fork_prefix`].
#[derive(Clone, Debug)]
pub struct SwapImage {
    /// logical block index extraction started at
    first_block: usize,
    /// table token count at extraction time
    len: usize,
    blocks: Vec<SwapBlock>,
    /// FNV-1a over geometry, fills and every f32 bit pattern — verified
    /// on restore so a corrupted host-tier copy fails loudly instead of
    /// silently serving wrong tokens
    checksum: u64,
}

impl SwapImage {
    /// Logical block index the snapshot starts at (blocks below it stay
    /// resident in the pool as a shared prefix).
    pub fn first_block(&self) -> usize {
        self.first_block
    }

    /// Table token count at extraction time (== restored table length).
    pub fn tokens(&self) -> usize {
        self.len
    }

    /// Snapshot blocks held — the swap-tier capacity this image charges.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Host-tier bytes this image holds (K + V + running sums).
    pub fn payload_bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| (b.k.len() + b.v.len() + b.ksum.len()) * std::mem::size_of::<f32>())
            .sum()
    }

    /// Chaos hook: perturb the stored checksum so the next restore fails
    /// verification — models a corrupted host-tier copy. Deliberately
    /// not an XOR: corrupting the same parked image twice must not
    /// cancel back to a valid checksum.
    pub fn corrupt_for_chaos(&mut self) {
        self.checksum = self.checksum.wrapping_add(1);
    }
}

fn fnv_u64(h: &mut u64, x: u64) {
    for b in x.to_le_bytes() {
        *h = (*h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
}

fn swap_checksum(first_block: usize, len: usize, blocks: &[SwapBlock]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    fnv_u64(&mut h, first_block as u64);
    fnv_u64(&mut h, len as u64);
    for blk in blocks {
        fnv_u64(&mut h, blk.fill as u64);
        for slab in [&blk.k, &blk.v, &blk.ksum] {
            for &x in slab.iter() {
                fnv_u64(&mut h, x.to_bits() as u64);
            }
        }
    }
    h
}

/// Refcounted fixed-size physical KV block store. All mutation goes
/// through a session's [`BlockTable`]; blocks referenced by more than
/// one table are immutable until copy-on-write hands the writer a
/// private copy.
pub struct PagedKvPool {
    block_size: usize,
    heads: usize,
    head_dim: usize,
    /// floats per physical block in `k`/`v` (`B * H * D`)
    slot: usize,
    /// physical K payload, `[n_phys, B, H, D]` row-major per block
    k: Vec<f32>,
    /// physical V payload, same layout
    v: Vec<f32>,
    /// per-block key running sums, `[n_phys, H * D]` — accumulated in
    /// token append order, exactly like `BlockPoolCache`
    ksum: Vec<f32>,
    /// tokens written into each physical block
    fill: Vec<usize>,
    /// tables referencing each physical block; 0 = free
    refs: Vec<usize>,
    /// free physical ids per arena, reused before the store grows — a
    /// freed block returns to the arena that last owned it, so a decode
    /// worker's sessions keep recycling worker-local (cache-warm) blocks
    free_lists: Vec<Vec<usize>>,
    /// arena each physical block currently belongs to
    arena_of: Vec<usize>,
    /// model layer each physical block is currently charged to
    layer_of: Vec<usize>,
    /// live blocks charged per layer (`sum == used`); grows on demand as
    /// deeper layers allocate
    used_by_layer: Vec<usize>,
    capacity: Option<usize>,
    used: usize,
}

impl PagedKvPool {
    pub fn new(
        block_size: usize,
        heads: usize,
        head_dim: usize,
        capacity_blocks: Option<usize>,
    ) -> PagedKvPool {
        assert!(block_size > 0 && heads > 0 && head_dim > 0);
        PagedKvPool {
            block_size,
            heads,
            head_dim,
            slot: block_size * heads * head_dim,
            k: Vec::new(),
            v: Vec::new(),
            ksum: Vec::new(),
            fill: Vec::new(),
            refs: Vec::new(),
            free_lists: vec![Vec::new()],
            arena_of: Vec::new(),
            layer_of: Vec::new(),
            used_by_layer: Vec::new(),
            capacity: capacity_blocks,
            used: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Physical blocks currently referenced by at least one table.
    pub fn used_blocks(&self) -> usize {
        self.used
    }

    /// Live blocks charged per model layer (index = layer; the vec only
    /// extends as far as the deepest layer that ever allocated). Sums to
    /// [`PagedKvPool::used_blocks`] — the per-layer breakdown behind the
    /// engine's layer-summed accounting.
    pub fn used_blocks_by_layer(&self) -> &[usize] {
        &self.used_by_layer
    }

    pub fn capacity_blocks(&self) -> Option<usize> {
        self.capacity
    }

    /// Blocks still allocatable under the capacity (`None` = unbounded).
    pub fn free_blocks(&self) -> Option<usize> {
        self.capacity.map(|c| c.saturating_sub(self.used))
    }

    /// Resident bytes of *unique* K/V block payload — the O(N + S·tail)
    /// number prefix sharing is about (a private `KvCache` per session
    /// would pay O(S·N)).
    pub fn payload_bytes(&self) -> usize {
        self.used * self.slot * 2 * std::mem::size_of::<f32>()
    }

    /// Allocate one physical block with `arena` affinity: prefer a block
    /// last homed in this arena (LIFO within the arena — the warmest
    /// candidate), else steal from the longest other free list (lowest
    /// index on ties, migrating the block's home), else grow the store.
    /// The arena only decides WHICH free id is handed out; the block is
    /// zeroed identically either way, and block ids never enter any
    /// attention arithmetic, so affinity cannot change outputs. The
    /// `layer` tag charges the block to that layer's usage counter.
    fn alloc(&mut self, arena: usize, layer: usize) -> Result<usize> {
        if let Some(cap) = self.capacity {
            if self.used >= cap {
                bail!("paged pool exhausted: {} blocks in use, capacity {cap}", self.used);
            }
        }
        if arena >= self.free_lists.len() {
            self.free_lists.resize_with(arena + 1, Vec::new);
        }
        if layer >= self.used_by_layer.len() {
            self.used_by_layer.resize(layer + 1, 0);
        }
        let w = self.heads * self.head_dim;
        self.used += 1;
        self.used_by_layer[layer] += 1;
        let donor = if !self.free_lists[arena].is_empty() {
            Some(arena)
        } else {
            self.free_lists
                .iter()
                .enumerate()
                .filter(|(_, l)| !l.is_empty())
                .max_by_key(|(i, l)| (l.len(), std::cmp::Reverse(*i)))
                .map(|(i, _)| i)
        };
        if let Some(d) = donor {
            let pid = self.free_lists[d].pop().expect("donor free list non-empty");
            self.arena_of[pid] = arena;
            self.layer_of[pid] = layer;
            self.fill[pid] = 0;
            self.refs[pid] = 1;
            self.ksum[pid * w..(pid + 1) * w].fill(0.0);
            return Ok(pid);
        }
        let pid = self.refs.len();
        self.k.resize((pid + 1) * self.slot, 0.0);
        self.v.resize((pid + 1) * self.slot, 0.0);
        self.ksum.resize((pid + 1) * w, 0.0);
        self.fill.push(0);
        self.refs.push(1);
        self.arena_of.push(arena);
        self.layer_of.push(layer);
        Ok(pid)
    }

    /// Append one token's K/V rows (each `[H * D]`) on behalf of `table`.
    /// Allocates a fresh block at block boundaries; a *shared* partial
    /// tail block is copied first (copy-on-write), so no other table ever
    /// observes the write. Errors only when a bounded pool is exhausted.
    pub fn append(&mut self, table: &mut BlockTable, k_row: &[f32], v_row: &[f32]) -> Result<()> {
        let w = self.heads * self.head_dim;
        assert_eq!(k_row.len(), w, "k row width");
        assert_eq!(v_row.len(), w, "v row width");
        let in_block = table.len % self.block_size;
        if in_block == 0 {
            let pid = self.alloc(table.arena, table.layer)?;
            table.blocks.push(pid);
        } else {
            let tail = *table.blocks.last().expect("partial tail implies a mapped block");
            if self.refs[tail] > 1 {
                // copy-on-write: divergence pays for its own private tail
                let copy = self.alloc(table.arena, table.layer)?;
                let n = self.fill[tail];
                debug_assert_eq!(n, in_block, "shared tail fill mismatch");
                let (src, dst) = (tail * self.slot, copy * self.slot);
                self.k.copy_within(src..src + n * w, dst);
                self.v.copy_within(src..src + n * w, dst);
                self.ksum.copy_within(tail * w..(tail + 1) * w, copy * w);
                self.fill[copy] = n;
                self.refs[tail] -= 1;
                *table.blocks.last_mut().expect("just read") = copy;
            }
        }
        let pid = *table.blocks.last().expect("tail block mapped");
        debug_assert_eq!(self.refs[pid], 1, "writing a shared block");
        debug_assert_eq!(self.fill[pid], in_block, "tail fill out of sync");
        let off = pid * self.slot + in_block * w;
        self.k[off..off + w].copy_from_slice(k_row);
        self.v[off..off + w].copy_from_slice(v_row);
        let soff = pid * w;
        for (s, &x) in self.ksum[soff..soff + w].iter_mut().zip(k_row) {
            *s += x;
        }
        self.fill[pid] += 1;
        table.len += 1;
        Ok(())
    }

    /// Bulk-append a whole `[N, H, D]` prefix (prefill path).
    pub fn append_tensors(&mut self, table: &mut BlockTable, k: &Tensor, v: &Tensor) -> Result<()> {
        assert_eq!(k.shape, v.shape, "k/v shape mismatch");
        assert_eq!(k.rank(), 3, "expected [N, H, D]");
        assert_eq!(k.shape[1], self.heads, "head count");
        assert_eq!(k.shape[2], self.head_dim, "head dim");
        let w = self.heads * self.head_dim;
        for t in 0..k.shape[0] {
            self.append(table, &k.data[t * w..(t + 1) * w], &v.data[t * w..(t + 1) * w])?;
        }
        Ok(())
    }

    /// Fork `table`: O(blocks) refcount bumps, zero bytes copied. Both
    /// sides keep reading the shared physical blocks; whichever writes a
    /// shared tail first pays the one-block copy.
    pub fn fork(&mut self, table: &BlockTable) -> BlockTable {
        for &pid in &table.blocks {
            self.refs[pid] += 1;
        }
        BlockTable {
            blocks: table.blocks.clone(),
            len: table.len,
            arena: table.arena,
            layer: table.layer,
        }
    }

    /// Release a table's references; blocks dropping to zero references
    /// return to their arena's free list for reuse.
    pub fn release(&mut self, table: &mut BlockTable) {
        for &pid in &table.blocks {
            self.refs[pid] -= 1;
            if self.refs[pid] == 0 {
                self.free_lists[self.arena_of[pid]].push(pid);
                self.used_by_layer[self.layer_of[pid]] -= 1;
                self.used -= 1;
            }
        }
        table.blocks.clear();
        table.len = 0;
    }

    /// Evict `table`: release its references and report how many physical
    /// blocks were actually reclaimed (refcount reached zero). Blocks a
    /// live table still references — a shared prefix under a forker —
    /// stay resident and are NOT counted; refcounts already encode
    /// exactly which bytes the rest of the system depends on.
    pub fn evict(&mut self, table: &mut BlockTable) -> usize {
        let before = self.used;
        self.release(table);
        before - self.used
    }

    /// Fork only `table`'s first `blocks` (full) blocks — the
    /// suffix-only eviction primitive: a swapped victim's refcounted
    /// shared prefix stays resident and is re-attached through this,
    /// while its private tail lives in a [`SwapImage`]. Like
    /// [`PagedKvPool::fork`], O(blocks) refcount bumps, zero copies.
    pub fn fork_prefix(&mut self, table: &BlockTable, blocks: usize) -> BlockTable {
        assert!(blocks <= table.n_blocks(), "prefix fork past the mapped range");
        for &pid in &table.blocks[..blocks] {
            debug_assert_eq!(self.fill[pid], self.block_size, "prefix fork of a partial block");
            self.refs[pid] += 1;
        }
        BlockTable {
            blocks: table.blocks[..blocks].to_vec(),
            len: blocks * self.block_size,
            arena: table.arena,
            layer: table.layer,
        }
    }

    /// Copy-only snapshot of `table`'s logical blocks `[from_block..)` —
    /// the host-tier swap-out primitive. The pool itself is untouched
    /// (no refcount, fill or free-list changes); callers evict the table
    /// afterwards and hold the image until [`restore_blocks`] brings the
    /// bytes back. The checksum covers geometry, fills and every f32 bit
    /// pattern, so restore-time verification catches a corrupted copy.
    ///
    /// [`restore_blocks`]: PagedKvPool::restore_blocks
    pub fn extract_blocks(&self, table: &BlockTable, from_block: usize) -> SwapImage {
        assert!(from_block <= table.n_blocks(), "extract past the mapped range");
        let w = self.heads * self.head_dim;
        let blocks: Vec<SwapBlock> = table.blocks[from_block..]
            .iter()
            .map(|&pid| {
                let off = pid * self.slot;
                let n = self.fill[pid] * w;
                SwapBlock {
                    fill: self.fill[pid],
                    k: self.k[off..off + n].to_vec(),
                    v: self.v[off..off + n].to_vec(),
                    ksum: self.ksum[pid * w..(pid + 1) * w].to_vec(),
                }
            })
            .collect();
        let checksum = swap_checksum(from_block, table.len(), &blocks);
        SwapImage { first_block: from_block, len: table.len(), blocks, checksum }
    }

    /// Reverse of [`extract_blocks`]: verify the checksum, then allocate
    /// fresh physical blocks and copy the snapshot onto the end of
    /// `table`, which must hold exactly the image's `first_block` full
    /// blocks (empty for a whole-session image, or a freshly
    /// [`fork_prefix`]-ed shared prefix for a suffix-only one). Returns
    /// the number of blocks allocated — identical to what re-ingesting
    /// the same tokens would have allocated, so pool occupancy (and
    /// every scheduling decision derived from it) cannot tell the two
    /// resume paths apart. A checksum mismatch fails before any
    /// allocation; a bounded pool running out mid-restore leaves the
    /// partial blocks on `table` for the caller to release.
    ///
    /// [`extract_blocks`]: PagedKvPool::extract_blocks
    /// [`fork_prefix`]: PagedKvPool::fork_prefix
    pub fn restore_blocks(&mut self, table: &mut BlockTable, image: &SwapImage) -> Result<usize> {
        if swap_checksum(image.first_block, image.len, &image.blocks) != image.checksum {
            bail!("swap image checksum mismatch: host-tier copy corrupted");
        }
        if table.n_blocks() != image.first_block
            || table.len != image.first_block * self.block_size
        {
            bail!(
                "swap restore onto a mismatched table: {} blocks / {} tokens resident, \
                 image starts at block {}",
                table.n_blocks(),
                table.len,
                image.first_block
            );
        }
        let w = self.heads * self.head_dim;
        for blk in &image.blocks {
            let pid = self.alloc(table.arena, table.layer)?;
            let off = pid * self.slot;
            self.k[off..off + blk.k.len()].copy_from_slice(&blk.k);
            self.v[off..off + blk.v.len()].copy_from_slice(&blk.v);
            self.ksum[pid * w..(pid + 1) * w].copy_from_slice(&blk.ksum);
            self.fill[pid] = blk.fill;
            table.blocks.push(pid);
        }
        table.len = image.len;
        Ok(image.blocks.len())
    }

    /// Tokens of logical block `b` under `table` — equals the physical
    /// fill (shared partial blocks are immutable, so every referencing
    /// table sees the same fill).
    fn block_tokens(&self, table: &BlockTable, b: usize) -> usize {
        let cnt = self.fill[table.blocks[b]];
        debug_assert_eq!(cnt, (table.len - b * self.block_size).min(self.block_size));
        cnt
    }

    /// Key slice `[D]` for (logical token, head) of `table`'s sequence.
    pub fn k_at(&self, table: &BlockTable, t: usize, h: usize) -> &[f32] {
        debug_assert!(t < table.len);
        let pid = table.blocks[t / self.block_size];
        let off = pid * self.slot + ((t % self.block_size) * self.heads + h) * self.head_dim;
        &self.k[off..off + self.head_dim]
    }

    /// Value slice `[D]` for (logical token, head) of `table`'s sequence.
    pub fn v_at(&self, table: &BlockTable, t: usize, h: usize) -> &[f32] {
        debug_assert!(t < table.len);
        let pid = table.blocks[t / self.block_size];
        let off = pid * self.slot + ((t % self.block_size) * self.heads + h) * self.head_dim;
        &self.v[off..off + self.head_dim]
    }

    /// Physical block `pid`'s K and V slabs (`[fill, H, D]`, the block's
    /// first token at offset 0) — the indirection the paged fused decode
    /// row streams through.
    pub(crate) fn block_kv(&self, pid: usize) -> (&[f32], &[f32]) {
        let off = pid * self.slot;
        let n = self.fill[pid] * self.heads * self.head_dim;
        (&self.k[off..off + n], &self.v[off..off + n])
    }

    /// Mean representative of `table`'s logical block `b`, head `h` —
    /// the shared `sum * (1/count)` formula, bit-identical to
    /// `BlockPoolCache::mean_into` / `mean_pool_blocks` on the same
    /// token stream.
    pub fn mean_into(&self, table: &BlockTable, b: usize, h: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.head_dim);
        let cnt = self.block_tokens(table, b);
        let off = table.blocks[b] * self.heads * self.head_dim + h * self.head_dim;
        write_mean(&self.ksum[off..off + self.head_dim], cnt, out);
    }

    /// All of head `h`'s block representatives for `table`, written
    /// contiguously into `out` (`[n_blocks, D]`) — the per-head slab the
    /// fused gate scans.
    pub fn means_for_head_into(&self, table: &BlockTable, h: usize, out: &mut [f32]) {
        let d = self.head_dim;
        debug_assert_eq!(out.len(), table.n_blocks() * d);
        for b in 0..table.n_blocks() {
            self.mean_into(table, b, h, &mut out[b * d..(b + 1) * d]);
        }
    }

    /// Materialize `table`'s keys as a `[len, H, D]` tensor (recompute
    /// baselines and parity tests).
    pub fn k_tensor(&self, table: &BlockTable) -> Tensor {
        self.gather(table, &self.k)
    }

    /// Materialize `table`'s values as a `[len, H, D]` tensor.
    pub fn v_tensor(&self, table: &BlockTable) -> Tensor {
        self.gather(table, &self.v)
    }

    fn gather(&self, table: &BlockTable, store: &[f32]) -> Tensor {
        let w = self.heads * self.head_dim;
        let mut data = Vec::with_capacity(table.len * w);
        for t in 0..table.len {
            let pid = table.blocks[t / self.block_size];
            let off = pid * self.slot + (t % self.block_size) * w;
            data.extend_from_slice(&store[off..off + w]);
        }
        Tensor::from_vec(&[table.len, self.heads, self.head_dim], data)
            .expect("pool layout is always consistent")
    }
}

/// Refresh a session's materialized per-head representative slabs
/// (`[H, cap, D]`, `cap` a power of two ≥ `n_blocks`) from the pool —
/// the paged mirror of `backend::RepsCache::sync`: a single appended
/// token can only change the last block's mean, so steady-state decode
/// refreshes one block per head; `full` (prefill, or a capacity grow —
/// which a fresh fork hits on its first decode) rebuilds everything.
fn sync_reps(
    pool: &PagedKvPool,
    table: &BlockTable,
    reps: &mut Vec<f32>,
    cap: &mut usize,
    full: bool,
) {
    let (h, d) = (pool.heads(), pool.head_dim());
    let nb = table.n_blocks();
    if nb == 0 {
        return;
    }
    if full || nb > *cap {
        *cap = (*cap).max(nb.next_power_of_two());
        reps.clear();
        reps.resize(h * *cap * d, 0.0);
        for hh in 0..h {
            let off = hh * *cap * d;
            pool.means_for_head_into(table, hh, &mut reps[off..off + nb * d]);
        }
    } else {
        for hh in 0..h {
            let off = (hh * *cap + (nb - 1)) * d;
            pool.mean_into(table, nb - 1, hh, &mut reps[off..off + d]);
        }
    }
}

/// One fused decode row through the block table: gate against the
/// session's representative slabs, select top-k with the NaN-safe `>=`
/// test, stream the selected blocks via `block_kv` indirection — the
/// same `fused_row_blocks` routine the contiguous caches use, so the
/// output is bit-identical to `FusedMobaAttention` / recomputing
/// `moba_attention` over the whole prefix.
#[allow(clippy::too_many_arguments)]
fn paged_decode_row(
    pool: &PagedKvPool,
    table: &BlockTable,
    reps: &[f32],
    reps_cap: usize,
    topk: usize,
    scratch: &mut FusedScratch,
    q_row: &[f32],
) -> Vec<f32> {
    let (h, d) = (pool.heads(), pool.head_dim());
    let block_size = pool.block_size();
    let t = table.len() - 1;
    let scale = 1.0 / (d as f32).sqrt();
    let nb = table.n_blocks();
    let kk = topk.min(nb);
    let mut out = vec![0.0f32; h * d];
    scratch.ensure_blocks(nb);
    for hh in 0..h {
        let qh = &q_row[hh * d..(hh + 1) * d];
        let head = hh * reps_cap * d;
        let reps_h = &reps[head..head + nb * d];
        fused_row_blocks(
            qh,
            reps_h,
            h,
            hh,
            d,
            block_size,
            kk,
            t,
            scale,
            scratch,
            &mut out[hh * d..(hh + 1) * d],
            |b| pool.block_kv(table.physical(b)),
        );
    }
    out
}

/// MoBA attention over a shared paged pool: fused single-pass prefill,
/// decode through the session's [`BlockTable`]. [`fork`] shares the
/// whole prefix copy-on-write — the shared-system-prompt serving
/// scenario. Outputs are bit-identical to every private-cache sparse
/// backend (`moba` / `cached-sparse` / `fused`).
///
/// [`fork`]: AttentionBackend::fork
pub struct PagedMobaAttention {
    pool: SharedKvPool,
    table: BlockTable,
    block_size: usize,
    topk: usize,
    workers: usize,
    /// materialized per-head representative slabs, `[H, reps_cap, D]`
    reps: Vec<f32>,
    reps_cap: usize,
    scratch: FusedScratch,
}

impl PagedMobaAttention {
    /// Attach a new session to `pool` (geometry comes from the pool).
    pub fn new(pool: SharedKvPool, topk: usize) -> PagedMobaAttention {
        assert!(topk > 0);
        let (block_size, head_dim) = {
            let p = sync::read(&pool);
            (p.block_size(), p.head_dim())
        };
        PagedMobaAttention {
            pool,
            table: BlockTable::new(),
            block_size,
            topk,
            workers: 1,
            reps: Vec::new(),
            reps_cap: 0,
            scratch: FusedScratch::new(head_dim, 0, block_size),
        }
    }

    /// Standalone backend over its own fresh unbounded pool (benches,
    /// conformance tests, CLI selection without a serving engine).
    pub fn with_private_pool(
        heads: usize,
        head_dim: usize,
        block_size: usize,
        topk: usize,
    ) -> PagedMobaAttention {
        PagedMobaAttention::new(shared_pool(block_size, heads, head_dim, None), topk)
    }

    /// Spread batch/prefill rows over `workers` threads (bit-identical
    /// output for any count; decode rows run inline, like the other
    /// cached backends).
    pub fn with_workers(mut self, workers: usize) -> PagedMobaAttention {
        self.workers = workers.max(1);
        self
    }

    /// Tag this session's block table with a model layer so the shared
    /// pool charges its blocks to that layer's usage counter. Forks and
    /// prefix forks inherit the tag through the pool.
    pub fn with_layer(mut self, layer: usize) -> PagedMobaAttention {
        self.table.set_layer(layer);
        self
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn topk(&self) -> usize {
        self.topk
    }

    /// The shared pool handle this session allocates from.
    pub fn pool(&self) -> &SharedKvPool {
        &self.pool
    }

    /// Logical blocks this session currently maps.
    pub fn n_blocks(&self) -> usize {
        self.table.n_blocks()
    }
}

impl Drop for PagedMobaAttention {
    fn drop(&mut self) {
        // release even through a poisoned lock: a panicking decode worker
        // must not strand this session's refcounts in the shared pool
        sync::write(&self.pool).release(&mut self.table);
    }
}

impl AttentionBackend for PagedMobaAttention {
    fn name(&self) -> &'static str {
        "paged"
    }

    fn forward(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        fused_moba_attention(q, k, v, self.block_size, self.topk, self.workers)
    }

    fn gate(&self, q: &Tensor, k: &Tensor) -> Option<Gate> {
        Some(moba_gate(q, k, self.block_size, self.topk))
    }

    fn reset(&mut self) {
        let mut pool = sync::write(&self.pool);
        pool.release(&mut self.table);
        self.reps.clear();
        self.reps_cap = 0;
    }

    fn evict(&mut self) -> Result<usize> {
        let freed = {
            let mut pool = sync::write(&self.pool);
            pool.evict(&mut self.table)
        };
        self.reps.clear();
        self.reps_cap = 0;
        Ok(freed)
    }

    fn prefill(&mut self, q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        debug_assert!(self.table.is_empty(), "prefill on non-empty state");
        {
            let mut pool = sync::write(&self.pool);
            pool.append_tensors(&mut self.table, k, v)
                .expect("paged pool exhausted in prefill (admission must reserve blocks)");
            sync_reps(&pool, &self.table, &mut self.reps, &mut self.reps_cap, true);
        }
        // the pool's running-sum means double as the fused pass's
        // representatives — no second pooling pass over K
        fused_moba_attention_with_reps(
            q,
            k,
            v,
            self.block_size,
            self.topk,
            self.workers,
            &self.reps,
            self.reps_cap,
        )
    }

    fn decode(&mut self, q_row: &[f32], k_row: &[f32], v_row: &[f32]) -> Vec<f32> {
        {
            let mut pool = sync::write(&self.pool);
            pool.append(&mut self.table, k_row, v_row)
                .expect("paged pool exhausted in decode (admission must reserve blocks)");
            sync_reps(&pool, &self.table, &mut self.reps, &mut self.reps_cap, false);
        }
        // the attention row streams under a shared READ lock: this
        // session's blocks are immutable while its table references them
        // (CoW), so decode shards run concurrently and only appends
        // serialize
        let pool = sync::read(&self.pool);
        paged_decode_row(
            &pool,
            &self.table,
            &self.reps,
            self.reps_cap,
            self.topk,
            &mut self.scratch,
            q_row,
        )
    }

    fn seq_len(&self) -> usize {
        self.table.len()
    }

    fn set_arena(&mut self, arena: usize) {
        self.table.set_arena(arena);
    }

    fn fork(&self) -> Result<Box<dyn AttentionBackend>> {
        let (table, head_dim) = {
            let mut pool = sync::write(&self.pool);
            let table = pool.fork(&self.table);
            (table, pool.head_dim())
        };
        // reps stay empty: the fork's first decode sees n_blocks >
        // reps_cap (0) and rebuilds the slabs from the pool in full
        Ok(Box::new(PagedMobaAttention {
            pool: self.pool.clone(),
            table,
            block_size: self.block_size,
            topk: self.topk,
            workers: self.workers,
            reps: Vec::new(),
            reps_cap: 0,
            scratch: FusedScratch::new(head_dim, 0, self.block_size),
        }))
    }

    fn fork_prefix(&self, blocks: usize) -> Result<Box<dyn AttentionBackend>> {
        if blocks > self.table.n_blocks() {
            bail!("prefix fork of {blocks} blocks but only {} mapped", self.table.n_blocks());
        }
        let (table, head_dim) = {
            let mut pool = sync::write(&self.pool);
            let table = pool.fork_prefix(&self.table, blocks);
            (table, pool.head_dim())
        };
        Ok(Box::new(PagedMobaAttention {
            pool: self.pool.clone(),
            table,
            block_size: self.block_size,
            topk: self.topk,
            workers: self.workers,
            reps: Vec::new(),
            reps_cap: 0,
            scratch: FusedScratch::new(head_dim, 0, self.block_size),
        }))
    }

    fn swap_out(&self, from_block: usize) -> Result<SwapImage> {
        if from_block > self.table.n_blocks() {
            bail!("swap-out from block {from_block} but only {} mapped", self.table.n_blocks());
        }
        let pool = sync::read(&self.pool);
        Ok(pool.extract_blocks(&self.table, from_block))
    }

    fn swap_in(&mut self, image: &SwapImage) -> Result<usize> {
        let restored = {
            let mut pool = sync::write(&self.pool);
            pool.restore_blocks(&mut self.table, image)?
        };
        // reps stay empty: the next decode sees n_blocks > reps_cap and
        // rebuilds the slabs in full from the restored running sums —
        // the same lazy path a fresh fork takes, so outputs match the
        // re-prefill resume bit-for-bit
        self.reps.clear();
        self.reps_cap = 0;
        Ok(restored)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::backend::{CachedDecodeBackend, DecodePolicy, FusedMobaAttention};
    use crate::sparse::gate::mean_pool_blocks;
    use crate::sparse::kv_cache::KvCache;
    use crate::util::rng::Rng;

    fn rand_t(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect()).unwrap()
    }

    fn row(t: &Tensor, i: usize) -> &[f32] {
        let w = t.shape[1] * t.shape[2];
        &t.data[i * w..(i + 1) * w]
    }

    #[test]
    fn pool_roundtrips_kv_rows() {
        let k = rand_t(&[23, 2, 4], 1);
        let v = rand_t(&[23, 2, 4], 2);
        let mut pool = PagedKvPool::new(8, 2, 4, None);
        let mut table = BlockTable::new();
        pool.append_tensors(&mut table, &k, &v).unwrap();
        assert_eq!(table.len(), 23);
        assert_eq!(table.n_blocks(), 3);
        assert_eq!(pool.used_blocks(), 3);
        assert_eq!(pool.k_tensor(&table), k);
        assert_eq!(pool.v_tensor(&table), v);
        assert_eq!(pool.k_at(&table, 17, 1), {
            let mut c = KvCache::new(2, 4);
            c.append_tensors(&k, &v);
            c.k_at(17, 1).to_vec()
        });
    }

    #[test]
    fn pool_means_match_batch_pooling_bitwise() {
        for &n in &[32usize, 37, 5] {
            let k = rand_t(&[n, 2, 8], 100 + n as u64);
            let v = rand_t(&[n, 2, 8], 200 + n as u64);
            let mut pool = PagedKvPool::new(16, 2, 8, None);
            let mut table = BlockTable::new();
            pool.append_tensors(&mut table, &k, &v).unwrap();
            let batch = mean_pool_blocks(&k, 16);
            let nb = table.n_blocks();
            let mut slab = vec![0.0f32; nb * 8];
            for h in 0..2 {
                pool.means_for_head_into(&table, h, &mut slab);
                for b in 0..nb {
                    let want = &batch.data[(b * 2 + h) * 8..(b * 2 + h) * 8 + 8];
                    assert_eq!(&slab[b * 8..(b + 1) * 8], want, "n={n} h={h} b={b}");
                }
            }
        }
    }

    #[test]
    fn fork_shares_blocks_and_cow_isolates_writes() {
        let k = rand_t(&[20, 1, 4], 3);
        let v = rand_t(&[20, 1, 4], 4);
        let mut pool = PagedKvPool::new(8, 1, 4, None);
        let mut a = BlockTable::new();
        pool.append_tensors(&mut a, &k, &v).unwrap(); // 20 tokens: 2 full + 4-token tail
        assert_eq!(pool.used_blocks(), 3);
        let mut b = pool.fork(&a);
        assert_eq!(pool.used_blocks(), 3, "fork copies nothing");
        assert_eq!(b.len(), 20);
        assert_eq!(a.physical(2), b.physical(2), "tail shared until a write");

        // b writes the shared tail → CoW copy; a's bytes are untouched
        let (kr, vr) = ([9.0f32; 4], [7.0f32; 4]);
        pool.append(&mut b, &kr, &vr).unwrap();
        assert_eq!(pool.used_blocks(), 4);
        assert_ne!(a.physical(2), b.physical(2));
        assert_eq!(pool.k_tensor(&a), k, "CoW leaked into the parent");
        assert_eq!(pool.k_at(&b, 20, 0), &kr);
        // a now owns its tail exclusively again → appends in place
        pool.append(&mut a, &[1.0; 4], &[2.0; 4]).unwrap();
        assert_eq!(pool.used_blocks(), 4);

        // release returns blocks; the survivor keeps the shared prefix
        pool.release(&mut b);
        assert_eq!(pool.used_blocks(), 3);
        pool.release(&mut a);
        assert_eq!(pool.used_blocks(), 0);
        assert_eq!(pool.payload_bytes(), 0);
    }

    #[test]
    fn freed_blocks_are_reused_with_clean_sums() {
        let mut pool = PagedKvPool::new(2, 1, 2, None);
        let mut a = BlockTable::new();
        pool.append(&mut a, &[4.0, 4.0], &[0.0, 0.0]).unwrap();
        pool.release(&mut a);
        let mut b = BlockTable::new();
        pool.append(&mut b, &[2.0, 6.0], &[0.0, 0.0]).unwrap();
        assert_eq!(pool.used_blocks(), 1);
        let mut mean = [0.0f32; 2];
        pool.mean_into(&b, 0, 0, &mut mean);
        assert_eq!(mean, [2.0, 6.0], "stale sum survived block reuse");
    }

    #[test]
    fn arena_affine_alloc_prefers_local_free_blocks_and_steals_across() {
        // two sessions homed in different arenas fill and free blocks;
        // a new same-arena session recycles its own arena's blocks
        // first, and only steals cross-arena once local ones run out
        let mut pool = PagedKvPool::new(2, 1, 2, None);
        let (mut a, mut b) = (BlockTable::new(), BlockTable::new());
        a.set_arena(0);
        b.set_arena(1);
        for i in 0..4 {
            pool.append(&mut a, &[i as f32, 0.0], &[0.0, 0.0]).unwrap();
            pool.append(&mut b, &[i as f32, 1.0], &[0.0, 0.0]).unwrap();
        }
        let a_blocks: Vec<usize> = (0..2).map(|i| a.physical(i)).collect();
        let b_blocks: Vec<usize> = (0..2).map(|i| b.physical(i)).collect();
        pool.release(&mut a);
        pool.release(&mut b);
        // a fresh arena-1 session: its first two blocks come from
        // arena 1's free list, the next two are stolen from arena 0
        let mut c = BlockTable::new();
        c.set_arena(1);
        for i in 0..8 {
            pool.append(&mut c, &[i as f32, 2.0], &[0.0, 0.0]).unwrap();
        }
        assert!(b_blocks.contains(&c.physical(0)), "first alloc not arena-local");
        assert!(b_blocks.contains(&c.physical(1)), "second alloc not arena-local");
        assert!(a_blocks.contains(&c.physical(2)), "exhausted arena must steal");
        assert!(a_blocks.contains(&c.physical(3)), "exhausted arena must steal");
        assert_eq!(pool.used_blocks(), 4, "recycled, not grown");
        // recycled blocks carry clean sums regardless of arena hops
        let mut mean = [0.0f32; 2];
        pool.mean_into(&c, 0, 0, &mut mean);
        assert_eq!(mean, [0.5, 2.0], "stale sum survived cross-arena reuse");
    }

    #[test]
    fn per_layer_accounting_tracks_alloc_release_and_reuse() {
        let mut pool = PagedKvPool::new(2, 1, 2, None);
        let (mut a, mut b) = (BlockTable::new(), BlockTable::new());
        b.set_layer(2);
        for i in 0..4 {
            pool.append(&mut a, &[i as f32, 0.0], &[0.0, 0.0]).unwrap();
            pool.append(&mut b, &[i as f32, 1.0], &[0.0, 0.0]).unwrap();
        }
        assert_eq!(pool.used_blocks(), 4);
        assert_eq!(pool.used_blocks_by_layer(), &[2, 0, 2]);
        // forks share blocks: no new charge until a write diverges
        let mut f = pool.fork(&b);
        assert_eq!(f.layer(), 2, "forks inherit the layer tag");
        assert_eq!(pool.used_blocks_by_layer(), &[2, 0, 2]);
        pool.append(&mut f, &[9.0, 9.0], &[0.0, 0.0]).unwrap();
        assert_eq!(pool.used_blocks_by_layer(), &[2, 0, 3]);
        pool.release(&mut f);
        assert_eq!(pool.used_blocks_by_layer(), &[2, 0, 2]);
        pool.release(&mut b);
        assert_eq!(pool.used_blocks_by_layer(), &[2, 0, 0]);
        // a freed block recycled under a different layer moves its charge
        let mut c = BlockTable::new();
        c.set_layer(1);
        pool.append(&mut c, &[1.0, 1.0], &[0.0, 0.0]).unwrap();
        assert_eq!(pool.used_blocks_by_layer(), &[2, 1, 0]);
        assert_eq!(pool.used_blocks(), 3);
        let total: usize = pool.used_blocks_by_layer().iter().sum();
        assert_eq!(total, pool.used_blocks(), "per-layer counters must sum to used");
    }

    #[test]
    fn evict_reclaims_only_unshared_blocks() {
        // parent: 2 full blocks + 4-token tail; fork diverges through CoW
        let k = rand_t(&[20, 1, 4], 5);
        let v = rand_t(&[20, 1, 4], 6);
        let mut pool = PagedKvPool::new(8, 1, 4, None);
        let mut parent = BlockTable::new();
        pool.append_tensors(&mut parent, &k, &v).unwrap();
        let mut forker = pool.fork(&parent);
        for i in 0..12 {
            pool.append(&mut forker, &[i as f32; 4], &[0.0; 4]).unwrap();
        }
        // forker: 3 shared-prefix refs + CoW tail + 1 fresh = 5 phys used
        assert_eq!(pool.used_blocks(), 5);
        // evicting the forker frees only its private tail blocks; the
        // shared prefix (still referenced by the parent) stays resident
        let freed = pool.evict(&mut forker);
        assert_eq!(freed, 2, "only the CoW tail + fresh block free");
        assert_eq!(pool.used_blocks(), 3);
        assert_eq!(pool.k_tensor(&parent), k, "prefix bytes survive eviction");
        // evicting the last holder frees everything
        assert_eq!(pool.evict(&mut parent), 3);
        assert_eq!(pool.used_blocks(), 0);
    }

    #[test]
    fn backend_evict_releases_and_reingest_is_bitwise_identical() {
        let n = 37;
        let q = rand_t(&[n, 2, 8], 81);
        let k = rand_t(&[n, 2, 8], 82);
        let v = rand_t(&[n, 2, 8], 83);
        let mut twin = PagedMobaAttention::with_private_pool(2, 8, 16, 2);
        let mut victim = PagedMobaAttention::with_private_pool(2, 8, 16, 2);
        let split = 20;
        for t in 0..split {
            let a = victim.decode(row(&q, t), row(&k, t), row(&v, t));
            let b = twin.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(a, b, "t={t}");
        }
        let freed = victim.evict().unwrap();
        assert_eq!(freed, 2, "20 tokens over 16-blocks = 2 phys blocks");
        assert_eq!(victim.seq_len(), 0);
        assert_eq!(victim.pool().read().unwrap().used_blocks(), 0);
        // re-prefill the same stream, then keep decoding: bit-identical
        let (qp, kp, vp) = (
            Tensor::from_vec(&[split, 2, 8], q.data[..split * 16].to_vec()).unwrap(),
            Tensor::from_vec(&[split, 2, 8], k.data[..split * 16].to_vec()).unwrap(),
            Tensor::from_vec(&[split, 2, 8], v.data[..split * 16].to_vec()).unwrap(),
        );
        victim.prefill(&qp, &kp, &vp);
        for t in split..n {
            let a = victim.decode(row(&q, t), row(&k, t), row(&v, t));
            let b = twin.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(a, b, "post-resume t={t}");
        }
    }

    #[test]
    fn swap_roundtrip_restores_bytes_sums_and_occupancy() {
        let k = rand_t(&[23, 2, 4], 91);
        let v = rand_t(&[23, 2, 4], 92);
        let mut pool = PagedKvPool::new(8, 2, 4, None);
        let mut table = BlockTable::new();
        pool.append_tensors(&mut table, &k, &v).unwrap();
        let image = pool.extract_blocks(&table, 0);
        assert_eq!(image.n_blocks(), 3);
        assert_eq!(image.tokens(), 23);
        assert!(image.payload_bytes() > 0);
        assert_eq!(pool.used_blocks(), 3, "extraction is copy-only");
        // the swap-out lifecycle: snapshot, evict, restore
        assert_eq!(pool.evict(&mut table), 3);
        assert_eq!(pool.used_blocks(), 0);
        let restored = pool.restore_blocks(&mut table, &image).unwrap();
        assert_eq!(restored, 3, "restore allocates what re-ingest would");
        assert_eq!(pool.used_blocks(), 3);
        assert_eq!(table.len(), 23);
        assert_eq!(pool.k_tensor(&table), k, "K bytes must round-trip exactly");
        assert_eq!(pool.v_tensor(&table), v, "V bytes must round-trip exactly");
        // running sums round-trip too, so representative means are
        // bit-identical to the never-swapped pool
        let mut mean = [0.0f32; 4];
        let mut want = [0.0f32; 4];
        let mut twin_pool = PagedKvPool::new(8, 2, 4, None);
        let mut twin = BlockTable::new();
        twin_pool.append_tensors(&mut twin, &k, &v).unwrap();
        for b in 0..3 {
            for h in 0..2 {
                pool.mean_into(&table, b, h, &mut mean);
                twin_pool.mean_into(&twin, b, h, &mut want);
                assert_eq!(mean, want, "b={b} h={h}");
            }
        }
    }

    #[test]
    fn suffix_swap_keeps_shared_prefix_resident() {
        // parent holds a 16-token (2 full blocks) prefix; the fork
        // diverges by 12 tokens, so its tail blocks are entirely its own
        let k = rand_t(&[16, 1, 4], 93);
        let v = rand_t(&[16, 1, 4], 94);
        let mut pool = PagedKvPool::new(8, 1, 4, None);
        let mut parent = BlockTable::new();
        pool.append_tensors(&mut parent, &k, &v).unwrap();
        let mut fork = pool.fork(&parent);
        for i in 0..12 {
            pool.append(&mut fork, &[i as f32; 4], &[0.5; 4]).unwrap();
        }
        assert_eq!(pool.used_blocks(), 4, "2 shared + 2 private tail blocks");
        let before = pool.k_tensor(&fork);
        // suffix-only swap: snapshot blocks [2..), evict, re-fork prefix
        let image = pool.extract_blocks(&fork, 2);
        assert_eq!(image.n_blocks(), 2);
        assert_eq!(pool.evict(&mut fork), 2, "only the private tail frees");
        assert_eq!(pool.used_blocks(), 2, "shared prefix never left");
        let mut resumed = pool.fork_prefix(&parent, 2);
        assert_eq!(resumed.len(), 16);
        assert_eq!(pool.restore_blocks(&mut resumed, &image).unwrap(), 2);
        assert_eq!(resumed.len(), 28);
        assert_eq!(pool.k_tensor(&resumed), before, "suffix restore must be exact");
        assert_eq!(resumed.physical(0), parent.physical(0), "prefix blocks shared again");
        assert_eq!(pool.used_blocks(), 4);
    }

    #[test]
    fn corrupted_swap_image_fails_restore_without_allocating() {
        let k = rand_t(&[10, 1, 4], 95);
        let v = rand_t(&[10, 1, 4], 96);
        let mut pool = PagedKvPool::new(8, 1, 4, None);
        let mut table = BlockTable::new();
        pool.append_tensors(&mut table, &k, &v).unwrap();
        let mut image = pool.extract_blocks(&table, 0);
        pool.evict(&mut table);
        image.corrupt_for_chaos();
        let err = pool.restore_blocks(&mut table, &image).unwrap_err();
        assert!(err.to_string().contains("checksum"), "got: {err}");
        assert_eq!(pool.used_blocks(), 0, "failed restore must not leak blocks");
        assert_eq!(table.n_blocks(), 0, "failed restore must not touch the table");
    }

    #[test]
    fn restore_rejects_a_mismatched_table() {
        let k = rand_t(&[10, 1, 4], 97);
        let v = rand_t(&[10, 1, 4], 98);
        let mut pool = PagedKvPool::new(8, 1, 4, None);
        let mut table = BlockTable::new();
        pool.append_tensors(&mut table, &k, &v).unwrap();
        let image = pool.extract_blocks(&table, 0);
        // table still holds its blocks: restoring on top must refuse
        assert!(pool.restore_blocks(&mut table, &image).is_err());
        assert_eq!(table.len(), 10, "refused restore must not corrupt the table");
    }

    #[test]
    fn backend_swap_roundtrip_decodes_bitwise_identically() {
        // the backend-level swap contract mirroring the evict/re-ingest
        // twin test: swap out mid-decode, evict, swap back in, keep
        // decoding — every row must equal the never-swapped twin's
        let n = 37;
        let q = rand_t(&[n, 2, 8], 84);
        let k = rand_t(&[n, 2, 8], 85);
        let v = rand_t(&[n, 2, 8], 86);
        let mut twin = PagedMobaAttention::with_private_pool(2, 8, 16, 2);
        let mut victim = PagedMobaAttention::with_private_pool(2, 8, 16, 2);
        let split = 20;
        for t in 0..split {
            let a = victim.decode(row(&q, t), row(&k, t), row(&v, t));
            let b = twin.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(a, b, "t={t}");
        }
        let image = victim.swap_out(0).unwrap();
        assert_eq!(victim.evict().unwrap(), 2);
        assert_eq!(victim.seq_len(), 0);
        assert_eq!(victim.swap_in(&image).unwrap(), 2);
        assert_eq!(victim.seq_len(), split);
        for t in split..n {
            let a = victim.decode(row(&q, t), row(&k, t), row(&v, t));
            let b = twin.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(a, b, "post-swap-in t={t}");
        }
    }

    #[test]
    fn capacity_bounds_allocation() {
        let mut pool = PagedKvPool::new(4, 1, 2, Some(2));
        let mut t = BlockTable::new();
        for i in 0..8 {
            pool.append(&mut t, &[i as f32, 0.0], &[0.0, 0.0]).unwrap();
        }
        assert_eq!(pool.free_blocks(), Some(0));
        assert!(pool.append(&mut t, &[9.0, 0.0], &[0.0, 0.0]).is_err());
        pool.release(&mut t);
        assert_eq!(pool.free_blocks(), Some(2));
    }

    #[test]
    fn paged_backend_bitwise_matches_private_backends() {
        // golden append-one-token loop: paged decode == fused/cached
        // private decode == two-pass batch recompute, bit-for-bit
        let n = 53;
        let (bs, topk) = (16, 2);
        let q = rand_t(&[n, 2, 8], 31);
        let k = rand_t(&[n, 2, 8], 32);
        let v = rand_t(&[n, 2, 8], 33);
        let mut paged = PagedMobaAttention::with_private_pool(2, 8, bs, topk);
        let mut fused = FusedMobaAttention::new(2, 8, bs, topk);
        let mut cached = CachedDecodeBackend::new(2, 8, bs, topk, DecodePolicy::Sparse);
        for t in 0..n {
            let got = paged.decode(row(&q, t), row(&k, t), row(&v, t));
            assert_eq!(got, fused.decode(row(&q, t), row(&k, t), row(&v, t)), "t={t}");
            assert_eq!(got, cached.decode(row(&q, t), row(&k, t), row(&v, t)), "t={t}");
        }
        assert_eq!(paged.seq_len(), n);
    }

    #[test]
    fn forked_backends_diverge_bitwise_identically_to_private() {
        // shared 40-token prefix (partial tail block), two divergent
        // continuations — each fork must match a private backend fed the
        // same full stream, bit-for-bit, through the CoW boundary
        let (n, split, bs, topk) = (56, 40, 16, 2);
        let streams = [(41u64, 42u64, 43u64), (51, 52, 53)];
        let q0 = rand_t(&[n, 2, 8], streams[0].0);
        let k0 = rand_t(&[n, 2, 8], streams[0].1);
        let v0 = rand_t(&[n, 2, 8], streams[0].2);
        let mut parent = PagedMobaAttention::with_private_pool(2, 8, bs, topk);
        for t in 0..split {
            parent.decode(row(&q0, t), row(&k0, t), row(&v0, t));
        }
        let mut forks = [parent.fork().unwrap(), parent.fork().unwrap()];
        for (f, &(sq, sk, sv)) in forks.iter_mut().zip(&streams) {
            let q = rand_t(&[n, 2, 8], sq);
            let k = rand_t(&[n, 2, 8], sk);
            let v = rand_t(&[n, 2, 8], sv);
            let mut private = FusedMobaAttention::new(2, 8, bs, topk);
            for t in 0..split {
                private.decode(row(&q0, t), row(&k0, t), row(&v0, t));
            }
            for t in split..n {
                let a = f.decode(row(&q, t), row(&k, t), row(&v, t));
                let b = private.decode(row(&q, t), row(&k, t), row(&v, t));
                assert_eq!(a, b, "t={t}");
            }
            assert_eq!(f.seq_len(), n);
        }
    }

    #[test]
    fn reset_releases_and_backend_is_reusable() {
        let q = rand_t(&[24, 1, 4], 61);
        let k = rand_t(&[24, 1, 4], 62);
        let v = rand_t(&[24, 1, 4], 63);
        let mut b = PagedMobaAttention::with_private_pool(1, 4, 8, 2);
        let first = b.prefill(&q, &k, &v);
        assert_eq!(b.seq_len(), 24);
        b.reset();
        assert_eq!(b.seq_len(), 0);
        assert_eq!(b.pool().read().unwrap().used_blocks(), 0);
        assert_eq!(b.prefill(&q, &k, &v).data, first.data, "reuse after reset");
    }

    #[test]
    fn shared_prefix_memory_is_prefix_plus_tails() {
        // the acceptance-criterion accounting: S sessions over an N-token
        // shared prefix cost ceil(N/B) + S·(own tail) blocks, not
        // S·ceil(N/B)
        let (bs, prefix, extra, sessions) = (16usize, 64usize, 8usize, 4usize);
        let q = rand_t(&[prefix + extra, 2, 8], 71);
        let k = rand_t(&[prefix + extra, 2, 8], 72);
        let v = rand_t(&[prefix + extra, 2, 8], 73);
        let mut parent = PagedMobaAttention::with_private_pool(2, 8, bs, 2);
        let sub = |t: &Tensor| {
            Tensor::from_vec(&[prefix, 2, 8], t.data[..prefix * 2 * 8].to_vec()).unwrap()
        };
        parent.prefill(&sub(&q), &sub(&k), &sub(&v));
        let mut forks: Vec<_> = (0..sessions).map(|_| parent.fork().unwrap()).collect();
        for f in forks.iter_mut() {
            for t in prefix..prefix + extra {
                f.decode(row(&q, t), row(&k, t), row(&v, t));
            }
        }
        let used = parent.pool().read().unwrap().used_blocks();
        // 64/16 = 4 shared prefix blocks + one 8-token tail block per fork
        assert_eq!(used, prefix / bs + sessions, "expected O(N + S·tail) blocks");
        let private = sessions * ((prefix + extra + bs - 1) / bs);
        assert!(used * 2 < private, "paged pool is not sharing: {used} vs private {private}");
    }
}
