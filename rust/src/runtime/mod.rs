//! Runtime layer: artifact manifest, model state and checkpoints, plus —
//! behind the `xla` feature — the PJRT client wrapper (`engine`), the only
//! module in the crate that links against the `xla` crate.
//!
//! Flow: `Manifest::load` (artifact metadata from python's AOT pass) →
//! `Engine::load` (HLO text → compile, cached) → `Engine::train_step` /
//! `eval_losses` / `logits` / `kernel` (host tensors in/out). Manifest,
//! `ModelState` and checkpoint I/O are pure host code and compile (and
//! test) without any device runtime.

pub mod checkpoint;
#[cfg(feature = "xla")]
pub mod engine;
pub mod manifest;
pub mod state;

#[cfg(feature = "xla")]
pub use engine::{Engine, Input};
pub use manifest::{Artifact, Manifest};
pub use state::ModelState;

use std::path::PathBuf;

/// Default artifacts directory: `$MOBA_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("MOBA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
