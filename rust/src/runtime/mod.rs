//! Runtime layer: PJRT client wrapper, artifact manifest, model state and
//! checkpoints. The only module that links against the `xla` crate.
//!
//! Flow: `Manifest::load` (artifact metadata from python's AOT pass) →
//! `Engine::load` (HLO text → compile, cached) → `Engine::train_step` /
//! `eval_losses` / `logits` / `kernel` (host tensors in/out).

pub mod checkpoint;
pub mod engine;
pub mod manifest;

pub use engine::{Engine, Input, ModelState};
pub use manifest::{Artifact, Manifest};

use std::path::PathBuf;

/// Default artifacts directory: `$MOBA_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("MOBA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
