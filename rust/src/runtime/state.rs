//! Host-resident model state: parameters + Adam moments, initialized from
//! the manifest's parameter spec. Pure host code — the PJRT engine (behind
//! the `xla` feature) consumes it, but checkpointing and initialization
//! need no device runtime.

use anyhow::{bail, Result};

use super::manifest::Artifact;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Full optimizer state for one model geometry. Host-resident between
/// steps; uploaded per call (see DESIGN.md §7 for the measured cost).
#[derive(Clone)]
pub struct ModelState {
    pub params: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub step: u64,
}

impl ModelState {
    /// Initialize from the artifact's parameter spec with the repo RNG.
    /// Mirrors `model.init_params` (normal / zeros / ones per leaf).
    pub fn init(art: &Artifact, seed: u64) -> Result<ModelState> {
        let mut root = Rng::new(seed);
        let mut params = Vec::with_capacity(art.params.len());
        for (i, spec) in art.params.iter().enumerate() {
            let mut rng = root.split(i as u64);
            let n = spec.numel();
            let data = match spec.init.as_str() {
                "normal" => (0..n).map(|_| rng.normal_f32(spec.scale as f32)).collect(),
                "zeros" => vec![0.0; n],
                "ones" => vec![1.0; n],
                other => bail!("unknown init kind '{other}'"),
            };
            params.push(Tensor::from_vec(&spec.shape, data)?);
        }
        let zeros: Vec<Tensor> =
            art.params.iter().map(|s| Tensor::zeros(&s.shape)).collect();
        Ok(ModelState { params, m: zeros.clone(), v: zeros, step: 0 })
    }

    pub fn param_count(&self) -> usize {
        self.params.iter().map(|t| t.len()).sum()
    }

    /// Verify leaf shapes against another artifact of the same geometry
    /// (used when the stage scheduler swaps executables, Fig 5a).
    pub fn compatible_with(&self, art: &Artifact) -> bool {
        self.params.len() == art.params.len()
            && self
                .params
                .iter()
                .zip(&art.params)
                .all(|(t, s)| t.shape == s.shape)
    }
}
